"""Fast serving-path smoke for CI (seconds, not the QPS grid).

The continuous-batching acceptance contract (ISSUE 8; DESIGN.md §14),
gated on every CI run under BOTH topologies (scripts/ci.sh):

  a short mixed read/write run through ``QueryEngine`` — many client
  streams, every bucket boundary, appends interleaved through the ring
  -> every answer bit-identical to an unbatched twin replaying the
  engine's ``write_log`` at the recorded MVCC versions -> p50/p99 read
  latency finite -> one trace per (site, bucket), zero retraces after
  warmup -> ONE version bump per flush (host mirror == device scalar).

Exits nonzero with a diagnostic on any violation.  Like
scripts/fault_smoke.py it runs on whatever topology the process has —
ci.sh invokes it plain and under a forced 8-device host mesh; with 8+
devices the engine serves on the real shard_map backend.
"""

import math
import sys

import numpy as np
import jax

sys.path.insert(0, "src")

from repro.core import Schema                              # noqa: E402
from repro.dist import mesh                                # noqa: E402
from repro.frame import IndexedFrame                       # noqa: E402
from repro.serving.query_engine import (QueryEngine,       # noqa: E402
                                        replay_unbatched)

FAILURES = []


def check(ok: bool, msg: str):
    print(("  OK   " if ok else "  FAIL ") + msg)
    if not ok:
        FAILURES.append(msg)


def main() -> int:
    ndev = len(jax.devices())
    s = 8 if ndev >= 8 else 4
    rt = mesh.mesh_runtime(s) if ndev >= s else None
    backend = "shard_map" if rt is not None else "vmap"
    print(f"serve smoke: {s} shards on the {backend} backend "
          f"({ndev} device(s))")

    rng = np.random.default_rng(8)
    n = 2048
    sch = Schema.of("k", k="int64", v="float32")
    cols = {"k": np.arange(n, dtype=np.int64),
            "v": rng.standard_normal(n).astype(np.float32)}
    twin = IndexedFrame.from_columns(cols, sch, num_shards=s,
                                     rows_per_batch=512, rt=rt)
    eng = QueryEngine(
        IndexedFrame.from_columns(cols, sch, num_shards=s,
                                  rows_per_batch=512, rt=rt),
        ladder=(8, 16, 32), max_matches=4, flush_deadline_ticks=2)

    # mixed traffic: reader streams covering every bucket boundary
    # (1, B, B+1, ladder max — plus misses and out-of-range keys), one
    # writer stream staging a delta per round; a tick per request so
    # each rung of the ladder actually compiles and is then reused
    reqs = []
    wi = 0
    for step in range(6):
        for stream, size in enumerate((1, 8, 9, 32)):
            q = rng.integers(-5, n + 20, size=size).astype(np.int64)
            reqs.append(eng.submit_lookup(q, stream_id=stream))
            eng.tick()
        eng.submit_append({"k": np.asarray([n + wi], np.int64),
                           "v": np.asarray([float(wi)], np.float32)},
                          stream_id=99)
        wi += 1
        eng.tick()
    eng.drain()

    summary = eng.latency_summary()
    p99 = summary["read"]["p99_ms"]
    check(all(r.done for r in reqs), f"all {len(reqs)} requests answered")
    check(math.isfinite(p99) and p99 > 0,
          f"p99 read latency finite ({p99:.3f} ms, "
          f"p50 {summary['read']['p50_ms']:.3f} ms)")
    mism = replay_unbatched(twin, reqs, eng.write_log)
    check(mism == 0,
          f"batched answers bit-identical to the unbatched twin "
          f"({mism} mismatching request(s) of {len(reqs)})")
    check(eng.zero_retraces_after_warmup,
          f"zero retraces after warmup ({eng.retraces} traces for "
          f"{eng.expected_traces} (site, bucket) pairs)")
    check(eng.stats.flushes >= 2,
          f"writes interleaved through the ring "
          f"({eng.stats.flushes} flushes, {eng.stats.writes} writes)")
    check(eng.verify_version(),
          f"one version bump per flush (host mirror "
          f"{eng.version_host} == device scalar)")

    if FAILURES:
        print(f"\nserve smoke: {len(FAILURES)} violation(s)")
        return 1
    print("serve smoke: all serving contracts hold")
    return 0


if __name__ == "__main__":
    sys.exit(main())
