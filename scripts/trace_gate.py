"""CI tracing-count gate (ISSUE 4 + ISSUE 5 / DESIGN.md §4, §11).

Fails (exit 1) if appends within a capacity class retrace ANY fused read
entry point:

* single table — ``fused_lookup`` (via ``IndexedTable.lookup``) and
  ``indexed_join`` call sites, 12 successive arena appends;
* distributed — the jitted ``dist.lookup`` site over 12
  ``append_distributed`` rounds, on the vmap backend always and on the
  shard_map backend when the process has >= 4 devices (scripts/ci.sh
  runs this gate under both topologies, so the forced-8 pass exercises
  shard_map even on single-device CI);
* the Frame API — the SAME jitted sites driven through ``IndexedFrame``
  (the frame as the jit argument, ``.lookup``/``.join`` inside): facade
  dispatch must add zero retraces (ISSUE 5 acceptance), local and
  distributed (broadcast AND routed flavors), appends through
  ``frame.append`` including the coalesced list form;
* the append queue — ``enqueue``/``flush`` driven through FULL ring
  wraps (every lane filled, flushed, refilled) must trace each site
  exactly ONCE per topology (ISSUE 7 / DESIGN.md §13), and the jitted
  read sites must not retrace as the ring fills and drains;
* the serving engine — the full pad-to-bucket ladder driven with
  varying request counts while appends interleave (ISSUE 8 /
  DESIGN.md §14): exactly one trace per (read site, bucket) rung on
  warmup, ZERO retraces on a second full-ladder pass;
* skew resilience — a tracked + replicated frame under hot-set CHURN
  (every append crowns a different celebrity key, each auto-refreshing
  the mirror): the hybrid lookup/join sites and the jitted replica
  refresh itself each compile exactly once per topology (ISSUE 9 /
  DESIGN.md §15 — the hot set and the mirror's freshness are data
  leaves, never treedef);
* partitioned retention — appends into one partition, a ``drop_partition``
  of another, and a rolling ``retain`` sweep leave every surviving
  partition's jitted read site compiled (ISSUE 10 / DESIGN.md §16:
  drop is a treedef-only removal, survivors' subtrees are the same
  objects, so the partition layer's site counters stay flat).

Fast by construction: tiny tables, one compile per site, zero retraces —
the whole gate is a few seconds of XLA work.

    PYTHONPATH=src python scripts/trace_gate.py
"""

import sys

import numpy as np
import jax
import jax.numpy as jnp

from repro import IndexedFrame
from repro.core import Schema, append, create_index, joins

SCH = Schema.of("k", k="int64", v="float32")
APPENDS = 12


def fail(msg: str):
    print(f"TRACE GATE FAIL: {msg}")
    sys.exit(1)


def gate_single_table():
    rng = np.random.default_rng(0)
    cols = {"k": rng.integers(0, 64, 400).astype(np.int64),
            "v": rng.random(400).astype(np.float32)}
    t = create_index(cols, SCH, rows_per_batch=64).with_flat_data()
    q = rng.integers(0, 64, 32).astype(np.int64)
    pc = {"pk": q, "tag": np.arange(32, dtype=np.int32)}
    counts = {"lookup": 0, "join": 0}

    @jax.jit
    def f_lookup(tbl, qq):
        counts["lookup"] += 1
        return tbl.lookup(qq, 4)[0]

    @jax.jit
    def f_join(tbl, p):
        counts["join"] += 1
        return joins.indexed_join(tbl, p, "pk", max_matches=4)

    jax.block_until_ready(f_lookup(t, q))
    jax.block_until_ready(f_join(t, pc)[2])
    for i in range(APPENDS):
        t = append(t, {"k": rng.integers(0, 64, 16).astype(np.int64),
                       "v": rng.random(16).astype(np.float32)})
        jax.block_until_ready(f_lookup(t, q))
        jax.block_until_ready(f_join(t, pc)[2])
    if counts["lookup"] != 1:
        fail(f"fused_lookup call site retraced: {counts['lookup']} traces "
             f"across {APPENDS} same-class appends (expected 1)")
    if counts["join"] != 1:
        fail(f"indexed_join call site retraced: {counts['join']} traces "
             f"across {APPENDS} same-class appends (expected 1)")
    print(f"  single-table: 1 compile per site across {APPENDS} appends")


def gate_distributed(rt, label):
    from repro import dist
    rng = np.random.default_rng(1)
    cols = {"k": rng.integers(0, 200, 800).astype(np.int64),
            "v": rng.random(800).astype(np.float32)}
    shards = 4
    dt = dist.create_distributed(cols, SCH, shards, rows_per_batch=64,
                                 rt=rt)
    q = jnp.asarray(rng.choice(cols["k"], 32).astype(np.int64))
    counts = {"lookup": 0}

    @jax.jit
    def f(d, qq):
        counts["lookup"] += 1
        return dist.lookup(d, qq, max_matches=4, rt=rt)[1]

    jax.block_until_ready(f(dt, q))
    for i in range(APPENDS):
        dt = dist.append_distributed(
            dt, {"k": rng.integers(0, 200, 8).astype(np.int64),
                 "v": rng.random(8).astype(np.float32)}, rt=rt)
        jax.block_until_ready(f(dt, q))
    if counts["lookup"] != 1:
        fail(f"dist.lookup ({label}) retraced: {counts['lookup']} traces "
             f"across {APPENDS} same-class appends (expected 1)")
    print(f"  dist ({label}): 1 compile across {APPENDS} appends")


def gate_frame_single():
    """Facade dispatch adds zero retraces: the frame IS the jit argument."""
    rng = np.random.default_rng(2)
    cols = {"k": rng.integers(0, 64, 400).astype(np.int64),
            "v": rng.random(400).astype(np.float32)}
    fr = IndexedFrame.from_columns(cols, SCH,
                                   rows_per_batch=64).with_flat_data()
    q = jnp.asarray(rng.integers(0, 64, 32).astype(np.int64))
    pc = {"pk": q, "tag": jnp.arange(32, dtype=jnp.int32)}
    counts = {"lookup": 0, "join": 0}

    @jax.jit
    def f_lookup(frame, qq):
        counts["lookup"] += 1
        return frame.lookup(qq, max_matches=4)[1]

    @jax.jit
    def f_join(frame, p):
        counts["join"] += 1
        return frame.join(p, "pk", max_matches=4)[2]

    jax.block_until_ready(f_lookup(fr, q))
    jax.block_until_ready(f_join(fr, pc))
    for i in range(APPENDS):
        delta = {"k": rng.integers(0, 64, 8).astype(np.int64),
                 "v": rng.random(8).astype(np.float32)}
        # alternate single-delta and coalesced-list appends: both must
        # keep the frame structurally equal to its parent
        fr = fr.append([delta, delta] if i % 2 else delta)
        jax.block_until_ready(f_lookup(fr, q))
        jax.block_until_ready(f_join(fr, pc))
    for site, n in counts.items():
        if n != 1:
            fail(f"IndexedFrame.{site} call site retraced: {n} traces "
                 f"across {APPENDS} same-class appends (expected 1)")
    print(f"  frame (local): 1 compile per site across {APPENDS} appends")


def gate_frame_distributed(rt, label):
    rng = np.random.default_rng(3)
    cols = {"k": rng.integers(0, 200, 800).astype(np.int64),
            "v": rng.random(800).astype(np.float32)}
    fr = IndexedFrame.from_columns(cols, SCH, num_shards=4,
                                   rows_per_batch=64, rt=rt)
    q = jnp.asarray(rng.choice(cols["k"], 32).astype(np.int64))
    counts = {"bcast": 0, "routed": 0}

    @jax.jit
    def f_bcast(frame, qq):
        counts["bcast"] += 1
        return frame.lookup(qq, max_matches=4)[1]     # auto -> L2 bcast

    @jax.jit
    def f_routed(frame, qq):
        counts["routed"] += 1
        return frame.lookup(qq, max_matches=4, op="routed")[1]

    jax.block_until_ready(f_bcast(fr, q))
    jax.block_until_ready(f_routed(fr, q))
    for i in range(APPENDS):
        fr = fr.append({"k": rng.integers(0, 200, 8).astype(np.int64),
                        "v": rng.random(8).astype(np.float32)})
        jax.block_until_ready(f_bcast(fr, q))
        jax.block_until_ready(f_routed(fr, q))
    for site, n in counts.items():
        if n != 1:
            fail(f"IndexedFrame.lookup[{site}] ({label}) retraced: {n} "
                 f"traces across {APPENDS} same-class appends (expected 1)")
    print(f"  frame ({label}): 1 compile per flavor across "
          f"{APPENDS} appends")


def gate_queue(rt, label):
    """enqueue/flush across ≥2 FULL ring wraps: one trace per site, and
    the jitted read site stays compiled while the ring fills/drains."""
    from repro.core import table as table_mod
    rng = np.random.default_rng(4)
    cols = {"k": rng.integers(0, 64, 400).astype(np.int64),
            "v": rng.random(400).astype(np.float32)}
    kw = {} if rt is None else dict(num_shards=4, rt=rt)
    fr = IndexedFrame.from_columns(cols, SCH, rows_per_batch=64,
                                   reserve=4096, **kw).with_queue(
                                       lanes=3, lane_rows=16)
    q = jnp.asarray(rng.integers(0, 64, 32).astype(np.int64))
    counts = {"lookup": 0}

    @jax.jit
    def f_lookup(frame, qq):
        counts["lookup"] += 1
        return frame.lookup(qq, max_matches=4)[1]

    jax.block_until_ready(f_lookup(fr, q))
    base = dict(table_mod.QUEUE_TRACES)
    wraps, traced = 3, None
    for wrap in range(wraps):
        for i in range(fr.queue.lanes):       # fill EVERY lane
            fr = fr.enqueue(
                {"k": rng.integers(0, 64, 8).astype(np.int64),
                 "v": rng.random(8).astype(np.float32)}, donate=False)
        fr = fr.flush()
        jax.block_until_ready(f_lookup(fr, q))
        if wrap == 0:
            traced = dict(table_mod.QUEUE_TRACES)
    for site in ("enqueue", "flush"):
        first = traced[site] - base[site]
        later = table_mod.QUEUE_TRACES[site] - traced[site]
        if first != 1 or later != 0:
            fail(f"queue {site} ({label}): {first} first-wrap + {later} "
                 f"later traces across {wraps} full ring wraps "
                 f"(expected 1 + 0)")
    if counts["lookup"] != 1:
        fail(f"read site ({label}) retraced {counts['lookup']}x while the "
             f"ring wrapped (expected 1)")
    print(f"  queue ({label}): 1 trace per site across {wraps} "
          f"full ring wraps")


def gate_serving(rt, label):
    """ISSUE 8: the QueryEngine's pad-to-bucket contract — drive the
    FULL bucket ladder with varying request counts while appends
    interleave through the ring; exactly one trace per (site, bucket)
    on pass 1, ZERO new traces on pass 2."""
    from repro.serving.query_engine import QueryEngine
    rng = np.random.default_rng(5)
    n = 512
    cols = {"k": np.arange(n, dtype=np.int64),
            "v": rng.random(n).astype(np.float32)}
    kw = {} if rt is None else dict(num_shards=4, rt=rt)
    fr = IndexedFrame.from_columns(cols, SCH, rows_per_batch=64,
                                   reserve=4096, **kw)
    eng = QueryEngine(fr, ladder=(4, 8, 16), max_matches=4,
                      flush_deadline_ticks=2)
    # every rung, from every side of its boundary, several request
    # counts per tick — with a write staged between ticks
    sizes = [1, 3, 4, 5, 8, 9, 16]
    warm = None
    for pas in range(2):
        for i, s in enumerate(sizes):
            for _ in range(1 + i % 2):
                eng.submit_lookup(rng.integers(0, n, s).astype(np.int64))
                eng.tick()
            eng.submit_append(
                {"k": rng.integers(0, n, 4).astype(np.int64),
                 "v": rng.random(4).astype(np.float32)})
            eng.tick()
        if pas == 0:
            warm = eng.retraces
            if warm != eng.expected_traces or warm != len(eng.ladder):
                fail(f"serving ({label}): {warm} warmup traces for "
                     f"{eng.expected_traces} (site, bucket) pairs over a "
                     f"{len(eng.ladder)}-rung ladder (expected equal)")
    if eng.retraces != warm:
        fail(f"serving ({label}): {eng.retraces - warm} retraces on the "
             f"second full-ladder pass (expected 0)")
    if not eng.zero_retraces_after_warmup:
        fail(f"serving ({label}): zero_retraces_after_warmup is False "
             f"({eng.retraces} traces, {eng.expected_traces} expected)")
    print(f"  serving ({label}): {warm} traces = one per ladder rung, "
          f"0 on pass 2 ({eng.stats.batches} batches, "
          f"{eng.stats.flushes} flushes interleaved)")


def gate_skew(rt, label):
    """ISSUE 9: hot-set churn (appends crowning a ROTATING celebrity key,
    each auto-refreshing the mirror) never retraces the hybrid read
    sites, and the jitted replica refresh compiles once per topology."""
    from repro.dist import dtable as _dd
    rng = np.random.default_rng(6)
    cols = {"k": rng.integers(0, 200, 800).astype(np.int64),
            "v": rng.random(800).astype(np.float32)}
    fr = IndexedFrame.from_columns(cols, SCH, num_shards=4,
                                   rows_per_batch=64, reserve=4096, rt=rt,
                                   track_hot=8)
    fr = fr.with_replica(capacity=8, max_matches=4)
    base_refresh = _dd.REPLICA_TRACES["refresh"]
    q = jnp.asarray(rng.integers(0, 200, 32).astype(np.int64))
    pc = {"pk": q, "tag": jnp.arange(32, dtype=jnp.int32)}
    counts = {"lookup": 0, "join": 0}

    @jax.jit
    def f_lookup(frame, qq):
        counts["lookup"] += 1
        return frame.lookup(qq, max_matches=4, op="hybrid")[1]

    @jax.jit
    def f_join(frame, p):
        counts["join"] += 1
        return frame.join(p, "pk", max_matches=4, op="hybrid")[2]

    jax.block_until_ready(f_lookup(fr, q))
    jax.block_until_ready(f_join(fr, pc))
    for i in range(APPENDS):
        hot_key = np.int64(i % 5)   # a different celebrity every append
        fr = fr.append({"k": np.full(12, hot_key),
                        "v": rng.random(12).astype(np.float32)})
        jax.block_until_ready(f_lookup(fr, q))
        jax.block_until_ready(f_join(fr, pc))
    for site, n in counts.items():
        if n != 1:
            fail(f"hybrid {site} ({label}) retraced: {n} traces across "
                 f"{APPENDS} hot-churn appends (expected 1)")
    refreshes = _dd.REPLICA_TRACES["refresh"] - base_refresh
    if refreshes != 1:
        fail(f"replica refresh ({label}) retraced: {refreshes} traces "
             f"across {APPENDS} auto-refreshing appends (expected 1)")
    print(f"  skew ({label}): hybrid sites + refresh compiled once "
          f"across {APPENDS} hot-churn appends")


def gate_partition(rt, label):
    """ISSUE 10: partitioned retention — appends landing in ONE
    partition, a drop of ANOTHER, and a rolling ``retain`` sweep must
    leave every surviving read site compiled: survivors' subtrees are
    the same objects (drop is treedef-only), so the partition layer's
    per-partition jitted sites never retrace (DESIGN.md §16)."""
    from repro.core import partition as partition_mod
    from repro.frame import PartitionSpec
    rng = np.random.default_rng(7)
    spec = PartitionSpec.range_("k", [0, 64, 128, 192, 256],
                                ids=["p0", "p1", "p2", "p3"])
    cols = {"k": rng.integers(0, 256, 600).astype(np.int64),
            "v": rng.random(600).astype(np.float32)}
    kw = {} if rt is None else dict(num_shards=4, rt=rt)
    fr = IndexedFrame.from_columns(cols, SCH, rows_per_batch=64,
                                   partition_by=spec, **kw)
    q = rng.integers(0, 256, 32).astype(np.int64)
    pc = {"pk": q, "tag": np.arange(32, dtype=np.int32)}
    base = partition_mod.site_traces()
    base_exp = partition_mod.expected_site_traces()

    def read():
        jax.block_until_ready(fr.lookup(q, max_matches=4)[1])
        jax.block_until_ready(fr.join(pc, "pk", max_matches=4)[2])

    read()                                      # warmup: compile the sites
    warm = partition_mod.site_traces() - base
    for i in range(APPENDS):                    # appends into ONE partition
        fr = fr.append({"k": rng.integers(64, 128, 8).astype(np.int64),
                        "v": rng.random(8).astype(np.float32)})
        read()
    fr = fr.drop_partition("p3")                # drop of ANOTHER partition
    read()
    fr = fr.retain(min_value=64)                # rolling retention sweep
    read()
    traced = partition_mod.site_traces() - base
    expected = partition_mod.expected_site_traces() - base_exp
    if traced != warm:
        fail(f"partition ({label}): {traced - warm} retraces of surviving "
             f"read sites across {APPENDS} appends + drop + retain "
             f"(expected 0 after {warm} warmup traces)")
    if traced != expected:
        fail(f"partition ({label}): {traced} traces vs {expected} distinct "
             f"site fingerprints (expected equal)")
    if fr.num_partitions != 2:
        fail(f"partition ({label}): expected 2 surviving partitions, "
             f"got {fr.num_partitions}")
    print(f"  partition ({label}): {warm} site compiles, 0 retraces "
          f"across {APPENDS} appends + drop + retain")


def main():
    print(f"trace gate: {len(jax.devices())} device(s), "
          f"backend={jax.default_backend()}")
    gate_single_table()
    gate_frame_single()
    gate_queue(None, "local")
    gate_serving(None, "local")
    gate_partition(None, "local")
    try:
        from repro.dist import mesh
    except ImportError:
        print("  dist layer unavailable; single-table gate only")
        return
    gate_distributed(mesh.vmap_runtime(), "vmap")
    gate_frame_distributed(mesh.vmap_runtime(), "vmap")
    gate_queue(mesh.vmap_runtime(), "vmap")
    gate_serving(mesh.vmap_runtime(), "vmap")
    gate_skew(mesh.vmap_runtime(), "vmap")
    gate_partition(mesh.vmap_runtime(), "vmap")
    if len(jax.devices()) >= 4:
        gate_distributed(mesh.mesh_runtime(4), "shard_map")
        gate_frame_distributed(mesh.mesh_runtime(4), "shard_map")
        gate_queue(mesh.mesh_runtime(4), "shard_map")
        gate_serving(mesh.mesh_runtime(4), "shard_map")
        gate_skew(mesh.mesh_runtime(4), "shard_map")
        gate_partition(mesh.mesh_runtime(4), "shard_map")
    else:
        print("  shard_map gate skipped (<4 devices; ci.sh's forced-8 "
              "pass covers it)")
    print("TRACE GATE OK")


if __name__ == "__main__":
    main()
