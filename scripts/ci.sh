#!/usr/bin/env bash
# CI gate: tier-1 tests, example smoke runs, and the two quick benchmarks
# that back the committed artifacts (BENCH_lookup.json / BENCH_dist.json).
#
#   bash scripts/ci.sh            # full gate (~20 min on CPU)
#   bash scripts/ci.sh --fast     # tests + examples only
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}

echo "== tier-1 pytest =="
python -m pytest -q

echo "== example smoke =="
python scripts/smoke_examples.py

if [[ "${1:-}" != "--fast" ]]; then
  echo "== quick benchmarks =="
  python -m benchmarks.run --only lookup_path --out /tmp/ci_bench_lookup.json
  python -m benchmarks.run --only fault_tolerance --out /tmp/ci_bench_dist.json
fi

echo "CI gate OK"
