#!/usr/bin/env bash
# CI gate: tier-1 tests under BOTH device topologies, example smoke runs,
# and the quick benchmarks that back the committed artifacts
# (BENCH_lookup.json / BENCH_dist.json / BENCH_scale.json).
#
#   bash scripts/ci.sh            # full gate (~30 min on CPU)
#   bash scripts/ci.sh --fast     # tests + examples only
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}

# Public-API drift gate (ISSUE 5): fails when the exported surface no
# longer matches the committed api_surface.txt — API changes must be
# declared (regenerate the file), never accidental.
echo "== public API surface =="
python scripts/api_surface.py --check

# Fast tracing-count gate (seconds): fails if appends within a
# capacity class retrace any fused read entry point — free functions AND
# the IndexedFrame facade (ISSUE 4 + 5 acceptance; DESIGN.md §4, §11).
# Run under both topologies so the shard_map backend's gate executes
# even on single-device CI.
echo "== trace gate (single device) =="
python scripts/trace_gate.py
echo "== trace gate (forced 8-device host mesh) =="
XLA_FLAGS="${XLA_FLAGS:-} --xla_force_host_platform_device_count=8" \
  python scripts/trace_gate.py

# Fast fault-injection smoke (seconds): a seeded shard kill through the
# supervised frame must heal automatically — bit-identical answers vs a
# never-failed twin, ONE trace of the fused read site, replay bounded by
# the checkpoint suffix (ISSUE 6 acceptance; DESIGN.md §12).  Both
# topologies, so the recovery state machine runs on shard_map too.
echo "== fault smoke (single device) =="
python scripts/fault_smoke.py
echo "== fault smoke (forced 8-device host mesh) =="
XLA_FLAGS="${XLA_FLAGS:-} --xla_force_host_platform_device_count=8" \
  python scripts/fault_smoke.py

# Fast serving smoke (seconds): a short mixed read/write run through
# the continuous-batching QueryEngine — p99 finite, every answer
# bit-identical to an unbatched twin replaying the engine's write_log,
# zero retraces after warmup, one version bump per flush (ISSUE 8
# acceptance; DESIGN.md §14).  Both topologies, plus an explicit run of
# the serving suite (it also rides the full tier-1 passes below — the
# forced-8 pass runs the in-process shard_map serving tests).
echo "== serve smoke (single device) =="
python scripts/serve_smoke.py
echo "== serve smoke (forced 8-device host mesh) =="
XLA_FLAGS="${XLA_FLAGS:-} --xla_force_host_platform_device_count=8" \
  python scripts/serve_smoke.py
echo "== serving suite (single device) =="
python -m pytest -q tests/test_serving.py tests/test_query_engine.py
echo "== serving suite (forced 8-device host mesh) =="
XLA_FLAGS="${XLA_FLAGS:-} --xla_force_host_platform_device_count=8" \
  python -m pytest -q tests/test_serving.py tests/test_query_engine.py

echo "== tier-1 pytest (single device) =="
python -m pytest -q

# Second pass on a forced 8-device host mesh: the shard_map backend's
# parity suite (tests/test_mesh_parity.py) runs its full in-process
# matrix here instead of skipping to the subprocess fallback.
echo "== tier-1 pytest (forced 8-device host mesh) =="
XLA_FLAGS="${XLA_FLAGS:-} --xla_force_host_platform_device_count=8" \
  python -m pytest -q

echo "== example smoke =="
python scripts/smoke_examples.py

if [[ "${1:-}" != "--fast" ]]; then
  echo "== quick benchmarks =="
  python -m benchmarks.run --only lookup_path --out /tmp/ci_bench_lookup.json
  python -m benchmarks.run --only fault_tolerance --out /tmp/ci_bench_dist.json
  python -m benchmarks.run --only scalability --out /tmp/ci_bench_scale.json
fi

echo "CI gate OK"
