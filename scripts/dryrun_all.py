"""Run the full dry-run matrix: every applicable (arch x shape) cell on
the single-pod (16,16) and multi-pod (2,16,16) meshes.

One subprocess per cell (isolates failures, bounds memory); resumable —
cells already recorded in the output JSONL are skipped.

    PYTHONPATH=src python scripts/dryrun_all.py --out experiments/dryrun.jsonl
    PYTHONPATH=src python scripts/dryrun_all.py --multi-pod --out experiments/dryrun_mp.jsonl
"""

import argparse
import json
import os
import subprocess
import sys
import time

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
sys.path.insert(0, SRC)

from repro.configs import ARCH_IDS, REGISTRY, SHAPES, applicable  # noqa: E402

# per-arch microbatch counts for train_4k (memory fit; DESIGN.md §6)
TRAIN_MICROBATCH = {
    "deepseek-v3-671b": 8,
    "jamba-v0.1-52b": 8,
    "gemma3-4b": 8,        # 262k vocab logits
    "whisper-large-v3": 4,
    "default": 4,
}

# archs whose params exceed single-axis TP sharding: FSDP over data too.
# For train always; for serving shapes only when 16-way TP still exceeds
# HBM (ds-v3: 1.34 TB bf16 / 16 = 84 GB per device; jamba: 104/16 = 6.5 GB
# fits, so serving keeps weights TP-only and avoids per-token re-gathers).
FSDP_ARCHS_TRAIN = {"deepseek-v3-671b", "jamba-v0.1-52b"}
FSDP_ARCHS_ALWAYS = {"deepseek-v3-671b"}


def cells():
    for arch in ARCH_IDS:
        cfg = REGISTRY[arch].full()
        for shape in SHAPES:
            if applicable(cfg, shape):
                yield arch, shape


def recorded(path):
    done = set()
    if os.path.exists(path):
        with open(path) as f:
            for line in f:
                try:
                    r = json.loads(line)
                except json.JSONDecodeError:
                    continue
                if r.get("ok"):
                    done.add((r["arch"], r["shape"]))
    return done


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--timeout", type=int, default=7200)
    ap.add_argument("--only-arch")
    ap.add_argument("--save-hlo")
    args = ap.parse_args()

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    done = recorded(args.out)
    todo = [(a, s) for a, s in cells()
            if (a, s) not in done
            and (not args.only_arch or a == args.only_arch)]
    print(f"{len(done)} cells recorded, {len(todo)} to run")

    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    failures = []
    for i, (arch, shape) in enumerate(todo):
        mb = TRAIN_MICROBATCH.get(arch, TRAIN_MICROBATCH["default"]) \
            if shape == "train_4k" else 1
        cmd = [sys.executable, "-m", "repro.launch.dryrun",
               "--arch", arch, "--shape", shape, "--out", args.out,
               "--microbatches", str(mb)]
        if arch in FSDP_ARCHS_ALWAYS or \
                (arch in FSDP_ARCHS_TRAIN and shape == "train_4k"):
            cmd.append("--fsdp")
        if args.save_hlo:
            cmd += ["--save-hlo", args.save_hlo]
        if args.multi_pod:
            cmd.append("--multi-pod")
        t0 = time.time()
        print(f"[{i + 1}/{len(todo)}] {arch} x {shape} (mb={mb})...",
              flush=True)
        r = subprocess.run(cmd, env=env, timeout=args.timeout,
                           capture_output=True, text=True)
        tail = "\n".join((r.stdout + r.stderr).splitlines()[-4:])
        print(f"    rc={r.returncode} in {time.time() - t0:.0f}s\n"
              + "\n".join("    " + l for l in tail.splitlines()),
              flush=True)
        if r.returncode != 0:
            failures.append((arch, shape))
    print(f"\ndone; {len(failures)} failures: {failures}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
