"""Re-run launch/hlo.py analysis over saved HLO modules — metric updates
without recompiling.

    PYTHONPATH=src python scripts/reanalyze.py experiments/dryrun_single.jsonl
"""

import gzip
import json
import os
import sys

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
sys.path.insert(0, SRC)

from repro.launch import hlo  # noqa: E402


def main():
    path = sys.argv[1]
    hlo_dir = sys.argv[2] if len(sys.argv) > 2 else "experiments/hlo"
    out = []
    updated = 0
    for line in open(path):
        r = json.loads(line)
        f = r.get("hlo_file")
        if r.get("ok") and f and os.path.exists(os.path.join(hlo_dir, f)):
            chips = 1
            for v in r["mesh"].values():
                chips *= v
            with gzip.open(os.path.join(hlo_dir, f), "rt") as fh:
                ana = hlo.analyze(fh.read(), total_devices=chips)
            r["collectives"] = ana["collectives"]
            r["collective_wire_bytes"] = ana["collective_wire_bytes"]
            r["dot_flops"] = ana["dot_flops"]
            r["hbm_bytes"] = ana["hbm_bytes"]
            updated += 1
        out.append(r)
    with open(path, "w") as fh:
        for r in out:
            fh.write(json.dumps(r) + "\n")
    print(f"re-analyzed {updated}/{len(out)} records")


if __name__ == "__main__":
    main()
