"""Regenerate the §Dry-run and §Roofline tables in EXPERIMENTS.md from the
dry-run JSONL records.

    PYTHONPATH=src python scripts/make_experiments.py
"""

import json
import os
import sys

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
sys.path.insert(0, SRC)

from repro.configs import REGISTRY  # noqa: E402
from repro.launch import roofline  # noqa: E402

SINGLE = "experiments/dryrun_single.jsonl"
MP = "experiments/dryrun_mp.jsonl"


def load(path):
    cells = {}
    if not os.path.exists(path):
        return cells
    for line in open(path):
        r = json.loads(line)
        if r.get("ok"):
            cells[(r["arch"], r["shape"])] = r
    return cells


def gib(b):
    return f"{b / 2**30:.2f}"


def dryrun_table(single, mp):
    hdr = ("| arch | shape | kind | mesh 16x16: args+temp GiB/dev "
           "(compile s) | mesh 2x16x16: args+temp GiB/dev (compile s) | "
           "collectives (single-pod: AR/AG/A2A/CP count) |\n"
           "|---|---|---|---|---|---|")
    lines = [hdr]
    for key in sorted(single):
        r, m = single[key], mp.get(key)
        mem = r["memory"]
        cell1 = (f"{gib(mem['argument_size_in_bytes'])}+"
                 f"{gib(mem['temp_size_in_bytes'])} "
                 f"({r['compile_seconds']})")
        if m:
            mm = m["memory"]
            cell2 = (f"{gib(mm['argument_size_in_bytes'])}+"
                     f"{gib(mm['temp_size_in_bytes'])} "
                     f"({m['compile_seconds']})")
        else:
            cell2 = "—"
        c = r["collectives"]
        cc = "/".join(str(int(c.get(k, {}).get("count", 0)))
                      for k in ("all-reduce", "all-gather", "all-to-all",
                                "collective-permute"))
        lines.append(f"| {key[0]} | {key[1]} | {r['kind']} | {cell1} | "
                     f"{cell2} | {cc} |")
    return "\n".join(lines)


NOTES = {
    ("compute",): "raise arithmetic intensity (larger per-device tiles, "
                  "fewer remat recomputes)",
    ("memory",): "cut HBM traffic: fewer remat passes / bf16 saves / "
                 "larger fused blocks",
    ("collective",): "overlap or shrink collectives: SP reduce-scatter, "
                     "bf16 combines, fewer FSDP re-gathers",
}


def roofline_table(single):
    rows = []
    for (arch, shape), rec in sorted(single.items()):
        cfg = REGISTRY[arch].full() if arch in REGISTRY else None
        t = roofline.terms(rec, cfg)
        rows.append((t, rec))
    hdr = ("| arch | shape | compute | memory (lo–hi) | collective | "
           "dominant | MODEL/HLO flops | bound step | note |\n"
           "|---|---|---|---|---|---|---|---|---|")
    lines = [hdr]
    for t, rec in rows:
        note = NOTES[(t["dominant"],)]
        lines.append(
            f"| {t['arch']} | {t['shape']} | "
            f"{roofline._fmt_s(t['compute_s'])} | "
            f"{roofline._fmt_s(t['memory_lo_s'])}–"
            f"{roofline._fmt_s(t['memory_hi_s'])} | "
            f"{roofline._fmt_s(t['collective_s'])} | **{t['dominant']}** | "
            f"{t['useful_ratio']:.2f} | "
            f"{roofline._fmt_s(t['step_bound_s'])} | {note} |")
    return "\n".join(lines)


def splice(text, marker, table):
    tag = f"<!-- {marker} -->"
    assert tag in text, marker
    pre, _, rest = text.partition(tag)
    # drop any previously generated table (up to the next blank-blank or
    # next section header)
    lines = rest.splitlines()
    keep = []
    skipping = True
    for i, l in enumerate(lines):
        if skipping and (l.startswith("|") or not l.strip()):
            continue
        skipping = False
        keep = lines[i:]
        break
    return pre + tag + "\n\n" + table + "\n\n" + "\n".join(keep)


def main():
    single, mp = load(SINGLE), load(MP)
    with open("EXPERIMENTS.md") as f:
        text = f.read()
    text = splice(text, "DRYRUN_TABLE", dryrun_table(single, mp))
    text = splice(text, "ROOFLINE_TABLE", roofline_table(single))
    with open("EXPERIMENTS.md", "w") as f:
        f.write(text)
    print(f"wrote tables: {len(single)} single-pod cells, "
          f"{len(mp)} multi-pod cells")


if __name__ == "__main__":
    main()
