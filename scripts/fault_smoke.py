"""Fast fault-injection smoke for CI (seconds, not the chaos sweep).

The self-healing acceptance contract (ISSUE 6; DESIGN.md §12), gated on
every CI run under BOTH topologies (scripts/ci.sh):

  inject a seeded shard kill through ``IndexedFrame.supervised`` ->
  recovery is automatic (no caller-side handling) -> every post-recovery
  answer is bit-identical to a never-failed twin frame -> the fused read
  site traced exactly ONCE (zero recompiles across kill + heal + appends)
  -> replay cost was the checkpoint-anchored suffix, not full history.

Second scenario (ISSUE 7 / DESIGN.md §13): the SAME kill lands mid-ring —
deltas staged in the device-resident append queue but not yet flushed.
The supervisor's host-side pending mirror must rebuild the lost shard's
ring lanes deterministically, and the eventual flush must land
bit-identical to a never-failed twin streaming the same deltas.

Exits nonzero with a diagnostic on any violation.  Like
scripts/trace_gate.py it runs on whatever topology the process has —
ci.sh invokes it plain and under a forced 8-device host mesh; with 8+
devices the supervised frame runs on the real shard_map backend.
"""

import sys
import tempfile

import numpy as np
import jax

sys.path.insert(0, "src")

from repro.core import Schema                              # noqa: E402
from repro.dist import mesh                                # noqa: E402
from repro.dist.resilience import (Fault, FaultInjector,   # noqa: E402
                                   RecoveryPolicy)
from repro.dist.runtime import Lineage                     # noqa: E402
from repro.frame import IndexedFrame                       # noqa: E402

FAILURES = []


def check(ok: bool, msg: str):
    print(("  OK   " if ok else "  FAIL ") + msg)
    if not ok:
        FAILURES.append(msg)


def main() -> int:
    ndev = len(jax.devices())
    s = 8 if ndev >= 8 else 4
    rt = mesh.mesh_runtime(s) if ndev >= s else None
    backend = "shard_map" if rt is not None else "vmap"
    print(f"fault smoke: {s} shards on the {backend} backend "
          f"({ndev} device(s))")

    rng = np.random.default_rng(11)
    n = 2048
    sch = Schema.of("k", k="int64", v="float32")
    cols = {"k": np.arange(n, dtype=np.int64),
            "v": rng.standard_normal(n).astype(np.float32)}
    frame = IndexedFrame.from_columns(cols, sch, num_shards=s,
                                      rows_per_batch=512, rt=rt)
    twin = IndexedFrame.from_columns(cols, sch, num_shards=s,
                                     rows_per_batch=512, rt=rt)
    with tempfile.TemporaryDirectory() as ckpt_dir:
        mgr = frame.supervised(
            lineage=Lineage(sch, cols, rows_per_batch=512),
            injector=FaultInjector([Fault("shard_loss", step=3,
                                          shard=s - 1)], seed=11),
            policy=RecoveryPolicy(checkpoint_every=2),
            checkpoint_dir=ckpt_dir)
        q = rng.integers(0, n, size=64).astype(np.int64)
        identical = True
        for step in range(6):
            c, v = mgr.lookup(q, max_matches=4)
            tc, tv = twin.lookup(q, max_matches=4)
            identical &= np.array_equal(np.asarray(v), np.asarray(tv))
            for k in tc:
                identical &= np.array_equal(np.asarray(c[k]),
                                            np.asarray(tc[k]))
            delta = {"k": np.asarray([n + step], np.int64),
                     "v": np.asarray([float(step)], np.float32)}
            mgr.append(delta)
            twin = twin.append(delta)

        check(mgr.stats.recoveries == 1,
              f"exactly one automatic recovery "
              f"(got {mgr.stats.recoveries})")
        check(not mgr.dead, f"no shard left unrecovered (dead={mgr.dead})")
        check(identical,
              "every answer bit-identical to the never-failed twin")
        check(mgr.retraces == 1,
              f"fused read site traced once across kill + heal + appends "
              f"(got {mgr.retraces})")
        replayed = mgr.stats.replayed_deltas
        check(bool(replayed) and replayed[0] <= 2,
              f"replay bounded by the checkpoint suffix "
              f"(replayed {replayed} of {mgr.stats.appends} deltas)")

    ring_scenario(s, rt)

    if FAILURES:
        print(f"\nfault smoke: {len(FAILURES)} violation(s)")
        return 1
    print("fault smoke: all recovery contracts hold")
    return 0


def ring_scenario(s: int, rt):
    """Kill a shard while its append ring holds staged, unflushed deltas."""
    print("ring scenario: shard kill mid-ring (staged deltas unflushed)")
    rng = np.random.default_rng(23)
    n = 2048
    sch = Schema.of("k", k="int64", v="float32")
    cols = {"k": np.arange(n, dtype=np.int64),
            "v": rng.standard_normal(n).astype(np.float32)}
    deltas = [{"k": np.asarray([n + i], np.int64),
               "v": np.asarray([float(i)], np.float32)} for i in range(4)]
    frame = IndexedFrame.from_columns(cols, sch, num_shards=s,
                                      rows_per_batch=512,
                                      rt=rt).with_queue(lanes=4,
                                                        lane_rows=512)
    twin = IndexedFrame.from_columns(cols, sch, num_shards=s,
                                     rows_per_batch=512,
                                     rt=rt).with_queue(lanes=4,
                                                       lane_rows=512)
    with tempfile.TemporaryDirectory() as ckpt_dir:
        # step 3 = the third enqueue: two deltas already staged in the
        # ring, none flushed — the kill erases the shard's ring lanes too
        mgr = frame.supervised(
            lineage=Lineage(sch, cols, rows_per_batch=512),
            injector=FaultInjector([Fault("shard_loss", step=3,
                                          shard=s - 1)], seed=23),
            policy=RecoveryPolicy(checkpoint_every=2),
            checkpoint_dir=ckpt_dir)
        for d in deltas:
            mgr.enqueue(d)
            twin = twin.enqueue(d, donate=False)
        mgr.flush()
        twin = twin.flush()

        q = np.concatenate([rng.integers(0, n, 60),
                            np.arange(n, n + 4)]).astype(np.int64)
        c, v = mgr.lookup(q, max_matches=4)
        tc, tv = twin.lookup(q, max_matches=4)
        identical = np.array_equal(np.asarray(v), np.asarray(tv))
        for k in tc:
            identical &= np.array_equal(np.asarray(c[k]), np.asarray(tc[k]))
        check(mgr.stats.recoveries == 1,
              f"one automatic mid-ring recovery "
              f"(got {mgr.stats.recoveries})")
        check(not mgr.dead, f"no shard left unrecovered (dead={mgr.dead})")
        check(identical,
              "flushed ring bit-identical to the never-failed twin")
        check(mgr.stats.enqueues == 4 and mgr.stats.flushes == 1,
              f"supervisor counted the stream (enqueues="
              f"{mgr.stats.enqueues}, flushes={mgr.stats.flushes})")
        check(mgr.frame.pending_rows == 0,
              f"ring drained after flush "
              f"(pending={mgr.frame.pending_rows})")
        check(mgr.frame.version == twin.version,
              f"one version bump for the whole ring (supervised="
              f"{mgr.frame.version}, twin={twin.version})")


if __name__ == "__main__":
    sys.exit(main())
