"""Fast fault-injection smoke for CI (seconds, not the chaos sweep).

The self-healing acceptance contract (ISSUE 6; DESIGN.md §12), gated on
every CI run under BOTH topologies (scripts/ci.sh):

  inject a seeded shard kill through ``IndexedFrame.supervised`` ->
  recovery is automatic (no caller-side handling) -> every post-recovery
  answer is bit-identical to a never-failed twin frame -> the fused read
  site traced exactly ONCE (zero recompiles across kill + heal + appends)
  -> replay cost was the checkpoint-anchored suffix, not full history.

Exits nonzero with a diagnostic on any violation.  Like
scripts/trace_gate.py it runs on whatever topology the process has —
ci.sh invokes it plain and under a forced 8-device host mesh; with 8+
devices the supervised frame runs on the real shard_map backend.
"""

import sys
import tempfile

import numpy as np
import jax

sys.path.insert(0, "src")

from repro.core import Schema                              # noqa: E402
from repro.dist import mesh                                # noqa: E402
from repro.dist.resilience import (Fault, FaultInjector,   # noqa: E402
                                   RecoveryPolicy)
from repro.dist.runtime import Lineage                     # noqa: E402
from repro.frame import IndexedFrame                       # noqa: E402

FAILURES = []


def check(ok: bool, msg: str):
    print(("  OK   " if ok else "  FAIL ") + msg)
    if not ok:
        FAILURES.append(msg)


def main() -> int:
    ndev = len(jax.devices())
    s = 8 if ndev >= 8 else 4
    rt = mesh.mesh_runtime(s) if ndev >= s else None
    backend = "shard_map" if rt is not None else "vmap"
    print(f"fault smoke: {s} shards on the {backend} backend "
          f"({ndev} device(s))")

    rng = np.random.default_rng(11)
    n = 2048
    sch = Schema.of("k", k="int64", v="float32")
    cols = {"k": np.arange(n, dtype=np.int64),
            "v": rng.standard_normal(n).astype(np.float32)}
    frame = IndexedFrame.from_columns(cols, sch, num_shards=s,
                                      rows_per_batch=512, rt=rt)
    twin = IndexedFrame.from_columns(cols, sch, num_shards=s,
                                     rows_per_batch=512, rt=rt)
    with tempfile.TemporaryDirectory() as ckpt_dir:
        mgr = frame.supervised(
            lineage=Lineage(sch, cols, rows_per_batch=512),
            injector=FaultInjector([Fault("shard_loss", step=3,
                                          shard=s - 1)], seed=11),
            policy=RecoveryPolicy(checkpoint_every=2),
            checkpoint_dir=ckpt_dir)
        q = rng.integers(0, n, size=64).astype(np.int64)
        identical = True
        for step in range(6):
            c, v = mgr.lookup(q, max_matches=4)
            tc, tv = twin.lookup(q, max_matches=4)
            identical &= np.array_equal(np.asarray(v), np.asarray(tv))
            for k in tc:
                identical &= np.array_equal(np.asarray(c[k]),
                                            np.asarray(tc[k]))
            delta = {"k": np.asarray([n + step], np.int64),
                     "v": np.asarray([float(step)], np.float32)}
            mgr.append(delta)
            twin = twin.append(delta)

        check(mgr.stats.recoveries == 1,
              f"exactly one automatic recovery "
              f"(got {mgr.stats.recoveries})")
        check(not mgr.dead, f"no shard left unrecovered (dead={mgr.dead})")
        check(identical,
              "every answer bit-identical to the never-failed twin")
        check(mgr.retraces == 1,
              f"fused read site traced once across kill + heal + appends "
              f"(got {mgr.retraces})")
        replayed = mgr.stats.replayed_deltas
        check(bool(replayed) and replayed[0] <= 2,
              f"replay bounded by the checkpoint suffix "
              f"(replayed {replayed} of {mgr.stats.appends} deltas)")

    if FAILURES:
        print(f"\nfault smoke: {len(FAILURES)} violation(s)")
        return 1
    print("fault smoke: all recovery contracts hold")
    return 0


if __name__ == "__main__":
    sys.exit(main())
