"""CI gate: execute every example end-to-end and fail on any error.

    python scripts/smoke_examples.py [--only NAME] [--timeout SECONDS]

Each example is run as its own subprocess with PYTHONPATH=src (exactly how
a user runs them), so import errors, missing layers (the old repro.dist
hole), and runtime exceptions all surface here instead of in user reports.
"""

from __future__ import annotations

import argparse
import os
import subprocess
import sys
import time

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))

# example -> extra argv (keep every run CI-sized)
EXAMPLES = {
    "quickstart.py": [],
    "threat_detection.py": [],
    "serve_indexed.py": [],
    "train_lm.py": ["--steps", "6"],
}


def run_example(name: str, extra, timeout: float) -> tuple[bool, float]:
    env = dict(os.environ)
    env["PYTHONPATH"] = "src" + (os.pathsep + env["PYTHONPATH"]
                                 if env.get("PYTHONPATH") else "")
    t0 = time.time()
    proc = subprocess.run(
        [sys.executable, os.path.join("examples", name), *extra],
        cwd=ROOT, env=env, capture_output=True, text=True, timeout=timeout)
    dt = time.time() - t0
    ok = proc.returncode == 0
    if not ok:
        print(f"--- {name} stdout ---\n{proc.stdout[-2000:]}")
        print(f"--- {name} stderr ---\n{proc.stderr[-4000:]}")
    return ok, dt


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", choices=list(EXAMPLES))
    ap.add_argument("--timeout", type=float, default=900.0)
    args = ap.parse_args(argv)

    todo = [args.only] if args.only else list(EXAMPLES)
    failures = 0
    for name in todo:
        print(f"== {name} ==", flush=True)
        try:
            ok, dt = run_example(name, EXAMPLES[name], args.timeout)
        except subprocess.TimeoutExpired:
            ok, dt = False, args.timeout
            print(f"   TIMEOUT after {args.timeout:.0f}s")
        print(f"   {'OK' if ok else 'FAILED'} in {dt:.1f}s", flush=True)
        failures += 0 if ok else 1
    print(f"\n{len(todo) - failures}/{len(todo)} examples passed")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
