"""Fig 10: append/createIndex write throughput vs rows-per-write.

Both APIs share the writing mechanism (hash-route + segment build), so the
numbers coincide — the paper makes the same observation."""

import numpy as np

from repro.core import Schema, append, create_index
from benchmarks.common import Report, timeit

SCH = Schema.of("k", k="int64", v="float32")


def run(quick: bool = True):
    rng = np.random.default_rng(3)
    rep = Report("write_throughput")
    base_n = 20_000 if quick else 200_000
    cols = {"k": rng.integers(0, base_n, base_n).astype(np.int64),
            "v": rng.random(base_n).astype(np.float32)}
    t0 = create_index(cols, SCH, rows_per_batch=4096)

    for rows in (1_000, 10_000, 100_000) if not quick else (500, 2_000,
                                                            10_000):
        delta = {"k": rng.integers(0, base_n, rows).astype(np.int64),
                 "v": rng.random(rows).astype(np.float32)}
        t_app = timeit(lambda: append(t0, delta), reps=3)
        t_create = timeit(lambda: create_index(delta, SCH,
                                               rows_per_batch=4096), reps=3)
        rep.add(f"rows={rows}",
                append_rows_per_s=rows / t_app["median_s"],
                create_rows_per_s=rows / t_create["median_s"],
                append_ms=t_app["median_s"] * 1e3,
                create_ms=t_create["median_s"] * 1e3)
    return rep.to_dict()


if __name__ == "__main__":
    run(quick=True)
