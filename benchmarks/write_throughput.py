"""Fig 10: append/createIndex write throughput vs rows-per-write.

Measured across write paths (DESIGN.md §4):

* ``arena``          — the default: jit-compiled in-place ingest into the
                       reserved tail, zero pytree shape change.
* ``arena_donated``  — the same ingest with parent buffers donated to XLA
                       (true in-place aliasing; measured as a chained
                       stream, since donation consumes the parent).
* ``segment``        — the PR-3 baseline: one exactly-sized delta segment
                       per append (host-coordinated build + snapshot
                       extension).
* ``create``         — full createIndex over the delta alone.

Plus the facade's write-hot-stream amortization (ISSUE 5 satellite):
``frame_seq`` appends N deltas one ``IndexedFrame.append`` at a time (N
``_arena_fits`` pre-flights + N ``int(fill)`` host round-trips + N ingest
launches); ``frame_batched`` hands the same N deltas as ONE list —
coalesced host-side, one round-trip, one launch, one version.

Batch sizes mirror Fig 5's sweep.  Results merge into
``BENCH_append.json`` at the repo root (shared with Fig 9).
"""

import numpy as np

from repro import IndexedFrame
from repro.core import Schema, append, create_index
from benchmarks.common import Report, SyncCounter, timeit
from benchmarks.append_read_latency import merge_artifact

SCH = Schema.of("k", k="int64", v="float32")
STREAM_DELTAS = 8


def run(quick: bool = True):
    rng = np.random.default_rng(3)
    rep = Report("write_throughput")
    base_n = 20_000 if quick else 200_000
    sizes = (500, 2_000, 10_000) if quick else (1_000, 10_000, 100_000)
    cols = {"k": rng.integers(0, base_n, base_n).astype(np.int64),
            "v": rng.random(base_n).astype(np.float32)}
    bench_rows = []

    for rows in sizes:
        delta = {"k": rng.integers(0, base_n, rows).astype(np.int64),
                 "v": rng.random(rows).astype(np.float32)}
        # reserve the whole measured stream: every append stays in-class
        stream_rows = rows * 16
        t0 = create_index(cols, SCH, rows_per_batch=4096,
                          reserve=base_n + stream_rows)
        t_seg0 = create_index(cols, SCH, rows_per_batch=4096, reserve=0)

        t_arena = timeit(lambda: append(t0, delta), reps=5)
        # donated stream: chained (donation consumes the parent), capped
        # well inside the reserved class
        state = {"t": create_index(cols, SCH, rows_per_batch=4096,
                                   reserve=base_n + stream_rows)}

        def donated_step():
            state["t"] = append(state["t"], delta, donate=True)

        t_donate = timeit(donated_step, reps=5)
        t_segment = timeit(lambda: append(t_seg0, delta, mode="segment"),
                           reps=3)
        t_create = timeit(lambda: create_index(delta, SCH,
                                               rows_per_batch=4096),
                          reps=3)

        # facade stream: N deltas, sequential vs coalesced-list append
        chunk = max(rows // STREAM_DELTAS, 1)
        deltas = [{"k": rng.integers(0, base_n, chunk).astype(np.int64),
                   "v": rng.random(chunk).astype(np.float32)}
                  for _ in range(STREAM_DELTAS)]
        stream_total = STREAM_DELTAS * chunk
        fr0 = IndexedFrame.from_columns(cols, SCH, rows_per_batch=4096,
                                        reserve=base_n + stream_rows)

        def frame_seq():
            f = fr0
            for d in deltas:
                f = f.append(d)
            return f

        t_frame_seq = timeit(frame_seq, reps=3)
        t_frame_batched = timeit(lambda: fr0.append(deltas), reps=3)

        # measured host syncs per stream (SyncCounter wraps the
        # jax.device_get funnel every hot-path sync routes through)
        with SyncCounter() as sc_seq:
            frame_seq()
        with SyncCounter() as sc_batched:
            fr0.append(deltas)

        row = dict(rows=rows,
                   stream_deltas=STREAM_DELTAS,
                   frame_seq_syncs=sc_seq.syncs,
                   frame_batched_syncs=sc_batched.syncs,
                   frame_seq_rows_per_s=(stream_total
                                         / t_frame_seq["median_s"]),
                   frame_batched_rows_per_s=(stream_total
                                             / t_frame_batched["median_s"]),
                   batched_vs_seq=(t_frame_seq["median_s"]
                                   / t_frame_batched["median_s"]),
                   arena_rows_per_s=rows / t_arena["median_s"],
                   arena_donated_rows_per_s=rows / t_donate["median_s"],
                   segment_rows_per_s=rows / t_segment["median_s"],
                   create_rows_per_s=rows / t_create["median_s"],
                   arena_ms=t_arena["median_s"] * 1e3,
                   arena_donated_ms=t_donate["median_s"] * 1e3,
                   segment_ms=t_segment["median_s"] * 1e3,
                   create_ms=t_create["median_s"] * 1e3,
                   arena_vs_segment=(t_segment["median_s"]
                                     / t_arena["median_s"]))
        bench_rows.append(row)
        rep.add(f"rows={rows}", **row)

    merge_artifact("fig10_write_throughput",
                   {"quick": quick, "rows": bench_rows})
    return rep.to_dict()


if __name__ == "__main__":
    run(quick=True)
