"""ISSUE 8: the QPS × write-rate serving grid — "millions of users",
measured.

An open-loop driver (arrivals on a fixed schedule, so queueing delay is
charged to latency — the coordinated-omission-safe way to measure a
server) pushes mixed lookup traffic through the continuous-batching
``QueryEngine`` while a writer stream stages deltas into the append
ring.  Per grid cell:

* p50/p99/mean request latency (scheduled arrival -> answer ready) and
  achieved read throughput;
* write-visibility lag (submit -> flush made it readable);
* MEASURED host syncs per tick (``common.SyncCounter``), trace counts,
  flushes, pad overhead.

Cells run on the vmap emulation backend in-process and on the REAL
shard_map backend under a forced 8-device host mesh (subprocess worker,
same idiom as ``benchmarks.scalability``), plus one supervised cell
where a seeded shard kill lands mid-run and the engine serves through
the heal.  Every cell's answers are verified bit-identical to an
unbatched MVCC twin replaying the engine's ``write_log``
(``replay_unbatched``); the committed summary asserts
``zero_retraces_after_warmup`` and ``batched_equals_unbatched`` under
BOTH topologies.

Results -> ``BENCH_serve.json`` at the repo root.
"""

import dataclasses
import json
import os
import subprocess
import sys
import time

import numpy as np

from repro import IndexedFrame
from repro.core import Schema
from repro.dist import mesh
from repro.serving.query_engine import (EngineStats, QueryEngine,
                                        replay_unbatched)
from benchmarks.common import Report, SyncCounter

SCH = Schema.of("k", k="int64", v="float32")
ARTIFACT = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_serve.json")

N_ROWS = 4096
LADDER = (8, 16, 32)
SIZES = (1, 4, 8, 9, 16, 32)          # request sizes, boundary-heavy
MESH_DEVICES = 8


def _build(num_shards, rt, rng):
    cols = {"k": np.arange(N_ROWS, dtype=np.int64),
            "v": rng.standard_normal(N_ROWS).astype(np.float32)}
    mk = lambda: IndexedFrame.from_columns(
        cols, SCH, num_shards=num_shards, rows_per_batch=512,
        reserve=4 * N_ROWS, rt=rt)
    return mk(), mk(), cols


def _drive(eng, rng, *, qps, write_rate, requests):
    """Open-loop mixed traffic: reads arrive every 1/qps seconds (each a
    multi-key request), every ``1/write_rate``-th arrival is a writer
    delta instead.  Ticks run continuously between arrivals."""
    interval = 1.0 / qps
    reqs, wi = [], 0
    t0 = time.perf_counter()
    for i in range(requests):
        due = t0 + i * interval
        while time.perf_counter() < due:
            if eng.has_work:
                eng.tick()
        if write_rate > 0 and (i + 1) % max(1, round(1 / write_rate)) == 0:
            eng.submit_append(
                {"k": np.asarray([N_ROWS + wi], np.int64),
                 "v": np.asarray([float(wi)], np.float32)},
                stream_id=99, t_submit=due)
            wi += 1
        else:
            size = SIZES[int(rng.integers(len(SIZES)))]
            reqs.append(eng.submit_lookup(
                rng.integers(-5, N_ROWS + 64, size).astype(np.int64),
                stream_id=i % 4, t_submit=due))
        eng.tick()
    eng.drain()
    elapsed = time.perf_counter() - t0
    return reqs, elapsed


def _cell(eng, site_cache, backend, num_shards, *, qps, write_rate,
          requests, seed):
    """One grid cell on a SHARED warmed engine: the frame at cell start
    (an MVCC parent — it stays queryable as the engine appends past it)
    seeds the unbatched replay twin, so jitted sites carry across cells
    and compile time never pollutes a cell's p99."""
    rng = np.random.default_rng(seed)
    frame0 = dataclasses.replace(eng.frame, queue=None)
    eng.stats = EngineStats()
    eng.write_log = []
    with SyncCounter() as sc:
        reqs, elapsed = _drive(eng, rng, qps=qps, write_rate=write_rate,
                               requests=requests)
    summary = eng.latency_summary()
    mismatches = replay_unbatched(frame0, reqs, eng.write_log,
                                  site_cache=site_cache)
    return {
        "backend": backend, "shards": num_shards,
        "offered_qps": qps, "write_rate": write_rate,
        "requests": len(reqs), "writes": eng.stats.writes,
        "achieved_qps": len(reqs) / elapsed if elapsed else 0.0,
        "read_p50_ms": summary["read"].get("p50_ms"),
        "read_p99_ms": summary["read"].get("p99_ms"),
        "read_mean_ms": summary["read"].get("mean_ms"),
        "write_visibility_p99_ms":
            summary["write_visibility"].get("p99_ms"),
        "keys_per_s": eng.stats.batched_keys / elapsed if elapsed else 0.0,
        "mean_batch_keys": summary["mean_batch_keys"],
        "pad_fraction": summary["pad_fraction"],
        "syncs_per_tick": sc.syncs / max(1, eng.stats.ticks),
        "ticks": eng.stats.ticks, "flushes": eng.stats.flushes,
        "retraces": eng.retraces,
        "expected_traces": eng.expected_traces,
        "zero_retraces_after_warmup": eng.zero_retraces_after_warmup,
        "batched_equals_unbatched": mismatches == 0,
        "mismatches": mismatches,
    }


def _supervised_cell(num_shards, rt, *, requests, seed):
    """One chaos cell: a seeded shard kill lands mid-run; the engine
    keeps serving through the automatic heal."""
    import tempfile
    from repro.dist.resilience import (Fault, FaultInjector,
                                       RecoveryPolicy)
    from repro.dist.runtime import Lineage
    rng = np.random.default_rng(seed)
    cols = {"k": np.arange(N_ROWS, dtype=np.int64),
            "v": rng.standard_normal(N_ROWS).astype(np.float32)}
    mk = lambda: IndexedFrame.from_columns(
        cols, SCH, num_shards=num_shards, rows_per_batch=512,
        reserve=4 * N_ROWS, rt=rt)
    with tempfile.TemporaryDirectory() as ckpt:
        mgr = mk().supervised(
            lineage=Lineage(SCH, cols, rows_per_batch=512),
            injector=FaultInjector([Fault("shard_loss", step=12,
                                          shard=num_shards - 1)],
                                   seed=seed),
            policy=RecoveryPolicy(checkpoint_every=3),
            checkpoint_dir=ckpt)
        eng = QueryEngine(mgr, ladder=LADDER, max_matches=4,
                          flush_deadline_ticks=2)
        # warmup mirrors _grid: compile every rung + the write path
        # before the measured window, then replay from the warmed frame
        for b in LADDER:
            eng.submit_lookup(rng.integers(0, N_ROWS, b).astype(np.int64))
            eng.tick()
        for wi in range(2):   # two cycles: see the sharding note in _grid
            eng.submit_append({"k": np.asarray([2 * N_ROWS + wi], np.int64),
                               "v": np.asarray([0.0], np.float32)})
            eng.drain()
        twin = dataclasses.replace(eng.frame, queue=None)
        eng.stats = EngineStats()
        eng.write_log = []
        reqs, elapsed = _drive(eng, rng, qps=100, write_rate=0.2,
                               requests=requests)
        summary = eng.latency_summary()
        mismatches = replay_unbatched(twin, reqs, eng.write_log)
        return {
            "backend": "vmap+supervised", "shards": num_shards,
            "offered_qps": 100, "write_rate": 0.2,
            "requests": len(reqs),
            "achieved_qps": len(reqs) / elapsed if elapsed else 0.0,
            "read_p50_ms": summary["read"].get("p50_ms"),
            "read_p99_ms": summary["read"].get("p99_ms"),
            "recoveries": mgr.stats.recoveries,
            "dead_shards": sorted(mgr.dead),
            "flushes": eng.stats.flushes,
            "batched_equals_unbatched": mismatches == 0,
            "mismatches": mismatches,
            "served_through_heal": (mgr.stats.recoveries == 1
                                    and not mgr.dead),
        }


def _grid(backend, num_shards, rt, *, quick: bool, seed0: int = 31):
    qps_axis = (100, 400) if quick else (50, 200, 800)
    wr_axis = (0.0, 0.2) if quick else (0.0, 0.1, 0.3)
    requests = 48 if quick else 192
    rng = np.random.default_rng(seed0)
    _, owned, _ = _build(num_shards, rt, rng)
    eng = QueryEngine(owned, ladder=LADDER, max_matches=4,
                      flush_deadline_ticks=2)
    for b in LADDER:                      # warm every rung once per backend
        eng.submit_lookup(rng.integers(0, N_ROWS, b).astype(np.int64))
        eng.tick()
    # Warm the write path TWICE: the first enqueue/flush cycle compiles
    # against fresh uncommitted host arrays, and XLA re-lowers both
    # executables once more when the ring comes back device-committed
    # (NamedSharding) from that first flush.  Cycle two pins the
    # steady-state layout so no measured cell pays the ~1.3s re-lower.
    for wi in range(2):
        eng.submit_append({"k": np.asarray([N_ROWS + wi], np.int64),
                           "v": np.asarray([0.0], np.float32)})
        eng.drain()
    site_cache = {}                       # replay oracle compiles, shared
    rows = []
    for qi, qps in enumerate(qps_axis):
        for wi, wr in enumerate(wr_axis):
            rows.append(_cell(eng, site_cache, backend, num_shards,
                              qps=qps, write_rate=wr, requests=requests,
                              seed=seed0 + 10 * qi + wi))
    return rows


def _mesh_worker(quick: bool):
    """Runs under XLA_FLAGS=--xla_force_host_platform_device_count=8:
    the grid on the REAL shard_map backend."""
    import jax
    assert len(jax.devices()) >= MESH_DEVICES, jax.devices()
    rt = mesh.mesh_runtime(MESH_DEVICES)
    rows = _grid("shard_map", MESH_DEVICES, rt, quick=quick, seed0=57)
    print("SERVE_MESH_JSON " + json.dumps(rows), flush=True)


def _mesh_grid(quick: bool):
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + f" --xla_force_host_platform_device_count="
                          f"{MESH_DEVICES}").strip()
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    cmd = [sys.executable, "-m", "benchmarks.serve", "--mesh-worker"]
    if not quick:
        cmd.append("--full")
    proc = subprocess.run(cmd, capture_output=True, text=True, env=env,
                          cwd=root, timeout=3600)
    if proc.returncode != 0:
        raise RuntimeError(f"serve mesh worker failed:\n{proc.stdout}\n"
                           f"{proc.stderr}")
    line = [ln for ln in proc.stdout.splitlines()
            if ln.startswith("SERVE_MESH_JSON ")][-1]
    return json.loads(line[len("SERVE_MESH_JSON "):])


def run(quick: bool = True):
    rng_label = "quick" if quick else "full"
    rep = Report("serve")
    rows = _grid("vmap", 4, mesh.vmap_runtime(), quick=quick)
    rows += _mesh_grid(quick)
    rows.append(_supervised_cell(4, mesh.vmap_runtime(),
                                 requests=48 if quick else 192, seed=91))
    for r in rows:
        rep.add(f"{r['backend']} qps={r['offered_qps']} "
                f"wr={r['write_rate']}",
                p50_ms=r.get("read_p50_ms"), p99_ms=r.get("read_p99_ms"),
                achieved_qps=r.get("achieved_qps"))

    plain = [r for r in rows if "supervised" not in r["backend"]]
    summary = {
        "zero_retraces_after_warmup":
            all(r["zero_retraces_after_warmup"] for r in plain),
        "batched_equals_unbatched":
            all(r["batched_equals_unbatched"] for r in rows),
        "backends": sorted({r["backend"] for r in rows}),
        "served_through_heal":
            all(r.get("served_through_heal", True) for r in rows),
        "max_syncs_per_tick":
            max(r.get("syncs_per_tick", 0.0) for r in rows),
    }
    doc = {"benchmark": "serve", "mode": rng_label,
           "ladder": list(LADDER), "request_sizes": list(SIZES),
           "grid": rows, "summary": summary}
    with open(ARTIFACT, "w") as f:
        json.dump(doc, f, indent=2)
        f.write("\n")
    print(f"wrote {ARTIFACT}")
    for k, v in summary.items():
        print(f"  {k}: {v}")
    if not (summary["zero_retraces_after_warmup"]
            and summary["batched_equals_unbatched"]):
        raise RuntimeError(f"serving acceptance violated: {summary}")
    return rep.to_dict()


if __name__ == "__main__":
    if "--mesh-worker" in sys.argv:
        _mesh_worker(quick="--full" not in sys.argv)
    else:
        run(quick="--full" not in sys.argv)
