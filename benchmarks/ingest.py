"""ISSUE 7: streaming ingest through the device-resident append queue.

Measures the write paths a streaming producer can take at Fig-5's small
batch sizes, where per-append host overhead dominates:

* ``frame_seq``     — N ``IndexedFrame.append`` calls (PR 5's facade:
                      one ``_arena_fits`` pre-flight + one ``fill`` sync
                      per call; N version bumps).
* ``frame_batched`` — the same N deltas as ONE coalesced list append
                      (host-side numpy concat, one fused launch).
* ``queued``        — N ``enqueue`` (pure on-device lane scatters, ZERO
                      host syncs) + one ``flush`` (ONE fused jit, ONE
                      sync: the overflow-flag read).

Alongside wall clock, every path's host syncs are MEASURED with
``common.SyncCounter`` (the ``jax.device_get`` funnel) — the acceptance
metric is ≤1 sync per flush vs ≥1 per append today.  The retrace check
drives ≥2 full ring wraps through ``enqueue``/``flush`` on the local and
the vmap-distributed backend and asserts ``core.table.QUEUE_TRACES``
stays at one trace per site per topology.

Results -> ``BENCH_ingest.json`` at the repo root.
"""

import dataclasses
import json
import os

import numpy as np

from repro import IndexedFrame
from repro.core import Schema
from repro.core import table as table_mod
from repro.dist import mesh
from benchmarks.common import Report, SyncCounter, timeit

SCH = Schema.of("k", k="int64", v="float32")
STREAM_DELTAS = 8
ARTIFACT = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_ingest.json")


def _deltas(rng, n_deltas: int, rows: int, base: int):
    return [{"k": (rng.integers(0, 1 << 40, rows) | (1 << 41)
                   ).astype(np.int64),
             "v": rng.random(rows).astype(np.float32)}
            for _ in range(n_deltas)]


def _stream_paths(fr0, deltas):
    """(frame_seq, frame_batched, queued) thunks over one delta stream."""

    def frame_seq():
        f = fr0
        for d in deltas:
            f = f.append(d)
        return f

    def frame_batched():
        return fr0.append(list(deltas))

    def queued():
        # fresh ring per stream; the ring is linearly owned so every
        # enqueue donates it (pure in-place lane scatter).  The flush
        # keeps the shared base table alive (donate=False) so reps are
        # independent.
        f = dataclasses.replace(fr0, queue=None).with_queue(
            lanes=fr0.queue.lanes, lane_rows=fr0.queue.lane_rows)
        for d in deltas:
            f = f.enqueue(d)
        return f.flush()

    return frame_seq, frame_batched, queued


def _wrap_gate(fr0, rng, rows: int, label: str, rep: Report) -> dict:
    """≥2 full ring wraps; QUEUE_TRACES must not move after wrap 1."""
    fr = fr0
    lanes = fr.queue.lanes
    base = dict(table_mod.QUEUE_TRACES)
    wraps = 3
    for w in range(wraps):
        for d in _deltas(rng, lanes, rows, 0):
            fr = fr.enqueue(d)
        fr = fr.flush()
        if w == 0:     # first wrap may trace; later wraps must not
            after_first = dict(table_mod.QUEUE_TRACES)
    retraces = {k: table_mod.QUEUE_TRACES[k] - after_first[k]
                for k in after_first}
    out = dict(wraps=wraps, enqueue_retraces=retraces["enqueue"],
               flush_retraces=retraces["flush"],
               first_wrap_traces={k: after_first[k] - base[k]
                                  for k in base})
    rep.add(f"ring_wraps[{label}]", **{k: v for k, v in out.items()
                                       if not isinstance(v, dict)})
    return out


def run(quick: bool = True):
    rng = np.random.default_rng(7)
    rep = Report("ingest")
    base_n = 20_000 if quick else 200_000
    sizes = (500, 2_000, 10_000) if quick else (1_000, 10_000, 100_000)
    cols = {"k": rng.integers(0, base_n, base_n).astype(np.int64),
            "v": rng.random(base_n).astype(np.float32)}
    doc = {"quick": quick, "stream_deltas": STREAM_DELTAS, "rows": []}

    for rows in sizes:
        stream_rows = rows * STREAM_DELTAS
        fr0 = IndexedFrame.from_columns(
            cols, SCH, rows_per_batch=4096,
            reserve=base_n + 4 * stream_rows).with_queue(
                lanes=STREAM_DELTAS, lane_rows=rows)
        deltas = _deltas(rng, STREAM_DELTAS, rows, base_n)
        frame_seq, frame_batched, queued = _stream_paths(fr0, deltas)

        t_seq = timeit(frame_seq, reps=3)
        t_batched = timeit(frame_batched, reps=3)
        t_queued = timeit(queued, reps=5)
        with SyncCounter() as sc_seq:
            frame_seq()
        with SyncCounter() as sc_batched:
            frame_batched()
        with SyncCounter() as sc_queued:
            queued()

        row = dict(
            rows_per_delta=rows,
            stream_rows=stream_rows,
            queued_rows_per_s=stream_rows / t_queued["median_s"],
            frame_seq_rows_per_s=stream_rows / t_seq["median_s"],
            frame_batched_rows_per_s=stream_rows / t_batched["median_s"],
            queued_vs_seq=t_seq["median_s"] / t_queued["median_s"],
            queued_vs_batched=t_batched["median_s"] / t_queued["median_s"],
            queued_syncs_per_stream=sc_queued.syncs,
            queued_syncs_per_flush=sc_queued.syncs,  # one flush per stream
            frame_seq_syncs_per_stream=sc_seq.syncs,
            frame_batched_syncs_per_stream=sc_batched.syncs,
            queued_ms=t_queued["median_s"] * 1e3,
            frame_seq_ms=t_seq["median_s"] * 1e3,
            frame_batched_ms=t_batched["median_s"] * 1e3)
        doc["rows"].append(row)
        rep.add(f"rows={rows}", **row)

    # retrace gate across ≥2 ring wraps, local + vmap-dist backends
    small = sizes[0]
    fr_local = IndexedFrame.from_columns(
        cols, SCH, rows_per_batch=4096,
        reserve=base_n + 64 * small).with_queue(lanes=4, lane_rows=small)
    doc["ring_wraps_local"] = _wrap_gate(fr_local, rng, small, "local", rep)
    fr_dist = IndexedFrame.from_columns(
        cols, SCH, num_shards=4, rt=mesh.vmap_runtime(),
        rows_per_batch=4096, reserve=base_n + 64 * small).with_queue(
            lanes=4, lane_rows=small)
    doc["ring_wraps_dist_vmap"] = _wrap_gate(fr_dist, rng, small,
                                             "dist_vmap", rep)

    import jax
    doc["backend"] = jax.default_backend()
    with open(ARTIFACT, "w") as f:
        json.dump(doc, f, indent=2)
    return rep.to_dict()


if __name__ == "__main__":
    run(quick=True)
