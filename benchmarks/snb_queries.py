"""Fig 13: SNB short-read analogs on a power-law social graph, driven
through the ``IndexedFrame`` facade (the paper's user API) so the Zipf
claims land on a paper workload, not only synthetic keys.

SQ1  person lookup (point query on vertex id)
SQ2  recent posts of person (lookup, multi-match)
SQ3  friends of person (edge lookup by src)
SQ4  posts of friends (lookup -> join)
SQ5  full-profile projection (row-layout tax — the paper's slow case)
SQ6  2-hop scan-heavy traversal (fallback path, non-indexed win is small)
SQ7  replies to person (join on dst)
SQ8  celebrity fan-in (ISSUE 9): the SNB degree skew concentrated on a
     4-shard distributed frame — routed vs hot-key-replicated hybrid on
     a probe batch dominated by the highest-degree vertices, parity
     checked bitwise.
"""

import jax
import numpy as np

from repro.core import Schema, joins
from repro.frame import IndexedFrame
from benchmarks.common import Report, edge_table, powerlaw_keys, timeit

V_SCH = Schema.of("vid", vid="int64", age="int32", f0="float32",
                  f1="float32", f2="float32", f3="float32")
E_SCH = Schema.of("src", src="int64", dst="int64", weight="float32")


def _celebrity_fanin(rep, rng, edges, quick):
    """SQ8: the skew cell — edges land on 4 shards with the hot-key
    tracker counting ingest; the probe batch is drawn from the SAME
    power law as the graph (celebrity-heavy), so routing funnels most
    lanes to one owner while the hybrid answers them from the mirror."""
    n_q = 2_048 if quick else 8_192
    base = {k: v[:4] for k, v in edges.items()}
    rest = {k: v[4:] for k, v in edges.items()}
    ef = IndexedFrame.from_columns(base, E_SCH, num_shards=4,
                                   rows_per_batch=2048, track_hot=64,
                                   reserve=len(edges["src"]) + 4096)
    ef = ef.with_replica(capacity=64, max_matches=16)
    ef = ef.append(rest)                      # tracker counts, mirror fresh
    probe = powerlaw_keys(rng, n_q, int(edges["src"].max()) + 1)

    jh = jax.jit(lambda f, q: f.lookup(q, max_matches=16, op="hybrid"))
    jr = jax.jit(lambda f, q: f.lookup(q, max_matches=16, op="routed"))
    th = timeit(jh, ef, probe, reps=5)["median_s"]
    tr = timeit(jr, ef, probe, reps=5)["median_s"]
    ch, vh = jax.tree.map(np.asarray, jh(ef, probe))
    cr, vr = jax.tree.map(np.asarray, jr(ef, probe))
    parity = bool(np.array_equal(vh, vr)
                  and all(np.array_equal(ch[k], cr[k]) for k in ch))
    from repro import dist
    rep.add("SQ8_celebrity_fanin", hybrid_ms=th * 1e3, routed_ms=tr * 1e3,
            hot_fraction=dist.hot_fraction(ef.data, probe),
            planner_rule=ef.plan_lookup(probe, max_matches=16,
                                        op="hybrid").reason,
            parity_ok=parity)


def run(quick: bool = True):
    rng = np.random.default_rng(9)
    n_v = 5_000 if quick else 50_000
    n_e = 40_000 if quick else 400_000
    rep = Report("snb_queries")

    verts = {"vid": np.arange(n_v, dtype=np.int64),
             "age": rng.integers(13, 90, n_v).astype(np.int32),
             **{f"f{i}": rng.random(n_v).astype(np.float32)
                for i in range(4)}}
    edges = edge_table(rng, n_e, n_v)
    edges = {"src": edges["src"], "dst": edges["dst"],
             "weight": edges["weight"]}
    vf = IndexedFrame.from_columns(verts, V_SCH, rows_per_batch=2048)
    ef = IndexedFrame.from_columns(edges, E_SCH, rows_per_batch=2048)
    hot = powerlaw_keys(rng, 64, n_v)        # hot vertices (power law)

    qs = {
        "SQ1_person": (
            jax.jit(lambda f, q: f.lookup(q, max_matches=1)),
            jax.jit(lambda f, q: joins.scan_lookup(f.data, q,
                                                   max_matches=1)),
            vf, hot[:8]),
        "SQ3_friends": (
            jax.jit(lambda f, q: f.lookup(q, max_matches=64)),
            jax.jit(lambda f, q: joins.scan_lookup(f.data, q,
                                                   max_matches=64)),
            ef, hot[:8]),
    }
    for name, (idx_fn, van_fn, frame, q) in qs.items():
        ti = timeit(idx_fn, frame, q, reps=3)["median_s"]
        tv = timeit(van_fn, frame, q, reps=3)["median_s"]
        rep.add(name, indexed_ms=ti * 1e3, vanilla_ms=tv * 1e3,
                speedup=tv / ti,
                planner_rule=frame.plan_lookup(q).reason)

    # SQ7: replies to person — indexed join vs per-query hash join
    probe7 = {"dst": edges["dst"][:512]}
    j7i = jax.jit(lambda f, p: f.join(p, "dst", max_matches=1))
    j7v = jax.jit(lambda b, p: joins.hash_join(
        b, "vid", p, "dst", max_matches=1, num_buckets=16384))
    ti = timeit(j7i, vf, probe7, reps=3)["median_s"]
    tv = timeit(j7v, verts, probe7, reps=3)["median_s"]
    rep.add("SQ7_replies", indexed_ms=ti * 1e3, vanilla_ms=tv * 1e3,
            speedup=tv / ti,
            planner_rule=vf.plan_join(probe7, "dst").reason)

    # SQ4: friends-of -> posts join (two-stage indexed, one jitted graph)
    def sq4(ef_, vf_, q):
        rids, _ = ef_.data.lookup(q, 32)
        friends = ef_.data.gather_rows(jax.numpy.maximum(rids, 0),
                                       names=("dst",))["dst"].reshape(-1)
        return vf_.lookup(friends, max_matches=1)
    rep.add("SQ4_posts_of_friends",
            indexed_ms=timeit(jax.jit(sq4), ef, vf, hot[:8],
                              reps=3)["median_s"] * 1e3)

    # SQ5: full-profile projection — row layout pays vs columnar
    vf_col = IndexedFrame.from_columns(verts, V_SCH, rows_per_batch=2048,
                                       layout="columnar")
    j_scan = jax.jit(lambda f: f.data.scan_column("f2"))
    t_row = timeit(j_scan, vf, reps=3)["median_s"]
    t_col = timeit(j_scan, vf_col, reps=3)["median_s"]
    rep.add("SQ5_projection", row_ms=t_row * 1e3, col_ms=t_col * 1e3,
            row_tax=t_row / t_col)

    # SQ8: the ISSUE-9 skew cell (distributed, hybrid vs routed)
    _celebrity_fanin(rep, rng, edges, quick)
    return rep.to_dict()


if __name__ == "__main__":
    run(quick=True)
