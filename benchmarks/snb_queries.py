"""Fig 13: SNB short-read analogs on a power-law social graph.

SQ1  person lookup (point query on vertex id)
SQ2  recent posts of person (lookup, multi-match)
SQ3  friends of person (edge lookup by src)
SQ4  posts of friends (lookup -> join)
SQ5  full-profile projection (row-layout tax — the paper's slow case)
SQ6  2-hop scan-heavy traversal (fallback path, non-indexed win is small)
SQ7  replies to person (join on dst)
"""

import jax
import numpy as np

from repro.core import Schema, create_index, joins
from benchmarks.common import Report, edge_table, powerlaw_keys, timeit

V_SCH = Schema.of("vid", vid="int64", age="int32", f0="float32",
                  f1="float32", f2="float32", f3="float32")
E_SCH = Schema.of("src", src="int64", dst="int64", weight="float32")


def run(quick: bool = True):
    rng = np.random.default_rng(9)
    n_v = 5_000 if quick else 50_000
    n_e = 40_000 if quick else 400_000
    rep = Report("snb_queries")

    verts = {"vid": np.arange(n_v, dtype=np.int64),
             "age": rng.integers(13, 90, n_v).astype(np.int32),
             **{f"f{i}": rng.random(n_v).astype(np.float32)
                for i in range(4)}}
    edges = edge_table(rng, n_e, n_v)
    edges = {"src": edges["src"], "dst": edges["dst"],
             "weight": edges["weight"]}
    vt = create_index(verts, V_SCH, rows_per_batch=2048)
    et = create_index(edges, E_SCH, rows_per_batch=2048)
    hot = powerlaw_keys(rng, 64, n_v)        # hot vertices (power law)

    qs = {
        "SQ1_person": (
            jax.jit(lambda t, q: joins.indexed_lookup(t, q,
                                                      max_matches=1)),
            jax.jit(lambda t, q: joins.scan_lookup(t, q, max_matches=1)),
            vt, hot[:8]),
        "SQ3_friends": (
            jax.jit(lambda t, q: joins.indexed_lookup(t, q,
                                                      max_matches=64)),
            jax.jit(lambda t, q: joins.scan_lookup(t, q, max_matches=64)),
            et, hot[:8]),
    }
    for name, (idx_fn, van_fn, tab, q) in qs.items():
        ti = timeit(idx_fn, tab, q, reps=3)["median_s"]
        tv = timeit(van_fn, tab, q, reps=3)["median_s"]
        rep.add(name, indexed_ms=ti * 1e3, vanilla_ms=tv * 1e3,
                speedup=tv / ti)

    # SQ7: replies to person — indexed join vs per-query hash join
    probe7 = {"dst": edges["dst"][:512]}
    j7i = jax.jit(lambda t, p: joins.indexed_join(t, p, "dst",
                                                  max_matches=1))
    j7v = jax.jit(lambda b, p: joins.hash_join(
        b, "vid", p, "dst", max_matches=1, num_buckets=16384))
    ti = timeit(j7i, vt, probe7, reps=3)["median_s"]
    tv = timeit(j7v, verts, probe7, reps=3)["median_s"]
    rep.add("SQ7_replies", indexed_ms=ti * 1e3, vanilla_ms=tv * 1e3,
            speedup=tv / ti)

    # SQ4: friends-of -> posts join (two-stage indexed, one jitted graph)
    def sq4(et_, vt_, q):
        rids, _ = et_.lookup(q, 32)
        friends = et_.gather_rows(jax.numpy.maximum(rids, 0),
                                  names=("dst",))["dst"].reshape(-1)
        return joins.indexed_lookup(vt_, friends, max_matches=1)
    rep.add("SQ4_posts_of_friends",
            indexed_ms=timeit(jax.jit(sq4), et, vt, hot[:8],
                              reps=3)["median_s"] * 1e3)

    # SQ5: full-profile projection — row layout pays vs columnar
    vt_col = create_index(verts, V_SCH, rows_per_batch=2048,
                          layout="columnar")
    j_scan = jax.jit(lambda t: t.scan_column("f2"))
    t_row = timeit(j_scan, vt, reps=3)["median_s"]
    t_col = timeit(j_scan, vt_col, reps=3)["median_s"]
    rep.add("SQ5_projection", row_ms=t_row * 1e3, col_ms=t_col * 1e3,
            row_tax=t_row / t_col)
    return rep.to_dict()


if __name__ == "__main__":
    run(quick=True)
