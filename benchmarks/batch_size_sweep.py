"""Fig 5: row-batch size sweep — reads (joins) and writes (appends),
normalized to the smallest batch.  The paper finds a 4 MB sweet spot;
our batch knob is rows_per_batch (rows x row_bytes = batch bytes)."""

import jax
import numpy as np

from repro.core import Schema, append, create_index, joins
from benchmarks.common import Report, powerlaw_keys, timeit

SCH = Schema.of("k", k="int64", v="float32")   # 12 B rows


def run(quick: bool = True):
    rng = np.random.default_rng(6)
    n = 40_000 if quick else 400_000
    rep = Report("batch_size_sweep")
    cols = {"k": powerlaw_keys(rng, n, n // 8),
            "v": rng.random(n).astype(np.float32)}
    probe = {"pk": rng.choice(cols["k"], 256).astype(np.int64)}
    delta = {"k": rng.choice(cols["k"], 1000).astype(np.int64),
             "v": rng.random(1000).astype(np.float32)}
    jfn = jax.jit(lambda t, p: joins.indexed_join(t, p, "pk",
                                                  max_matches=16))

    base_read = base_write = None
    for rpb in (256, 1024, 4096, 16384):
        t = create_index(cols, SCH, rows_per_batch=rpb)
        tr = timeit(jfn, t, probe, reps=3)["median_s"]
        tw = timeit(lambda: append(t, delta), reps=3)["median_s"]
        base_read = base_read or tr
        base_write = base_write or tw
        rep.add(f"rows_per_batch={rpb} (~{rpb * 12 // 1024}KB)",
                read_ms=tr * 1e3, write_ms=tw * 1e3,
                read_norm=tr / base_read, write_norm=tw / base_write)
    return rep.to_dict()


if __name__ == "__main__":
    run(quick=True)
