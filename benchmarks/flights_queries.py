"""Fig 15: US-Flights Q1-Q7 — string keys (pre-hashed) vs int keys.

Q1 join flights x planes ON tailNum (string)      Q2 filter tailNum = x
Q3 join on flightNum < 200 subset (int)           Q4 ... < 400 subset
Q5/Q6/Q7 point queries with ~10/100/1000 matches (int)

The paper finds int keys beat string keys (strings pay a hash); we
pre-hash strings at ingest, so the residual string tax is the host-side
hashing, measured separately."""

import time

import jax
import numpy as np

from repro.core import Schema, create_index, joins
from repro.core.hashing import hash_string_host
from benchmarks.common import Report, flights_table, timeit

F_SCH = Schema.of("flightnum", tailnum_h="int64", flightnum="int64",
                  delay="float32", distance="int32")
FT_SCH = Schema.of("tailnum_h", tailnum_h="int64", flightnum="int64",
                   delay="float32", distance="int32")
P_SCH = Schema.of("tailnum_h", tailnum_h="int64", year="int32")


def run(quick: bool = True):
    rng = np.random.default_rng(10)
    n = 60_000 if quick else 600_000
    rep = Report("flights_queries")
    flights, tails = flights_table(rng, n)
    planes = {"tailnum_h": tails,
              "year": rng.integers(1990, 2020, len(tails))
              .astype(np.int32)}

    ft_tail = create_index(flights, FT_SCH, rows_per_batch=4096)
    ft_num = create_index(flights, F_SCH, rows_per_batch=4096)

    nb = 1 << max(14, (n // 4).bit_length())

    # Q1: join flights x planes ON tailNum (string key, pre-hashed)
    j1i = jax.jit(lambda t, p: joins.indexed_join(t, p, "tailnum_h",
                                                  max_matches=256))
    j1v = jax.jit(lambda b, p: joins.hash_join(
        b, "tailnum_h", p, "tailnum_h", max_matches=256, num_buckets=nb))
    ti = timeit(j1i, ft_tail, planes, reps=3)
    tv = timeit(j1v, flights, planes, reps=3)
    rep.add("Q1_join_tailnum_str", indexed_ms=ti["median_s"] * 1e3,
            vanilla_ms=tv["median_s"] * 1e3,
            speedup=tv["median_s"] / ti["median_s"])

    # Q2: select * where tailNum = x (string key) + host hashing tax
    t0 = time.perf_counter()
    key = hash_string_host("N00042")
    hash_tax = time.perf_counter() - t0
    j2i = jax.jit(lambda t, q: joins.indexed_lookup(t, q,
                                                    max_matches=512))
    j2v = jax.jit(lambda t, q: joins.scan_lookup(t, q, max_matches=512))
    ti = timeit(j2i, ft_tail, np.asarray([key]), reps=3)
    tv = timeit(j2v, ft_tail, np.asarray([key]), reps=3)
    rep.add("Q2_filter_tailnum_str", indexed_ms=ti["median_s"] * 1e3,
            vanilla_ms=tv["median_s"] * 1e3,
            speedup=tv["median_s"] / ti["median_s"],
            string_hash_tax_us=hash_tax * 1e6)

    # Q3/Q4: join with selected flights subset (int key)
    j3i = jax.jit(lambda t, p: joins.indexed_join(t, p, "flightnum",
                                                  max_matches=32))
    j3v = jax.jit(lambda b, p: joins.hash_join(
        b, "flightnum", p, "flightnum", max_matches=32, num_buckets=nb))
    for name, bound in (("Q3_join_fnum_lt200", 200),
                        ("Q4_join_fnum_lt400", 400)):
        sel = flights["flightnum"] < bound
        probe = {"flightnum": flights["flightnum"][sel][:2048]}
        ti = timeit(j3i, ft_num, probe, reps=3)
        tv = timeit(j3v, flights, probe, reps=3)
        rep.add(name, indexed_ms=ti["median_s"] * 1e3,
                vanilla_ms=tv["median_s"] * 1e3,
                speedup=tv["median_s"] / ti["median_s"])

    # Q5-Q7: point queries with growing match counts (int key)
    counts = np.bincount(flights["flightnum"], minlength=8000)
    for name, want in (("Q5_point_10", 10), ("Q6_point_100", 100),
                       ("Q7_point_1000", 1000)):
        key = int(np.argmin(np.abs(counts - want)))
        mm = max(want * 2, 16)
        j5i = jax.jit(lambda t, q, mm=mm: joins.indexed_lookup(
            t, q, max_matches=mm))
        j5v = jax.jit(lambda t, q, mm=mm: joins.scan_lookup(
            t, q, max_matches=mm))
        ti = timeit(j5i, ft_num, np.asarray([key]), reps=3)
        tv = timeit(j5v, ft_num, np.asarray([key]), reps=3)
        rep.add(name, indexed_ms=ti["median_s"] * 1e3,
                vanilla_ms=tv["median_s"] * 1e3,
                speedup=tv["median_s"] / ti["median_s"],
                matches=int(counts[key]))
    return rep.to_dict()


if __name__ == "__main__":
    run(quick=True)
