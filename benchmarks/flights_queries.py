"""Fig 15: US-Flights Q1-Q7 — string keys (pre-hashed) vs int keys.

Q1 join flights x planes ON tailNum (string)      Q2 filter tailNum = x
Q3 join on flightNum < 200 subset (int)           Q4 ... < 400 subset
Q5/Q6/Q7 point queries with ~10/100/1000 matches (int)

The paper finds int keys beat string keys (strings pay a hash); we
pre-hash strings at ingest, so the residual string tax is the host-side
hashing, measured separately.

ISSUE 10 port: the indexed side runs through the ``IndexedFrame`` facade
on BOTH backends (local + vmap dist); two new cells land in
``BENCH_workloads.json``:

* ``dict_encode`` — streaming STRING ingest (the same tail-number
  vocabulary every batch) hashed per batch vs through a
  ``hashing.StringDictionary`` (hash each string once, table-lookup
  after): the before/after on the paper's Fig-15 string tax;
* ``partitioned`` — month-partitioned flights (a ``flightdate`` YYYYMM
  key, ``PartitionSpec.range_`` one partition per month): a one-month
  point query prunes to 1/12 partitions (planner rule P1), pruned vs
  unpruned latency reported.
"""

import time

import jax
import numpy as np

from repro import IndexedFrame, PartitionSpec
from repro.core import Schema, joins
from repro.core.hashing import (StringDictionary, hash_string_host,
                                hash_strings_host)
from benchmarks.common import (Report, flights_table, timeit,
                               update_workloads)

F_SCH = Schema.of("flightnum", tailnum_h="int64", flightnum="int64",
                  delay="float32", distance="int32")
FT_SCH = Schema.of("tailnum_h", tailnum_h="int64", flightnum="int64",
                   delay="float32", distance="int32")
P_SCH = Schema.of("tailnum_h", tailnum_h="int64", year="int32")
FD_SCH = Schema.of("flightdate", flightdate="int64", delay="float32")

# hot flight numbers planted by run() for the Q5-Q7 result-size sweep,
# chosen above the 0..7999 uniform range so Q3/Q4's <200/<400 probe
# subsets are untouched
HOT_10, HOT_100, HOT_1000 = 8010, 8100, 8500


def _queries(rep, rows, backend, flights, planes, tails, n, kw):
    ft_tail = IndexedFrame.from_columns(flights, FT_SCH,
                                        rows_per_batch=4096, **kw)
    ft_num = IndexedFrame.from_columns(flights, F_SCH,
                                       rows_per_batch=4096, **kw)
    nb = 1 << max(14, (n // 4).bit_length())

    def add(label, ti, tv, **extra):
        row = {"label": f"{label} {backend}", "backend": backend,
               "indexed_ms": ti["median_s"] * 1e3,
               "vanilla_ms": tv["median_s"] * 1e3,
               "speedup": tv["median_s"] / ti["median_s"], **extra}
        rows.append(row)
        rep.add(row["label"], **{k: v for k, v in row.items()
                                 if k != "label"})

    # Q1: join flights x planes ON tailNum (string key, pre-hashed)
    j1i = jax.jit(lambda f, p: f.join(p, "tailnum_h", max_matches=256)[2])
    j1v = jax.jit(lambda b, p: joins.hash_join(
        b, "tailnum_h", p, "tailnum_h", max_matches=256, num_buckets=nb))
    add("Q1_join_tailnum_str", timeit(j1i, ft_tail, planes, reps=3),
        timeit(j1v, flights, planes, reps=3))

    # Q2: select * where tailNum = x (string key) + host hashing tax
    t0 = time.perf_counter()
    key = hash_string_host("N00042")
    hash_tax = time.perf_counter() - t0
    j2i = jax.jit(lambda f, q: f.lookup(q, max_matches=512)[1])
    j2v = jax.jit(lambda t, q: joins.scan_lookup(t, q, max_matches=512))
    ti = timeit(j2i, ft_tail, np.asarray([key]), reps=3)
    if backend == "local":   # scan baseline is single-table only
        tv = timeit(j2v, ft_tail.data, np.asarray([key]), reps=3)
    else:
        tv = ti
    add("Q2_filter_tailnum_str", ti, tv,
        string_hash_tax_us=hash_tax * 1e6)

    # Q3/Q4: join with selected flights subset (int key)
    j3i = jax.jit(lambda f, p: f.join(p, "flightnum", max_matches=32)[2])
    j3v = jax.jit(lambda b, p: joins.hash_join(
        b, "flightnum", p, "flightnum", max_matches=32, num_buckets=nb))
    for name, bound in (("Q3_join_fnum_lt200", 200),
                        ("Q4_join_fnum_lt400", 400)):
        sel = flights["flightnum"] < bound
        probe = {"flightnum": flights["flightnum"][sel][:2048]}
        add(name, timeit(j3i, ft_num, probe, reps=3),
            timeit(j3v, flights, probe, reps=3))

    # Q5-Q7: point queries with growing match counts (int key; hot keys
    # planted by run() so the result sizes actually span 10/100/1000)
    counts = np.bincount(flights["flightnum"], minlength=8501)
    for name, key, want in (("Q5_point_10", HOT_10, 10),
                            ("Q6_point_100", HOT_100, 100),
                            ("Q7_point_1000", HOT_1000, 1000)):
        mm = max(want * 2, 16)
        j5i = jax.jit(lambda f, q, mm=mm: f.lookup(q, max_matches=mm)[1])
        j5v = jax.jit(lambda t, q, mm=mm: joins.scan_lookup(
            t, q, max_matches=mm))
        ti = timeit(j5i, ft_num, np.asarray([key]), reps=3)
        if backend == "local":
            tv = timeit(j5v, ft_num.data, np.asarray([key]), reps=3)
        else:
            tv = ti   # scan baseline is single-table; dist rows compare
        add(name, ti, tv, matches=int(counts[key]))


def _dict_encode_cell(rep, rows, rng, *, batches=20, batch_rows=5000,
                      n_planes=400):
    """Streaming string ingest, same vocabulary every batch: per-batch
    FNV byte walk vs dictionary-encode (hash once, table after)."""
    vocab = np.array([f"N{i:05d}" for i in range(n_planes)], dtype=object)
    stream = [vocab[rng.integers(0, n_planes, batch_rows)]
              for _ in range(batches)]

    t0 = time.perf_counter()
    plain = [hash_strings_host(b) for b in stream]
    t_plain = time.perf_counter() - t0

    d = StringDictionary()
    t0 = time.perf_counter()
    encoded = [d.encode(b) for b in stream]
    t_dict = time.perf_counter() - t0

    for p, e in zip(plain, encoded):    # bit-identical codes
        np.testing.assert_array_equal(p, e)
    row = {"label": f"string_ingest_{batches}x{batch_rows}",
           "plain_ms": t_plain * 1e3, "dict_ms": t_dict * 1e3,
           "speedup": t_plain / t_dict,
           "strings_hashed": d.hashed, "rows_reused": d.reused,
           "vocab": len(d)}
    rows.append(row)
    rep.add(row["label"], **{k: v for k, v in row.items()
                             if k != "label"})


def _partitioned_cell(rep, rows, rng, n):
    """Month-partitioned flights: a one-month point query prunes to 1/12
    partitions (planner rule P1)."""
    months = np.arange(202401, 202413)
    cols = {"flightdate": rng.choice(months, n).astype(np.int64),
            "delay": rng.standard_normal(n).astype(np.float32)}
    spec = PartitionSpec.range_("flightdate",
                                list(months) + [202413],
                                ids=[f"m{m % 100:02d}" for m in months])
    fp = IndexedFrame.from_columns(cols, FD_SCH, rows_per_batch=4096,
                                   partition_by=spec)
    fm = IndexedFrame.from_columns(cols, FD_SCH, rows_per_batch=4096)
    q = np.asarray([202406], np.int64)
    mm = 4096
    plan = fp.plan_lookup(q, max_matches=mm)
    assert plan.kind == "PartitionedLookup" and plan.meta == [5], plan
    t_pruned = timeit(lambda: fp.lookup(q, max_matches=mm)[1], reps=3)
    t_full = timeit(lambda: fm.lookup(q, max_matches=mm)[1], reps=3)
    row = {"label": "month_point_query (1/12 months)",
           "backend": "local+partitioned",
           "pruned_ms": t_pruned["median_s"] * 1e3,
           "unpruned_ms": t_full["median_s"] * 1e3,
           "prune_speedup": t_full["median_s"] / t_pruned["median_s"],
           "partitions_scanned": 1, "partitions_total": 12,
           "plan": plan.reason}
    rows.append(row)
    rep.add(row["label"], **{k: v for k, v in row.items()
                             if k not in ("label", "plan")})


def run(quick: bool = True):
    rng = np.random.default_rng(10)
    n = 60_000 if quick else 600_000
    rep = Report("flights_queries")
    flights, tails = flights_table(rng, n)
    fn = flights["flightnum"]       # plant Q5-Q7's hot result sizes
    fn[:1000], fn[1000:1100], fn[1100:1110] = HOT_1000, HOT_100, HOT_10
    planes = {"tailnum_h": tails,
              "year": rng.integers(1990, 2020, len(tails))
              .astype(np.int32)}
    rows = []

    _queries(rep, rows, "local", flights, planes, tails, n, {})
    _queries(rep, rows, "dist_vmap", flights, planes, tails, n,
             {"num_shards": 4})
    _dict_encode_cell(rep, rows, rng)
    _partitioned_cell(rep, rows, rng, n)

    update_workloads("flights_queries", {"quick": quick, "rows": rows})
    return rep.to_dict()


if __name__ == "__main__":
    run(quick=True)
