"""Fig 12 grown into a chaos sweep: fault type × write rate through the
supervised frame (dist/resilience.py; DESIGN.md §12).

The original Fig-12 scenario — kill one shard mid-run, the failed query
pays the rebuild, the tail stays flat — is now ONE cell of a grid.  Each
cell drives a seeded ``FaultInjector`` plan through
``IndexedFrame.supervised`` (no caller-side failure handling anywhere in
the loop), alongside a never-failed twin frame receiving the identical
appends, and reports:

* steady-state vs failure-query latency (the Fig-12 spike shape),
* MTTR and replay cost (``replayed_deltas`` — O(deltas since the last
  checkpoint), not O(full history): the checkpoint-anchored lineage),
* recompile count (the manager's retrace counter: recovery must re-enter
  the SAME jit cache entry — the flat tail depends on it),
* retry/drop accounting for the capacity-pressure cells,
* bit-identity of every post-recovery answer against the twin.

Results land in ``BENCH_dist.json`` at the repo root (the committed
artifact) as well as the harness report.
"""

import json
import os
import time

import numpy as np

from repro.core import Schema
from repro.dist.resilience import Fault, FaultInjector, RecoveryPolicy
from repro.dist.runtime import Lineage
from repro.frame import IndexedFrame
from benchmarks.common import Report, powerlaw_keys

SCH = Schema.of("k", k="int64", v="float32")
NUM_SHARDS = 4

# fault plans are step-indexed over ticks (one tick per supervised read
# or append); write_rate w means each loop step is 1 read + w appends
_FAULT_PLANS = {
    "none": lambda kill, shard: [],
    "shard_loss": lambda kill, shard: [
        Fault("shard_loss", step=kill, shard=shard)],
    "straggler": lambda kill, shard: [
        Fault("straggler", step=kill, shard=shard, severity=16.0)],
    "capacity_pressure": lambda kill, shard: [
        Fault("capacity_pressure", step=kill, severity=8.0)],
    # corrupt the newest checkpoint one tick before killing the shard:
    # recovery must reject it (CRC) and fall back to an older anchor
    "checkpoint_corruption": lambda kill, shard: [
        Fault("checkpoint_corruption", step=kill - 1),
        Fault("shard_loss", step=kill, shard=shard)],
}


def _bit_identical(mgr, twin, q, max_matches, op):
    cols, valid = mgr.lookup(q, max_matches=max_matches, op=op)
    tc, tv = twin.lookup(q, max_matches=max_matches, op=op)
    ok = np.array_equal(np.asarray(valid), np.asarray(tv))
    for k in tc:
        ok &= np.array_equal(np.asarray(cols[k]), np.asarray(tc[k]))
    return ok


def _chaos_cell(fault_kind: str, write_rate: int, *, base_cols, ckpt_root,
                n_steps: int, kill_step: int, rng) -> dict:
    """One grid cell: seeded fault plan, supervised query/append loop,
    twin-checked answers."""
    frame = IndexedFrame.from_columns(base_cols, SCH,
                                      num_shards=NUM_SHARDS,
                                      rows_per_batch=2048)
    twin = IndexedFrame.from_columns(base_cols, SCH,
                                     num_shards=NUM_SHARDS,
                                     rows_per_batch=2048)
    # kill_step is in loop steps; convert to injector ticks (1 read +
    # write_rate appends per step, fault fires on the read tick)
    kill_tick = kill_step * (1 + write_rate)
    dead_shard = 2
    mgr = frame.supervised(
        lineage=Lineage(SCH, base_cols, rows_per_batch=2048),
        injector=FaultInjector(
            _FAULT_PLANS[fault_kind](kill_tick, dead_shard), seed=5),
        policy=RecoveryPolicy(checkpoint_every=max(1, 2 * write_rate),
                              keep_checkpoints=3),
        checkpoint_dir=os.path.join(ckpt_root,
                                    f"{fault_kind}_w{write_rate}"))
    op = "routed" if fault_kind == "capacity_pressure" else "auto"
    q = rng.choice(base_cols["k"], 128).astype(np.int64)
    n = base_cols["k"].shape[0]

    lat, identical = [], True
    total_deltas = 0
    for step in range(n_steps):
        t0 = time.perf_counter()
        ok = _bit_identical(mgr, twin, q, 16, op)
        lat.append(time.perf_counter() - t0)
        identical &= bool(ok)
        for w in range(write_rate):
            delta = {"k": np.asarray(
                         [n + (step * write_rate + w)], np.int64),
                     "v": np.asarray([float(step)], np.float32)}
            mgr.append(delta)
            twin = twin.append(delta)
            total_deltas += 1

    st = mgr.stats
    steady = float(np.median(lat[1:kill_step]))
    failure = float(lat[kill_step])
    post = float(np.median(lat[kill_step + 1:]))
    return {
        "fault": fault_kind, "write_rate": write_rate,
        "steady_state_ms": steady * 1e3,
        "failure_query_ms": failure * 1e3,
        "failure_spike_x": failure / steady,
        "post_recovery_ms": post * 1e3,
        "recovered": bool(post < 2 * steady),
        "bit_identical": identical,
        "mttr_ms": [s * 1e3 for s in st.mttr_s],
        "recoveries": st.recoveries,
        "replayed_deltas": st.replayed_deltas,
        "total_deltas": total_deltas,
        "retraces": mgr.retraces,
        "retries": st.retries, "drops": st.drops,
        "corrupt_checkpoints": st.corrupt_checkpoints,
        "straggler_events": st.straggler_events,
        "degraded_reads": st.degraded_reads,
    }


def run(quick: bool = True):
    import jax
    import tempfile
    rng = np.random.default_rng(5)
    n = 20_000 if quick else 200_000
    n_steps = 24 if quick else 100
    kill_step = 10
    write_rates = (0, 2) if quick else (0, 1, 4)
    kinds = (list(_FAULT_PLANS) if not quick
             else ["shard_loss", "capacity_pressure",
                   "checkpoint_corruption"])
    rep = Report("fault_tolerance")

    base_cols = {"k": powerlaw_keys(rng, n, n // 8),
                 "v": rng.random(n).astype(np.float32)}
    cells = []
    with tempfile.TemporaryDirectory() as ckpt_root:
        for kind in kinds:
            for w in write_rates:
                if kind == "checkpoint_corruption" and w == 0:
                    # a write-free run has exactly one checkpoint; with
                    # it corrupt there is no older anchor to fall back to
                    continue
                cell = _chaos_cell(kind, w, base_cols=base_cols,
                                   ckpt_root=ckpt_root, n_steps=n_steps,
                                   kill_step=kill_step, rng=rng)
                cells.append(cell)
                rep.add(f"{kind}_w{w}",
                        failure_ms=cell["failure_query_ms"],
                        spike_x=cell["failure_spike_x"],
                        mttr_ms=(cell["mttr_ms"][0]
                                 if cell["mttr_ms"] else 0.0),
                        replayed=(cell["replayed_deltas"][0]
                                  if cell["replayed_deltas"] else 0),
                        retraces=cell["retraces"],
                        bit_identical=cell["bit_identical"])

    # the acceptance claims, checked over the whole sweep
    healed = [c for c in cells if c["recoveries"]]
    summary = {
        "all_bit_identical": all(c["bit_identical"] for c in cells),
        "zero_recompiles": all(
            c["retraces"] <= (2 if c["fault"] == "capacity_pressure"
                              else 1) for c in cells),
        "replay_bounded_by_suffix": all(
            max(c["replayed_deltas"]) <= max(1, 2 * c["write_rate"])
            for c in healed),
    }
    out_path = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                            "BENCH_dist.json"))
    with open(out_path, "w") as f:
        json.dump({"benchmark": "fault_tolerance_chaos_sweep",
                   "quick": quick, "backend": jax.default_backend(),
                   "num_shards": NUM_SHARDS, "rows": n,
                   "steps": n_steps, "kill_step": kill_step,
                   "summary": summary, "cells": cells}, f, indent=2)
    return rep.to_dict()


if __name__ == "__main__":
    run(quick=True)
