"""Fig 12: executor failure during a query sequence.

Kill one shard mid-run; the failed query pays the rebuild (re-shuffle +
re-index + append replay), subsequent queries return to steady state.
Because a rebuilt dtable has identical leaf shapes, the recovered queries
re-enter the jitted join's compile cache — the paper's flat post-recovery
tail depends on exactly that.

Results land in ``BENCH_dist.json`` at the repo root (the committed
artifact) as well as the harness report.
"""

import json
import os
import time

import numpy as np

from repro.core import Schema
from repro.dist import (append_distributed, create_distributed,
                        indexed_join_bcast, runtime)
from benchmarks.common import Report, block, powerlaw_keys

SCH = Schema.of("k", k="int64", v="float32")


def run(quick: bool = True):
    rng = np.random.default_rng(5)
    n = 20_000 if quick else 200_000
    n_queries = 30 if quick else 200
    kill_at = 10
    rep = Report("fault_tolerance")

    cols = {"k": powerlaw_keys(rng, n, n // 8),
            "v": rng.random(n).astype(np.float32)}
    dt = create_distributed(cols, SCH, 4, rows_per_batch=2048)
    lin = runtime.Lineage(SCH, cols, rows_per_batch=2048)
    delta = {"k": rng.choice(cols["k"], 100).astype(np.int64),
             "v": rng.random(100).astype(np.float32)}
    dt = append_distributed(dt, delta)
    lin.record_append(delta)

    probe = rng.choice(cols["k"], 128).astype(np.int64)
    import jax
    jfn = jax.jit(lambda d, p: indexed_join_bcast(d, {"pk": p}, "pk", 16))
    block(jfn(dt, probe))                          # compile outside loop
    lat = []
    rebuild_s = None
    for i in range(n_queries):
        t0 = time.perf_counter()
        if i == kill_at:
            dt = runtime.fail_shard(dt, 2)        # executor dies
            dt = runtime.rebuild_shard(dt, 2, lin)  # lineage recovery
            rebuild_s = time.perf_counter() - t0
        block(jfn(dt, probe))
        lat.append(time.perf_counter() - t0)

    steady = float(np.median(lat[1:kill_at]))
    post = float(np.median(lat[kill_at + 1:]))
    rep.add("steady_state", ms=steady * 1e3)
    rep.add("failure_query", ms=lat[kill_at] * 1e3,
            spike_x=lat[kill_at] / steady,
            rebuild_ms=rebuild_s * 1e3)
    rep.add("post_recovery", ms=post * 1e3, recovered=post < 2 * steady)

    out_path = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                            "BENCH_dist.json"))
    with open(out_path, "w") as f:
        json.dump({"benchmark": "fault_tolerance", "quick": quick,
                   "backend": jax.default_backend(),
                   "num_shards": 4, "rows": n, "queries": n_queries,
                   "kill_at": kill_at,
                   "steady_state_ms": steady * 1e3,
                   "failure_query_ms": lat[kill_at] * 1e3,
                   "failure_spike_x": lat[kill_at] / steady,
                   "rebuild_ms": rebuild_s * 1e3,
                   "post_recovery_ms": post * 1e3,
                   "recovered": bool(post < 2 * steady)}, f, indent=2)
    return rep.to_dict()


if __name__ == "__main__":
    run(quick=True)
