"""Shared benchmark machinery: timing, synthetic datasets, reporting.

CPU-container caveat (DESIGN.md §8): wall-clock numbers here are CPU-XLA
measurements used for *relative* claims — indexed vs non-indexed, exactly
the comparison the paper makes.  TPU-roofline claims live in the dry-run
records (EXPERIMENTS.md §Roofline).
"""

from __future__ import annotations

import json
import time

import jax
import numpy as np


def block(x):
    jax.tree.map(lambda a: a.block_until_ready()
                 if hasattr(a, "block_until_ready") else a, x)
    return x


class SyncCounter:
    """Counts host<->device synchronization points while active.

    Wraps ``jax.device_get`` and ``jax.block_until_ready`` (the two
    funnels the repo's hot paths route every host sync through) so
    benchmarks report MEASURED syncs-per-operation, not just wall clock —
    the ISSUE-7 acceptance metric for the append queue (≤1 sync per
    flush).  Implicit conversions (``int(arr)``, ``np.asarray(arr)``)
    bypass the funnels, so hot paths must use ``jax.device_get``; the
    queue tests assert the flush path's count stays honest.

        with SyncCounter() as sc:
            frame = frame.enqueue(delta)       # 0 syncs
            frame = frame.flush()              # 1 sync (overflow flag)
        assert sc.syncs == 1
    """

    def __init__(self):
        self.device_gets = 0
        self.blocks = 0

    @property
    def syncs(self) -> int:
        return self.device_gets + self.blocks

    def __enter__(self):
        self._orig_get = jax.device_get
        self._orig_block = jax.block_until_ready

        def counted_get(x):
            self.device_gets += 1
            return self._orig_get(x)

        def counted_block(x):
            self.blocks += 1
            return self._orig_block(x)

        jax.device_get = counted_get
        jax.block_until_ready = counted_block
        return self

    def __exit__(self, *exc):
        jax.device_get = self._orig_get
        jax.block_until_ready = self._orig_block
        return False


def timeit(fn, *args, reps: int = 5, warmup: int = 1, **kw):
    """Median/mean/std seconds over reps (after warmup compiles)."""
    for _ in range(warmup):
        block(fn(*args, **kw))
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        block(fn(*args, **kw))
        ts.append(time.perf_counter() - t0)
    ts = np.asarray(ts)
    return {"median_s": float(np.median(ts)), "mean_s": float(ts.mean()),
            "std_s": float(ts.std()), "reps": reps}


# --- synthetic datasets -------------------------------------------------------

def powerlaw_keys(rng, n: int, n_unique: int, alpha: float = 1.3):
    """SNB-like power-law key distribution (social-graph degree skew)."""
    ranks = np.arange(1, n_unique + 1, dtype=np.float64)
    p = ranks ** -alpha
    p /= p.sum()
    return rng.choice(n_unique, size=n, p=p).astype(np.int64)


def edge_table(rng, n_edges: int, n_vertices: int):
    """SNB edge table analog: (src, dst, weight)."""
    return {"src": powerlaw_keys(rng, n_edges, n_vertices),
            "dst": rng.integers(0, n_vertices, n_edges).astype(np.int64),
            "weight": rng.random(n_edges).astype(np.float32)}


def star_schema(rng, n_fact: int, n_dim: int):
    """TPC-DS analog: store_sales (fact) + date_dim."""
    fact = {"ss_sold_date_sk": rng.integers(0, n_dim, n_fact)
            .astype(np.int64),
            "ss_net_paid": rng.random(n_fact).astype(np.float32),
            "ss_quantity": rng.integers(1, 100, n_fact).astype(np.int32)}
    dim = {"d_date_sk": np.arange(n_dim, dtype=np.int64),
           "d_year": (2000 + np.arange(n_dim) // 365).astype(np.int32)}
    return fact, dim


def flights_table(rng, n: int, n_planes: int = 400):
    """US-Flights analog: tailNum is a string key (pre-hashed at ingest,
    DESIGN.md §9), flightNum an int key."""
    from repro.core.hashing import hash_strings_host
    tails = hash_strings_host([f"N{i:05d}" for i in range(n_planes)])
    return {"tailnum_h": tails[rng.integers(0, n_planes, n)],
            "flightnum": rng.integers(0, 8000, n).astype(np.int64),
            "delay": rng.standard_normal(n).astype(np.float32),
            "distance": rng.integers(50, 5000, n).astype(np.int32)}, tails


# --- reporting ---------------------------------------------------------------

class Report:
    def __init__(self, name: str):
        self.name = name
        self.rows = []

    def add(self, label: str, **fields):
        self.rows.append({"label": label, **fields})
        flat = "  ".join(f"{k}={v:.4g}" if isinstance(v, float)
                         else f"{k}={v}" for k, v in fields.items())
        print(f"  [{self.name}] {label}: {flat}", flush=True)

    def to_dict(self):
        return {"benchmark": self.name, "rows": self.rows}


def update_workloads(section: str, payload: dict,
                     path: str | None = None) -> str:
    """Merge one workload benchmark's rows into the committed
    ``BENCH_workloads.json`` at the repo root (tpcds_join and
    flights_queries share the artifact — ROADMAP workload item)."""
    import os
    if path is None:
        path = os.path.abspath(os.path.join(os.path.dirname(__file__),
                                            "..", "BENCH_workloads.json"))
    doc = {}
    if os.path.exists(path):
        try:
            with open(path) as f:
                doc = json.load(f)
        except (OSError, json.JSONDecodeError):
            doc = {}
    doc[section] = payload
    doc["backend"] = jax.default_backend()
    with open(path, "w") as f:
        json.dump(doc, f, indent=2)
    return path
