"""Fig 7 + Table III: indexed join vs vanilla joins across probe scales.

The paper's S/M/L/XL probe relations (10K..10M rows against a 1B build
side) scale to CPU as ratios: build N, probes N/1000..N/10.  The indexed
side is pre-built once (amortized — the paper's core argument); baselines
rebuild their hash table per query, exactly like Spark's BroadcastHash.
"""

import jax
import numpy as np
import jax.numpy as jnp

from repro.core import Schema, create_index, joins
from repro.core.hashindex import suggest_num_buckets
from benchmarks.common import Report, powerlaw_keys, timeit

SCH = Schema.of("k", k="int64", v="float32")


def run(quick: bool = True):
    rng = np.random.default_rng(0)
    n = 50_000 if quick else 1_000_000
    rep = Report("join_scaling")
    build = {"k": powerlaw_keys(rng, n, n // 4),
             "v": rng.random(n).astype(np.float32)}
    table = create_index(build, SCH, rows_per_batch=4096)  # amortized
    nb = suggest_num_buckets(n, load=0.125)

    # the algorithms under test, compiled once (per probe shape)
    j_idx = jax.jit(lambda t, p: joins.indexed_join(t, p, "pk",
                                                    max_matches=16))
    j_hash = jax.jit(lambda b, p: joins.hash_join(
        b, "k", p, "pk", max_matches=16, num_buckets=nb))
    j_sm = jax.jit(lambda b, p: joins.sort_merge_join(
        b, "k", p, "pk", max_matches=16))

    for scale, frac in [("S", 1000), ("M", 100), ("L", 10)]:
        np_rows = max(64, n // frac)
        probe = {"pk": rng.choice(build["k"], np_rows).astype(np.int64),
                 "tag": np.arange(np_rows, dtype=np.int32)}
        t_idx = timeit(j_idx, table, probe)
        t_hash = timeit(j_hash, build, probe)
        t_sm = timeit(j_sm, build, probe)
        rep.add(f"{scale} (probe={np_rows})",
                indexed_ms=t_idx["median_s"] * 1e3,
                hash_ms=t_hash["median_s"] * 1e3,
                sortmerge_ms=t_sm["median_s"] * 1e3,
                speedup_vs_hash=t_hash["median_s"] / t_idx["median_s"],
                speedup_vs_sm=t_sm["median_s"] / t_idx["median_s"])
    return rep.to_dict()


if __name__ == "__main__":
    run(quick=True)
