"""Fig 8: SQL operator microbenchmarks — indexed vs vanilla.

join / eq-filter use the index (big wins); projection & non-eq filter pay
the row-layout tax (the paper's own finding: columnar beats row storage
for projections — we measure both layouts to reproduce it)."""

import jax
import numpy as np
import jax.numpy as jnp

from repro.core import Schema, create_index, joins
from repro.core.hashindex import suggest_num_buckets
from repro.core.planner import (Aggregate, Col, Eq, Filter, Lit, Lt,
                                Planner, Relation)
from benchmarks.common import Report, powerlaw_keys, timeit

SCH = Schema.of("k", k="int64", a="float32", b="float32", c="float32")


def run(quick: bool = True):
    rng = np.random.default_rng(1)
    n = 50_000 if quick else 500_000
    rep = Report("operators")
    cols = {"k": powerlaw_keys(rng, n, n // 8),
            "a": rng.random(n).astype(np.float32),
            "b": rng.random(n).astype(np.float32),
            "c": rng.random(n).astype(np.float32)}
    t_row = create_index(cols, SCH, rows_per_batch=4096, layout="row")
    t_col = create_index(cols, SCH, rows_per_batch=4096, layout="columnar")
    pl = Planner(max_matches=64)
    rel_row, rel_col = Relation("r", table=t_row), Relation("c", table=t_col)
    plain = Relation("p", cols=cols)
    key = int(cols["k"][0])

    nb = suggest_num_buckets(n, load=0.125)

    # join (indexed wins)
    probe = {"k": rng.choice(cols["k"], 512).astype(np.int64)}
    j_ij = jax.jit(lambda t, p: joins.indexed_join(t, p, "k",
                                                   max_matches=32))
    j_hj = jax.jit(lambda b, p: joins.hash_join(b, "k", p, "k",
                                                max_matches=32,
                                                num_buckets=nb))
    t_ij = timeit(j_ij, t_row, probe)
    t_hj = timeit(j_hj, cols, probe)
    rep.add("join", indexed_ms=t_ij["median_s"] * 1e3,
            vanilla_ms=t_hj["median_s"] * 1e3,
            speedup=t_hj["median_s"] / t_ij["median_s"])

    # eq-filter on key (indexed lookup vs scan)
    keys1 = np.asarray([key], np.int64)
    j_if = jax.jit(lambda t, q: joins.indexed_lookup(t, q, max_matches=64))
    j_sf = jax.jit(lambda t, q: joins.scan_lookup(t, q, max_matches=64))
    t_if = timeit(j_if, t_row, keys1)
    t_sf = timeit(j_sf, t_row, keys1)
    rep.add("filter_eq_key", indexed_ms=t_if["median_s"] * 1e3,
            vanilla_ms=t_sf["median_s"] * 1e3,
            speedup=t_sf["median_s"] / t_if["median_s"])

    # non-eq filter (fallback path; no index help — parity expected)
    def range_filter(t):
        vals, valid = t.scan_column("k")
        return valid & (vals < 100)
    j_rf = jax.jit(range_filter)
    t_lt_i = timeit(j_rf, t_row)
    t_lt_c = timeit(j_rf, t_col)
    rep.add("filter_range", row_ms=t_lt_i["median_s"] * 1e3,
            columnar_ms=t_lt_c["median_s"] * 1e3)

    # projection: row layout pays, columnar doesn't (paper's SQ5/SQ6 case)
    j_proj = jax.jit(lambda t: t.scan_column("b"))
    t_proj_row = timeit(j_proj, t_row)
    t_proj_col = timeit(j_proj, t_col)
    rep.add("projection", row_layout_ms=t_proj_row["median_s"] * 1e3,
            columnar_ms=t_proj_col["median_s"] * 1e3,
            row_tax=t_proj_row["median_s"] / t_proj_col["median_s"])

    # aggregation over an indexed lookup
    def agg(t, q):
        cols_, valid = joins.indexed_lookup(t, q, max_matches=64)
        return joins.aggregate(cols_["a"], valid, "sum")
    t_agg = timeit(jax.jit(agg), t_row, keys1)
    rep.add("aggregate_indexed", ms=t_agg["median_s"] * 1e3)

    # full scan (both pay once)
    t_scan = timeit(jax.jit(lambda t: t.scan_column("k")), t_row)
    rep.add("scan", ms=t_scan["median_s"] * 1e3)

    # planner overhead (rule rewrite itself, host-side)
    t_plan = timeit(lambda: pl.plan(Filter(rel_row, Eq(Col("k"),
                                                       Lit(key)))),
                    reps=20)
    rep.add("planner_rewrite_overhead", us=t_plan["median_s"] * 1e6)
    return rep.to_dict()


if __name__ == "__main__":
    run(quick=True)
