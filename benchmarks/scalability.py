"""Fig 6: scalability of the distributed Indexed DataFrame.

Three sweeps:

* horizontal / vertical (vmap lanes, as before): fixed data over more
  shards; fixed shards over more data.
* **mesh sweep** (the Fig-6 shape): the shard_map backend on a real
  multi-device host mesh (``XLA_FLAGS=
  --xla_force_host_platform_device_count=8``), 1/2/4/8 devices, timing
  the broadcast point lookup against ``lookup_routed`` at large Q.
  Broadcast probes every query on every device (s×Q lanes); routing
  probes each query once on its owner plus two all-to-alls (~2Q lanes at
  the 2x-overprovisioned capacity) — the s× redundancy the ROADMAP
  flags, measured.

The mesh sweep needs the forced device count set *before* jax
initializes, so it runs in a subprocess (``--mesh-worker``); the parent
collects its JSON and lands everything in ``BENCH_scale.json`` at the
repo root (the committed artifact) as well as the harness report.
"""

import json
import os
import subprocess
import sys

import jax
import numpy as np

from repro.core import Schema
from repro.core.planner import Planner
from benchmarks.common import Report, powerlaw_keys, timeit

SCH = Schema.of("k", k="int64", v="float32")
MESH_DEVICES = (1, 2, 4, 8)


def _vmap_sweeps(rep, rng, n):
    from repro.dist import create_distributed, indexed_join_bcast

    sch = SCH
    cols = {"k": powerlaw_keys(rng, n, n // 8),
            "v": rng.random(n).astype(np.float32)}
    probe = rng.choice(cols["k"], 256).astype(np.int64)
    jfn = jax.jit(lambda dt, p: indexed_join_bcast(dt, {"pk": p}, "pk", 16))

    # horizontal: fixed data, more shards (vmap lanes on CPU)
    base = None
    for shards in (1, 2, 4, 8):
        dt = create_distributed(cols, sch, shards, rows_per_batch=2048)
        t = timeit(jfn, dt, probe, reps=3)["median_s"]
        base = base or t
        rep.add(f"horizontal shards={shards}", ms=t * 1e3,
                vs_1shard=t / base)

    # vertical: fixed shards, growing data
    for mult in (1, 2, 4):
        nn = n * mult
        cc = {"k": powerlaw_keys(rng, nn, nn // 8),
              "v": rng.random(nn).astype(np.float32)}
        dt = create_distributed(cc, sch, 4, rows_per_batch=2048)
        t = timeit(jfn, dt, probe, reps=3)["median_s"]
        rep.add(f"vertical n={nn}", ms=t * 1e3)


def _mesh_worker(quick: bool):
    """Runs inside the forced-8-device subprocess (XLA_FLAGS is set in
    the child's env before python starts, so the module-level jax import
    already sees 8 devices): shard_map backend, broadcast vs routed
    point lookups per device count."""
    from repro import dist
    from repro.dist import mesh

    assert len(jax.devices()) >= max(MESH_DEVICES), jax.devices()
    sch = SCH
    rng = np.random.default_rng(7)
    n = 60_000 if quick else 400_000
    total_q = 131_072 if quick else 262_144
    max_matches = 8
    cols = {"k": powerlaw_keys(rng, n, n // 8),
            "v": rng.random(n).astype(np.float32)}
    # point-lookup workload: the key universe queried uniformly (each
    # distinct entity equally likely) — per-(src,dest) exchange lanes stay
    # near their expected load, so the 2x capacity never drops and the
    # broadcast/routed comparison is exact-vs-exact
    uniq = np.unique(cols["k"])
    q_flat = rng.choice(uniq, total_q).astype(np.int64)

    rows = []
    for d in MESH_DEVICES:
        rt = mesh.mesh_runtime(d)
        dt = dist.create_distributed(cols, sch, d, rows_per_batch=2048,
                                     rt=rt)
        per = total_q // d
        q_sharded = q_flat[:per * d].reshape(d, per)
        # 2x-overprovisioned exchange lanes: expected per-(src,dest) load
        # is per/d; drops are counted and reported (retry contract)
        cap = max(64, -(-2 * per // d))

        jb = jax.jit(lambda t_, p_, _rt=rt: dist.lookup(
            t_, p_, max_matches=max_matches, rt=_rt))
        jr = jax.jit(lambda t_, p_, _rt=rt, _c=cap: dist.lookup_routed(
            t_, p_, max_matches=max_matches, capacity=_c, rt=_rt))

        tb = timeit(jb, dt, q_flat, reps=5)["median_s"]
        tr = timeit(jr, dt, q_sharded, reps=5)["median_s"]
        dropped = int(np.asarray(jr(dt, q_sharded)[3]).sum())
        phys = Planner().physical_lookup(dt, total_q)
        rows.append({"label": f"mesh devices={d}",
                     "devices": d, "total_queries": total_q,
                     "bcast_ms": tb * 1e3, "routed_ms": tr * 1e3,
                     "routed_speedup": tb / tr,
                     "routed_capacity": cap, "routed_dropped": dropped,
                     "planner": ("routed" if phys.kind == "RoutedLookup"
                                 else "bcast"),
                     "planner_rule": phys.reason})
    print("MESH_SWEEP_JSON " + json.dumps(rows), flush=True)


def _mesh_sweep(rep, quick: bool):
    """Spawn the forced-device subprocess and fold its rows in."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + f" --xla_force_host_platform_device_count="
                          f"{max(MESH_DEVICES)}").strip()
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    cmd = [sys.executable, "-m", "benchmarks.scalability", "--mesh-worker"]
    if not quick:
        cmd.append("--full")
    proc = subprocess.run(cmd, capture_output=True, text=True, env=env,
                          cwd=root, timeout=3600)
    if proc.returncode != 0:
        raise RuntimeError(f"mesh worker failed:\n{proc.stdout}\n"
                           f"{proc.stderr}")
    line = [ln for ln in proc.stdout.splitlines()
            if ln.startswith("MESH_SWEEP_JSON ")][-1]
    rows = json.loads(line[len("MESH_SWEEP_JSON "):])
    for r in rows:
        rep.add(r["label"], bcast_ms=r["bcast_ms"],
                routed_ms=r["routed_ms"],
                routed_speedup=r["routed_speedup"],
                routed_dropped=r["routed_dropped"])
    return rows


def run(quick: bool = True):
    rng = np.random.default_rng(7)
    n = 30_000 if quick else 300_000
    rep = Report("scalability")
    _vmap_sweeps(rep, rng, n)
    mesh_rows = _mesh_sweep(rep, quick)

    out_path = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                            "BENCH_scale.json"))
    with open(out_path, "w") as f:
        json.dump({"benchmark": "scalability", "quick": quick,
                   "backend": jax.default_backend(),
                   "mesh_sweep": mesh_rows,
                   "rows": rep.to_dict()["rows"]}, f, indent=2)
    return rep.to_dict()


if __name__ == "__main__":
    if "--mesh-worker" in sys.argv:
        _mesh_worker(quick="--full" not in sys.argv)
    else:
        run(quick="--full" not in sys.argv)
