"""Fig 6: scalability of the distributed Indexed DataFrame.

Three sweeps:

* horizontal / vertical (vmap lanes, as before): fixed data over more
  shards; fixed shards over more data.
* **mesh sweep** (the Fig-6 shape): the shard_map backend on a real
  multi-device host mesh (``XLA_FLAGS=
  --xla_force_host_platform_device_count=8``), 1/2/4/8 devices, timing
  the broadcast point lookup against ``lookup_routed`` at large Q.
  Broadcast probes every query on every device (s×Q lanes); routing
  probes each query once on its owner plus two all-to-alls (~2Q lanes at
  the 2x-overprovisioned capacity) — the s× redundancy the ROADMAP
  flags, measured.

* **skew sweep** (ISSUE 9): Zipf-distributed data and queries at
  s in {0.5, 1.0, 1.5}, pure routing at the standard 2x exchange
  capacity vs the hot-key-replicated hybrid (DESIGN.md §15) — on the
  vmap-4 backend in this process and on the forced-8 shard_map mesh in
  the subprocess.  The headline: at s=1.5 routing drops and needs
  capacity-doubling retries to deliver; the hybrid stays flat at zero
  drops, bit-identical to the full-capacity routed oracle.

The mesh sweep needs the forced device count set *before* jax
initializes, so it runs in a subprocess (``--mesh-worker``); the parent
collects its JSON and lands everything in ``BENCH_scale.json`` at the
repo root (the committed artifact) as well as the harness report.
"""

import json
import os
import subprocess
import sys

import jax
import numpy as np

from repro.core import Schema
from repro.core.planner import Planner
from benchmarks.common import Report, powerlaw_keys, timeit

SCH = Schema.of("k", k="int64", v="float32")
MESH_DEVICES = (1, 2, 4, 8)
ZIPF_S = (0.5, 1.0, 1.5)


def _zipf_keys(rng, n, uniques, s):
    ranks = np.arange(1, uniques + 1, dtype=np.float64)
    p = ranks ** -float(s)
    p /= p.sum()
    return rng.choice(uniques, size=n, p=p).astype(np.int64)


def _skew_rows(num_shards, rt, quick, topology):
    """The skew sweep (ISSUE 9 headline): Zipf-distributed data AND
    queries at s in ZIPF_S, pure routing at the standard 2x-provisioned
    exchange capacity vs the hot-key-replicated hybrid.

    Per cell: one-shot routed latency + drops at the standard capacity,
    the retry-until-delivered blowup (the RecoveryManager's doubling
    contract replayed by hand — total wall clock a pressured caller
    actually waits), hybrid latency + drops at the SAME capacity, hot
    coverage, and a bitwise parity check against the full-capacity
    routed oracle.  At s=1.5 most queries hit one owner: routing
    collapses (drops at any fixed capacity, delivered only after
    doublings) while the hybrid stays flat — its hot lanes never enter
    the exchange.
    """
    from repro import dist

    n = 40_000 if quick else 200_000
    total_q = 8_192 if quick else 32_768
    uniques = 4_096
    max_matches = 8
    per = -(-total_q // num_shards)
    cap = max(64, -(-2 * per // num_shards))    # standard 2x provisioning
    rows = []
    for s_exp in ZIPF_S:
        rng = np.random.default_rng(17 + int(s_exp * 10))
        data_k = _zipf_keys(rng, n, uniques, s_exp)
        q = _zipf_keys(rng, total_q, uniques, s_exp)
        base = {"k": np.arange(4, dtype=np.int64),
                "v": np.zeros(4, np.float32)}
        dt = dist.create_distributed(base, SCH, num_shards,
                                     rows_per_batch=2048,
                                     reserve=n + 4096, track_hot=64, rt=rt)
        dt = dist.append_distributed(
            dt, {"k": data_k, "v": rng.random(n).astype(np.float32)},
            rt=rt)
        dt = dist.attach_replica(dt, capacity=64, max_matches=max_matches)
        dt = dist.refresh_replica(dt, rt=rt)

        jr = jax.jit(lambda t_, p_, _rt=rt, _c=cap:
                     dist.lookup_routed_report(
                         t_, p_, max_matches=max_matches, capacity=_c,
                         rt=_rt))
        jh = jax.jit(lambda t_, p_, _rt=rt, _c=cap:
                     dist.lookup_hybrid_report(
                         t_, p_, max_matches=max_matches, capacity=_c,
                         rt=_rt))
        tr = timeit(jr, dt, q, reps=5)["median_s"]
        th = timeit(jh, dt, q, reps=5)["median_s"]
        routed_drops = int(np.asarray(jr(dt, q)[3]).sum())
        hybrid_drops = int(np.asarray(jh(dt, q)[3]).sum())

        # retry-until-delivered: double capacity per attempt until the
        # exchange stops dropping (the resilience layer's contract)
        deliver_ms, retries, c = 0.0, 0, cap
        while True:
            ja = jax.jit(lambda t_, p_, _rt=rt, _c=c:
                         dist.lookup_routed_report(
                             t_, p_, max_matches=max_matches, capacity=_c,
                             rt=_rt))
            deliver_ms += timeit(ja, dt, q, reps=3)["median_s"] * 1e3
            if int(np.asarray(ja(dt, q)[3]).sum()) == 0 or retries >= 8:
                break
            retries += 1
            c *= 2

        ch, vh = dist.lookup_hybrid_flat(dt, q, max_matches=max_matches,
                                         rt=rt)
        cr, vr = dist.lookup_routed_flat(dt, q, max_matches=max_matches,
                                         rt=rt)
        parity = bool(np.array_equal(np.asarray(vh), np.asarray(vr))
                      and all(np.array_equal(np.asarray(ch[k]),
                                             np.asarray(cr[k]))
                              for k in ch))
        rows.append({"label": f"skew {topology} s={s_exp}",
                     "topology": topology, "zipf_s": s_exp,
                     "num_shards": num_shards, "total_queries": total_q,
                     "capacity": cap,
                     "routed_ms": tr * 1e3, "routed_dropped": routed_drops,
                     "routed_delivered_ms": deliver_ms,
                     "routed_retries": retries,
                     "hybrid_ms": th * 1e3, "hybrid_dropped": hybrid_drops,
                     "hot_fraction": dist.hot_fraction(dt, q),
                     "parity_ok": parity})
    return rows


def _vmap_sweeps(rep, rng, n):
    from repro.dist import create_distributed, indexed_join_bcast

    sch = SCH
    cols = {"k": powerlaw_keys(rng, n, n // 8),
            "v": rng.random(n).astype(np.float32)}
    probe = rng.choice(cols["k"], 256).astype(np.int64)
    jfn = jax.jit(lambda dt, p: indexed_join_bcast(dt, {"pk": p}, "pk", 16))

    # horizontal: fixed data, more shards (vmap lanes on CPU)
    base = None
    for shards in (1, 2, 4, 8):
        dt = create_distributed(cols, sch, shards, rows_per_batch=2048)
        t = timeit(jfn, dt, probe, reps=3)["median_s"]
        base = base or t
        rep.add(f"horizontal shards={shards}", ms=t * 1e3,
                vs_1shard=t / base)

    # vertical: fixed shards, growing data
    for mult in (1, 2, 4):
        nn = n * mult
        cc = {"k": powerlaw_keys(rng, nn, nn // 8),
              "v": rng.random(nn).astype(np.float32)}
        dt = create_distributed(cc, sch, 4, rows_per_batch=2048)
        t = timeit(jfn, dt, probe, reps=3)["median_s"]
        rep.add(f"vertical n={nn}", ms=t * 1e3)


def _mesh_worker(quick: bool):
    """Runs inside the forced-8-device subprocess (XLA_FLAGS is set in
    the child's env before python starts, so the module-level jax import
    already sees 8 devices): shard_map backend, broadcast vs routed
    point lookups per device count."""
    from repro import dist
    from repro.dist import mesh

    assert len(jax.devices()) >= max(MESH_DEVICES), jax.devices()
    sch = SCH
    rng = np.random.default_rng(7)
    n = 60_000 if quick else 400_000
    total_q = 131_072 if quick else 262_144
    max_matches = 8
    cols = {"k": powerlaw_keys(rng, n, n // 8),
            "v": rng.random(n).astype(np.float32)}
    # point-lookup workload: the key universe queried uniformly (each
    # distinct entity equally likely) — per-(src,dest) exchange lanes stay
    # near their expected load, so the 2x capacity never drops and the
    # broadcast/routed comparison is exact-vs-exact
    uniq = np.unique(cols["k"])
    q_flat = rng.choice(uniq, total_q).astype(np.int64)

    rows = []
    for d in MESH_DEVICES:
        rt = mesh.mesh_runtime(d)
        dt = dist.create_distributed(cols, sch, d, rows_per_batch=2048,
                                     rt=rt)
        per = total_q // d
        q_sharded = q_flat[:per * d].reshape(d, per)
        # 2x-overprovisioned exchange lanes: expected per-(src,dest) load
        # is per/d; drops are counted and reported (retry contract)
        cap = max(64, -(-2 * per // d))

        jb = jax.jit(lambda t_, p_, _rt=rt: dist.lookup(
            t_, p_, max_matches=max_matches, rt=_rt))
        jr = jax.jit(lambda t_, p_, _rt=rt, _c=cap: dist.lookup_routed(
            t_, p_, max_matches=max_matches, capacity=_c, rt=_rt))

        tb = timeit(jb, dt, q_flat, reps=5)["median_s"]
        tr = timeit(jr, dt, q_sharded, reps=5)["median_s"]
        dropped = int(np.asarray(jr(dt, q_sharded)[3]).sum())
        phys = Planner().physical_lookup(dt, total_q)
        rows.append({"label": f"mesh devices={d}",
                     "devices": d, "total_queries": total_q,
                     "bcast_ms": tb * 1e3, "routed_ms": tr * 1e3,
                     "routed_speedup": tb / tr,
                     "routed_capacity": cap, "routed_dropped": dropped,
                     "planner": ("routed" if phys.kind == "RoutedLookup"
                                 else "bcast"),
                     "planner_rule": phys.reason})
    print("MESH_SWEEP_JSON " + json.dumps(rows), flush=True)
    skew = _skew_rows(max(MESH_DEVICES), mesh.mesh_runtime(max(MESH_DEVICES)),
                      quick, f"shard_map-{max(MESH_DEVICES)}")
    print("SKEW_SWEEP_JSON " + json.dumps(skew), flush=True)


def _mesh_sweep(rep, quick: bool):
    """Spawn the forced-device subprocess and fold its rows in."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + f" --xla_force_host_platform_device_count="
                          f"{max(MESH_DEVICES)}").strip()
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    cmd = [sys.executable, "-m", "benchmarks.scalability", "--mesh-worker"]
    if not quick:
        cmd.append("--full")
    proc = subprocess.run(cmd, capture_output=True, text=True, env=env,
                          cwd=root, timeout=3600)
    if proc.returncode != 0:
        raise RuntimeError(f"mesh worker failed:\n{proc.stdout}\n"
                           f"{proc.stderr}")
    def grab(tag):
        line = [ln for ln in proc.stdout.splitlines()
                if ln.startswith(tag + " ")][-1]
        return json.loads(line[len(tag) + 1:])

    rows = grab("MESH_SWEEP_JSON")
    for r in rows:
        rep.add(r["label"], bcast_ms=r["bcast_ms"],
                routed_ms=r["routed_ms"],
                routed_speedup=r["routed_speedup"],
                routed_dropped=r["routed_dropped"])
    skew_rows = grab("SKEW_SWEEP_JSON")
    return rows, skew_rows


def run(quick: bool = True):
    rng = np.random.default_rng(7)
    n = 30_000 if quick else 300_000
    rep = Report("scalability")
    _vmap_sweeps(rep, rng, n)
    skew_rows = _skew_rows(4, None, quick, "vmap-4")
    for r in skew_rows:
        rep.add(r["label"], routed_ms=r["routed_ms"],
                routed_dropped=r["routed_dropped"],
                routed_delivered_ms=r["routed_delivered_ms"],
                hybrid_ms=r["hybrid_ms"],
                hybrid_dropped=r["hybrid_dropped"],
                hot_fraction=r["hot_fraction"], parity_ok=r["parity_ok"])
    mesh_rows, skew_mesh = _mesh_sweep(rep, quick)
    for r in skew_mesh:
        rep.add(r["label"], routed_ms=r["routed_ms"],
                routed_dropped=r["routed_dropped"],
                routed_delivered_ms=r["routed_delivered_ms"],
                hybrid_ms=r["hybrid_ms"],
                hybrid_dropped=r["hybrid_dropped"],
                hot_fraction=r["hot_fraction"], parity_ok=r["parity_ok"])

    out_path = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                            "BENCH_scale.json"))
    with open(out_path, "w") as f:
        json.dump({"benchmark": "scalability", "quick": quick,
                   "backend": jax.default_backend(),
                   "mesh_sweep": mesh_rows,
                   "skew_sweep": skew_rows + skew_mesh,
                   "rows": rep.to_dict()["rows"]}, f, indent=2)
    return rep.to_dict()


if __name__ == "__main__":
    if "--mesh-worker" in sys.argv:
        _mesh_worker(quick="--full" not in sys.argv)
    else:
        run(quick="--full" not in sys.argv)
