"""Fig 6: horizontal (shards) and vertical (problem size) scalability of
the distributed indexed join."""

import jax
import numpy as np

from repro.core import Schema
from repro.dist import create_distributed, indexed_join_bcast
from benchmarks.common import Report, powerlaw_keys, timeit

SCH = Schema.of("k", k="int64", v="float32")


def run(quick: bool = True):
    rng = np.random.default_rng(7)
    n = 30_000 if quick else 300_000
    rep = Report("scalability")
    cols = {"k": powerlaw_keys(rng, n, n // 8),
            "v": rng.random(n).astype(np.float32)}
    probe = rng.choice(cols["k"], 256).astype(np.int64)
    jfn = jax.jit(lambda dt, p: indexed_join_bcast(dt, {"pk": p}, "pk", 16))

    # horizontal: fixed data, more shards (vmap lanes on CPU)
    base = None
    for shards in (1, 2, 4, 8):
        dt = create_distributed(cols, SCH, shards, rows_per_batch=2048)
        t = timeit(jfn, dt, probe, reps=3)["median_s"]
        base = base or t
        rep.add(f"horizontal shards={shards}", ms=t * 1e3,
                vs_1shard=t / base)

    # vertical: fixed shards, growing data
    for mult in (1, 2, 4):
        nn = n * mult
        cc = {"k": powerlaw_keys(rng, nn, nn // 8),
              "v": rng.random(nn).astype(np.float32)}
        dt = create_distributed(cc, SCH, 4, rows_per_batch=2048)
        t = timeit(jfn, dt, probe, reps=3)["median_s"]
        rep.add(f"vertical n={nn}", ms=t * 1e3)
    return rep.to_dict()


if __name__ == "__main__":
    run(quick=True)
