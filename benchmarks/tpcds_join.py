"""Fig 14: TPC-DS store_sales JOIN date_dim across scale factors.

Paper §III-C: "the index is always pre-built on the side of the join that
remains in place, i.e., the larger table (the build side)" — so
store_sales (fact) is indexed on ss_sold_date_sk and date_dim rows probe
it.  The paper's trend reproduces: the larger the fact table, the larger
the win (vanilla re-hashes the whole fact table per query; the index
amortizes it).

ISSUE 10 port: the indexed side now runs through the ``IndexedFrame``
facade (the frame is the jit argument) on BOTH backends (local + vmap
dist), plus a partitioned cell — store_sales date-partitioned by sale
year (``partition_by=PartitionSpec.range_``), probed with one year's
dates: planner rule P3 prunes the join to 1/Y partitions, and the row
reports pruned vs unpruned latency.  Results land in
``BENCH_workloads.json`` (committed artifact, shared with
flights_queries).
"""

import jax
import numpy as np

from repro import IndexedFrame, PartitionSpec
from repro.core import Schema, joins
from repro.core.hashindex import suggest_num_buckets
from benchmarks.common import (Report, star_schema, timeit,
                               update_workloads)

FACT_SCH = Schema.of("ss_sold_date_sk", ss_sold_date_sk="int64",
                     ss_net_paid="float32", ss_quantity="int32")

DAYS_PER_YEAR, YEARS = 365, 5


def _facade_cells(rep, rows, fact, dim, sf, n_fact, mm, nb):
    probe = {"d_date_sk": dim["d_date_sk"], "d_year": dim["d_year"]}
    j_hash = jax.jit(lambda f, p, nb=nb: joins.hash_join(
        f, "ss_sold_date_sk", p, "d_date_sk", max_matches=mm,
        num_buckets=nb))
    t_hash = timeit(j_hash, fact, probe, reps=3)

    for backend, kw in (("local", {}), ("dist_vmap", {"num_shards": 4})):
        fr = IndexedFrame.from_columns(fact, FACT_SCH,
                                       rows_per_batch=4096, **kw)
        j_idx = jax.jit(lambda f, p: f.join(p, "d_date_sk",
                                            max_matches=mm))
        t_idx = timeit(j_idx, fr, probe, reps=3)
        row = {"label": f"SF~{sf} (fact={n_fact}) {backend}",
               "backend": backend,
               "indexed_ms": t_idx["median_s"] * 1e3,
               "vanilla_ms": t_hash["median_s"] * 1e3,
               "speedup": t_hash["median_s"] / t_idx["median_s"]}
        rows.append(row)
        rep.add(row["label"], **{k: v for k, v in row.items()
                                 if k != "label"})


def _partitioned_cell(rep, rows, fact, dim, sf, mm):
    """Date-partitioned store_sales: one partition per sale year, probed
    with ONE year of dates — P3 prunes to 1/Y partitions."""
    cuts = [y * DAYS_PER_YEAR for y in range(YEARS + 1)]
    spec = PartitionSpec.range_("ss_sold_date_sk", cuts,
                                ids=[f"y{2000 + y}" for y in range(YEARS)])
    fp = IndexedFrame.from_columns(fact, FACT_SCH, rows_per_batch=4096,
                                   partition_by=spec)
    fm = IndexedFrame.from_columns(fact, FACT_SCH, rows_per_batch=4096)
    year = (dim["d_date_sk"] >= DAYS_PER_YEAR) & \
           (dim["d_date_sk"] < 2 * DAYS_PER_YEAR)
    probe = {"d_date_sk": dim["d_date_sk"][year],
             "d_year": dim["d_year"][year]}
    plan = fp.plan_join(probe, "d_date_sk", max_matches=mm)
    assert plan.kind == "PartitionedJoin" and plan.meta == [1], plan
    # both sides run the facade eagerly: the partitioned path routes on
    # HOST keys (jit would forfeit pruning), so its baseline must too
    t_pruned = timeit(lambda: fp.join(probe, "d_date_sk",
                                      max_matches=mm)[2], reps=3)
    t_full = timeit(lambda: fm.join(probe, "d_date_sk",
                                    max_matches=mm)[2], reps=3)
    row = {"label": f"SF~{sf} partitioned (1/{YEARS} years probed)",
           "backend": "local+partitioned",
           "pruned_ms": t_pruned["median_s"] * 1e3,
           "unpruned_ms": t_full["median_s"] * 1e3,
           "prune_speedup": t_full["median_s"] / t_pruned["median_s"],
           "partitions_scanned": 1, "partitions_total": YEARS,
           "plan": plan.reason}
    rows.append(row)
    rep.add(row["label"], **{k: v for k, v in row.items()
                             if k not in ("label", "plan")})


def run(quick: bool = True):
    rng = np.random.default_rng(8)
    rep = Report("tpcds_join")
    sfs = (1, 4, 16) if quick else (1, 10, 100)
    base_fact = 20_000 if quick else 100_000
    mm = 64   # matched sales rows returned per date key
    rows = []

    for sf in sfs:
        n_fact, n_dim = base_fact * sf, DAYS_PER_YEAR * YEARS
        fact, dim = star_schema(rng, n_fact, n_dim)
        nb = suggest_num_buckets(n_fact, load=0.125)
        _facade_cells(rep, rows, fact, dim, sf, n_fact, mm, nb)
        if sf == sfs[-1]:
            _partitioned_cell(rep, rows, fact, dim, sf, mm)

    update_workloads("tpcds_join", {"quick": quick, "rows": rows})
    return rep.to_dict()


if __name__ == "__main__":
    run(quick=True)
