"""Fig 14: TPC-DS store_sales JOIN date_dim across scale factors.

Paper §III-C: "the index is always pre-built on the side of the join that
remains in place, i.e., the larger table (the build side)" — so
store_sales (fact) is indexed on ss_sold_date_sk and date_dim rows probe
it.  The paper's trend reproduces: the larger the fact table, the larger
the win (vanilla re-hashes the whole fact table per query; the index
amortizes it)."""

import jax
import numpy as np

from repro.core import Schema, create_index, joins
from repro.core.hashindex import suggest_num_buckets
from benchmarks.common import Report, star_schema, timeit

FACT_SCH = Schema.of("ss_sold_date_sk", ss_sold_date_sk="int64",
                     ss_net_paid="float32", ss_quantity="int32")


def run(quick: bool = True):
    rng = np.random.default_rng(8)
    rep = Report("tpcds_join")
    sfs = (1, 4, 16) if quick else (1, 10, 100)
    base_fact = 20_000 if quick else 100_000
    mm = 64   # matched sales rows returned per date key

    for sf in sfs:
        n_fact, n_dim = base_fact * sf, 365 * 5
        fact, dim = star_schema(rng, n_fact, n_dim)
        fact_t = create_index(fact, FACT_SCH, rows_per_batch=4096)
        probe = {"d_date_sk": dim["d_date_sk"], "d_year": dim["d_year"]}
        nb = suggest_num_buckets(n_fact, load=0.125)
        j_idx = jax.jit(lambda t, p: joins.indexed_join(
            t, p, "d_date_sk", max_matches=mm))
        j_hash = jax.jit(lambda f, p, nb=nb: joins.hash_join(
            f, "ss_sold_date_sk", p, "d_date_sk", max_matches=mm,
            num_buckets=nb))
        t_idx = timeit(j_idx, fact_t, probe, reps=3)
        t_hash = timeit(j_hash, fact, probe, reps=3)
        rep.add(f"SF~{sf} (fact={n_fact})",
                indexed_ms=t_idx["median_s"] * 1e3,
                vanilla_ms=t_hash["median_s"] * 1e3,
                speedup=t_hash["median_s"] / t_idx["median_s"])
    return rep.to_dict()


if __name__ == "__main__":
    run(quick=True)
