"""Fig 9: read latency while appending — a jitted indexed join measured
after every append.

Models the paper's "users query data sources that get written into
regularly".  The pre-arena write path (``mode="segment"``, ``reserve=0``)
grows the table's pytree every version, so every append recompiles the
jitted read site AND adds probe fan-out — latency is dominated by
retraces.  The arena path (DESIGN.md §4) lands appends in the reserved
tail with zero pytree shape change: the read site compiles once and the
per-append latency stays flat across ≥50 appends (the acceptance claim
of ISSUE 4).  Results land in ``BENCH_append.json`` at the repo root
(shared with Fig 10 / write_throughput.py).
"""

import json
import os

import jax
import numpy as np

from repro.core import Schema, append, compact, create_index, joins
from benchmarks.common import Report, powerlaw_keys, timeit

SCH = Schema.of("k", k="int64", v="float32")

ARTIFACT = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                        "BENCH_append.json"))


def merge_artifact(section: str, payload: dict):
    """Read-merge-write one section of the shared BENCH_append.json."""
    doc = {}
    if os.path.exists(ARTIFACT):
        try:
            with open(ARTIFACT) as f:
                doc = json.load(f)
        except (json.JSONDecodeError, OSError):
            doc = {}
    doc[section] = payload
    doc["backend"] = jax.default_backend()
    with open(ARTIFACT, "w") as f:
        json.dump(doc, f, indent=2)


def _delta(rng, cols, rows):
    return {"k": rng.choice(cols["k"], rows).astype(np.int64),
            "v": rng.random(rows).astype(np.float32)}


def _latency_stream(t, mode, rng, cols, rows_per_write, n_appends, jfn,
                    probe):
    """Append every round, measure the jitted join after each; returns
    (per-append latencies seconds, final table)."""
    lat = []
    for _ in range(n_appends):
        t = append(t, _delta(rng, cols, rows_per_write), mode=mode)
        lat.append(timeit(jfn, t, probe, reps=1, warmup=1)["median_s"])
    return lat, t


def run(quick: bool = True):
    rng = np.random.default_rng(2)
    n = 30_000 if quick else 300_000
    n_appends = 60 if quick else 200          # acceptance: flat across >=50
    n_seg = 20 if quick else 60               # baseline (retraces: costly)
    rep = Report("append_read_latency")
    traces = {"n": 0}

    @jax.jit
    def jfn(t, p):
        traces["n"] += 1        # bumps only while tracing: the definitive
        return joins.indexed_join(t, p, "pk", max_matches=16)

    bench_rows = []

    for rows_per_write in (100, 1_000, 10_000):
        cols = {"k": powerlaw_keys(rng, n, n // 8),
                "v": rng.random(n).astype(np.float32)}
        probe = {"pk": rng.choice(cols["k"], 256).astype(np.int64)}

        # --- arena path: reserved capacity, in-place ingest ---------------
        # reserve the full stream so every append stays in-class (the
        # steady state the paper's Fig 9 plots); promotions are measured
        # by the class-boundary spike below
        t = create_index(cols, SCH, rows_per_batch=4096,
                         reserve=n + rows_per_write * (n_appends + 1))
        base = timeit(jfn, t, probe, reps=3)["median_s"]
        traces0 = traces["n"]
        lat, t_end = _latency_stream(t, "arena", rng, cols, rows_per_write,
                                     n_appends, jfn, probe)
        arena_retraces = traces["n"] - traces0
        flat_ratio = float(np.median(lat[-10:]) / np.median(lat[:10]))
        p95_ratio = float(np.percentile(lat, 95) / np.median(lat))

        # --- pre-arena baseline: per-append segments + retraces -----------
        t0 = create_index(cols, SCH, rows_per_batch=4096, reserve=0)
        traces0 = traces["n"]
        lat_seg, t_seg = _latency_stream(t0, "segment", rng, cols,
                                         rows_per_write, n_seg, jfn, probe)
        seg_retraces = traces["n"] - traces0
        t_seg = compact(t_seg)
        after = timeit(jfn, t_seg, probe, reps=3)["median_s"]

        row = dict(rows_per_write=rows_per_write, appends=n_appends,
                   base_ms=base * 1e3,
                   arena_first10_ms=float(np.median(lat[:10])) * 1e3,
                   arena_last10_ms=float(np.median(lat[-10:])) * 1e3,
                   arena_flat_ratio=flat_ratio,
                   arena_p95_over_median=p95_ratio,
                   arena_retraces=arena_retraces,   # acceptance: 0
                   arena_lat_ms=[round(x * 1e3, 4) for x in lat],
                   segment_appends=n_seg,
                   segment_retraces=seg_retraces,   # the pre-arena cost
                   segment_last5_ms=float(np.median(lat_seg[-5:])) * 1e3,
                   segment_slowdown=float(np.median(lat_seg[-5:]) / base),
                   after_compact_ms=after * 1e3,
                   arena_segments_end=t_end.num_segments,
                   segment_segments_end=n_seg + 1)
        bench_rows.append(row)
        rep.add(f"write={rows_per_write}",
                **{k: v for k, v in row.items() if k != "arena_lat_ms"})

    merge_artifact("fig9_append_read_latency",
                   {"quick": quick, "rows": bench_rows})
    return rep.to_dict()


if __name__ == "__main__":
    run(quick=True)
