"""Fig 9: read latency while appending — S joins with an append every 5.

Models the paper's "users query data sources that get written into
regularly": reads slow down as segments accumulate (probe fan-out), the
knob being append size.  Compaction resets the fan-out (the paper's cTrie
amortizes the same way)."""

import jax
import numpy as np

from repro.core import Schema, append, compact, create_index, joins
from benchmarks.common import Report, powerlaw_keys, timeit

SCH = Schema.of("k", k="int64", v="float32")


def run(quick: bool = True):
    rng = np.random.default_rng(2)
    n = 30_000 if quick else 300_000
    n_joins = 20 if quick else 200
    rep = Report("append_read_latency")
    jfn = jax.jit(lambda t, p: joins.indexed_join(t, p, "pk",
                                                  max_matches=16))

    for rows_per_write in (100, 1_000, 10_000):
        cols = {"k": powerlaw_keys(rng, n, n // 8),
                "v": rng.random(n).astype(np.float32)}
        t = create_index(cols, SCH, rows_per_batch=4096)
        probe = {"pk": rng.choice(cols["k"], 256).astype(np.int64)}
        base = timeit(jfn, t, probe, reps=3)["median_s"]
        lat = []
        for i in range(n_joins):
            if i and i % 5 == 0:
                delta = {"k": rng.choice(cols["k"], rows_per_write)
                         .astype(np.int64),
                         "v": rng.random(rows_per_write)
                         .astype(np.float32)}
                t = append(t, delta)
            lat.append(timeit(jfn, t, probe, reps=1,
                              warmup=1)["median_s"])
        slowdown = float(np.median(lat[-5:]) / base)
        t = compact(t)
        after = timeit(jfn, t, probe, reps=3)["median_s"]
        rep.add(f"write={rows_per_write}",
                base_ms=base * 1e3,
                end_ms=float(np.median(lat[-5:])) * 1e3,
                read_slowdown=slowdown,
                segments_before_compact=len(lat) // 5 + 1,
                after_compact_ms=after * 1e3)
    return rep.to_dict()


if __name__ == "__main__":
    run(quick=True)
