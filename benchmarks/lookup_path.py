"""Fused vs segment-looped lookup — the paper's Fig-1 amortization claim.

The index is built once and probed millions of times (paper §III-C), so the
probe -> chain-walk -> gather path must not scale with the number of MVCC
append segments.  This benchmark measures exactly that: the same point
lookup through

  * ``fused``  — one pass over the table's stored Snapshot (DESIGN.md §3):
    stacked bucket planes, flat prev array, single-gather row decode
    (``flat_build_s`` is now just the field access: the probe-side view is
    built eagerly inside create_index/append);
  * ``ref``    — the pre-fusion segment loop: every probe re-scans all
    segment indexes and every chain step re-scans all segments.

swept over segment counts (1 / 4 / 16 appends) and key skew (uniform and
SNB-like power-law), at ``max_matches=8``.  Results also land in
``BENCH_lookup.json`` at the repo root (the committed artifact).

Both paths are timed in their production call style: the fused path's core
is jitted inside ops.fused_lookup; the segment-looped path runs eagerly —
jit-compiling its O(segments x matches) select/gather chain is itself
pathological (XLA compile grows super-linearly: ~2 s at 8 segments, ~40 s
at 10, minutes at 16 on CPU), which is exactly the fan-out the Snapshot
removes.
"""

from __future__ import annotations

import json
import os
import time

import jax
import numpy as np

from benchmarks.common import Report, powerlaw_keys, timeit
from repro import IndexedFrame
from repro.core import Schema, append, create_index, joins

SCH = Schema.of("k", k="int64", v="float32", tag="int32")

MAX_MATCHES = 8
SEGMENT_COUNTS = (1, 4, 16)


def _make_cols(rng, n, n_unique, skew):
    if skew == "powerlaw":
        keys = powerlaw_keys(rng, n, n_unique)
    else:
        keys = rng.integers(0, n_unique, n).astype(np.int64)
    return {"k": keys,
            "v": rng.random(n).astype(np.float32),
            "tag": np.arange(n, dtype=np.int32)}


def _build_table(rng, total_rows, num_segments, n_unique, skew,
                 rows_per_batch):
    per = total_rows // num_segments
    t = create_index(_make_cols(rng, per, n_unique, skew), SCH,
                     rows_per_batch=rows_per_batch)
    for _ in range(num_segments - 1):
        t = append(t, _make_cols(rng, per, n_unique, skew))
    return t


def run(quick: bool = True):
    rep = Report("lookup_path")
    rng = np.random.default_rng(0)
    total_rows = 24_576 if quick else 262_144
    nq = 4096 if quick else 32_768
    n_unique = max(64, total_rows // 8)
    rows_per_batch = 512

    bench_rows = []
    for skew in ("uniform", "powerlaw"):
        for segs in SEGMENT_COUNTS:
            t = _build_table(rng, total_rows, segs, n_unique, skew,
                             rows_per_batch)
            if skew == "powerlaw":
                q = powerlaw_keys(rng, nq, n_unique)
            else:
                q = rng.integers(0, n_unique, nq).astype(np.int64)

            t0 = time.perf_counter()
            fv = t.flat_view()
            jax.block_until_ready(fv.prev)
            flat_build_s = time.perf_counter() - t0

            fused_fn = lambda qq: t.lookup(qq, MAX_MATCHES)[0]
            ref_fn = lambda qq: t.lookup(qq, MAX_MATCHES, fused=False)[0]
            fused_t = timeit(fused_fn, q, reps=3, warmup=1)
            ref_t = timeit(ref_fn, q, reps=3, warmup=1)

            fused_full = lambda qq: joins.indexed_lookup(
                t, qq, max_matches=MAX_MATCHES)[0]["v"]
            ref_full = lambda qq: joins.indexed_lookup(
                t, qq, max_matches=MAX_MATCHES, fused=False)[0]["v"]
            fused_full_t = timeit(fused_full, q, reps=3, warmup=1)
            ref_full_t = timeit(ref_full, q, reps=3, warmup=1)

            # the public facade dispatches onto the same fused path —
            # its overhead must be noise (ISSUE 5: zero-cost seam)
            fr = IndexedFrame(data=t)
            frame_full = lambda qq: fr.lookup(
                qq, max_matches=MAX_MATCHES)[0]["v"]
            frame_full_t = timeit(frame_full, q, reps=3, warmup=1)

            speedup = ref_t["median_s"] / fused_t["median_s"]
            speedup_full = (ref_full_t["median_s"]
                            / fused_full_t["median_s"])
            row = dict(skew=skew, segments=segs, queries=nq,
                       max_matches=MAX_MATCHES, total_rows=total_rows,
                       fused_s=fused_t["median_s"],
                       ref_s=ref_t["median_s"],
                       speedup=speedup,
                       fused_full_s=fused_full_t["median_s"],
                       ref_full_s=ref_full_t["median_s"],
                       speedup_full=speedup_full,
                       frame_full_s=frame_full_t["median_s"],
                       facade_overhead=(frame_full_t["median_s"]
                                        / fused_full_t["median_s"]),
                       flat_build_s=flat_build_s,
                       flat_extra_bytes=fv.nbytes())
            bench_rows.append(row)
            rep.add(f"{skew}/segs={segs}", **{
                k: v for k, v in row.items() if k not in ("skew",)})

    out_path = os.path.join(os.path.dirname(__file__), "..",
                            "BENCH_lookup.json")
    with open(os.path.abspath(out_path), "w") as f:
        json.dump({"benchmark": "lookup_path",
                   "quick": quick,
                   "backend": jax.default_backend(),
                   "rows": bench_rows}, f, indent=2)
    return rep.to_dict()
