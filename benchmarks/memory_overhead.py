"""Fig 11: index memory overhead per partition.

The paper reports <2% cTrie overhead on the 30 GB SNB edge table (wide
rows).  Overhead is a function of row width — we sweep it and report the
per-partition ratio for the SNB-like width alongside narrower rows."""

import numpy as np

from repro.core import Schema
from repro.dist import create_distributed
from benchmarks.common import Report, powerlaw_keys


def run(quick: bool = True):
    rng = np.random.default_rng(4)
    n = 40_000 if quick else 400_000
    shards = 8
    rep = Report("memory_overhead")

    for width_cols, label in ((2, "narrow(16B)"), (14, "snb-like(64B)"),
                              (62, "wide(256B)"),
                              (248, "paper-row(~1KB)")):
        sch = Schema.of("k", k="int64",
                        **{f"c{i}": "float32" for i in range(width_cols)})
        cols = {"k": powerlaw_keys(rng, n, n // 4),
                **{f"c{i}": rng.random(n).astype(np.float32)
                   for i in range(width_cols)}}
        dt = create_distributed(cols, sch, shards, rows_per_batch=2048)
        per_shard = []
        for s in range(shards):
            seg = dt.table.segments[0]
            idx_b = (seg.index.bucket_keys[s].size * 8
                     + seg.index.bucket_ptrs[s].size * 4
                     + seg.prev[s].size * 4)
            dat_b = (seg.data[s].size * 4 if dt.table.layout == "row"
                     else sum(a[s].size * a.dtype.itemsize
                              for a in seg.data.values()))
            per_shard.append(idx_b / dat_b)
        rep.add(label, mean_overhead=float(np.mean(per_shard)),
                max_overhead=float(np.max(per_shard)),
                min_overhead=float(np.min(per_shard)))
    return rep.to_dict()


if __name__ == "__main__":
    run(quick=True)
