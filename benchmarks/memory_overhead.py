"""Fig 11: index memory overhead per partition — logical vs reserved.

The paper reports <2% cTrie overhead on the 30 GB SNB edge table (wide
rows).  Overhead is a function of row width — we sweep it and report the
per-partition ratio for the SNB-like width alongside narrower rows.

Arena tables over-allocate to a capacity class (DESIGN.md §4), so the
planes carry reserved slack that is capacity planning, NOT index
overhead.  Two ratios are therefore reported per width:

* ``logical``  — occupied index entries + live-row pointers over live-row
  data bytes: the apples-to-apples Fig-11 figure.
* ``reserved`` — full reserved planes over full reserved data: what the
  accelerator actually holds resident, with ``slack`` (reserved/logical
  data bytes) making the arena headroom explicit.

The partitioned section (ISSUE 10) reports the same two ratios PER
PARTITION on a skewed rolling-window layout (most rows in the newest
window): cold windows run at much higher slack than the hot one, and
per-window accounting (``PartitionedTable.per_partition_bytes``) stops
that slack being attributed to the hot window the way a whole-table
ratio does.
"""

import numpy as np

from repro.core import Schema
from repro.core.hashindex import EMPTY_KEY
from repro.core.partition import PartitionSpec, create_partitioned
from repro.core.table import INDEX_ENTRY_BYTES, ROW_PTR_BYTES
from repro.dist import create_distributed
from benchmarks.common import Report, powerlaw_keys


def run(quick: bool = True):
    rng = np.random.default_rng(4)
    n = 40_000 if quick else 400_000
    shards = 8
    rep = Report("memory_overhead")

    for width_cols, label in ((2, "narrow(16B)"), (14, "snb-like(64B)"),
                              (62, "wide(256B)"),
                              (248, "paper-row(~1KB)")):
        sch = Schema.of("k", k="int64",
                        **{f"c{i}": "float32" for i in range(width_cols)})
        cols = {"k": powerlaw_keys(rng, n, n // 4),
                **{f"c{i}": rng.random(n).astype(np.float32)
                   for i in range(width_cols)}}
        dt = create_distributed(cols, sch, shards, rows_per_batch=2048)
        seg = dt.table.segments[0]
        row_bytes = sch.width_words * 4
        logical, reserved, slack = [], [], []
        for s in range(shards):
            nvalid = int(np.asarray(seg.valid[s]).sum())
            occupied = int((np.asarray(seg.index.bucket_keys[s])
                            != int(EMPTY_KEY)).sum())
            idx_logical = (occupied * INDEX_ENTRY_BYTES
                           + nvalid * ROW_PTR_BYTES)
            idx_reserved = (seg.index.bucket_keys[s].size * 8
                            + seg.index.bucket_ptrs[s].size * 4
                            + seg.prev[s].size * 4 + seg.valid[s].size)
            dat_logical = nvalid * row_bytes
            dat_reserved = (seg.data[s].size * 4
                            if dt.table.layout == "row"
                            else sum(a[s].size * a.dtype.itemsize
                                     for a in seg.data.values()))
            logical.append(idx_logical / max(dat_logical, 1))
            reserved.append(idx_reserved / dat_reserved)
            slack.append(dat_reserved / max(dat_logical, 1))
        rep.add(label,
                mean_overhead_logical=float(np.mean(logical)),
                max_overhead_logical=float(np.max(logical)),
                mean_overhead_reserved=float(np.mean(reserved)),
                max_overhead_reserved=float(np.max(reserved)),
                mean_arena_slack=float(np.mean(slack)))

    _per_partition(rep, rng, n)
    return rep.to_dict()


def _per_partition(rep, rng, n):
    """Rolling-window layout, 97% of rows in the newest window: report
    logical/reserved per window vs the whole-table aggregate."""
    width = 1_000_000
    nwin = 4
    sch = Schema.of("k", k="int64",
                    **{f"c{i}": "float32" for i in range(14)})
    win = rng.choice(nwin, n, p=[0.01, 0.01, 0.01, 0.97])
    keys = (win.astype(np.int64) * width
            + rng.integers(0, width, n).astype(np.int64))
    cols = {"k": keys, **{f"c{i}": rng.random(n).astype(np.float32)
                          for i in range(14)}}
    spec = PartitionSpec.range_("k", [w * width for w in range(nwin + 1)],
                                ids=[f"w{w}" for w in range(nwin)])
    pt = create_partitioned(cols, sch, spec, rows_per_batch=2048)
    for r in pt.per_partition_bytes():
        rep.add(f"snb-like(64B) partition {r['partition']}",
                rows=r["rows"],
                overhead_logical=(r["index_logical"]
                                  / max(r["data_logical"], 1)),
                overhead_reserved=(r["index_reserved"]
                                   / max(r["data_reserved"], 1)),
                arena_slack=(r["data_reserved"]
                             / max(r["data_logical"], 1)))
    rep.add("snb-like(64B) whole-table (slack smeared)",
            rows=int(np.asarray(pt.num_rows())),
            overhead_logical=(int(pt.index_nbytes(logical=True))
                              / int(pt.data_nbytes(logical=True))),
            arena_slack=(int(pt.data_nbytes())
                         / int(pt.data_nbytes(logical=True))))


if __name__ == "__main__":
    run(quick=True)
