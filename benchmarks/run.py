"""Benchmark harness entry point: one module per paper table/figure.

  python -m benchmarks.run [--full] [--only NAME] [--out results.json]

Default is quick mode (CI-scale datasets); --full uses the larger sizes.
See DESIGN.md §8 for the module ↔ paper figure mapping.
"""

import argparse
import json
import sys
import time

from benchmarks import (append_read_latency, batch_size_sweep,
                        fault_tolerance, flights_queries, join_scaling,
                        memory_overhead, operators, scalability,
                        snb_queries, tpcds_join, write_throughput)

MODULES = {
    "join_scaling": join_scaling,          # Fig 7 + Table III
    "operators": operators,                # Fig 8
    "append_read_latency": append_read_latency,  # Fig 9
    "write_throughput": write_throughput,  # Fig 10
    "memory_overhead": memory_overhead,    # Fig 11
    "fault_tolerance": fault_tolerance,    # Fig 12
    "batch_size_sweep": batch_size_sweep,  # Fig 5
    "scalability": scalability,            # Fig 6
    "tpcds_join": tpcds_join,              # Fig 14
    "snb_queries": snb_queries,            # Fig 13
    "flights_queries": flights_queries,    # Fig 15
}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", choices=list(MODULES))
    ap.add_argument("--out", default="benchmarks/results.json")
    args = ap.parse_args(argv)

    todo = {args.only: MODULES[args.only]} if args.only else MODULES
    results, failures = [], 0
    for name, mod in todo.items():
        print(f"\n== {name} ==", flush=True)
        t0 = time.time()
        try:
            results.append(mod.run(quick=not args.full))
            print(f"   done in {time.time() - t0:.1f}s", flush=True)
        except Exception as e:  # report and continue
            failures += 1
            print(f"   FAILED: {type(e).__name__}: {e}", flush=True)
            results.append({"benchmark": name, "error": str(e)})
    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=2)
        print(f"\nwrote {args.out}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
