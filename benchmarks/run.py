"""Benchmark harness entry point: one module per paper table/figure.

  python -m benchmarks.run [--full] [--only NAME] [--out results.json]

Default is quick mode (CI-scale datasets); --full uses the larger sizes.
See DESIGN.md §8 for the module ↔ paper figure mapping.
"""

import argparse
import importlib
import json
import sys
import time

# Modules import lazily so one broken dependency cannot take down the whole
# harness.  lookup_path, fault_tolerance, and scalability additionally write
# the committed artifacts BENCH_lookup.json / BENCH_dist.json /
# BENCH_scale.json at the repo root (scalability's mesh sweep forces an
# 8-device host topology in a subprocess); append_read_latency and
# write_throughput share BENCH_append.json (Fig 9 + Fig 10, the arena
# write path before/after — DESIGN.md §4).
MODULES = {
    "lookup_path": "Fig 1 / §III-C hot path (-> BENCH_lookup.json)",
    "join_scaling": "Fig 7 + Table III",
    "operators": "Fig 8",
    "append_read_latency": "Fig 9 (-> BENCH_append.json)",
    "write_throughput": "Fig 10 (-> BENCH_append.json)",
    "ingest": "ISSUE 7 streaming ingest: ring enqueue/flush vs facade "
              "appends, measured syncs (-> BENCH_ingest.json)",
    "memory_overhead": "Fig 11 (logical vs reserved)",
    "fault_tolerance": "Fig 12 chaos sweep: fault x write rate through "
                       "the supervised frame (-> BENCH_dist.json)",
    "serve": "ISSUE 8 continuous-batching query engine: QPS x write-rate "
             "grid, p50/p99 SLOs, both topologies (-> BENCH_serve.json)",
    "batch_size_sweep": "Fig 5",
    "scalability": "Fig 6 (mesh sweep + ISSUE 9 Zipf skew sweep "
                   "-> BENCH_scale.json)",
    "tpcds_join": "Fig 14",
    "snb_queries": "Fig 13",
    "flights_queries": "Fig 15",
}


def _load(name: str):
    return importlib.import_module(f"benchmarks.{name}")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", choices=list(MODULES))
    ap.add_argument("--out", default="benchmarks/results.json")
    args = ap.parse_args(argv)

    todo = [args.only] if args.only else list(MODULES)
    results, failures = [], 0
    for name in todo:
        print(f"\n== {name} ==", flush=True)
        t0 = time.time()
        try:
            results.append(_load(name).run(quick=not args.full))
            print(f"   done in {time.time() - t0:.1f}s", flush=True)
        except Exception as e:  # report and continue
            failures += 1
            print(f"   FAILED: {type(e).__name__}: {e}", flush=True)
            results.append({"benchmark": name, "error": str(e)})
    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=2)
        print(f"\nwrote {args.out}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
