"""End-to-end serving driver: batched requests over the indexed KV cache.

    PYTHONPATH=src python examples/serve_indexed.py

Requests share a long system-prompt prefix; the engine resolves cached KV
pages with the paper's point lookup (hash(prefix page) -> page pointer),
skips their prefill, decodes batched with the paged Pallas kernel
(interpret mode on CPU), and commits new pages as MVCC appends.  The
prefix cache underneath (serving/kvcache.py) runs on the public
``IndexedFrame`` facade — ``from_columns`` / ``.lookup`` / ``.append``.
"""

from repro.launch.serve import main

if __name__ == "__main__":
    raise SystemExit(main(["--requests", "6", "--steps", "8",
                           "--prompt-len", "48", "--shared-prefix", "32"]))
