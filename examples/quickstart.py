"""Quickstart: the Indexed DataFrame public API in 5 minutes.

    PYTHONPATH=src python examples/quickstart.py

Paper Listing 1 (createIndex / cacheIndex / getRows / appendRows / join)
on the ONE public object — ``repro.IndexedFrame`` — which fronts both
the single-partition and the hash-partitioned backend and routes every
read through the planner's physical-operator selection (DESIGN.md §11).
"""

import numpy as np

from repro import IndexedFrame
from repro.core.planner import Col, Eq, Lit

rng = np.random.default_rng(0)

# -- 1. createIndex: build an indexed dataframe over a keyed table ---------
print("== createIndex ==")
from repro.core import Schema  # schemas are shared by both backends

schema = Schema.of("user_id", user_id="int64", score="float32",
                   country="int32")
users = {"user_id": rng.integers(0, 10_000, 50_000).astype(np.int64),
         "score": rng.random(50_000).astype(np.float32),
         "country": rng.integers(0, 200, 50_000).astype(np.int32)}
df = IndexedFrame.from_columns(users, schema, rows_per_batch=4096)
print(f"indexed {int(df.num_rows())} rows; index overhead "
      f"{df.index_nbytes() / df.data_nbytes():.1%} of data")

# -- 2. point lookup (getRows) ----------------------------------------------
print("\n== point lookup ==")
key = int(users["user_id"][0])
rows, valid = df.lookup(np.asarray([key]), max_matches=32)
n = int(valid[0].sum())
print(f"user {key}: {n} rows, newest score {float(rows['score'][0, 0]):.3f}")

# -- 3. appendRows: fine-grained MVCC append --------------------------------
print("\n== appendRows (MVCC) ==")
df2 = df.append({"user_id": np.asarray([key], np.int64),
                 "score": np.asarray([9.99], np.float32),
                 "country": np.asarray([42], np.int32)})
rows2, valid2 = df2.lookup(np.asarray([key]), max_matches=32)
print(f"v{df2.version}: {int(valid2[0].sum())} rows "
      f"(newest score {float(rows2['score'][0, 0]):.2f}); "
      f"parent v{df.version} still has {n} — divergent versions coexist")

# a LIST of deltas coalesces into one fused ingest: one host round-trip,
# one version bump, chains bit-identical to appending them one by one
deltas = [{"user_id": rng.integers(0, 10_000, 256).astype(np.int64),
           "score": rng.random(256).astype(np.float32),
           "country": rng.integers(0, 200, 256).astype(np.int32)}
          for _ in range(4)]
df3 = df2.append(deltas)
print(f"coalesced 4 deltas -> one append, v{df3.version}")

# -- 3b. streaming ingest: the device-resident append ring (DESIGN.md §13) --
print("\n== streaming ingest (append ring) ==")
stream = df3.with_queue(lanes=8, lane_rows=512)
for i in range(6):  # e.g. per-second micro-batches off a feed
    stream = stream.enqueue(
        {"user_id": rng.integers(0, 10_000, 128).astype(np.int64),
         "score": rng.random(128).astype(np.float32),
         "country": rng.integers(0, 200, 128).astype(np.int32)})
print(f"staged {stream.pending_deltas} deltas / {stream.pending_rows} rows "
      f"on-device with ZERO host syncs — still v{stream.version}, "
      f"invisible to readers")
stream = stream.flush()   # ONE fused jit + ONE host sync for all 6 deltas
print(f"flushed -> v{stream.version} (one version bump for the whole ring; "
      f"{int(stream.num_rows())} rows)")
# a full ring auto-flushes through append(queued=True); raw enqueue
# raises core.table.QueueOverflow instead

# -- 4. indexed join ---------------------------------------------------------
print("\n== indexed join ==")
events = {"user_id": rng.choice(users["user_id"], 1000).astype(np.int64),
          "event": np.arange(1000, dtype=np.int32)}
bcols, pcols, valid = df3.join(events, "user_id", max_matches=8)
print(f"join matched {int(np.asarray(valid).sum())} (event, user) pairs")

# -- 5. the planner picks the physical operator (Catalyst analog) ------------
print("\n== planner ==")
print(df3.plan_join(events, "user_id").explain().rstrip())
print(df3.filter(Eq(Col("user_id"), Lit(key))).explain().rstrip())
count = df3.filter(Eq(Col("user_id"), Lit(key))).agg("count",
                                                     "score").execute()
print(f"rows for user {key} via plan: {int(count)}")

# -- 6. distributed: the SAME facade, hash-partitioned across shards ---------
print("\n== distributed (4 shards) ==")
ddf = IndexedFrame.from_columns(users, schema, num_shards=4,
                                rows_per_batch=4096)
cols, valid = ddf.lookup(np.asarray([key]), max_matches=32)
plan = ddf.plan_lookup(np.asarray([key]))
print(f"key {key}: {int(valid.sum())} rows; planner chose {plan.kind}")
print(plan.explain().rstrip())
big_q = rng.choice(users["user_id"], 8192).astype(np.int64)
print(ddf.plan_lookup(big_q).explain().rstrip())
bc, pc, v = ddf.join({"user_id": events["user_id"]}, "user_id",
                     max_matches=8)
print(f"join matched {int(np.asarray(v).sum())} pairs "
      f"[{ddf.plan_join({'user_id': events['user_id']}, 'user_id').kind}]")

# -- 7. elasticity: reshard the same frame ------------------------------------
print("\n== reshard ==")
ddf8 = ddf.reshard(8)
_, v8 = ddf8.lookup(np.asarray([key]), max_matches=32)
print(f"resharded 4 -> {ddf8.num_shards} shards; "
      f"{int(v8.sum())} rows still found")
print("\nquickstart OK")
