"""Quickstart: the Indexed DataFrame public API in 5 minutes.

    PYTHONPATH=src python examples/quickstart.py

Mirrors the paper's Listing 1 (createIndex / cacheIndex / getRows /
appendRows / join) on the JAX implementation.
"""

import numpy as np

from repro.core import Schema, append, create_index, joins
from repro.core.planner import Col, Eq, Filter, Join, Lit, Planner, Relation
from repro.dist import create_distributed, indexed_join_bcast, lookup

rng = np.random.default_rng(0)

# -- 1. createIndex: build an indexed dataframe over a keyed table ---------
print("== createIndex ==")
schema = Schema.of("user_id", user_id="int64", score="float32",
                   country="int32")
users = {"user_id": rng.integers(0, 10_000, 50_000).astype(np.int64),
         "score": rng.random(50_000).astype(np.float32),
         "country": rng.integers(0, 200, 50_000).astype(np.int32)}
df = create_index(users, schema, rows_per_batch=4096)
print(f"indexed {int(df.num_rows())} rows; index overhead "
      f"{df.index_nbytes() / df.data_nbytes():.1%} of data")

# -- 2. point lookup (getRows) ----------------------------------------------
print("\n== point lookup ==")
key = int(users["user_id"][0])
rows, valid = joins.indexed_lookup(df, np.asarray([key]), max_matches=32)
n = int(valid[0].sum())
print(f"user {key}: {n} rows, newest score {float(rows['score'][0, 0]):.3f}")

# -- 3. appendRows: fine-grained MVCC append --------------------------------
print("\n== appendRows (MVCC) ==")
df2 = append(df, {"user_id": np.asarray([key], np.int64),
                  "score": np.asarray([9.99], np.float32),
                  "country": np.asarray([42], np.int32)})
rows2, valid2 = joins.indexed_lookup(df2, np.asarray([key]), max_matches=32)
print(f"v{df2.version}: {int(valid2[0].sum())} rows "
      f"(newest score {float(rows2['score'][0, 0]):.2f}); "
      f"parent v{df.version} still has {n} — divergent versions coexist")

# -- 4. indexed join ---------------------------------------------------------
print("\n== indexed join ==")
events = {"user_id": rng.choice(users["user_id"], 1000).astype(np.int64),
          "event": np.arange(1000, dtype=np.int32)}
bcols, pcols, valid = joins.indexed_join(df2, events, "user_id",
                                         max_matches=8)
print(f"join matched {int(np.asarray(valid).sum())} (event, user) pairs")

# -- 5. the planner picks indexed operators (Catalyst analog) ----------------
print("\n== planner ==")
plan = Planner().plan(Join(Relation("users", table=df2),
                           Relation("events", cols=events), on="user_id"))
print(plan.explain().rstrip())
plan2 = Planner().plan(Filter(Relation("users", table=df2),
                              Eq(Col("user_id"), Lit(key))))
print(plan2.explain().rstrip())

# -- 6. distributed: hash-partitioned across shards --------------------------
print("\n== distributed (4 shards) ==")
ddf = create_distributed(users, schema, num_shards=4, rows_per_batch=4096)
cols, valid, owner = lookup(ddf, np.asarray([key]), max_matches=32)
print(f"key {key} owned by shard {int(owner[0])}, "
      f"{int(valid.sum())} rows found")
bc, pc, v = indexed_join_bcast(ddf, {"user_id": events["user_id"]},
                               "user_id", 8)
print(f"broadcast join matched {int(np.asarray(v).sum())} pairs")
print("\nquickstart OK")
