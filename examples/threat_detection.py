"""The paper's motivating workload (§II): online threat detection.

Network connection events stream in (fine-grained appends); an analyst's
dashboard runs interactive point lookups ("what did this host do?") and
joins against a threat-intel feed — on *fresh* data, with no dataset
reload.  This is Fig 9's read-while-write pattern end to end.

    PYTHONPATH=src python examples/threat_detection.py
"""

import time

import jax
import numpy as np

from repro.core import Schema, append, compact, create_index, joins

rng = np.random.default_rng(0)

CONN_SCHEMA = Schema.of("src_ip", src_ip="int64", dst_ip="int64",
                        dst_port="int32", nbytes="float32")
INTEL_SCHEMA = Schema.of("ip", ip="int64", severity="int32")

N_HOSTS = 5_000
print("ingesting initial connection log (the 'Broconn table')...")
n0 = 100_000
conns = {"src_ip": rng.integers(0, N_HOSTS, n0).astype(np.int64),
         "dst_ip": rng.integers(0, N_HOSTS, n0).astype(np.int64),
         "dst_port": rng.choice([22, 80, 443, 445, 3389], n0)
         .astype(np.int32),
         "nbytes": rng.exponential(1e4, n0).astype(np.float32)}
log = create_index(conns, CONN_SCHEMA, rows_per_batch=4096)

# threat-intel feed: known-bad IPs, indexed for the join
bad = rng.choice(N_HOSTS, 200, replace=False).astype(np.int64)
intel = create_index({"ip": bad,
                      "severity": rng.integers(1, 5, 200).astype(np.int32)},
                     INTEL_SCHEMA, rows_per_batch=1024)

lookup_host = jax.jit(lambda t, q: joins.indexed_lookup(
    t, q, max_matches=256))
flag_conns = jax.jit(lambda t, ips: joins.indexed_lookup(
    t, ips, max_matches=1))

print("streaming 10 append rounds with interactive queries between...")
for round_i in range(10):
    # 1k fresh events arrive (some from bad hosts)
    n = 1_000
    fresh = {"src_ip": np.concatenate([
                 rng.integers(0, N_HOSTS, n - 50),
                 rng.choice(bad, 50)]).astype(np.int64),
             "dst_ip": rng.integers(0, N_HOSTS, n).astype(np.int64),
             "dst_port": rng.choice([22, 443, 445], n).astype(np.int32),
             "nbytes": rng.exponential(1e4, n).astype(np.float32)}
    t0 = time.perf_counter()
    log = append(log, fresh)
    if log.num_segments > 4:
        # periodic compaction bounds probe fan-out AND keeps the jitted
        # query's pytree structure stable (no retrace per append round) —
        # the cTrie amortizes the same way via node sharing
        log = compact(log)
    t_append = time.perf_counter() - t0

    # interactive: what did this suspicious host just do?
    suspect = int(bad[round_i % len(bad)])
    t0 = time.perf_counter()
    rows, valid = lookup_host(log, np.asarray([suspect]))
    jax.block_until_ready(valid)
    t_lookup = time.perf_counter() - t0
    hits = int(valid[0].sum())

    # interactive: flag all fresh events against the intel feed
    t0 = time.perf_counter()
    sev, sv = flag_conns(intel, fresh["src_ip"])
    jax.block_until_ready(sv)
    t_join = time.perf_counter() - t0
    flagged = int(np.asarray(sv).sum())

    print(f"round {round_i}: append({n} rows)={t_append * 1e3:6.1f}ms  "
          f"host-lookup={t_lookup * 1e3:6.1f}ms ({hits} conns)  "
          f"intel-join={t_join * 1e3:6.1f}ms ({flagged} flagged)  "
          f"v{log.version}")

print(f"\nfinal log: {int(log.num_rows())} rows across "
      f"{log.num_segments} segments; index overhead "
      f"{log.index_nbytes() / log.data_nbytes():.1%}")
print("threat_detection OK")
