"""End-to-end training driver: LM trained from the indexed data pipeline.

    PYTHONPATH=src python examples/train_lm.py                  # quick (~15M)
    PYTHONPATH=src python examples/train_lm.py --full           # ~100M model
    PYTHONPATH=src python examples/train_lm.py --resume         # restart demo

Demonstrates the whole stack: ExampleStore (the indexed cache) feeds
batches, streaming appends land mid-training without a reload, checkpoints
capture (params, optimizer, data cursor), and --resume restores the exact
batch sequence — the fault-tolerance contract of DESIGN.md §6.
"""

import argparse

from repro.launch.train import run
from repro.models.common import ModelConfig


def model_100m():
    """~100M-param llama-style config (tinyllama family, scaled)."""
    return ModelConfig(
        name="lm-100m", family="dense", num_layers=12, d_model=768,
        num_heads=12, num_kv_heads=4, head_dim=64, d_ff=2048,
        vocab_size=32000, rope_theta=1e4, dtype="float32")


def model_15m():
    return ModelConfig(
        name="lm-15m", family="dense", num_layers=6, d_model=256,
        num_heads=8, num_kv_heads=4, head_dim=32, d_ff=1024,
        vocab_size=8192, rope_theta=1e4, dtype="float32")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="~100M params, 200 steps")
    ap.add_argument("--steps", type=int)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    args = ap.parse_args()

    cfg = model_100m() if args.full else model_15m()
    steps = args.steps or (200 if args.full else 60)
    n_params_est = (cfg.vocab_size * cfg.d_model * 2
                    + cfg.num_layers * (cfg.d_model * (cfg.q_dim
                                                       + 2 * cfg.kv_dim)
                                        + cfg.q_dim * cfg.d_model
                                        + 3 * cfg.d_model * cfg.d_ff))
    print(f"training {cfg.name} (~{n_params_est / 1e6:.0f}M params) "
          f"for {steps} steps; ckpt -> {args.ckpt_dir}")
    run(cfg, steps=steps, batch=8, seq=256 if args.full else 128,
        ckpt_dir=args.ckpt_dir, ckpt_every=25, resume=args.resume,
        append_every=15)   # streaming appends land mid-training
    print("train_lm OK")


if __name__ == "__main__":
    main()
