"""Data pipeline: example store on the indexed cache, streaming appends,
resumable cursor, curriculum join."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import Schema, create_index
from repro.core.partition import PartitionSpec
from repro.data import (BatchPipeline, Cursor, ExampleStore,
                        synthetic_examples)


def test_store_append_and_lookup(rng):
    store = ExampleStore(seq_len=16, rows_per_batch=8)
    ids, toks = synthetic_examples(rng, 20, 16, 100)
    v0 = store.append_examples(ids, toks)
    assert v0 == 0 and store.num_examples == 20
    got, w, valid = store.lookup(ids[:5])
    assert np.asarray(valid[:, 0]).all()
    np.testing.assert_array_equal(np.asarray(got[:, 0]), toks[:5])


def test_store_partitioned_windows_and_retention(rng):
    spec = PartitionSpec.range_("example_id", [0, 100, 200],
                                ids=["w0", "w1"])
    store = ExampleStore(seq_len=8, rows_per_batch=16, partition_by=spec)
    plain = ExampleStore(seq_len=8, rows_per_batch=16)
    ids0, toks0 = synthetic_examples(rng, 12, 8, 50)
    ids1, toks1 = synthetic_examples(rng, 9, 8, 50, id_base=100)
    for s in (store, plain):
        s.append_examples(ids0, toks0)
        s.append_examples(ids1, toks1)
    probe = np.concatenate([ids0[:3], ids1[:3]])
    got_p, w_p, v_p = store.lookup(probe)
    got_m, w_m, v_m = plain.lookup(probe)
    np.testing.assert_array_equal(np.asarray(v_p), np.asarray(v_m))
    np.testing.assert_array_equal(np.asarray(got_p), np.asarray(got_m))
    np.testing.assert_array_equal(np.asarray(w_p), np.asarray(w_m))

    rep = store.memory_report()
    assert [r["partition"] for r in rep] == ["w0", "w1"]
    assert rep[0]["rows"] == 12 and rep[1]["rows"] == 9
    assert rep[0]["data_logical"] <= rep[0]["data_reserved"]
    assert len(plain.memory_report()) == 1

    store.drop_partition("w0")          # O(1) window retirement
    _, _, v_after = store.lookup(probe)
    v_after = np.asarray(v_after)
    assert not v_after[:3].any() and v_after[3:].all()
    assert [r["partition"] for r in store.memory_report()] == ["w1"]
    with pytest.raises(ValueError, match="not partitioned"):
        plain.drop_partition("w0")


def test_streaming_append_fresh_data_visible(rng):
    store = ExampleStore(seq_len=8, rows_per_batch=4)
    ids, toks = synthetic_examples(rng, 10, 8, 50)
    store.append_examples(ids, toks)
    ids2, toks2 = synthetic_examples(rng, 6, 8, 50, id_base=10)
    v = store.append_examples(ids2, toks2)
    assert v == 1 and store.num_examples == 16
    got, _, valid = store.lookup(ids2[-2:])
    assert np.asarray(valid[:, 0]).all()
    np.testing.assert_array_equal(np.asarray(got[:, 0]), toks2[-2:])


def test_pipeline_deterministic_and_resumable(rng):
    store = ExampleStore(seq_len=8, rows_per_batch=16)
    ids, toks = synthetic_examples(rng, 64, 8, 50)
    store.append_examples(ids, toks)
    p1 = BatchPipeline(store, batch=4, seed=7)
    seq1 = [np.asarray(p1.next_batch()["tokens"]) for _ in range(5)]
    # resume from step 2 via cursor state
    p2 = BatchPipeline(store, batch=4, seed=7)
    p2.next_batch(); p2.next_batch()
    state = p2.cursor.state_dict()
    p3 = BatchPipeline(store, batch=4, seed=0)
    p3.cursor = Cursor.from_state(state)
    seq3 = [np.asarray(p3.next_batch()["tokens"]) for _ in range(3)]
    for a, b in zip(seq1[2:], seq3):
        np.testing.assert_array_equal(a, b)


def test_curriculum_weighted_batch(rng):
    store = ExampleStore(seq_len=8, rows_per_batch=16)
    ids, toks = synthetic_examples(rng, 32, 8, 50)
    store.append_examples(ids, toks)
    wsch = Schema.of("example_id", example_id="int64", weight="float32")
    wtab = create_index({"example_id": ids,
                         "weight": np.linspace(0.1, 2.0, 32)
                         .astype(np.float32)}, wsch, rows_per_batch=16)
    pipe = BatchPipeline(store, batch=4, seed=0)
    b = pipe.weighted_batch(wtab)
    assert b["tokens"].shape == (4, 8)


def test_index_overhead_small(rng):
    store = ExampleStore(seq_len=512, rows_per_batch=64)
    ids, toks = synthetic_examples(rng, 256, 512, 1000)
    store.append_examples(ids, toks)
    # the paper's Fig-11 claim transfers: index ≪ data for realistic rows
    assert store.index_overhead_bytes() < 0.05 * store.data_bytes()
