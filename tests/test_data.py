"""Data pipeline: example store on the indexed cache, streaming appends,
resumable cursor, curriculum join."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import Schema, create_index
from repro.data import (BatchPipeline, Cursor, ExampleStore,
                        synthetic_examples)


def test_store_append_and_lookup(rng):
    store = ExampleStore(seq_len=16, rows_per_batch=8)
    ids, toks = synthetic_examples(rng, 20, 16, 100)
    v0 = store.append_examples(ids, toks)
    assert v0 == 0 and store.num_examples == 20
    got, w, valid = store.lookup(ids[:5])
    assert np.asarray(valid[:, 0]).all()
    np.testing.assert_array_equal(np.asarray(got[:, 0]), toks[:5])


def test_streaming_append_fresh_data_visible(rng):
    store = ExampleStore(seq_len=8, rows_per_batch=4)
    ids, toks = synthetic_examples(rng, 10, 8, 50)
    store.append_examples(ids, toks)
    ids2, toks2 = synthetic_examples(rng, 6, 8, 50, id_base=10)
    v = store.append_examples(ids2, toks2)
    assert v == 1 and store.num_examples == 16
    got, _, valid = store.lookup(ids2[-2:])
    assert np.asarray(valid[:, 0]).all()
    np.testing.assert_array_equal(np.asarray(got[:, 0]), toks2[-2:])


def test_pipeline_deterministic_and_resumable(rng):
    store = ExampleStore(seq_len=8, rows_per_batch=16)
    ids, toks = synthetic_examples(rng, 64, 8, 50)
    store.append_examples(ids, toks)
    p1 = BatchPipeline(store, batch=4, seed=7)
    seq1 = [np.asarray(p1.next_batch()["tokens"]) for _ in range(5)]
    # resume from step 2 via cursor state
    p2 = BatchPipeline(store, batch=4, seed=7)
    p2.next_batch(); p2.next_batch()
    state = p2.cursor.state_dict()
    p3 = BatchPipeline(store, batch=4, seed=0)
    p3.cursor = Cursor.from_state(state)
    seq3 = [np.asarray(p3.next_batch()["tokens"]) for _ in range(3)]
    for a, b in zip(seq1[2:], seq3):
        np.testing.assert_array_equal(a, b)


def test_curriculum_weighted_batch(rng):
    store = ExampleStore(seq_len=8, rows_per_batch=16)
    ids, toks = synthetic_examples(rng, 32, 8, 50)
    store.append_examples(ids, toks)
    wsch = Schema.of("example_id", example_id="int64", weight="float32")
    wtab = create_index({"example_id": ids,
                         "weight": np.linspace(0.1, 2.0, 32)
                         .astype(np.float32)}, wsch, rows_per_batch=16)
    pipe = BatchPipeline(store, batch=4, seed=0)
    b = pipe.weighted_batch(wtab)
    assert b["tokens"].shape == (4, 8)


def test_index_overhead_small(rng):
    store = ExampleStore(seq_len=512, rows_per_batch=64)
    ids, toks = synthetic_examples(rng, 256, 512, 1000)
    store.append_examples(ids, toks)
    # the paper's Fig-11 claim transfers: index ≪ data for realistic rows
    assert store.index_overhead_bytes() < 0.05 * store.data_bytes()
