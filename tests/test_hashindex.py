"""Unit + property tests for the dense hash index (the cTrie replacement)."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import hashing
from repro.core.hashindex import (EMPTY_KEY, build_index, chain_walk,
                                  match_counts, probe, suggest_num_buckets)
from repro.core.pointers import NULL_PTR


def _oracle_latest(keys, q):
    """Latest (max row id) per query key, -1 if absent."""
    out = np.full(len(q), -1, np.int32)
    for i, k in enumerate(q):
        hits = np.nonzero(keys == k)[0]
        if len(hits):
            out[i] = hits.max()
    return out


def test_probe_latest_matches_oracle(rng):
    keys = rng.integers(0, 200, size=1000).astype(np.int64)
    rids = np.arange(1000, dtype=np.int32)
    idx, _, _ = build_index(keys, rids)
    q = np.concatenate([keys[:100], rng.integers(200, 400, 50)]).astype(np.int64)
    got = np.asarray(probe(idx, q))
    np.testing.assert_array_equal(got, _oracle_latest(keys, q))


def test_chain_walk_enumerates_all_rows(rng):
    keys = rng.integers(0, 50, size=600).astype(np.int64)
    rids = np.arange(600, dtype=np.int32)
    idx, prev_rows, prev_vals = build_index(keys, rids)
    prev = jnp.full((600,), NULL_PTR, jnp.int32).at[prev_rows].set(
        prev_vals, mode="drop")
    q = np.arange(50, dtype=np.int64)
    head = probe(idx, q)
    rows, truncated = chain_walk(prev, head, max_matches=64)
    rows = np.asarray(rows)
    for i, k in enumerate(q):
        expect = np.sort(np.nonzero(keys == k)[0])[::-1]  # newest first
        got = rows[i][rows[i] >= 0]
        np.testing.assert_array_equal(got, expect[:64])
    assert not np.asarray(truncated).any()


def test_chain_walk_truncation(rng):
    keys = np.zeros(100, np.int64)  # all same key
    idx, prev_rows, prev_vals = build_index(keys, np.arange(100, dtype=np.int32))
    prev = jnp.full((100,), NULL_PTR, jnp.int32).at[prev_rows].set(
        prev_vals, mode="drop")
    head = probe(idx, np.zeros(1, np.int64))
    rows, truncated = chain_walk(prev, head, max_matches=10)
    assert np.asarray(truncated)[0]
    assert (np.asarray(rows)[0] >= 0).all()
    counts = match_counts(prev, head, 10)
    assert int(counts[0]) == 10


def test_invalid_rows_excluded(rng):
    keys = rng.integers(0, 30, size=200).astype(np.int64)
    valid = rng.random(200) < 0.5
    idx, _, _ = build_index(keys, np.arange(200, dtype=np.int32), valid=jnp.asarray(valid))
    q = np.arange(30, dtype=np.int64)
    got = np.asarray(probe(idx, q))
    masked = np.where(valid, keys, -10**18)
    np.testing.assert_array_equal(got, _oracle_latest(masked, q))


def test_overflow_retry_doubles_buckets(rng):
    # force tiny bucket count so the first build overflows
    keys = rng.integers(0, 10**9, size=4096).astype(np.int64)
    idx, _, _ = build_index(keys, np.arange(4096, dtype=np.int32),
                            num_buckets=16, slots=4, max_retries=12)
    assert idx.num_buckets > 16
    got = np.asarray(probe(idx, keys[:64]))
    assert (got >= 0).all()


def test_empty_key_never_matches():
    keys = np.array([1, 2, 3], np.int64)
    idx, _, _ = build_index(keys, np.arange(3, dtype=np.int32))
    got = probe(idx, jnp.asarray([np.iinfo(np.int64).min], jnp.int64))
    assert int(got[0]) == -1


@settings(max_examples=30, deadline=None)
@given(st.lists(st.integers(min_value=-2**62, max_value=2**62), min_size=1,
                max_size=300),
       st.integers(min_value=0, max_value=10**6))
def test_property_probe_exact(keys_list, extra):
    """Every inserted key is found with its latest row id; absent keys miss."""
    keys = np.asarray(keys_list, np.int64)
    idx, _, _ = build_index(keys, np.arange(len(keys), dtype=np.int32))
    q = np.concatenate([keys, [extra]]).astype(np.int64)
    got = np.asarray(probe(idx, q))
    np.testing.assert_array_equal(got, _oracle_latest(keys, q))


@settings(max_examples=20, deadline=None)
@given(st.integers(min_value=1, max_value=10**5))
def test_property_bucket_hash_in_range(n):
    nb = suggest_num_buckets(n)
    assert nb & (nb - 1) == 0
    ks = np.arange(min(n, 1000), dtype=np.int64) * 7919
    b = np.asarray(hashing.bucket_hash(jnp.asarray(ks), nb))
    assert (b >= 0).all() and (b < nb).all()


def test_partition_hash_balanced(rng):
    keys = rng.integers(0, 2**60, size=100_000).astype(np.int64)
    for s in (3, 4, 16, 255):
        d = np.asarray(hashing.partition_hash(jnp.asarray(keys), s))
        counts = np.bincount(d, minlength=s)
        assert counts.min() > 0.8 * len(keys) / s
        assert counts.max() < 1.2 * len(keys) / s


def test_string_hashing_stable():
    a = hashing.hash_string_host("N12345")
    b = hashing.hash_string_host("N12345")
    c = hashing.hash_string_host("N12346")
    assert a == b and a != c
