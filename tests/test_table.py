"""IndexedTable: create/append/MVCC/divergence/compaction (paper §III-C/E)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import Schema, append, compact, create_index, joins
from repro.core.table import IndexedTable


SCH = Schema.of("k", k="int64", v="float32", tag="int32")


def _mk(rng, n, key_range=100, rows_per_batch=64, layout="row"):
    cols = {"k": rng.integers(0, key_range, n).astype(np.int64),
            "v": rng.random(n).astype(np.float32),
            "tag": np.arange(n, dtype=np.int32)}
    return cols, create_index(cols, SCH, rows_per_batch=rows_per_batch,
                              layout=layout)


def _oracle_rows(all_cols_list, key):
    """(v, tag) rows for `key`, newest first across appends."""
    ks = np.concatenate([c["k"] for c in all_cols_list])
    vs = np.concatenate([c["v"] for c in all_cols_list])
    ts = np.concatenate([c["tag"] for c in all_cols_list])
    hits = np.nonzero(ks == key)[0][::-1]
    return vs[hits], ts[hits]


@pytest.mark.parametrize("layout", ["row", "columnar"])
def test_lookup_matches_oracle(rng, layout):
    cols, t = _mk(rng, 500, layout=layout)
    for key in (int(cols["k"][0]), int(cols["k"][37]), 10**9):
        got, valid = joins.indexed_lookup(t, np.array([key], np.int64),
                                          max_matches=32)
        ev, et = _oracle_rows([cols], key)
        n = int(valid[0].sum())
        assert n == min(len(ev), 32)
        np.testing.assert_allclose(np.asarray(got["v"][0][:n]), ev[:n])
        np.testing.assert_array_equal(np.asarray(got["tag"][0][:n]), et[:n])


@pytest.mark.parametrize("layout", ["row", "columnar"])
def test_append_chains_into_parent(rng, layout):
    cols, t = _mk(rng, 300, layout=layout)
    key = int(cols["k"][5])
    extra = {"k": np.array([key, key], np.int64),
             "v": np.array([100.0, 200.0], np.float32),
             "tag": np.array([9000, 9001], np.int32)}
    t2 = append(t, extra)
    got, valid = joins.indexed_lookup(t2, np.array([key], np.int64),
                                      max_matches=64)
    ev, et = _oracle_rows([cols, extra], key)
    n = int(valid[0].sum())
    assert n == len(ev)
    np.testing.assert_allclose(np.asarray(got["v"][0][:n]), ev)
    assert t2.version == t.version + 1


@pytest.mark.parametrize("mode", ["arena", "segment"])
def test_divergent_appends_coexist(rng, mode):
    """Paper Listing 2: two appends on one parent — both materialize.

    The arena path updates the tail functionally (non-donated appends
    never touch the parent's buffers), the segment path shares the parent
    segment by reference — divergence holds either way."""
    cols, t = _mk(rng, 200)
    parent_before = jax.tree_util.tree_leaves(t)
    a = {"k": np.array([1], np.int64), "v": np.array([1.0], np.float32),
         "tag": np.array([1], np.int32)}
    b = {"k": np.array([1], np.int64), "v": np.array([2.0], np.float32),
         "tag": np.array([2], np.int32)}
    ta, tb = append(t, a, mode=mode), append(t, b, mode=mode)
    ga, va = joins.indexed_lookup(ta, np.array([1], np.int64), max_matches=64)
    gb, vb = joins.indexed_lookup(tb, np.array([1], np.int64), max_matches=64)
    base = _oracle_rows([cols], 1)[0]
    assert int(va[0].sum()) == len(base) + 1
    assert int(vb[0].sum()) == len(base) + 1
    assert float(ga["v"][0, 0]) == 1.0
    assert float(gb["v"][0, 0]) == 2.0
    if mode == "segment":
        # zero-copy sharing: parent segment arrays are the same buffers
        assert ta.segments[0] is t.segments[0]
        assert tb.segments[0] is t.segments[0]
    # MVCC: the parent version is bit-identical after both appends
    for before, after in zip(parent_before, jax.tree_util.tree_leaves(t)):
        np.testing.assert_array_equal(np.asarray(before), np.asarray(after))
    gp, vp = joins.indexed_lookup(t, np.array([1], np.int64), max_matches=64)
    assert int(vp[0].sum()) == len(base)


def test_compact_preserves_semantics(rng):
    cols, t = _mk(rng, 200, key_range=20)
    extra = {"k": rng.integers(0, 20, 50).astype(np.int64),
             "v": rng.random(50).astype(np.float32),
             "tag": np.arange(50, dtype=np.int32) + 1000}
    t2 = append(t, extra)
    t3 = compact(t2)
    assert t3.num_segments == 1
    q = np.arange(20, dtype=np.int64)
    g2, v2 = joins.indexed_lookup(t2, q, max_matches=64)
    g3, v3 = joins.indexed_lookup(t3, q, max_matches=64)
    np.testing.assert_array_equal(np.asarray(v2), np.asarray(v3))
    np.testing.assert_allclose(np.asarray(g2["v"]) * np.asarray(v2),
                               np.asarray(g3["v"]) * np.asarray(v3))


def test_scan_column_returns_all_valid_rows(rng):
    cols, t = _mk(rng, 130, rows_per_batch=64)  # padding rows exist
    vals, valid = t.scan_column("v")
    assert int(valid.sum()) == 130
    np.testing.assert_allclose(np.sort(np.asarray(vals)[np.asarray(valid)]),
                               np.sort(cols["v"]))


def test_memory_overhead_accounting(rng):
    """Fig-11 analog: index bytes ≪ data bytes for wide rows."""
    n = 4096
    wide = Schema.of("k", k="int64", **{f"c{i}": "float32" for i in range(62)})
    cols = {"k": np.arange(n, dtype=np.int64) * 3,
            **{f"c{i}": np.ones(n, np.float32) for i in range(62)}}
    t = create_index(cols, wide, rows_per_batch=1024)
    ratio = t.index_nbytes() / t.data_nbytes()
    assert ratio < 0.25  # wide-row regime; benchmark reports the full curve


def test_version_increments_and_num_rows(rng):
    cols, t = _mk(rng, 100)
    assert t.version == 0
    t2 = append(t, {"k": np.array([5], np.int64),
                    "v": np.array([0.5], np.float32),
                    "tag": np.array([7], np.int32)})
    assert t2.version == 1
    assert int(t2.num_rows()) == 101


@settings(max_examples=15, deadline=None)
@given(st.lists(st.integers(min_value=0, max_value=9), min_size=1,
                max_size=80),
       st.lists(st.integers(min_value=0, max_value=9), min_size=1,
                max_size=40))
def test_property_append_lookup(base_keys, delta_keys):
    base = {"k": np.asarray(base_keys, np.int64),
            "v": np.arange(len(base_keys), dtype=np.float32),
            "tag": np.arange(len(base_keys), dtype=np.int32)}
    delta = {"k": np.asarray(delta_keys, np.int64),
             "v": np.arange(len(delta_keys), dtype=np.float32) + 1000,
             "tag": np.arange(len(delta_keys), dtype=np.int32) + 1000}
    t = append(create_index(base, SCH, rows_per_batch=32), delta)
    q = np.arange(10, dtype=np.int64)
    got, valid = joins.indexed_lookup(t, q, max_matches=128)
    for i in range(10):
        ev, et = _oracle_rows([base, delta], i)
        n = int(valid[i].sum())
        assert n == len(ev)
        np.testing.assert_allclose(np.asarray(got["v"][i][:n]), ev)
