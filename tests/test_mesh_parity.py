"""Mesh-native execution parity: the shard_map backend must be
bit-identical to the vmap backend for EVERY dist op (ISSUE 3).

The distributed layer maps one per-shard function over the shard axis
through a single seam (``dist.mesh.axis_map``); these tests pin down
that the two backends of that seam — ``jax.vmap(axis_name=...)``
emulation and ``jax.shard_map`` over a real device mesh — produce
bitwise-equal results for build, lookup (broadcast and routed), both
joins, append, fail/rebuild, reshard, and checkpoint roundtrip, plus a
tracing-count test pinning zero retraces across structurally-equal
appends under shard_map.

Multi-device meshes come from ``XLA_FLAGS=
--xla_force_host_platform_device_count=8`` (scripts/ci.sh runs the suite
under both topologies).  On a single-device process the mesh-parametrized
tests skip and a subprocess test forces the 8-device topology instead, so
the tier-1 gate always exercises the shard_map path.

Routed-lookup *semantics* (miss/overflow: reported drops, never silent
misses or key-0 answers — the retry contract) run on the vmap backend so
they hold on every topology.
"""

import os
import subprocess
import sys

import numpy as np
import jax
import jax.numpy as jnp
import pytest

pytest.importorskip("repro.dist")

from repro import dist
from repro.core import Schema, hashing
from repro.dist import checkpoint, mesh
from repro.dist import runtime as drt
from repro.dist import shuffle as shf

NDEV = len(jax.devices())
SCH = Schema.of("k", k="int64", v="float32")

# the smallest nontrivial mesh + the acceptance topology (8); the property
# suite randomizes shard counts separately, so intermediate sizes add
# runtime without adding coverage
MESHES = ([s for s in (2, 8) if s <= NDEV]
          or [pytest.param(2, marks=pytest.mark.skip(
              reason="single-device process; the subprocess test and "
                     "scripts/ci.sh's forced-8 rerun cover shard_map"))])

_CACHE = {}


def _built(s):
    """(cols, rt_vmap, rt_mesh, dt_vmap, dt_mesh) for s shards (cached —
    the build itself is asserted bit-identical in test_build_parity)."""
    if s not in _CACHE:
        rng = np.random.default_rng(7)
        n = 1500
        cols = {"k": rng.integers(0, 300, n).astype(np.int64),
                "v": rng.random(n).astype(np.float32)}
        rv, rs = mesh.vmap_runtime(), mesh.mesh_runtime(s)
        _CACHE[s] = (cols, rv, rs,
                     dist.create_distributed(cols, SCH, s,
                                             rows_per_batch=128, rt=rv),
                     dist.create_distributed(cols, SCH, s,
                                             rows_per_batch=128, rt=rs))
    return _CACHE[s]


def _assert_trees_bitwise_equal(a, b):
    la, ta = jax.tree_util.tree_flatten(a)
    lb, tb = jax.tree_util.tree_flatten(b)
    assert ta == tb
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def _queries(cols, rng, extra=()):
    return np.concatenate([rng.choice(cols["k"], 40),
                           np.asarray(extra, np.int64)]).astype(np.int64)


# --- partition hash host/device agreement ---------------------------------

def test_partition_hash_host_agrees_with_device(rng):
    """Ingest routes on the host, queries route on device: one disagreeing
    bit strands rows on a shard no probe ever visits."""
    ii = np.iinfo(np.int64)
    keys = np.concatenate([
        rng.integers(ii.min, ii.max, 4096),
        [0, 1, -1, ii.min, ii.max, ii.min + 1, ii.max - 1]]).astype(np.int64)
    for s in (1, 2, 3, 4, 7, 8, 16):
        host = hashing.partition_hash_host(keys, s)
        dev = np.asarray(hashing.partition_hash(jnp.asarray(keys), s))
        np.testing.assert_array_equal(host, dev)
        assert host.min() >= 0 and host.max() < s


# --- op-by-op backend parity ----------------------------------------------

@pytest.mark.parametrize("s", MESHES)
def test_build_parity(s):
    _, _, _, dtv, dts = _built(s)
    _assert_trees_bitwise_equal(dtv, dts)


@pytest.mark.parametrize("s", MESHES)
def test_lookup_parity(s, rng):
    cols, rv, rs, dtv, dts = _built(s)
    q = _queries(cols, rng, extra=[10**12, 0])
    gv, vv, ov = dist.lookup(dtv, q, max_matches=16, rt=rv)
    gs, vs, os_ = dist.lookup(dts, q, max_matches=16, rt=rs)
    _assert_trees_bitwise_equal((gv, vv, ov), (gs, vs, os_))
    assert int(np.asarray(vv).sum()) > 0


@pytest.mark.parametrize("s", MESHES)
def test_lookup_routed_parity_and_matches_broadcast(s, rng):
    cols, rv, rs, dtv, dts = _built(s)
    q = rng.choice(cols["k"], 16 * s).astype(np.int64).reshape(s, 16)
    outv = dist.lookup_routed(dtv, q, max_matches=16, rt=rv)
    outs = dist.lookup_routed(dts, q, max_matches=16, rt=rs)
    _assert_trees_bitwise_equal(outv, outs)
    cv, vv, answered, dropped = outv
    assert int(np.asarray(dropped).sum()) == 0
    assert bool(np.asarray(answered).all())
    # routed answers the same rows as the broadcast path, per query
    gb, vb, _ = dist.lookup(dtv, q.reshape(-1), max_matches=16, rt=rv)
    vb = np.asarray(vb).reshape(s, 16, 16)
    np.testing.assert_array_equal(np.asarray(vv).sum(-1), vb.sum(-1))
    got, ref = np.asarray(cv["v"]), np.asarray(gb["v"]).reshape(s, 16, 16)
    for i in range(s):
        for j in range(16):
            np.testing.assert_array_equal(
                np.sort(got[i, j][np.asarray(vv)[i, j]]),
                np.sort(ref[i, j][vb[i, j]]))


@pytest.mark.parametrize("s", MESHES)
def test_join_bcast_parity(s, rng):
    cols, rv, rs, dtv, dts = _built(s)
    pk = _queries(cols, rng)
    pc = {"pk": pk, "tag": np.arange(pk.shape[0], dtype=np.int32)}
    jv = dist.indexed_join_bcast(dtv, pc, "pk", 8, rt=rv)
    js = dist.indexed_join_bcast(dts, pc, "pk", 8, rt=rs)
    _assert_trees_bitwise_equal(jv, js)


@pytest.mark.parametrize("s", MESHES)
def test_join_shuffle_parity(s, rng):
    cols, rv, rs, dtv, dts = _built(s)
    pk = rng.choice(cols["k"], 16 * s).astype(np.int64).reshape(s, 16)
    pc = {"pk": pk, "tag": np.arange(16 * s, dtype=np.int32).reshape(s, 16)}
    pv = rng.random((s, 16)) < 0.9
    jv = dist.indexed_join_shuffle(dtv, pc, "pk", pv, 8, rt=rv)
    js = dist.indexed_join_shuffle(dts, pc, "pk", pv, 8, rt=rs)
    _assert_trees_bitwise_equal(jv, js)
    assert int(np.asarray(jv[3]).sum()) == 0


@pytest.mark.parametrize("s", MESHES)
def test_shuffle_all_to_all_matches_transpose_oracle(s, rng):
    """Satellite: ``shuffle_global``'s docstringed all_to_all equivalence,
    proven — same outboxes, identical inboxes, under BOTH backends."""
    cols, rv, rs, _, _ = _built(s)
    n, cap = 48, 24
    keys = rng.integers(-10**18, 10**18, (s, n)).astype(np.int64)
    rows = {"a": keys.astype(np.int32),
            "b": rng.random((s, n, 2)).astype(np.float32)}
    valid = rng.random((s, n)) < 0.8
    oracle = shf.shuffle_global(jnp.asarray(keys), rows,
                                jnp.asarray(valid), s, cap)
    for rt in (rv, rs):
        got = mesh.axis_map(
            lambda k, r, v, _rt=rt: shf.shuffle_global_axis(
                k, r, v, s, cap, _rt.axis), rt)(
            jnp.asarray(keys), rows, jnp.asarray(valid))
        _assert_trees_bitwise_equal(oracle, got)


@pytest.mark.parametrize("s", MESHES)
def test_append_parity(s, rng):
    cols, rv, rs, dtv, dts = _built(s)
    delta = {"k": np.asarray([int(cols["k"][0]), 3, 7], np.int64),
             "v": np.asarray([41.0, 42.0, 43.0], np.float32)}
    av = dist.append_distributed(dtv, delta, rt=rv)
    as_ = dist.append_distributed(dts, delta, rt=rs)
    _assert_trees_bitwise_equal(av, as_)
    q = _queries(cols, rng, extra=[3, 7])
    _assert_trees_bitwise_equal(dist.lookup(av, q, max_matches=16, rt=rv),
                                dist.lookup(as_, q, max_matches=16, rt=rs))


@pytest.mark.parametrize("s", MESHES)
def test_fail_rebuild_parity(s, rng):
    cols, rv, rs, dtv, dts = _built(s)
    lin = drt.Lineage(SCH, cols, rows_per_batch=128)
    delta = {"k": np.asarray([int(cols["k"][1])], np.int64),
             "v": np.asarray([9.0], np.float32)}
    lin.record_append(delta)
    pairs = []
    for dt0, rt in ((dtv, rv), (dts, rs)):
        dt1 = dist.append_distributed(dt0, delta, rt=rt)
        broken = drt.fail_shard(dt1, shard=1 % s)
        pairs.append((drt.rebuild_shard(broken, 1 % s, lin, rt=rt), rt))
    _assert_trees_bitwise_equal(pairs[0][0], pairs[1][0])
    q = _queries(cols, rng)
    _assert_trees_bitwise_equal(
        dist.lookup(pairs[0][0], q, max_matches=16, rt=pairs[0][1]),
        dist.lookup(pairs[1][0], q, max_matches=16, rt=pairs[1][1]))


@pytest.mark.parametrize("s", MESHES)
def test_reshard_parity(s, rng):
    cols, rv, rs, dtv, dts = _built(s)
    target = 2 if s != 2 else 4
    rt_out = (mesh.mesh_runtime(target) if target <= NDEV
              else mesh.vmap_runtime())
    a = checkpoint.reshard_dtable(dtv, target, rt=rv, rt_out=rv)
    b = checkpoint.reshard_dtable(dts, target, rt=rs, rt_out=rt_out)
    _assert_trees_bitwise_equal(a, b)
    q = _queries(cols, rng)
    _assert_trees_bitwise_equal(dist.lookup(a, q, max_matches=16, rt=rv),
                                dist.lookup(b, q, max_matches=16, rt=rv))


@pytest.mark.parametrize("s", MESHES)
def test_compact_parity_and_checkpoint_roundtrip(s, rng, tmp_path):
    """Satellite: ``compact_distributed`` after appends — bit-identical
    across backends, lookups bit-identical before/after per backend, and
    the compacted table checkpoint-roundtrips bit-identically."""
    cols, rv, rs, dtv, dts = _built(s)
    delta = {"k": np.asarray([int(cols["k"][0]), 5, 9, 5], np.int64),
             "v": np.asarray([1.0, 2.0, 3.0, 4.0], np.float32)}
    av = dist.append_distributed(dtv, delta, rt=rv)
    as_ = dist.append_distributed(dts, delta, rt=rs)
    cv = dist.compact_distributed(av, rt=rv)
    cs = dist.compact_distributed(as_, rt=rs, rt_out=rs)
    _assert_trees_bitwise_equal(cv, cs)
    assert cv.table.num_segments == 1
    q = _queries(cols, rng, extra=[5, 9, 10**12])
    for pre, post, rt in ((av, cv, rv), (as_, cs, rs)):
        gb, vb, _ = dist.lookup(pre, q, max_matches=16, rt=rt)
        ga, va, _ = dist.lookup(post, q, max_matches=16, rt=rt)
        np.testing.assert_array_equal(np.asarray(vb), np.asarray(va))
        np.testing.assert_array_equal(
            np.asarray(gb["v"]) * np.asarray(vb),
            np.asarray(ga["v"]) * np.asarray(va))
    path = str(tmp_path / "ck_compact")
    checkpoint.save_dtable(path, cs)
    restored = checkpoint.restore_dtable(path, cv)  # cross-backend template
    _assert_trees_bitwise_equal(restored, cs)


@pytest.mark.parametrize("s", MESHES)
def test_checkpoint_roundtrip_parity(s, rng, tmp_path):
    cols, rv, rs, dtv, dts = _built(s)
    pa, pb = str(tmp_path / "ckv"), str(tmp_path / "cks")
    checkpoint.save_dtable(pa, dtv)
    checkpoint.save_dtable(pb, dts)
    # cross-restore: a shard_map-built checkpoint restores into a
    # vmap-built template (and vice versa) — same construction, same tree
    ra = checkpoint.restore_dtable(pa, dts)
    rb = checkpoint.restore_dtable(pb, dtv)
    _assert_trees_bitwise_equal(ra, rb)
    q = _queries(cols, rng)
    _assert_trees_bitwise_equal(dist.lookup(ra, q, max_matches=16, rt=rv),
                                dist.lookup(rb, q, max_matches=16, rt=rs))


# --- tracing counts under shard_map ---------------------------------------

@pytest.mark.parametrize("s", MESHES)
def test_no_retrace_across_appends_shard_map(s, rng):
    """Satellite: arena appends (DESIGN.md §4) change NO dtable pytree
    structure, so jitted shard_map queries never retrace across appends —
    successive versions AND divergent siblings all re-enter the original
    compile-cache entry (the Fig-12 flat tail depends on this)."""
    cols, rv, rs, _, dts = _built(s)
    traces = {"n": 0}

    @jax.jit
    def f(dt, qq):
        traces["n"] += 1                    # bumps only while tracing
        _, valid, _ = dist.lookup(dt, qq, max_matches=4, rt=rs)
        return valid

    q = jnp.asarray(rng.choice(cols["k"], 32).astype(np.int64))
    f(dts, q)
    assert traces["n"] == 1
    f(dts, q)
    assert traces["n"] == 1                 # same dtable: cache hit

    def delta(keys):
        return {"k": np.asarray(keys, np.int64),
                "v": np.ones(len(keys), np.float32)}

    d2a = dist.append_distributed(dts, delta([1, 2, 3]), rt=rs)
    d2b = dist.append_distributed(dts, delta([50, 51, 52]), rt=rs)
    va = f(d2a, q)
    vb = f(d2b, q)
    f(d2a, q)
    # successive in-class appends: zero retraces of the read site
    d = d2a
    for i in range(10):
        d = dist.append_distributed(d, delta([i, 60 + i]), rt=rs)
        f(d, q)
    assert traces["n"] == 1                 # ZERO retraces across appends
    # and the cached executions are still the right answers
    _assert_trees_bitwise_equal(
        va, dist.lookup(d2a, q, max_matches=4, rt=mesh.vmap_runtime())[1])
    _assert_trees_bitwise_equal(
        vb, dist.lookup(d2b, q, max_matches=4, rt=mesh.vmap_runtime())[1])


# --- failure path under shard_map (ISSUE 6 satellite) ---------------------

@pytest.mark.parametrize("s", MESHES)
def test_failed_shard_all_miss_under_shard_map(s, rng):
    """A dead shard answers every lookup with a miss under the REAL mesh
    backend — the sentinel blanking survives shard_map lowering (psum
    owner-select, all_to_all routing), never a fabricated key-0 match."""
    cols, rv, rs, dtv, dts = _built(s)
    dead = 1 % s
    owned = _keys_owned_by(dead, s, 2 * s)
    brv, brs = drt.fail_shard(dtv, dead), drt.fail_shard(dts, dead)
    gb, vb, _ = dist.lookup(brv, owned, max_matches=8, rt=rv)
    gs, vs, _ = dist.lookup(brs, owned, max_matches=8, rt=rs)
    assert int(np.asarray(vb).sum()) == 0
    assert int(np.asarray(vs).sum()) == 0
    qr = np.broadcast_to(owned[:s], (s, s)).copy()
    cvr = dist.lookup_routed(brv, qr, max_matches=8, rt=rv)
    csr = dist.lookup_routed(brs, qr, max_matches=8, rt=rs)
    _assert_trees_bitwise_equal(cvr, csr)
    _, vr, ans, dropped = csr
    assert bool(np.asarray(ans).all())          # delivered to the owner...
    assert int(np.asarray(vr).sum()) == 0       # ...which honestly missed
    assert int(np.asarray(dropped).sum()) == 0


@pytest.mark.parametrize("s", MESHES)
def test_routed_drop_retry_contract_under_shard_map(s, rng):
    """The drop->retry contract on the real mesh: a capacity-starved
    exchange REPORTS its drops (bit-identical to vmap), and resubmitting
    at doubled capacity delivers everything — exactly the loop
    resilience.RecoveryManager automates."""
    cols, rv, rs, dtv, dts = _built(s)
    hot = _keys_owned_by(0, s, 8)               # all owned by shard 0
    q = np.broadcast_to(hot, (s, 8)).copy()
    cap = 2
    outv = dist.lookup_routed(dtv, q, max_matches=8, capacity=cap, rt=rv)
    outs = dist.lookup_routed(dts, q, max_matches=8, capacity=cap, rt=rs)
    _assert_trees_bitwise_equal(outv, outs)
    _, _, answered, dropped = outs
    n_dropped = int(np.asarray(dropped).sum())
    assert n_dropped > 0                        # starved: reported, not silent
    assert int(np.asarray(answered).sum()) + n_dropped == q.size
    while n_dropped > 0:                        # the retry contract
        cap *= 2
        _, valid, answered, dropped = dist.lookup_routed(
            dts, q, max_matches=8, capacity=min(cap, 8), rt=rs)
        n_dropped = int(np.asarray(dropped).sum())
    assert bool(np.asarray(answered).all())
    # delivered queries answer with the key's true multiplicity (capped
    # at max_matches) — retry recovered everything the starved pass lost
    mult = np.minimum(np.bincount(cols["k"])[hot], 8)
    np.testing.assert_array_equal(np.asarray(valid).sum(-1),
                                  np.broadcast_to(mult, (s, 8)))


@pytest.mark.parametrize("s", MESHES)
def test_supervised_recovery_under_shard_map(s, rng, tmp_path):
    """The tentpole's state machine on the real mesh backend: a seeded
    shard kill through frame.supervised heals via checkpoint + lineage
    suffix and stays bit-identical to a never-failed vmap twin."""
    from repro.dist.resilience import Fault, FaultInjector, RecoveryPolicy
    from repro.frame import IndexedFrame
    cols, rv, rs, _, _ = _built(s)
    frame = IndexedFrame.from_columns(cols, SCH, num_shards=s,
                                      rows_per_batch=128, rt=rs)
    twin = IndexedFrame.from_columns(cols, SCH, num_shards=s,
                                     rows_per_batch=128, rt=rv)
    lin = drt.Lineage(SCH, cols, rows_per_batch=128)
    mgr = frame.supervised(
        lineage=lin,
        injector=FaultInjector([Fault("shard_loss", step=3,
                                      shard=1 % s)]),
        policy=RecoveryPolicy(checkpoint_every=2),
        checkpoint_dir=str(tmp_path / "ckpts"))
    q = rng.choice(cols["k"], 48).astype(np.int64)
    for step in range(6):
        c, v = mgr.lookup(q, max_matches=8)
        tc, tv = twin.lookup(q, max_matches=8)
        np.testing.assert_array_equal(np.asarray(v), np.asarray(tv))
        for k in tc:
            np.testing.assert_array_equal(np.asarray(c[k]),
                                          np.asarray(tc[k]))
        delta = {"k": np.asarray([1000 + step], np.int64),
                 "v": np.asarray([float(step)], np.float32)}
        mgr.append(delta)
        twin = twin.append(delta)
    assert mgr.stats.recoveries == 1 and not mgr.dead
    assert mgr.retraces == 1                    # zero recompiles post-heal


# --- routed lookup miss/overflow semantics (any topology) -----------------

def _keys_owned_by(shard, num_shards, count, start=0):
    """First ``count`` non-negative keys partition-hashed to ``shard``."""
    out, k = [], start
    while len(out) < count:
        if int(hashing.partition_hash_host(np.asarray([k]), num_shards)[0]) \
                == shard:
            out.append(k)
        k += 1
    return np.asarray(out, np.int64)


def test_routed_overflow_surfaces_as_drops(rng):
    """Satellite: lane overflow is a *reported* drop (retry contract),
    never a silent miss — mirrors the hash-index build's overflow
    contract."""
    s = 4
    hot = _keys_owned_by(0, s, 8)           # every query owned by shard 0
    cols = {"k": np.arange(64, dtype=np.int64),
            "v": np.ones(64, np.float32)}
    dt = dist.create_distributed(cols, SCH, s, rows_per_batch=32)
    q = np.broadcast_to(hot, (s, 8)).copy()
    _, valid, answered, dropped = dist.lookup_routed(dt, q, max_matches=4,
                                                     capacity=2)
    answered = np.asarray(answered)
    # every source shard fits 2 of its 8 queries into the (src, 0) lane
    np.testing.assert_array_equal(np.asarray(dropped), [6] * s)
    np.testing.assert_array_equal(answered.sum(1), [2] * s)
    # conservation: every input query is answered or counted as dropped
    assert int(answered.sum()) + int(np.asarray(dropped).sum()) == q.size
    # unanswered lanes carry no fabricated matches
    assert not np.asarray(valid)[~answered].any()


def test_routed_retry_with_capacity_n_never_drops(rng):
    s = 4
    hot = _keys_owned_by(0, s, 8)
    cols = {"k": np.arange(64, dtype=np.int64),
            "v": np.ones(64, np.float32)}
    dt = dist.create_distributed(cols, SCH, s, rows_per_batch=32)
    q = np.broadcast_to(hot, (s, 8)).copy()
    _, valid, answered, dropped = dist.lookup_routed(dt, q, max_matches=4)
    assert int(np.asarray(dropped).sum()) == 0
    assert bool(np.asarray(answered).all())
    # every key 0..63 exists exactly once
    np.testing.assert_array_equal(np.asarray(valid).sum(-1),
                                  np.ones((s, 8), np.int64))


def test_routed_miss_is_miss_not_key_zero(rng):
    """Inbox padding lanes carry key 0 in their buffers; they must probe
    the EMPTY sentinel.  A table CONTAINING key 0 must not answer padded
    or absent-key queries with key 0's rows (mirrors
    test_failed_shard_answers_miss_not_key_zero)."""
    s = 4
    cols = {"k": np.arange(64, dtype=np.int64),   # key 0 exists
            "v": np.ones(64, np.float32)}
    dt = dist.create_distributed(cols, SCH, s, rows_per_batch=32)
    absent = np.arange(10**6, 10**6 + 32, dtype=np.int64).reshape(s, 8)
    _, valid, answered, dropped = dist.lookup_routed(dt, absent,
                                                     max_matches=4)
    assert bool(np.asarray(answered).all())       # delivered...
    assert int(np.asarray(valid).sum()) == 0      # ...and honestly missed
    assert int(np.asarray(dropped).sum()) == 0


def test_routed_failed_shard_answers_miss(rng):
    cols = {"k": np.arange(64, dtype=np.int64),
            "v": np.ones(64, np.float32)}
    dt = dist.create_distributed(cols, SCH, 4, rows_per_batch=32)
    owner0 = int(hashing.partition_hash_host(np.asarray([0]), 4)[0])
    broken = drt.fail_shard(dt, owner0)
    q = np.zeros((4, 4), np.int64)
    _, valid, answered, _ = dist.lookup_routed(broken, q, max_matches=4)
    assert bool(np.asarray(answered).all())
    assert int(np.asarray(valid).sum()) == 0


def test_routed_invalid_input_lanes_never_answered(rng):
    cols = {"k": np.arange(64, dtype=np.int64),
            "v": np.ones(64, np.float32)}
    dt = dist.create_distributed(cols, SCH, 4, rows_per_batch=32)
    q = np.broadcast_to(np.arange(8, dtype=np.int64), (4, 8)).copy()
    qv = np.zeros((4, 8), bool)
    qv[:, :3] = True
    _, valid, answered, dropped = dist.lookup_routed(dt, q, valid=qv,
                                                     max_matches=4)
    np.testing.assert_array_equal(np.asarray(answered), qv)
    assert not np.asarray(valid)[~qv].any()
    assert int(np.asarray(dropped).sum()) == 0


def test_stored_negative_zero_bits():
    """Where the stored BITS of a float -0.0 survive, pinned exactly
    (DESIGN.md §10): the vmap broadcast lookup always; lookup_routed
    under BOTH backends (answers cross the wire as word-packed ints over
    all_to_all); the shard_map broadcast select only numerically — XLA
    lowers every cross-device float combine (psum / sharded gather /
    all_gather) as a zero-padded sum, and -0.0 + 0.0 == +0.0."""
    cols = {"k": np.arange(8, dtype=np.int64),
            "v": np.full(8, -0.0, np.float32)}
    runtimes = [mesh.vmap_runtime()]
    if NDEV >= 4:
        runtimes.append(mesh.mesh_runtime(4))
    for rt in runtimes:
        dt = dist.create_distributed(cols, SCH, 4, rows_per_batch=8, rt=rt)
        q = np.arange(8, dtype=np.int64)
        g, v, _ = dist.lookup(dt, q, max_matches=2, rt=rt)
        got = np.asarray(g["v"])[np.asarray(v)]
        assert got.size == 8
        np.testing.assert_array_equal(got, np.zeros(8, np.float32))
        if rt.backend == "vmap":            # local select: exact bits
            assert np.signbit(got).all()
        gr, vr, ans, _ = dist.lookup_routed(dt, q.reshape(4, 2),
                                            max_matches=2, rt=rt)
        assert bool(np.asarray(ans).all())
        rbits = np.asarray(gr["v"])[np.asarray(vr)]
        assert rbits.size == 8
        assert np.signbit(rbits).all(), rt.backend  # routed: exact bits


def test_choose_lookup_routes_at_volume():
    class D:
        num_shards = 8
    assert dist.choose_lookup(D(), 64) == "bcast"
    assert dist.choose_lookup(D(), 10**6) == "routed"
    D.num_shards = 1                        # nothing to route to
    assert dist.choose_lookup(D(), 10**6) == "bcast"


# --- forced 8-device topology from a single-device process ----------------

_SUBPROCESS_PARITY = r"""
import numpy as np, jax, jax.numpy as jnp
from repro import dist
from repro.core import Schema
from repro.dist import mesh
assert len(jax.devices()) == 8, jax.devices()
SCH = Schema.of("k", k="int64", v="float32")
rng = np.random.default_rng(3)
cols = {"k": rng.integers(0, 200, 800).astype(np.int64),
        "v": rng.random(800).astype(np.float32)}
rv, rs = mesh.vmap_runtime(), mesh.mesh_runtime(8)
dtv = dist.create_distributed(cols, SCH, 8, rows_per_batch=64, rt=rv)
dts = dist.create_distributed(cols, SCH, 8, rows_per_batch=64, rt=rs)
for a, b in zip(jax.tree_util.tree_leaves(dtv), jax.tree_util.tree_leaves(dts)):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
q = np.concatenate([cols["k"][:32], [10**12]]).astype(np.int64)
gv, vv, _ = dist.lookup(dtv, q, max_matches=8, rt=rv)
gs, vs, _ = dist.lookup(dts, q, max_matches=8, rt=rs)
np.testing.assert_array_equal(np.asarray(vv), np.asarray(vs))
np.testing.assert_array_equal(np.asarray(gv["v"]), np.asarray(gs["v"]))
qs = rng.choice(cols["k"], 64).astype(np.int64).reshape(8, 8)
ov = dist.lookup_routed(dtv, qs, max_matches=8, rt=rv)
os_ = dist.lookup_routed(dts, qs, max_matches=8, rt=rs)
for a, b in zip(jax.tree_util.tree_leaves(ov), jax.tree_util.tree_leaves(os_)):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
assert int(np.asarray(ov[3]).sum()) == 0 and bool(np.asarray(ov[2]).all())
print("MESH_PARITY_8DEV_OK")
"""


_SUBPROCESS_FAILURE = r"""
import numpy as np, jax, tempfile
from repro import dist
from repro.core import Schema, hashing
from repro.dist import mesh
from repro.dist import runtime as drt
from repro.dist.resilience import Fault, FaultInjector, RecoveryPolicy
from repro.frame import IndexedFrame
assert len(jax.devices()) == 8, jax.devices()
SCH = Schema.of("k", k="int64", v="float32")
rng = np.random.default_rng(3)
cols = {"k": rng.integers(0, 200, 800).astype(np.int64),
        "v": rng.random(800).astype(np.float32)}
rv, rs = mesh.vmap_runtime(), mesh.mesh_runtime(8)
# dead shard answers all-miss on the real mesh
dts = dist.create_distributed(cols, SCH, 8, rows_per_batch=64, rt=rs)
dead = 2
owned = [k for k in range(500)
         if int(hashing.partition_hash_host(np.asarray([k]), 8)[0]) == dead]
owned = np.asarray(owned[:16], np.int64)
_, vs, _ = dist.lookup(drt.fail_shard(dts, dead), owned, max_matches=8, rt=rs)
assert int(np.asarray(vs).sum()) == 0
# supervised kill-one-shard heals bit-identical to a never-failed vmap twin
frame = IndexedFrame.from_columns(cols, SCH, num_shards=8,
                                  rows_per_batch=64, rt=rs)
twin = IndexedFrame.from_columns(cols, SCH, num_shards=8,
                                 rows_per_batch=64, rt=rv)
mgr = frame.supervised(
    lineage=drt.Lineage(SCH, cols, rows_per_batch=64),
    injector=FaultInjector([Fault("shard_loss", step=3, shard=dead)]),
    policy=RecoveryPolicy(checkpoint_every=2),
    checkpoint_dir=tempfile.mkdtemp())
q = rng.choice(cols["k"], 48).astype(np.int64)
for step in range(6):
    c, v = mgr.lookup(q, max_matches=8)
    tc, tv = twin.lookup(q, max_matches=8)
    np.testing.assert_array_equal(np.asarray(v), np.asarray(tv))
    for k in tc:
        np.testing.assert_array_equal(np.asarray(c[k]), np.asarray(tc[k]))
    delta = {"k": np.asarray([1000 + step], np.int64),
             "v": np.asarray([float(step)], np.float32)}
    mgr.append(delta)
    twin = twin.append(delta)
assert mgr.stats.recoveries == 1 and not mgr.dead, vars(mgr.stats)
assert mgr.retraces == 1, mgr.retraces
print("MESH_FAILURE_8DEV_OK")
"""


def _run_forced_8(script: str) -> subprocess.CompletedProcess:
    import repro
    src = os.path.dirname(os.path.dirname(os.path.abspath(repro.__file__)))
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=8").strip()
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    return subprocess.run([sys.executable, "-c", script],
                          capture_output=True, text=True, env=env,
                          timeout=600)


@pytest.mark.skipif(NDEV >= 8, reason="in-process mesh tests already "
                    "run on this topology")
def test_parity_on_forced_8_device_mesh_subprocess():
    """The acceptance topology: even a single-device tier-1 run proves
    the shard_map backend on a forced 8-device host mesh."""
    proc = _run_forced_8(_SUBPROCESS_PARITY)
    assert proc.returncode == 0, proc.stdout + "\n" + proc.stderr
    assert "MESH_PARITY_8DEV_OK" in proc.stdout


@pytest.mark.skipif(NDEV >= 8, reason="in-process mesh tests already "
                    "run on this topology")
def test_failure_path_on_forced_8_device_mesh_subprocess():
    """The failure path on the acceptance topology: dead-shard all-miss
    and the supervised kill -> heal -> bit-identical contract, under a
    forced 8-device shard_map mesh."""
    proc = _run_forced_8(_SUBPROCESS_FAILURE)
    assert proc.returncode == 0, proc.stdout + "\n" + proc.stderr
    assert "MESH_FAILURE_8DEV_OK" in proc.stdout
