"""Distributed Indexed DataFrame: shuffle, dtable ops, fault tolerance,
checkpoint/elastic reshard (paper §III-C/D, Fig 12)."""

import numpy as np
import jax.numpy as jnp
import pytest

pytest.importorskip("repro.dist")

from repro.core import Schema, create_index, joins
from repro.dist import (append_distributed, checkpoint, choose_join,
                        create_distributed, indexed_join_bcast,
                        indexed_join_shuffle, lookup, runtime)
from repro.dist import shuffle as shf

SCH = Schema.of("k", k="int64", v="float32")


@pytest.fixture
def dt_and_cols(rng):
    n = 3000
    cols = {"k": rng.integers(0, 500, n).astype(np.int64),
            "v": rng.random(n).astype(np.float32)}
    return create_distributed(cols, SCH, 4, rows_per_batch=256), cols


# --- shuffle ------------------------------------------------------------

def test_route_local_exact(rng):
    from repro.core import hashing
    n, s, cap = 200, 4, 80
    keys = rng.integers(0, 10**6, n).astype(np.int64)
    rows = rng.integers(0, 100, (n, 3)).astype(np.int32)
    valid = rng.random(n) < 0.9
    lk, lr, lv, dropped = shf.route_local(jnp.asarray(keys), jnp.asarray(rows),
                                          jnp.asarray(valid), s, cap)
    assert int(dropped) == 0
    dest = np.asarray(hashing.partition_hash(jnp.asarray(keys), s))
    lv_, lk_ = np.asarray(lv), np.asarray(lk)
    for d in range(s):
        sent = np.sort(keys[valid & (dest == d)])
        got = np.sort(lk_[d][lv_[d]])
        np.testing.assert_array_equal(got, sent)


def test_route_overflow_detected(rng):
    keys = np.zeros(100, np.int64)  # all to one shard
    rows = np.zeros((100, 1), np.int32)
    _, _, _, dropped = shf.route_local(jnp.asarray(keys),
                                       jnp.asarray(rows),
                                       jnp.ones(100, bool), 4, 10)
    assert int(dropped) == 90


def test_shuffle_global_delivers_everything(rng):
    s, n, cap = 4, 120, 60
    keys = rng.integers(0, 10**6, (s, n)).astype(np.int64)
    rows = keys[..., None].astype(np.int32)
    valid = np.ones((s, n), bool)
    rk, rr, rv, dropped = shf.shuffle_global(jnp.asarray(keys),
                                             jnp.asarray(rows),
                                             jnp.asarray(valid), s, cap)
    assert int(np.asarray(dropped).sum()) == 0
    got = np.sort(np.asarray(rk)[np.asarray(rv)])
    np.testing.assert_array_equal(got, np.sort(keys.ravel()))


# --- dtable --------------------------------------------------------------

def test_dist_lookup_matches_single_table(dt_and_cols, rng):
    dt, cols = dt_and_cols
    t = create_index(cols, SCH, rows_per_batch=256)
    q = np.concatenate([cols["k"][:50], [10**12]]).astype(np.int64)
    gd, vd, _ = lookup(dt, q, max_matches=32)
    gs, vs = joins.indexed_lookup(t, q, max_matches=32)
    np.testing.assert_array_equal(np.asarray(vd).sum(1), np.asarray(vs).sum(1))
    # same multiset of matched values per query
    for i in range(len(q)):
        np.testing.assert_allclose(
            np.sort(np.asarray(gd["v"][i])[np.asarray(vd[i])]),
            np.sort(np.asarray(gs["v"][i])[np.asarray(vs[i])]), rtol=1e-6)


def test_join_shuffle_and_bcast_agree(dt_and_cols, rng):
    dt, cols = dt_and_cols
    p = 64
    pk = rng.choice(cols["k"], p).astype(np.int64)
    pc_sharded = {"pk": pk.reshape(4, -1),
                  "tag": np.arange(p, dtype=np.int32).reshape(4, -1)}
    bc, pc, v, dropped = indexed_join_shuffle(
        dt, pc_sharded, "pk", jnp.ones((4, p // 4), bool), 32)
    assert int(np.asarray(dropped).sum()) == 0
    bc2, pc2, v2 = indexed_join_bcast(dt, {"pk": pk}, "pk", 32)
    assert int(np.asarray(v).sum()) == int(np.asarray(v2).sum())


def test_choose_join_threshold():
    class D: pass
    assert choose_join(D(), 100) == "bcast"
    assert choose_join(D(), 10**7) == "shuffle"


def test_distributed_append_mvcc(dt_and_cols, rng):
    dt, cols = dt_and_cols
    key = int(cols["k"][0])
    base = int(np.sum(cols["k"] == key))
    dt2 = append_distributed(dt, {"k": np.array([key], np.int64),
                                  "v": np.array([42.0], np.float32)})
    assert dt2.version == 1 and dt.version == 0
    _, v2, _ = lookup(dt2, np.array([key], np.int64), max_matches=64)
    _, v1, _ = lookup(dt, np.array([key], np.int64), max_matches=64)
    assert int(v2.sum()) == base + 1
    assert int(v1.sum()) == base


# --- fault tolerance -------------------------------------------------------

def test_fail_and_rebuild_shard(dt_and_cols, rng):
    dt, cols = dt_and_cols
    lin = runtime.Lineage(SCH, cols, rows_per_batch=256)
    delta = {"k": np.array([int(cols["k"][0])], np.int64),
             "v": np.array([7.0], np.float32)}
    dt = append_distributed(dt, delta)
    lin.record_append(delta)

    q = cols["k"][:40].astype(np.int64)
    expect, ve, _ = lookup(dt, q, max_matches=64)
    ve = np.asarray(ve)

    broken = runtime.fail_shard(dt, 1)
    rebuilt = runtime.rebuild_shard(broken, 1, lin)
    got, vg, _ = lookup(rebuilt, q, max_matches=64)
    np.testing.assert_array_equal(np.asarray(vg), ve)
    np.testing.assert_allclose(np.asarray(got["v"]) * ve,
                               np.asarray(expect["v"]) * ve, rtol=1e-6)


def test_failed_shard_answers_miss_not_key_zero(rng):
    """A dead shard must answer every lookup with a miss: blanking must use
    EMPTY/NULL sentinels, not zeros (0 is a legal key and a legal row id)."""
    from repro.core import hashing
    cols = {"k": np.arange(64, dtype=np.int64),
            "v": np.ones(64, np.float32)}
    dt = create_distributed(cols, SCH, 4, rows_per_batch=32)
    owner0 = int(np.asarray(
        hashing.partition_hash(jnp.asarray([0], jnp.int64), 4))[0])
    broken = runtime.fail_shard(dt, owner0)
    _, v, _ = lookup(broken, np.array([0], np.int64), max_matches=8)
    assert int(np.asarray(v).sum()) == 0


def test_version_vector_fencing():
    vv = runtime.VersionVector.fresh(4)
    assert vv.check_fresh(0, 0)
    vv.bump_all()
    assert not vv.check_fresh(0, 0)
    assert vv.check_fresh(0, 1)
    vv.mark_stale(2)
    assert not vv.check_fresh(2, 1)


def test_straggler_policy():
    sp = runtime.StragglerPolicy(deadline_factor=2.0)
    slow = sp.observe([1.0, 1.1, 0.9, 5.0])
    assert slow == [3]
    plan = sp.plan_speculative(4)
    assert plan == {3: 0}


# --- checkpoint / elastic -----------------------------------------------

def test_checkpoint_roundtrip(tmp_path, dt_and_cols):
    dt, cols = dt_and_cols
    path = str(tmp_path / "ck")
    checkpoint.save_dtable(path, dt)
    dt2 = checkpoint.restore_dtable(path, dt)
    q = cols["k"][:10].astype(np.int64)
    g1, v1, _ = lookup(dt, q, max_matches=16)
    g2, v2, _ = lookup(dt2, q, max_matches=16)
    np.testing.assert_array_equal(np.asarray(v1), np.asarray(v2))


def test_elastic_reshard(dt_and_cols):
    dt, cols = dt_and_cols
    for m in (2, 8):
        dtm = checkpoint.reshard_dtable(dt, m)
        assert dtm.num_shards == m
        q = cols["k"][:20].astype(np.int64)
        g1, v1, _ = lookup(dt, q, max_matches=32)
        g2, v2, _ = lookup(dtm, q, max_matches=32)
        np.testing.assert_array_equal(np.asarray(v1).sum(1),
                                      np.asarray(v2).sum(1))


def test_restore_shape_mismatch_raises(tmp_path, dt_and_cols):
    dt, _ = dt_and_cols
    path = str(tmp_path / "ck")
    checkpoint.save_dtable(path, dt)
    bigger = checkpoint.reshard_dtable(dt, 8)
    with pytest.raises(ValueError):
        checkpoint.restore_dtable(path, bigger)
