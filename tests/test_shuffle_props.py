"""Property-based shuffle tests (ISSUE 3 satellite).

The exchange's whole correctness story is three invariants, asserted here
over randomized shard counts, capacities, and pytree payload shapes
(hypothesis, or the deterministic ``repro.testing`` shim in hermetic
containers — conftest installs it):

1. **Conservation** — every valid row is delivered exactly once or
   counted in ``dropped``; nothing is silently lost, nothing duplicated.
2. **Destination correctness** — a delivered row sits in the outbox/inbox
   of exactly ``partition_hash(key)``.
3. **capacity = n never drops** — the exact-exchange configuration the
   join/lookup defaults rely on.

Payloads are pytrees: every leaf must ride the same permutation as the
keys (a misaligned leaf silently joins the wrong rows).  The transpose
oracle vs ``lax.all_to_all`` equivalence is asserted here on the vmap
backend (single-device safe); tests/test_mesh_parity.py repeats it under
shard_map on a real mesh.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings
import hypothesis.strategies as st

pytest.importorskip("repro.dist")

from repro.core import hashing
from repro.dist import mesh
from repro.dist import shuffle as shf


def _payload(keys, lanes):
    """A pytree payload whose every leaf is derived from (key, lane), so
    alignment after routing is checkable leaf by leaf.  ``half`` guards
    the packed exchange's sub-4-byte handling (bitcast, never a value
    cast)."""
    return {"lane": lanes.astype(np.int32),
            "wide": np.stack([keys.astype(np.float64),
                              lanes.astype(np.float64)], axis=-1),
            "half": (np.abs(keys) % 97).astype(np.float16),
            "nested": {"neg": (-keys).astype(np.int64)}}


def _check_outbox(keys, valid, lk, lp, lv, dropped, num_shards, capacity):
    """Invariants 1-3 for one source's outboxes [s, cap]."""
    keys = np.asarray(keys)
    valid = np.asarray(valid)
    lk, lv = np.asarray(lk), np.asarray(lv)
    lanes = np.asarray(lp["lane"])
    dest = hashing.partition_hash_host(keys, num_shards)

    delivered = int(lv.sum())
    assert delivered + int(dropped) == int(valid.sum())

    for d in range(num_shards):
        got_lanes = np.sort(lanes[d][lv[d]])
        want = np.flatnonzero(valid & (dest == d))
        if capacity >= want.size:
            np.testing.assert_array_equal(got_lanes, want)
        else:
            # capacity-bounded: a subset, each source lane at most once
            assert got_lanes.size == capacity
            assert np.isin(got_lanes, want).all()
            assert np.unique(got_lanes).size == got_lanes.size
        # destination correctness + payload alignment for every leaf
        np.testing.assert_array_equal(lk[d][lv[d]],
                                      keys[lanes[d][lv[d]]])
        np.testing.assert_array_equal(
            np.asarray(lp["nested"]["neg"])[d][lv[d]],
            -keys[lanes[d][lv[d]]])
        np.testing.assert_array_equal(
            np.asarray(lp["half"])[d][lv[d]],
            (np.abs(keys) % 97).astype(np.float16)[lanes[d][lv[d]]])
        np.testing.assert_array_equal(
            np.asarray(lp["wide"])[d][lv[d]],
            np.stack([keys[lanes[d][lv[d]]].astype(np.float64),
                      lanes[d][lv[d]].astype(np.float64)], axis=-1))


@settings(max_examples=25, deadline=None)
@given(st.integers(min_value=1, max_value=8),
       st.integers(min_value=1, max_value=24),
       st.lists(st.integers(min_value=-2**63, max_value=2**63 - 1),
                min_size=1, max_size=48),
       st.integers(min_value=0, max_value=2**32 - 1))
def test_route_local_properties(num_shards, capacity, key_list, seed):
    keys = np.asarray(key_list, np.int64)
    n = keys.shape[0]
    rng = np.random.default_rng(seed)
    valid = rng.random(n) < 0.85
    lanes = np.arange(n)
    lk, lp, lv, dropped = shf.route_local(
        jnp.asarray(keys), _payload(keys, lanes), jnp.asarray(valid),
        num_shards, capacity)
    _check_outbox(keys, valid, lk, lp, lv, int(dropped), num_shards,
                  capacity)


@settings(max_examples=25, deadline=None)
@given(st.integers(min_value=1, max_value=48),
       st.integers(min_value=0, max_value=2**32 - 1))
def test_route_local_capacity_n_never_drops(n, seed):
    rng = np.random.default_rng(seed)
    for num_shards in (1, 3, 8):
        keys = rng.integers(-2**62, 2**62, n).astype(np.int64)
        valid = rng.random(n) < 0.9
        _, _, lv, dropped = shf.route_local(
            jnp.asarray(keys), _payload(keys, np.arange(n)),
            jnp.asarray(valid), num_shards, n)
        assert int(dropped) == 0
        assert int(np.asarray(lv).sum()) == int(valid.sum())


@settings(max_examples=20, deadline=None)
@given(st.integers(min_value=1, max_value=8),
       st.integers(min_value=1, max_value=16),
       st.integers(min_value=1, max_value=24),
       st.integers(min_value=0, max_value=2**32 - 1))
def test_shuffle_global_properties(num_shards, n, capacity, seed):
    """The full exchange: per-source conservation, destination-correct
    inboxes, payload alignment — and the all_to_all path bit-identical to
    the transpose oracle on the same inputs."""
    rng = np.random.default_rng(seed)
    keys = rng.integers(-2**62, 2**62, (num_shards, n)).astype(np.int64)
    valid = rng.random((num_shards, n)) < 0.85
    lanes = np.broadcast_to(np.arange(n), (num_shards, n))
    payload = _payload(keys.reshape(-1), lanes.reshape(-1))
    payload = jax.tree.map(
        lambda a: a.reshape((num_shards, n) + a.shape[1:]), payload)

    rk, rp, rv, dropped = shf.shuffle_global(
        jnp.asarray(keys), payload, jnp.asarray(valid), num_shards,
        capacity)
    rk, rv = np.asarray(rk), np.asarray(rv)
    dropped = np.asarray(dropped)

    # conservation per source shard
    src_of_lane = np.repeat(np.arange(num_shards), capacity)
    for i in range(num_shards):
        from_i = int(rv[:, src_of_lane == i].sum())
        assert from_i + int(dropped[i]) == int(valid[i].sum())
    if capacity >= n:
        assert int(dropped.sum()) == 0

    # destination correctness + alignment: inbox d holds only keys owned
    # by d, and every delivered leaf matches its source (src, lane) row
    neg = np.asarray(rp["nested"]["neg"])
    lane_ids = np.asarray(rp["lane"])
    for d in range(num_shards):
        m = rv[d]
        if not m.any():
            continue
        np.testing.assert_array_equal(
            hashing.partition_hash_host(rk[d][m], num_shards), d)
        src = src_of_lane[m]
        np.testing.assert_array_equal(rk[d][m],
                                      keys[src, lane_ids[d][m]])
        np.testing.assert_array_equal(neg[d][m], -rk[d][m])

    # oracle equivalence: the mesh-native all_to_all body, vmap backend
    rt = mesh.vmap_runtime()
    got = mesh.axis_map(
        lambda k, r, v: shf.shuffle_global_axis(k, r, v, num_shards,
                                                capacity, rt.axis), rt)(
        jnp.asarray(keys), payload, jnp.asarray(valid))
    for a, b in zip(jax.tree_util.tree_leaves((rk, rp, rv, dropped)),
                    jax.tree_util.tree_leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_pack_words_roundtrip_is_bit_exact(rng):
    """Every supported dtype survives pack -> unpack bit-for-bit,
    including -0.0, NaN payloads, and 2-byte floats (which must bitcast,
    never value-cast)."""
    n = 64
    f32 = rng.standard_normal(n).astype(np.float32)
    f32[:3] = [-0.0, np.nan, np.inf]
    f16 = rng.standard_normal((n, 2)).astype(np.float16)
    f16[0, 0] = -0.0
    tree = {"i64": rng.integers(-2**62, 2**62, n),
            "f32": f32, "f16": f16,
            "bf16": jnp.asarray(f32, jnp.bfloat16),
            "i16": rng.integers(-2**15, 2**15, (n, 3)).astype(np.int16),
            "u8": rng.integers(0, 255, n).astype(np.uint8),
            "b": rng.random(n) < 0.5}
    packed, spec = shf.pack_words(tree)
    assert packed.dtype == jnp.int32
    out = shf.unpack_words(packed, spec)
    for k in tree:
        a, b = jnp.asarray(tree[k]), out[k]
        assert a.dtype == b.dtype and a.shape == b.shape, k
        if jnp.issubdtype(a.dtype, jnp.floating):
            itype = {2: jnp.int16, 4: jnp.int32}[a.dtype.itemsize]
            a = jax.lax.bitcast_convert_type(a, itype)
            b = jax.lax.bitcast_convert_type(b, itype)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b), k)
    if hasattr(jnp, "float8_e4m3fn"):
        with pytest.raises(TypeError, match="unsupported"):
            shf.pack_words({"e": jnp.zeros(4, jnp.float8_e4m3fn)})


def test_rank_paths_bit_identical(rng, monkeypatch):
    """route_local has two per-destination rank computations (one-hot
    cumsum below RANK_ONEHOT_MAX_SHARDS, stable argsort above) — same
    outboxes bit for bit, on the same inputs."""
    n = 200
    keys = rng.integers(-2**62, 2**62, n).astype(np.int64)
    valid = rng.random(n) < 0.8
    payload = _payload(keys, np.arange(n))
    for num_shards, capacity in ((1, 7), (4, 11), (8, 200), (96, 2)):
        monkeypatch.setattr(shf, "RANK_ONEHOT_MAX_SHARDS", 128)  # cumsum
        a = shf.route_local(jnp.asarray(keys), payload, jnp.asarray(valid),
                            num_shards, capacity)
        monkeypatch.setattr(shf, "RANK_ONEHOT_MAX_SHARDS", 0)    # argsort
        b = shf.route_local(jnp.asarray(keys), payload, jnp.asarray(valid),
                            num_shards, capacity)
        for x, y in zip(jax.tree_util.tree_leaves(a),
                        jax.tree_util.tree_leaves(b)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_all_to_all_axis_matches_transpose(rng):
    """The raw collective: outbox [s, cap, ...] per shard -> src-major
    inbox, for every shard count a CI host can emulate."""
    for s in (1, 2, 4, 8):
        x = rng.integers(0, 10**9, (s, s, 5, 3)).astype(np.int64)
        ref = jnp.swapaxes(jnp.asarray(x), 0, 1).reshape(s, s * 5, 3)
        got = jax.vmap(lambda b: shf.all_to_all_axis(b, "shards"),
                       axis_name="shards")(jnp.asarray(x))
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))
