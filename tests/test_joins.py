"""Join operators: indexed path vs the vanilla baselines (paper Fig 7/8)."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import Schema, create_index, joins

SCH = Schema.of("k", k="int64", v="float32")


def _sorted_pairs(cols, valid):
    """Canonical multiset of (probe_tag, build_v) matches for comparison."""
    v = np.asarray(valid)
    out = []
    bv = np.asarray(cols[0]["v"]) if "v" in cols[0] else None
    return v


def test_indexed_vs_hash_vs_sortmerge(rng):
    n, q = 800, 100
    bkeys = rng.integers(0, 150, n).astype(np.int64)
    build = {"k": bkeys, "v": rng.random(n).astype(np.float32)}
    t = create_index(build, SCH, rows_per_batch=128)
    pk = np.concatenate([rng.choice(bkeys, q - 10),
                         rng.integers(200, 300, 10)]).astype(np.int64)
    probe_cols = {"pk": pk, "tag": np.arange(q, dtype=np.int32)}

    bi, pi, vi = joins.indexed_join(t, probe_cols, "pk", max_matches=32)
    bh, ph, vh = joins.hash_join(build, "k", probe_cols, "pk", max_matches=32)
    bs, ps, vs = joins.sort_merge_join(build, "k", probe_cols, "pk",
                                       max_matches=32)

    np.testing.assert_array_equal(np.asarray(vi), np.asarray(vh))
    np.testing.assert_array_equal(np.asarray(vi), np.asarray(vs))
    # matched values agree (newest-first ordering is part of the contract)
    for b in (bh, bs):
        np.testing.assert_allclose(
            np.asarray(bi["v"]) * np.asarray(vi),
            np.asarray(b["v"]) * np.asarray(vi), rtol=1e-6)


def test_scan_lookup_equals_indexed_lookup(rng):
    n = 400
    build = {"k": rng.integers(0, 60, n).astype(np.int64),
             "v": rng.random(n).astype(np.float32)}
    t = create_index(build, SCH, rows_per_batch=64)
    q = np.arange(70, dtype=np.int64)
    gi, vi = joins.indexed_lookup(t, q, max_matches=32)
    gs, vs = joins.scan_lookup(t, q, max_matches=32)
    np.testing.assert_array_equal(np.asarray(vi), np.asarray(vs))
    np.testing.assert_allclose(np.asarray(gi["v"]) * np.asarray(vi),
                               np.asarray(gs["v"]) * np.asarray(vs))


def test_aggregate_ops():
    vals = jnp.asarray([1.0, 2.0, 3.0, 4.0])
    valid = jnp.asarray([True, True, False, True])
    assert float(joins.aggregate(vals, valid, "sum")) == 7.0
    assert int(joins.aggregate(vals, valid, "count")) == 3
    assert float(joins.aggregate(vals, valid, "min")) == 1.0
    assert float(joins.aggregate(vals, valid, "max")) == 4.0
    assert abs(float(joins.aggregate(vals, valid, "mean")) - 7 / 3) < 1e-6


@settings(max_examples=20, deadline=None)
@given(st.lists(st.integers(min_value=0, max_value=12), min_size=1,
                max_size=100),
       st.lists(st.integers(min_value=0, max_value=15), min_size=1,
                max_size=30))
def test_property_join_agreement(bkeys, pkeys):
    build = {"k": np.asarray(bkeys, np.int64),
             "v": np.arange(len(bkeys), dtype=np.float32)}
    probe_cols = {"pk": np.asarray(pkeys, np.int64),
                  "tag": np.arange(len(pkeys), dtype=np.int32)}
    t = create_index(build, SCH, rows_per_batch=32)
    bi, _, vi = joins.indexed_join(t, probe_cols, "pk", max_matches=128)
    bh, _, vh = joins.hash_join(build, "k", probe_cols, "pk", max_matches=128)
    np.testing.assert_array_equal(np.asarray(vi), np.asarray(vh))
    np.testing.assert_allclose(np.asarray(bi["v"]) * vi,
                               np.asarray(bh["v"]) * vh)
