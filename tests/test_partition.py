"""Partitioned indexes (ISSUE 10 / DESIGN.md §16).

The contract under test, in order of importance:

* **Pruned reads are exact**: lookups, joins, and partition-column
  filters through a partitioned frame are bit-identical (masked by
  validity — invalid lanes are zeroed on the partitioned side, row-0
  garbage on the monolithic side) to the same reads through an
  UNPARTITIONED frame built from the same rows.  Property-tested over
  random key sets and delta sequences, local and distributed, and on a
  forced-8 shard_map mesh when the process has the devices.
* **Retention is observational**: ``drop_partition``/``retain`` answer
  exactly like a frame REBUILT from the surviving rows (drop ≡
  filter-out), with one version bump and zero retraces of surviving
  read sites (the trace accounting the CI gate also checks).
* **MVCC visibility**: a lookup planned against version v never sees
  rows appended after v; per-key match order stays newest-first across
  partition boundaries because the partition column IS the key.
* **Planner rules**: P1 prunes a point lookup to exactly one partition,
  P2 prunes a partition-column range filter, P3 keeps joins exchange-
  free — each with the pruned/scanned sets named in ``explain()``.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import Schema, partition
from repro.core import planner as planner_mod
from repro.core.partition import PartitionSpec
from repro.frame import IndexedFrame

SCH = Schema.of("k", k="int64", v="float32")
NDEV = len(jax.devices())

# keys in [0, 36) over three range partitions of width 12
CUTS = [0, 12, 24, 36]
IDS = ["jan", "feb", "mar"]
KEYS = st.lists(st.integers(min_value=0, max_value=35), min_size=1,
                max_size=50)

SHARDS = ([1, 2] if NDEV < 8 else [1, 2, 8])


def _spec():
    return PartitionSpec.range_("k", CUTS, ids=IDS)


def _cols_from(keys, base):
    keys = np.asarray(keys, np.int64)
    return {"k": keys,
            "v": (np.arange(len(keys), dtype=np.float32) * 0.5
                  + np.float32(base))}


def _rt(num_shards):
    if num_shards == 8 and NDEV >= 8:
        from repro.dist import mesh
        return mesh.mesh_runtime(8)
    return None


def _build_pair(base, deltas, num_shards, *, rows_per_batch=16):
    """The partitioned frame and its monolithic twin, same rows."""
    rt = _rt(num_shards)
    kw = dict(rows_per_batch=rows_per_batch)
    if num_shards > 1:
        kw.update(num_shards=num_shards, rt=rt)
    fp = IndexedFrame.from_columns(base, SCH, partition_by=_spec(), **kw)
    fm = IndexedFrame.from_columns(base, SCH, **kw)
    for d in deltas:
        fp = fp.append(dict(d))
        fm = fm.append(dict(d))
    return fp, fm


def _masked(cols, valid):
    v = np.asarray(valid)
    return {n: np.asarray(c) * v for n, c in cols.items()}, v


def _assert_reads_match(fp, fm, q, *, max_matches=64):
    cp, vp = fp.lookup(q, max_matches=max_matches)
    cm, vm = fm.lookup(q, max_matches=max_matches)
    mp, vp_ = _masked(cp, vp)
    mm_, vm_ = _masked(cm, vm)
    np.testing.assert_array_equal(vp_, vm_)
    for n in mp:   # bit-identical, ORDER included (newest-first MVCC)
        np.testing.assert_array_equal(mp[n], mm_[n])


# --- spec validation -------------------------------------------------------


def test_spec_validates():
    with pytest.raises(ValueError):
        PartitionSpec.range_("k", [0, 10, 5])          # not ascending
    with pytest.raises(ValueError):
        PartitionSpec.range_("k", [0, 10], ids=["a", "b"])  # id count
    with pytest.raises(ValueError):
        PartitionSpec.list_("k", [[1, 2], [2, 3]])     # overlap
    with pytest.raises(ValueError):
        PartitionSpec.list_("k", [[1], []])            # empty group
    s = _spec()
    assert s.num_partitions == 3
    assert s.index_of("feb") == 1 and s.index_of(2) == 2
    np.testing.assert_array_equal(
        s.route_host(np.array([0, 11, 12, 35, 36, -1], np.int64)),
        [0, 0, 1, 2, -1, -1])


def test_list_spec_values_above_max_member_are_misses():
    # regression: searchsorted returns len(flat) for values above the
    # largest list member — must be a clean miss, not an IndexError
    spec = PartitionSpec.list_("k", [[1, 2], [7, 9]])
    np.testing.assert_array_equal(
        spec.route_host(np.array([9, 10, 99, -5, 2], np.int64)),
        [1, -1, -1, -1, 0])
    assert spec.prune_eq(10) == () and spec.prune_eq(9) == (1,)
    cols = {"k": np.array([1, 7], np.int64),
            "v": np.zeros(2, np.float32)}
    fr = IndexedFrame.from_columns(cols, SCH, partition_by=spec,
                                   rows_per_batch=8)
    # lookup above the max member: a miss, not a crash
    _, v = fr.lookup(np.array([99], np.int64), max_matches=4)
    assert not np.asarray(v).any()
    # strict append of an unmapped high value: the intended ValueError
    with pytest.raises(ValueError, match="outside every partition"):
        fr.append({"k": np.array([99], np.int64),
                   "v": np.zeros(1, np.float32)})
    # planner prune on such a literal: empty pruned set, no crash
    pred = planner_mod.Eq(planner_mod.Col("k"), planner_mod.Lit(99))
    assert "pruned" in fr.filter(pred).explain()


def test_ids_must_be_filesystem_safe():
    # ids name checkpoint subdirs — path-hostile ids are rejected
    for bad in ("a/b", "..", "", "a b", "p\x00"):
        with pytest.raises(ValueError, match="filesystem|invalid|ids"):
            PartitionSpec.range_("k", [0, 10, 20], ids=[bad, "ok"])


def test_non_key_partition_column_rejects_keyed_reads():
    spec = PartitionSpec.range_("v_bucket", [0, 2, 4])
    sch = Schema.of("k", k="int64", v_bucket="int64", v="float32")
    cols = {"k": np.arange(8, dtype=np.int64),
            "v_bucket": np.arange(8, dtype=np.int64) % 4,
            "v": np.zeros(8, np.float32)}
    fr = IndexedFrame.from_columns(cols, sch, partition_by=spec,
                                   rows_per_batch=8)
    with pytest.raises(ValueError, match="partition column"):
        fr.lookup(np.array([1], np.int64), max_matches=4)


def test_unmapped_rows_rejected_strictly():
    cols = _cols_from([1, 2, 99], 0)    # 99 outside every range
    with pytest.raises(ValueError, match="outside every partition"):
        IndexedFrame.from_columns(cols, SCH, partition_by=_spec(),
                                  rows_per_batch=8)


# --- pruned reads ≡ unpartitioned (the exactness property) -----------------


@settings(max_examples=15, deadline=None)
@given(KEYS, st.lists(KEYS, min_size=0, max_size=3),
       st.lists(st.integers(min_value=-3, max_value=38), min_size=1,
                max_size=24))
def test_property_pruned_lookup_equals_unpartitioned(base_keys, deltas,
                                                     queries):
    base = _cols_from(base_keys, 0)
    ds = [_cols_from(d, 1000 * (i + 1)) for i, d in enumerate(deltas)]
    fp, fm = _build_pair(base, ds, 1)
    _assert_reads_match(fp, fm, np.asarray(queries, np.int64))


@settings(max_examples=8, deadline=None)
@given(KEYS, st.lists(KEYS, min_size=0, max_size=2),
       st.lists(st.integers(min_value=-3, max_value=38), min_size=1,
                max_size=16))
def test_property_pruned_lookup_equals_unpartitioned_dist(base_keys,
                                                          deltas, queries):
    base = _cols_from(base_keys, 0)
    ds = [_cols_from(d, 1000 * (i + 1)) for i, d in enumerate(deltas)]
    fp, fm = _build_pair(base, ds, 2)
    _assert_reads_match(fp, fm, np.asarray(queries, np.int64))


@pytest.mark.parametrize("num_shards", SHARDS)
def test_join_parity(num_shards):
    rng = np.random.default_rng(3)
    base = _cols_from(rng.integers(0, 36, 200), 0)
    fp, fm = _build_pair(base, [_cols_from(rng.integers(0, 36, 40), 500)],
                         num_shards)
    pc = {"pk": rng.integers(-2, 38, 33).astype(np.int64),
          "tag": np.arange(33, dtype=np.int32)}
    bp, pp, vp = fp.join(pc, "pk", max_matches=64)
    bm, pm, vm = fm.join(pc, "pk", max_matches=64)
    v = np.asarray(vp)
    np.testing.assert_array_equal(v, np.asarray(vm))
    for n in bp:
        np.testing.assert_array_equal(np.asarray(bp[n]) * v,
                                      np.asarray(bm[n]) * v)
    for n in pp:   # probe broadcast is dense (valid-independent)
        np.testing.assert_array_equal(np.asarray(pp[n]),
                                      np.asarray(pm[n]))


@pytest.mark.parametrize("num_shards", SHARDS)
def test_filter_parity_p2(num_shards):
    rng = np.random.default_rng(4)
    base = _cols_from(rng.integers(0, 36, 150), 0)
    rt = _rt(num_shards)
    kw = {} if num_shards == 1 else dict(num_shards=num_shards, rt=rt)
    fp = IndexedFrame.from_columns(base, SCH, partition_by=_spec(),
                                   rows_per_batch=16, **kw)
    fm = IndexedFrame.from_columns(base, SCH, rows_per_batch=16, **kw)
    pred = planner_mod.Lt(planner_mod.Col("k"), planner_mod.Lit(12))
    gc, gv = fp.filter(pred).execute()
    wc, wv = fm.filter(pred).execute()
    for n in wc:
        np.testing.assert_array_equal(
            np.sort(np.asarray(gc[n])[np.asarray(gv)]),
            np.sort(np.asarray(wc[n])[np.asarray(wv)]))
    plan = fp.filter(pred).explain()
    assert "P2" in plan and "pruned" in plan


# --- retention: drop ≡ filter-out, O(1), zero retraces ---------------------


@settings(max_examples=10, deadline=None)
@given(KEYS, st.sampled_from(IDS))
def test_property_drop_equals_filter_out(base_keys, victim):
    base = _cols_from(base_keys, 0)
    fp = IndexedFrame.from_columns(base, SCH, partition_by=_spec(),
                                   rows_per_batch=16)
    i = _spec().index_of(victim)
    lo, hi = _spec().ranges[i]
    keep = (base["k"] < lo) | (base["k"] >= hi)
    spec_kept = PartitionSpec(
        column="k", kind="range",
        ranges=tuple(r for j, r in enumerate(_spec().ranges) if j != i),
        ids=tuple(p for j, p in enumerate(IDS) if j != i))
    dropped = fp.drop_partition(victim)
    assert dropped.version == fp.version + 1
    if keep.any():
        rebuilt = IndexedFrame.from_columns(
            {n: c[keep] for n, c in base.items()}, SCH,
            partition_by=spec_kept, rows_per_batch=16)
        q = np.arange(-1, 37, dtype=np.int64)
        _assert_reads_match(dropped, rebuilt, q)


def test_retain_sweeps_below_watermark():
    base = _cols_from(np.arange(36), 0)
    fp = IndexedFrame.from_columns(base, SCH, partition_by=_spec(),
                                   rows_per_batch=16)
    swept = fp.retain(min_value=24)          # jan + feb wholly below
    assert swept.partition_ids == ("mar",)
    assert swept.version == fp.version + 1   # ONE bump for the sweep
    assert fp.retain(min_value=0).version == fp.version  # no-op, no bump
    kept = fp.retain(keep=["feb"])
    assert kept.partition_ids == ("feb",)
    with pytest.raises(ValueError):
        fp.retain(min_value=1000)            # cannot drop every partition
    with pytest.raises(ValueError):
        fp.retain()                          # exactly one selector


@pytest.mark.parametrize("num_shards", SHARDS)
def test_drop_and_retain_zero_retrace(num_shards):
    rng = np.random.default_rng(5)
    base = _cols_from(rng.integers(0, 36, 120), 0)
    rt = _rt(num_shards)
    kw = {} if num_shards == 1 else dict(num_shards=num_shards, rt=rt)
    fr = IndexedFrame.from_columns(base, SCH, partition_by=_spec(),
                                   rows_per_batch=16, **kw)
    q = rng.integers(0, 36, 17).astype(np.int64)
    t0 = partition.site_traces()
    fr.lookup(q, max_matches=8)                      # warmup
    warm = partition.site_traces() - t0
    fr = fr.append(_cols_from(rng.integers(12, 24, 9), 900))  # one part
    fr.lookup(q, max_matches=8)
    fr = fr.drop_partition("jan")
    fr.lookup(q, max_matches=8)
    fr = fr.retain(min_value=24)
    fr.lookup(q, max_matches=8)
    assert partition.site_traces() - t0 == warm, \
        "append/drop/retain retraced a surviving read site"
    assert partition.site_traces() == partition.expected_site_traces()


# --- MVCC visibility -------------------------------------------------------


def test_mvcc_snapshot_isolation_and_newest_first():
    base = _cols_from([5, 17, 29], 0)
    fp = IndexedFrame.from_columns(base, SCH, partition_by=_spec(),
                                   rows_per_batch=16)
    v0 = int(np.asarray(fp.version))
    old_handle = fp
    fp2 = fp.append({"k": np.array([17], np.int64),
                     "v": np.array([777.0], np.float32)})
    assert int(np.asarray(fp2.version)) == v0 + 1
    # the pre-append handle still answers at its own version
    c_old, v_old = old_handle.lookup(np.array([17], np.int64),
                                     max_matches=4)
    assert np.asarray(v_old)[0].sum() == 1
    # the post-append frame sees both rows, newest FIRST
    c_new, v_new = fp2.lookup(np.array([17], np.int64), max_matches=4)
    assert np.asarray(v_new)[0].sum() == 2
    np.testing.assert_array_equal(
        np.asarray(c_new["v"])[0][np.asarray(v_new)[0]],
        np.float32([777.0, 1.0 * 0.5]))


# --- planner rules ---------------------------------------------------------


def test_p1_point_lookup_prunes_to_one_partition():
    base = _cols_from(np.arange(36), 0)
    fr = IndexedFrame.from_columns(base, SCH, partition_by=_spec(),
                                   rows_per_batch=16)
    plan = fr.plan_lookup(np.array([17], np.int64))
    assert plan.kind == "PartitionedLookup"
    assert plan.meta == [1]                  # exactly feb
    assert "P1" in plan.reason and "1/3" in plan.reason
    assert "feb" in plan.reason and "pruned" in plan.reason


def test_p3_join_plan_names_pruned_set():
    base = _cols_from(np.arange(36), 0)
    fr = IndexedFrame.from_columns(base, SCH, partition_by=_spec(),
                                   rows_per_batch=16)
    pc = {"pk": np.array([1, 2, 3], np.int64)}
    plan = fr.plan_join(pc, "pk", max_matches=4)
    assert plan.kind == "PartitionedJoin" and plan.meta == [0]
    assert "P3" in plan.reason and "no cross-partition exchange" in plan.reason


def test_forced_op_rejected():
    base = _cols_from(np.arange(36), 0)
    fr = IndexedFrame.from_columns(base, SCH, partition_by=_spec(),
                                   rows_per_batch=16)
    with pytest.raises(ValueError, match="auto"):
        fr.lookup(np.array([1], np.int64), max_matches=4, op="routed")
    with pytest.raises(ValueError):
        fr.with_queue()
    with pytest.raises(ValueError):
        fr.with_hot_tracker(8)


# --- in-trace fallback (tracer keys) ---------------------------------------


def test_lookup_inside_jit_scans_all_partitions_correctly():
    rng = np.random.default_rng(6)
    base = _cols_from(rng.integers(0, 36, 100), 0)
    fp = IndexedFrame.from_columns(base, SCH, partition_by=_spec(),
                                   rows_per_batch=16)
    fm = IndexedFrame.from_columns(base, SCH, rows_per_batch=16)
    q = rng.integers(0, 36, 9).astype(np.int64)

    @jax.jit
    def f(fr, qq):
        return fr.lookup(qq, max_matches=16)

    cp, vp = f(fp, jnp.asarray(q))
    cm, vm = fm.lookup(q, max_matches=16)
    v = np.asarray(vp)
    np.testing.assert_array_equal(v, np.asarray(vm))
    for n in cp:
        np.testing.assert_array_equal(np.asarray(cp[n]) * v,
                                      np.asarray(cm[n]) * v)


# --- vmap vs shard_map parity (forced-8 runs in ci.sh) ---------------------


@pytest.mark.skipif(NDEV < 8, reason="needs the forced-8 host mesh "
                                     "(scripts/ci.sh second pass)")
def test_shard_map_parity_forced_8():
    from repro.dist import mesh
    rng = np.random.default_rng(7)
    base = _cols_from(rng.integers(0, 36, 400), 0)
    delta = _cols_from(rng.integers(0, 36, 50), 700)
    q = rng.integers(-2, 38, 41).astype(np.int64)
    fv = IndexedFrame.from_columns(base, SCH, partition_by=_spec(),
                                   num_shards=8, rows_per_batch=16,
                                   rt=mesh.vmap_runtime()).append(delta)
    fs = IndexedFrame.from_columns(base, SCH, partition_by=_spec(),
                                   num_shards=8, rows_per_batch=16,
                                   rt=mesh.mesh_runtime(8)).append(delta)
    cv, vv = fv.lookup(q, max_matches=64)
    cs, vs = fs.lookup(q, max_matches=64)
    v = np.asarray(vv)
    np.testing.assert_array_equal(v, np.asarray(vs))
    for n in cv:
        np.testing.assert_array_equal(np.asarray(cv[n]) * v,
                                      np.asarray(cs[n]) * v)


# --- supervision: per-partition heal ---------------------------------------


@pytest.mark.parametrize("num_shards", [2])
def test_supervised_heals_one_partition_without_touching_others(
        num_shards, tmp_path):
    from repro.dist.resilience import Fault, FaultInjector
    rng = np.random.default_rng(8)
    base = _cols_from(rng.integers(0, 36, 200), 0)
    fr = IndexedFrame.from_columns(base, SCH, partition_by=_spec(),
                                   num_shards=num_shards,
                                   rows_per_batch=16)
    sup = fr.supervised(lineage=True, checkpoint_dir=str(tmp_path))
    q = rng.integers(0, 36, 21).astype(np.int64)
    c0, v0 = sup.lookup(q, max_matches=32)
    base_traces = sup.retraces
    sup.managers[1].injector = FaultInjector(
        [Fault("shard_loss", step=1, shard=0)])
    sup.lookup(q, max_matches=32)                  # tick 0
    c1, v1 = sup.lookup(q, max_matches=32)         # tick 1: kill + heal
    assert sup.last_report.recovered == (0,)
    assert sup.last_report.answered.all()
    np.testing.assert_array_equal(np.asarray(v1), np.asarray(v0))
    for n in c1:
        np.testing.assert_array_equal(np.asarray(c1[n]),
                                      np.asarray(c0[n]))
    # the other partitions' managers never healed anything
    assert sup.managers[0].stats.recoveries == 0
    assert sup.managers[2].stats.recoveries == 0
    assert sup.managers[1].stats.recoveries == 1
    assert sup.retraces == base_traces             # heal re-enters the cache
    # routed append + retention under supervision
    sup.append(_cols_from([3, 30], 600))
    sup.drop_partition("jan")
    _, v2 = sup.lookup(np.array([3, 30], np.int64), max_matches=32)
    assert not np.asarray(v2)[0].any() and np.asarray(v2)[1].any()


# --- checkpoint round-trip -------------------------------------------------


@pytest.mark.parametrize("num_shards", [1, 2])
def test_save_load_round_trip(num_shards, tmp_path):
    rng = np.random.default_rng(9)
    base = _cols_from(rng.integers(0, 36, 90), 0)
    kw = {} if num_shards == 1 else dict(num_shards=num_shards)
    fr = IndexedFrame.from_columns(base, SCH, partition_by=_spec(),
                                   rows_per_batch=16, **kw)
    fr = fr.append(_cols_from([1, 13, 25], 300))
    fr.save(str(tmp_path / "pt"))
    like = IndexedFrame.from_columns(base, SCH, partition_by=_spec(),
                                     rows_per_batch=16, **kw)
    back = IndexedFrame.load(str(tmp_path / "pt"), like)
    assert int(np.asarray(back.version)) == int(np.asarray(fr.version))
    q = np.arange(36, dtype=np.int64)
    _assert_reads_match(back, fr, q)
