"""Snapshot-as-stored-pytree: jit/vmap round trips, zero in-graph rebuilds,
compile-cache stability (DESIGN.md §3).

PR-1's FlatView was a host-side instance cache, so call sites that took the
table as a jit *argument* unflattened a fresh pytree per trace and rebuilt
the view in-graph every call.  The Snapshot is part of the table's stored
pytree form; these tests pin the three properties that buys:

1. a jitted lookup taking the table as a pytree argument performs ZERO
   in-graph view rebuilds, across appends (construction-counter check);
2. structurally equal tables (divergent same-shape appends) hit the same
   compile-cache entry — no retrace;
3. the same single-partition code runs unchanged over a stacked leading
   shard axis under vmap (the repro.dist execution model).
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import Schema, append, create_index, joins
from repro.core import snapshot as snap_mod

SCH = Schema.of("k", k="int64", v="float32")


def _cols(rng, n, key_range=50, tag=0):
    return {"k": rng.integers(0, key_range, n).astype(np.int64),
            "v": (rng.random(n) + tag).astype(np.float32)}


def _delta(keys):
    keys = np.asarray(keys, np.int64)
    return {"k": keys, "v": np.ones(len(keys), np.float32)}


@pytest.mark.parametrize("layout", ["row", "columnar"])
def test_snapshot_pytree_roundtrip(rng, layout):
    """Table (segments + snapshot) survives tree_flatten/unflatten with
    fused results intact — the snapshot is data, not a host cache."""
    t = create_index(_cols(rng, 300), SCH, rows_per_batch=64, layout=layout)
    t = append(t, _delta([1, 2, 3])).with_flat_data()
    q = np.concatenate([_cols(rng, 30)["k"], [10**9]]).astype(np.int64)

    leaves, treedef = jax.tree_util.tree_flatten(t)
    assert all(isinstance(a, jax.Array) for a in leaves)
    t2 = jax.tree_util.tree_unflatten(treedef, leaves)
    assert t2.snapshot.bucket_counts == t.snapshot.bucket_counts

    c1, v1 = joins.indexed_lookup(t, q, max_matches=8)
    c2, v2 = joins.indexed_lookup(t2, q, max_matches=8)
    cr, vr = joins.indexed_lookup(t, q, max_matches=8, fused=False)
    np.testing.assert_array_equal(np.asarray(v2), np.asarray(vr))
    for name in c1:
        np.testing.assert_array_equal(np.asarray(c2[name]),
                                      np.asarray(cr[name]))


@pytest.mark.parametrize("layout", ["row", "columnar"])
def test_jit_table_arg_matches_ref(rng, layout):
    """Full fused pipeline under jit with the table as a pytree argument,
    against the segment-looped reference."""
    t = create_index(_cols(rng, 400), SCH, rows_per_batch=64,
                     layout=layout).with_flat_data()
    t = append(t, _delta([5, 6, 7, 8]))
    q = np.concatenate([_cols(rng, 40)["k"],
                        [np.iinfo(np.int64).min, 10**9]]).astype(np.int64)

    f = jax.jit(lambda tbl, qq: joins.indexed_lookup(tbl, qq,
                                                     max_matches=6))
    cols_j, valid_j = f(t, q)
    cols_r, valid_r = joins.indexed_lookup(t, q, max_matches=6, fused=False)
    np.testing.assert_array_equal(np.asarray(valid_j), np.asarray(valid_r))
    for name in cols_j:
        np.testing.assert_array_equal(np.asarray(cols_j[name]),
                                      np.asarray(cols_r[name]))


def test_jit_zero_ingraph_rebuilds_across_appends(rng):
    """THE tracing-count regression (ISSUE 2 acceptance): a jitted lookup
    taking the table as a pytree argument must perform zero in-graph
    snapshot rebuilds — across MVCC appends.  Eager host-side construction
    (create/append) bumps the counters; traces and jitted calls must not."""
    t = create_index(_cols(rng, 300), SCH,
                     rows_per_batch=64).with_flat_data()
    versions = [t]
    for i in range(3):
        t = append(t, _delta([i, i + 10, i + 20]))
        versions.append(t)
    q = _cols(rng, 64)["k"]

    f = jax.jit(lambda tbl, qq: joins.indexed_lookup(tbl, qq,
                                                     max_matches=6))
    for tv in versions:
        blocks0 = snap_mod.BLOCK_BUILDS
        data0 = snap_mod.DATA_BUILDS
        cols_j, valid_j = f(tv, q)          # traces (new shapes) + runs
        jax.block_until_ready(valid_j)
        assert snap_mod.BLOCK_BUILDS == blocks0, \
            "jitted lookup rebuilt probe blocks in-graph"
        assert snap_mod.DATA_BUILDS == data0, \
            "jitted lookup rebuilt flat data in-graph"
        cols_r, valid_r = joins.indexed_lookup(tv, q, max_matches=6,
                                               fused=False)
        np.testing.assert_array_equal(np.asarray(valid_j),
                                      np.asarray(valid_r))
        for name in cols_j:
            np.testing.assert_array_equal(np.asarray(cols_j[name]),
                                          np.asarray(cols_r[name]))


def test_compile_cache_arena_append_no_retrace(rng):
    """Arena appends (DESIGN.md §4) change NO pytree structure — children
    and divergent siblings all hit the parent's compile-cache entry."""
    traces = {"n": 0}

    @jax.jit
    def f(tbl, qq):
        traces["n"] += 1                    # bumps only while tracing
        rows, _ = tbl.lookup(qq, 4)
        return rows

    t = create_index(_cols(rng, 300), SCH,
                     rows_per_batch=64).with_flat_data()
    q = _cols(rng, 32)["k"]

    f(t, q)
    assert traces["n"] == 1
    f(t, q)
    assert traces["n"] == 1                 # same table: cached

    t2a = append(t, _delta([1, 2, 3, 4]))
    t2b = append(t, _delta([30, 31, 32, 33]))  # divergent, same shapes
    r_a = f(t2a, q)
    r_b = f(t2b, q)
    f(t2a, q)
    assert traces["n"] == 1                 # zero retraces across appends

    np.testing.assert_array_equal(np.asarray(r_a),
                                  np.asarray(t2a.lookup_ref(q, 4)[0]))
    np.testing.assert_array_equal(np.asarray(r_b),
                                  np.asarray(t2b.lookup_ref(q, 4)[0]))


def test_compile_cache_structurally_equal_append_no_retrace(rng):
    """Segment-chain appends DO grow the pytree (one retrace), but
    divergent same-shape appends stay structurally equal — the second
    sibling must hit the first's compile-cache entry (the PR-2 contract,
    kept on the reference write path)."""
    traces = {"n": 0}

    @jax.jit
    def f(tbl, qq):
        traces["n"] += 1                    # bumps only while tracing
        rows, _ = tbl.lookup(qq, 4)
        return rows

    t = create_index(_cols(rng, 300), SCH, rows_per_batch=64,
                     reserve=0).with_flat_data()
    q = _cols(rng, 32)["k"]

    f(t, q)
    assert traces["n"] == 1
    f(t, q)
    assert traces["n"] == 1                 # same table: cached

    t2a = append(t, _delta([1, 2, 3, 4]), mode="segment")
    t2b = append(t, _delta([30, 31, 32, 33]), mode="segment")
    r_a = f(t2a, q)
    assert traces["n"] == 2                 # new structure: one retrace
    r_b = f(t2b, q)
    assert traces["n"] == 2                 # structurally equal: cache hit
    f(t2a, q)
    assert traces["n"] == 2

    np.testing.assert_array_equal(np.asarray(r_a),
                                  np.asarray(t2a.lookup_ref(q, 4)[0]))
    np.testing.assert_array_equal(np.asarray(r_b),
                                  np.asarray(t2b.lookup_ref(q, 4)[0]))


def test_lookup_cache_independent_of_flat_data(rng):
    """Materializing flat data (gather path) must not retrace the lookup
    cores: the probe path strips ``data`` before entering its jits."""
    t = create_index(_cols(rng, 200), SCH, rows_per_batch=64)
    from repro.kernels import ops
    q = _cols(rng, 16)["k"]
    r1, _ = ops.fused_lookup(q, t.snapshot, max_matches=4)
    td = t.with_flat_data()
    assert td.snapshot.data is not None
    r2, _ = ops.fused_lookup(q, td.snapshot, max_matches=4)
    np.testing.assert_array_equal(np.asarray(r1), np.asarray(r2))
    # the dispatcher's jitted core saw identical (data-stripped) pytrees
    stripped = snap_mod.strip_data(td.snapshot)
    assert stripped.data is None
    assert jax.tree_util.tree_structure(stripped) == \
        jax.tree_util.tree_structure(t.snapshot)


def test_vmap_stacked_tables_match_per_table(rng):
    """The dist execution model: stack two structurally equal tables along
    a leading shard axis and vmap the unchanged lookup — per-shard results
    must equal each table's own."""
    t = create_index(_cols(rng, 300), SCH,
                     rows_per_batch=64).with_flat_data()
    ta = append(t, _delta([1, 2, 3]))
    tb = append(t, _delta([40, 41, 42]))
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), ta, tb)
    q = _cols(rng, 48)["k"]

    rows_s, trunc_s = jax.vmap(lambda tt: tt.lookup(q, 8))(stacked)
    cols_s = jax.vmap(lambda tt: tt.gather_rows(
        jnp.maximum(tt.lookup(q, 8)[0], 0)))(stacked)
    for i, tv in enumerate((ta, tb)):
        rr, tr = tv.lookup_ref(q, 8)
        np.testing.assert_array_equal(np.asarray(rows_s[i]), np.asarray(rr))
        np.testing.assert_array_equal(np.asarray(trunc_s[i]),
                                      np.asarray(tr))
        cr = tv.gather_rows_ref(jnp.maximum(rr, 0))
        for name in cr:
            np.testing.assert_array_equal(np.asarray(cols_s[name][i]),
                                          np.asarray(cr[name]))


def test_indexed_lookup_validation_errors(rng):
    """Satellite: clear ValueError instead of opaque gather shape errors."""
    t = create_index(_cols(rng, 100), SCH, rows_per_batch=64)
    q = np.asarray([1, 2], np.int64)
    with pytest.raises(ValueError, match="max_matches"):
        joins.indexed_lookup(t, q, max_matches=0)
    with pytest.raises(ValueError, match="max_matches"):
        joins.indexed_lookup(t, q, max_matches=-3)
    with pytest.raises(ValueError, match="int64"):
        joins.indexed_lookup(t, q.astype(np.int32), max_matches=4)
    with pytest.raises(ValueError, match="int64"):
        joins.indexed_lookup(t, q.astype(np.float32), max_matches=4)
