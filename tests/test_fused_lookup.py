"""Fused lookup pipeline vs segment-looped reference (DESIGN.md §3).

The fused path (stored Snapshot + one-pass probe/chain-walk/gather) is the
default through joins.indexed_lookup / indexed_join; these sweeps pin it to
the original segment-looped code bit for bit, and pin the Pallas kernel to
the vectorized oracle that stands in for it off-TPU.  Pytree/jit/vmap
properties of the Snapshot live in test_snapshot.py; the distributed layer
built on it in test_dist.py.
"""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import Schema, append, compact, create_index, joins
from repro.core.hashindex import EMPTY_KEY
from repro.kernels import ops

SCH = Schema.of("k", k="int64", v="float32", tag="int32")


def _table(rng, n_base, n_appends, layout, key_range=60, rows_per_batch=64,
           append_rows=37, mode="segment"):
    """``mode="segment"`` (default here) grows one delta segment per
    append — the multi-segment machinery these sweeps exercise;
    ``mode="arena"`` lands appends in the reserved tail (DESIGN.md §4)."""
    cols = {"k": rng.integers(0, key_range, n_base).astype(np.int64),
            "v": rng.random(n_base).astype(np.float32),
            "tag": np.arange(n_base, dtype=np.int32)}
    t = create_index(cols, SCH, rows_per_batch=rows_per_batch, layout=layout,
                     reserve=0 if mode == "segment" else None)
    for i in range(n_appends):
        extra = {"k": rng.integers(0, key_range, append_rows)
                 .astype(np.int64),
                 "v": rng.random(append_rows).astype(np.float32),
                 "tag": np.arange(append_rows, dtype=np.int32)
                 + 1000 * (i + 1)}
        t = append(t, extra, mode=mode)
    return t


def _queries(rng, key_range):
    """Duplicate-heavy present keys + absent keys + the EMPTY sentinel."""
    q = np.concatenate([
        rng.integers(0, key_range, 80),          # present (dup-heavy)
        rng.integers(key_range, 2 * key_range, 15),  # absent
        [np.iinfo(np.int64).min],                # EMPTY sentinel
        [np.iinfo(np.int64).max, -1],            # extreme values
    ])
    return q.astype(np.int64)


@pytest.mark.parametrize("layout", ["row", "columnar"])
@pytest.mark.parametrize("n_appends", [0, 1, 4, 15])
@pytest.mark.parametrize("mode", ["segment", "arena"])
def test_fused_lookup_parity_sweep(rng, layout, n_appends, mode):
    """Fused row ids are bit-identical to the segment-looped reference —
    on the growing segment chain AND on arena tables (whose appends land
    in-place in the reserved tail; compile-cache tests in test_arena.py)."""
    t = _table(rng, 300, n_appends, layout, mode=mode)
    if mode == "segment":
        assert t.num_segments == n_appends + 1
    else:
        assert t.num_segments == 1   # every append fit the reserved tail
    q = _queries(rng, 60)
    for mm in (1, 4, 8):
        rf, tf = t.lookup(q, mm)
        rr, tr = t.lookup_ref(q, mm)
        np.testing.assert_array_equal(np.asarray(rf), np.asarray(rr))
        np.testing.assert_array_equal(np.asarray(tf), np.asarray(tr))


@pytest.mark.parametrize("layout", ["row", "columnar"])
def test_fused_gather_and_probe_parity(rng, layout):
    t = _table(rng, 250, 3, layout)
    q = _queries(rng, 60)
    np.testing.assert_array_equal(np.asarray(t.probe_latest(q)),
                                  np.asarray(t.probe_latest_ref(q)))
    rids, _ = t.lookup(q, 6)
    safe = jnp.maximum(rids, 0)
    gf = t.gather_rows(safe)
    gr = t.gather_rows_ref(safe)
    for name in gf:
        np.testing.assert_array_equal(np.asarray(gf[name]),
                                      np.asarray(gr[name]))
    # gather_prev parity incl. NULL and out-of-range ids
    probe_ids = jnp.asarray([-1, 0, 5, t.capacity - 1, t.capacity, 10**6],
                            jnp.int32)
    np.testing.assert_array_equal(np.asarray(t.gather_prev(probe_ids)),
                                  np.asarray(t.gather_prev_ref(probe_ids)))


def test_fused_truncation_matches_reference(rng):
    """All-equal keys: chains longer than max_matches truncate identically."""
    n = 100
    cols = {"k": np.zeros(n, np.int64),
            "v": rng.random(n).astype(np.float32),
            "tag": np.arange(n, dtype=np.int32)}
    t = create_index(cols, SCH, rows_per_batch=32)
    t = append(t, {"k": np.zeros(8, np.int64),
                   "v": np.ones(8, np.float32),
                   "tag": np.arange(8, dtype=np.int32)})
    q = np.array([0, 1], np.int64)
    for mm in (4, 108, 128):
        rf, tf = t.lookup(q, mm)
        rr, tr = t.lookup_ref(q, mm)
        np.testing.assert_array_equal(np.asarray(rf), np.asarray(rr))
        np.testing.assert_array_equal(np.asarray(tf), np.asarray(tr))
    assert bool(t.lookup(q, 4)[1][0])        # 108 rows > 4 -> truncated
    assert not bool(t.lookup(q, 128)[1][0])  # fits -> not truncated


@pytest.mark.parametrize("layout", ["row", "columnar"])
def test_indexed_join_fused_default_matches_ref(rng, layout):
    t = _table(rng, 400, 2, layout)
    pk = rng.integers(0, 80, 64).astype(np.int64)
    probe_cols = {"pk": pk, "tag": np.arange(64, dtype=np.int32)}
    bf, pf, vf = joins.indexed_join(t, probe_cols, "pk", max_matches=16)
    br, pr, vr = joins.indexed_join(t, probe_cols, "pk", max_matches=16,
                                    fused=False)
    np.testing.assert_array_equal(np.asarray(vf), np.asarray(vr))
    for name in bf:
        np.testing.assert_array_equal(np.asarray(bf[name]),
                                      np.asarray(br[name]))


def test_fused_kernel_matches_oracle_and_reference(rng):
    """Force the Pallas kernel (interpret) — parity with both the oracle
    path and the segment-looped reference."""
    t = _table(rng, 200, 2, "row", key_range=40)
    fv = t.flat_view()
    q = _queries(rng, 40)
    rk, tk = ops.fused_lookup(q, fv, max_matches=5, use_kernel=True,
                              interpret=True)
    ro, to = ops.fused_lookup(q, fv, max_matches=5, use_kernel=False)
    rr, tr = t.lookup_ref(q, 5)
    np.testing.assert_array_equal(np.asarray(rk), np.asarray(ro))
    np.testing.assert_array_equal(np.asarray(tk), np.asarray(to))
    np.testing.assert_array_equal(np.asarray(rk), np.asarray(rr))
    np.testing.assert_array_equal(np.asarray(tk), np.asarray(tr))


def test_snapshot_append_reuses_parent_blocks(rng):
    """Regression: append extends the parent's stored Snapshot — it must
    reuse the parent's per-segment blocks by reference, never rebuild."""
    t = _table(rng, 300, 2, "row")
    fv1 = t.snapshot
    t2 = append(t, {"k": np.array([1, 2], np.int64),
                    "v": np.array([0.5, 0.7], np.float32),
                    "tag": np.array([7, 8], np.int32)}, mode="segment")
    fv2 = t2.snapshot
    assert fv2 is t2.flat_view()
    assert len(fv2.blocks) == len(fv1.blocks) + 1
    for b1, b2 in zip(fv1.blocks, fv2.blocks):
        assert b2 is b1  # shared by reference, never recomputed
    # parent's snapshot is untouched (MVCC: versions coexist)
    assert t.snapshot is fv1
    assert len(fv1.blocks) == t.num_segments


def test_snapshot_eager_probe_side_lazy_data(rng):
    """create_index stores the probe-side Snapshot eagerly; the flat-data
    side stays lazy, and host reads must NOT mutate the pytree structure
    (the lazy cache lives outside the tree; with_flat_data is the only way
    the stored form gains the data leaf)."""
    import jax
    cols = {"k": np.arange(50, dtype=np.int64),
            "v": np.ones(50, np.float32),
            "tag": np.zeros(50, np.int32)}
    t = create_index(cols, SCH, rows_per_batch=32)
    assert t.snapshot is t.flat_view()
    assert len(t.snapshot.blocks) == 1
    assert t.snapshot.data is None              # probe path needs no rows
    treedef_before = jax.tree_util.tree_structure(t)
    t.gather_rows(jnp.asarray([0, 1, 2], jnp.int32))   # first fused decode
    assert t.snapshot.data is None              # read did not mutate the tree
    assert jax.tree_util.tree_structure(t) == treedef_before
    assert getattr(t, "_flatdata", None) is not None   # host cache amortizes
    td = t.with_flat_data()                     # explicit materialization
    assert td is not t and td.snapshot.data is not None
    assert td.with_flat_data() is td            # no-op once materialized


def test_flatview_mixed_bucket_counts(rng):
    """Segments whose delta indexes have different bucket counts keep
    ragged planes; each segment probes modulo its own count."""
    cols = {"k": rng.integers(0, 5000, 3000).astype(np.int64),
            "v": rng.random(3000).astype(np.float32),
            "tag": np.arange(3000, dtype=np.int32)}
    t = create_index(cols, SCH, rows_per_batch=256)
    t = append(t, {"k": rng.integers(0, 5000, 10).astype(np.int64),
                   "v": rng.random(10).astype(np.float32),
                   "tag": np.arange(10, dtype=np.int32)}, mode="segment")
    fv = t.flat_view()
    assert len(set(fv.bucket_counts)) > 1  # genuinely mixed
    q = np.concatenate([cols["k"][:50],
                        rng.integers(5000, 10000, 20)]).astype(np.int64)
    rf, tf = t.lookup(q, 8)
    rr, tr = t.lookup_ref(q, 8)
    np.testing.assert_array_equal(np.asarray(rf), np.asarray(rr))
    np.testing.assert_array_equal(np.asarray(tf), np.asarray(tr))


def test_compact_resets_flatview(rng):
    t = _table(rng, 200, 3, "row", key_range=20)
    t.flat_view()
    tc = compact(t)
    assert tc.num_segments == 1
    q = np.arange(25, dtype=np.int64)
    rf, _ = tc.lookup(q, 32)
    rr, _ = tc.lookup_ref(q, 32)
    np.testing.assert_array_equal(np.asarray(rf), np.asarray(rr))


def test_aggregate_preserves_integer_dtypes():
    """min/max/sum on integer columns must not promote to float."""
    vals = jnp.asarray([5, -3, 7, 2], jnp.int32)
    valid = jnp.asarray([True, False, True, True])
    mn = joins.aggregate(vals, valid, "min")
    mx = joins.aggregate(vals, valid, "max")
    sm = joins.aggregate(vals, valid, "sum")
    assert mn.dtype == jnp.int32 and int(mn) == 2
    assert mx.dtype == jnp.int32 and int(mx) == 7
    # sum may widen for overflow safety but must stay integral
    assert jnp.issubdtype(sm.dtype, jnp.integer) and int(sm) == 14
    # all-invalid: identity values, still the column dtype
    none = jnp.zeros(4, bool)
    assert joins.aggregate(vals, none, "min").dtype == jnp.int32
    i64 = jnp.asarray([2**40, -2**40], jnp.int64)
    v64 = jnp.asarray([True, True])
    assert joins.aggregate(i64, v64, "max").dtype == jnp.int64
    assert int(joins.aggregate(i64, v64, "max")) == 2**40
    # floats unchanged
    f = jnp.asarray([1.5, 2.5], jnp.float32)
    assert joins.aggregate(f, v64, "min").dtype == jnp.float32
    assert float(joins.aggregate(f, jnp.zeros(2, bool), "max")) == -np.inf


def test_interpret_resolution():
    from repro.kernels import runtime
    import jax
    on_tpu = jax.default_backend() == "tpu"
    assert runtime.resolve_interpret(None) == (not on_tpu)
    assert runtime.resolve_interpret(True) is True
    assert runtime.resolve_interpret(False) is False
