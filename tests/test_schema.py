"""Row codec roundtrips (the paper's binary row batches)."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import Schema


def test_roundtrip_all_dtypes(rng):
    sch = Schema.of("a", a="int64", b="int32", c="float32", d="float64")
    cols = {"a": rng.integers(-2**62, 2**62, 50).astype(np.int64),
            "b": rng.integers(-2**31, 2**31 - 1, 50).astype(np.int32),
            "c": rng.standard_normal(50).astype(np.float32),
            "d": rng.standard_normal(50)}
    words = sch.encode_rows(cols)
    assert words.shape == (50, sch.width_words)
    back = sch.decode_rows(words)
    for k in cols:
        np.testing.assert_array_equal(np.asarray(back[k]), cols[k])


def test_partial_decode_and_key(rng):
    sch = Schema.of("k", k="int64", v="float32")
    cols = {"k": np.arange(10, dtype=np.int64) * -7,
            "v": np.ones(10, np.float32)}
    words = sch.encode_rows(cols)
    np.testing.assert_array_equal(np.asarray(sch.key_from_words(words)),
                                  cols["k"])
    only_v = sch.decode_rows(words, names=("v",))
    assert set(only_v) == {"v"}


def test_schema_validation():
    with pytest.raises(AssertionError):
        Schema.of("missing", a="int32")
    with pytest.raises(AssertionError):
        Schema((Schema.of("a", a="int32").columns[0],) * 2, "a")


@settings(max_examples=30, deadline=None)
@given(st.lists(st.integers(min_value=-2**63, max_value=2**63 - 1),
                min_size=1, max_size=64))
def test_property_int64_roundtrip(vals):
    sch = Schema.of("x", x="int64")
    cols = {"x": np.asarray(vals, np.int64)}
    back = sch.decode_rows(sch.encode_rows(cols))
    np.testing.assert_array_equal(np.asarray(back["x"]), cols["x"])


@settings(max_examples=30, deadline=None)
@given(st.lists(st.floats(allow_nan=False, width=32), min_size=1,
                max_size=64))
def test_property_f32_roundtrip(vals):
    sch = Schema.of("x", x="float32")
    cols = {"x": np.asarray(vals, np.float32)}
    back = sch.decode_rows(sch.encode_rows(cols))
    np.testing.assert_array_equal(np.asarray(back["x"]), cols["x"])
