"""Training substrate: optimizer math, schedules, microbatch equivalence,
gradient compression with error feedback."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.models.common import ModelConfig
from repro.train import compress, optim
from repro.train.step import init_params, make_train_step

CFG = ModelConfig(name="t", family="dense", num_layers=2, d_model=32,
                  num_heads=2, num_kv_heads=2, head_dim=16, d_ff=64,
                  vocab_size=64, dtype="float32")


def test_lr_schedule():
    c = optim.AdamWConfig(lr_peak=1e-3, warmup_steps=10, decay_steps=100,
                          lr_min_ratio=0.1)
    assert float(optim.lr_at(c, jnp.asarray(0))) < 2e-4
    assert abs(float(optim.lr_at(c, jnp.asarray(10))) - 1e-3) < 1e-5
    assert abs(float(optim.lr_at(c, jnp.asarray(1000))) - 1e-4) < 1e-6


def test_grad_clip():
    g = {"a": jnp.full((10,), 10.0)}
    clipped, norm = optim.clip_by_global_norm(g, 1.0)
    assert abs(float(norm) - np.sqrt(1000)) < 1e-3
    assert abs(float(optim.global_norm(clipped)) - 1.0) < 1e-5


def test_training_reduces_loss(rng):
    params = init_params(CFG, jax.random.PRNGKey(0))
    ocfg = optim.AdamWConfig(lr_peak=3e-3, warmup_steps=5, decay_steps=200,
                             weight_decay=0.0)
    step = jax.jit(make_train_step(CFG, ocfg, remat="none"))
    opt = optim.init_state(ocfg, params)
    # one fixed batch: loss must drop by a lot when memorizing
    batch = {"tokens": jnp.asarray(rng.integers(1, 64, (4, 16)), jnp.int32)}
    first = None
    for i in range(60):
        params, opt, metrics = step(params, opt, batch)
        first = first if first is not None else float(metrics["loss"])
    assert float(metrics["loss"]) < first * 0.5


def test_microbatch_equivalence(rng):
    params = init_params(CFG, jax.random.PRNGKey(0))
    ocfg = optim.AdamWConfig()
    batch = {"tokens": jnp.asarray(rng.integers(1, 64, (8, 16)), jnp.int32)}
    s1 = make_train_step(CFG, ocfg, microbatches=1, remat="none")
    s4 = make_train_step(CFG, ocfg, microbatches=4, remat="none")
    opt = optim.init_state(ocfg, params)
    p1, _, m1 = s1(params, opt, batch)
    p4, _, m4 = s4(params, opt, batch)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-5)


def test_moment_dtype_bf16_state_size():
    params = init_params(CFG, jax.random.PRNGKey(0))
    s32 = optim.init_state(optim.AdamWConfig(moment_dtype="float32"), params)
    s16 = optim.init_state(optim.AdamWConfig(moment_dtype="bfloat16"),
                           params)
    b32 = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(s32["m"]))
    b16 = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(s16["m"]))
    assert b16 * 2 == b32


def test_compress_roundtrip_small_error(rng):
    x = jnp.asarray(rng.standard_normal(1000) * 5, jnp.float32)
    y = compress.compress_roundtrip(x)
    rel = float(jnp.linalg.norm(x - y) / jnp.linalg.norm(x))
    assert rel < 0.01  # int8 block quant ~ 0.5% rms error


def test_error_feedback_accumulates(rng):
    """Sum of compressed grads + final residual == sum of true grads."""
    grads = [{"w": jnp.asarray(rng.standard_normal(256), jnp.float32)}
             for _ in range(10)]
    res = compress.init_residual(grads[0])
    sent_total = jnp.zeros(256)
    for g in grads:
        sent, res = compress.ef_compress_grads(g, res)
        sent_total = sent_total + sent["w"]
    true_total = sum(g["w"] for g in grads)
    np.testing.assert_allclose(np.asarray(sent_total + res["w"]),
                               np.asarray(true_total), rtol=1e-4, atol=1e-4)


def test_master_weights_update_bf16_params(rng):
    cfg_bf = ModelConfig(**{**CFG.__dict__, "dtype": "bfloat16",
                            "name": "bf"})
    params = init_params(cfg_bf, jax.random.PRNGKey(0))
    ocfg = optim.AdamWConfig(master_weights=True, lr_peak=1e-3,
                             warmup_steps=1, decay_steps=10)
    opt = optim.init_state(ocfg, params)
    assert "master" in opt
    batch = {"tokens": jnp.asarray(
        np.random.default_rng(0).integers(1, 64, (2, 8)), jnp.int32)}
    step = make_train_step(cfg_bf, ocfg, remat="none")
    p2, o2, m = step(params, opt, batch)
    assert np.isfinite(float(m["loss"]))
    # master copy stays f32
    assert all(x.dtype == jnp.float32 for x in jax.tree.leaves(o2["master"]))
