"""The serving path's test wall (ISSUE 8).

The continuous-batching ``QueryEngine`` sits on top of everything the
repo has built — planner-routed reads, the MVCC arena write path, the
device-resident append ring, supervised recovery — so its contract is
checked against all of them:

* pad-to-bucket batched answers bit-identical to per-request
  ``frame.lookup`` / ``frame.join``, including every bucket boundary
  (1, B-1, B, B+1, ladder max), all-miss batches, and duplicate keys
  (explicit cases + a hypothesis property sweep);
* strict FIFO head-run batching (never reorders past an incompatible
  request);
* the one-version-bump MVCC interleaving contract: reads ride the
  pre-flush snapshot, a flush lands the whole ring as ONE version, and
  ``replay_unbatched`` proves the engine's answers equal an unbatched
  twin replaying ``write_log`` at the recorded versions;
* zero retraces after warmup: traces == distinct (site, bucket) pairs;
* both backends (vmap in-process, shard_map in-process on >=8 devices
  else via the forced-8 subprocess), forced-routed with pad sentinels
  through the exchange, and supervised serving mid-heal.
"""

import os
import subprocess
import sys

import numpy as np
import jax
import pytest
from hypothesis import given, settings, strategies as st

from repro import IndexedFrame
from repro.core import Schema
from repro.dist import mesh
from repro.serving.query_engine import (PAD_KEY, QueryEngine, bucket_ladder,
                                        pad_keys, pick_bucket,
                                        replay_unbatched)

NDEV = len(jax.devices())
SCH = Schema.of("k", k="int64", v="float32")
N = 512


def _cols(rng, n=N):
    return {"k": np.arange(n, dtype=np.int64),
            "v": rng.random(n).astype(np.float32)}


def _frame(rng, **kw):
    return IndexedFrame.from_columns(_cols(rng), SCH, rows_per_batch=128,
                                     reserve=2048, **kw)


def _twin_frames(rng, **kw):
    """A (reference, engine-owned) pair built from the SAME columns."""
    cols = _cols(rng)
    mk = lambda: IndexedFrame.from_columns(cols, SCH, rows_per_batch=128,
                                           reserve=2048, **kw)
    return mk(), mk()


def _assert_req_equals_direct(req, frame):
    """One request's engine answer == the un-padded facade call."""
    cols, valid = frame.lookup(req.keys, max_matches=req.max_matches)
    np.testing.assert_array_equal(req.result[1], np.asarray(valid))
    for c in cols:
        np.testing.assert_array_equal(req.result[0][c], np.asarray(cols[c]))


# -- units -------------------------------------------------------------------


def test_bucket_ladder_and_pick():
    assert bucket_ladder(64, min_bucket=8) == (8, 16, 32, 64)
    assert bucket_ladder(60, min_bucket=5) == (8, 16, 32, 64)
    lad = bucket_ladder(64, min_bucket=8)
    assert pick_bucket(1, lad) == 8
    assert pick_bucket(8, lad) == 8
    assert pick_bucket(9, lad) == 16
    assert pick_bucket(64, lad) == 64
    with pytest.raises(ValueError):
        pick_bucket(65, lad)
    with pytest.raises(ValueError):
        bucket_ladder(4, min_bucket=8)


def test_pad_keys_sentinel():
    out = pad_keys(np.asarray([3, 1, 2], np.int64), 8)
    np.testing.assert_array_equal(out[:3], [3, 1, 2])
    assert (out[3:] == PAD_KEY).all() and out.dtype == np.int64
    # the sentinel is the reserved EMPTY slot marker: a guaranteed miss
    from repro.core.hashindex import EMPTY_KEY
    assert PAD_KEY == int(np.asarray(EMPTY_KEY))


def test_admission_validation(rng):
    eng = QueryEngine(_frame(rng), ladder=(8, 16))
    with pytest.raises(ValueError):
        eng.submit_lookup(np.zeros(0, np.int64))          # empty
    with pytest.raises(ValueError):
        eng.submit_lookup(np.zeros(17, np.int64))         # > ladder max
    with pytest.raises(ValueError):
        eng.submit_lookup(np.zeros(4, np.float32))        # non-integer keys
    with pytest.raises(ValueError):
        QueryEngine(_frame(rng), ladder=(16, 8))          # not increasing


# -- batched == unbatched, bit-identical -------------------------------------


def test_bucket_boundaries_bit_identical(rng):
    """Every boundary size, all-miss, and duplicate keys: the padded
    batch answer equals the per-request unbatched facade call."""
    frame, owned = _twin_frames(rng)
    eng = QueryEngine(owned, ladder=(8, 16, 32), max_matches=4)
    sizes = [1, 7, 8, 9, 16, 32]                  # 1, B-1, B, B+1, ladder max
    reqs = []
    for s in sizes:
        reqs.append(eng.submit_lookup(
            rng.integers(0, N, size=s).astype(np.int64)))
        eng.tick()                                # one batch per tick
    # all-miss batch (every key absent) and duplicates within one batch
    reqs.append(eng.submit_lookup(np.full(5, N + 999, np.int64)))
    eng.tick()
    reqs.append(eng.submit_lookup(np.asarray([7, 7, 7, 3, 7], np.int64)))
    # a key equal to the pad sentinel itself: a guaranteed miss, not a crash
    reqs.append(eng.submit_lookup(np.asarray([PAD_KEY, 3], np.int64)))
    eng.drain()
    for r in reqs:
        assert r.done and r.bucket in (8, 16, 32)
        _assert_req_equals_direct(r, frame)
    assert eng.zero_retraces_after_warmup


@settings(max_examples=15, deadline=None)
@given(st.lists(st.lists(st.integers(min_value=-3, max_value=N + 3),
                         min_size=1, max_size=32),
                min_size=1, max_size=6))
def test_property_batched_equals_unbatched(key_lists):
    """Hypothesis sweep: arbitrary request mixes (hits, misses, negative
    keys, duplicates, any size <= ladder max) answered through the
    engine == per-request ``frame.lookup``, bit-identical in order."""
    rng = np.random.default_rng(0)
    frame, owned = _twin_frames(rng)
    eng = QueryEngine(owned, ladder=(8, 16, 32), max_matches=4)
    reqs = [eng.submit_lookup(np.asarray(ks, np.int64)) for ks in key_lists]
    eng.drain()
    for r in reqs:
        _assert_req_equals_direct(r, frame)


def test_fifo_head_run_batching(rng):
    """Compatible neighbours coalesce into ONE padded batch; an
    incompatible request (different max_matches) breaks the run and is
    NEVER reordered past."""
    eng = QueryEngine(_frame(rng), ladder=(8, 16, 32), max_matches=4)
    a = eng.submit_lookup(rng.integers(0, N, 3).astype(np.int64))
    b = eng.submit_lookup(rng.integers(0, N, 5).astype(np.int64))
    c = eng.submit_lookup(rng.integers(0, N, 2).astype(np.int64),
                          max_matches=2)          # incompatible: new batch
    d = eng.submit_lookup(rng.integers(0, N, 4).astype(np.int64),
                          max_matches=2)
    eng.tick()
    assert eng.stats.batches == 2
    assert a.bucket == b.bucket == 8              # 3 + 5 -> one bucket-8 batch
    assert c.bucket == d.bucket == 8
    assert a.t_done <= c.t_done                   # FIFO order preserved
    # ladder-max bound: head run stops before overflowing the top bucket
    e = eng.submit_lookup(rng.integers(0, N, 20).astype(np.int64))
    f = eng.submit_lookup(rng.integers(0, N, 20).astype(np.int64))
    eng.tick()
    assert eng.stats.batches == 4                 # 20 + 20 > 32: two batches
    assert e.bucket == f.bucket == 32


# -- MVCC interleaving --------------------------------------------------------


def test_reads_ride_preflush_snapshot(rng):
    """A delta admitted in tick t is invisible to tick-t reads (staged in
    the ring), visible after the deadline flush — ONE version bump for
    the whole ring, host mirror exact."""
    eng = QueryEngine(_frame(rng), ladder=(8,), max_matches=4,
                      flush_deadline_ticks=2)
    v0 = eng.version_host
    new_key = np.asarray([N + 1], np.int64)
    w = eng.submit_append({"k": new_key, "v": np.asarray([1.5], np.float32)})
    r1 = eng.submit_lookup(new_key)
    eng.tick()                                    # reads first, then staging
    assert not r1.result[1].any() and r1.version == v0
    assert eng.staged_writes == 1 and w.t_visible is None
    r2 = eng.submit_lookup(new_key)
    eng.tick()                                    # tick 2: deadline flush
    assert not r2.result[1].any()                 # still pre-flush snapshot
    assert w.t_visible is not None and w.version == v0 + 1
    r3 = eng.submit_lookup(new_key)
    eng.tick()
    assert r3.result[1][0, 0] and r3.version == v0 + 1
    assert eng.stats.flushes == 1 and eng.verify_version()


def test_ring_full_autoflush_and_oversize_bypass(rng):
    """A full ring flushes mid-tick and the delta retries; a delta too
    big for any lane lands through the direct coalesced append."""
    eng = QueryEngine(_frame(rng), ladder=(8,), queue_lanes=2,
                      queue_lane_rows=4, flush_deadline_ticks=100)
    for i in range(5):                            # 5 deltas, 2 lanes
        eng.submit_append({"k": np.asarray([N + i], np.int64),
                           "v": np.asarray([float(i)], np.float32)})
    big = eng.submit_append(
        {"k": np.arange(N + 10, N + 30, dtype=np.int64),
         "v": np.zeros(20, np.float32)})          # 20 rows > lane_rows=4
    eng.tick()
    assert eng.stats.direct_appends == 1 and big.t_visible is not None
    assert eng.stats.flushes >= 2                 # ring-full auto-flushes
    eng.drain()
    r = eng.submit_lookup(np.asarray([N, N + 4, N + 15], np.int64))
    eng.drain()
    assert r.result[1][:, 0].all()                # every delta landed
    assert eng.verify_version()


def test_write_log_twin_replay(rng):
    """The committed bit-identity claim: a mixed read/write run replayed
    unbatched on a twin at the recorded versions -> zero mismatches."""
    frame0, owned = _twin_frames(rng)
    eng = QueryEngine(owned, ladder=(8, 16), max_matches=4,
                      flush_deadline_ticks=2)
    reqs = []
    for step in range(8):
        reqs.append(eng.submit_lookup(
            rng.integers(-3, N + 20, size=int(rng.integers(1, 16)))
            .astype(np.int64)))
        eng.submit_append({"k": np.asarray([N + step], np.int64),
                           "v": np.asarray([float(step)], np.float32)})
        eng.tick()
    eng.drain()
    assert eng.stats.flushes >= 2                 # interleaving actually ran
    assert replay_unbatched(frame0, reqs, eng.write_log) == 0


# -- joins --------------------------------------------------------------------


def test_join_batching_parity(rng):
    frame, owned = _twin_frames(rng)
    eng = QueryEngine(owned, ladder=(8, 16), max_matches=4)
    reqs = []
    for s in (1, 5, 8, 9):
        pc = {"k": rng.integers(0, N, s).astype(np.int64),
              "p": rng.random(s).astype(np.float32)}
        reqs.append(eng.submit_join(pc, "k"))
    eng.drain()
    for r in reqs:
        bcols, pcols, valid = frame.join(r.probe_cols, "k",
                                         max_matches=r.max_matches)
        np.testing.assert_array_equal(r.result[2], np.asarray(valid))
        for c in bcols:
            np.testing.assert_array_equal(r.result[0][c],
                                          np.asarray(bcols[c]))
        for c in pcols:
            np.testing.assert_array_equal(r.result[1][c],
                                          np.asarray(pcols[c]))
    assert eng.zero_retraces_after_warmup


# -- zero retraces ------------------------------------------------------------


def test_zero_retraces_across_ladder_and_writes(rng):
    """Two full passes over the ladder with appends interleaved: traces
    == distinct (site, bucket) pairs, pass 2 adds ZERO."""
    eng = QueryEngine(_frame(rng), ladder=(8, 16, 32), max_matches=4,
                      flush_deadline_ticks=1)
    for pas in range(2):
        for s in (1, 8, 9, 16, 17, 32):
            eng.submit_lookup(rng.integers(0, N, s).astype(np.int64))
            eng.submit_append({"k": np.asarray([N + s + 100 * pas], np.int64),
                               "v": np.asarray([0.0], np.float32)})
            eng.tick()
        if pas == 0:
            warm = eng.retraces
            assert warm == eng.expected_traces == 3     # one per bucket
    assert eng.retraces == warm                          # pass 2: zero new
    assert eng.zero_retraces_after_warmup


# -- distributed + supervised -------------------------------------------------


def test_dist_vmap_engine_parity(rng):
    rt = mesh.vmap_runtime()
    for op in ("auto", "routed"):
        frame0, owned = _twin_frames(rng, num_shards=4, rt=rt)
        eng = QueryEngine(owned, ladder=(8, 16), max_matches=4, op=op,
                          flush_deadline_ticks=2)
        reqs = []
        for step in range(4):
            reqs.append(eng.submit_lookup(
                rng.integers(-3, N + 9, size=int(rng.integers(1, 16)))
                .astype(np.int64)))
            eng.submit_append({"k": np.asarray([N + step], np.int64),
                               "v": np.asarray([float(step)], np.float32)})
            eng.tick()
        eng.drain()
        assert replay_unbatched(frame0, reqs, eng.write_log, op=op) == 0
        assert eng.zero_retraces_after_warmup, op


def test_supervised_serve_through_heal(rng, tmp_path):
    """The engine serves traffic across a shard kill + automatic heal:
    one recovery, no dead shards, answers == the unbatched twin."""
    from repro.dist.resilience import Fault, FaultInjector, RecoveryPolicy
    from repro.dist.runtime import Lineage
    rt = mesh.vmap_runtime()
    cols = _cols(np.random.default_rng(0))
    twin = IndexedFrame.from_columns(cols, SCH, num_shards=4,
                                     rows_per_batch=128, rt=rt)
    mgr = IndexedFrame.from_columns(cols, SCH, num_shards=4,
                                    rows_per_batch=128, rt=rt).supervised(
        lineage=Lineage(SCH, cols, rows_per_batch=128),
        injector=FaultInjector([Fault("shard_loss", step=3, shard=3)],
                               seed=7),
        policy=RecoveryPolicy(checkpoint_every=2),
        checkpoint_dir=str(tmp_path))
    eng = QueryEngine(mgr, ladder=(8, 16), max_matches=4,
                      flush_deadline_ticks=2)
    assert eng.supervised and eng.frame is mgr.frame
    reqs = []
    for step in range(6):
        reqs.append(eng.submit_lookup(
            rng.integers(0, N, size=5).astype(np.int64)))
        eng.submit_append({"k": np.asarray([N + step], np.int64),
                           "v": np.asarray([float(step)], np.float32)})
        eng.tick()
    eng.drain()
    assert mgr.stats.recoveries == 1 and not mgr.dead
    assert replay_unbatched(twin, reqs, eng.write_log) == 0
    assert eng.verify_version()


def test_frame_serve_entrypoint(rng):
    """``frame.serve(...)`` is the facade door to the engine."""
    eng = _frame(rng).serve(ladder=(8,), max_matches=4)
    r = eng.submit_lookup(np.asarray([3], np.int64))
    eng.drain()
    assert isinstance(eng, QueryEngine) and r.done
    assert r.result[1][0, 0]                       # key 3 exists


# -- forced-8 shard_map topology ---------------------------------------------

_SUBPROCESS_SERVE = """
import numpy as np, jax
from repro import IndexedFrame
from repro.core import Schema
from repro.dist import mesh
from repro.serving.query_engine import QueryEngine, replay_unbatched
assert len(jax.devices()) == 8, jax.devices()
SCH = Schema.of("k", k="int64", v="float32")
rng = np.random.default_rng(5)
N = 1024
cols = {"k": np.arange(N, dtype=np.int64),
        "v": rng.random(N).astype(np.float32)}
rt = mesh.mesh_runtime(8)
frame0 = IndexedFrame.from_columns(cols, SCH, num_shards=8,
                                   rows_per_batch=128, rt=rt)
eng = QueryEngine(
    IndexedFrame.from_columns(cols, SCH, num_shards=8, rows_per_batch=128,
                              rt=rt),
    ladder=(8, 16, 32), max_matches=4, flush_deadline_ticks=2)
reqs = []
for step in range(6):
    for s in (1, 8, 9, 32):
        reqs.append(eng.submit_lookup(
            rng.integers(-3, N + 9, size=s).astype(np.int64)))
    eng.submit_append({"k": np.asarray([N + step], np.int64),
                       "v": np.asarray([float(step)], np.float32)})
    eng.tick()
eng.drain()
assert replay_unbatched(frame0, reqs, eng.write_log) == 0
assert eng.zero_retraces_after_warmup, (eng.retraces, eng.expected_traces)
assert eng.verify_version()
print("SERVE_8DEV_OK")
"""


@pytest.mark.skipif(NDEV < 8, reason="needs 8 devices (ci.sh forced-8 "
                    "pass; the subprocess test covers single-device runs)")
def test_serve_shard_map_in_process():
    exec(compile(_SUBPROCESS_SERVE, "<serve-8dev>", "exec"), {})


@pytest.mark.skipif(NDEV >= 8, reason="in-process test runs on this "
                    "topology")
def test_serve_shard_map_subprocess():
    """Engine bit-identity + zero retraces on the real shard_map backend
    under a forced 8-device host topology."""
    import repro
    src = os.path.dirname(os.path.dirname(os.path.abspath(repro.__file__)))
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=8").strip()
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", _SUBPROCESS_SERVE],
                          capture_output=True, text=True, env=env,
                          timeout=600)
    assert proc.returncode == 0, proc.stdout + "\n" + proc.stderr
    assert "SERVE_8DEV_OK" in proc.stdout
