"""IndexedFrame facade parity (ISSUE 5): every facade method must be
bit-identical to the free-function path it dispatches to, on both
backends — the facade is a seam, not a reimplementation.

Covers: planner-driven physical-operator selection (rules L1-L3/J1-J3
named by ``explain()``), lookup/join parity local + distributed (vmap
in-process; shard_map in-process on >=8 devices, else via a forced-8
subprocess), MVCC divergent versions through the facade, coalesced
list-append ≡ sequential appends (one version bump, one ingest),
relational plans, save/load/reshard, the unified input validation, and
zero retraces for jitted sites taking the frame as an argument.
"""

import os
import subprocess
import sys

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import IndexedFrame
from repro.core import Schema, append, coalesce_deltas, create_index, joins
from repro.core.planner import Col, Eq, Filter, Lit, Planner
from repro import dist
from repro.dist import mesh

NDEV = len(jax.devices())
SCH = Schema.of("k", k="int64", v="float32", tag="int32")


def _cols(rng, n=400, key_range=50):
    return {"k": rng.integers(0, key_range, n).astype(np.int64),
            "v": rng.random(n).astype(np.float32),
            "tag": rng.integers(0, 9, n).astype(np.int32)}


def _delta(rng, n=16, key_range=50):
    return {"k": rng.integers(0, key_range, n).astype(np.int64),
            "v": rng.random(n).astype(np.float32),
            "tag": rng.integers(0, 9, n).astype(np.int32)}


def _assert_cols_equal(a, b):
    assert set(a) == set(b)
    for k in a:
        np.testing.assert_array_equal(np.asarray(a[k]), np.asarray(b[k]), k)


@pytest.fixture
def local(rng):
    cols = _cols(rng)
    return cols, IndexedFrame.from_columns(cols, SCH, rows_per_batch=64)


@pytest.fixture
def dframe(rng):
    cols = _cols(rng)
    return cols, IndexedFrame.from_columns(cols, SCH, num_shards=4,
                                           rows_per_batch=64)


# --- lookup parity ---------------------------------------------------------

def test_local_lookup_matches_free_function(local, rng):
    cols, fr = local
    q = np.concatenate([rng.choice(cols["k"], 24), [10**12]]).astype(np.int64)
    fc, fv = fr.lookup(q, max_matches=8)
    t = create_index(cols, SCH, rows_per_batch=64)
    gc, gv = joins.indexed_lookup(t, q, max_matches=8)
    np.testing.assert_array_equal(np.asarray(fv), np.asarray(gv))
    _assert_cols_equal(fc, gc)


def test_dist_lookup_bcast_matches_free_function(dframe, rng):
    cols, fr = dframe
    q = np.concatenate([rng.choice(cols["k"], 24), [10**12]]).astype(np.int64)
    assert fr.plan_lookup(q).kind == "BroadcastLookup"
    fc, fv = fr.lookup(q, max_matches=8)
    gc, gv, _ = dist.lookup(fr.data, q, max_matches=8)
    np.testing.assert_array_equal(np.asarray(fv), np.asarray(gv))
    _assert_cols_equal(fc, gc)


def test_dist_lookup_routed_matches_bcast_bitwise(dframe, rng):
    """The routed flavor answers every query identically to broadcast
    (including the word-packed float payload, bit-exact)."""
    cols, fr = dframe
    q = np.concatenate([rng.choice(cols["k"], 30),
                        [10**12, -7]]).astype(np.int64)
    bc, bv = fr.lookup(q, max_matches=8, op="bcast")
    rc, rv = fr.lookup(q, max_matches=8, op="routed")
    np.testing.assert_array_equal(np.asarray(rv), np.asarray(bv))
    _assert_cols_equal(rc, bc)


def test_dist_lookup_routed_matches_free_function(dframe, rng):
    """Facade routed ≡ dist.lookup_routed with the same source split."""
    cols, fr = dframe
    q = rng.choice(cols["k"], 32).astype(np.int64)
    fc, fv = fr.lookup(q, max_matches=8, op="routed")
    gc, gv, answered, dropped = dist.lookup_routed(
        fr.data, q.reshape(4, 8), max_matches=8)
    assert int(np.asarray(dropped).sum()) == 0
    assert bool(np.asarray(answered).all())
    np.testing.assert_array_equal(np.asarray(fv),
                                  np.asarray(gv).reshape(32, 8))
    for k in fc:
        np.testing.assert_array_equal(
            np.asarray(fc[k]), np.asarray(gc[k]).reshape(32, 8), k)


def test_lookup_ragged_batch_routed(dframe, rng):
    """Q not divisible by num_shards: the flat adapter pads with invalid
    lanes and trims the answers back to input order."""
    cols, fr = dframe
    q = rng.choice(cols["k"], 13).astype(np.int64)
    bc, bv = fr.lookup(q, max_matches=8, op="bcast")
    rc, rv = fr.lookup(q, max_matches=8, op="routed")
    np.testing.assert_array_equal(np.asarray(rv), np.asarray(bv))
    _assert_cols_equal(rc, bc)


# --- planner physical selection --------------------------------------------

def test_planner_selects_lookup_flavor_by_volume(dframe):
    cols, fr = dframe
    small = np.zeros(16, np.int64)
    big = np.zeros(4096, np.int64)
    p_small = fr.plan_lookup(small)
    p_big = fr.plan_lookup(big)
    assert p_small.kind == "BroadcastLookup" and "L2" in p_small.reason
    assert p_big.kind == "RoutedLookup" and "L3" in p_big.reason
    # the threshold is a Planner knob, not a constant
    p = Planner(routed_threshold=8)
    assert fr.plan_lookup(small, planner=p).kind == "RoutedLookup"


def test_planner_selects_join_flavor_by_probe_rows(dframe):
    cols, fr = dframe
    pc = {"k": np.zeros(32, np.int64)}
    p_small = fr.plan_join(pc, "k")
    assert p_small.kind == "BroadcastJoin" and "J2" in p_small.reason
    p = Planner(bcast_threshold=8)
    p_big = fr.plan_join(pc, "k", planner=p)
    assert p_big.kind == "ShuffleJoin" and "J3" in p_big.reason


def test_planner_local_rules(local):
    cols, fr = local
    q = np.zeros(10**7, np.int64)[:0]  # shape only matters
    pl = fr.plan_lookup(np.zeros(8, np.int64))
    assert pl.kind == "IndexedLookup" and "L1" in pl.reason
    pj = fr.plan_join({"k": np.zeros(8, np.int64)}, "k")
    assert pj.kind == "IndexedJoin" and "J1" in pj.reason


def test_choose_helpers_delegate_to_planner():
    """The legacy dist.choose_* helpers and the Planner rules must never
    disagree (the cost model lives in ONE place now)."""
    class D:
        num_shards = 8
    p = Planner()
    for q in (1, 64, 4095, 4096, 10**6):
        assert dist.choose_lookup(D(), q) == p.lookup_flavor(8, q)[0]
    for r in (1, 10**6, 10**6 + 1, 10**8):
        assert dist.choose_join(D(), r) == p.join_flavor(r)[0]


def test_forced_op_validation(local, dframe):
    _, fr = local
    _, df = dframe
    q = np.zeros(4, np.int64)
    with pytest.raises(ValueError):
        fr.lookup(q, op="routed")        # nothing to route on 1 shard
    with pytest.raises(ValueError):
        df.lookup(q, op="local")
    with pytest.raises(ValueError):
        df.lookup(q, op="sideways")


# --- join parity ------------------------------------------------------------

def test_local_join_matches_free_function(local, rng):
    cols, fr = local
    pc = {"k": rng.choice(cols["k"], 40).astype(np.int64),
          "ev": np.arange(40, dtype=np.int32)}
    fb, fp, fv = fr.join(pc, "k", max_matches=8)
    t = create_index(cols, SCH, rows_per_batch=64)
    gb, gp, gv = joins.indexed_join(t, pc, "k", max_matches=8)
    np.testing.assert_array_equal(np.asarray(fv), np.asarray(gv))
    _assert_cols_equal(fb, gb)
    _assert_cols_equal(fp, gp)


def test_dist_join_bcast_matches_free_function(dframe, rng):
    cols, fr = dframe
    pc = {"k": rng.choice(cols["k"], 40).astype(np.int64),
          "ev": np.arange(40, dtype=np.int32)}
    fb, fp, fv = fr.join(pc, "k", max_matches=8)
    gb, gp, gv = dist.indexed_join_bcast(fr.data, pc, "k", 8)
    np.testing.assert_array_equal(np.asarray(fv), np.asarray(gv))
    _assert_cols_equal(fb, gb)
    _assert_cols_equal(fp, gp)


def test_dist_join_shuffle_matches_bcast(dframe, rng):
    """The shuffle flavor (routed exchange, flat contract) returns the
    same rows in the same probe order as broadcast."""
    cols, fr = dframe
    pc = {"k": np.concatenate([rng.choice(cols["k"], 39),
                               [10**12]]).astype(np.int64),
          "ev": np.arange(40, dtype=np.int32)}
    bb, bp, bv = fr.join(pc, "k", max_matches=8, op="bcast")
    sb, sp, sv = fr.join(pc, "k", max_matches=8, op="shuffle")
    np.testing.assert_array_equal(np.asarray(sv), np.asarray(bv))
    _assert_cols_equal(sb, bb)
    _assert_cols_equal(sp, bp)


def test_join_local_vs_dist_same_semantics(local, dframe, rng):
    cols_l, fr = local
    cols_d, df = dframe
    # same data in both frames -> same multiset of join matches
    pc = {"k": rng.choice(cols_l["k"], 24).astype(np.int64)}
    fd = IndexedFrame.from_columns(cols_l, SCH, num_shards=4,
                                   rows_per_batch=64)
    lb, _, lv = fr.join(pc, "k", max_matches=16)
    db, _, dv = fd.join(pc, "k", max_matches=16)
    assert int(np.asarray(lv).sum()) == int(np.asarray(dv).sum())
    np.testing.assert_array_equal(
        np.sort(np.asarray(lb["v"])[np.asarray(lv)]),
        np.sort(np.asarray(db["v"])[np.asarray(dv)]))


# --- appends: MVCC + coalescing --------------------------------------------

def test_append_matches_free_function(local, rng):
    cols, fr = local
    d = _delta(rng)
    fr2 = fr.append(d)
    t2 = append(create_index(cols, SCH, rows_per_batch=64), d)
    q = np.unique(np.concatenate([d["k"], cols["k"][:8]]))
    fc, fv = fr2.lookup(q, max_matches=16)
    gc, gv = joins.indexed_lookup(t2, q, max_matches=16)
    np.testing.assert_array_equal(np.asarray(fv), np.asarray(gv))
    _assert_cols_equal(fc, gc)


@pytest.mark.parametrize("num_shards", [1, 4])
def test_append_list_coalesces_to_one_version(rng, num_shards):
    cols = _cols(rng)
    fr = IndexedFrame.from_columns(cols, SCH, num_shards=num_shards,
                                   rows_per_batch=64)
    deltas = [_delta(rng, n) for n in (16, 5, 32)]
    seq = fr
    for d in deltas:
        seq = seq.append(d)
    batched = fr.append(deltas)
    # one fused ingest -> ONE version bump; sequential bumped three times
    v0 = int(np.asarray(fr.version).ravel()[0])
    assert int(np.asarray(batched.version).ravel()[0]) == v0 + 1
    assert int(np.asarray(seq.version).ravel()[0]) == v0 + 3
    # ...but decoded answers are bit-identical (chain order preserved)
    q = np.unique(np.concatenate([d["k"] for d in deltas]))
    sc, sv = seq.lookup(q, max_matches=32)
    bc, bv = batched.lookup(q, max_matches=32)
    np.testing.assert_array_equal(np.asarray(bv), np.asarray(sv))
    _assert_cols_equal(bc, sc)


def test_coalesce_deltas_valid_masks(rng):
    d1, d2 = _delta(rng, 6), _delta(rng, 4)
    v2 = np.asarray([True, False, True, False])
    cols, valid = coalesce_deltas([d1, d2], SCH, [None, v2])
    assert valid.shape == (10,)
    assert valid[:6].all() and np.array_equal(valid[6:], v2)
    with pytest.raises(ValueError):
        coalesce_deltas([], SCH)
    with pytest.raises(ValueError):
        coalesce_deltas([d1, d2], SCH, [None])


def test_mvcc_divergent_versions_through_facade(local, rng):
    cols, fr = local
    key = int(cols["k"][0])
    da = {"k": np.asarray([key], np.int64),
          "v": np.asarray([111.0], np.float32),
          "tag": np.asarray([1], np.int32)}
    db = {"k": np.asarray([key], np.int64),
          "v": np.asarray([222.0], np.float32),
          "tag": np.asarray([2], np.int32)}
    child_a, child_b = fr.append(da), fr.append(db)
    q = np.asarray([key], np.int64)
    base_n = int(np.asarray(fr.lookup(q, max_matches=32)[1]).sum())
    ca, va = child_a.lookup(q, max_matches=32)
    cb, vb = child_b.lookup(q, max_matches=32)
    # parent unchanged, children diverge (paper Listing 2)
    assert int(np.asarray(fr.lookup(q, max_matches=32)[1]).sum()) == base_n
    assert int(np.asarray(va).sum()) == base_n + 1
    assert float(np.asarray(ca["v"])[0, 0]) == 111.0
    assert float(np.asarray(cb["v"])[0, 0]) == 222.0


def test_compact_preserves_lookups(dframe, rng):
    cols, fr = dframe
    fr2 = fr.append([_delta(rng), _delta(rng)])
    q = rng.choice(cols["k"], 16).astype(np.int64)
    before = fr2.lookup(q, max_matches=16)
    after = fr2.compact().lookup(q, max_matches=16)
    np.testing.assert_array_equal(np.asarray(after[1]),
                                  np.asarray(before[1]))
    _assert_cols_equal(after[0], before[0])


# --- relational plans -------------------------------------------------------

@pytest.mark.parametrize("num_shards", [1, 4])
def test_filter_execute_matches_lookup(rng, num_shards):
    cols = _cols(rng)
    fr = IndexedFrame.from_columns(cols, SCH, num_shards=num_shards,
                                   rows_per_batch=64)
    key = int(cols["k"][0])
    plan = fr.filter(Eq(Col("k"), Lit(key)),
                     planner=Planner(max_matches=128))
    txt = plan.explain()
    assert "R1" in txt
    if num_shards > 1:
        assert "BroadcastLookup" in txt and "L2" in txt
    else:
        assert "IndexedLookup" in txt
    rows, valid = plan.execute()
    exp = np.sort(cols["v"][cols["k"] == key])
    np.testing.assert_allclose(
        np.sort(np.asarray(rows["v"])[np.asarray(valid)]), exp)


def test_join_plan_sees_through_wrapped_probe(dframe):
    """J2/J3 uses the probe subtree's source cardinality even when the
    probe side is wrapped in Filter/Project (not a bare Relation)."""
    from repro.core.planner import Join, Project, Relation
    _, df = dframe
    probe = Relation("p", cols={"k": np.zeros(64, np.int64)})
    wrapped = Project(probe, ("k",))
    phys = Planner(bcast_threshold=32).plan(
        Join(df.relation(), wrapped, on="k"))
    assert phys.kind == "ShuffleJoin"
    assert "probe_rows=64" in phys.reason


def test_agg_and_join_plans(local, dframe, rng):
    cols, fr = local
    key = int(cols["k"][0])
    got = fr.filter(Eq(Col("k"), Lit(key)),
                    planner=Planner(max_matches=128)).agg("count",
                                                          "v").execute()
    assert int(got) == int(np.sum(cols["k"] == key))
    _, df = dframe
    # join plan through the relation tree names the dist flavor
    from repro.core.planner import Join, Relation
    probe = Relation("p", cols={"k": np.arange(5, dtype=np.int64)})
    phys = Planner().plan(Join(df.relation(), probe, on="k"))
    assert phys.kind == "BroadcastJoin"
    assert "R2" in phys.reason and "J2" in phys.reason


# --- persistence / elasticity ----------------------------------------------

@pytest.mark.parametrize("num_shards", [1, 4])
def test_save_load_roundtrip(rng, tmp_path, num_shards):
    cols = _cols(rng)
    fr = IndexedFrame.from_columns(cols, SCH, num_shards=num_shards,
                                   rows_per_batch=64).append(_delta(rng))
    path = str(tmp_path / "ckpt")
    fr.save(path)
    fr2 = IndexedFrame.load(path, fr)
    q = rng.choice(cols["k"], 16).astype(np.int64)
    a, b = fr.lookup(q, max_matches=8), fr2.lookup(q, max_matches=8)
    np.testing.assert_array_equal(np.asarray(b[1]), np.asarray(a[1]))
    _assert_cols_equal(b[0], a[0])
    v1 = np.asarray(fr.version).ravel()[0]
    assert int(np.asarray(fr2.version).ravel()[0]) == int(v1)


def test_load_rejects_wrong_backend(rng, tmp_path, local, dframe):
    _, fr = local
    _, df = dframe
    p1, p2 = str(tmp_path / "l"), str(tmp_path / "d")
    fr.save(p1)
    df.save(p2)
    with pytest.raises(ValueError):
        IndexedFrame.load(p2, fr)   # dtable ckpt into local template
    with pytest.raises(ValueError):
        dist.checkpoint.restore_table(p2, fr.data)


def test_reshard_local_to_distributed(local, rng):
    cols, fr = local
    fr2 = fr.append(_delta(rng))
    df = fr2.reshard(4)
    assert df.is_distributed and df.num_shards == 4
    q = np.unique(rng.choice(cols["k"], 16)).astype(np.int64)
    a, b = fr2.lookup(q, max_matches=16), df.lookup(q, max_matches=16)
    valid = np.asarray(a[1])
    np.testing.assert_array_equal(np.asarray(b[1]), valid)
    # invalid-lane fill is backend-defined (local decodes a clamped row 0,
    # dist zero-fills); the contract covers valid lanes
    for k in a[0]:
        np.testing.assert_array_equal(np.asarray(b[0][k])[valid],
                                      np.asarray(a[0][k])[valid], k)
    assert int(np.asarray(df.version).ravel()[0]) == int(
        np.asarray(fr2.version).ravel()[0])


def test_reshard_distributed(dframe, rng):
    cols, fr = dframe
    df2 = fr.reshard(2)
    assert df2.num_shards == 2
    q = rng.choice(cols["k"], 16).astype(np.int64)
    a, b = fr.lookup(q, max_matches=8), df2.lookup(q, max_matches=8)
    np.testing.assert_array_equal(np.asarray(b[1]), np.asarray(a[1]))
    _assert_cols_equal(b[0], a[0])


# --- unified validation ------------------------------------------------------

def test_validation_facade_and_dist_layer(local, dframe):
    _, fr = local
    _, df = dframe
    q64 = np.zeros(8, np.int64)
    bad_dtype = [np.zeros(8, np.int32), np.zeros(8, np.float32)]
    for frame in (fr, df):
        with pytest.raises(ValueError):
            frame.lookup(q64, max_matches=0)
        with pytest.raises(ValueError):
            frame.join({"k": q64}, "k", max_matches=-3)
        for bad in bad_dtype:
            with pytest.raises(ValueError):
                frame.lookup(bad, max_matches=4)
    # the dist free functions now reject what joins.indexed_lookup rejects
    for bad in bad_dtype:
        with pytest.raises(ValueError):
            dist.lookup(df.data, bad, max_matches=4)
        with pytest.raises(ValueError):
            dist.lookup_routed(df.data, bad.reshape(4, 2), max_matches=4)
    with pytest.raises(ValueError):
        dist.lookup(df.data, q64, max_matches=0)
    with pytest.raises(ValueError):
        dist.lookup_routed(df.data, q64.reshape(4, 2), max_matches=0)
    with pytest.raises(ValueError):
        dist.indexed_join_shuffle(df.data, {"k": q64.reshape(4, 2)}, "k",
                                  np.ones((4, 2), bool), 0)


# --- zero retraces through the facade ---------------------------------------

def test_jitted_frame_sites_do_not_retrace_across_appends(rng):
    cols = _cols(rng, key_range=64)
    fr = IndexedFrame.from_columns(cols, SCH,
                                   rows_per_batch=64).with_flat_data()
    q = jnp.asarray(rng.integers(0, 64, 32).astype(np.int64))
    counts = {"lookup": 0}

    @jax.jit
    def f(frame, qq):
        counts["lookup"] += 1
        return frame.lookup(qq, max_matches=4)[1]

    jax.block_until_ready(f(fr, q))
    for _ in range(6):
        fr = fr.append(_delta(rng, key_range=64))
        jax.block_until_ready(f(fr, q))
    assert counts["lookup"] == 1


# --- shard_map backend (forced-8 when single-device) ------------------------

_SUBPROCESS_FRAME = r"""
import numpy as np, jax
from repro import IndexedFrame
from repro.core import Schema
from repro.dist import mesh
assert len(jax.devices()) == 8, jax.devices()
SCH = Schema.of("k", k="int64", v="float32", tag="int32")
rng = np.random.default_rng(5)
cols = {"k": rng.integers(0, 200, 800).astype(np.int64),
        "v": rng.random(800).astype(np.float32),
        "tag": rng.integers(0, 9, 800).astype(np.int32)}
fv = IndexedFrame.from_columns(cols, SCH, num_shards=8, rows_per_batch=64,
                               rt=mesh.vmap_runtime())
fs = IndexedFrame.from_columns(cols, SCH, num_shards=8, rows_per_batch=64,
                               rt=mesh.mesh_runtime(8))
q = np.concatenate([rng.choice(cols["k"], 31), [10**12]]).astype(np.int64)
for op in ("bcast", "routed"):
    av, bv = fv.lookup(q, max_matches=8, op=op), fs.lookup(q, max_matches=8,
                                                           op=op)
    np.testing.assert_array_equal(np.asarray(av[1]), np.asarray(bv[1]))
    np.testing.assert_array_equal(np.asarray(av[0]["tag"]),
                                  np.asarray(bv[0]["tag"]))
    if op == "routed":  # word-packed exchange: float payload bit-exact
        np.testing.assert_array_equal(np.asarray(av[0]["v"]),
                                      np.asarray(bv[0]["v"]))
pc = {"k": rng.choice(cols["k"], 24).astype(np.int64),
      "ev": np.arange(24, dtype=np.int32)}
for op in ("bcast", "shuffle"):
    ja, jb = fv.join(pc, "k", max_matches=8, op=op), fs.join(
        pc, "k", max_matches=8, op=op)
    np.testing.assert_array_equal(np.asarray(ja[2]), np.asarray(jb[2]))
    np.testing.assert_array_equal(np.asarray(ja[0]["tag"]),
                                  np.asarray(jb[0]["tag"]))
d = {"k": rng.integers(0, 200, 16).astype(np.int64),
     "v": rng.random(16).astype(np.float32),
     "tag": rng.integers(0, 9, 16).astype(np.int32)}
av = fv.append([d, d]).lookup(q, max_matches=8)
bv = fs.append([d, d]).lookup(q, max_matches=8)
np.testing.assert_array_equal(np.asarray(av[1]), np.asarray(bv[1]))
print("FRAME_PARITY_8DEV_OK")
"""


@pytest.mark.skipif(NDEV < 8, reason="needs 8 devices (ci.sh forced-8 "
                    "pass; the subprocess test covers single-device runs)")
def test_frame_parity_shard_map_in_process():
    env_script = compile(_SUBPROCESS_FRAME, "<frame-parity>", "exec")
    exec(env_script, {})


@pytest.mark.skipif(NDEV >= 8, reason="in-process test runs on this "
                    "topology")
def test_frame_parity_shard_map_subprocess():
    """Facade parity on the shard_map backend, forced-8 host topology."""
    import repro
    src = os.path.dirname(os.path.dirname(os.path.abspath(repro.__file__)))
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=8").strip()
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", _SUBPROCESS_FRAME],
                          capture_output=True, text=True, env=env,
                          timeout=600)
    assert proc.returncode == 0, proc.stdout + "\n" + proc.stderr
    assert "FRAME_PARITY_8DEV_OK" in proc.stdout
