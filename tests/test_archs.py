"""Per-arch smoke tests: reduced config of the same family, one
forward/train step on CPU, output shapes + no NaNs (assignment f)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, REGISTRY, get_smoke
from repro.configs import shapes as shp
from repro.train import optim
from repro.train.step import init_params, make_loss_fn, make_train_step


def _smoke_batch(cfg, rng, b=2, s=16):
    batch = {"tokens": jnp.asarray(
        rng.integers(1, cfg.vocab_size, (b, s)), jnp.int32)}
    if cfg.encoder_decoder:
        batch["frames"] = jnp.asarray(
            rng.standard_normal((b, cfg.encoder_seq, cfg.d_model)),
            jnp.float32)
    if cfg.family == "vlm":
        p = 4
        batch["patch_emb"] = jnp.asarray(
            rng.standard_normal((b, p, cfg.d_model)), jnp.float32)
        pos = np.broadcast_to(np.arange(s, dtype=np.int32), (3, b, s))
        batch["mrope_positions"] = jnp.asarray(pos)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(rng, arch):
    cfg = get_smoke(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = _smoke_batch(cfg, rng)
    ocfg = optim.AdamWConfig(lr_peak=1e-3, warmup_steps=2, decay_steps=10)
    step = make_train_step(cfg, ocfg, remat="none")
    opt = optim.init_state(ocfg, params)
    params2, opt2, metrics = step(params, opt, batch)
    assert np.isfinite(float(metrics["loss"])), arch
    assert np.isfinite(float(metrics["grad_norm"])), arch
    # params actually moved
    moved = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(
        a.astype(jnp.float32) - b.astype(jnp.float32)))), params, params2)
    assert max(jax.tree.leaves(moved)) > 0, arch
    assert int(opt2["step"]) == 1


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_shapes(rng, arch):
    cfg = get_smoke(arch)
    params = init_params(cfg, jax.random.PRNGKey(1))
    loss_fn = make_loss_fn(cfg, remat="none")
    batch = _smoke_batch(cfg, rng)
    loss, metrics = loss_fn(params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), arch


@pytest.mark.parametrize("arch", [a for a in ARCH_IDS
                                  if not REGISTRY[a].full().encoder_decoder])
def test_smoke_decode_step(rng, arch):
    from repro.models import transformer as tf
    cfg = get_smoke(arch)
    params = init_params(cfg, jax.random.PRNGKey(2))
    cache = tf.init_cache(cfg, 2, 32, dtype=jnp.float32)
    tok = jnp.asarray(rng.integers(1, cfg.vocab_size, (2, 1)), jnp.int32)
    logits, cache = tf.decode_step(params, cfg, tok, cache)
    assert logits.shape == (2, 1, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all(), arch


def test_full_configs_match_assignment():
    """The exact assigned numbers (layers/d_model/heads/kv/d_ff/vocab)."""
    expect = {
        "deepseek-v3-671b": (61, 7168, 128, 128, 129280),
        "deepseek-v2-lite-16b": (27, 2048, 16, 16, 102400),
        "qwen3-0.6b": (28, 1024, 16, 8, 151936),
        "gemma3-4b": (34, 2560, 8, 4, 262144),
        "qwen1.5-4b": (40, 2560, 20, 20, 151936),
        "tinyllama-1.1b": (22, 2048, 32, 4, 32000),
        "qwen2-vl-2b": (28, 1536, 12, 2, 151936),
        "mamba2-370m": (48, 1024, 0, 0, 50280),
        "jamba-v0.1-52b": (32, 4096, 32, 8, 65536),
        "whisper-large-v3": (32, 1280, 20, 20, 51866),
    }
    for arch, (nl, dm, h, kv, vocab) in expect.items():
        cfg = REGISTRY[arch].full()
        assert cfg.num_layers == nl, arch
        assert cfg.d_model == dm, arch
        assert cfg.num_heads == h, arch
        assert cfg.num_kv_heads == kv, arch
        assert cfg.vocab_size == vocab, arch
    assert REGISTRY["deepseek-v3-671b"].full().moe.num_experts == 256
    assert REGISTRY["deepseek-v3-671b"].full().moe.top_k == 8
    assert REGISTRY["jamba-v0.1-52b"].full().moe.num_experts == 16
    assert REGISTRY["mamba2-370m"].full().ssm.d_state == 128


def test_shape_applicability_rules():
    """long_500k only for sub-quadratic archs (DESIGN.md §5)."""
    runs_500k = {a for a in ARCH_IDS
                 if shp.applicable(REGISTRY[a].full(), "long_500k")}
    assert runs_500k == {"gemma3-4b", "mamba2-370m", "jamba-v0.1-52b"}
    for a in ARCH_IDS:
        assert shp.applicable(REGISTRY[a].full(), "train_4k")
        assert shp.applicable(REGISTRY[a].full(), "decode_32k")


def test_input_specs_no_allocation():
    """ShapeDtypeStructs only — no device arrays created."""
    cfg = REGISTRY["qwen3-0.6b"].full()
    spec = shp.input_specs(cfg, "train_4k")
    for leaf in jax.tree.leaves(spec["batch"]):
        assert isinstance(leaf, jax.ShapeDtypeStruct)
    spec_d = shp.input_specs(cfg, "decode_32k")
    assert spec_d["last_tok"].shape == (128, 1)
    for leaf in jax.tree.leaves(spec_d["caches"]):
        assert isinstance(leaf, jax.ShapeDtypeStruct)
