"""Planner rule firing + execution equivalence (Catalyst analog, §III-B)."""

import numpy as np
import jax.numpy as jnp

from repro.core import Schema, create_index
from repro.core.planner import (Aggregate, Col, Eq, Filter, Join, Lit,
                                Lt, Planner, Project, Relation)

SCH = Schema.of("k", k="int64", v="float32")


def _setup(rng):
    cols = {"k": rng.integers(0, 40, 300).astype(np.int64),
            "v": rng.random(300).astype(np.float32)}
    t = create_index(cols, SCH, rows_per_batch=64)
    return cols, Relation("t", table=t)


def test_rule_r1_eq_filter_on_key(rng):
    cols, rel = _setup(rng)
    plan = Planner().plan(Filter(rel, Eq(Col("k"), Lit(3))))
    assert plan.kind == "IndexedLookup"
    assert "R1" in plan.reason


def test_rule_r5_fallback_non_key(rng):
    cols, rel = _setup(rng)
    plan = Planner().plan(Filter(rel, Eq(Col("v"), Lit(0.5))))
    assert plan.kind == "ScanFilter"
    plan2 = Planner().plan(Filter(rel, Lt(Col("k"), Lit(5))))
    assert plan2.kind == "ScanFilter"


def test_rules_r2_r3_join_sides(rng):
    cols, rel = _setup(rng)
    plain = Relation("p", cols={"k": np.arange(5, dtype=np.int64)})
    assert Planner().plan(Join(rel, plain, on="k")).kind == "IndexedJoin"
    assert "R2" in Planner().plan(Join(rel, plain, on="k")).reason
    assert "R3" in Planner().plan(Join(plain, rel, on="k")).reason
    assert Planner().plan(Join(plain, plain, on="k")).kind == "HashJoin"


def test_execution_equivalence_filter(rng):
    """IndexedLookup result == ScanFilter result for the same predicate."""
    cols, rel = _setup(rng)
    pl = Planner(max_matches=128)
    key = int(cols["k"][0])
    idx_cols, idx_valid = pl.execute(Filter(rel, Eq(Col("k"), Lit(key))))
    scan_cols, scan_valid = pl.execute(
        Filter(Relation("p", cols=cols), Eq(Col("k"), Lit(key))))
    got = np.sort(np.asarray(idx_cols["v"])[np.asarray(idx_valid)])
    exp = np.sort(np.asarray(scan_cols["v"])[np.asarray(scan_valid)])
    np.testing.assert_allclose(got, exp)


def test_execution_equivalence_join(rng):
    cols, rel = _setup(rng)
    pl = Planner(max_matches=128)
    probe = Relation("p", cols={"k": np.arange(10, dtype=np.int64),
                                "tag": np.arange(10, dtype=np.int32)})
    ic, iv = pl.execute(Join(rel, probe, on="k"))
    hc, hv = pl.execute(Join(Relation("b", cols=cols), probe, on="k"))
    assert int(np.asarray(iv).sum()) == int(np.asarray(hv).sum())
    np.testing.assert_allclose(
        np.sort(np.asarray(ic["b_v"])[np.asarray(iv)]),
        np.sort(np.asarray(hc["b_v"])[np.asarray(hv)]))


def test_aggregate_over_indexed_lookup(rng):
    cols, rel = _setup(rng)
    pl = Planner(max_matches=128)
    key = int(cols["k"][0])
    got = pl.execute(Aggregate(Filter(rel, Eq(Col("k"), Lit(key))),
                               "count", "v"))
    assert int(got) == int(np.sum(cols["k"] == key))


def test_explain_renders(rng):
    cols, rel = _setup(rng)
    txt = Planner().plan(Join(rel, Relation("p", cols=cols), on="k")).explain()
    assert "IndexedJoin" in txt and "R2" in txt
