"""Serving layer: prefix cache (point lookup / MVCC commit), page pool,
paged decode vs dense decode equivalence, engine prefix reuse."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.models import transformer as tf
from repro.models.common import ModelConfig
from repro.serving import (Engine, PagePool, PrefixCache, Request,
                           paged_decode_step, prefix_hashes)
from repro.train.step import init_params

CFG = ModelConfig(name="srv", family="dense", num_layers=3, d_model=64,
                  num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
                  vocab_size=128, dtype="float32")


def test_prefix_hashes_properties(rng):
    t1 = rng.integers(1, 100, 64).astype(np.int32)
    t2 = t1.copy()
    t2[40] = t2[40] + 1  # diverge inside page 2 (page=16)
    h1, h2 = prefix_hashes(t1, 16), prefix_hashes(t2, 16)
    assert len(h1) == 4
    np.testing.assert_array_equal(h1[:2], h2[:2])   # shared prefix pages
    assert (h1[2:] != h2[2:]).all()                 # diverged + chained


def test_prefix_cache_lookup_and_commit(rng):
    cache = PrefixCache()
    toks = rng.integers(1, 100, 64).astype(np.int32)
    hs = prefix_hashes(toks, 16)
    assert cache.lookup_prefix(toks, 16)[0] == 0
    cache.commit(hs, [10, 11, 12, 13], seq_id=0)
    n, ids = cache.lookup_prefix(toks, 16)
    assert n == 4
    np.testing.assert_array_equal(ids, [10, 11, 12, 13])
    # a second sequence sharing 2 pages hits exactly those
    toks2 = toks.copy()
    toks2[40] += 1
    n2, ids2 = cache.lookup_prefix(toks2, 16)
    assert n2 == 2
    np.testing.assert_array_equal(ids2, [10, 11])
    # MVCC: commit of the divergent suffix bumps the version
    v = cache.commit(prefix_hashes(toks2, 16)[2:], [20, 21], seq_id=1)
    assert v == 1
    assert cache.lookup_prefix(toks2, 16)[0] == 4


def test_prefix_cache_edge_cases(rng):
    """lookup_prefix edges (ISSUE 8): empty token stream, sub-page tail,
    and a committed-then-released page never resurfacing as a hit."""
    cache = PrefixCache()
    toks = rng.integers(1, 100, 64).astype(np.int32)
    cache.commit(prefix_hashes(toks, 16), [10, 11, 12, 13], seq_id=0)

    # empty token stream: zero pages, empty id vector, no probe crash
    n, ids = cache.lookup_prefix(np.zeros(0, np.int32), 16)
    assert n == 0 and ids.shape == (0,) and ids.dtype == np.int32
    # a stream shorter than one page hashes to zero boundaries
    assert cache.lookup_prefix(toks[:15], 16)[0] == 0
    # a sub-page tail is ignored: 64 full + 7 tail tokens -> the same
    # 4-page hit as the aligned stream
    n, ids = cache.lookup_prefix(
        np.concatenate([toks, toks[:7]]), 16)
    assert n == 4
    np.testing.assert_array_equal(ids, [10, 11, 12, 13])

    # committed-then-released: the MVCC index row survives (appends are
    # immutable) but the page's KV is gone — the hit run must stop AT
    # the released page, and pages behind it stay usable
    cache.release([12])
    n, ids = cache.lookup_prefix(toks, 16)
    assert n == 2
    np.testing.assert_array_equal(ids, [10, 11])
    cache.release([10])
    assert cache.lookup_prefix(toks, 16)[0] == 0


def test_page_pool_alloc_release():
    pool = PagePool.create(2, 8, 4, 2, 8, dtype=jnp.float32)
    ids = pool.alloc(3)
    assert len(pool.free) == 5
    pool.release(ids)
    assert len(pool.free) == 8
    with pytest.raises(RuntimeError):
        pool.alloc(9)


def test_paged_decode_matches_dense(rng):
    """The Pallas-paged path == the dense-cache decode path."""
    params = init_params(CFG, jax.random.PRNGKey(0))
    B, S, page = 2, 32, 8
    prompts = rng.integers(1, CFG.vocab_size, (B, S)).astype(np.int32)

    # dense path: prefill -> decode one token
    _, caches = tf.prefill(params, CFG, jnp.asarray(prompts))
    dense_cache = tf.init_cache(CFG, B, S + 8, dtype=jnp.float32)
    k = caches[0]["k"]                      # [L, B, S, Hkv, Dh]
    dense_cache[0]["k"] = dense_cache[0]["k"].at[:, :, :S].set(k)
    dense_cache[0]["v"] = dense_cache[0]["v"].at[:, :, :S].set(
        caches[0]["v"])
    dense_cache[0]["length"] = jnp.full((CFG.num_layers, B), S, jnp.int32)
    tok = jnp.asarray(rng.integers(1, CFG.vocab_size, (B, 1)), jnp.int32)
    dense_logits, _ = tf.decode_step(params, CFG, tok, dense_cache)

    # paged path: write pages + decode with the kernel
    pool = PagePool.create(CFG.num_layers, 32, page, CFG.num_kv_heads,
                           CFG.head_dim, dtype=jnp.float32)
    npages = S // page
    pts = np.full((B, 8), -1, np.int32)
    for b in range(B):
        ids = pool.alloc(npages + 1)        # + decode page
        pool = pool.write_pages(k[:, b], caches[0]["v"][:, b],
                                ids[:npages])
        pts[b, :npages + 1] = ids
    lengths = jnp.full((B,), S, jnp.int32)
    paged_logits, pool = paged_decode_step(
        params, CFG, tok, pool, jnp.asarray(pts), lengths, interpret=True)
    np.testing.assert_allclose(np.asarray(paged_logits),
                               np.asarray(dense_logits),
                               rtol=2e-4, atol=2e-4)


def test_engine_prefix_reuse(rng):
    params = init_params(CFG, jax.random.PRNGKey(1))
    eng = Engine(params, CFG, num_pages=128, page=8)
    shared = rng.integers(1, CFG.vocab_size, 24)
    reqs = []
    for i in range(3):
        tail = rng.integers(1, CFG.vocab_size, 8)
        reqs.append(Request(seq_id=i, prompt=np.concatenate(
            [shared, tail]).astype(np.int32)))
    eng.run(reqs, steps=3)
    # requests 2,3 hit the pages request 1 committed
    assert eng.stats["pages_reused"] >= 4
    assert eng.stats["prefill_tokens_skipped"] >= 32
    assert all(len(r.out) == 3 for r in reqs)
    assert eng.cache.memory_overhead_bytes() > 0


def test_make_serve_step_families(rng):
    from repro.serving.engine import make_serve_step
    step = make_serve_step(CFG)
    params = init_params(CFG, jax.random.PRNGKey(0))
    cache = tf.init_cache(CFG, 2, 16, dtype=jnp.float32)
    tok = jnp.asarray(rng.integers(1, CFG.vocab_size, (2, 1)), jnp.int32)
    logits, cache2 = step(params, cache, tok)
    assert logits.shape == (2, 1, CFG.vocab_size)
