"""Self-healing supervision layer (dist/resilience.py; DESIGN.md §12).

The contract under test is the paper's Fig-12 shape made automatic: a
seeded chaos plan kills shards / delays stragglers / squeezes routed
capacity / corrupts checkpoints mid-stream, and ``frame.supervised``
reads keep returning answers bit-identical to a never-failed twin frame
— with zero caller-side failure handling, zero retraces of the fused
read sites after recovery, and replay cost bounded by the lineage
suffix since the last checkpoint.
"""

import os

import numpy as np
import pytest

pytest.importorskip("repro.dist")

import jax.numpy as jnp

from repro.core import Schema
from repro.dist import checkpoint as ckpt
from repro.dist.resilience import (FAULT_KINDS, Fault, FaultInjector,
                                   RecoveryManager, RecoveryPolicy)
from repro.dist.runtime import Lineage, StragglerPolicy, fail_shard
from repro.frame import IndexedFrame

SCH = Schema.of("k", k="int64", v="float32")
N = 512


def _base_cols(rng, n=N):
    return {"k": np.arange(n, dtype=np.int64),
            "v": rng.standard_normal(n).astype(np.float32)}


def _delta(step, width=8):
    lo = N + step * width
    return {"k": np.arange(lo, lo + width, dtype=np.int64),
            "v": np.full(width, float(step), np.float32)}


def _supervised(rng, tmp_path, *, faults=(), num_shards=4,
                policy=None, seed=0):
    cols = _base_cols(rng)
    frame = IndexedFrame.from_columns(cols, SCH, num_shards=num_shards)
    twin = IndexedFrame.from_columns(cols, SCH, num_shards=num_shards)
    mgr = frame.supervised(
        lineage=Lineage(SCH, cols),
        injector=FaultInjector(faults, seed=seed),
        policy=policy or RecoveryPolicy(checkpoint_every=2),
        checkpoint_dir=str(tmp_path / "ckpts"))
    return mgr, twin


def _assert_same_answers(mgr, twin, q, *, max_matches=4, op="auto"):
    cols, valid = mgr.lookup(q, max_matches=max_matches, op=op)
    tc, tv = twin.lookup(q, max_matches=max_matches, op=op)
    np.testing.assert_array_equal(np.asarray(valid), np.asarray(tv))
    for k in tc:
        np.testing.assert_array_equal(np.asarray(cols[k]), np.asarray(tc[k]))


# --- Fault / FaultInjector ------------------------------------------------


def test_fault_validates():
    with pytest.raises(ValueError, match="unknown fault kind"):
        Fault("meteor_strike", step=0)
    with pytest.raises(ValueError):
        Fault("shard_loss", step=-1)
    with pytest.raises(ValueError):
        Fault("straggler", step=0, severity=0.0)


def test_injector_fires_at_planned_steps():
    inj = FaultInjector([Fault("shard_loss", step=2, shard=1),
                         Fault("straggler", step=2, shard=0),
                         Fault("capacity_pressure", step=5)])
    fired = [inj.tick() for _ in range(6)]
    assert [len(f) for f in fired] == [0, 0, 2, 0, 0, 1]
    assert {f.kind for f in fired[2]} == {"shard_loss", "straggler"}
    assert len(inj.fired) == 3


def test_plan_random_is_deterministic():
    mk = lambda: FaultInjector.plan_random(seed=7, num_shards=4, steps=20,
                                           n_faults=3)
    assert mk().plan == mk().plan
    other = FaultInjector.plan_random(seed=8, num_shards=4, steps=20,
                                      n_faults=3)
    assert mk().plan != other.plan
    for f in mk().plan:
        assert f.kind in FAULT_KINDS and 1 <= f.step < 20


def test_corrupt_checkpoint_detected_by_restore(rng, tmp_path):
    cols = _base_cols(rng)
    frame = IndexedFrame.from_columns(cols, SCH, num_shards=4)
    path = str(tmp_path / "ck")
    ckpt.save_dtable(path, frame.data)
    FaultInjector(seed=3).corrupt_checkpoint(path)
    with pytest.raises(ValueError, match="CRC32"):
        ckpt.restore_dtable(path, frame.data)


# --- supervised recovery (the tentpole acceptance path) -------------------


def test_seeded_shard_kill_recovers_bit_identical(rng, tmp_path):
    mgr, twin = _supervised(
        rng, tmp_path, faults=[Fault("shard_loss", step=3, shard=2)])
    q = rng.integers(0, N, size=64).astype(np.int64)
    for step in range(8):
        _assert_same_answers(mgr, twin, q)
        d = _delta(step)
        mgr.append(d)
        twin = twin.append(d)
    assert mgr.stats.recoveries == 1
    assert not mgr.dead
    # zero recompiles: ONE trace of the fused read site across the kill
    assert mgr.retraces == 1
    # replay cost is the checkpoint-anchored suffix, not full history
    assert mgr.stats.replayed_deltas[0] <= 2


def test_recovery_replays_only_checkpoint_suffix(rng, tmp_path):
    mgr, twin = _supervised(
        rng, tmp_path, faults=[Fault("shard_loss", step=8, shard=1)],
        policy=RecoveryPolicy(checkpoint_every=3))
    q = rng.integers(0, N, size=32).astype(np.int64)
    for step in range(10):
        mgr.append(_delta(step))
        twin = twin.append(_delta(step))
    _assert_same_answers(mgr, twin, q)
    assert mgr.stats.recoveries == 1
    # 10 appends, checkpoint every 3 -> at most 3 deltas past the anchor
    assert mgr.stats.replayed_deltas[0] <= 3
    assert len(mgr.lineage.deltas) < 10   # truncate kept the log bounded


def test_corrupt_newest_checkpoint_falls_back_to_older(rng, tmp_path):
    mgr, twin = _supervised(
        rng, tmp_path,
        faults=[Fault("checkpoint_corruption", step=9),
                Fault("shard_loss", step=10, shard=0)],
        policy=RecoveryPolicy(checkpoint_every=2, keep_checkpoints=3))
    q = rng.integers(0, N, size=32).astype(np.int64)
    for step in range(7):
        mgr.append(_delta(step))
        twin = twin.append(_delta(step))
        _assert_same_answers(mgr, twin, q)
    assert mgr.stats.recoveries == 1
    assert mgr.stats.corrupt_checkpoints >= 1   # newest was rejected
    assert not mgr.dead


def test_budget_exhausted_degrades_honestly(rng, tmp_path):
    cols = _base_cols(rng)
    frame = IndexedFrame.from_columns(cols, SCH, num_shards=4)
    # no lineage, no checkpoints: shard 2 is unrecoverable by design
    mgr = RecoveryManager(
        frame, injector=FaultInjector([Fault("shard_loss", step=1,
                                             shard=2)]))
    q = rng.integers(0, N, size=64).astype(np.int64)
    mgr.lookup(q, max_matches=4)
    cols_out, valid = mgr.lookup(q, max_matches=4)
    rep = mgr.last_report
    assert mgr.dead == {2} and rep.degraded
    from repro.core import hashing
    owner = hashing.partition_hash_host(q, 4)
    np.testing.assert_array_equal(rep.answered, owner != 2)
    # dead shard answers are misses, never fabricated matches
    assert not np.asarray(valid)[owner == 2].any()
    assert np.asarray(valid)[owner != 2].any()
    assert mgr.stats.degraded_reads >= 1


def test_routed_pressure_retries_until_delivered(rng, tmp_path):
    mgr, twin = _supervised(
        rng, tmp_path,
        faults=[Fault("capacity_pressure", step=1, severity=8.0)])
    # big batch so the planner picks RoutedLookup on its own
    q = rng.integers(0, N, size=2048).astype(np.int64)
    _assert_same_answers(mgr, twin, q, op="routed")   # tick 0: no fault
    _assert_same_answers(mgr, twin, q, op="routed")   # tick 1: pressured
    assert mgr.stats.retries >= 1                     # capacity doubled
    assert mgr.last_report.dropped == 0               # ...until delivered
    assert mgr.last_report.retries >= 1


def test_straggler_fault_plans_speculative_copy(rng, tmp_path):
    mgr, _ = _supervised(
        rng, tmp_path,
        faults=[Fault("straggler", step=1, shard=3, severity=16.0)])
    q = rng.integers(0, N, size=16).astype(np.int64)
    mgr.lookup(q, max_matches=4)
    mgr.lookup(q, max_matches=4)
    assert mgr.stats.straggler_events == 1
    plan = mgr.stats.speculative_plans[0]
    assert 3 in plan and plan[3] != 3


def test_supervised_join_heals_too(rng, tmp_path):
    mgr, twin = _supervised(
        rng, tmp_path, faults=[Fault("shard_loss", step=1, shard=1)])
    probe = {"k": rng.integers(0, N, size=48).astype(np.int64)}
    mgr.join(probe, "k", max_matches=4)         # tick 0 clean
    b, p, v = mgr.join(probe, "k", max_matches=4)   # kill fires, heals
    tb, tp, tv = twin.join(probe, "k", max_matches=4)
    np.testing.assert_array_equal(np.asarray(v), np.asarray(tv))
    for k in tb:
        np.testing.assert_array_equal(np.asarray(b[k]), np.asarray(tb[k]))
    assert mgr.stats.recoveries == 1 and not mgr.last_report.degraded


def test_supervised_rejects_local_frame(rng):
    frame = IndexedFrame.from_columns(_base_cols(rng), SCH, num_shards=1)
    with pytest.raises(ValueError, match="distributed"):
        frame.supervised()


def test_append_list_coalesces_and_records_lineage(rng, tmp_path):
    mgr, twin = _supervised(rng, tmp_path, faults=[
        Fault("shard_loss", step=4, shard=0)])
    q = rng.integers(0, N, size=32).astype(np.int64)
    deltas = [_delta(0), _delta(1)]
    mgr.append(deltas)               # ONE fused ingest, one lineage record
    twin = twin.append(deltas)
    assert int(np.asarray(mgr.frame.version)) == \
        int(np.asarray(twin.version))
    for step in range(2, 6):
        mgr.append(_delta(step))
        twin = twin.append(_delta(step))
    _assert_same_answers(mgr, twin, q)
    assert mgr.stats.recoveries == 1


# --- Lineage.truncate / deltas_since (checkpoint anchoring) ---------------


def test_lineage_truncate_bounds_log_and_validates(rng, tmp_path):
    cols = _base_cols(rng)
    lin = Lineage(SCH, cols)
    frame = IndexedFrame.from_columns(cols, SCH, num_shards=4)
    for step in range(4):
        frame = frame.append(_delta(step))
        lin.record_append(_delta(step))
    path = str(tmp_path / "anchor")
    ckpt.save_dtable(path, frame.data)
    lin.truncate(4, path)
    assert lin.base_version == 4 and not lin.has_base
    assert len(lin.deltas) == 0 and lin.version == 4
    with pytest.raises(ValueError, match="suffix"):
        lin.deltas_since(2)          # below the anchor: gone
    frame2 = frame.append(_delta(4))
    lin.record_append(_delta(4))
    rebuilt = lin.replay(4, like=frame.data)
    q = np.arange(N + 5 * 8, dtype=np.int64)
    gc, gv = IndexedFrame(data=rebuilt).lookup(q, max_matches=4, op="bcast")
    tc, tv = frame2.lookup(q, max_matches=4, op="bcast")
    np.testing.assert_array_equal(np.asarray(gv), np.asarray(tv))
    for k in tc:
        np.testing.assert_array_equal(np.asarray(gc[k]), np.asarray(tc[k]))


def test_truncated_lineage_replay_needs_template(rng, tmp_path):
    cols = _base_cols(rng)
    lin = Lineage(SCH, cols)
    frame = IndexedFrame.from_columns(cols, SCH, num_shards=4)
    path = str(tmp_path / "anchor")
    ckpt.save_dtable(path, frame.data)
    lin.truncate(0, path)
    with pytest.raises(ValueError, match="like"):
        lin.replay(4)


# --- StragglerPolicy guards (satellite) -----------------------------------


def test_straggler_empty_durations_no_crash():
    sp = StragglerPolicy()
    assert sp.observe([]) == []
    assert sp.observe(np.array([])) == []


def test_straggler_all_fast_batch_flags_nothing():
    sp = StragglerPolicy()
    # near-zero median: factor x median ~ 0 would flag harmless jitter
    assert sp.observe([1e-7, 2e-7, 1.5e-7, 9e-7]) == []


def test_straggler_floor_still_catches_real_stragglers():
    sp = StragglerPolicy(min_deadline=1e-3)
    assert sp.observe([1e-4, 1.2e-4, 0.9e-4, 0.5]) == [3]
    assert sp.observe([1.0, 1.1, 0.9, 5.0]) == [3]


def test_straggler_validates_params():
    with pytest.raises(ValueError):
        StragglerPolicy(deadline_factor=0.0)
    with pytest.raises(ValueError):
        StragglerPolicy(min_deadline=-1.0)


# --- checkpoint integrity (satellite) -------------------------------------


def test_checkpoint_meta_has_format_version_and_crcs(rng, tmp_path):
    frame = IndexedFrame.from_columns(_base_cols(rng), SCH, num_shards=2)
    path = str(tmp_path / "ck")
    ckpt.save_dtable(path, frame.data)
    import json
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)
    assert meta["format_version"] == ckpt.FORMAT_VERSION
    assert len(meta["leaf_crc32"]) == meta["num_leaves"]


def test_checkpoint_missing_meta_raises(rng, tmp_path):
    frame = IndexedFrame.from_columns(_base_cols(rng), SCH, num_shards=2)
    path = str(tmp_path / "ck")
    ckpt.save_dtable(path, frame.data)
    os.remove(os.path.join(path, "meta.json"))
    with pytest.raises(ValueError, match="meta.json is missing"):
        ckpt.restore_dtable(path, frame.data)


def test_checkpoint_truncated_meta_raises(rng, tmp_path):
    frame = IndexedFrame.from_columns(_base_cols(rng), SCH, num_shards=2)
    path = str(tmp_path / "ck")
    ckpt.save_dtable(path, frame.data)
    meta_path = os.path.join(path, "meta.json")
    with open(meta_path) as f:
        text = f.read()
    with open(meta_path, "w") as f:
        f.write(text[:len(text) // 2])
    with pytest.raises(ValueError, match="corrupt or truncated"):
        ckpt.restore_dtable(path, frame.data)


def test_checkpoint_missing_leaves_raises(rng, tmp_path):
    frame = IndexedFrame.from_columns(_base_cols(rng), SCH, num_shards=2)
    path = str(tmp_path / "ck")
    ckpt.save_dtable(path, frame.data)
    os.remove(os.path.join(path, "leaves.npz"))
    with pytest.raises(ValueError, match="leaves.npz"):
        ckpt.restore_dtable(path, frame.data)


def test_v1_checkpoint_without_crcs_still_restores(rng, tmp_path):
    # back-compat: a meta.json with no leaf_crc32 (format v1) skips the
    # CRC pass but keeps shape validation
    frame = IndexedFrame.from_columns(_base_cols(rng), SCH, num_shards=2)
    path = str(tmp_path / "ck")
    ckpt.save_dtable(path, frame.data)
    import json
    meta_path = os.path.join(path, "meta.json")
    with open(meta_path) as f:
        meta = json.load(f)
    del meta["leaf_crc32"]
    meta["format_version"] = 1
    with open(meta_path, "w") as f:
        json.dump(meta, f)
    restored = ckpt.restore_dtable(path, frame.data)
    q = np.arange(32, dtype=np.int64)
    gc, gv = IndexedFrame(data=restored).lookup(q, max_matches=4, op="bcast")
    tc, tv = frame.lookup(q, max_matches=4, op="bcast")
    np.testing.assert_array_equal(np.asarray(gv), np.asarray(tv))


# --- splice/version guards ------------------------------------------------


def test_splice_rejects_version_mismatch(rng):
    from repro.dist.runtime import splice_shard
    cols = _base_cols(rng)
    frame = IndexedFrame.from_columns(cols, SCH, num_shards=4)
    ahead = frame.append(_delta(0))
    with pytest.raises(ValueError, match="version"):
        splice_shard(frame.data, 0, ahead.data)


def test_lookup_routed_report_contract(rng):
    from repro.dist import lookup_routed_report
    cols = _base_cols(rng)
    frame = IndexedFrame.from_columns(cols, SCH, num_shards=4)
    q = rng.integers(0, N, size=100).astype(np.int64)
    c, v, answered, dropped = lookup_routed_report(
        frame.data, jnp.asarray(q), max_matches=4)
    assert np.asarray(answered).shape == (100,)
    assert np.asarray(answered).all() and int(np.asarray(dropped).sum()) == 0
    # starve the exchange: drops are REPORTED, answered goes false
    c2, v2, ans2, drop2 = lookup_routed_report(
        frame.data, jnp.asarray(np.zeros(100, np.int64)), max_matches=4,
        capacity=1)
    assert int(np.asarray(drop2).sum()) > 0
    assert not np.asarray(ans2).all()
    # unanswered lanes are misses, not fabricated matches
    assert not np.asarray(v2)[~np.asarray(ans2)].any()
