"""Capacity-reserved arena write path (ISSUE 4 / DESIGN.md §4).

The acceptance contract this file pins:

1. ≥10 successive same-class appends cause ZERO retraces of the fused
   read entry points (``fused_lookup`` / ``indexed_join`` call sites) —
   under the single table AND under both dist backends (the shard_map
   side lives in test_mesh_parity.py, which needs a multi-device mesh).
2. Exactly one compile per capacity class: promotion (capacity
   exhaustion) retraces a read site once, then the next class's appends
   are free again.
3. The donated ingest consumes the parent and produces bit-identical
   lookups to the non-donated path.
4. Fill-masking: reserved-but-unwritten lanes can never be decoded, even
   when presented as forged row ids.
5. Threshold compaction bounds segment fan-out under repeated promotion.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import Schema, append, compact, create_index, joins
from repro.core.pointers import NULL_PTR
from repro.core.table import DEFAULT_COMPACT_THRESHOLD, capacity_class

SCH = Schema.of("k", k="int64", v="float32")


def _cols(rng, n, key_range=60, tag=0.0):
    return {"k": rng.integers(0, key_range, n).astype(np.int64),
            "v": (rng.random(n) + tag).astype(np.float32)}


# --- capacity classes ------------------------------------------------------

def test_capacity_class_policy():
    assert capacity_class(1, 64) == 64             # one batch covers 2*1
    assert capacity_class(100, 64) == 256          # 2*100 -> 4 batches
    assert capacity_class(64, 64) == 128
    assert capacity_class(4096, 4096) == 8192
    # classes are powers of two in batches: promotion is geometric
    for n in (1, 7, 100, 5000):
        c = capacity_class(n, 64)
        assert c % 64 == 0 and ((c // 64) & (c // 64 - 1)) == 0
        assert c >= 2 * n


def test_create_reserves_capacity_and_tracks_fill(rng):
    t = create_index(_cols(rng, 300), SCH, rows_per_batch=64)
    assert t.capacity == capacity_class(300, 64)
    assert int(t.fill) == 300
    assert t.spare_capacity() == t.capacity - 300
    # reserve=0 reproduces the pre-arena exact-fit layout
    t0 = create_index(_cols(rng, 300), SCH, rows_per_batch=64, reserve=0)
    assert t0.capacity == 320 and t0.spare_capacity() == 20


# --- the acceptance tracing counts ----------------------------------------

def test_ten_appends_zero_retraces_fused_read_sites(rng):
    """THE tentpole pin: ≥10 successive same-class appends retrace
    NEITHER the fused_lookup nor the indexed_join call site."""
    lookup_traces = {"n": 0}
    join_traces = {"n": 0}

    @jax.jit
    def f_lookup(tbl, qq):
        lookup_traces["n"] += 1
        rows, _ = tbl.lookup(qq, 4)
        return rows

    @jax.jit
    def f_join(tbl, pc):
        join_traces["n"] += 1
        return joins.indexed_join(tbl, pc, "pk", max_matches=4)

    t = create_index(_cols(rng, 300), SCH,
                     rows_per_batch=64).with_flat_data()
    q = _cols(rng, 32)["k"]
    pc = {"pk": q, "tag": np.arange(32, dtype=np.int32)}
    f_lookup(t, q)
    f_join(t, pc)
    versions = [t]
    for i in range(10):
        t = append(t, _cols(rng, 17, tag=float(i)))
        versions.append(t)
        r = f_lookup(t, q)
        f_join(t, pc)
        np.testing.assert_array_equal(np.asarray(r),
                                      np.asarray(t.lookup_ref(q, 4)[0]))
    assert lookup_traces["n"] == 1
    assert join_traces["n"] == 1
    # MVCC: every intermediate version still answers (and still cached)
    for tv in versions:
        f_lookup(tv, q)
    assert lookup_traces["n"] == 1


def test_one_compile_per_capacity_class(rng):
    """Promotion to the next class retraces a read site exactly once;
    appends inside the new class are free again."""
    traces = {"n": 0}

    @jax.jit
    def f(tbl, qq):
        traces["n"] += 1
        rows, _ = tbl.lookup(qq, 4)
        return rows

    t = create_index(_cols(rng, 100), SCH, rows_per_batch=64)
    q = _cols(rng, 32)["k"]
    f(t, q)
    assert traces["n"] == 1

    spare = t.spare_capacity()
    t = append(t, _cols(rng, spare + 1))    # exhausts the class -> promote
    assert t.num_segments == 2
    f(t, q)
    assert traces["n"] == 2                 # exactly one new compile
    for i in range(10):                     # ...amortized over the class
        t = append(t, _cols(rng, 9))
        f(t, q)
    assert traces["n"] == 2


def test_vmap_dist_backend_zero_retraces(rng):
    """The dist acceptance half on the default (vmap) backend: ≥10
    appends, zero retraces of the jitted distributed lookup."""
    dist = pytest.importorskip("repro.dist")
    cols = _cols(rng, 600, key_range=200)
    dt = dist.create_distributed(cols, SCH, 4, rows_per_batch=64)
    traces = {"n": 0}

    @jax.jit
    def f(d, qq):
        traces["n"] += 1
        _, valid, _ = dist.lookup(d, qq, max_matches=4)
        return valid

    q = jnp.asarray(_cols(rng, 24, key_range=200)["k"])
    f(dt, q)
    for i in range(10):
        dt = dist.append_distributed(dt, _cols(rng, 11, key_range=200))
        f(dt, q)
    assert traces["n"] == 1
    assert int(dt.version) == 10


# --- donation --------------------------------------------------------------

def test_donated_append_bit_identical_and_consumes_parent(rng):
    t1 = create_index(_cols(rng, 300), SCH, rows_per_batch=64)
    t2 = create_index(_cols(np.random.default_rng(0), 300), SCH,
                      rows_per_batch=64)
    # same delta through both paths -> bit-identical children
    rng_d = np.random.default_rng(1)
    delta = _cols(rng_d, 23)
    a = append(t1, delta)
    b = append(t2, delta, donate=True)
    for la, lb in zip(jax.tree_util.tree_leaves(a),
                      jax.tree_util.tree_leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
    # the donated parent is consumed (its buffers were aliased away)
    with pytest.raises(RuntimeError):
        jax.block_until_ready(t2.lookup(jnp.asarray([1], jnp.int64), 2)[0])
    # the non-donated parent is alive and unchanged
    jax.block_until_ready(t1.lookup(jnp.asarray([1], jnp.int64), 2)[0])


def test_donated_append_chain(rng):
    """A write-hot stream: chained donated appends stay correct."""
    all_cols = [_cols(rng, 200)]
    t = create_index(all_cols[0], SCH, rows_per_batch=64)
    for i in range(12):
        d = _cols(rng, 13, tag=float(i))
        all_cols.append(d)
        t = append(t, d, donate=True)
    ks = np.concatenate([c["k"] for c in all_cols])
    vs = np.concatenate([c["v"] for c in all_cols])
    got, valid = joins.indexed_lookup(t, np.arange(60, dtype=np.int64),
                                      max_matches=128)
    for key in range(60):
        hits = np.nonzero(ks == key)[0][::-1]
        n = int(valid[key].sum())
        assert n == len(hits)
        np.testing.assert_allclose(np.asarray(got["v"][key][:n]), vs[hits])


# --- fill masking ----------------------------------------------------------

def test_fill_masks_reserved_lanes(rng):
    """Forged row ids pointing into reserved-but-unwritten lanes decode
    to zeros/misses on every path (the donated-aliasing defense)."""
    t = create_index(_cols(rng, 100), SCH, rows_per_batch=64)
    fill, cap = int(t.fill), t.capacity
    assert fill < cap
    forged = jnp.asarray([fill, cap - 1, fill - 1, 0], jnp.int32)
    got = t.gather_rows(forged)
    # the reserved lanes decode to exact zeros; written lanes decode rows
    assert float(jnp.abs(got["v"][0])) == 0.0
    assert float(jnp.abs(got["v"][1])) == 0.0
    assert int(got["k"][0]) == 0 and int(got["k"][1]) == 0
    np.testing.assert_array_equal(np.asarray(t.gather_prev(forged[:2])),
                                  [NULL_PTR, NULL_PTR])
    # lookup can never emit a row id >= fill
    rows, _ = t.lookup(jnp.asarray(_cols(rng, 50)["k"], jnp.int64), 8)
    assert int(jnp.max(rows)) < fill


def test_fill_mask_inside_kernel_walk(rng):
    """The donation-alias nightmare, forged by hand: a head pointer into
    reserved space and a reserved prev lane that bounces back to a written
    row.  The kernel must truncate at the reserved hop exactly like the
    oracle — masking only the kernel outputs would let the bounced-back
    (in-range!) garbage survive."""
    import dataclasses as dc
    from repro.kernels import ops
    t = create_index(_cols(rng, 100), SCH, rows_per_batch=64)
    fill = int(t.fill)
    snap = t.snapshot
    blk = snap.blocks[-1]
    bkeys = np.asarray(t.segments[0].index.bucket_keys)
    bptrs = np.asarray(blk.ptrs).copy()
    i, j = map(int, np.argwhere(bptrs >= 0)[0])
    victim_key = int(bkeys[i, j])
    victim_ptr = int(bptrs[i, j])
    prev = np.asarray(snap.prev).copy()
    # case A: the victim's chain hops into reserved space, which points
    # back at row 0 (a perfectly in-range id)
    prev[victim_ptr] = fill + 1
    prev[fill + 1] = 0
    snap_a = dc.replace(snap, prev=jnp.asarray(prev))
    q = jnp.asarray([victim_key], jnp.int64)
    for use_kernel in (False, True):
        rows, _ = ops.fused_lookup(q, snap_a, max_matches=4,
                                   use_kernel=use_kernel, interpret=True)
        rows = np.asarray(rows)[0]
        assert rows[0] == victim_ptr, (use_kernel, rows)
        assert (rows[1:] == NULL_PTR).all(), (use_kernel, rows)
    # case B: the head pointer itself is forged into reserved space
    bptrs[i, j] = fill + 1
    snap_b = dc.replace(snap, blocks=snap.blocks[:-1]
                        + (dc.replace(blk, ptrs=jnp.asarray(bptrs)),))
    for use_kernel in (False, True):
        rows, _ = ops.fused_lookup(q, snap_b, max_matches=4,
                                   use_kernel=use_kernel, interpret=True)
        assert (np.asarray(rows)[0] == NULL_PTR).all(), use_kernel


def test_promotion_with_sparse_valid_delta(rng):
    """A mostly-invalid delta whose raw lane count exceeds its valid-row
    capacity class still promotes cleanly (the packed rows are trimmed to
    their class before padding)."""
    t = create_index(_cols(rng, 100), SCH, rows_per_batch=64, reserve=0)
    spare = t.spare_capacity()
    lanes = 1000
    valid = np.zeros(lanes, bool)
    valid[::7] = True                       # sparse, > spare valid rows
    nv = int(valid.sum())
    assert nv > spare
    d = {"k": np.arange(lanes, dtype=np.int64) + 10_000,
         "v": np.arange(lanes, dtype=np.float32)}
    t2 = append(t, d, valid=valid)
    assert int(t2.num_rows()) == 100 + nv
    got, v = joins.indexed_lookup(
        t2, np.asarray([10_000, 10_007, 10_001], np.int64), max_matches=2)
    np.testing.assert_array_equal(np.asarray(v).sum(1), [1, 1, 0])
    np.testing.assert_array_equal(np.asarray(got["v"][:2, 0]), [0.0, 7.0])


def test_logical_nbytes_not_inflated_when_shard_stacked(rng):
    """data_nbytes(logical=True) on a shard-stacked table counts each
    valid row once (per-row bytes must not absorb the shard axis)."""
    dist = pytest.importorskip("repro.dist")
    cols = _cols(rng, 400, key_range=100)
    dt = dist.create_distributed(cols, SCH, 4, rows_per_batch=64)
    assert int(dt.table.data_nbytes(logical=True)) \
        == 400 * SCH.width_words * 4


def test_fill_is_a_leaf_not_structure(rng):
    """fill/version ride as data leaves: same treedef across versions."""
    t = create_index(_cols(rng, 100), SCH, rows_per_batch=64)
    t2 = append(t, _cols(rng, 10))
    assert (jax.tree_util.tree_structure(t)
            == jax.tree_util.tree_structure(t2))
    assert int(t2.fill) == int(t.fill) + 10
    assert int(t2.version) == int(t.version) + 1


# --- promotion + threshold compaction --------------------------------------

def test_promotion_grows_geometrically(rng):
    t = create_index(_cols(rng, 100), SCH, rows_per_batch=64)
    caps = [t.capacity]
    for _ in range(3):
        t = append(t, _cols(rng, t.spare_capacity() + 1))
        caps.append(t.capacity)
    # each promotion at least doubles the tail class
    tails = [c2 - c1 for c1, c2 in zip(caps, caps[1:])]
    for a, b in zip(tails, tails[1:]):
        assert b >= 2 * a or b >= caps[0]


def test_threshold_compaction_bounds_fanout(rng):
    """Segment growth past the threshold triggers compaction; lookups are
    preserved across it."""
    all_cols = [_cols(rng, 40, key_range=12)]
    t = create_index(all_cols[0], SCH, rows_per_batch=16, reserve=0)
    for i in range(12):
        d = _cols(rng, 20, key_range=12)
        all_cols.append(d)
        t = append(t, d, mode="segment", compact_threshold=3)
        assert t.num_segments <= 4          # threshold + the fresh delta
    ks = np.concatenate([c["k"] for c in all_cols])
    vs = np.concatenate([c["v"] for c in all_cols])
    got, valid = joins.indexed_lookup(t, np.arange(12, dtype=np.int64),
                                      max_matches=512)
    for key in range(12):
        hits = np.nonzero(ks == key)[0][::-1]
        n = int(valid[key].sum())
        assert n == len(hits)
        np.testing.assert_allclose(np.asarray(got["v"][key][:n]), vs[hits])
    assert DEFAULT_COMPACT_THRESHOLD >= 3   # the default is no tighter


def test_arena_promotion_trips_threshold(rng):
    """Arena-path promotions count toward the threshold too: a table that
    keeps exhausting its class gets compacted back to one segment."""
    n0 = 100
    t = create_index({"k": np.arange(n0, dtype=np.int64),
                      "v": np.zeros(n0, np.float32)}, SCH,
                     rows_per_batch=64, reserve=0)
    total = n0
    for i in range(6):
        nd = t.spare_capacity() + 1         # always exhausts the class
        d = {"k": np.arange(total, total + nd, dtype=np.int64),
             "v": np.full(nd, float(i + 1), np.float32)}
        t = append(t, d, compact_threshold=2)
        total += nd
        assert t.num_segments <= 3          # threshold + the fresh tail
    assert int(t.num_rows()) == total
    got, valid = joins.indexed_lookup(
        t, np.asarray([0, n0, total - 1, total], np.int64), max_matches=2)
    np.testing.assert_array_equal(np.asarray(valid).sum(1), [1, 1, 1, 0])


def test_compact_returns_reserved_arena(rng):
    t = create_index(_cols(rng, 100), SCH, rows_per_batch=64, reserve=0)
    for _ in range(3):
        t = append(t, _cols(rng, 30), mode="segment")
    tc = compact(t)
    assert tc.num_segments == 1
    assert tc.spare_capacity() > 0          # compaction re-reserves
    t2 = append(tc, _cols(rng, 10))         # ...so appends are in-place
    assert t2.num_segments == 1
    assert (jax.tree_util.tree_structure(t2)
            == jax.tree_util.tree_structure(tc))


# --- memory accounting ------------------------------------------------------

def test_logical_vs_reserved_nbytes(rng):
    t = create_index(_cols(rng, 300), SCH, rows_per_batch=64)
    res_d, log_d = int(t.data_nbytes()), int(t.data_nbytes(logical=True))
    res_i, log_i = int(t.index_nbytes()), int(t.index_nbytes(logical=True))
    # logical counts valid rows only; reserved counts the arena planes
    assert log_d == 300 * SCH.width_words * 4
    assert res_d == t.capacity * SCH.width_words * 4
    assert log_d < res_d and log_i < res_i
    # appends grow logical bytes but not reserved bytes (same planes)
    t2 = append(t, _cols(rng, 50))
    assert int(t2.data_nbytes()) == res_d
    assert int(t2.data_nbytes(logical=True)) == 350 * SCH.width_words * 4
