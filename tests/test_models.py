"""Model-family unit tests: forward/backward finite, prefill/decode
consistency, SSD chunked-vs-sequential equivalence, MoE routing."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.models.common import (MLAConfig, ModelConfig, MoEConfig,
                                 SSMConfig)
from repro.models import transformer as tf
from repro.models import whisper as wh
from repro.models import ssm as ssm_mod
from repro.models import rope as rp


def tiny_dense(**kw):
    base = dict(name="tiny", family="dense", num_layers=3, d_model=64,
                num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
                vocab_size=128, dtype="float32")
    base.update(kw)
    return ModelConfig(**base)


CONFIGS = {
    "dense": tiny_dense(),
    "qk_norm_bias": tiny_dense(qk_norm=True, qkv_bias=True),
    "sliding": tiny_dense(num_layers=6, local_global_pattern=2,
                          sliding_window=8, local_rope_theta=1e4),
    "moe": tiny_dense(num_layers=4, moe=MoEConfig(
        num_experts=4, top_k=2, d_ff_expert=64, num_shared=1,
        first_dense_layers=1)),
    "moe_v3": tiny_dense(num_layers=3, moe=MoEConfig(
        num_experts=4, top_k=2, d_ff_expert=64, num_shared=1,
        router="sigmoid", router_aux_free_bias=True), mtp_depth=1),
    "mla": tiny_dense(mla=MLAConfig(q_lora_rank=32, kv_lora_rank=32,
                                    qk_nope_head_dim=16, qk_rope_head_dim=8,
                                    v_head_dim=16)),
    "ssm": tiny_dense(family="ssm", num_heads=0, num_kv_heads=0, head_dim=0,
                      ssm=SSMConfig(d_state=16, head_dim=16, chunk=8)),
    "hybrid": tiny_dense(family="hybrid", num_layers=4, attn_layer_period=4,
                         attn_layer_offset=1,
                         ssm=SSMConfig(d_state=16, head_dim=16, chunk=8),
                         # ample capacity: no MoE token drops, so prefill
                         # and decode agree exactly (drops are a train-time
                         # approximation that decode never applies)
                         moe=MoEConfig(num_experts=4, top_k=2,
                                       d_ff_expert=64, every_k=2,
                                       capacity_factor=8.0)),
}


@pytest.mark.parametrize("name", list(CONFIGS))
def test_forward_backward_finite(rng, name):
    cfg = CONFIGS[name]
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 16)), jnp.int32)
    loss, metrics = tf.forward_train(params, cfg, toks)
    assert np.isfinite(float(loss)), name
    g = jax.grad(lambda p: tf.forward_train(p, cfg, toks)[0])(params)
    leaves = jax.tree.leaves(g)
    assert all(np.isfinite(np.asarray(l)).all() for l in leaves), name


@pytest.mark.parametrize("name", ["dense", "sliding", "mla", "ssm", "hybrid"])
def test_prefill_decode_consistency(rng, name):
    """Greedy decode logits must match teacher-forced forward logits."""
    cfg = CONFIGS[name]
    params = tf.init_params(cfg, jax.random.PRNGKey(1))
    B, S = 2, 12
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)

    # teacher-forced logits at the last position
    x = tf._embed(params, cfg, toks)
    h, _, _ = tf.backbone_prefill(params, cfg, x)
    full_logits = tf._logits(params, cfg, h)          # [B, S, V]

    # decode token-by-token from an empty cache
    cache = tf.init_cache(cfg, B, S + 4, dtype=jnp.float32)
    for t in range(S):
        lg, cache = tf.decode_step(params, cfg, toks[:, t:t + 1], cache)
    np.testing.assert_allclose(np.asarray(lg[:, 0]),
                               np.asarray(full_logits[:, -1]),
                               rtol=2e-3, atol=2e-3)


def test_scan_groups_partition():
    cfg = CONFIGS["hybrid"]
    groups = tf.scan_groups(cfg)
    assert sum(n for _, n in groups) == cfg.num_layers
    kinds = tf.layer_kinds(cfg)
    assert kinds[1].attn == "gqa"          # period 4, offset 1
    assert kinds[0].attn == "ssm"
    assert kinds[1].ffn == "moe"           # every 2nd layer

    g3 = tf.scan_groups(CONFIGS["sliding"])
    kinds3 = tf.layer_kinds(CONFIGS["sliding"])
    assert kinds3[2].window is None        # global every 3rd (pattern=2)
    assert kinds3[0].window == 8


def test_ssd_chunked_equals_sequential(rng):
    """SSD chunked algorithm == naive sequential recurrence."""
    b, s, h, p, n, chunk = 2, 32, 4, 8, 16, 8
    x = rng.standard_normal((b, s, h, p)).astype(np.float32)
    dt = np.abs(rng.standard_normal((b, s, h))).astype(np.float32) * 0.1 + 0.01
    A = -np.abs(rng.standard_normal(h)).astype(np.float32)
    B = rng.standard_normal((b, s, 1, n)).astype(np.float32)
    C = rng.standard_normal((b, s, 1, n)).astype(np.float32)
    D = rng.standard_normal(h).astype(np.float32)

    y, final = ssm_mod.ssd_chunked(jnp.asarray(x), jnp.asarray(dt),
                                   jnp.asarray(A), jnp.asarray(B),
                                   jnp.asarray(C), jnp.asarray(D), chunk)

    # sequential oracle
    st = np.zeros((b, h, p, n), np.float32)
    ys = np.zeros_like(x)
    for t in range(s):
        decay = np.exp(dt[:, t] * A[None, :])               # [b,h]
        Bh = np.repeat(B[:, t], h, axis=1)                  # [b,h,n]
        Ch = np.repeat(C[:, t], h, axis=1)
        st = st * decay[..., None, None] + np.einsum(
            "bh,bhn,bhp->bhpn", dt[:, t], Bh, x[:, t])
        ys[:, t] = np.einsum("bhn,bhpn->bhp", Ch, st) + x[:, t] * D[None, :, None]
    np.testing.assert_allclose(np.asarray(y), ys, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(final), st, rtol=2e-4, atol=2e-4)


def test_moe_capacity_drop_passthrough(rng):
    """Tokens over capacity contribute nothing (residual passthrough)."""
    from repro.models import moe as moe_mod
    cfg = CONFIGS["moe"]
    p = moe_mod.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jnp.asarray(rng.standard_normal((1, 8, cfg.d_model)), jnp.float32)
    out, aux = moe_mod.moe_ffn(p, x, cfg)
    assert out.shape == x.shape
    assert np.isfinite(np.asarray(out)).all()
    assert float(aux) > 0


def test_mrope_sections(rng):
    x = jnp.asarray(rng.standard_normal((1, 6, 2, 16)), jnp.float32)
    pos = jnp.stack([jnp.arange(6)[None], jnp.arange(6)[None] * 0,
                     jnp.arange(6)[None] * 0])     # [3, 1, 6]
    out = rp.rotate_mrope(x, pos, 1e4, (4, 2, 2))
    assert out.shape == x.shape
    # all-zero positions = identity on the (h, w) slots
    out0 = rp.rotate_mrope(x, pos * 0, 1e4, (4, 2, 2))
    np.testing.assert_allclose(np.asarray(out0), np.asarray(x), atol=1e-6)


def test_flash_equals_dense_attention(rng):
    from repro.models import flash
    from repro.models.attention import causal_mask, gqa_core
    b, s, h, hk, d = 2, 37, 8, 2, 16
    q = jnp.asarray(rng.standard_normal((b, s, h, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, hk, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, hk, d)), jnp.float32)
    pos = jnp.arange(s)[None]
    for window in (None, 7, 64):
        mask = jnp.broadcast_to(causal_mask(pos, pos, window), (b, s, s))
        dense = gqa_core(q, k, v, mask, d ** -0.5)
        for bk in (8, 16, 64):
            fl = flash.flash_gqa(q, k, v, scale=d ** -0.5, causal=True,
                                 window=window, block_k=bk)
            np.testing.assert_allclose(np.asarray(fl), np.asarray(dense),
                                       rtol=2e-5, atol=2e-5)


def test_moe_sorted_equals_einsum(rng):
    from repro.models import moe as moe_mod
    cfg = tiny_dense(moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=32,
                                   num_shared=1, capacity_factor=8.0))
    p = moe_mod.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jnp.asarray(rng.standard_normal((2, 16, cfg.d_model)), jnp.float32)
    o1, a1 = moe_mod.moe_ffn_einsum(p, x, cfg)
    o2, a2 = moe_mod.moe_ffn_sorted(p, x, cfg)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(float(a1), float(a2), rtol=1e-5)


def test_moe_ep_shardmap_single_device(rng):
    from repro.models import moe as moe_mod
    from repro.models import sharding as shd
    cfg = tiny_dense(moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=32,
                                   capacity_factor=8.0))
    p = moe_mod.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jnp.asarray(rng.standard_normal((2, 16, cfg.d_model)), jnp.float32)
    o1, _ = moe_mod.moe_ffn_einsum(p, x, cfg)
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((1, 1), ("data", "model"))
    with shd.logical_sharding(mesh, shd.rules_single_pod()):
        o3, _ = moe_mod.moe_ffn(p, x, cfg)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o3),
                               rtol=2e-4, atol=2e-4)


def test_mla_flash_prefill_matches_dense(rng):
    """MLA prefill above the flash threshold == dense-path logits."""
    from repro.models import attention as attn, flash
    cfg = CONFIGS["mla"]
    p = attn.mla_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jnp.asarray(rng.standard_normal((1, 24, cfg.d_model)), jnp.float32)
    dense_out, _ = attn.mla_prefill(p, x, cfg)
    old = flash.FLASH_THRESHOLD
    try:
        flash.FLASH_THRESHOLD = 4
        flash_out, _ = attn.mla_prefill(p, x, cfg)
    finally:
        flash.FLASH_THRESHOLD = old
    np.testing.assert_allclose(np.asarray(flash_out), np.asarray(dense_out),
                               rtol=2e-4, atol=2e-4)


def test_whisper_train_and_decode(rng):
    cfg = ModelConfig(name="tiny-whisper", family="audio", num_layers=2,
                      d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
                      d_ff=128, vocab_size=100, encoder_decoder=True,
                      encoder_layers=2, encoder_seq=30, dtype="float32",
                      tie_embeddings=True)
    params = wh.init_params(cfg, jax.random.PRNGKey(0))
    frames = jnp.asarray(rng.standard_normal((2, 30, 64)), jnp.float32)
    toks = jnp.asarray(rng.integers(0, 100, (2, 10)), jnp.int32)
    loss, _ = wh.forward_train(params, cfg, frames, toks)
    assert np.isfinite(float(loss))

    enc = wh.encode(params, cfg, frames)
    cache = wh.init_cache(cfg, 2, 16, dtype=jnp.float32)
    ck, cv = wh.build_cross_cache(params, cfg, enc)
    cache = dict(cache, cross_k=ck, cross_v=cv)
    lg, cache = wh.decode_step(params, cfg, toks[:, :1], cache)
    assert np.isfinite(np.asarray(lg)).all()
    assert int(cache["length"][0, 0]) == 1
