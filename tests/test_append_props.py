"""Property tests (hypothesis / repro.testing shim): the arena write path
is observationally equivalent to the segment-chain reference.

For random delta sequences, three builds of the same logical table —

  * arena appends (in-place ingest + promotion, DESIGN.md §4),
  * segment-chain appends (the pre-arena reference path),
  * either of the above followed by ``compact()``

— must answer every lookup with bit-identical decoded columns and valid
masks (row ids are representation-dependent: arenas reserve capacity, so
global row addresses differ; decoded VALUES are the contract).  The same
holds for the donated arena ingest, and for the distributed table against
the single-table oracle.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import Schema, append, compact, create_index, joins

SCH = Schema.of("k", k="int64", v="float32")

KEYS = st.lists(st.integers(min_value=0, max_value=11), min_size=1,
                max_size=60)


def _cols_from(keys, base):
    keys = np.asarray(keys, np.int64)
    return {"k": keys,
            "v": (np.arange(len(keys), dtype=np.float32) * 0.5
                  + np.float32(base))}


def _lookup_all(t, max_matches=192):
    q = np.arange(12, dtype=np.int64)
    cols, valid = joins.indexed_lookup(t, q, max_matches=max_matches)
    v = np.asarray(valid)
    return {"valid": v,
            "v": np.asarray(cols["v"]) * v,
            "k": np.asarray(cols["k"]) * v}


def _assert_same_answers(a, b):
    np.testing.assert_array_equal(a["valid"], b["valid"])
    np.testing.assert_array_equal(a["v"], b["v"])       # bit-identical
    np.testing.assert_array_equal(a["k"], b["k"])


@settings(max_examples=20, deadline=None)
@given(KEYS, st.lists(KEYS, min_size=1, max_size=5))
def test_property_arena_equals_segment_chain_equals_compacted(base_keys,
                                                              deltas):
    base = _cols_from(base_keys, 0)
    ta = create_index(base, SCH, rows_per_batch=16)
    ts = create_index(base, SCH, rows_per_batch=16, reserve=0)
    for i, dk in enumerate(deltas):
        d = _cols_from(dk, 1000 * (i + 1))
        ta = append(ta, d, mode="arena")
        ts = append(ts, d, mode="segment")
    ans_a, ans_s = _lookup_all(ta), _lookup_all(ts)
    _assert_same_answers(ans_a, ans_s)
    _assert_same_answers(ans_a, _lookup_all(compact(ta)))
    _assert_same_answers(ans_s, _lookup_all(compact(ts)))


@settings(max_examples=10, deadline=None)
@given(KEYS, st.lists(KEYS, min_size=1, max_size=4))
def test_property_donated_ingest_equals_functional(base_keys, deltas):
    base = _cols_from(base_keys, 0)
    ta = create_index(base, SCH, rows_per_batch=16)
    td = create_index(base, SCH, rows_per_batch=16)
    for i, dk in enumerate(deltas):
        d = _cols_from(dk, 1000 * (i + 1))
        ta = append(ta, d)
        td = append(td, d, donate=True)
    _assert_same_answers(_lookup_all(ta), _lookup_all(td))
    # representations agree leaf-for-leaf, not just answer-for-answer
    for la, ld in zip(jax.tree_util.tree_leaves(ta),
                      jax.tree_util.tree_leaves(td)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(ld))


@settings(max_examples=8, deadline=None)
@given(KEYS, st.lists(KEYS, min_size=1, max_size=3))
def test_property_partial_valid_deltas(base_keys, deltas):
    """Deltas with invalid lanes: the arena packs valid rows; answers
    match the reference built from only the valid rows."""
    rng = np.random.default_rng(len(base_keys))
    base = _cols_from(base_keys, 0)
    ta = create_index(base, SCH, rows_per_batch=16)
    kept = [base]
    for i, dk in enumerate(deltas):
        d = _cols_from(dk, 1000 * (i + 1))
        valid = rng.random(len(dk)) < 0.6
        ta = append(ta, d, valid=valid)
        kept.append({k: v[valid] for k, v in d.items()})
    ks = np.concatenate([c["k"] for c in kept])
    vs = np.concatenate([c["v"] for c in kept])
    got, valid = joins.indexed_lookup(ta, np.arange(12, dtype=np.int64),
                                      max_matches=192)
    for key in range(12):
        hits = np.nonzero(ks == key)[0][::-1]
        n = int(valid[key].sum())
        assert n == len(hits)
        np.testing.assert_array_equal(np.asarray(got["v"][key][:n]),
                                      vs[hits])


@settings(max_examples=6, deadline=None)
@given(st.lists(st.integers(min_value=0, max_value=40), min_size=8,
                max_size=60),
       st.lists(st.lists(st.integers(min_value=0, max_value=40),
                         min_size=1, max_size=16),
                min_size=1, max_size=3))
def test_property_distributed_matches_single_table(base_keys, deltas):
    """Arena appends distribute: the dtable answers the same multiset of
    rows as the single-table oracle after every delta, and compacting the
    dtable changes nothing."""
    dist = pytest.importorskip("repro.dist")
    base = _cols_from(base_keys, 0)
    dt = dist.create_distributed(base, SCH, 4, rows_per_batch=16)
    t = create_index(base, SCH, rows_per_batch=16)
    for i, dk in enumerate(deltas):
        d = _cols_from(dk, 1000 * (i + 1))
        dt = dist.append_distributed(dt, d)
        t = append(t, d)
    q = np.arange(41, dtype=np.int64)
    gd, vd, _ = dist.lookup(dt, q, max_matches=128)
    gs, vs = joins.indexed_lookup(t, q, max_matches=128)
    np.testing.assert_array_equal(np.asarray(vd).sum(1),
                                  np.asarray(vs).sum(1))
    for i in range(len(q)):
        np.testing.assert_array_equal(
            np.sort(np.asarray(gd["v"][i])[np.asarray(vd[i])]),
            np.sort(np.asarray(gs["v"][i])[np.asarray(vs[i])]))
    dc = dist.compact_distributed(dt)
    gc, vc, _ = dist.lookup(dc, q, max_matches=128)
    np.testing.assert_array_equal(np.asarray(vd), np.asarray(vc))
    np.testing.assert_array_equal(np.asarray(gd["v"]) * np.asarray(vd),
                                  np.asarray(gc["v"]) * np.asarray(vc))
