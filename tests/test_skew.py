"""Skew-resilient execution (DESIGN.md §15, ISSUE 9): hot-key tracking,
replication, and the routed/broadcast hybrid.

Contracts pinned here:

  * determinism — the hot tracker is a pure fold over ingest deltas: the
    same stream produces a bit-identical hot set whether the rows arrive
    through plain appends or through the device ring (enqueue+flush),
    whether the shard axis is vmap-emulated or a real forced-8 shard_map
    mesh, and regardless of row order WITHIN a delta (the fold counts a
    multiset per delta, not a sequence),
  * exactness — in ``topk`` mode with capacity >= distinct keys the
    per-shard counts equal an exact host-side bincount; in ``sketch``
    mode the count-min estimates upper-bound and agree on heavy hitters,
  * parity — the hybrid flavors are bit-identical to the pure-routing
    oracle: hot hits, cold hits, misses, EMPTY pads, a stale mirror
    (version gating degrades to pure routing), and deeper-than-mirror
    ``max_matches`` (static fallback) all produce the same bits,
  * planning — rules L4/J4 fire exactly when a fresh-capable mirror
    covers the read, with the uniform reason format (est_fanout,
    pending_ring_rows, hot_fraction),
  * supervision — a killed shard blanks its tracker slice and stales the
    mirror; heal restores BOTH bit-identically; under capacity pressure
    a hot-only batch answers from the mirror with zero drops and zero
    retries while pure routing must retry (the satellite-1 fix),
  * elasticity — reshard re-seeds the tracker onto the new owners and
    re-mirrors, so L4 keeps firing across topology changes.
"""

import os
import subprocess
import sys
import tempfile

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

pytest.importorskip("repro.dist")

from repro import dist
from repro.core import Schema, hashing
from repro.core import planner as planner_mod
from repro.core import table as table_mod
from repro.core.hashindex import EMPTY_KEY
from repro.dist import dtable as dt_mod
from repro.dist import resilience, runtime as drt
from repro.frame import IndexedFrame

NDEV = len(jax.devices())
SCH = Schema.of("k", k="int64", v="float32")

KEYS = st.lists(st.integers(min_value=0, max_value=11), min_size=1,
                max_size=60)


def _cols_from(keys, base=0):
    keys = np.asarray(keys, np.int64)
    return {"k": keys,
            "v": (np.arange(len(keys), dtype=np.float32) * 0.5
                  + np.float32(base))}


def _skewed(rng, n=120, celebrity=7):
    k = np.where(rng.random(n) < 0.5, np.int64(celebrity),
                 rng.integers(100, 200, n).astype(np.int64))
    return {"k": k, "v": np.arange(n, dtype=np.float32)}


def _tracker_leaves(dt):
    h = dt.table.hot
    out = {"keys": np.asarray(h.keys), "counts": np.asarray(h.counts)}
    if h.sketch is not None:
        out["sketch"] = np.asarray(h.sketch)
    return out


def _assert_same_tracker(a, b):
    for k in a:
        np.testing.assert_array_equal(a[k], b[k], err_msg=f"tracker {k}")


def _assert_same_answers(res_a, res_b):
    ca, va = res_a
    cb, vb = res_b
    np.testing.assert_array_equal(np.asarray(va), np.asarray(vb))
    for k in ca:
        np.testing.assert_array_equal(np.asarray(ca[k]),
                                      np.asarray(cb[k]), err_msg=k)


# -- tracker determinism ------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(st.lists(KEYS, min_size=1, max_size=4))
def test_property_tracker_flush_equals_coalesced_append(deltas):
    """The ring flush folds its coalesced pending rows into the tracker
    as ONE delta — bit-identical to appending the coalesced rows."""
    base = _cols_from([0, 1, 2, 3])
    fa = IndexedFrame.from_columns(base, SCH, num_shards=2, track_hot=16,
                                   rows_per_batch=16, reserve=1024)
    fb = fa.with_queue(lanes=4, lane_rows=256)
    all_rows = [_cols_from(dk, 10 * i) for i, dk in enumerate(deltas)]
    merged = {c: np.concatenate([r[c] for r in all_rows]) for c in ("k", "v")}
    fa = fa.append(merged)
    for r in all_rows:
        fb = fb.enqueue(r)
    fb = fb.flush()
    _assert_same_tracker(_tracker_leaves(fa.data), _tracker_leaves(fb.data))


@settings(max_examples=10, deadline=None)
@given(KEYS, st.integers(min_value=0, max_value=2 ** 31 - 1))
def test_property_tracker_permutation_invariant_within_delta(keys, seed):
    """One delta is a multiset: permuting its rows cannot change the
    tracker (the fold sorts before counting)."""
    base = _cols_from([0, 1, 2, 3])
    perm = np.random.default_rng(seed).permutation(len(keys))
    cols = _cols_from(keys)
    fa = IndexedFrame.from_columns(base, SCH, num_shards=2, track_hot=16,
                                   reserve=256).append(cols)
    fb = IndexedFrame.from_columns(base, SCH, num_shards=2, track_hot=16,
                                   reserve=256).append(
        {c: cols[c][perm] for c in cols})
    _assert_same_tracker(_tracker_leaves(fa.data), _tracker_leaves(fb.data))


@settings(max_examples=10, deadline=None)
@given(st.lists(KEYS, min_size=1, max_size=4))
def test_property_topk_counts_exact_when_capacity_covers(deltas):
    """topk capacity >= distinct keys => Misra-Gries lower bounds are
    exact: per-shard (key, count) pairs equal a host bincount over the
    shard's ingested rows (creation rows are NOT back-counted)."""
    fr = IndexedFrame.from_columns(_cols_from([0, 1, 2, 3]), SCH,
                                   num_shards=2, track_hot=16, reserve=1024)
    streamed = np.concatenate([np.asarray(dk, np.int64) for dk in deltas])
    for i, dk in enumerate(deltas):
        fr = fr.append(_cols_from(dk, 10 * i))
    t = _tracker_leaves(fr.data)
    owner = hashing.partition_hash_host(streamed, 2)
    for s in range(2):
        mine = streamed[owner == s]
        want = {int(k): int(c) for k, c in
                zip(*np.unique(mine, return_counts=True))}
        got = {int(k): int(c)
               for k, c in zip(t["keys"][s], t["counts"][s])
               if k != int(np.asarray(EMPTY_KEY))}
        assert got == want


def test_sketch_mode_upper_bounds_and_agrees_on_heavy_hitter():
    rng = np.random.default_rng(3)
    cols = _skewed(rng, n=300)
    kw = dict(num_shards=2, reserve=1024)
    fr_t = IndexedFrame.from_columns(_cols_from([0]), SCH, track_hot=8,
                                     **kw).append(cols)
    fr_s = IndexedFrame.from_columns(_cols_from([0]), SCH, track_hot=8,
                                     hot_mode="sketch", **kw).append(cols)
    for fr in (fr_t, fr_s):
        t = _tracker_leaves(fr.data)
        flat = {int(k): int(c) for ks, cs in zip(t["keys"], t["counts"])
                for k, c in zip(ks, cs) if k != int(np.asarray(EMPTY_KEY))}
        # the celebrity tops both trackers...
        assert max(flat, key=flat.get) == 7
        # ...topk is a lower bound, the sketch an upper bound
        true = int((cols["k"] == 7).sum())
        if fr is fr_t:
            assert flat[7] <= true
        else:
            assert flat[7] >= true


# -- hybrid parity vs the pure-routing oracle ---------------------------------


def _built_replicated(rng, num_shards=4):
    fr = IndexedFrame.from_columns(_cols_from([0, 1, 2, 3]), SCH,
                                   num_shards=num_shards, track_hot=16,
                                   reserve=4096)
    fr = fr.with_replica(capacity=8, max_matches=4)
    return fr.append(_skewed(rng, n=200))     # auto-refreshes the mirror


QUERIES = st.lists(
    st.one_of(st.just(7),                      # the celebrity (hot)
              st.integers(min_value=100, max_value=199),   # cold hits
              st.integers(min_value=5000, max_value=5010),  # misses
              st.just(int(np.asarray(EMPTY_KEY)))),         # pad lanes
    min_size=1, max_size=40)


@settings(max_examples=15, deadline=None)
@given(QUERIES, st.integers(min_value=0, max_value=2 ** 31 - 1))
def test_property_hybrid_bit_identical_to_routed(qkeys, seed):
    fr = _built_replicated(np.random.default_rng(seed % 5))
    q = np.asarray(qkeys, np.int64)
    _assert_same_answers(fr.lookup(q, max_matches=4, op="hybrid"),
                         fr.lookup(q, max_matches=4, op="routed"))
    rep = fr.data.replica
    assert int(np.asarray(rep.version)) == int(np.asarray(fr.data.version))
    elig, _ = dt_mod._replica_split(fr.data, jnp.asarray(q))
    elig = np.asarray(elig)
    assert elig[q == 7].all()                  # celebrity answered locally
    assert not elig[q == int(np.asarray(EMPTY_KEY))].any()   # pads never


def test_hybrid_join_bit_identical_to_shuffle():
    fr = _built_replicated(np.random.default_rng(1))
    probe = {"k": np.array([7, 150, 42, 7], np.int64),
             "w": np.arange(4, dtype=np.float32)}
    bh, ph, vh = fr.join(probe, "k", max_matches=4, op="hybrid")
    bs, ps, vs = fr.join(probe, "k", max_matches=4, op="shuffle")
    np.testing.assert_array_equal(np.asarray(vh), np.asarray(vs))
    for k in bh:
        np.testing.assert_array_equal(np.asarray(bh[k]), np.asarray(bs[k]))
    for k in ph:
        np.testing.assert_array_equal(np.asarray(ph[k]), np.asarray(ps[k]))


def test_stale_mirror_version_gated_to_pure_routing():
    """An un-refreshed mirror after a version bump is never consulted:
    eligibility collapses to empty and the hybrid IS the routed path."""
    fr = _built_replicated(np.random.default_rng(2))
    dt2 = dist.append_distributed(fr.data, _cols_from([7, 7, 300], 99),
                                  rt=fr.rt)       # raw append: NO refresh
    q = jnp.asarray(np.array([7, 150, 300], np.int64))
    elig, _ = dt_mod._replica_split(dt2, q)
    assert not bool(np.asarray(elig).any())
    _assert_same_answers(
        dist.lookup_hybrid_flat(dt2, q, max_matches=4, rt=fr.rt),
        dist.lookup_routed_flat(dt2, q, max_matches=4, rt=fr.rt))


def test_deeper_than_mirror_static_fallback():
    """max_matches > replica.max_matches cannot be served from the
    mirror prefix — the hybrid statically lowers to pure routing."""
    fr = _built_replicated(np.random.default_rng(4))
    q = np.array([7, 150], np.int64)
    _assert_same_answers(fr.lookup(q, max_matches=16, op="hybrid"),
                         fr.lookup(q, max_matches=16, op="routed"))
    assert fr.plan_lookup(np.full(5000, 7, np.int64),
                          max_matches=16).kind == "RoutedLookup"


# -- planner rules + uniform reasons ------------------------------------------


def test_planner_L4_J4_and_uniform_reasons():
    fr = _built_replicated(np.random.default_rng(5))
    q_big = np.full(5000, 7, np.int64)
    p = fr.plan_lookup(q_big, max_matches=4)
    assert p.kind == "HybridLookup" and "L4" in p.reason
    assert "est_fanout=hot:0x cold:1x" in p.reason
    assert "pending_ring_rows=0" in p.reason
    assert "hot_fraction=1.00" in p.reason
    p3 = fr.plan_lookup(q_big, max_matches=16)     # deeper than mirror
    assert p3.kind == "RoutedLookup" and "L3" in p3.reason
    assert "est_fanout=1x" in p3.reason
    p2 = fr.plan_lookup(np.array([7], np.int64), max_matches=4)
    assert p2.kind == "BroadcastLookup" and "L2" in p2.reason
    assert "est_fanout=4x" in p2.reason
    small = planner_mod.Planner(max_matches=4, bcast_threshold=10)
    pj = fr.plan_join({"k": q_big[:50]}, "k", max_matches=4, planner=small)
    assert pj.kind == "HybridJoin" and "J4" in pj.reason
    assert "est_fanout=hot:0x cold:1x" in pj.reason
    # no mirror -> L3/J3 exactly as before the feature
    bare = IndexedFrame.from_columns(_cols_from([0, 1]), SCH, num_shards=4,
                                     reserve=64)
    pb = bare.plan_lookup(q_big, max_matches=4)
    assert pb.kind == "RoutedLookup" and "L3" in pb.reason


def test_pending_ring_rows_annotation_counts_unflushed():
    fr = _built_replicated(np.random.default_rng(6)).with_queue(
        lanes=2, lane_rows=64)
    fr = fr.enqueue(_cols_from([7, 7, 8]))
    p = fr.plan_lookup(np.full(5000, 7, np.int64), max_matches=4)
    assert "pending_ring_rows=3" in p.reason


# -- supervision: kill+heal, pressure retries ---------------------------------


def test_supervised_kill_heal_restores_tracker_and_mirror_bitwise():
    rng = np.random.default_rng(7)
    base = _cols_from([0, 1, 2, 3])
    fr = IndexedFrame.from_columns(base, SCH, num_shards=4, track_hot=16,
                                   reserve=4096)
    fr = fr.with_replica(capacity=8, max_matches=4)
    lin = drt.Lineage(SCH, base, rows_per_batch=fr.data.table.rows_per_batch)
    delta = _skewed(rng, n=200)
    fr = fr.append(delta)
    lin.record_append(delta)
    want_rep = fr.data.replica
    want_hot = _tracker_leaves(fr.data)
    q = np.full(64, 7, np.int64)
    want = fr.lookup(q, max_matches=4, op="routed")

    mgr = fr.supervised(lineage=lin, checkpoint_dir=tempfile.mkdtemp())
    mgr.frame = type(fr)(data=drt.fail_shard(mgr.frame.data, 2),
                         rt=fr.rt, queue=fr.queue)
    killed = mgr.frame.data
    assert int(np.asarray(killed.replica.version)) == -1   # mirror staled
    assert (np.asarray(killed.table.hot.keys)[2]
            == int(np.asarray(EMPTY_KEY))).all()           # slice blanked
    got = mgr.lookup(q, max_matches=4)                     # heals inline
    _assert_same_answers(got, want)
    assert mgr.last_report.recovered == (2,)
    healed = mgr.frame.data
    _assert_same_tracker(_tracker_leaves(mgr.frame.data), want_hot)
    np.testing.assert_array_equal(np.asarray(healed.replica.keys),
                                  np.asarray(want_rep.keys))
    np.testing.assert_array_equal(np.asarray(healed.replica.valid),
                                  np.asarray(want_rep.valid))
    for k in want_rep.cols:
        np.testing.assert_array_equal(np.asarray(healed.replica.cols[k]),
                                      np.asarray(want_rep.cols[k]))
    assert (int(np.asarray(healed.replica.version))
            == int(np.asarray(want_rep.version)))


def test_capacity_pressure_hot_batch_answers_from_mirror_without_retries():
    """The satellite-1 fix: under exchange pressure a celebrity-only
    batch is fully served by the mirror (0 drops, 0 retries), while the
    same batch on a mirror-less frame must drop and retry its way
    through the throttled exchange."""
    q = np.full(64, 7, np.int64)        # every lane targets ONE owner

    def pressured(fr, op):
        inj = resilience.FaultInjector(
            [resilience.Fault(kind="capacity_pressure", step=0,
                              severity=4.0)])
        mgr = fr.supervised(injector=inj)
        out = mgr.lookup(q, max_matches=4, op=op)
        return out, mgr.last_report

    fr_h = _built_replicated(np.random.default_rng(8), num_shards=4)
    bare = IndexedFrame.from_columns(_cols_from([0, 1, 2, 3]), SCH,
                                     num_shards=4, reserve=4096)
    bare = bare.append(_skewed(np.random.default_rng(8), n=200))
    got_h, rep_h = pressured(fr_h, "hybrid")
    got_r, rep_r = pressured(bare, "routed")
    assert rep_h.dropped == 0 and rep_h.retries == 0
    assert rep_r.retries > 0                # pure routing had to double
    assert rep_r.dropped == 0               # ...but delivered in the end
    _assert_same_answers(got_h, got_r)
    assert rep_h.answered.all() and rep_r.answered.all()


# -- elasticity ---------------------------------------------------------------


def test_reshard_reseeds_tracker_and_remirrors():
    fr = _built_replicated(np.random.default_rng(9), num_shards=4)
    q = np.full(5000, 7, np.int64)
    want = fr.lookup(q, max_matches=4, op="routed")
    fr2 = fr.reshard(2)
    assert fr2.data.table.hot is not None
    assert (int(np.asarray(fr2.data.replica.version))
            == int(np.asarray(fr2.data.version)))
    p = fr2.plan_lookup(q, max_matches=4)
    assert p.kind == "HybridLookup"          # L4 survives the topology flip
    _assert_same_answers(fr2.lookup(q, max_matches=4), want)
    # the celebrity's count rode along to its new owner
    t = _tracker_leaves(fr2.data)
    flat = {int(k): int(c) for ks, cs in zip(t["keys"], t["counts"])
            for k, c in zip(ks, cs) if k != int(np.asarray(EMPTY_KEY))}
    assert flat.get(7, 0) > 0


# -- forced-8 shard_map determinism -------------------------------------------

_SUBPROCESS_SKEW = r"""
import numpy as np, jax, jax.numpy as jnp
assert len(jax.devices()) == 8, jax.devices()
from repro.core.schema import Schema
from repro.frame import IndexedFrame
from repro.dist import mesh
from repro.dist import dtable as dt_mod

SCH = Schema.of("k", k="int64", v="float32")
rng = np.random.default_rng(11)
base = {"k": np.arange(4, dtype=np.int64),
        "v": np.zeros(4, np.float32)}
stream_k = np.where(rng.random(160) < 0.5, np.int64(7),
                    rng.integers(100, 200, 160).astype(np.int64))
stream = {"k": stream_k, "v": np.arange(160, dtype=np.float32)}


def build(rt):
    fr = IndexedFrame.from_columns(base, SCH, num_shards=8, rt=rt,
                                   track_hot=16, reserve=4096)
    fr = fr.with_replica(capacity=8, max_matches=4)
    return fr.append(stream)


fv = build(None)                      # vmap emulation
fm = build(mesh.mesh_runtime(8))      # real shard_map mesh
hv, hm = fv.data.table.hot, fm.data.table.hot
np.testing.assert_array_equal(np.asarray(hv.keys), np.asarray(hm.keys))
np.testing.assert_array_equal(np.asarray(hv.counts), np.asarray(hm.counts))
np.testing.assert_array_equal(np.asarray(fv.data.replica.keys),
                              np.asarray(fm.data.replica.keys))
for k in fv.data.replica.cols:
    np.testing.assert_array_equal(np.asarray(fv.data.replica.cols[k]),
                                  np.asarray(fm.data.replica.cols[k]))
q = np.array([7, 150, 5000, int(np.asarray(dt_mod.EMPTY_KEY))], np.int64)
for fr in (fv, fm):
    ch, vh = fr.lookup(q, max_matches=4, op="hybrid")
    cr, vr = fr.lookup(q, max_matches=4, op="routed")
    np.testing.assert_array_equal(np.asarray(vh), np.asarray(vr))
    for k in ch:
        np.testing.assert_array_equal(np.asarray(ch[k]), np.asarray(cr[k]))
print("SKEW_8DEV_OK")
"""


def _run_forced_8(script: str) -> subprocess.CompletedProcess:
    import repro
    src = os.path.dirname(os.path.dirname(os.path.abspath(repro.__file__)))
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=8").strip()
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    return subprocess.run([sys.executable, "-c", script],
                          capture_output=True, text=True, env=env,
                          timeout=600)


@pytest.mark.skipif(NDEV >= 8, reason="in-process mesh tests already "
                    "run on this topology")
def test_same_stream_same_hot_set_on_forced_8_mesh_subprocess():
    """The acceptance property: one ingest stream, two topologies
    (vmap emulation vs an 8-device shard_map mesh) — bit-identical hot
    set, mirror, and hybrid answers."""
    proc = _run_forced_8(_SUBPROCESS_SKEW)
    assert proc.returncode == 0, proc.stdout + "\n" + proc.stderr
    assert "SKEW_8DEV_OK" in proc.stdout
