"""ISSUE 7: the device-resident append queue (DESIGN.md §13).

Property tests: for random delta sequences, enqueue+flush, ONE coalesced
list append, and N sequential appends must answer every lookup with
bit-identical decoded columns and valid masks — locally, on the
vmap-distributed backend, and (forced-8 subprocess) on shard_map.
Plus the MVCC visibility contract (queued rows invisible, one version
bump per flush), the overflow -> promote path, ring-full behaviour
(QueueOverflow vs ``append(queued=True)`` auto-flush), the zero-retrace
guarantee across full ring wraps, the ≤1-host-sync flush, and the
vectorized string hasher's bit-identity with the scalar reference.
"""

import os
import subprocess
import sys

import numpy as np
import jax
import pytest
from hypothesis import given, settings, strategies as st

from repro import IndexedFrame
from repro.core import Schema, table as table_mod
from repro.core.hashing import hash_string_host, hash_strings_host
from repro.dist import mesh

NDEV = len(jax.devices())
SCH = Schema.of("k", k="int64", v="float32")

KEYS = st.lists(st.integers(min_value=0, max_value=11), min_size=1,
                max_size=24)
DELTAS = st.lists(KEYS, min_size=1, max_size=6)


def _base(n=64):
    rng = np.random.default_rng(0)
    return {"k": rng.integers(0, 12, n).astype(np.int64),
            "v": rng.random(n).astype(np.float32)}


def _delta(keys, tag):
    keys = np.asarray(keys, np.int64)
    return {"k": keys,
            "v": (np.arange(len(keys), dtype=np.float32) * 0.5
                  + np.float32(tag))}


def _vals(fr, max_matches=256):
    cols, valid = fr.lookup(np.arange(12, dtype=np.int64),
                            max_matches=max_matches)
    m = np.asarray(valid)
    return np.where(m, np.asarray(cols["v"]), np.nan), m


def _assert_same(fa, fb, tag=""):
    va, ma = _vals(fa)
    vb, mb = _vals(fb)
    np.testing.assert_array_equal(ma, mb, err_msg=tag)
    np.testing.assert_array_equal(va, vb, err_msg=tag)


# --- equivalence: enqueue+flush == coalesced == sequential -----------------

@settings(max_examples=20, deadline=None)
@given(DELTAS)
def test_queue_flush_equivalence_local(key_lists):
    deltas = [_delta(ks, i) for i, ks in enumerate(key_lists)]
    fr0 = IndexedFrame.from_columns(_base(), SCH, rows_per_batch=64,
                                    reserve=1024)
    fq = fr0.with_queue(lanes=8, lane_rows=32)
    for d in deltas:
        fq = fq.enqueue(d, donate=False)
    fq = fq.flush()
    fc = fr0.append(list(deltas))
    fs = fr0
    for d in deltas:
        fs = fs.append(d)
    _assert_same(fq, fc, "queued vs coalesced")
    _assert_same(fq, fs, "queued vs sequential")
    assert fq.version == fc.version == 1
    assert fs.version == len(deltas)
    assert fq.pending_rows == 0


@settings(max_examples=8, deadline=None)
@given(DELTAS)
def test_queue_flush_equivalence_dist_vmap(key_lists):
    deltas = [_delta(ks, i) for i, ks in enumerate(key_lists)]
    fr0 = IndexedFrame.from_columns(_base(), SCH, num_shards=4,
                                    rt=mesh.vmap_runtime(),
                                    rows_per_batch=64, reserve=1024)
    fq = fr0.with_queue(lanes=8, lane_rows=32)
    for d in deltas:
        fq = fq.enqueue(d, donate=False)
    fq = fq.flush()
    fc = fr0.append(list(deltas))
    _assert_same(fq, fc, "dist queued vs coalesced")
    assert fq.version == fc.version
    assert fq.pending_rows == 0


# --- MVCC visibility --------------------------------------------------------

def test_queued_rows_invisible_until_flush():
    fr = IndexedFrame.from_columns(_base(), SCH, rows_per_batch=64,
                                   reserve=1024).with_queue(lanes=4,
                                                            lane_rows=32)
    v0, m0 = _vals(fr)
    fr = fr.enqueue(_delta([3, 3, 7], 9), donate=False)
    assert fr.pending_deltas == 1 and fr.pending_rows == 3
    v1, m1 = _vals(fr)
    np.testing.assert_array_equal(m0, m1)   # ring rows hard-masked out
    np.testing.assert_array_equal(v0, v1)
    assert fr.version == 0                  # no bump before flush
    assert "pending_ring_rows=3" in fr.plan_lookup(np.arange(4)).reason
    fr = fr.flush()
    assert fr.version == 1                  # exactly ONE bump for the ring
    _, m2 = _vals(fr)
    assert m2.sum() == m0.sum() + 3


# --- overflow -> promote ----------------------------------------------------

def test_flush_overflow_promotes_bit_identical():
    deltas = [_delta(list(range(10)), i) for i in range(3)]
    fr0 = IndexedFrame.from_columns(_base(), SCH, rows_per_batch=64,
                                    reserve=8)   # ring > spare capacity
    t0 = fr0.data
    q = table_mod.empty_queue(SCH, lanes=4, lane_rows=16)
    for d in deltas:
        q = table_mod.enqueue(q, d, donate=False)
    child, ring, promoted = table_mod.flush_queue(t0, q)
    assert promoted                          # held flush took the promote path
    assert table_mod.queue_pending(ring) == (0, 0)
    ref = fr0.append(list(deltas))
    import dataclasses
    _assert_same(dataclasses.replace(fr0, data=child), ref, "promoted parity")
    assert int(np.asarray(child.version)) == int(np.asarray(ref.data.version))


# --- ring-full: QueueOverflow vs append(queued=True) auto-flush -------------

def test_ring_full_raises_and_queued_append_autoflushes():
    fr = IndexedFrame.from_columns(_base(), SCH, rows_per_batch=64,
                                   reserve=1024).with_queue(lanes=2,
                                                            lane_rows=16)
    d = _delta([1, 2, 3], 0)
    fr = fr.enqueue(d, donate=False).enqueue(d, donate=False)
    with pytest.raises(table_mod.QueueOverflow):
        fr.enqueue(d, donate=False)
    with pytest.raises(table_mod.QueueOverflow):   # oversize delta
        fr.flush().enqueue(_delta(list(range(17)), 0), donate=False)
    # the facade auto-flushes instead of raising
    fr2 = IndexedFrame.from_columns(_base(), SCH, rows_per_batch=64,
                                    reserve=1024).with_queue(lanes=2,
                                                             lane_rows=16)
    deltas = [_delta([i, i + 1], i) for i in range(5)]
    for dd in deltas:
        fr2 = fr2.append(dd, queued=True)
    fr2 = fr2.flush()
    _assert_same(fr2, IndexedFrame.from_columns(
        _base(), SCH, rows_per_batch=64, reserve=1024).append(deltas),
        "auto-flush stream parity")
    assert fr2.pending_rows == 0


# --- zero retraces across full ring wraps ----------------------------------

@pytest.mark.parametrize("dist", [False, True])
def test_ring_wrap_zero_retraces(dist):
    kw = (dict(num_shards=4, rt=mesh.vmap_runtime()) if dist else {})
    fr = IndexedFrame.from_columns(_base(), SCH, rows_per_batch=64,
                                   reserve=4096, **kw).with_queue(
                                       lanes=3, lane_rows=16)
    traced = None
    for wrap in range(3):
        for i in range(3):
            fr = fr.enqueue(_delta([wrap, i, 5], wrap * 3 + i), donate=False)
        fr = fr.flush()
        if wrap == 0:
            traced = dict(table_mod.QUEUE_TRACES)
    assert dict(table_mod.QUEUE_TRACES) == traced, (
        "enqueue/flush retraced after the first full ring wrap")


# --- ≤1 host sync per flush -------------------------------------------------

def test_flush_costs_one_host_sync(monkeypatch):
    fr = IndexedFrame.from_columns(_base(), SCH, rows_per_batch=64,
                                   reserve=1024).with_queue(lanes=4,
                                                            lane_rows=32)
    for i in range(3):
        fr = fr.enqueue(_delta([i, i], i), donate=False)
    real = jax.device_get
    syncs = {"n": 0}

    def counting(x):
        syncs["n"] += 1
        return real(x)

    monkeypatch.setattr(jax, "device_get", counting)
    fr = fr.flush()
    monkeypatch.setattr(jax, "device_get", real)
    assert syncs["n"] == 1, f"flush cost {syncs['n']} host syncs, want 1"
    assert fr.version == 1


def test_enqueue_costs_zero_host_syncs(monkeypatch):
    fr = IndexedFrame.from_columns(_base(), SCH, rows_per_batch=64,
                                   reserve=1024).with_queue(lanes=4,
                                                            lane_rows=32)
    real = jax.device_get
    syncs = {"n": 0}

    def counting(x):
        syncs["n"] += 1
        return real(x)

    monkeypatch.setattr(jax, "device_get", counting)
    fr = fr.enqueue(_delta([1, 2], 0), donate=False)
    monkeypatch.setattr(jax, "device_get", real)
    assert syncs["n"] == 0, f"enqueue cost {syncs['n']} host syncs, want 0"
    assert fr.pending_rows == 2     # host mirror, no device round-trip


# --- vectorized string hashing ---------------------------------------------

@settings(max_examples=50, deadline=None)
@given(st.lists(st.text(min_size=0, max_size=12), min_size=0, max_size=16))
def test_hash_strings_host_matches_scalar(strings):
    vec = hash_strings_host(strings)
    ref = np.array([np.int64(np.uint64(hash_string_host(s)
                                       & 0xFFFFFFFFFFFFFFFF))
                    for s in strings], dtype=np.int64)
    np.testing.assert_array_equal(vec, ref)


def test_string_keys_stream_through_queue():
    names = [f"user-{i}" for i in range(40)]
    rng = np.random.default_rng(2)
    cols = {"k": np.array(names, dtype=object),
            "v": rng.random(40).astype(np.float32)}
    fr = IndexedFrame.from_columns(cols, SCH, rows_per_batch=64,
                                   reserve=512).with_queue(lanes=4,
                                                           lane_rows=16)
    d = {"k": ["user-new-a", "user-new-b"],
         "v": np.array([1.5, 2.5], np.float32)}
    fr = fr.enqueue(d, donate=False).flush()
    q = hash_strings_host(["user-3", "user-new-b", "missing"])
    got, valid = fr.lookup(q, max_matches=4)
    m = np.asarray(valid)
    assert m[0].any() and m[1].any() and not m[2].any()
    assert np.asarray(got["v"])[1][m[1]][0] == np.float32(2.5)


@settings(max_examples=30, deadline=None)
@given(st.lists(st.lists(st.text(min_size=0, max_size=12),
                         min_size=0, max_size=12),
                min_size=0, max_size=4))
def test_string_dictionary_codes_bit_identical(batches):
    from repro.core.hashing import StringDictionary
    d = StringDictionary()
    seen = set()
    for b in batches:
        np.testing.assert_array_equal(d.encode(b), hash_strings_host(b))
        seen |= set(b)
    assert len(d) == len(seen)
    assert d.hashed == len(seen)          # each unique hashed exactly once
    before = d.reused
    codes = d.encode(sorted(seen))        # warm dict: every row reused
    assert d.reused == before + len(seen) and d.hashed == len(seen)
    assert d.decode(codes) == sorted(seen)


def test_string_dictionary_through_facade():
    from repro.core.hashing import StringDictionary
    d = StringDictionary()
    names = [f"user-{i % 8}" for i in range(24)]
    vals = np.arange(24, dtype=np.float32)
    fr_d = IndexedFrame.from_columns(
        {"k": np.array(names, dtype=object), "v": vals}, SCH,
        rows_per_batch=64, reserve=256, dictionary=d)
    fr_p = IndexedFrame.from_columns(
        {"k": np.array(names, dtype=object), "v": vals}, SCH,
        rows_per_batch=64, reserve=256)
    assert d.hashed == 8      # 24 rows, 8 unique strings byte-walked
    delta = {"k": ["user-3", "user-99"], "v": np.array([9., 9.],
                                                      np.float32)}
    fr_d = fr_d.append(dict(delta), dictionary=d)
    fr_p = fr_p.append(dict(delta))
    assert d.hashed == 9      # only the novel string paid the byte walk
    assert d.reused == 1      # "user-3" answered from the warm table
    q = hash_strings_host(["user-3", "user-99"])
    cd, vd = fr_d.lookup(q, max_matches=8)
    cp, vp = fr_p.lookup(q, max_matches=8)
    np.testing.assert_array_equal(np.asarray(vd), np.asarray(vp))
    md = np.asarray(vd)
    np.testing.assert_array_equal(np.asarray(cd["v"])[md],
                                  np.asarray(cp["v"])[md])


# --- shard_map backend (forced-8 when single-device) ------------------------

_SUBPROCESS_QUEUE = r"""
import numpy as np, jax
from repro import IndexedFrame
from repro.core import Schema
from repro.dist import mesh
assert len(jax.devices()) == 8, jax.devices()
SCH = Schema.of("k", k="int64", v="float32")
rng = np.random.default_rng(11)
cols = {"k": rng.integers(0, 100, 400).astype(np.int64),
        "v": rng.random(400).astype(np.float32)}
deltas = [{"k": rng.integers(0, 100, 32).astype(np.int64),
           "v": rng.random(32).astype(np.float32)} for _ in range(3)]
q = np.arange(100, dtype=np.int64)
outs = []
for rt in (mesh.vmap_runtime(), mesh.mesh_runtime(8)):
    f = IndexedFrame.from_columns(cols, SCH, num_shards=8, rows_per_batch=64,
                                  rt=rt).with_queue(lanes=4, lane_rows=32)
    for d in deltas:
        f = f.enqueue(d)
    assert f.pending_rows == 96, f.pending_rows
    f = f.flush()
    assert f.pending_rows == 0
    c, v = f.lookup(q, max_matches=16)
    outs.append((np.asarray(c["v"]), np.asarray(v)))
np.testing.assert_array_equal(outs[0][0], outs[1][0])
np.testing.assert_array_equal(outs[0][1], outs[1][1])
# held flush -> promote under shard_map, donated end to end
mk = lambda: IndexedFrame.from_columns(cols, SCH, num_shards=8,
                                       rows_per_batch=64,
                                       rt=mesh.mesh_runtime(8), reserve=8)
ref = mk().append(list(deltas))
f = mk().with_queue(lanes=4, lane_rows=32)
for d in deltas:
    f = f.enqueue(d)
f = f.flush(donate=True)
ca, va = f.lookup(q, max_matches=16)
cb, vb = ref.lookup(q, max_matches=16)
np.testing.assert_array_equal(np.asarray(va), np.asarray(vb))
np.testing.assert_array_equal(np.asarray(ca["v"]), np.asarray(cb["v"]))
assert f.version == ref.version, (f.version, ref.version)
print("QUEUE_PARITY_8DEV_OK")
"""


@pytest.mark.skipif(NDEV < 8, reason="needs 8 devices (ci.sh forced-8 "
                    "pass; the subprocess test covers single-device runs)")
def test_queue_parity_shard_map_in_process():
    exec(compile(_SUBPROCESS_QUEUE, "<queue-parity>", "exec"), {})


@pytest.mark.skipif(NDEV >= 8, reason="in-process test runs on this "
                    "topology")
def test_queue_parity_shard_map_subprocess():
    """Queue parity on the shard_map backend, forced-8 host topology."""
    import repro
    src = os.path.dirname(os.path.dirname(os.path.abspath(repro.__file__)))
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=8").strip()
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", _SUBPROCESS_QUEUE],
                          capture_output=True, text=True, env=env,
                          timeout=600)
    assert proc.returncode == 0, proc.stdout + "\n" + proc.stderr
    assert "QUEUE_PARITY_8DEV_OK" in proc.stdout
