"""Dry-run smoke via subprocess (needs its own XLA device-count flag).

Small mesh (2x2 / 1x2x2), reduced configs, reduced shapes — proves the
launch stack (shardings, step factories, HLO analysis) composes end to
end.  The production 512-device run is scripts/run_dryrun.sh -> records in
EXPERIMENTS.md.
"""

import json
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(args, devices=4):
    env = dict(os.environ, PYTHONPATH=SRC, DRYRUN_DEVICES=str(devices))
    env.pop("XLA_FLAGS", None)
    return subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun"] + args,
        env=env, capture_output=True, text=True, timeout=900)


@pytest.mark.parametrize("shape,extra", [
    ("train_4k", ["--seq", "64", "--batch", "4"]),
    ("prefill_32k", ["--seq", "64", "--batch", "4"]),
    ("decode_32k", ["--seq", "128", "--batch", "4"]),
])
def test_dryrun_cells_single_pod(tmp_path, shape, extra):
    out = str(tmp_path / "r.jsonl")
    r = _run(["--arch", "tinyllama-1.1b", "--smoke", "--mesh", "2x2",
              "--shape", shape, "--out", out] + extra)
    assert r.returncode == 0, r.stdout + r.stderr
    rec = json.loads(open(out).read().splitlines()[-1])
    assert rec["ok"]
    assert rec["dot_flops"] > 0
    assert rec["memory"]["temp_size_in_bytes"] > 0


def test_dryrun_multi_pod_axis(tmp_path):
    """The pod axis shards: 1x2x2 mesh with ('pod','data','model')."""
    out = str(tmp_path / "mp.jsonl")
    r = _run(["--arch", "qwen3-0.6b", "--smoke", "--mesh", "2x2x1",
              "--shape", "train_4k", "--seq", "64", "--batch", "4",
              "--out", out])
    assert r.returncode == 0, r.stdout + r.stderr
    rec = json.loads(open(out).read().splitlines()[-1])
    assert rec["ok"]
    assert rec["mesh"] == {"pod": 2, "data": 2, "model": 1}


def test_dryrun_moe_arch(tmp_path):
    """MoE arch exercises the shard_map EP dispatch under jit+scan."""
    out = str(tmp_path / "moe.jsonl")
    r = _run(["--arch", "deepseek-v2-lite-16b", "--smoke", "--mesh", "2x2",
              "--shape", "train_4k", "--seq", "64", "--batch", "4",
              "--out", out])
    assert r.returncode == 0, r.stdout + r.stderr
    rec = json.loads(open(out).read().splitlines()[-1])
    assert rec["ok"]


def test_roofline_from_records(tmp_path):
    out = str(tmp_path / "r.jsonl")
    r = _run(["--arch", "tinyllama-1.1b", "--smoke", "--mesh", "2x2",
              "--shape", "train_4k", "--seq", "64", "--batch", "4",
              "--out", out])
    assert r.returncode == 0, r.stdout + r.stderr
    from repro.launch import roofline
    rec = json.loads(open(out).read().splitlines()[-1])
    t = roofline.terms(rec)
    assert t["compute_s"] > 0 and t["memory_s"] > 0
    assert t["dominant"] in ("compute", "memory", "collective")
    md = roofline.to_markdown([t])
    assert "dominant" in md
