"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.hashindex import build_index, probe as probe_jnp
from repro.kernels import ops, ref
from repro.kernels.decode_attention import decode_paged
from repro.kernels.hash_probe import QUERY_TILE


# --- hash probe ------------------------------------------------------------

@pytest.mark.parametrize("n_keys", [10, 1000, 5000])
@pytest.mark.parametrize("n_query", [1, 255, 1024])
def test_probe_kernel_sweep(rng, n_keys, n_query):
    keys = rng.integers(-2**62, 2**62, n_keys).astype(np.int64)
    idx, _, _ = build_index(keys, np.arange(n_keys, dtype=np.int32))
    q = np.concatenate([
        rng.choice(keys, min(n_query, n_keys)),
        rng.integers(-2**62, 2**62, max(0, n_query - n_keys))
    ])[:n_query].astype(np.int64)
    a = np.asarray(probe_jnp(idx, q))
    b = np.asarray(ops.probe(idx, q, interpret=True))
    np.testing.assert_array_equal(a, b)


def test_probe_kernel_empty_and_negative_keys(rng):
    keys = np.array([-5, 0, 5, np.iinfo(np.int64).max], np.int64)
    idx, _, _ = build_index(keys, np.arange(4, dtype=np.int32))
    q = np.array([-5, 0, 5, np.iinfo(np.int64).max, 17], np.int64)
    a = np.asarray(probe_jnp(idx, q))
    b = np.asarray(ops.probe(idx, q, interpret=True))
    np.testing.assert_array_equal(a, b)
    assert b[4] == -1


def test_probe_kernel_tile_padding(rng):
    """Non-multiple-of-tile query counts are padded internally."""
    keys = rng.integers(0, 10**6, 100).astype(np.int64)
    idx, _, _ = build_index(keys, np.arange(100, dtype=np.int32))
    for nq in (1, QUERY_TILE - 1, QUERY_TILE, QUERY_TILE + 1):
        q = rng.choice(keys, nq).astype(np.int64)
        a = np.asarray(probe_jnp(idx, q))
        b = np.asarray(ops.probe(idx, q, interpret=True))
        np.testing.assert_array_equal(a, b)


# --- fused multi-segment lookup ---------------------------------------------

@pytest.mark.parametrize("n_segments", [1, 3])
@pytest.mark.parametrize("n_query,max_matches", [(1, 4), (255, 1), (600, 8)])
def test_fused_lookup_kernel_sweep(rng, n_segments, n_query, max_matches):
    """Pallas fused kernel (interpret) vs the vectorized flat oracle."""
    from repro.core import Schema, append, create_index
    from repro.kernels import ops
    from repro.kernels import ref as ref_mod
    from repro.core.hashing import bucket_hash, split64
    from repro.kernels.hash_probe import QUERY_TILE, fused_lookup_tiles

    sch = Schema.of("k", k="int64", v="float32")
    base = {"k": rng.integers(0, 120, 400).astype(np.int64),
            "v": rng.random(400).astype(np.float32)}
    t = create_index(base, sch, rows_per_batch=64)
    for _ in range(n_segments - 1):
        t = append(t, {"k": rng.integers(0, 120, 50).astype(np.int64),
                       "v": rng.random(50).astype(np.float32)})
    fv = t.flat_view()

    q = np.concatenate([rng.choice(base["k"], min(n_query, 300)),
                        rng.integers(120, 240, max(0, n_query - 300))
                        ])[:n_query].astype(np.int64)
    pad = (-len(q)) % QUERY_TILE
    qp = jnp.pad(jnp.asarray(q), (0, pad),
                 constant_values=np.iinfo(np.int64).min)
    bids = jnp.stack([bucket_hash(qp, nb) for nb in fv.bucket_counts])
    qhi, qlo = split64(qp)

    rk, lk = fused_lookup_tiles(bids, qhi, qlo, fv,
                                max_matches=max_matches, interpret=True)
    ro, lo = ref_mod.fused_lookup_ref(bids, qhi, qlo, fv, max_matches)
    np.testing.assert_array_equal(np.asarray(rk), np.asarray(ro))
    np.testing.assert_array_equal(np.asarray(lk), np.asarray(lo))

    # ... and through the public dispatcher against the table reference
    rows_k, trunc_k = ops.fused_lookup(q, fv, max_matches=max_matches,
                                       use_kernel=True, interpret=True)
    rows_r, trunc_r = t.lookup_ref(q, max_matches)
    np.testing.assert_array_equal(np.asarray(rows_k), np.asarray(rows_r))
    np.testing.assert_array_equal(np.asarray(trunc_k), np.asarray(trunc_r))


# --- decode attention --------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,hq,hkv,d,page,npages", [
    (1, 4, 1, 64, 8, 2),
    (2, 8, 2, 64, 16, 4),
    (3, 16, 4, 128, 16, 3),
])
def test_decode_attention_sweep(rng, dtype, b, hq, hkv, d, page, npages):
    p_total = b * npages + 2
    q = jnp.asarray(rng.standard_normal((b, hq, d)), dtype)
    kp = jnp.asarray(rng.standard_normal((p_total, page, hkv, d)), dtype)
    vp = jnp.asarray(rng.standard_normal((p_total, page, hkv, d)), dtype)
    pt = np.full((b, npages), -1, np.int32)
    lengths = np.zeros(b, np.int32)
    for i in range(b):
        used = rng.integers(1, npages + 1)
        pt[i, :used] = rng.choice(p_total, used, replace=False)
        lengths[i] = rng.integers(1, used * page + 1)
    out_k = decode_paged(q, kp, vp, jnp.asarray(pt), jnp.asarray(lengths),
                         d ** -0.5, interpret=True)
    out_r = ref.decode_attention_ref(q, kp, vp, jnp.asarray(pt),
                                     jnp.asarray(lengths), d ** -0.5)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               rtol=tol, atol=tol)


def test_decode_attention_single_token(rng):
    """length=1: softmax over one position is exact."""
    b, hq, hkv, d, page = 1, 2, 1, 64, 8
    q = jnp.asarray(rng.standard_normal((b, hq, d)), jnp.float32)
    kp = jnp.asarray(rng.standard_normal((2, page, hkv, d)), jnp.float32)
    vp = jnp.asarray(rng.standard_normal((2, page, hkv, d)), jnp.float32)
    pt = jnp.asarray([[0, -1]], jnp.int32)
    lengths = jnp.asarray([1], jnp.int32)
    out = decode_paged(q, kp, vp, pt, lengths, d ** -0.5, interpret=True)
    np.testing.assert_allclose(np.asarray(out)[0, 0],
                               np.asarray(vp)[0, 0, 0], rtol=1e-5)
