"""Shared test fixtures.

NOTE: no XLA_FLAGS device-count override here — smoke tests and benches
run on the single real CPU device; only launch/dryrun.py (run as a script
or subprocess) forces 512 placeholder devices.
"""

import numpy as np
import pytest

try:  # hermetic containers may lack hypothesis; fall back to the shim
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    from repro import testing as _repro_testing

    _repro_testing.install()


@pytest.fixture
def rng():
    return np.random.default_rng(0)
