"""Shared test fixtures.

NOTE: no XLA_FLAGS device-count override here — smoke tests and benches
run on the single real CPU device; only launch/dryrun.py (run as a script
or subprocess) forces 512 placeholder devices.
"""

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
