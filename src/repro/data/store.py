"""ExampleStore — the training-data pipeline on the Indexed DataFrame.

The paper's threat-detection / social-graph pattern is "data keeps arriving
in fine-grained appends; queries must see it without a full reload".  The
training analog: tokenized examples stream in (new crawl shards, RLHF
rollouts), and the input pipeline must serve fresh batches without
rebuilding the dataset.

Structure (exactly the Indexed Batch RDD, §III-C):

  * token buffers  — [num_batches, rows_per_batch, seq_len] int32 device
                     arrays (the row batches; payload kept un-codec'd for
                     zero-copy batch gathers)
  * metadata table — IndexedTable keyed by example_id with (slot, length,
                     weight) columns — the cTrie + backward pointers
  * appends        — one MVCC append of metadata + one new token buffer;
                     parent versions keep serving readers (Listing 2)

``lookup`` (by example id) and ``metadata_join`` (example ↔ curriculum
weight) are the paper's point-lookup / indexed-join run inside the input
pipeline.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Schema, append, create_index, joins
from repro.core import partition as partition_mod

META_SCHEMA = Schema.of("example_id", example_id="int64", slot="int32",
                        length="int32", weight="float32")


@dataclasses.dataclass
class ExampleStore:
    seq_len: int
    rows_per_batch: int = 1024
    buffers: list = dataclasses.field(default_factory=list)  # [rpb, S] each
    table: object = None
    _slots: object = None        # np.int32 [num_examples] valid slot ids
    # Optional core.partition.PartitionSpec over example_id: the metadata
    # table becomes a PartitionedTable (one arena per id window, DESIGN.md
    # §16) so old crawl windows retire in O(1) via ``drop_partition`` and
    # ``memory_report`` attributes arena slack per window.
    partition_by: object = None

    # -- writes ------------------------------------------------------------
    def append_examples(self, example_ids, tokens, weights=None):
        """tokens [N, seq_len] int32; one fine-grained append (paper Fig 10).

        Returns the new store version.
        """
        tokens = np.asarray(tokens, np.int32)
        n = tokens.shape[0]
        assert tokens.shape[1] == self.seq_len
        lengths = (tokens != 0).sum(axis=1).astype(np.int32)
        weights = (np.ones(n, np.float32) if weights is None
                   else np.asarray(weights, np.float32))

        # pack into fixed-capacity buffers (row batches); slot ids are
        # buffer-capacity based, so each append starts on a fresh buffer
        # (padding rows occupy dead slots, exactly like the paper's
        # partially-filled row batches)
        cap = self.rows_per_batch
        slot_base = len(self.buffers) * cap
        slots = np.arange(n, dtype=np.int32) + slot_base
        pad = (-n) % cap
        buf = np.pad(tokens, ((0, pad), (0, 0))).reshape(-1, cap,
                                                         self.seq_len)
        self.buffers.extend(jnp.asarray(b) for b in buf)
        self._slots = slots if self._slots is None else \
            np.concatenate([self._slots, slots])

        cols = {"example_id": np.asarray(example_ids, np.int64),
                "slot": slots, "length": lengths, "weight": weights}
        if self.table is None:
            if self.partition_by is not None:
                self.table = partition_mod.create_partitioned(
                    cols, META_SCHEMA, self.partition_by,
                    rows_per_batch=cap)
            else:
                self.table = create_index(cols, META_SCHEMA,
                                          rows_per_batch=cap)
        elif self.partition_by is not None:
            self.table = partition_mod.append_partitioned(self.table, cols)
        else:
            self.table = append(self.table, cols)
        return int(self.table.version)

    # -- reads ---------------------------------------------------------------
    @property
    def num_examples(self) -> int:
        return 0 if self._slots is None else len(self._slots)

    def slot_of(self, example_index) -> np.ndarray:
        """Dense example index [0, num_examples) -> raw buffer slot."""
        return self._slots[np.asarray(example_index)]

    @property
    def version(self) -> int:
        return 0 if self.table is None else int(self.table.version)

    def gather_tokens(self, slots) -> jnp.ndarray:
        """[B] slots -> [B, seq_len] tokens (one gather per touched buffer)."""
        slots = jnp.asarray(slots, jnp.int32)
        cap = self.rows_per_batch
        stack = jnp.stack(self.buffers)                 # [NB, cap, S]
        return stack[slots // cap, slots % cap]

    def lookup(self, example_ids, max_matches: int = 1):
        """Point lookup by id -> (tokens [Q, M, S], weight, valid)."""
        if self.partition_by is not None:
            cols, valid = partition_mod.lookup_partitioned(
                self.table, jnp.asarray(example_ids, jnp.int64),
                max_matches=max_matches)
        else:
            cols, valid = joins.indexed_lookup(
                self.table, jnp.asarray(example_ids, jnp.int64),
                max_matches=max_matches)
        toks = self.gather_tokens(jnp.maximum(cols["slot"], 0))
        return toks, cols["weight"], valid

    def metadata_join(self, probe_cols: dict, key: str,
                      max_matches: int = 1):
        """Indexed join against the metadata table (curriculum/dedup)."""
        if self.partition_by is not None:
            return partition_mod.join_partitioned(
                self.table, probe_cols, key, max_matches=max_matches)
        return joins.indexed_join(self.table, probe_cols, key,
                                  max_matches=max_matches)

    # -- retention + memory accounting ---------------------------------------
    def drop_partition(self, partition_id):
        """Retire one id window O(1) (partitioned stores only): the
        window's metadata arena is removed structurally — survivors'
        arenas are untouched, readers keep their jit caches.  Token
        buffers are kept (slots stay dense); the retired examples are
        simply unreachable through the index."""
        if self.partition_by is None:
            raise ValueError("store is not partitioned: construct with "
                             "partition_by=PartitionSpec...")
        self.table = partition_mod.drop_partition(self.table, partition_id)
        return int(self.table.version)

    def index_overhead_bytes(self) -> int:
        """Logical index bytes (occupied entries + live-row pointers) —
        the Fig-11 overhead figure; arena slack is capacity planning, not
        index overhead (DESIGN.md §4), and is reported separately by
        ``self.table.index_nbytes()`` / per window by
        ``memory_report()``."""
        if self.table is None:
            return 0
        return int(self.table.index_nbytes(logical=True))

    def memory_report(self) -> list:
        """Logical vs reserved bytes per partition (one entry for a
        monolithic store): cold windows' arena slack is attributed to
        those windows, not smeared over the hot one
        (benchmarks/memory_overhead.py reports the same split)."""
        if self.table is None:
            return []
        if self.partition_by is not None:
            return self.table.per_partition_bytes()
        return [{"partition": None, "desc": "monolithic",
                 "rows": int(np.asarray(self.table.num_rows())),
                 "index_logical": int(self.table.index_nbytes(logical=True)),
                 "index_reserved": int(self.table.index_nbytes()),
                 "data_logical": int(self.table.data_nbytes(logical=True)),
                 "data_reserved": int(self.table.data_nbytes())}]

    def data_bytes(self) -> int:
        return sum(int(b.size) * 4 for b in self.buffers)
