"""data — training-data pipeline on the Indexed DataFrame.

  store.py     ExampleStore: token row-batches + indexed metadata (MVCC)
  pipeline.py  resumable batch sampling, curriculum joins, synth source
"""

from repro.data.store import ExampleStore, META_SCHEMA
from repro.data.pipeline import BatchPipeline, Cursor, synthetic_examples

__all__ = ["ExampleStore", "META_SCHEMA", "BatchPipeline", "Cursor",
           "synthetic_examples"]
