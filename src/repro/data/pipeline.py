"""Batch pipeline over the ExampleStore: deterministic resumable sampling,
streaming-append awareness, curriculum weighting via indexed join.

The cursor is (seed, step) — restoring a checkpoint restores the exact
batch sequence (fault tolerance requires the data order to be replayable,
paper §III-D's replayable-source requirement applied to training).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.store import ExampleStore


@dataclasses.dataclass
class Cursor:
    seed: int
    step: int = 0

    def state_dict(self):
        return {"seed": self.seed, "step": self.step}

    @staticmethod
    def from_state(d):
        return Cursor(seed=int(d["seed"]), step=int(d["step"]))


class BatchPipeline:
    """Samples [batch, seq_len] token batches from a (growing) store."""

    def __init__(self, store: ExampleStore, batch: int, seed: int = 0):
        self.store = store
        self.batch = batch
        self.cursor = Cursor(seed)

    def next_batch(self):
        """Deterministic sample of `batch` slots from the *current* store
        version (appends between steps are picked up automatically — the
        fresh-data-without-reload property the paper targets)."""
        n = self.store.num_examples
        if n == 0:
            raise RuntimeError("empty store")
        rng = np.random.default_rng((self.cursor.seed << 20)
                                    ^ self.cursor.step)
        slots = self.store.slot_of(rng.integers(0, n, self.batch))
        self.cursor.step += 1
        toks = self.store.gather_tokens(slots)
        return {"tokens": toks}

    def weighted_batch(self, weight_table, key: str = "example_id"):
        """Curriculum sampling: join slots -> weights via the indexed join,
        then importance-sample (the paper's metadata-join use case)."""
        n = self.store.num_examples
        rng = np.random.default_rng((self.cursor.seed << 20)
                                    ^ self.cursor.step)
        dense = rng.integers(0, n, self.batch * 4)
        cand = self.store.slot_of(dense)
        toks = self.store.gather_tokens(cand)
        vals, valid = self.store.table.scan_column("example_id")
        # dense index aligns with append order = scan order of valid rows
        ids = np.asarray(vals)[np.asarray(valid)][dense]
        from repro.core import joins
        cols, v = joins.indexed_lookup(weight_table,
                                       jnp.asarray(ids, jnp.int64),
                                       max_matches=1)
        w = np.where(np.asarray(v[:, 0]),
                     np.asarray(cols["weight"][:, 0]), 1.0)
        p = w / w.sum()
        pick = rng.choice(len(cand), self.batch, replace=False, p=p)
        self.cursor.step += 1
        return {"tokens": toks[pick]}


def synthetic_examples(rng, n: int, seq_len: int, vocab: int,
                       id_base: int = 0):
    """Host-side synthetic token source (stands in for Kafka/HDFS)."""
    ids = np.arange(n, dtype=np.int64) + id_base
    toks = rng.integers(1, vocab, (n, seq_len)).astype(np.int32)
    return ids, toks
