"""Production meshes.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state — the dry-run script sets
XLA_FLAGS before any jax init; tests that import this module on the single
real CPU device are unaffected.

Single pod: (data=16, model=16) = 256 chips (one v5e pod).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the ``pod`` axis
composes with ``data`` as the batch/ZeRO super-axis (gradients all-reduce
hierarchically: fast ICI within a pod, DCN between pods — which is why
grad compression targets the pod axis, train/compress.py).
"""

from __future__ import annotations

import jax


def make_mesh(shape, axes):
    """``jax.make_mesh`` across jax versions: ``axis_types``/``AxisType``
    only exist on newer jax; older releases are Auto-by-default anyway."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(axis_type.Auto,) * len(shape))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_mesh_from_spec(spec: str):
    """'16x16' -> (data, model); '2x16x16' -> (pod, data, model).

    Small variants ('2x2', '1x2x2') drive the subprocess tests.
    """
    dims = tuple(int(x) for x in spec.split("x"))
    axes = {2: ("data", "model"), 3: ("pod", "data", "model")}[len(dims)]
    return make_mesh(dims, axes)


def data_axes(mesh) -> tuple:
    """The batch super-axis for this mesh ('pod' composes with 'data')."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def hardware_constants():
    """TPU v5e-class target (per chip)."""
    return {
        "peak_flops_bf16": 197e12,     # FLOP/s
        "hbm_bandwidth": 819e9,        # B/s
        "ici_bandwidth": 50e9,         # B/s per link
        "hbm_bytes": 16 * 2**30,
    }
