"""Roofline terms per (arch × shape × mesh) from dry-run records.

    compute term    = HLO_dot_FLOPs_per_device / peak_FLOP/s
    memory term     = HBM_traffic_per_device   / HBM_bw
    collective term = wire_bytes_per_device    / ICI_bw

All three numerators come from launch/hlo.py's trip-count-corrected parse
of the compiled per-device module (the HLO shapes are post-partitioning,
so "per device" is inherent).  MODEL_FLOPS uses the assignment's formula:
6·N·D (train, N = active params) / 2·N·D (prefill) / decode adds the KV
read term 4·B·S·Σ_attn(H·effective head dim).

Usage:
  python -m repro.launch.roofline dryrun.jsonl [--md]
"""

from __future__ import annotations

import argparse
import json
import sys

from repro.launch.mesh import hardware_constants

HW = hardware_constants()


def _attn_kv_flops_per_token(cfg, s: int) -> float:
    """Decode-time attention FLOPs per token (whole KV read), windowed
    layers capped at their window."""
    from repro.models import transformer as tf
    total = 0.0
    for kind in tf.layer_kinds(cfg):
        s_eff = min(s, kind.window) if kind.window else s
        if kind.attn == "gqa":
            total += 4 * cfg.num_heads * cfg.head_dim * s_eff
        elif kind.attn == "mla":
            m = cfg.mla
            total += (2 * cfg.num_heads * (m.kv_lora_rank
                                           + m.qk_rope_head_dim)
                      + 2 * cfg.num_heads * m.kv_lora_rank) * s_eff
        # ssm: O(1) state update, no KV term
    if cfg.encoder_decoder:
        # decoder self (s) + cross (encoder_seq)
        total += 4 * cfg.num_heads * cfg.head_dim * cfg.num_layers \
            * cfg.encoder_seq
    return total


def model_flops(record: dict, cfg=None) -> float:
    """Global useful FLOPs for the cell (assignment formulas)."""
    n = record["params"]["active"]
    b, s = record["batch"], record["seq"]
    kind = record["kind"]
    if kind == "train":
        return 6.0 * n * b * s
    if kind == "prefill":
        return 2.0 * n * b * s
    # decode: one token per sequence + KV-cache read compute
    kv = _attn_kv_flops_per_token(cfg, s) * b if cfg is not None else 0.0
    return 2.0 * n * b + kv


def terms(record: dict, cfg=None) -> dict:
    """Three roofline terms.  The memory term is a RANGE:

      memory_lo — from ``memory_analysis``: arguments read once + outputs
                  written once + peak temp touched twice.  Optimistic:
                  assumes perfect consumer fusion (every live byte moves
                  ~twice) — close to what a fused TPU lowering achieves.
      memory_hi — from the HLO instruction sum (trip-count-corrected):
                  every materialized intermediate read+written at the
                  *compiled module's* fusion granularity.  Pessimistic on
                  TPU (the CPU backend fuses less), exact for this module.

    The truth lies between; both move together under real optimizations,
    so §Perf tracks both.  ``memory_s`` (dominance / fraction) uses the
    geometric mean of the bounds.
    """
    chips = 1
    for v in record["mesh"].values():
        chips *= v
    compute = record["dot_flops"] / HW["peak_flops_bf16"]
    mem = record.get("memory", {})
    lo_bytes = (mem.get("argument_size_in_bytes", 0)
                + mem.get("output_size_in_bytes", 0)
                + 2 * mem.get("temp_size_in_bytes", 0))
    memory_lo = lo_bytes / HW["hbm_bandwidth"]
    memory_hi = record["hbm_bytes"] / HW["hbm_bandwidth"]
    memory = (memory_lo * memory_hi) ** 0.5 if memory_lo and memory_hi \
        else max(memory_lo, memory_hi)
    collective = record["collective_wire_bytes"] / HW["ici_bandwidth"]
    dominant = max(
        (("compute", compute), ("memory", memory),
         ("collective", collective)), key=lambda kv: kv[1])[0]
    mf = model_flops(record, cfg)
    hlo_total = record["dot_flops"] * chips
    return {
        "arch": record["arch"], "shape": record["shape"],
        "chips": chips,
        "compute_s": compute, "memory_s": memory,
        "memory_lo_s": memory_lo, "memory_hi_s": memory_hi,
        "collective_s": collective, "dominant": dominant,
        "model_flops": mf,
        "useful_ratio": (mf / hlo_total) if hlo_total else 0.0,
        "roofline_fraction": (
            compute / max(compute, memory, collective)
            if max(compute, memory, collective) else 0.0),
        "step_bound_s": max(compute, memory, collective),
    }


def _fmt_s(x: float) -> str:
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.2f}ms"
    return f"{x * 1e6:.1f}us"


def to_markdown(rows: list[dict]) -> str:
    hdr = ("| arch | shape | chips | compute | memory (lo–hi) | "
           "collective | dominant | useful ratio |\n"
           "|---|---|---|---|---|---|---|---|")
    lines = [hdr]
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['chips']} | "
            f"{_fmt_s(r['compute_s'])} | "
            f"{_fmt_s(r['memory_lo_s'])}–{_fmt_s(r['memory_hi_s'])} | "
            f"{_fmt_s(r['collective_s'])} | **{r['dominant']}** | "
            f"{r['useful_ratio']:.2f} |")
    return "\n".join(lines)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("jsonl")
    ap.add_argument("--md", action="store_true")
    args = ap.parse_args(argv)
    from repro.configs import REGISTRY
    by_cell = {}
    with open(args.jsonl) as f:
        for line in f:
            rec = json.loads(line)
            if not rec.get("ok"):
                continue
            by_cell[(rec["arch"], rec["shape"])] = rec  # last record wins
    rows = []
    for rec in by_cell.values():
        arch = rec["arch"].removesuffix("-smoke")
        cfg = REGISTRY[arch].full() if arch in REGISTRY else None
        rows.append(terms(rec, cfg))
    if args.md:
        print(to_markdown(rows))
    else:
        for r in rows:
            print(json.dumps(r))
    return 0


if __name__ == "__main__":
    sys.exit(main())
