"""Parameter / optimizer / batch / cache sharding policies.

Name-based rules over pytree paths — the policy layer DESIGN.md §6
describes.  Everything degrades gracefully: an axis is sharded over a mesh
axis only when divisible (small-arch caveat: 8-head models cannot split
16-way; the largest divisible dim gets the axis instead, and the roofline
discussion records the imbalance).

Policies:
  * params: TP over 'model' (heads / ff / vocab / experts), replicated over
    data axes.
  * optimizer moments: params policy + ZeRO over the data super-axis on the
    largest still-unsharded divisible dim.
  * batch: leading batch dim over the data super-axis.
  * decode caches: batch over data, kv-heads over model when divisible;
    ``seq_shard=True`` (long-context, batch=1) moves the KV sequence dim
    onto the data axis instead (sequence-parallel cache).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch.mesh import data_axes


def _div(n: int, mesh, axis) -> bool:
    if axis is None:
        return False
    size = 1
    for a in (axis if isinstance(axis, tuple) else (axis,)):
        size *= mesh.shape[a]
    return n > 0 and n % size == 0


def _maybe(n: int, mesh, axis):
    return axis if _div(n, mesh, axis) else None


# --- params ------------------------------------------------------------------

# last-dim-sharded matmul weights (column parallel)
_COL = ("wq", "wk", "wv", "wq_b", "wkv_b", "w_gate", "w_up", "w_in",
        "shared_gate", "shared_up", "b_in", "bq", "bk", "bv", "proj")
# second-to-last-dim-sharded (row parallel)
_ROW = ("wo", "w_down", "w_out", "shared_down", "b_out")
# fully replicated small tensors
_REP = ("router", "router_bias", "conv_w", "conv_b", "A_log", "D",
        "dt_bias", "norm", "ln1", "ln2", "ln_x", "q_norm", "k_norm",
        "q_a_norm", "kv_a_norm", "final_norm", "norm_h", "norm_e",
        "wq_a", "wkv_a", "pos_dec", "w", "b")


def _leaf_name(path) -> str:
    names = [p.key for p in path if hasattr(p, "key")]
    return names[-1] if names else ""


def _under(path, name: str) -> bool:
    return any(getattr(p, "key", None) == name for p in path)


def param_spec(path, leaf, mesh) -> P:
    name = _leaf_name(path)
    nd = leaf.ndim
    if name == "embed":
        return P(_maybe(leaf.shape[0], mesh, "model"), None)
    if name == "lm_head":
        return P(None, _maybe(leaf.shape[1], mesh, "model"))
    # MoE expert weights: [L, E, D, F] when scan-stacked (nd=4), [E, D, F]
    # only in the unstacked MTP block.  Dense scan-stacked FFN weights are
    # also nd=3 ([L, D, F]) — those take the column/row rules below.
    moe_expert = name in ("w_gate", "w_up", "w_down") \
        and (nd == 4 or (nd == 3 and _under(path, "mtp"))) \
        and not _under(path, "mlp")
    if moe_expert:
        # [*, E, D, F]: expert-parallel on E (matches moe_ffn_ep's espec)
        e_dim = nd - 3
        spec = [None] * nd
        spec[e_dim] = _maybe(leaf.shape[e_dim], mesh, "model")
        return P(*spec)
    if name in _COL and nd >= 1:
        spec = [None] * nd
        spec[-1] = _maybe(leaf.shape[-1], mesh, "model")
        return P(*spec)
    if name in _ROW and nd >= 2:
        spec = [None] * nd
        spec[-2] = _maybe(leaf.shape[-2], mesh, "model")
        return P(*spec)
    if name in _REP or nd <= 1:
        return P(*([None] * nd))
    return P(*([None] * nd))


def params_shardings(params_shapes, mesh, *, fsdp: bool = False):
    """TP over 'model'; with ``fsdp`` the data super-axis additionally
    shards each leaf's largest free divisible dim (ZeRO-3 / FSDP via
    GSPMD: weights live sharded, XLA all-gathers them at use inside the
    layer scan and reduce-scatters their grads)."""
    def spec(path, leaf):
        ps = param_spec(path, leaf, mesh)
        if fsdp:
            ps = zero_spec(ps, leaf, mesh)
        return NamedSharding(mesh, ps)

    return jax.tree_util.tree_map_with_path(spec, params_shapes)


# --- optimizer state (ZeRO) ---------------------------------------------------

def zero_spec(pspec: P, leaf, mesh, dp=None) -> P:
    """Add the data super-axis on the largest unsharded divisible dim."""
    dp = dp or data_axes(mesh)
    spec = list(pspec) + [None] * (leaf.ndim - len(pspec))
    cands = sorted(
        (i for i in range(leaf.ndim)
         if spec[i] is None and _div(leaf.shape[i], mesh, dp)),
        key=lambda i: -leaf.shape[i])
    if cands:
        spec[cands[0]] = dp if len(dp) > 1 else dp[0]
    return P(*spec)


def opt_state_shardings(opt_shapes, params_shapes, mesh, *,
                        dp_only: bool = False):
    if dp_only:
        pspecs = jax.tree.map(lambda l: P(*([None] * l.ndim)),
                              params_shapes)
    else:
        pspecs = jax.tree_util.tree_map_with_path(
            lambda path, leaf: param_spec(path, leaf, mesh), params_shapes)

    zdp = mesh.axis_names if dp_only else None

    def moment(ps, leaf):
        return NamedSharding(mesh, zero_spec(ps, leaf, mesh, dp=zdp))

    out = dict(opt_shapes)
    out = {}
    for key in opt_shapes:
        if key in ("m", "v", "master"):
            out[key] = jax.tree.map(moment, pspecs, opt_shapes[key])
        elif key == "step":
            out[key] = NamedSharding(mesh, P())
        else:
            out[key] = jax.tree.map(
                lambda l: NamedSharding(mesh, P(*([None] * l.ndim))),
                opt_shapes[key])
    return out


# --- batch / cache ------------------------------------------------------------

def batch_shardings(batch_shapes, mesh, axes=None):
    dp = tuple(axes) if axes else data_axes(mesh)
    dpa = dp if len(dp) > 1 else dp[0]

    def spec(path, leaf):
        name = _leaf_name(path)
        if name == "mrope_positions":            # [3, B, S]
            s = [None] * leaf.ndim
            if leaf.ndim >= 2:
                s[1] = dpa if _div(leaf.shape[1], mesh, dp) else None
            return NamedSharding(mesh, P(*s))
        s = [None] * leaf.ndim
        if leaf.ndim >= 1:
            s[0] = dpa if _div(leaf.shape[0], mesh, dp) else None
        return NamedSharding(mesh, P(*s))

    return jax.tree_util.tree_map_with_path(spec, batch_shapes)


def cache_shardings(cache_shapes, mesh, *, seq_shard: bool = False,
                    seq_axis=None):
    """Decode caches.  Layout per leaf name:
      k/v/cross_k/cross_v [G, B, S, Hkv, Dh]
      c_kv [G, B, S, R]; k_rope [G, B, S, Dr]
      state [G, B, H, P, N]; conv [G, B, K, C]; length [G, B]

    ``seq_shard`` moves the KV sequence dim onto ``seq_axis`` (default the
    data super-axis for batch=1 long-context; 'model' is the decode
    hillclimb: memory/model_size with a tiny attention psum).
    """
    dp = data_axes(mesh)
    dpa = dp if len(dp) > 1 else dp[0]
    sax = seq_axis if seq_axis is not None else dpa
    sax_t = sax if isinstance(sax, tuple) else (sax,)

    def spec(path, leaf):
        name = _leaf_name(path)
        sh = leaf.shape
        bdim = 1 if leaf.ndim >= 2 else 0
        seq_on_dp = seq_shard and any(a in dp for a in sax_t)
        batch_ax = dpa if _div(sh[bdim], mesh, dp) and not seq_on_dp \
            else None
        if name in ("k", "v", "cross_k", "cross_v"):
            s = [None, batch_ax, None,
                 None if seq_shard and "model" in sax_t
                 else _maybe(sh[3], mesh, "model"), None]
            if seq_shard:
                s[2] = sax if _div(sh[2], mesh, sax) else None
            return NamedSharding(mesh, P(*s))
        if name in ("c_kv", "k_rope"):
            s = [None, batch_ax, None, None]
            if seq_shard:
                s[2] = sax if _div(sh[2], mesh, sax) else None
            return NamedSharding(mesh, P(*s))
        if name == "state":
            return NamedSharding(mesh, P(
                None, batch_ax, _maybe(sh[2], mesh, "model"), None, None))
        if name == "conv":
            return NamedSharding(mesh, P(
                None, batch_ax, None, _maybe(sh[3], mesh, "model")))
        if name == "length":
            return NamedSharding(mesh, P(None, batch_ax))
        s = [None] * leaf.ndim
        if leaf.ndim >= 2:
            s[1] = batch_ax
        return NamedSharding(mesh, P(*s))

    return jax.tree_util.tree_map_with_path(spec, cache_shapes)
