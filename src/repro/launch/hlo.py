"""Post-SPMD HLO analysis: collectives + dot-FLOPs with while-loop
trip-count multipliers — the roofline's measurement layer.

``compiled.as_text()`` is the partitioned, optimized per-device module, so
collectives are materialized there.  Two XLA facts shape this parser:

  * CPU-backend HLO references operands by *name* (``all-reduce(%x)``), so
    sizes come from each instruction's declared return type, resolved
    through a per-module symbol table.
  * ``HloCostAnalysis`` (and hence ``compiled.cost_analysis()``) counts a
    ``while`` body ONCE — but every layer scan / microbatch loop is a
    while.  We recover true per-step totals by parsing each while's trip
    count from its condition computation and propagating multipliers over
    the call graph (ENTRY -> fusions/calls -> while bodies, nested scans
    compose multiplicatively).

Outputs:
  ``analyze(text)`` -> {
     "collectives": {kind: {count, bytes}},   # bytes = output-shape bytes
     "collective_wire_bytes": float,          # ring-model wire bytes
     "dot_flops": float,                      # 2 * prod(out) * contracted
     "hbm_bytes": float,                      # materialized operand+output
                                              # traffic at top-level-instr
                                              # granularity (fusion
                                              # internals excluded), trip-
                                              # count multiplied
     "op_histogram": {...}
  }

Wire-byte model per op (g = participants in its replica group):
  all-reduce: 2 (g-1)/g * size     all-gather: (g-1)/g * size(out)
  reduce-scatter: (g-1)/g * size(in) ~= (g-1) * size(out)
  all-to-all: (g-1)/g * size       collective-permute: size
"""

from __future__ import annotations

import math
import re

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
    "token": 0, "opaque": 0,
}

_KINDS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
          "collective-permute")

_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->")
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^)]*\))|(?:[\w\[\],{}\/* ]+?))\s*"
    r"([\w\-]+)\((.*)$")
_TYPE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_ATTR_CALLS = re.compile(r"\b(?:calls|to_apply|body|condition)=%?([\w.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_GROUPS = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_EXPL = re.compile(r"replica_groups=\{\{([^}]*)\}")


def _type_bytes(type_str: str) -> int:
    total = 0
    for d, dims in _TYPE.findall(type_str):
        if d not in _DTYPE_BYTES:
            continue
        n = 1
        for x in dims.split(","):
            if x:
                n *= int(x)
        total += n * _DTYPE_BYTES[d]
    return total


def _shape_dims(type_str: str):
    m = _TYPE.search(type_str)
    if not m:
        return []
    return [int(x) for x in m.group(2).split(",") if x]


class _Instr:
    __slots__ = ("name", "ret", "op", "rest")

    def __init__(self, name, ret, op, rest):
        self.name, self.ret, self.op, self.rest = name, ret, op, rest


def _parse_computations(text: str) -> dict:
    comps, cur, name = {}, None, None
    for raw in text.splitlines():
        line = raw.rstrip()
        if not line.startswith(" ") and "->" in line and "{" in line:
            m = _COMP_HDR.match(line.strip())
            if m:
                name = m.group(1)
                cur = []
                comps[name] = cur
                if line.strip().startswith("ENTRY"):
                    comps["__entry__"] = cur
                    comps["__entry_name__"] = name
                continue
        if cur is None:
            continue
        m = _INSTR.match(line)
        if m:
            cur.append(_Instr(*m.groups()))
    return comps


def _group_size(rest: str, total_devices: int | None) -> int:
    m = _GROUPS.search(rest)
    if m:
        return int(m.group(2))
    m = _GROUPS_EXPL.search(rest)
    if m:
        return len(m.group(1).split(","))
    return total_devices or 1


def _while_trip(comps, cond_name, symtab) -> int:
    """Trip count from the condition computation.

    Scan-lowered conditions are ``i < constant(N)``; the compare may be
    wrapped in a kLoop fusion, but the bound constant is defined in the
    condition computation itself — take the max integer constant there.
    """
    best = 0
    for ins in comps.get(cond_name, ()):
        c = symtab.get((cond_name, ins.name))
        if c is not None:
            best = max(best, c)
    return best or 1


def analyze(text: str, total_devices: int | None = None) -> dict:
    comps = _parse_computations(text)
    entry = comps.get("__entry_name__")
    if entry is None:
        return {"collectives": {}, "collective_wire_bytes": 0.0,
                "dot_flops": 0.0, "op_histogram": {}}

    # constants (for while trip counts) and return types per computation
    consts: dict = {}
    rets: dict = {}
    for cname, instrs in comps.items():
        if cname.startswith("__"):
            continue
        for ins in instrs:
            rets[(cname, ins.name)] = ins.ret
            if ins.op == "constant":
                m = re.match(r"(\d+)\)", ins.rest)
                if m:
                    consts[(cname, ins.name)] = int(m.group(1))

    # call-graph multiplier propagation (memoized DFS)
    mult: dict = {}

    def visit(cname: str, m: float):
        mult[cname] = mult.get(cname, 0.0) + m
        for ins in comps.get(cname, ()):
            if ins.op == "while":
                names = _ATTR_CALLS.findall(ins.rest)
                body = cond = None
                for attr, nm in re.findall(
                        r"(body|condition)=%?([\w.\-]+)", ins.rest):
                    if attr == "body":
                        body = nm
                    else:
                        cond = nm
                trip = _while_trip(comps, cond, consts) if cond else 1
                if body:
                    visit(body, m * trip)
                if cond:
                    visit(cond, m * (trip + 1))
            else:
                bm = _BRANCHES.search(ins.rest)
                if bm:
                    for nm in bm.group(1).split(","):
                        visit(nm.strip().lstrip("%"), m)
                for nm in _ATTR_CALLS.findall(ins.rest):
                    visit(nm, m)

    visit(entry, 1.0)

    coll = {k: {"count": 0.0, "bytes": 0.0} for k in _KINDS}
    wire = 0.0
    dot_flops = 0.0
    hbm_bytes = 0.0
    histogram: dict = {}

    # classify fusion computations: pure-elementwise kLoop fusions fuse
    # into their consumers on the TPU backend -> charge output only
    _HEAVY = {"dot", "convolution", "reduce", "reduce-window", "scatter",
              "gather", "sort", "dynamic-slice", "dynamic-update-slice"}
    _BOOKKEEP = {"parameter", "constant", "tuple", "get-tuple-element",
                 "bitcast", "iota", "copy", "broadcast", "reshape",
                 "transpose", "slice", "pad", "concatenate"}
    fusion_ew: dict = {}
    for cname, instrs in comps.items():
        if cname.startswith("__"):
            continue
        fusion_ew[cname] = all(
            ins.op not in _HEAVY for ins in instrs)
    # ops that move no HBM traffic themselves (SSA bookkeeping / aliases /
    # control flow whose bodies are counted separately)
    _NO_TRAFFIC = {"parameter", "constant", "tuple", "get-tuple-element",
                   "bitcast", "while", "conditional", "call",
                   "after-all", "partition-id", "replica-id", "iota"}
    # in-place / sliced access: traffic is the *slice*, not the buffer.
    # DUS aliases its big operand (XLA buffer-assigns in place): count
    # 2x the update (smallest non-scalar operand); slicing ops count 2x
    # their output.  Without this, a scan writing one layer's [16,4096,D]
    # into a [L,16,4096,D] stack would be charged the whole stack x L.
    _INPLACE = ("dynamic-update-slice", "scatter")
    _SLICED = ("dynamic-slice", "gather", "slice")
    # elementwise / layout ops: the TPU backend fuses these into their
    # consumers (the CPU module this text comes from fuses less
    # aggressively), so charging operand+output would overstate TPU HBM
    # traffic several-fold (e.g. the exp/where/mul chain around flash
    # logits).  Charge one materialization (output bytes).
    _EW = {"add", "subtract", "multiply", "divide", "exponential", "exp",
           "tanh", "maximum", "minimum", "select", "compare", "convert",
           "and", "or", "xor", "not", "negate", "abs", "rsqrt", "sqrt",
           "power", "log", "floor", "ceil", "clamp", "reduce-precision",
           "broadcast", "reshape", "transpose", "pad", "concatenate",
           "reverse", "sign", "cosine", "sine", "logistic",
           "shift-left", "shift-right-logical", "shift-right-arithmetic",
           "remainder", "is-finite", "expm1", "log1p", "atan2"}

    for cname, instrs in comps.items():
        if cname.startswith("__"):
            continue
        m = mult.get(cname, 0.0)
        if m == 0.0:
            continue
        for ins in instrs:
            histogram[ins.op] = histogram.get(ins.op, 0) + m
            if ins.op not in _NO_TRAFFIC and not ins.op.endswith("-done"):
                key = ins.op + ins.name  # fusion names carry the pattern
                opd_bytes = []
                for opd in re.findall(r"%([\w.\-]+)", ins.rest.split(
                        ", metadata=")[0].split(", calls=")[0]):
                    t = rets.get((cname, opd))
                    if t:
                        opd_bytes.append(_type_bytes(t))
                ew_fusion = False
                if ins.op == "fusion":
                    called = _ATTR_CALLS.findall(ins.rest)
                    ew_fusion = bool(called) and all(
                        fusion_ew.get(c, False) for c in called)
                if any(p in key for p in _INPLACE):
                    upd = [b for b in opd_bytes if b > 128]
                    nb = 2 * (min(upd) if upd else _type_bytes(ins.ret))
                elif any(p in key for p in _SLICED):
                    nb = 2 * _type_bytes(ins.ret)
                elif ins.op in _EW or ew_fusion:
                    nb = _type_bytes(ins.ret)
                else:
                    nb = _type_bytes(ins.ret) + sum(opd_bytes)
                hbm_bytes += m * nb
            base = ins.op[:-6] if ins.op.endswith("-start") else ins.op
            if base in _KINDS and not ins.op.endswith("-done"):
                nbytes = _type_bytes(ins.ret)
                g = _group_size(ins.rest, total_devices)
                coll[base]["count"] += m
                coll[base]["bytes"] += m * nbytes
                if base == "all-reduce":
                    wire += m * 2 * (g - 1) / max(g, 1) * nbytes
                elif base == "all-gather":
                    wire += m * (g - 1) / max(g, 1) * nbytes
                elif base == "reduce-scatter":
                    wire += m * (g - 1) * nbytes
                elif base == "all-to-all":
                    wire += m * (g - 1) / max(g, 1) * nbytes
                else:  # collective-permute
                    wire += m * nbytes
            elif base in ("dot", "convolution"):
                out_elems = 1
                for d in _shape_dims(ins.ret):
                    out_elems *= d
                # contracted size: product of lhs contracting dims
                cdims = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}",
                                  ins.rest)
                lhs_shape = None
                opds = re.findall(r"%([\w.\-]+)", ins.rest)
                if opds:
                    lhs_t = rets.get((cname, opds[0]))
                    if lhs_t:
                        lhs_shape = _shape_dims(lhs_t)
                contracted = 1
                if cdims and lhs_shape:
                    for i in cdims.group(1).split(","):
                        if i and int(i) < len(lhs_shape):
                            contracted *= lhs_shape[int(i)]
                dot_flops += m * 2 * out_elems * contracted

    coll_out = {k: {"count": round(v["count"], 1), "bytes": v["bytes"]}
                for k, v in coll.items() if v["count"]}
    return {
        "collectives": coll_out,
        "collective_wire_bytes": wire,
        "dot_flops": dot_flops,
        "hbm_bytes": hbm_bytes,
        "op_histogram": dict(sorted(histogram.items(),
                                    key=lambda kv: -kv[1])[:30]),
    }


def collective_stats(text: str) -> dict:
    """Back-compat shim: collective inventory only."""
    a = analyze(text)
    out = dict(a["collectives"])
    out["total_operand_bytes"] = sum(v["bytes"] for v in
                                     a["collectives"].values())
    out["wire_bytes"] = a["collective_wire_bytes"]
    return out


def op_histogram(text: str, top: int = 25) -> dict:
    return dict(list(analyze(text)["op_histogram"].items())[:top])
