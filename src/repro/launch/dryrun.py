import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count="
                      + os.environ.get("DRYRUN_DEVICES", "512"))

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The two lines above run before ANY other import — jax locks the device
count on first init.  Override the placeholder device count with
DRYRUN_DEVICES (subprocess tests use 4/8).

Per cell this script:
  1. builds the full config and ShapeDtypeStruct inputs (no allocation),
  2. jits the right step (train_step / prefill / serve_step) with the
     sharding policy from launch/shardings.py,
  3. ``.lower().compile()`` — failure here (sharding mismatch, OOM, bad
     collective) is a bug in the system,
  4. records memory_analysis / cost_analysis / collective inventory as a
     JSON line for EXPERIMENTS.md §Dry-run and §Roofline.

Usage:
  python -m repro.launch.dryrun --arch qwen3-0.6b --shape train_4k
  python -m repro.launch.dryrun --all --out dryrun.jsonl
  python -m repro.launch.dryrun --all --multi-pod --out dryrun_mp.jsonl
  DRYRUN_DEVICES=4 python -m repro.launch.dryrun --arch X --smoke \
      --mesh 2x2 --shape train_4k --seq 64 --batch 4
"""

import argparse
import json
import sys
import time
import traceback
from functools import partial

import jax
import jax.numpy as jnp

from repro.configs import (ARCH_IDS, SHAPES, applicable, get_config,
                           get_smoke)
from repro.configs import shapes as shp
from repro.launch import hlo as hlo_mod
from repro.launch import shardings as shard
from repro.launch.mesh import (data_axes, make_mesh_from_spec,
                               make_production_mesh)
from repro.models import sharding as logical
from repro.models import transformer as tf
from repro.train import optim
from repro.train.step import init_params, make_train_step


def _rules_for(mesh, shape_name: str, policy: str = "tp"):
    multi = "pod" in mesh.axis_names
    if policy == "dp":
        return logical.rules_pure_dp(multi_pod=multi)
    base = (logical.rules_multi_pod() if multi
            else logical.rules_single_pod())
    if shape_name == "long_500k":
        return logical.rules_seq_parallel(base)
    if policy == "sp":
        return logical.rules_megatron_sp(base)
    return base


def _param_counts(params_shapes, cfg) -> dict:
    total = sum(x.size for x in jax.tree.leaves(params_shapes))
    routed = 0
    if cfg.moe is not None:
        def visit(path, leaf):
            nonlocal routed
            name = shard._leaf_name(path)
            if name in ("w_gate", "w_up", "w_down") and leaf.ndim >= 3 \
                    and not shard._under(path, "mlp") \
                    and leaf.shape[-3] == cfg.moe.num_experts:
                routed += leaf.size
        jax.tree_util.tree_map_with_path(visit, params_shapes)
    m = cfg.moe
    active = total - routed + (routed * m.top_k // m.num_experts
                               if m else 0)
    return {"total": int(total), "active": int(active),
            "routed_expert": int(routed)}


def lower_cell(cfg, shape_name: str, mesh, *, remat: str = "full",
               microbatches: int = 1, seq=None, batch=None,
               moment_dtype: str = "bfloat16", fsdp: bool = False,
               policy: str = "tp", moe_combine_dtype: str | None = None,
               kv_shard: str = "default"):
    """Build + lower + compile one cell.  Returns (compiled, record).

    Hillclimb knobs (§Perf):
      policy            'tp' (baseline) | 'sp' (Megatron sequence-parallel
                        residual stream) | 'dp' (pure data parallel —
                        small-model policy, params replicated)
      moe_combine_dtype 'bfloat16' halves the EP combine psum bytes
      kv_shard          'model' shards decode KV sequence over the model
                        axis (memory/16, tiny psum at decode)
    """
    from repro.models import moe as moe_mod
    moe_mod.COMBINE_DTYPE = (jnp.bfloat16
                             if moe_combine_dtype == "bfloat16" else None)
    sspec = SHAPES[shape_name]
    if seq or batch:
        import dataclasses as dc
        sspec = dc.replace(sspec, seq=seq or sspec.seq,
                           batch=batch or sspec.batch)
    rules = _rules_for(mesh, shape_name, policy)
    params_shapes = jax.eval_shape(partial(init_params, cfg),
                                   jax.random.PRNGKey(0))
    dp_axes = mesh.axis_names if policy == "dp" else None
    if policy == "dp":
        pshard = jax.tree.map(
            lambda l: jax.sharding.NamedSharding(
                mesh, jax.sharding.PartitionSpec(*([None] * l.ndim))),
            params_shapes)
    else:
        pshard = shard.params_shardings(params_shapes, mesh, fsdp=fsdp)
    counts = _param_counts(params_shapes, cfg)

    with mesh, logical.logical_sharding(mesh, rules):
        if sspec.kind == "train":
            ocfg = optim.AdamWConfig(moment_dtype=moment_dtype)
            opt_shapes = jax.eval_shape(partial(optim.init_state, ocfg),
                                        params_shapes)
            oshard = shard.opt_state_shardings(opt_shapes, params_shapes,
                                               mesh,
                                               dp_only=(policy == "dp"))
            batch_shapes = shp.batch_inputs(cfg, sspec)
            bshard = shard.batch_shardings(batch_shapes, mesh,
                                           axes=dp_axes)
            step = make_train_step(cfg, ocfg, microbatches=microbatches,
                                   remat=remat)
            jitted = jax.jit(step, in_shardings=(pshard, oshard, bshard),
                             donate_argnums=(0, 1))
            lowered = jitted.lower(params_shapes, opt_shapes, batch_shapes)
        elif sspec.kind == "prefill":
            batch_shapes = shp.batch_inputs(cfg, sspec)
            bshard = shard.batch_shardings(batch_shapes, mesh,
                                           axes=dp_axes)
            if cfg.encoder_decoder:
                from repro.models import whisper as wh

                def fn(params, batch):
                    return wh.prefill(params, cfg, batch["frames"],
                                      batch["tokens"])
            elif cfg.family == "vlm":
                def fn(params, batch):
                    return tf.prefill(params, cfg, batch["tokens"],
                                      patch_emb=batch["patch_emb"],
                                      mrope_positions=batch[
                                          "mrope_positions"])
            else:
                def fn(params, batch):
                    return tf.prefill(params, cfg, batch["tokens"])
            jitted = jax.jit(fn, in_shardings=(pshard, bshard))
            lowered = jitted.lower(params_shapes, batch_shapes)
        else:  # decode
            from repro.serving.engine import make_serve_step
            dec = shp.decode_inputs(cfg, sspec)
            seq_shard = (shape_name == "long_500k"
                         or kv_shard == "model")
            cshard = shard.cache_shardings(
                dec["caches"], mesh, seq_shard=seq_shard,
                seq_axis="model" if kv_shard == "model" else None)
            tshard = shard.batch_shardings(
                {"last_tok": dec["last_tok"]}, mesh,
                axes=dp_axes)["last_tok"]
            step = make_serve_step(cfg)
            jitted = jax.jit(step, in_shardings=(pshard, cshard, tshard),
                             donate_argnums=(1,))
            lowered = jitted.lower(params_shapes, dec["caches"],
                                   dec["last_tok"])

        t0 = time.time()
        compiled = lowered.compile()
        compile_s = time.time() - t0

    mem = compiled.memory_analysis()
    mem_rec = {k: int(getattr(mem, k))
               for k in ("argument_size_in_bytes", "output_size_in_bytes",
                         "temp_size_in_bytes", "alias_size_in_bytes",
                         "generated_code_size_in_bytes")
               if hasattr(mem, k)}
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):  # older jax: one dict per device
        cost = cost[0] if cost else {}
    cost_rec = {k: float(v) for k, v in cost.items()
                if isinstance(v, (int, float)) and k in
                ("flops", "bytes accessed", "transcendentals",
                 "utilization operand 0 {}", "bytes accessed output {}")}
    text = compiled.as_text()
    ana = hlo_mod.analyze(text, total_devices=mesh.devices.size)
    record = {
        "arch": cfg.name, "shape": shape_name, "kind": sspec.kind,
        "seq": sspec.seq, "batch": sspec.batch,
        "mesh": dict(zip(mesh.axis_names, mesh.devices.shape)),
        "params": counts, "memory": mem_rec, "cost": cost_rec,
        "collectives": ana["collectives"],
        "collective_wire_bytes": ana["collective_wire_bytes"],
        "dot_flops": ana["dot_flops"],
        "hbm_bytes": ana["hbm_bytes"],
        "compile_seconds": round(compile_s, 2),
        "hlo_ops": ana["op_histogram"],
        "remat": remat, "microbatches": microbatches, "fsdp": fsdp,
        "policy": policy, "moe_combine_dtype": moe_combine_dtype,
        "kv_shard": kv_shard,
        "ok": True,
    }
    return compiled, record


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--mesh", help="override, e.g. 2x2 / 1x2x2")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (subprocess tests)")
    ap.add_argument("--seq", type=int, help="override shape seq")
    ap.add_argument("--batch", type=int, help="override shape batch")
    ap.add_argument("--remat", default="full",
                    choices=("none", "dots", "full", "outs"))
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--moment-dtype", default="bfloat16",
                    choices=("float32", "bfloat16"))
    ap.add_argument("--fsdp", action="store_true",
                    help="shard params over the data super-axis too "
                         "(ZeRO-3/FSDP; required for 671B-class configs)")
    ap.add_argument("--policy", default="tp", choices=("tp", "sp", "dp"))
    ap.add_argument("--moe-combine-dtype", default=None,
                    choices=(None, "float32", "bfloat16"))
    ap.add_argument("--kv-shard", default="default",
                    choices=("default", "model"))
    ap.add_argument("--save-hlo", metavar="DIR",
                    help="gzip the optimized per-device HLO per cell "
                         "(re-analyze later without recompiling)")
    ap.add_argument("--out")
    args = ap.parse_args(argv)

    mesh = (make_mesh_from_spec(args.mesh) if args.mesh
            else make_production_mesh(multi_pod=args.multi_pod))
    cells = []
    if args.all:
        for a in ARCH_IDS:
            cfg_probe = get_config(a)
            for s in SHAPES:
                if applicable(cfg_probe, s):
                    cells.append((a, s))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    out_f = open(args.out, "a") if args.out else None
    failures = 0
    for arch, shape_name in cells:
        cfg = get_smoke(arch) if args.smoke else get_config(arch)
        print(f"=== {arch} x {shape_name} "
              f"mesh={dict(zip(mesh.axis_names, mesh.devices.shape))} ===",
              flush=True)
        try:
            t0 = time.time()
            compiled, rec = lower_cell(
                cfg, shape_name, mesh, remat=args.remat,
                microbatches=args.microbatches, seq=args.seq,
                batch=args.batch, moment_dtype=args.moment_dtype,
                fsdp=args.fsdp, policy=args.policy,
                moe_combine_dtype=args.moe_combine_dtype,
                kv_shard=args.kv_shard)
            print(f"  ok in {time.time() - t0:.1f}s  mem={rec['memory']}\n"
                  f"  dot_flops={rec['dot_flops']:.3e}  "
                  f"wire_bytes={rec['collective_wire_bytes']:.3e}",
                  flush=True)
            print(f"  collectives: {rec['collectives']}", flush=True)
            if args.save_hlo:
                import gzip
                os.makedirs(args.save_hlo, exist_ok=True)
                tag = (f"{cfg.name}_{shape_name}_"
                       f"{'x'.join(str(v) for v in mesh.devices.shape)}"
                       f"_{args.policy}")
                if args.kv_shard != "default":
                    tag += f"_kv{args.kv_shard}"
                if args.microbatches != 1:
                    tag += f"_mb{args.microbatches}"
                if args.moe_combine_dtype:
                    tag += f"_mc{args.moe_combine_dtype}"
                if args.remat != "full":
                    tag += f"_{args.remat}"
                with gzip.open(os.path.join(args.save_hlo,
                                            tag + ".hlo.gz"), "wt") as f:
                    f.write(compiled.as_text())
                rec["hlo_file"] = tag + ".hlo.gz"
        except Exception as e:
            failures += 1
            rec = {"arch": cfg.name, "shape": shape_name, "ok": False,
                   "error": f"{type(e).__name__}: {e}",
                   "trace": traceback.format_exc()[-2000:]}
            print(f"  FAIL: {rec['error']}", flush=True)
        if out_f:
            out_f.write(json.dumps(rec) + "\n")
            out_f.flush()
    if out_f:
        out_f.close()
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
