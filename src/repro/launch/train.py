"""Training launcher: mesh + shardings + indexed data pipeline + ckpt.

Runs for real at smoke scale on CPU (examples/train_lm.py drives it for a
~100M model) and lowers identically on the production mesh — the dry-run
imports the same ``build_trainer``.

Fault tolerance: checkpoint every ``ckpt_every`` steps (params, optimizer
state, data cursor); ``--resume`` restores and continues from the exact
batch sequence (the pipeline cursor is part of the state — paper §III-D's
replayable-source requirement applied to training).
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config, get_smoke
from repro.data import BatchPipeline, Cursor, ExampleStore, \
    synthetic_examples
from repro.dist import checkpoint as ckpt
from repro.launch import shardings as shard
from repro.launch.mesh import data_axes
from repro.models import sharding as logical
from repro.train import optim
from repro.train.step import init_params, make_train_step


def build_trainer(cfg, mesh=None, *, opt_cfg=None, microbatches=1,
                  remat="dots"):
    """Returns (init_fn, step_fn) — jitted when a mesh is given."""
    opt_cfg = opt_cfg or optim.AdamWConfig()
    step = make_train_step(cfg, opt_cfg, microbatches=microbatches,
                           remat=remat)

    def init_fn(key):
        params = init_params(cfg, key)
        return params, optim.init_state(opt_cfg, params)

    if mesh is None:
        return init_fn, jax.jit(step)

    params_shapes = jax.eval_shape(partial(init_params, cfg),
                                   jax.random.PRNGKey(0))
    pshard = shard.params_shardings(params_shapes, mesh)
    opt_shapes = jax.eval_shape(partial(optim.init_state, opt_cfg),
                                params_shapes)
    oshard = shard.opt_state_shardings(opt_shapes, params_shapes, mesh)
    jitted = jax.jit(step, in_shardings=(pshard, oshard, None),
                     donate_argnums=(0, 1))
    return init_fn, jitted


def run(cfg, *, steps: int, batch: int, seq: int, ckpt_dir: str | None,
        ckpt_every: int = 50, resume: bool = False, seed: int = 0,
        log_every: int = 10, append_every: int = 0):
    """The end-to-end loop: indexed example store -> batches -> steps."""
    rng = np.random.default_rng(seed)
    store = ExampleStore(seq_len=seq, rows_per_batch=256)
    ids, toks = synthetic_examples(rng, max(4 * batch, 512), seq,
                                   cfg.vocab_size)
    store.append_examples(ids, toks)
    pipe = BatchPipeline(store, batch, seed=seed)

    init_fn, step_fn = build_trainer(cfg)
    params, opt_state = init_fn(jax.random.PRNGKey(seed))

    start = 0
    if resume and ckpt_dir and os.path.exists(
            os.path.join(ckpt_dir, "manifest.json")):
        (params, opt_state), meta = ckpt.restore_pytree(
            ckpt_dir, (params, opt_state)), ckpt.manifest(ckpt_dir)["meta"]
        start = int(meta["step"])
        pipe.cursor = Cursor.from_state(meta["cursor"])
        print(f"resumed from step {start}")

    history = []
    for i in range(start, steps):
        if append_every and i and i % append_every == 0:
            # streaming appends: fresh data enters without a reload
            nids, ntoks = synthetic_examples(
                rng, batch, seq, cfg.vocab_size, id_base=store.num_examples)
            store.append_examples(nids, ntoks)
        batch_data = pipe.next_batch()
        batch_data = {k: jnp.asarray(v) for k, v in batch_data.items()}
        t0 = time.time()
        params, opt_state, metrics = step_fn(params, opt_state, batch_data)
        loss = float(metrics["loss"])
        history.append(loss)
        if i % log_every == 0 or i == steps - 1:
            print(f"step {i:5d}  loss {loss:.4f}  "
                  f"lr {float(metrics['lr']):.2e}  "
                  f"gnorm {float(metrics['grad_norm']):.2f}  "
                  f"{time.time() - t0:.2f}s  store v{store.version}",
                  flush=True)
        if ckpt_dir and (i + 1) % ckpt_every == 0:
            ckpt.save_pytree(ckpt_dir, (params, opt_state),
                             meta={"step": i + 1,
                                   "cursor": pipe.cursor.state_dict()})
    return params, history


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="tinyllama-1.1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--append-every", type=int, default=0)
    args = ap.parse_args(argv)
    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    run(cfg, steps=args.steps, batch=args.batch, seq=args.seq,
        ckpt_dir=args.ckpt_dir, resume=args.resume,
        append_every=args.append_every)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
