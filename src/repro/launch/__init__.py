"""launch — meshes, sharding policies, dry-run, drivers, roofline.

  mesh.py       make_production_mesh (single/multi-pod), hw constants
  shardings.py  param/optimizer(ZeRO)/batch/cache sharding policies
  dryrun.py     lower+compile every (arch x shape x mesh) cell (script;
                sets XLA_FLAGS before jax init — import via subprocess)
  hlo.py        post-SPMD HLO parse: collectives, dot-FLOPs, HBM traffic,
                while-trip-count corrected
  roofline.py   three-term roofline from dry-run records
  train.py      training driver (indexed data pipeline + ckpt/resume)
  serve.py      serving driver (indexed prefix cache + paged decode)
"""
