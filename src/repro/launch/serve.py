"""Serving launcher: batched decode with the indexed prefix/KV cache.

Smoke-scale real run on CPU (the Engine admits requests, reuses cached
prefix pages via the paper's point lookup, decodes with the paged Pallas
kernel in interpret mode).  Prints the prefix-cache hit statistics — the
paper's Fig 1 amortization argument, measured on serving.
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_smoke
from repro.serving import Engine, Request
from repro.train.step import init_params


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen1.5-4b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--steps", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--shared-prefix", type=int, default=32,
                    help="tokens shared across requests (cache hits)")
    args = ap.parse_args(argv)

    cfg = get_smoke(args.arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(params, cfg, num_pages=512, page=16)

    rng = np.random.default_rng(0)
    shared = rng.integers(1, cfg.vocab_size, args.shared_prefix)
    reqs = []
    for i in range(args.requests):
        tail = rng.integers(1, cfg.vocab_size,
                            args.prompt_len - args.shared_prefix)
        reqs.append(Request(seq_id=i,
                            prompt=np.concatenate([shared, tail])
                            .astype(np.int32)))
    t0 = time.time()
    eng.run(reqs, steps=args.steps)
    dt = time.time() - t0
    print(f"{args.requests} requests x {args.steps} tokens in {dt:.1f}s")
    print("engine stats:", eng.stats)
    print("prefix-cache index overhead:",
          eng.cache.memory_overhead_bytes(), "bytes")
    for r in reqs[:3]:
        print(f"  req {r.seq_id}: {r.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
