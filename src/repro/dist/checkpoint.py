"""Checkpoint / restore / elastic reshard for DistributedTable.

The paper's recovery story (§III-D) is lineage replay; checkpointing is
the complementary fast path — persist the dtable's leaves once, restore
in O(load) instead of O(replay).  Because a dtable is one pytree, a
checkpoint is just its flattened leaves plus structural metadata; restore
validates the template's structure leaf-by-leaf (shape mismatches are a
hard error, not a silent reinterpretation — restoring a 4-shard
checkpoint into an 8-shard dtable would scramble ownership).

``reshard_dtable`` is elastic scaling: collect every valid row (order-
preserving per shard, so per-key MVCC chains keep their newest-first
order), then re-route and re-index at the new shard count.  This is the
checkpoint-portable form of scaling — save at 4 shards, restore the data
at 8 by resharding, not by reinterpreting leaves.
"""

from __future__ import annotations

import dataclasses
import json
import os
import zipfile
import zlib

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist import dtable as _dtable
from repro.dist import mesh as _mesh

_LEAVES = "leaves.npz"
_META = "meta.json"
# v1: leaves + num_leaves only.  v2: adds per-leaf CRC32s — restore
# verifies them, so a flipped bit (disk rot, partial write, an injected
# checkpoint_corruption fault) is a clear ValueError, never a silently
# scrambled index.
FORMAT_VERSION = 2


def _leaf_crc(a: np.ndarray) -> int:
    return zlib.crc32(np.ascontiguousarray(a).tobytes())


def _save_leaves(path: str, obj, extra_meta: dict):
    """Persist any registered pytree: flattened leaves + metadata.

    MVCC versions and arena fill counters are data *leaves* (DESIGN.md
    §4), so they ride in ``leaves.npz`` like everything else; the meta
    entries carry the format version, per-leaf CRC32s (integrity,
    DESIGN.md §12), and informational fields (back-compat for old
    readers).
    """
    os.makedirs(path, exist_ok=True)
    leaves = [np.asarray(a) for a in jax.tree_util.tree_leaves(obj)]
    np.savez(os.path.join(path, _LEAVES),
             **{f"leaf_{i}": a for i, a in enumerate(leaves)})
    meta = {"format_version": FORMAT_VERSION, "num_leaves": len(leaves),
            "leaf_crc32": [_leaf_crc(a) for a in leaves], **extra_meta}
    with open(os.path.join(path, _META), "w") as f:
        json.dump(meta, f)


def _restore_leaves(path: str, like, meta: dict):
    """Unflatten a checkpoint into ``like``'s treedef, validating every
    leaf's shape against the template AND its recorded CRC32 (format v2)
    — shape mismatches and flipped bits are hard ``ValueError``s, not a
    silent reinterpretation / silently scrambled restore."""
    like_leaves, treedef = jax.tree_util.tree_flatten(like)
    if meta["num_leaves"] != len(like_leaves):
        raise ValueError(
            f"checkpoint has {meta['num_leaves']} leaves; template has "
            f"{len(like_leaves)} (different segment count or layout?)")
    leaves_path = os.path.join(path, _LEAVES)
    try:
        with np.load(leaves_path) as data:
            saved = [data[f"leaf_{i}"] for i in range(meta["num_leaves"])]
    except FileNotFoundError:
        raise ValueError(
            f"checkpoint at {path!r} has no {_LEAVES} (interrupted save?)")
    except KeyError as e:
        raise ValueError(
            f"checkpoint at {path!r} is truncated: {_LEAVES} is missing "
            f"{e} of the {meta['num_leaves']} recorded leaves") from e
    except (zipfile.BadZipFile, OSError) as e:
        raise ValueError(
            f"checkpoint {_LEAVES} at {path!r} is corrupt: {e}") from e
    crcs = meta.get("leaf_crc32")
    if crcs is not None:
        if len(crcs) != len(saved):
            raise ValueError(
                f"checkpoint meta at {path!r} is truncated: "
                f"{len(crcs)} CRCs for {len(saved)} leaves")
        for i, (s, want) in enumerate(zip(saved, crcs)):
            got = _leaf_crc(s)
            if got != want:
                raise ValueError(
                    f"checkpoint corruption at {path!r}: leaf {i} CRC32 "
                    f"{got:#010x} != recorded {want:#010x} (bit flip or "
                    f"partial write); restore from an older checkpoint or "
                    f"replay lineage")
    for i, (s, l) in enumerate(zip(saved, like_leaves)):
        if tuple(s.shape) != tuple(np.shape(l)):
            raise ValueError(
                f"leaf {i}: checkpoint shape {tuple(s.shape)} != template "
                f"shape {tuple(np.shape(l))}")
    # MVCC versions are data leaves (DESIGN.md §4), so unflatten restores
    # the checkpoint's own versions — no meta surgery needed (a version-0
    # empty-clone template cannot demote version-3 data).
    return jax.tree_util.tree_unflatten(
        treedef, [jnp.asarray(a) for a in saved])


def _read_meta(path: str) -> dict:
    meta_path = os.path.join(path, _META)
    try:
        with open(meta_path) as f:
            text = f.read()
    except FileNotFoundError:
        raise ValueError(
            f"no checkpoint at {path!r}: {_META} is missing (not a "
            f"checkpoint directory, or an interrupted save)")
    try:
        meta = json.loads(text)
    except json.JSONDecodeError as e:
        raise ValueError(
            f"checkpoint {_META} at {path!r} is corrupt or truncated: "
            f"{e}") from e
    if not isinstance(meta, dict) or "num_leaves" not in meta:
        raise ValueError(
            f"checkpoint {_META} at {path!r} is not a checkpoint record "
            f"(missing num_leaves)")
    return meta


def save_dtable(path: str, dt: _dtable.DistributedTable):
    """Persist a dtable: flattened pytree leaves + structural metadata."""
    _save_leaves(path, dt, {
        "num_shards": dt.num_shards,
        "version": int(np.asarray(dt.version)),
        "table_version": int(np.asarray(dt.table.version).ravel()[0])})


def restore_dtable(path: str,
                   like: _dtable.DistributedTable) -> _dtable.DistributedTable:
    """Restore a checkpoint into ``like``'s structure.

    ``like`` supplies the treedef (a dtable of the same construction —
    typically the live one or a freshly built empty clone).  Every leaf is
    validated against the template's shape; any mismatch (different shard
    count, capacity, segment count...) raises ``ValueError``.
    """
    meta = _read_meta(path)
    if meta.get("num_shards", like.num_shards) != like.num_shards:
        raise ValueError(
            f"checkpoint was saved with {meta['num_shards']} shards; "
            f"template has {like.num_shards} — reshard_dtable the restored "
            f"table instead of restoring into a different topology")
    return _restore_leaves(path, like, meta)


def save_table(path: str, t):
    """Persist a single-partition ``IndexedTable`` — the same leaves+meta
    layout as ``save_dtable``, so the facade's ``.save`` works for either
    backend."""
    _save_leaves(path, t, {"version": int(np.asarray(t.version))})


def restore_table(path: str, like):
    """Restore an ``IndexedTable`` checkpoint into ``like``'s structure
    (leaf-by-leaf shape validation, as ``restore_dtable``)."""
    meta = _read_meta(path)
    if "num_shards" in meta:
        raise ValueError(
            f"checkpoint at {path!r} holds a {meta['num_shards']}-shard "
            f"DistributedTable; restore it with restore_dtable")
    return _restore_leaves(path, like, meta)


def reshard_dtable(dt: _dtable.DistributedTable, num_shards: int, *,
                   rt: "_mesh.Runtime | None" = None,
                   rt_out: "_mesh.Runtime | None" = None
                   ) -> _dtable.DistributedTable:
    """Elastic scale up/down: collect valid rows, re-route, re-index.

    Preserves the dtable's global MVCC version; the resharded table is a
    single-segment compaction (per-key newest-first order survives because
    collection is order-preserving within each shard and a key's rows
    never span shards).  ``rt`` maps the collection over ``dt``'s shard
    axis; ``rt_out`` builds the new topology (they differ whenever the
    shard count changes — a shard_map runtime is pinned to its mesh size).
    """
    cols = _collect_cols(dt, rt=rt)
    fresh = _dtable.create_distributed(
        cols, dt.schema, num_shards, rows_per_batch=dt.rows_per_batch,
        layout=dt.layout, slots=dt.slots, rt=rt_out)
    return dataclasses.replace(fresh, version=dt.version)


# Row collection lives with the dtable now (compact_distributed shares it);
# kept under the old name for external callers.
_collect_cols = _dtable.collect_cols
