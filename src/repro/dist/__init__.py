"""repro.dist — the distributed Indexed DataFrame (paper §III-C/D).

Layout:
  mesh.py        the execution-backend seam: ``Runtime`` (vmap emulation
                 vs shard_map over a real device mesh) + ``axis_map``,
                 the one place the shard axis is mapped
  shuffle.py     capacity-bounded all-to-all over partition_hash (route
                 local outboxes; src<->dest transpose oracle + the
                 ``lax.all_to_all`` collective body)
  dtable.py      DistributedTable: shard-stacked IndexedTables (segments +
                 Snapshots as ONE pytree), create/append/lookup/
                 lookup_routed/joins — the single-partition code
                 axis-mapped over the shard axis
  runtime.py     Lineage append replay, fail/rebuild/splice shard,
                 VersionVector fencing, StragglerPolicy (paper Fig 12)
  checkpoint.py  save/restore pytree leaves (CRC-verified) + elastic
                 reshard
  resilience.py  FaultInjector (seeded chaos plans) + RecoveryManager —
                 the supervision layer ``IndexedFrame.supervised`` routes
                 reads through (fence, probe, heal, drop->retry)

Every op takes an optional ``rt`` (``mesh.Runtime``): the default vmap
backend emulates the shard axis on one device; ``mesh.mesh_runtime(s)``
runs the identical per-shard functions under ``shard_map`` on an
s-device mesh, where the shuffle's transpose is a genuine
``lax.all_to_all`` and the owner-select a cross-device ``lax.psum``.
The two backends are bit-identical (tests/test_mesh_parity.py).
"""

from repro.dist import checkpoint, mesh, runtime, shuffle
from repro.dist.dtable import (DistributedTable, HotReplica,
                               append_distributed, attach_replica,
                               choose_join, choose_lookup, collect_cols,
                               compact_distributed, create_distributed,
                               enqueue_distributed, flush_queue_distributed,
                               hot_fraction, indexed_join_bcast,
                               indexed_join_hybrid, indexed_join_routed,
                               indexed_join_shuffle, lookup, lookup_hybrid_flat,
                               lookup_hybrid_report, lookup_routed,
                               lookup_routed_flat, lookup_routed_report,
                               refresh_replica, reseed_tracker)
from repro.dist import resilience
from repro.dist.mesh import Runtime, mesh_runtime, vmap_runtime
from repro.dist.resilience import (Fault, FaultInjector,
                                   PartitionedSupervisor, RecoveryManager,
                                   RecoveryPolicy, supervise)

__all__ = [
    "DistributedTable", "Fault", "FaultInjector", "HotReplica",
    "PartitionedSupervisor",
    "RecoveryManager", "RecoveryPolicy", "Runtime", "append_distributed",
    "attach_replica", "checkpoint",
    "choose_join", "choose_lookup", "collect_cols", "compact_distributed",
    "create_distributed", "enqueue_distributed", "flush_queue_distributed",
    "hot_fraction", "indexed_join_bcast", "indexed_join_hybrid",
    "indexed_join_routed",
    "indexed_join_shuffle", "lookup", "lookup_hybrid_flat",
    "lookup_hybrid_report", "lookup_routed", "lookup_routed_flat",
    "lookup_routed_report", "mesh", "mesh_runtime", "refresh_replica",
    "reseed_tracker", "resilience", "runtime", "shuffle", "supervise",
    "vmap_runtime",
]
