"""repro.dist — the distributed Indexed DataFrame (paper §III-C/D).

Layout:
  shuffle.py     capacity-bounded all-to-all over partition_hash (route
                 local outboxes + the src<->dest transpose)
  dtable.py      DistributedTable: shard-stacked IndexedTables (segments +
                 Snapshots as ONE pytree), create/append/lookup/joins —
                 the single-partition code vmapped over the shard axis
  runtime.py     Lineage append replay, fail/rebuild shard, VersionVector
                 fencing, StragglerPolicy (paper Fig 12)
  checkpoint.py  save/restore pytree leaves + elastic reshard

CPU CI runs every shard axis under jax.vmap; on a real mesh the same
functions run under shard_map with the leading axis sharded over devices
(the shuffle's transpose becomes one lax.all_to_all).
"""

from repro.dist import checkpoint, runtime, shuffle
from repro.dist.dtable import (DistributedTable, append_distributed,
                               choose_join, create_distributed,
                               indexed_join_bcast, indexed_join_shuffle,
                               lookup)

__all__ = [
    "DistributedTable", "append_distributed", "checkpoint", "choose_join",
    "create_distributed", "indexed_join_bcast", "indexed_join_shuffle",
    "lookup", "runtime", "shuffle",
]
