"""Mesh-native execution backend for the distributed layer.

Every ``repro.dist`` op is written as a *per-shard* function: it sees one
shard's slice of the dtable pytree and may use named-axis collectives
(``lax.psum`` for the owner-select, ``lax.all_to_all`` for the shuffle,
``lax.axis_index`` for ownership tests).  This module owns the ONE seam
that decides how that function is mapped over the shard axis:

* ``backend="vmap"`` — ``jax.vmap(fn, axis_name=...)`` on one device.
  JAX gives every collective a batching rule, so the same psum /
  all_to_all / axis_index code runs unchanged; this is the CPU-CI
  emulation path (and the historical behaviour of the layer).
* ``backend="shard_map"`` — ``jax.shard_map`` over a real 1-D device
  mesh, the shard axis sharded over devices.  The per-shard function now
  runs SPMD: the shuffle's src<->dest transpose is a genuine
  ``lax.all_to_all`` over the interconnect and the owner-select is a
  cross-device ``lax.psum`` (paper §III-C; scalability Fig 6).

The two backends are **bit-identical by construction** — they map the
same per-shard function, and the collectives used move data unchanged
(all_to_all, axis_index); owner-selects are gathers on the stacked
outputs.  One platform caveat: XLA lowers cross-device float combines
(psum / sharded gather / all_gather) as zero-padded sums, so stored
float ``-0.0`` crossing shards in the broadcast select canonicalizes to
``+0.0`` (numerically equal; the packed all_to_all paths are bit-exact
for every payload — see DESIGN.md §10).
``tests/test_mesh_parity.py`` locks parity down op by op.

Collective mapping (vmap <-> shard_map):

  per-shard code               vmap backend          shard_map backend
  ---------------------------  --------------------  --------------------
  ``lax.axis_index(axis)``     batching rule (iota)  device's mesh coord
  ``lax.all_to_all`` shuffle   transpose-in-lane     ICI/DCN all-to-all
  ``lax.psum`` sums/counts     sum over stacked axis cross-device psum
  ``lax.ppermute`` rotations   gather permutation    neighbour exchange

CPU CI gets a real multi-device mesh via
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (scripts/ci.sh
runs the suite under both topologies).
"""

from __future__ import annotations

import dataclasses

import jax
import numpy as np
from jax.sharding import PartitionSpec as P

AXIS = "shards"


def _shard_map_impl(f, mesh, in_specs, out_specs):
    """``jax.shard_map`` across jax versions (newer: ``check_vma``;
    older: the experimental API with ``check_rep``)."""
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_vma=False)
    from jax.experimental.shard_map import shard_map as sm_exp
    return sm_exp(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_rep=False)


@dataclasses.dataclass(frozen=True)
class Runtime:
    """Backend selector for the shard axis (the dist-layer 'mesh config').

    ``backend`` is ``"vmap"`` (single-device emulation, the default) or
    ``"shard_map"`` (SPMD over ``mesh``).  ``axis`` names the shard axis
    for collectives under either backend.
    """

    backend: str = "vmap"
    mesh: object = None       # jax.sharding.Mesh when backend == "shard_map"
    axis: str = AXIS

    @property
    def num_devices(self) -> int | None:
        return None if self.mesh is None else int(self.mesh.shape[self.axis])

    def check(self, num_shards: int):
        """Raise early if this runtime cannot map ``num_shards`` shards."""
        if self.backend == "shard_map" and self.num_devices != num_shards:
            raise ValueError(
                f"shard_map runtime has a {self.num_devices}-device mesh "
                f"but the dtable has {num_shards} shards; build it with "
                f"mesh_runtime({num_shards})")
        return self


def vmap_runtime(axis: str = AXIS) -> Runtime:
    """The single-device emulation backend (collectives via vmap rules)."""
    return Runtime(backend="vmap", mesh=None, axis=axis)


def mesh_runtime(num_shards: int, *, devices=None,
                 axis: str = AXIS) -> Runtime:
    """A shard_map backend over a 1-D mesh of ``num_shards`` devices.

    ``devices`` defaults to the first ``num_shards`` of
    ``jax.devices()``; CPU CI forces eight with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8``.
    """
    devices = list(jax.devices() if devices is None else devices)
    if len(devices) < num_shards:
        raise ValueError(
            f"need {num_shards} devices for a {num_shards}-shard mesh, "
            f"have {len(devices)} (CPU: set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={num_shards})")
    mesh = jax.sharding.Mesh(np.asarray(devices[:num_shards]), (axis,))
    return Runtime(backend="shard_map", mesh=mesh, axis=axis)


def resolve(rt: Runtime | None) -> Runtime:
    """None -> the default vmap backend (back-compat for every call site)."""
    return rt if rt is not None else vmap_runtime()


def axis_map(fn, rt: Runtime | None, in_axes=0):
    """Map a per-shard function over the leading shard axis — THE seam.

    ``fn`` takes per-shard pytrees (no shard axis) and may use collectives
    over ``rt.axis``; every output grows a leading ``[num_shards]`` axis.
    ``in_axes`` is 0 (sharded on axis 0) or ``None`` (replicated to every
    shard), a single value or one per positional argument — the same
    contract as ``jax.vmap``'s, restricted to {0, None}.

    vmap backend: exactly ``jax.vmap(fn, in_axes, axis_name=rt.axis)``.
    shard_map backend: ``in_axes=0`` becomes ``P(axis)`` (leaf rows live
    on their shard's device), ``None`` becomes ``P()`` (replicated); the
    per-device block keeps a leading axis of size 1, which the wrapper
    squeezes on the way in and restores on the way out so ``fn`` sees the
    same shapes under both backends.
    """
    rt = resolve(rt)
    if rt.backend == "vmap":
        return jax.vmap(fn, in_axes=in_axes, axis_name=rt.axis)
    if rt.backend != "shard_map":
        raise ValueError(f"unknown dist backend {rt.backend!r}")

    def mapped(*args):
        axes = (tuple(in_axes) if isinstance(in_axes, (tuple, list))
                else (in_axes,) * len(args))
        if len(axes) != len(args):
            raise ValueError(f"in_axes {axes} vs {len(args)} arguments")
        in_specs = tuple(P(rt.axis) if a == 0 else P() for a in axes)

        def blocked(*blocks):
            local = tuple(
                jax.tree.map(lambda x: x[0], b) if a == 0 else b
                for a, b in zip(axes, blocks))
            out = fn(*local)
            return jax.tree.map(lambda x: x[None], out)

        return _shard_map_impl(blocked, rt.mesh, in_specs, P(rt.axis))(*args)

    return mapped
