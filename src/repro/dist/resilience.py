"""Self-healing distributed execution: fault injection + supervised recovery.

The paper's §III-D / Fig-12 story is that an executor failure costs one
slow query, not the cache.  PR 2 built the recovery *pieces* — ``Lineage``
replay, ``fail_shard``/``rebuild_shard``, ``VersionVector`` fencing,
``StragglerPolicy`` — but they were disconnected props only the benchmark
drove by hand, and the routed lookup's ``answered=False``/``dropped``
retry contract was every caller's problem.  This module makes failure
handling part of the operator contract (the way Modin/Cylon-class
dataframe runtimes do):

* ``FaultInjector`` — a deterministic, seedable chaos plan: named faults
  (``shard_loss``, ``straggler``, ``capacity_pressure``,
  ``checkpoint_corruption``) that fire at planned supervision steps.
* ``RecoveryManager`` — the supervision layer ``IndexedFrame.supervised``
  routes distributed reads through.  Every read is fenced
  (``VersionVector``), integrity-probed (a cheap fill/sentinel scan), and
  auto-healed; dropped routed lookups auto-retry with doubled capacity
  under a bounded exponential-backoff budget.  On shard death it runs the
  full state machine:

      mark stale -> restore newest intact checkpoint -> replay only the
      lineage suffix since it (``Lineage.truncate`` keeps the log
      checkpoint-anchored, so replay is O(deltas since checkpoint)) ->
      splice the shard back (``runtime.splice_shard``) -> mark fresh.

  Leaf shapes never change, so the healed dtable re-enters the SAME jit
  cache entry — zero recompiles of the fused read sites, the Fig-12 flat
  tail (the manager's own retrace counter proves it; scripts/
  fault_smoke.py gates it in CI).  When the recovery budget is exhausted
  (every checkpoint corrupt, no base recipe) it degrades gracefully:
  surviving shards answer, the dead shard's queries come back as honest
  misses with a per-query ``answered`` mask and drop accounting in
  ``ReadReport`` — never fabricated matches.

DESIGN.md §12 records the fault model and the state machine;
benchmarks/fault_tolerance.py sweeps fault type × write rate into
``BENCH_dist.json``.
"""

from __future__ import annotations

import dataclasses
import os
import shutil
import time

import jax
import numpy as np

from repro.core import hashing
from repro.core import table as table_mod
from repro.core.hashindex import EMPTY_KEY
from repro.dist import checkpoint as _ckpt
from repro.dist import dtable as _dtable
from repro.dist import runtime as _runtime

FAULT_KINDS = ("shard_loss", "straggler", "capacity_pressure",
               "checkpoint_corruption")


@dataclasses.dataclass(frozen=True)
class Fault:
    """One named fault at a planned supervision step.

    ``shard`` targets shard loss / straggler delay; ``severity`` scales
    the fault (straggler slowdown factor; capacity divisor for pressure).
    """

    kind: str
    step: int
    shard: int = 0
    severity: float = 4.0

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(
                f"unknown fault kind {self.kind!r}; one of {FAULT_KINDS}")
        if self.step < 0 or self.severity <= 0:
            raise ValueError(
                f"step must be >= 0 and severity > 0, got "
                f"{self.step!r} / {self.severity!r}")


class FaultInjector:
    """A deterministic, seedable chaos plan.

    The supervision loop calls ``tick()`` once per step (read or write);
    faults whose ``step`` matches fire and are returned for the
    ``RecoveryManager`` to apply.  Determinism matters: the chaos sweep
    and the CI smoke must reproduce bit-identically from a seed.
    """

    def __init__(self, faults=(), *, seed: int = 0):
        self.plan = tuple(sorted(faults, key=lambda f: f.step))
        self.rng = np.random.default_rng(seed)
        self.seed = seed
        self.step = -1
        self.fired: list[Fault] = []

    @classmethod
    def plan_random(cls, *, seed: int, num_shards: int, steps: int,
                    kinds=FAULT_KINDS, n_faults: int = 1,
                    min_step: int = 1) -> "FaultInjector":
        """A seeded random plan: ``n_faults`` faults at distinct steps in
        ``[min_step, steps)`` — same seed, same chaos."""
        rng = np.random.default_rng(seed)
        span = np.arange(min_step, steps)
        at = rng.choice(span, size=min(n_faults, span.size), replace=False)
        faults = [Fault(kind=str(rng.choice(list(kinds))), step=int(st),
                        shard=int(rng.integers(num_shards)),
                        severity=float(2 ** rng.integers(1, 4)))
                  for st in sorted(int(s) for s in at)]
        return cls(faults, seed=seed)

    def tick(self) -> list[Fault]:
        """Advance one supervision step; return the faults firing now."""
        self.step += 1
        now = [f for f in self.plan if f.step == self.step]
        self.fired.extend(now)
        return now

    def corrupt_checkpoint(self, path: str) -> str:
        """Flip one seeded-random bit in the checkpoint's largest leaf —
        meta.json (with its recorded CRC32s) is left intact, so a restore
        MUST detect the flip (dist/checkpoint.py).  Returns the corrupted
        leaf's archive name."""
        leaves_path = os.path.join(path, "leaves.npz")
        with np.load(leaves_path) as data:
            arrs = {k: np.array(data[k]) for k in data.files}
        victims = [k for k, a in arrs.items() if a.nbytes > 0]
        if not victims:
            raise ValueError(f"checkpoint at {path!r} has no bytes to flip")
        name = max(victims, key=lambda k: arrs[k].nbytes)
        flat = np.ascontiguousarray(arrs[name]).reshape(-1).view(np.uint8)
        bit = int(self.rng.integers(flat.size * 8))
        flat[bit // 8] ^= np.uint8(1 << (bit % 8))
        arrs[name] = flat.view(arrs[name].dtype).reshape(arrs[name].shape)
        np.savez(leaves_path, **arrs)
        return name


@dataclasses.dataclass(frozen=True)
class RecoveryPolicy:
    """Budgets for the supervision layer.

    ``max_retries``/``backoff_*`` bound the routed drop->retry loop
    (capacity doubles per attempt, sleeps grow exponentially to the cap).
    ``checkpoint_every`` appends triggers an automatic checkpoint (0 =
    manual only); ``keep_checkpoints`` is the ring size — the lineage is
    truncated to the OLDEST kept checkpoint, so a corrupt newest
    checkpoint still has an older anchor plus a longer (but bounded)
    suffix.  ``probe_every`` reads runs the integrity probe (1 = every
    read).
    """

    max_retries: int = 4
    backoff_base_s: float = 0.002
    backoff_factor: float = 2.0
    backoff_cap_s: float = 0.25
    checkpoint_every: int = 8
    keep_checkpoints: int = 2
    probe_every: int = 1


@dataclasses.dataclass
class ReadReport:
    """Honest per-read accounting (the degraded-mode contract): which
    queries were answered by a live owner, what was dropped/retried, and
    what healed before the read ran."""

    answered: np.ndarray          # [Q] bool — owner alive AND delivered
    dropped: int                  # exchange drops left after retries
    retries: int                  # capacity-doubling retries this read
    recovered: tuple              # shards healed before this read
    degraded: bool                # some owner permanently dead
    operator: str                 # physical operator that answered


class RecoveryStats:
    """Counters the chaos sweep and CI smoke report (MTTR, replay cost,
    retrace count, retry/drop accounting)."""

    def __init__(self):
        self.reads = 0
        self.appends = 0
        self.enqueues = 0
        self.flushes = 0
        self.probes = 0
        self.recoveries = 0
        self.mttr_s: list[float] = []
        self.replayed_deltas: list[int] = []
        self.retries = 0
        self.drops = 0
        self.degraded_reads = 0
        self.corrupt_checkpoints = 0
        self.straggler_events = 0
        self.speculative_plans: list[dict] = []

    def to_dict(self) -> dict:
        return {**{k: v for k, v in vars(self).items()
                   if not k.startswith("_")}}


class RecoveryManager:
    """Supervises a distributed ``IndexedFrame``: reads are fenced,
    integrity-probed, auto-healed, and drop-retried — failure handling as
    part of the operator contract, not the caller's job (DESIGN.md §12).

    Build one with ``frame.supervised(...)``.  The manager owns the live
    frame (``.frame`` — recovery replaces its wrapped dtable) and mirrors
    the facade's read/write surface: ``lookup`` / ``join`` / ``append`` /
    ``checkpoint``.  Reads run through manager-owned jitted sites whose
    trace counter (``stats`` + ``retraces``) proves recovery re-enters
    the same compile-cache entry.
    """

    def __init__(self, frame, *, lineage: _runtime.Lineage | None = None,
                 policy: RecoveryPolicy | None = None,
                 injector: FaultInjector | None = None,
                 checkpoint_dir: str | None = None):
        if not getattr(frame, "is_distributed", False):
            raise ValueError(
                "supervision wraps the distributed backend; build the "
                "frame with num_shards > 1 (a single partition has no "
                "shard to lose)")
        self.frame = frame
        self.policy = policy if policy is not None else RecoveryPolicy()
        self.injector = injector
        self.lineage = lineage
        self.checkpoint_dir = checkpoint_dir
        s = frame.num_shards
        self.vv = _runtime.VersionVector.fresh(s)
        self.vv.versions = [self._version()] * s
        self.straggler = _runtime.StragglerPolicy()
        self.stats = RecoveryStats()
        self.last_report: ReadReport | None = None
        self.dead: set[int] = set()        # unrecoverable (budget spent)
        self._ckpts: list[tuple[int, str]] = []   # (version, path), old->new
        self._appends_since_ckpt = 0
        self._pressure_divisor: float | None = None
        self._sites: dict = {}             # (kind, mm, names) -> (jit fn, ctr)
        self._pending: list = []           # host (cols, valid) per ring delta
        self._expected_fill = self._fill()
        if checkpoint_dir is not None:
            # anchor immediately: recovery never needs the full history
            self.checkpoint()

    # -- cheap host facts ------------------------------------------------------

    def _version(self) -> int:
        return int(np.asarray(self.frame.version))

    def _fill(self) -> np.ndarray:
        return np.asarray(self.frame.data.table.snapshot.fill).reshape(-1)

    @property
    def retraces(self) -> int:
        """Total traces across the manager's jitted read sites — stays at
        one per (operator, max_matches, names) site across any number of
        appends AND recoveries (the Fig-12 zero-recompile claim)."""
        return sum(ctr["n"] for _, ctr in self._sites.values())

    # -- integrity probe -------------------------------------------------------

    def probe(self) -> list[int]:
        """The cheap dead-shard detector: a shard whose arena ``fill``
        disagrees with the supervisor's expectation, or whose bucket
        planes hold only EMPTY sentinels while rows are expected, is dead
        (``fail_shard`` blanks exactly these).  One [s] device->host
        transfer of ``fill`` plus one reduced sentinel scan."""
        self.stats.probes += 1
        dt = self.frame.data
        fill = self._fill()
        has_keys = np.zeros(fill.shape[0], bool)
        for seg in dt.table.segments:
            has_keys |= np.asarray(
                (seg.index.bucket_keys != EMPTY_KEY).any(axis=(1, 2)))
        expected = self._expected_fill
        alive = (fill == expected) & (has_keys | (expected == 0))
        return sorted(int(i) for i in np.nonzero(~alive)[0])

    # -- checkpoint ring -------------------------------------------------------

    def checkpoint(self) -> str:
        """Checkpoint the live dtable into the ring and truncate the
        lineage to the OLDEST kept checkpoint (the corruption fallback
        anchor) — the delta log stays bounded by the ring span."""
        if self.checkpoint_dir is None:
            raise ValueError("RecoveryManager has no checkpoint_dir")
        v = self._version()
        path = os.path.join(self.checkpoint_dir, f"ckpt_v{v}")
        _ckpt.save_dtable(path, self.frame.data)
        self._ckpts = [c for c in self._ckpts if c[0] != v] + [(v, path)]
        while len(self._ckpts) > max(1, self.policy.keep_checkpoints):
            _, old = self._ckpts.pop(0)
            shutil.rmtree(old, ignore_errors=True)
        if self.lineage is not None:
            oldest_v, oldest_path = self._ckpts[0]
            if oldest_v > self.lineage.base_version or \
                    self.lineage.has_base:
                self.lineage.truncate(oldest_v, oldest_path)
        self._appends_since_ckpt = 0
        return path

    # -- the supervision state machine ----------------------------------------

    def _recover_shard(self, shard: int) -> bool:
        """stale -> restore newest intact checkpoint -> replay the lineage
        suffix -> splice -> fresh.  Returns False when the budget is
        exhausted (the shard joins ``dead`` and reads degrade)."""
        if self.lineage is None:
            self.dead.add(shard)
            return False
        t0 = time.perf_counter()
        self.vv.mark_stale(shard)
        dt = self.frame.data
        fresh = replayed = None
        for version, path in reversed(self._ckpts):      # newest first
            try:
                fresh = self.lineage.replay_from(path, version, dt,
                                                 rt=self.frame.rt)
                replayed = self.lineage.version - version
                break
            except ValueError:
                self.stats.corrupt_checkpoints += 1
        if fresh is None and self.lineage.has_base:
            fresh = self.lineage.replay(self.frame.num_shards,
                                        rt=self.frame.rt, like=dt)
            replayed = len(self.lineage.deltas)
        if fresh is None:                  # budget exhausted: degrade
            self.dead.add(shard)
            return False
        healed = _runtime.splice_shard(dt, shard, fresh)
        if healed.replica is not None:
            # fail_shard marked the mirror stale (the dead executor's
            # copy died with it); with tracker and rows spliced back
            # bit-identically, one refresh restores the replica arena
            # bit-identically to a never-failed twin's.
            healed = _dtable.refresh_replica(healed, rt=self.frame.rt)
        self.frame = dataclasses.replace(self.frame, data=healed)
        self.vv.mark_fresh(shard, version=self._version())
        self._expected_fill = self._fill()
        self.stats.recoveries += 1
        self.stats.replayed_deltas.append(int(replayed))
        self.stats.mttr_s.append(time.perf_counter() - t0)
        return True

    def _heal(self) -> list[int]:
        """Fence + probe + recover: every shard the probe flags dead or
        the VersionVector fences stale is healed before the read runs."""
        version = self._version()
        suspects = set(self.probe())
        suspects.update(sh for sh in range(self.frame.num_shards)
                        if not self.vv.check_fresh(sh, version))
        recovered = []
        for shard in sorted(suspects - self.dead):
            if self._recover_shard(shard):
                recovered.append(shard)
        if recovered and self._pending:
            self._rebuild_ring()
        return recovered

    def _rebuild_ring(self):
        """Deterministically re-stage every pending (unflushed) delta
        into a FRESH ring after a heal: lineage replay restores the table
        to the last FLUSHED version, and re-enqueueing the manager's host
        mirror of the ring (``_pending``, in enqueue order) reproduces
        the lost shard's lanes bit-identically — a shard killed mid-ring
        heals to exactly the state a never-failed twin holds
        (scripts/fault_smoke.py gates this)."""
        q = self.frame.queue
        fr = dataclasses.replace(self.frame, queue=None).with_queue(
            lanes=q.lanes, lane_rows=q.lane_rows)
        for cols, valid in self._pending:
            fr = fr.enqueue(cols, valid)
        self.frame = fr

    # -- fault application -----------------------------------------------------

    def _apply_faults(self, faults):
        for f in faults:
            if f.kind == "shard_loss":
                self.frame = dataclasses.replace(
                    self.frame,
                    data=_runtime.fail_shard(self.frame.data, f.shard),
                    queue=self._fail_queue_shard(f.shard))
            elif f.kind == "capacity_pressure":
                self._pressure_divisor = max(2.0, float(f.severity))
            elif f.kind == "checkpoint_corruption":
                if self._ckpts and self.injector is not None:
                    self.injector.corrupt_checkpoint(self._ckpts[-1][1])
            elif f.kind == "straggler":
                base = 0.01
                durations = np.full(self.frame.num_shards, base)
                durations[f.shard] = base * float(f.severity)
                slow = self.straggler.observe(durations)
                if slow:
                    self.stats.straggler_events += 1
                    self.stats.speculative_plans.append(
                        self.straggler.plan_speculative(
                            self.frame.num_shards))

    def _fail_queue_shard(self, shard: int):
        """Blank the lost shard's slice of the append ring (a real
        executor death takes its staged lanes with it); the host mirror
        of what SHOULD be pending survives in ``_pending``, which is what
        ``_rebuild_ring`` heals from."""
        q = self.frame.queue
        if q is None:
            return None
        blanked = dataclasses.replace(
            q,
            cols={k: v.at[shard].set(0) for k, v in q.cols.items()},
            valid=q.valid.at[shard].set(False),
            fills=q.fills.at[shard].set(0),
            count=q.count.at[shard].set(0))
        return table_mod._set_queue_mirror(blanked,
                                           *table_mod.queue_pending(q))

    def _tick(self):
        if self.injector is not None:
            self._apply_faults(self.injector.tick())

    # -- jitted read sites (the zero-recompile proof) --------------------------

    def _site(self, kind: str, max_matches: int, names):
        key = (kind, max_matches, names)
        if key not in self._sites:
            ctr = {"n": 0}

            if kind == "BroadcastLookup":
                def f(fr, q):
                    ctr["n"] += 1
                    cols, valid, _ = _dtable.lookup(
                        fr.data, q, max_matches=max_matches, names=names,
                        rt=fr.rt)
                    return cols, valid
            elif kind == "RoutedLookup":
                def f(fr, q):
                    ctr["n"] += 1
                    return _dtable.lookup_routed_flat(
                        fr.data, q, max_matches=max_matches, names=names,
                        rt=fr.rt)
            elif kind == "HybridLookup":
                def f(fr, q):
                    ctr["n"] += 1
                    return _dtable.lookup_hybrid_flat(
                        fr.data, q, max_matches=max_matches, names=names,
                        rt=fr.rt)
            elif kind == "BroadcastJoin":
                def f(fr, pc, on):
                    ctr["n"] += 1
                    return _dtable.indexed_join_bcast(
                        fr.data, pc, on, max_matches, names=names,
                        rt=fr.rt)
            elif kind == "ShuffleJoin":
                def f(fr, pc, on):
                    ctr["n"] += 1
                    return _dtable.indexed_join_routed(
                        fr.data, pc, on, max_matches=max_matches,
                        names=names, rt=fr.rt)
            elif kind == "HybridJoin":
                def f(fr, pc, on):
                    ctr["n"] += 1
                    return _dtable.indexed_join_hybrid(
                        fr.data, pc, on, max_matches=max_matches,
                        names=names, rt=fr.rt)
            else:
                raise ValueError(f"unknown read site kind {kind!r}")
            static = (2,) if kind.endswith("Join") else ()
            self._sites[key] = (jax.jit(f, static_argnums=static), ctr)
        return self._sites[key]

    # -- reads -----------------------------------------------------------------

    def _answered_mask(self, keys_np: np.ndarray) -> np.ndarray:
        if not self.dead:
            return np.ones(keys_np.shape[0], bool)
        owner = hashing.partition_hash_host(keys_np,
                                            self.frame.num_shards)
        # EMPTY_KEY lanes are pad sentinels (serving pad-to-bucket) or
        # explicit guaranteed-miss probes: no owner needs to be alive to
        # answer them, so they never mark a read degraded
        pad = keys_np == int(np.asarray(EMPTY_KEY))
        return pad | ~np.isin(owner, np.asarray(sorted(self.dead)))

    def _routed_with_retry(self, q, max_matches: int, names):
        """The automated drop->retry contract: start at the pressured
        capacity, double per attempt under the exponential-backoff
        budget, stop at zero drops or budget exhaustion (drops are then
        reported honestly, never silently missed).

        When a fresh hot-key mirror covers this read's ``max_matches``,
        every attempt goes through the hybrid report: hot queries answer
        from the replica arena and are masked OUT of the exchange before
        capacity is spent, so a dropped-then-retried batch never re-routes
        its hot lanes at doubled capacity — the retry only re-runs the
        cold tail that actually dropped (the skew fix: under pressure a
        celebrity key can otherwise never be delivered at any doubling).
        """
        rep = self.frame.data.replica
        report = (_dtable.lookup_hybrid_report
                  if rep is not None and max_matches <= rep.max_matches
                  else _dtable.lookup_routed_report)
        s = self.frame.num_shards
        lanes = max(1, -(-int(np.shape(q)[0]) // s))
        cap = max(1, int(lanes / self._pressure_divisor))
        attempt = 0
        while True:
            cols, valid, answered, dropped = report(
                self.frame.data, q, max_matches=max_matches,
                capacity=min(cap, lanes), names=names, rt=self.frame.rt)
            n_dropped = int(np.asarray(dropped).sum())
            if n_dropped == 0 or attempt >= self.policy.max_retries:
                break
            self.stats.retries += 1
            self.stats.drops += n_dropped
            time.sleep(min(
                self.policy.backoff_base_s
                * self.policy.backoff_factor ** attempt,
                self.policy.backoff_cap_s))
            cap *= 2
            attempt += 1
        if n_dropped == 0:
            self._pressure_divisor = None     # delivery proven: relieved
        return cols, valid, np.asarray(answered), n_dropped, attempt

    def lookup(self, keys, *, max_matches: int = 64, names=None,
               op: str = "auto"):
        """Supervised ``frame.lookup``: same ``(cols [Q, M], valid
        [Q, M])`` contract, with fencing, healing, and drop-retry inside.
        ``self.last_report`` carries the per-read accounting."""
        self._tick()
        self.stats.reads += 1
        recovered = self._heal()
        names_t = None if names is None else tuple(names)
        kind = self.frame.plan_lookup(keys, max_matches=max_matches,
                                      op=op).kind
        q_np = np.asarray(keys).astype(np.int64).reshape(-1)
        retries = n_dropped = 0
        if (kind in ("RoutedLookup", "HybridLookup")
                and self._pressure_divisor is not None):
            q = jax.numpy.asarray(q_np)
            cols, valid, answered_x, n_dropped, retries = \
                self._routed_with_retry(q, max_matches, names_t)
            # pad-sentinel lanes never enter the routed exchange (their
            # qvalid is masked off), so answered_x reports them False —
            # but a guaranteed miss needs nobody to answer it
            answered = self._answered_mask(q_np) & (
                answered_x | (q_np == int(np.asarray(EMPTY_KEY))))
        else:
            fn, _ = self._site(kind, max_matches, names_t)
            cols, valid = fn(self.frame, jax.numpy.asarray(q_np))
            answered = self._answered_mask(q_np)
        degraded = bool((~answered).any())
        if degraded:
            self.stats.degraded_reads += 1
        self.stats.drops += n_dropped
        self.last_report = ReadReport(
            answered=answered, dropped=n_dropped, retries=retries,
            recovered=tuple(recovered), degraded=degraded, operator=kind)
        return cols, valid

    def join(self, probe_cols: dict, on: str, *, max_matches: int = 64,
             names=None, op: str = "auto"):
        """Supervised ``frame.join``: ``(build, probe, valid)`` in probe
        order, healed and fenced exactly like ``lookup``."""
        self._tick()
        self.stats.reads += 1
        recovered = self._heal()
        names_t = None if names is None else tuple(names)
        kind = self.frame.plan_join(probe_cols, on,
                                    max_matches=max_matches, op=op).kind
        fn, _ = self._site(kind, max_matches, names_t)
        out = fn(self.frame, {k: jax.numpy.asarray(v)
                              for k, v in probe_cols.items()}, on)
        q_np = np.asarray(probe_cols[on]).astype(np.int64).reshape(-1)
        answered = self._answered_mask(q_np)
        degraded = bool((~answered).any())
        if degraded:
            self.stats.degraded_reads += 1
        self.last_report = ReadReport(
            answered=answered, dropped=0, retries=0,
            recovered=tuple(recovered), degraded=degraded, operator=kind)
        return out

    # -- writes ----------------------------------------------------------------

    def append(self, cols, valid=None, *, donate: bool = False,
               compact_threshold: int | None = None) -> "RecoveryManager":
        """Supervised ``frame.append``: heals first (an ingest must never
        land on a blanked shard), records the delta into the lineage, and
        auto-checkpoints every ``policy.checkpoint_every`` appends.
        Returns ``self`` — the manager owns the new version."""
        self._tick()
        self._heal()
        if isinstance(cols, (list, tuple)):
            cols, valid = table_mod.coalesce_deltas(cols,
                                                    self.frame.schema,
                                                    valid)
        self.frame = self.frame.append(cols, valid, donate=donate,
                                       compact_threshold=compact_threshold)
        if self.lineage is not None:
            self.lineage.record_append(cols, valid)
        self.stats.appends += 1
        self.vv.bump_all()
        self._expected_fill = self._fill()
        self._appends_since_ckpt += 1
        if (self.checkpoint_dir is not None and self.policy.checkpoint_every
                and self._appends_since_ckpt >= self.policy.checkpoint_every):
            self.checkpoint()
        return self

    def enqueue(self, cols, valid=None) -> "RecoveryManager":
        """Supervised ``frame.enqueue``: stages the delta in the
        device-resident ring AND mirrors it host-side (``_pending``) so a
        shard killed mid-ring heals bit-identically — lineage only
        records landed versions, so the manager itself must remember
        what is staged.  No version bump, no checkpoint pressure."""
        self._tick()
        self._heal()
        from repro.frame import _hash_string_cols
        cols = _hash_string_cols(cols, self.frame.schema)
        host = ({k: np.asarray(v).copy() for k, v in cols.items()},
                None if valid is None else np.asarray(valid, bool).copy())
        self.frame = self.frame.enqueue(cols, valid)
        self._pending.append(host)
        self.stats.enqueues += 1
        return self

    def flush(self, *,
              compact_threshold: int | None = None) -> "RecoveryManager":
        """Supervised ``frame.flush``: lands the ring (one fused jit, one
        sync, ONE version bump) and records the coalesced pending deltas
        into the lineage as ONE append — replaying the log reproduces the
        flush bit-identically (flush ≡ coalesced append by the parity
        tests), keeping version parity between live and healed tables."""
        self._tick()
        self._heal()
        if not self._pending:
            return self
        self.frame = self.frame.flush(compact_threshold=compact_threshold)
        if self.lineage is not None:
            cols, valid = table_mod.coalesce_deltas(
                [c for c, _ in self._pending], self.frame.schema,
                [v for _, v in self._pending])
            self.lineage.record_append(cols, valid)
        self._pending.clear()
        self.stats.flushes += 1
        self.vv.bump_all()
        self._expected_fill = self._fill()
        self._appends_since_ckpt += 1
        if (self.checkpoint_dir is not None and self.policy.checkpoint_every
                and self._appends_since_ckpt >= self.policy.checkpoint_every):
            self.checkpoint()
        return self


def supervise(frame, *, lineage: _runtime.Lineage | None = None,
              policy: RecoveryPolicy | None = None,
              injector: FaultInjector | None = None,
              checkpoint_dir: str | None = None) -> RecoveryManager:
    """Functional entry point (``IndexedFrame.supervised`` delegates
    here): wrap a distributed frame in a ``RecoveryManager``."""
    return RecoveryManager(frame, lineage=lineage, policy=policy,
                           injector=injector, checkpoint_dir=checkpoint_dir)


# ---------------------------------------------------------------------------
# Partitioned supervision: one RecoveryManager per partition
# ---------------------------------------------------------------------------

class PartitionedSupervisor:
    """Per-partition supervision for a partitioned distributed frame
    (DESIGN.md §16): each partition gets its OWN ``RecoveryManager``
    (own checkpoints under ``checkpoint_dir/part_<id>``, own fault
    injector via ``supervisor.managers[i].injector``, own jitted read
    sites), and reads route pruned sub-batches to the owning partition's
    manager — a shard kill in one partition heals there without ever
    entering another partition's read path.

    Duck-types the ``RecoveryManager`` surface the serving engine and
    the facade rely on (``frame`` / ``lookup`` / ``join`` / ``append`` /
    ``flush`` / ``checkpoint`` / ``retraces`` / ``last_report``); like a
    manager it has no ``plan_lookup``, which is how ``QueryEngine``
    recognizes supervised mode."""

    def __init__(self, frame, *, policy: RecoveryPolicy | None = None,
                 checkpoint_dir: str | None = None,
                 with_lineage: bool = False):
        from repro.core import partition as _part
        pt = frame.data
        if not isinstance(pt, _part.PartitionedTable) or not pt.dist:
            raise ValueError(
                "PartitionedSupervisor wraps a PARTITIONED distributed "
                "frame (from_columns(partition_by=..., num_shards>1))")
        self._part = _part
        self._frame_cls = type(frame)
        self.rt = frame.rt
        self.spec = pt.spec
        self._version = pt.version
        self.managers = []
        for i, part in enumerate(pt.parts):
            sub = self._frame_cls(data=part, rt=frame.rt)
            lin = None
            if with_lineage:
                # one replay recipe per partition: its VALID base rows
                # (collect_cols drops pad lanes), at the partition's own
                # arena config so replay is bit-identical
                lin = _runtime.Lineage(
                    pt.schema, _dtable.collect_cols(part, rt=frame.rt),
                    rows_per_batch=pt.rows_per_batch, layout=pt.layout,
                    slots=pt.slots)
            cdir = (None if checkpoint_dir is None else
                    os.path.join(checkpoint_dir, f"part_{pt.spec.ids[i]}"))
            self.managers.append(RecoveryManager(sub, lineage=lin,
                                                 policy=policy,
                                                 checkpoint_dir=cdir))
        self.last_report: ReadReport | None = None

    # -- frame ownership ------------------------------------------------------

    @property
    def frame(self):
        pt = self._part.PartitionedTable(
            parts=tuple(m.frame.data for m in self.managers),
            version=self._version, spec=self.spec)
        return self._frame_cls(data=pt, rt=self.rt)

    @frame.setter
    def frame(self, fr):
        pt = fr.data
        if tuple(pt.spec.ids) != tuple(self.spec.ids):
            raise ValueError("cannot re-point a PartitionedSupervisor at a "
                             "different partition layout")
        for m, part in zip(self.managers, pt.parts):
            m.frame = dataclasses.replace(m.frame, data=part)
        self._version = pt.version

    @property
    def retraces(self) -> int:
        return sum(m.retraces for m in self.managers)

    # -- reads (pruned routing into per-partition managers) -------------------

    def _route(self, keys_np: np.ndarray):
        dest = self.spec.route_host(keys_np)
        return dest, [int(p) for p in np.unique(dest[dest >= 0])]

    def lookup(self, keys, *, max_matches: int = 64, names=None,
               op: str = "auto"):
        """Supervised pruned lookup: each touched partition's manager
        fences/heals/reads its own masked sub-batch; untouched
        partitions run nothing.  ``last_report`` merges per-partition
        accounting."""
        if op != "auto":
            raise ValueError("partitioned supervision picks per-partition "
                             "flavors itself; op must be 'auto'")
        fr = self.frame
        self._part._check_keyed(fr.data, "lookup")
        keys_np = np.asarray(keys).astype(np.int64).reshape(-1)
        q = keys_np.shape[0]
        sel = (tuple(names) if names is not None else fr.schema.names)
        import jax.numpy as jnp
        out_cols = {n: jnp.zeros((q, max_matches),
                                 fr.schema.column(n).jnp_dtype)
                    for n in sel}
        out_valid = jnp.zeros((q, max_matches), bool)
        answered = np.ones(q, bool)
        dropped = retries = 0
        recovered: list = []
        degraded = False
        dest, touched = self._route(keys_np)
        for p in touched:
            masked = np.where(dest == p, keys_np,
                              np.int64(np.asarray(EMPTY_KEY)))
            c, v = self.managers[p].lookup(
                jax.numpy.asarray(masked), max_matches=max_matches,
                names=names)
            out_valid = out_valid | v
            out_cols = {n: jnp.where(v, c[n], out_cols[n]) for n in sel}
            rep = self.managers[p].last_report
            answered &= rep.answered
            dropped += rep.dropped
            retries += rep.retries
            recovered.extend(rep.recovered)
            degraded |= rep.degraded
        self.last_report = ReadReport(
            answered=answered, dropped=dropped, retries=retries,
            recovered=tuple(recovered), degraded=degraded,
            operator="PartitionedLookup")
        return out_cols, out_valid

    def join(self, probe_cols: dict, on: str, *, max_matches: int = 64,
             names=None, op: str = "auto"):
        """Supervised pruned join: per-partition local joins through each
        owning partition's manager; probe broadcast rebuilt from the
        ORIGINAL probe side so output matches ``joins.indexed_join``."""
        if op != "auto":
            raise ValueError("partitioned supervision picks per-partition "
                             "flavors itself; op must be 'auto'")
        if on not in probe_cols:
            raise ValueError(f"probe column {on!r} not in probe_cols "
                             f"{sorted(probe_cols)}")
        import jax.numpy as jnp
        keys_np = np.asarray(probe_cols[on]).astype(np.int64).reshape(-1)
        bc, valid = self.lookup(keys_np, max_matches=max_matches,
                                names=names)
        m = valid.shape[1]
        probe_b = {k: jnp.broadcast_to(jnp.asarray(v)[:, None],
                                       (np.shape(v)[0], m))
                   for k, v in probe_cols.items()}
        return bc, probe_b, valid

    # -- writes ---------------------------------------------------------------

    def append(self, cols, valid=None, *, donate: bool = False,
               compact_threshold: int | None = None
               ) -> "PartitionedSupervisor":
        """Routed supervised append: each receiving partition's manager
        heals first, lands its slice, and records it in its own lineage;
        one global version bump."""
        if isinstance(cols, (list, tuple)):
            cols, valid = table_mod.coalesce_deltas(
                cols, self.managers[0].frame.schema, valid)
        for p, sub, sub_valid in self._part.split_by_partition(
                self.spec, cols, valid):
            self.managers[p].append(sub, sub_valid, donate=donate,
                                    compact_threshold=compact_threshold)
        self._version = self._version + 1
        return self

    def flush(self, **kw) -> "PartitionedSupervisor":
        """No frame-level ring on partitioned frames: nothing staged,
        nothing to land."""
        return self

    def checkpoint(self):
        """Checkpoint every partition (each manager anchors its own
        recovery)."""
        return [m.checkpoint() for m in self.managers]

    def drop_partition(self, pid) -> "PartitionedSupervisor":
        """O(1) retention under supervision: drop the partition AND its
        manager (its checkpoints stop being maintained)."""
        i = self.spec.index_of(pid)
        self.spec = self.spec.drop(i)
        del self.managers[i]
        self._version = self._version + 1
        return self
