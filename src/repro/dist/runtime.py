"""Distributed runtime: lineage recovery, version fencing, stragglers.

Paper §III-D + Fig 12: an executor failure must not lose the indexed
cache — the lost partition is rebuilt from *lineage* (the deterministic
recipe: re-route the base dataframe, re-index, replay appends), the failed
query pays the rebuild, and subsequent queries return to steady state.
``benchmarks/fault_tolerance.py`` measures exactly that spike shape.

Because a dtable's construction pipeline is deterministic (host routing,
vmapped builds, host-coordinated overflow retries), a lineage replay
reproduces the lost shard's leaves bit-for-bit shape-wise — so a rebuilt
dtable re-enters the same jit cache entry as the original (no recompile
after recovery, which is what keeps the Fig-12 tail flat).

``VersionVector`` is the stale-read fence of §III-D: readers carry the
version they indexed against; a shard that has moved on (or is marked
stale during rebuild) rejects the read.  ``StragglerPolicy`` plans
speculative re-execution for shards running past a deadline factor —
the standard lineage-system mitigation the paper inherits from Spark.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hashindex as hix
from repro.core import hashing
from repro.core.hashindex import EMPTY_KEY
from repro.core.pointers import NULL_PTR
from repro.core.schema import Schema
from repro.dist import dtable as _dtable
from repro.dist import mesh as _mesh


class Lineage:
    """Host-side append log: the deterministic recipe for any shard.

    Records the base dataframe and every appended delta (defensive copies
    — lineage must survive mutation of the caller's buffers).  ``replay``
    re-runs the exact construction pipeline at any shard count.

    The log would grow without bound under a write-hot stream, so it can
    be **checkpoint-anchored** (``truncate``): once a checkpoint holds the
    dtable at version ``v``, every delta at or below ``v`` is subsumed by
    the checkpoint and dropped — replay becomes restore-the-anchor plus
    the *suffix* of deltas since it, O(deltas since last checkpoint)
    instead of O(full history) (paper §III-D; DESIGN.md §12).
    """

    def __init__(self, schema: Schema, base_cols: dict, *,
                 rows_per_batch: int = 4096, layout: str = "row",
                 slots: int = hix.DEFAULT_SLOTS):
        self.schema = schema
        self.rows_per_batch = rows_per_batch
        self.layout = layout
        self.slots = slots
        self.base = {k: np.array(v, copy=True)
                     for k, v in base_cols.items()}
        self.deltas: list[tuple[dict, np.ndarray | None]] = []
        # version the replay STARTS from: 0 = the base recipe; after
        # truncate(v, path) the anchor checkpoint holds version v.
        self.base_version = 0
        self.anchor_path: str | None = None

    @property
    def version(self) -> int:
        """The dtable version a full replay reproduces (one bump per
        recorded append; appends are the only version-bumping ops a
        lineage records)."""
        return self.base_version + len(self.deltas)

    @property
    def has_base(self) -> bool:
        """Whether a from-scratch replay is still possible (False once
        ``truncate`` anchored the log to a checkpoint)."""
        return self.base is not None

    def record_append(self, delta_cols: dict, valid=None):
        self.deltas.append((
            {k: np.array(v, copy=True) for k, v in delta_cols.items()},
            None if valid is None else np.array(valid, bool, copy=True)))

    def deltas_since(self, version: int) -> list:
        """The replay suffix for a dtable restored at ``version``."""
        k = version - self.base_version
        if not 0 <= k <= len(self.deltas):
            raise ValueError(
                f"lineage covers versions [{self.base_version}, "
                f"{self.version}]; cannot take the suffix after {version}")
        return self.deltas[k:]

    def truncate(self, version: int, checkpoint_path: str):
        """Anchor the log at a checkpoint: deltas at or below ``version``
        are subsumed by ``checkpoint_path`` and dropped (the base recipe
        too).  Closes the unbounded delta log: replay cost from here on is
        O(deltas since the anchor)."""
        suffix = self.deltas_since(version)   # validates the version
        self.deltas = suffix
        self.base_version = version
        self.anchor_path = checkpoint_path
        self.base = None

    def _apply(self, dt: _dtable.DistributedTable, deltas,
               rt: "_mesh.Runtime | None") -> _dtable.DistributedTable:
        for delta, valid in deltas:
            dt = _dtable.append_distributed(dt, delta, valid, rt=rt)
        return dt

    def replay_from(self, checkpoint_path: str, version: int, like, *,
                    rt: "_mesh.Runtime | None" = None
                    ) -> _dtable.DistributedTable:
        """Restore the checkpoint holding ``version`` into ``like``'s
        structure, then replay only the lineage suffix since it — the
        fast recovery path (O(deltas since checkpoint)).  Raises
        ``ValueError`` on a corrupt/missing checkpoint (CRC-verified,
        dist/checkpoint.py) or a version outside the log."""
        from repro.dist import checkpoint as _ckpt
        suffix = self.deltas_since(version)   # validate before touching IO
        dt = _ckpt.restore_dtable(checkpoint_path, like)
        return self._apply(dt, suffix, rt)

    def replay(self, num_shards: int,
               rt: "_mesh.Runtime | None" = None, *,
               like=None) -> _dtable.DistributedTable:
        """Re-run the construction pipeline — on whichever execution
        backend the live system uses (lineage is backend-agnostic: the
        two are bit-identical, tests/test_mesh_parity.py).  A truncated
        lineage replays from its anchor checkpoint instead of the base
        recipe and then needs ``like`` (the live dtable) as the restore
        template."""
        if self.base is None:
            if like is None:
                raise ValueError(
                    "lineage was truncated to a checkpoint anchor; "
                    "replay needs like= (the live dtable) as the restore "
                    "template")
            return self.replay_from(self.anchor_path, self.base_version,
                                    like, rt=rt)
        dt = _dtable.create_distributed(
            self.base, self.schema, num_shards,
            rows_per_batch=self.rows_per_batch, layout=self.layout,
            slots=self.slots, rt=rt)
        if like is not None and like.table.hot is not None:
            # Re-attach an EMPTY tracker shaped like the live one BEFORE
            # replaying the append log: trackers are attached at creation
            # and count ingest only (never back-counted), so the replay
            # reproduces the live tracker bit-identically — and the
            # spliced pytree structurally matches (splice_shard tree_maps
            # the whole table).
            h = like.table.hot
            sd, sw = ((h.sketch.shape[-2], h.sketch.shape[-1])
                      if h.sketch is not None
                      else (_dtable.table_mod.SKETCH_DEPTH,
                            _dtable.table_mod.SKETCH_WIDTH))
            dt = dataclasses.replace(dt, table=dataclasses.replace(
                dt.table, hot=_dtable.table_mod.empty_tracker(
                    h.keys.shape[-1], mode=h.mode, sketch_depth=sd,
                    sketch_width=sw, num_shards=num_shards)))
        return self._apply(dt, self.deltas, rt)


def fail_shard(dt: _dtable.DistributedTable,
               shard: int) -> _dtable.DistributedTable:
    """Simulate executor loss: blank the shard's slice of every leaf.

    Shapes (and therefore jit caches) are preserved — only the shard's
    contents are gone, exactly like a re-attached blank executor.  Index
    structures are blanked to their *sentinels* (EMPTY keys, NULL
    pointers, valid=False), not zero: zero is a legal key and a legal row
    id, and a dead shard must answer every lookup with a miss, never a
    fabricated key-0 match.
    """

    def kill(leaf, fill):
        return leaf.at[shard].set(jnp.asarray(fill).astype(leaf.dtype))

    t = dt.table
    ehi, elo = hashing.split64(jnp.full((), EMPTY_KEY, jnp.int64))
    segments = tuple(dataclasses.replace(
        s,
        data=jax.tree.map(lambda a: kill(a, 0), s.data),
        valid=kill(s.valid, False),
        prev=kill(s.prev, NULL_PTR),
        index=dataclasses.replace(s.index,
                                  bucket_keys=kill(s.index.bucket_keys,
                                                   EMPTY_KEY),
                                  bucket_ptrs=kill(s.index.bucket_ptrs,
                                                   NULL_PTR)))
        for s in t.segments)
    snap = dataclasses.replace(
        t.snapshot,
        blocks=tuple(dataclasses.replace(b, key_hi=kill(b.key_hi, ehi),
                                         key_lo=kill(b.key_lo, elo),
                                         ptrs=kill(b.ptrs, NULL_PTR))
                     for b in t.snapshot.blocks),
        prev=kill(t.snapshot.prev, NULL_PTR),
        # arena fill -> 0: the dead shard's fused reads mask everything
        # out (defense in depth on top of the EMPTY/NULL sentinels)
        fill=kill(t.snapshot.fill, 0),
        data=(None if t.snapshot.data is None
              else jax.tree.map(lambda a: kill(a, 0), t.snapshot.data)))
    table = dataclasses.replace(t, segments=segments, snapshot=snap)
    if t.hot is not None:
        # The shard's hot-key counts died with it (rebuilt by lineage
        # replay, which replays them bit-identically into the splice).
        table = dataclasses.replace(table, hot=dataclasses.replace(
            t.hot, keys=kill(t.hot.keys, EMPTY_KEY),
            counts=kill(t.hot.counts, 0),
            sketch=(None if t.hot.sketch is None
                    else kill(t.hot.sketch, 0))))
    out = dataclasses.replace(dt, table=table)
    if dt.replica is not None:
        # The dead executor's replica copy is gone; our un-stacked
        # representation models that as global staleness (version -1 ⇒
        # hybrid degrades to pure routing), and the supervisor's heal
        # re-mirrors after the splice — bit-identical to a refresh on a
        # never-failed dtable, since tracker and rows are restored
        # bit-identically first.
        out = dataclasses.replace(out, replica=dataclasses.replace(
            dt.replica, version=jnp.asarray(-1, jnp.int32)))
    return out


def splice_shard(dt: _dtable.DistributedTable, shard: int,
                 fresh: _dtable.DistributedTable
                 ) -> _dtable.DistributedTable:
    """Splice one shard's slice of ``fresh`` into ``dt`` (the recovery
    state machine's final step — DESIGN.md §12).  Leaf shapes are
    untouched, so the spliced dtable re-enters every live jit cache
    entry.  Raises if the two dtables disagree on the global version
    (a lineage that missed a ``record_append``)."""
    if int(np.asarray(fresh.version)) != int(np.asarray(dt.version)):
        raise ValueError(
            f"lineage replays to version {int(np.asarray(fresh.version))} "
            f"but the dtable is at version {int(np.asarray(dt.version))}; "
            f"every append_distributed must be paired with "
            f"Lineage.record_append")

    def splice(broken, rebuilt):
        return broken.at[shard].set(rebuilt[shard])

    table = jax.tree.map(splice, dt.table, fresh.table)
    return dataclasses.replace(dt, table=table)


def rebuild_shard(dt: _dtable.DistributedTable, shard: int,
                  lineage: Lineage,
                  rt: "_mesh.Runtime | None" = None
                  ) -> _dtable.DistributedTable:
    """Lineage recovery (paper Fig 12): rebuild one shard and splice it in.

    CI-scale replays the whole pipeline and takes the shard's slice —
    determinism makes the splice exact; a production runtime re-routes
    only the lost partition's rows.  A checkpoint-anchored lineage
    replays restore + suffix instead of the full history.  Raises if the
    lineage's version disagrees with the live dtable (missed
    ``record_append``).
    """
    fresh = lineage.replay(dt.num_shards, rt=rt, like=dt)
    return splice_shard(dt, shard, fresh)


@dataclasses.dataclass
class VersionVector:
    """Per-shard MVCC fencing (paper §III-D stale-read detection)."""

    versions: list
    stale: set

    @classmethod
    def fresh(cls, num_shards: int) -> "VersionVector":
        return cls(versions=[0] * num_shards, stale=set())

    def bump(self, shard: int):
        self.versions[shard] += 1

    def bump_all(self):
        self.versions = [v + 1 for v in self.versions]

    def mark_stale(self, shard: int):
        """Fence a shard out (failed / mid-rebuild): no version passes."""
        self.stale.add(shard)

    def mark_fresh(self, shard: int, version: int | None = None):
        self.stale.discard(shard)
        if version is not None:
            self.versions[shard] = version

    def check_fresh(self, shard: int, version: int) -> bool:
        """True iff a read indexed at ``version`` is safe on ``shard``."""
        return shard not in self.stale and version >= self.versions[shard]


class StragglerPolicy:
    """Speculative re-execution planning (deadline = factor x median).

    ``min_deadline`` is an absolute floor (seconds): an all-fast batch has
    a near-zero median, and ``factor × ~0`` would flag every harmless
    microsecond of jitter as a straggler.  Below the floor, nothing is
    slow enough to be worth a speculative copy.
    """

    def __init__(self, deadline_factor: float = 2.0,
                 min_deadline: float = 1e-3):
        if deadline_factor <= 0 or min_deadline < 0:
            raise ValueError(
                f"deadline_factor must be > 0 and min_deadline >= 0, got "
                f"{deadline_factor!r} / {min_deadline!r}")
        self.deadline_factor = deadline_factor
        self.min_deadline = min_deadline
        self.slow: list[int] = []

    def observe(self, durations) -> list:
        """Record per-shard task durations; returns straggler indices.
        An empty batch observes nothing (and clears the straggler set)."""
        d = np.asarray(durations, dtype=np.float64)
        if d.size == 0:
            self.slow = []
            return self.slow
        deadline = max(self.deadline_factor * float(np.median(d)),
                       self.min_deadline)
        self.slow = [i for i, t in enumerate(d) if t > deadline]
        return self.slow

    def plan_speculative(self, num_shards: int) -> dict:
        """{straggler shard -> healthy shard to run the backup copy on};
        backups round-robin over the healthy shards."""
        healthy = [i for i in range(num_shards) if i not in self.slow]
        if not healthy:
            return {}
        return {s: healthy[j % len(healthy)]
                for j, s in enumerate(self.slow)}
