"""Capacity-bounded all-to-all shuffle over the partition hash.

Paper §III-C: the distributed Indexed DataFrame hash-partitions rows by
key so every row (and every probe) has exactly one owning shard.  Sparkle
(arXiv:1708.05746) showed shared-memory shuffle restructuring is where
distributed dataframe runtimes win or lose; ours is a two-phase, fully
vectorized exchange with **static shapes** (XLA needs them):

1. ``route_local`` — each source shard sorts its rows by destination
   (``hashing.partition_hash``) and scatters them into ``num_shards``
   capacity-bounded outboxes.  Overflow is *counted, never silent*: rows
   beyond ``capacity`` for one destination are dropped and reported, the
   exact analog of the hash-index build's overflow contract (callers
   retry with a bigger capacity).
2. ``shuffle_global`` — the all-to-all: outbox [src, dest, cap] becomes
   inbox [dest, src * cap].  On CPU CI this is a transpose; under
   ``shard_map`` on a real mesh the same data movement is one
   ``jax.lax.all_to_all`` over the shard axis.

Payloads are pytrees: ``rows`` may be a single [n, ...] array or a dict of
per-column arrays — every leaf rides the same key-derived permutation, so
routing stays consistent across columns.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import hashing
from repro.core.hashindex import _segment_rank


# Below this shard count the per-destination rank comes from a one-hot
# cumsum (O(n*s) adds, no sort); above it from a stable argsort
# (O(n log^2 n) — XLA's CPU sort is ~1us/element, which dominated the
# routed-lookup profile at CI sizes).  Both produce the same rank — a
# stable sort preserves input order within a destination, and so does the
# running count — so the outboxes are bit-identical either way.
RANK_ONEHOT_MAX_SHARDS = 64


def route_local(keys, rows, valid, num_shards: int, capacity: int):
    """Route [n] rows into ``num_shards`` capacity-bounded outboxes.

    keys     : [n] int64 routing keys
    rows     : [n, ...] array or pytree of [n, ...] arrays (the payload)
    valid    : [n] bool — invalid lanes are never routed
    Returns ``(keys [s, cap], rows [s, cap, ...], valid [s, cap],
    dropped)`` where ``dropped`` counts valid rows that overflowed their
    destination's capacity (0 means the exchange was exact).  Capacity
    keeps the FIRST ``capacity`` rows per destination in input order.
    """
    keys = jnp.asarray(keys, jnp.int64)
    valid = jnp.asarray(valid, bool)
    # invalid lanes route to a virtual shard num_shards and are dropped
    dest = jnp.where(valid, hashing.partition_hash(keys, num_shards),
                     jnp.int32(num_shards))
    if num_shards <= RANK_ONEHOT_MAX_SHARDS:
        order = None                          # rank in input order, no sort
        oh = dest[:, None] == jnp.arange(num_shards, dtype=jnp.int32)
        counts = jnp.cumsum(oh.astype(jnp.int32), axis=0)
        rank = jnp.take_along_axis(
            counts, jnp.minimum(dest, num_shards - 1)[:, None], axis=1
        )[:, 0] - 1
        d_s, v_s = dest, valid
    else:
        order = jnp.argsort(dest, stable=True)
        d_s = dest[order]
        v_s = valid[order]
        rank = _segment_rank(d_s)             # slot within the destination
    routed = v_s & (d_s < num_shards)
    ok = routed & (rank < capacity)
    dropped = jnp.sum(routed & (rank >= capacity))
    flat = jnp.where(ok, d_s * capacity + jnp.minimum(rank, capacity - 1),
                     jnp.int32(num_shards * capacity))  # out of range: drop

    def scatter(a):
        a = jnp.asarray(a)
        out = jnp.zeros((num_shards * capacity,) + a.shape[1:], a.dtype)
        out = out.at[flat].set(a if order is None else a[order],
                               mode="drop")
        return out.reshape((num_shards, capacity) + a.shape[1:])

    out_keys = scatter(keys)
    out_rows = jax.tree.map(scatter, rows)
    out_valid = (jnp.zeros((num_shards * capacity,), bool)
                 .at[flat].set(ok, mode="drop")
                 .reshape(num_shards, capacity))
    return out_keys, out_rows, out_valid, dropped


def shuffle_global(keys, rows, valid, num_shards: int, capacity: int):
    """All-to-all: per-source [s, n] rows -> per-destination inboxes.

    keys/valid : [s, n]; rows: [s, n, ...] array or pytree of such.
    Returns ``(keys [s, s*cap], rows [s, s*cap, ...], valid [s, s*cap],
    dropped [s])`` — destination-major; ``dropped[i]`` is source shard i's
    overflow count.  ``capacity`` bounds each (src, dest) lane; capacity =
    n can never drop.  The src<->dest transpose here is the single-device
    *oracle* for the exchange; the mesh-native path (``shuffle_global_axis``
    under ``dist.mesh.axis_map``) moves the same outboxes with one
    ``lax.all_to_all`` over the shard axis, and a dedicated test asserts
    the two produce identical inboxes.
    """
    route = jax.vmap(
        lambda k, r, v: route_local(k, r, v, num_shards, capacity))
    lk, lr, lv, dropped = route(keys, rows, valid)    # [src, dest, cap, ...]

    def all_to_all(x):                                # -> [dest, src*cap, ...]
        x = jnp.swapaxes(x, 0, 1)
        return x.reshape((num_shards, num_shards * capacity) + x.shape[3:])

    return (all_to_all(lk), jax.tree.map(all_to_all, lr), all_to_all(lv),
            dropped)


def pack_words(tree):
    """Pytree of [n, ...] leaves -> ([n, W] int32 words, static spec).

    One exchange beats many: every ``lax.all_to_all`` pays a launch +
    synchronization cost per call (painful on emulated CPU meshes,
    non-trivial on real interconnects), so the exchange payload is packed
    into a single int32 word matrix — 8-byte dtypes bitcast to two word
    planes, 4-byte dtypes to one, bools widened — sent in ONE collective,
    and unpacked bit-exactly on the other side.  ``spec`` is static
    (treedef + per-leaf dtype/shape/width): it never rides the wire.
    """
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    cols, spec = [], []
    for a in leaves:
        a = jnp.asarray(a)
        n, tail = a.shape[0], a.shape[1:]
        flat = a.reshape(n, -1)
        if a.dtype == jnp.bool_:
            w = flat.astype(jnp.int32)
        elif a.dtype.itemsize == 8:
            w = jax.lax.bitcast_convert_type(flat, jnp.int32).reshape(n, -1)
        elif a.dtype.itemsize == 4:
            w = jax.lax.bitcast_convert_type(flat, jnp.int32)
        elif a.dtype.itemsize == 2:
            # bitcast, not astype: float16/bfloat16 values must round-trip
            # bit-exactly (astype would silently truncate 3.7 -> 3)
            w = (jax.lax.bitcast_convert_type(flat, jnp.int16)
                 .astype(jnp.int32))
        elif jnp.issubdtype(a.dtype, jnp.integer):   # 1-byte ints
            w = flat.astype(jnp.int32)
        else:
            raise TypeError(f"pack_words: unsupported payload dtype "
                            f"{a.dtype}")
        cols.append(w)
        spec.append((a.dtype, tail, w.shape[1]))
    return jnp.concatenate(cols, axis=1), (treedef, tuple(spec))


def unpack_words(packed, spec):
    """Inverse of ``pack_words``: [n, W] int32 -> the original pytree."""
    treedef, leaf_specs = spec
    n = packed.shape[0]
    leaves, off = [], 0
    for dtype, tail, width in leaf_specs:
        w = packed[:, off:off + width]
        off += width
        if dtype == jnp.bool_:
            a = w != 0
        elif dtype.itemsize == 8:
            a = jax.lax.bitcast_convert_type(w.reshape(n, -1, 2), dtype)
        elif dtype.itemsize == 4:
            a = jax.lax.bitcast_convert_type(w, dtype)
        elif dtype.itemsize == 2:
            a = jax.lax.bitcast_convert_type(w.astype(jnp.int16), dtype)
        else:
            a = w.astype(dtype)
        leaves.append(a.reshape((n,) + tail))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def all_to_all_axis(x, axis_name: str):
    """Per-shard outbox [s, cap, ...] -> per-shard inbox [s*cap, ...].

    One ``lax.all_to_all`` over the named shard axis: chunk ``d`` of this
    shard's outbox is delivered to shard ``d``; the received chunks stack
    src-major, matching ``shuffle_global``'s ``[dest, src*cap]`` layout
    exactly.  Runs under either backend (vmap has an all_to_all batching
    rule; shard_map lowers it to the real collective).
    """
    x = jax.lax.all_to_all(x, axis_name, split_axis=0, concat_axis=0)
    return x.reshape((x.shape[0] * x.shape[1],) + x.shape[2:])


def shuffle_global_axis(keys, rows, valid, num_shards: int, capacity: int,
                        axis_name: str):
    """Per-shard body of the exchange, for use under ``mesh.axis_map``.

    keys/valid : [n] (this shard's slice); rows: [n, ...] pytree.
    Returns ``(keys [s*cap], rows [s*cap, ...], valid [s*cap], dropped)``
    — this shard's inbox, src-major, plus its own overflow count.  Mapped
    over the shard axis this computes exactly ``shuffle_global``; the
    transpose is now a genuine ``lax.all_to_all``, and the whole payload
    (keys + every row leaf + validity) rides ONE collective, word-packed.
    """
    lk, lr, lv, dropped = route_local(keys, rows, valid, num_shards,
                                      capacity)
    flat = jax.tree.map(
        lambda a: a.reshape((num_shards * capacity,) + a.shape[2:]),
        (lk, lr, lv))
    packed, spec = pack_words(flat)
    inbox = all_to_all_axis(
        packed.reshape(num_shards, capacity, packed.shape[1]), axis_name)
    ik, ir, iv = unpack_words(inbox, spec)
    return ik, ir, iv, dropped
