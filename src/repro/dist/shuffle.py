"""Capacity-bounded all-to-all shuffle over the partition hash.

Paper §III-C: the distributed Indexed DataFrame hash-partitions rows by
key so every row (and every probe) has exactly one owning shard.  Sparkle
(arXiv:1708.05746) showed shared-memory shuffle restructuring is where
distributed dataframe runtimes win or lose; ours is a two-phase, fully
vectorized exchange with **static shapes** (XLA needs them):

1. ``route_local`` — each source shard sorts its rows by destination
   (``hashing.partition_hash``) and scatters them into ``num_shards``
   capacity-bounded outboxes.  Overflow is *counted, never silent*: rows
   beyond ``capacity`` for one destination are dropped and reported, the
   exact analog of the hash-index build's overflow contract (callers
   retry with a bigger capacity).
2. ``shuffle_global`` — the all-to-all: outbox [src, dest, cap] becomes
   inbox [dest, src * cap].  On CPU CI this is a transpose; under
   ``shard_map`` on a real mesh the same data movement is one
   ``jax.lax.all_to_all`` over the shard axis.

Payloads are pytrees: ``rows`` may be a single [n, ...] array or a dict of
per-column arrays — every leaf rides the same key-derived permutation, so
routing stays consistent across columns.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import hashing
from repro.core.hashindex import _segment_rank


def route_local(keys, rows, valid, num_shards: int, capacity: int):
    """Route [n] rows into ``num_shards`` capacity-bounded outboxes.

    keys     : [n] int64 routing keys
    rows     : [n, ...] array or pytree of [n, ...] arrays (the payload)
    valid    : [n] bool — invalid lanes are never routed
    Returns ``(keys [s, cap], rows [s, cap, ...], valid [s, cap],
    dropped)`` where ``dropped`` counts valid rows that overflowed their
    destination's capacity (0 means the exchange was exact).
    """
    keys = jnp.asarray(keys, jnp.int64)
    valid = jnp.asarray(valid, bool)
    # invalid lanes sort to a virtual shard num_shards and are dropped
    dest = jnp.where(valid, hashing.partition_hash(keys, num_shards),
                     jnp.int32(num_shards))
    order = jnp.argsort(dest, stable=True)
    d_s = dest[order]
    v_s = valid[order]
    rank = _segment_rank(d_s)                 # slot within the destination
    routed = v_s & (d_s < num_shards)
    ok = routed & (rank < capacity)
    dropped = jnp.sum(routed & (rank >= capacity))
    flat = jnp.where(ok, d_s * capacity + jnp.minimum(rank, capacity - 1),
                     jnp.int32(num_shards * capacity))  # out of range: drop

    def scatter(a):
        a = jnp.asarray(a)
        out = jnp.zeros((num_shards * capacity,) + a.shape[1:], a.dtype)
        out = out.at[flat].set(a[order], mode="drop")
        return out.reshape((num_shards, capacity) + a.shape[1:])

    out_keys = scatter(keys)
    out_rows = jax.tree.map(scatter, rows)
    out_valid = (jnp.zeros((num_shards * capacity,), bool)
                 .at[flat].set(ok, mode="drop")
                 .reshape(num_shards, capacity))
    return out_keys, out_rows, out_valid, dropped


def shuffle_global(keys, rows, valid, num_shards: int, capacity: int):
    """All-to-all: per-source [s, n] rows -> per-destination inboxes.

    keys/valid : [s, n]; rows: [s, n, ...] array or pytree of such.
    Returns ``(keys [s, s*cap], rows [s, s*cap, ...], valid [s, s*cap],
    dropped [s])`` — destination-major; ``dropped[i]`` is source shard i's
    overflow count.  ``capacity`` bounds each (src, dest) lane; capacity =
    n can never drop.  The src<->dest transpose is the all-to-all (one
    ``lax.all_to_all`` under shard_map on a real mesh).
    """
    route = jax.vmap(
        lambda k, r, v: route_local(k, r, v, num_shards, capacity))
    lk, lr, lv, dropped = route(keys, rows, valid)    # [src, dest, cap, ...]

    def all_to_all(x):                                # -> [dest, src*cap, ...]
        x = jnp.swapaxes(x, 0, 1)
        return x.reshape((num_shards, num_shards * capacity) + x.shape[3:])

    return (all_to_all(lk), jax.tree.map(all_to_all, lr), all_to_all(lv),
            dropped)
