"""DistributedTable — the hash-partitioned Indexed DataFrame (paper §III-C/D).

A dtable stacks per-shard ``IndexedTable``s leaf-wise into ONE pytree whose
every array leaf carries a leading ``[num_shards]`` axis — segments AND the
stored Snapshot.  That buys two things the paper's distributed design needs:

* **The single-partition code IS the distributed code.**  Every query
  vmaps the unchanged ``IndexedTable`` methods over the shard axis; the
  fused lookup consumes each shard's Snapshot leaves directly (zero
  in-graph view rebuilds).  On a real mesh the same functions run under
  ``shard_map`` with the leading axis sharded over devices; CPU CI vmaps.
* **Jitted distributed queries take the dtable as a pytree argument** —
  e.g. ``jax.jit(lambda dt, q: indexed_join_bcast(dt, {"k": q}, "k", 16))``
  compiles once and stays cached across failure/rebuild cycles (leaf
  shapes are deterministic) and across structurally equal appends.

Construction routes rows to their owning shard (``partition_hash``) on the
host, pads every shard to a common capacity with ``valid=False`` lanes, and
builds all shards in one vmapped ``make_segment_arrays`` call (the
overflow-doubling retry stays a host loop, doubling until *every* shard
fits — bucket counts must agree across shards for the stacked pytree).

MVCC (paper §III-D/E): ``append_distributed`` is the functional append.
Shard planes are **capacity-reserved arenas** (DESIGN.md §4): within
reserved capacity the delta lands through the same fused in-place ingest
as the single-table path, axis-mapped per shard — zero pytree shape
change, so jitted distributed queries stay compile-cached across appends
under BOTH backends (vmap and shard_map).  Versions (global and
per-shard) are data leaves for the same reason.  Capacity exhaustion on
ANY shard promotes every shard to the next class together (the stacked
pytree needs uniform shapes), and ``compact_distributed`` bounds segment
fan-out.
"""

from __future__ import annotations

import dataclasses
import functools
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hashindex as hix
from repro.core import hashing
from repro.core import joins
from repro.core import planner as planner_mod
from repro.core import snapshot as snap_mod
from repro.core import table as table_mod
from repro.core.hashindex import EMPTY_KEY
from repro.core.pointers import NULL_PTR, PTR_DTYPE
from repro.core.schema import Schema
from repro.core.table import (IndexedTable, capacity_class,
                              make_segment_arrays, pad_to_batches)
from repro.dist import mesh, shuffle


@partial(jax.tree_util.register_dataclass,
         data_fields=["table", "version", "replica"],
         meta_fields=["num_shards"])
@dataclasses.dataclass(frozen=True)
class DistributedTable:
    """Shard-stacked Indexed DataFrame: one pytree, leading shard axis.

    ``version`` is a scalar int32 *data leaf* (DESIGN.md §4): arena
    appends bump it on-device, so successive dtable versions stay
    structurally equal and jitted distributed queries keep their compile
    cache across appends.

    ``replica`` is the optional hot-key mirror (``HotReplica``,
    DESIGN.md §15): hot rows replicated to every shard so skewed point
    queries answer locally instead of concentrating the routed exchange
    on one owner.  Its leaves carry NO shard axis — the mirror is
    identical everywhere by construction, and the hybrid dispatch reads
    it outside the axis-mapped region.  Appends carry it through
    unchanged; its stored fetch version then trails ``version``, which
    the hybrid dispatch treats as stale (pure routing) until
    ``refresh_replica`` re-mirrors — MVCC consistency by version gating,
    never by mutation."""

    table: IndexedTable   # every array leaf is [num_shards, ...]
    version: jax.Array    # global MVCC version (paper §III-D), scalar int32
    num_shards: int
    replica: object = None  # HotReplica | None — hot-key mirror (§15)

    @property
    def schema(self) -> Schema:
        return self.table.schema

    @property
    def rows_per_batch(self) -> int:
        return self.table.rows_per_batch

    @property
    def layout(self) -> str:
        return self.table.layout

    @property
    def slots(self) -> int:
        return self.table.slots

    def num_rows(self):
        """Total valid rows across all shards."""
        return self.table.num_rows()

    def index_nbytes(self) -> int:
        return self.table.index_nbytes()

    def data_nbytes(self) -> int:
        return self.table.data_nbytes()


# ---------------------------------------------------------------------------
# Host-side routing (ingest path: exact, no capacity bound)
# ---------------------------------------------------------------------------

def _route_host(cols, schema: Schema, num_shards: int, rows_per_batch: int,
                valid=None):
    """Partition columns by key hash into [num_shards, cap] padded arrays.

    The ingest path routes on the host (numpy) so it is exact — capacity is
    *derived* from the worst shard's row count, not guessed; query-time
    probe routing is the vectorized ``dist.shuffle`` instead.
    """
    keys = np.asarray(cols[schema.key]).astype(np.int64)
    n = keys.shape[0]
    v = (np.ones(n, bool) if valid is None
         else np.asarray(valid, bool).copy())
    # Host mirror of the device hash (bit-identical by test): rows land on
    # exactly the shard that query-time routing will probe.
    dest = hashing.partition_hash_host(keys, num_shards)
    counts = np.bincount(dest[v], minlength=num_shards)
    cap = pad_to_batches(max(int(counts.max()), 1), rows_per_batch)
    out = {c.name: np.zeros((num_shards, cap), np.dtype(c.dtype))
           for c in schema.columns}
    vout = np.zeros((num_shards, cap), bool)
    for d in range(num_shards):
        m = v & (dest == d)
        k = int(m.sum())
        for c in schema.columns:
            out[c.name][d, :k] = np.asarray(cols[c.name])[m]
        vout[d, :k] = True
    return ({name: jnp.asarray(a) for name, a in out.items()},
            jnp.asarray(vout), cap)


def _build_stacked_segment(shard_cols, shard_valid, heads, schema: Schema, *,
                           row_base: int, rows_per_batch: int, layout: str,
                           slots: int, rt: mesh.Runtime | None = None,
                           max_retries: int = 6):
    """One axis-mapped segment build across shards, retrying until no
    shard's bucket array overflows (all shards share one bucket count —
    the stacked pytree needs uniform shapes)."""
    cap = int(shard_valid.shape[1])
    nb = hix.suggest_num_buckets(cap, slots)
    for _ in range(max_retries):
        seg, overflow = mesh.axis_map(
            lambda c, v, h, _nb=nb: make_segment_arrays(
                c, v, h, schema, row_base=row_base,
                rows_per_batch=rows_per_batch, layout=layout,
                num_buckets=_nb, slots=slots), rt)(shard_cols, shard_valid,
                                                   heads)
        if int(jnp.max(overflow)) == 0:
            return seg
        nb *= 2
    raise RuntimeError("distributed segment build kept overflowing")


def create_distributed(cols: dict, schema: Schema, num_shards: int, *,
                       rows_per_batch: int = 4096, layout: str = "row",
                       slots: int = hix.DEFAULT_SLOTS, valid=None,
                       reserve: int | None = None,
                       track_hot: int | None = None, hot_mode: str = "topk",
                       rt: mesh.Runtime | None = None) -> DistributedTable:
    """Paper Listing 1 ``createIndex`` at cluster scope: hash-partition the
    dataframe, then build every shard's index in one axis-mapped pass
    (vmap lanes or shard_map devices, per ``rt`` — dist/mesh.py).

    Shard snapshots are built **with flat data**: distributed queries take
    the dtable as a jit argument, so everything the fused pipeline needs
    (probe planes, prev, row data) must live in the stored pytree.

    Every shard's planes are reserved to one common capacity class
    (DESIGN.md §4) — derived from the worst shard's row count, or from
    ``reserve`` (per-shard minimum rows; ``0`` = no over-allocation, the
    pre-arena layout) — so appends within the class run the in-place
    ingest with zero pytree shape change on every shard at once.
    """
    rt = mesh.resolve(rt).check(num_shards)
    sc, sv, cap = _route_host(cols, schema, num_shards, rows_per_batch,
                              valid)
    reserved = (capacity_class(cap, rows_per_batch) if reserve is None
                else pad_to_batches(max(cap, int(reserve), 1),
                                    rows_per_batch))
    pad = reserved - cap
    if pad:
        sc = {k: jnp.pad(v, ((0, 0), (0, pad))) for k, v in sc.items()}
        sv = jnp.pad(sv, ((0, 0), (0, pad)))
    heads = jnp.full((num_shards, reserved), NULL_PTR, PTR_DTYPE)
    seg = _build_stacked_segment(sc, sv, heads, schema, row_base=0,
                                 rows_per_batch=rows_per_batch,
                                 layout=layout, slots=slots, rt=rt)
    snap = mesh.axis_map(lambda s: snap_mod.snapshot_from_segments(
        (s,), layout, schema=schema, with_data=True), rt)(seg)
    # track_hot attaches an EMPTY per-shard tracker (created rows are not
    # back-counted — see table.with_hot: replay-deterministic)
    hot = (None if track_hot is None
           else table_mod.empty_tracker(track_hot, mode=hot_mode,
                                        num_shards=num_shards))
    table = IndexedTable(segments=(seg,), snapshot=snap, schema=schema,
                         rows_per_batch=rows_per_batch, layout=layout,
                         version=jnp.zeros((num_shards,), jnp.int32),
                         slots=slots, hot=hot)
    return DistributedTable(table=table, num_shards=num_shards,
                            version=jnp.asarray(0, jnp.int32))


@functools.lru_cache(maxsize=None)
def _dist_ingest_fn(rt: mesh.Runtime, donate: bool, schema: Schema,
                    layout: str, rb: int, bucket_counts: tuple, slots: int):
    """Jitted, axis-mapped arena ingest for one runtime + table structure
    (cached so repeated appends hit one compile-cache entry).  Works over
    the DEDUPLICATED tail state — required for the donated variant (XLA
    rejects the same buffer donated twice) and shared by the non-donated
    one for a single compile path."""

    def per_shard(state, parent_blocks, cols, valid):
        return table_mod._ingest_arrays(
            state, parent_blocks, cols, valid, schema=schema, layout=layout,
            rb=rb, bucket_counts=bucket_counts, slots=slots)

    mapped = mesh.axis_map(per_shard, rt)
    return jax.jit(mapped, donate_argnums=(0,) if donate else ())


def _dist_arena_ingest(dt: DistributedTable, sc, sv,
                       rt: mesh.Runtime, donate: bool):
    """Axis-mapped arena ingest over the stacked table; returns
    ``(child_table, overflow [s])``."""
    t = dt.table
    fn = _dist_ingest_fn(rt, donate, t.schema, t.layout,
                         t.segments[-1].row_base,
                         t.snapshot.bucket_counts, t.slots)
    out, ovf = fn(table_mod._dedup_state(t), t.snapshot.blocks[:-1], sc, sv)
    return table_mod._reassemble(t, out), ovf


def append_distributed(dt: DistributedTable, cols: dict, valid=None,
                       rt: mesh.Runtime | None = None, *,
                       donate: bool = False,
                       compact_threshold: int | None = None
                       ) -> DistributedTable:
    """Functional distributed append -> new version (paper §III-D MVCC).

    Routes the delta to owning shards, then lands it through the fused
    arena ingest (DESIGN.md §4), axis-mapped per shard: each shard probes
    its parent for head links, writes its bucket/chain planes, and bumps
    its ``fill`` — zero pytree shape change, so jitted distributed
    queries stay compile-cached across appends under both backends.  The
    parent dtable is untouched unless ``donate=True`` (in-place buffer
    aliasing; the parent becomes unusable).

    If ANY shard would exceed its reserved capacity (or overflow its
    buckets), every shard promotes together to the next capacity class —
    one recompile per class — and past ``compact_threshold`` segments the
    dtable is compacted (``compact_distributed``) to bound probe fan-out.
    """
    rt = mesh.resolve(rt).check(dt.num_shards)
    schema, rpb = dt.schema, dt.rows_per_batch
    sc, sv, cap = _route_host(cols, schema, dt.num_shards, rpb, valid)
    # per-shard fit: routed rows are left-packed, so counts are sv sums
    # host syncs via jax.device_get: the benchmarks' SyncCounter funnel
    counts = np.asarray(jax.device_get(sv)).sum(axis=1)
    tail = dt.table.segments[-1]
    spare = (tail.row_base + tail.capacity
             - np.asarray(jax.device_get(dt.table.snapshot.fill)))
    fits = bool((counts <= spare).all())

    if fits and donate:
        keys = jnp.where(sv, jnp.asarray(sc[schema.key], jnp.int64),
                         EMPTY_KEY)
        ovf = mesh.axis_map(table_mod._arena_fits, rt)(
            tail.index.bucket_keys, keys, sv)
        if int(jax.device_get(jnp.max(ovf))) == 0:
            child, _ = _dist_arena_ingest(dt, sc, sv, rt, True)
            return DistributedTable(table=child, num_shards=dt.num_shards,
                                    version=dt.version + 1,
                                    replica=dt.replica)
    elif fits:
        child, ovf = _dist_arena_ingest(dt, sc, sv, rt, False)
        if int(jax.device_get(jnp.max(ovf))) == 0:
            return DistributedTable(table=child, num_shards=dt.num_shards,
                                    version=dt.version + 1,
                                    replica=dt.replica)

    # promotion: seal every shard's tail, open a next-class arena on all
    # shards together (uniform shapes across the stacked pytree)
    new_cap = max(2 * tail.capacity,
                  capacity_class(max(int(counts.max()), 1), rpb))
    pad = new_cap - cap
    if pad:
        sc = {k: jnp.pad(v, ((0, 0), (0, pad))) for k, v in sc.items()}
        sv = jnp.pad(sv, ((0, 0), (0, pad)))
    keys = jnp.where(sv, jnp.asarray(sc[schema.key], jnp.int64), EMPTY_KEY)
    heads = mesh.axis_map(lambda t, k: t.probe_latest_ref(k), rt)(dt.table,
                                                                  keys)
    seg = _build_stacked_segment(sc, sv, heads, schema,
                                 row_base=dt.table.capacity,
                                 rows_per_batch=rpb, layout=dt.layout,
                                 slots=dt.slots, rt=rt)
    snap = mesh.axis_map(lambda sn, sg: snap_mod.extend_snapshot(
        sn, sg, schema=schema), rt)(dt.table.snapshot, seg)
    table = dataclasses.replace(dt.table,
                                segments=dt.table.segments + (seg,),
                                snapshot=snap,
                                version=dt.table.version + 1)
    child = DistributedTable(table=table, num_shards=dt.num_shards,
                             version=dt.version + 1, replica=dt.replica)
    threshold = (table_mod.DEFAULT_COMPACT_THRESHOLD
                 if compact_threshold is None else compact_threshold)
    if child.table.num_segments > threshold:
        child = compact_distributed(child, rt=rt, _bump_version=False)
    return child


# ---------------------------------------------------------------------------
# Device-resident append queue, per shard (DESIGN.md §13)
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _dist_enqueue_fn(rt: mesh.Runtime, donate: bool):
    """Jitted, axis-mapped ring enqueue (one compile-cache entry per
    runtime): every shard scatters its routed slice of the delta into its
    own ring's next lane — possibly zero valid rows, keeping per-shard
    ``count`` scalars in lockstep."""
    mapped = mesh.axis_map(table_mod._enqueue_core, rt)
    return jax.jit(mapped, donate_argnums=(0,) if donate else ())


@functools.lru_cache(maxsize=None)
def _dist_flush_fn(rt: mesh.Runtime, donate: bool, schema: Schema,
                   layout: str, rb: int, bucket_counts: tuple, slots: int,
                   cap: int):
    """Jitted, axis-mapped fused flush over the deduplicated tail state
    (one compile-cache entry per runtime + table structure, like
    ``_dist_ingest_fn``).  ``axis=rt.axis`` makes the ok gate a psum:
    every shard lands its ring or holds it *together*, so the stacked
    versions/fills stay uniform (same all-or-nothing contract as
    ``append_distributed`` promotion)."""

    def per_shard(state, parent_blocks, q):
        return table_mod._flush_core(
            state, parent_blocks, q, schema=schema, layout=layout, rb=rb,
            bucket_counts=bucket_counts, slots=slots, cap=cap,
            axis=rt.axis)

    return jax.jit(mesh.axis_map(per_shard, rt),
                   donate_argnums=(0, 2) if donate else ())


def enqueue_distributed(dt: DistributedTable, queue, cols: dict, valid=None,
                        *, rt: mesh.Runtime | None = None,
                        donate: bool = True):
    """Stage one delta across every shard's ring — NO table change, and
    the only host work is the numpy route (no device round-trip).

    The delta is hash-partitioned exactly like ``append_distributed``
    (host mirror of the device hash), each shard's slice landing in ITS
    ring's next lane, so a later ``flush_queue_distributed`` ingests the
    same per-shard rows in the same order as a direct append — bit
    identical by the parity tests.  Raises ``QueueOverflow`` when the
    rings are full or one shard's slice exceeds a lane.
    """
    rt = mesh.resolve(rt).check(dt.num_shards)
    lanes_used, rows = table_mod.queue_pending(queue)
    if lanes_used >= queue.lanes:
        raise table_mod.QueueOverflow(
            f"append queue is full ({queue.lanes} lanes pending); "
            f"flush first")
    n = int(np.shape(cols[dt.schema.key])[0])
    nv = n if valid is None else int(np.asarray(valid, bool).sum())
    sc, sv, cap = _route_host(cols, dt.schema, dt.num_shards, 1, valid)
    if cap > queue.lane_rows:
        raise table_mod.QueueOverflow(
            f"one shard owns {cap} delta rows but queue lanes hold "
            f"{queue.lane_rows}; append() it directly or size the ring "
            f"with with_queue(lane_rows=...)")
    pad = queue.lane_rows - cap
    if pad:
        sc = {k: jnp.pad(v, ((0, 0), (0, pad))) for k, v in sc.items()}
        sv = jnp.pad(sv, ((0, 0), (0, pad)))
    out = _dist_enqueue_fn(rt, donate)(queue, sc, sv)
    return table_mod._set_queue_mirror(out, lanes_used + 1, rows + nv)


def drain_queue_distributed(queue):
    """Ring contents -> host ``(cols, valid=None)`` in enqueue order.

    Lane-major across shards — (lane, shard, row) — so re-routing the
    drained delta packs every shard's rows back in exactly its ring
    order: the overflow -> promote path stays bit-identical to having
    flushed in place.
    """
    cols, valid, count = jax.device_get(
        (queue.cols, queue.valid, queue.count))
    c = int(np.asarray(count).reshape(-1)[0])
    v = np.asarray(valid) & (np.arange(queue.lanes)[None, :, None] < c)
    flat_v = np.transpose(v, (1, 0, 2)).reshape(-1)
    return ({k: np.transpose(np.asarray(a), (1, 0, 2)).reshape(-1)[flat_v]
             for k, a in cols.items()}, None)


def flush_queue_distributed(dt: DistributedTable, queue, *,
                            rt: mesh.Runtime | None = None,
                            donate: bool = False,
                            compact_threshold: int | None = None):
    """Land every shard's ring in its arena: ONE fused axis-mapped jit +
    ONE host sync (the psum'd ``ok`` flag, identical on all shards).
    Returns ``(dtable', ring', promoted)`` — same overflow -> promote and
    ``donate`` contracts as the local ``flush_queue``: a held flush
    drains the rings host-side and lands through ``append_distributed``
    (which seals and promotes every shard together).  Exactly ONE global
    version bump either way; an empty ring is a no-op.
    """
    rt = mesh.resolve(rt).check(dt.num_shards)
    lanes_used, _ = table_mod.queue_pending(queue)
    if lanes_used == 0:
        return dt, queue, False
    t = dt.table
    tail = t.segments[-1]
    fn = _dist_flush_fn(rt, donate, t.schema, t.layout, tail.row_base,
                        t.snapshot.bucket_counts, t.slots,
                        tail.row_base + tail.capacity)
    out, ring, ok = fn(table_mod._dedup_state(t), t.snapshot.blocks[:-1],
                       queue)
    child_t = table_mod._reassemble(t, out)
    if bool(np.asarray(jax.device_get(ok)).reshape(-1)[0]):  # THE one sync
        child = DistributedTable(table=child_t, num_shards=dt.num_shards,
                                 version=dt.version + 1, replica=dt.replica)
        return child, table_mod._set_queue_mirror(ring, 0, 0), False
    # held: child_t is content-identical to the parent; under donation
    # the parent buffers are consumed, so promote off the reassembled one
    held = DistributedTable(table=child_t, num_shards=dt.num_shards,
                            version=dt.version, replica=dt.replica)
    cols, valid = drain_queue_distributed(ring)
    child = append_distributed(held, cols, valid, rt=rt, donate=donate,
                               compact_threshold=compact_threshold)
    return child, table_mod.reset_queue(ring), True


def collect_cols(dt: DistributedTable,
                 rt: mesh.Runtime | None = None) -> dict:
    """All valid rows as host columns (shard-major, append order within —
    per-key MVCC chains keep their newest-first order because a key's rows
    never span shards)."""
    out = {}
    mask = None
    for name in dt.schema.names:
        vals, valid = mesh.axis_map(
            lambda t, _n=name: t.scan_column(_n), rt)(dt.table)
        if mask is None:
            mask = np.asarray(valid).reshape(-1)
        out[name] = np.asarray(vals).reshape(-1)[mask]
    return out


def compact_distributed(dt: DistributedTable, *,
                        rt: mesh.Runtime | None = None,
                        rt_out: "mesh.Runtime | None" = None,
                        reserve: int | None = None,
                        _bump_version: bool = True) -> DistributedTable:
    """Merge every shard's segments into one fresh arena (DESIGN.md §4).

    Collection is order-preserving per shard and routing is deterministic
    (``partition_hash``), so each row lands back on its own shard and
    per-key chains stay newest-first — lookups are bit-identical before
    and after.  The result is reserved at the capacity class of the live
    row count, so post-compaction appends re-enter the in-place path.
    """
    cols = collect_cols(dt, rt=rt)
    fresh = create_distributed(
        cols, dt.schema, dt.num_shards, rows_per_batch=dt.rows_per_batch,
        layout=dt.layout, slots=dt.slots, reserve=reserve,
        rt=rt_out if rt_out is not None else rt)
    old_tv = int(np.asarray(dt.table.version).ravel()[0])
    bump = 1 if _bump_version else 0
    # compaction rewrites storage, not history: tracker counts and the
    # (version-gated) mirror carry through unchanged (DESIGN.md §15)
    table = dataclasses.replace(
        fresh.table, version=jnp.full((dt.num_shards,), old_tv + bump,
                                      jnp.int32), hot=dt.table.hot)
    return DistributedTable(table=table, num_shards=dt.num_shards,
                            version=dt.version + bump, replica=dt.replica)


# ---------------------------------------------------------------------------
# Distributed queries (axis-mapped single-partition ops + collective select)
# ---------------------------------------------------------------------------

def lookup(dt: DistributedTable, keys, *, max_matches: int, names=None,
           rt: mesh.Runtime | None = None):
    """Distributed point lookup -> (cols [Q, M], valid [Q, M], owner [Q]).

    The broadcast path: every shard answers the full query batch through
    its own Snapshot (one axis-mapped per-shard function, identical under
    both backends); the owner shard's answer is then selected per query by
    indexing the stacked answers at ``[owner, iq]`` OUTSIDE the mapped
    region.  Under vmap that is one local gather (bit-exact always);
    under shard_map the stacked output is a device-sharded global array
    and GSPMD lowers the cross-shard gather to collectives.  Rows for a
    key live only on its owner, so the select is exact — with ONE
    platform caveat: XLA lowers cross-device float combines (psum,
    sharded gather, all_gather alike) as zero-padded sums, so a stored
    float ``-0.0`` can come back ``+0.0`` from the shard_map broadcast
    path (numerically equal; valid masks unaffected — DESIGN.md §10).
    ``lookup_routed`` moves answers as word-packed ints over
    ``all_to_all`` and IS bit-exact for every payload under both
    backends — and is the better path at large Q anyway; compute here is
    s× redundant (``choose_lookup`` picks).
    """
    rt = mesh.resolve(rt).check(dt.num_shards)
    joins.check_max_matches(max_matches)
    q = joins.as_int64_keys(keys)
    owner = hashing.partition_hash(q, dt.num_shards)

    def shard(t, qq):
        rids, _ = t.lookup(qq, max_matches)
        valid = rids != NULL_PTR
        # NULL rids decode to exact zeros — miss lanes carry no garbage
        cols = t.gather_rows(jnp.where(valid, rids, NULL_PTR), names=names)
        return cols, valid

    cols_s, valid_s = mesh.axis_map(shard, rt, in_axes=(0, None))(
        dt.table, q)
    iq = jnp.arange(q.shape[0])
    return ({k: v[owner, iq] for k, v in cols_s.items()},
            valid_s[owner, iq], owner)


def lookup_routed(dt: DistributedTable, keys, valid=None, *,
                  max_matches: int, capacity: int | None = None, names=None,
                  rt: mesh.Runtime | None = None):
    """Shuffle-routed point lookup: probe each query ONCE, on its owner.

    keys arrive sharded [s, n] (each shard's local query batch).  Queries
    ride the capacity-bounded exchange to their owning shard
    (``route_local`` + ``lax.all_to_all``, exactly like
    ``indexed_join_shuffle``'s probe side), the owner probes its Snapshot
    over the inbox, and the answers ride the inverse all-to-all home —
    chunk ``d`` of a source's outbox comes back as chunk ``d`` of its
    answer inbox, so the return trip needs no extra addressing beyond the
    locally-kept lane ids.

    Returns ``(cols [s, n, M], valid [s, n, M], answered [s, n],
    dropped [s])``.  ``answered[i, j]`` is False when query (i, j) was
    invalid on input OR overflowed its exchange lane; overflow is also
    counted in ``dropped[i]`` — the retry contract (resubmit with a
    bigger ``capacity``; the default ``n`` can never drop).  A dropped
    query is *reported*, never a silent miss; inbox padding probes the
    EMPTY sentinel, never key 0.

    Cost: each shard probes s*capacity inbox lanes instead of the full
    broadcast batch — with capacity ~ 2n/s that is ~2Q total probes
    versus broadcast's sQ (the s× redundancy the ROADMAP flags).
    """
    rt = mesh.resolve(rt).check(dt.num_shards)
    joins.check_max_matches(max_matches)
    s = dt.num_shards
    q = joins.as_int64_keys(keys)
    assert q.ndim == 2 and q.shape[0] == s, (q.shape, s)
    n = q.shape[1]
    cap = capacity if capacity is not None else n
    qv = (jnp.ones((s, n), bool) if valid is None
          else jnp.asarray(valid, bool))

    def shard(t, k, v):
        lane = jnp.arange(n, dtype=jnp.int32)
        ok, op, ov, dropped = shuffle.route_local(k, {"lane": lane}, v, s,
                                                  cap)
        # forward exchange, ONE collective: validity rides the key plane —
        # empty outbox slots carry the EMPTY sentinel, which the probe
        # side already treats as can-never-match (EMPTY_KEY is reserved;
        # a user query for it is a guaranteed miss on any path).  The
        # outbox lane ids stay local for the answer scatter.
        in_k = shuffle.all_to_all_axis(jnp.where(ov, ok, EMPTY_KEY),
                                       rt.axis)               # [s*cap]
        in_v = in_k != EMPTY_KEY
        rids, _ = t.lookup(in_k, max_matches)
        hit = (rids != NULL_PTR) & in_v[:, None]
        cols = t.gather_rows(jnp.where(hit, rids, NULL_PTR), names=names)
        # return exchange, ONE collective: the all-to-all is its own
        # inverse here — chunk d of the word-packed answer matrix is this
        # shard's reply to source d, arriving back in outbox lane order.
        # The words stay packed through the per-query scatter (scatter
        # cost on CPU is per-INDEX, so one [s*cap -> n] row scatter beats
        # one per answer leaf) and unpack at per-query size; unanswered
        # lanes keep all-zero words, which unpack to exactly the
        # zeros/False fill the contract promises.
        packed, spec = shuffle.pack_words((cols, hit))
        home = shuffle.all_to_all_axis(
            packed.reshape(s, cap, packed.shape[1]), rt.axis)
        slot = jnp.where(ov, op["lane"], jnp.int32(n)).reshape(-1)
        per_query = (jnp.zeros((n, home.shape[1]), home.dtype)
                     .at[slot].set(home, mode="drop"))
        out_cols, out_valid = shuffle.unpack_words(per_query, spec)
        answered = (jnp.zeros((n,), bool)
                    .at[slot].set(ov.reshape(-1), mode="drop"))
        return out_cols, out_valid, answered, dropped

    return mesh.axis_map(shard, rt)(dt.table, q, qv)


def lookup_routed_report(dt: DistributedTable, keys, *, max_matches: int,
                         capacity: int | None = None, names=None,
                         rt: mesh.Runtime | None = None):
    """Routed point lookup, flat contract, WITH the drop-retry report:
    ``[Q]`` keys in, ``(cols [Q, M], valid [Q, M], answered [Q],
    dropped [s])`` out.

    Splits the batch into ``num_shards`` equal source lanes (padding the
    tail with invalid queries) and rides ``lookup_routed``'s two
    all-to-alls.  ``capacity`` bounds each (src, dest) exchange lane —
    ``None`` means the lane count, which can never drop; anything smaller
    surfaces overflow as ``answered=False`` per query plus per-shard
    ``dropped`` counts, never a silent miss.  That is the retry contract
    a caller (or ``dist.resilience.RecoveryManager``, which automates it
    with doubled capacity under a backoff budget) resubmits against.
    """
    rt = mesh.resolve(rt).check(dt.num_shards)
    joins.check_max_matches(max_matches)
    q = joins.as_int64_keys(keys)
    assert q.ndim == 1, q.shape
    s = dt.num_shards
    qn = q.shape[0]
    n = max(1, -(-qn // s))
    qpad = jnp.pad(q, (0, s * n - qn))
    # serving pads batches to a bucket with the reserved EMPTY_KEY
    # sentinel (serving/query_engine.py PAD_KEY): mask those lanes out
    # of the exchange entirely, so pad lanes never consume routed
    # capacity or count as drops — they come back cols=0/valid=False
    # exactly like the tail padding
    qvalid = (jnp.arange(s * n) < qn) & (qpad != EMPTY_KEY)
    cols, valid, answered, dropped = lookup_routed(
        dt, qpad.reshape(s, n), qvalid.reshape(s, n),
        max_matches=max_matches, capacity=capacity, names=names, rt=rt)
    flat = {k: v.reshape((s * n,) + v.shape[2:])[:qn]
            for k, v in cols.items()}
    return (flat, valid.reshape(s * n, max_matches)[:qn],
            answered.reshape(s * n)[:qn], dropped)


def lookup_routed_flat(dt: DistributedTable, keys, *, max_matches: int,
                       names=None, rt: mesh.Runtime | None = None):
    """Routed point lookup with the FLAT contract: ``[Q]`` keys in,
    ``(cols [Q, M], valid [Q, M])`` out — the adapter the facade and the
    planner execute "RoutedLookup" through.

    Capacity is the per-shard lane count, so the exchange can never drop
    a query — the retry contract never fires on this path
    (``lookup_routed_report`` is the capacity-bounded form that surfaces
    it).
    """
    cols, valid, _, _ = lookup_routed_report(
        dt, keys, max_matches=max_matches, capacity=None, names=names,
        rt=rt)
    return cols, valid


# ---------------------------------------------------------------------------
# Hot-key replication + hybrid dispatch (skew resilience, DESIGN.md §15)
# ---------------------------------------------------------------------------

DEFAULT_REPLICA_SLOTS = 128
DEFAULT_REPLICA_MATCHES = 8

# Trace counter for the CI gate (scripts/trace_gate.py gate_skew): the
# refresh site must trace ONCE per (runtime, table structure) — hot-set
# churn across appends reuses the cached entry.
REPLICA_TRACES = {"refresh": 0}


@partial(jax.tree_util.register_dataclass,
         data_fields=["keys", "cols", "valid", "version"],
         meta_fields=["max_matches"])
@dataclasses.dataclass(frozen=True)
class HotReplica:
    """Fixed-capacity mirror of the hottest keys' rows (DESIGN.md §15).

    Conceptually each shard holds an identical copy beside its main
    arena; since the copies are identical by construction, the pytree
    stores ONE un-stacked instance (no ``[num_shards]`` axis) that the
    hybrid dispatch reads outside the axis-mapped region — under
    shard_map that is a replicated operand, exactly the broadcast the
    design calls for.  All mutable fields are data leaves (§4), so the
    hot set can churn across refreshes with zero retraces.

    MVCC rule: ``version`` records the dtable version the rows were
    fetched at.  The mirror is consulted ONLY while it equals the live
    version — any append/flush/compact bump makes it stale and the
    hybrid degrades to pure routing (bit-identical answers, no staleness
    window) until ``refresh_replica`` re-mirrors.  Rows are fetched
    through ``lookup_routed_flat`` (word-packed ints), so mirrored
    answers are bit-exact under both backends — the broadcast ``lookup``
    path's float ``-0.0`` caveat never applies.

    A query with ``max_matches`` ≤ ``max_matches`` stored here is fully
    answerable from the mirror: matches are newest-first, so the stored
    prefix IS the routed answer prefix, whatever the chain length.
    """

    keys: jax.Array     # [H] int64 hot keys — EMPTY_KEY = vacant slot
    cols: dict          # {name: [H, M] typed} — full schema, newest-first
    valid: jax.Array    # [H, M] bool match mask
    version: jax.Array  # scalar int32 — dtable version at fetch time
    max_matches: int    # M — the deepest chain prefix the mirror answers


def attach_replica(dt: DistributedTable, *,
                   capacity: int = DEFAULT_REPLICA_SLOTS,
                   max_matches: int = DEFAULT_REPLICA_MATCHES
                   ) -> DistributedTable:
    """Attach an empty, STALE mirror (version −1: never consulted until
    the first ``refresh_replica``).  One treedef change, done before
    entering jitted loops — like attaching a queue or tracker."""
    if dt.table.hot is None:
        raise ValueError(
            "attach_replica needs a hot-key tracker on the table "
            "(create with track_hot=... or frame.with_hot_tracker())")
    joins.check_max_matches(max_matches)
    sch = dt.schema
    cols = {c.name: jnp.zeros((capacity, max_matches), c.jnp_dtype)
            for c in sch.columns}
    rep = HotReplica(keys=jnp.full((capacity,), EMPTY_KEY, jnp.int64),
                     cols=cols,
                     valid=jnp.zeros((capacity, max_matches), bool),
                     version=jnp.asarray(-1, jnp.int32),
                     max_matches=max_matches)
    return dataclasses.replace(dt, replica=rep)


@functools.lru_cache(maxsize=None)
def _refresh_fn(rt: mesh.Runtime):
    """Jitted replica refresh for one runtime: merge the per-shard
    trackers into the global top-H (keys are disjoint across shards —
    routing partitions by key — so the merge is one flat sort), fetch
    those keys' newest rows through the bit-exact routed path, and stamp
    the live version.  Zero host syncs; returns only the new mirror, so
    the table's leaves never round-trip through the jit."""

    def core(dt):
        REPLICA_TRACES["refresh"] += 1
        rep = dt.replica
        hot = dt.table.hot
        h = rep.keys.shape[0]
        flat_k = hot.keys.reshape(-1)
        flat_c = hot.counts.reshape(-1)
        if flat_k.shape[0] < h:
            flat_k = jnp.pad(flat_k, (0, h - flat_k.shape[0]),
                             constant_values=EMPTY_KEY)
            flat_c = jnp.pad(flat_c, (0, h - flat_c.shape[0]))
        o = jnp.lexsort((flat_k, -flat_c))   # count desc, key asc: stable
        hot_k = jnp.where(flat_c[o[:h]] > 0, flat_k[o[:h]], EMPTY_KEY)
        cols, valid = lookup_routed_flat(dt, hot_k,
                                         max_matches=rep.max_matches,
                                         rt=rt)
        return dataclasses.replace(
            rep, keys=hot_k, cols=cols, valid=valid,
            version=jnp.asarray(dt.version, jnp.int32))

    return jax.jit(core)


def refresh_replica(dt: DistributedTable, *,
                    rt: mesh.Runtime | None = None) -> DistributedTable:
    """Re-mirror the current global top-H hot keys at the live version.

    ONE cached jit call (no host sync): tracker merge + routed fetch of
    H keys.  Callers decide cadence — the facade refreshes after every
    append/flush when a mirror is attached, keeping the hybrid hot; a
    skipped refresh is safe (stale mirror ⇒ pure routing).
    """
    rt = mesh.resolve(rt).check(dt.num_shards)
    if dt.replica is None:
        raise ValueError("refresh_replica: no replica attached "
                         "(attach_replica first)")
    if dt.table.hot is None:
        raise ValueError("refresh_replica: table has no hot-key tracker")
    return dataclasses.replace(dt, replica=_refresh_fn(rt)(dt))


def _replica_split(dt: DistributedTable, q):
    """In-graph hot/cold split: ``(eligible [Q], slot [Q])``.

    A query is hot when its key sits in the mirror AND the mirror is
    fresh (fetch version == live version).  EMPTY_KEY queries (serving
    pads, masked tails) are never hot — they stay guaranteed misses on
    the cold path, consuming no exchange capacity either way."""
    rep = dt.replica
    hit = q[:, None] == rep.keys[None, :]                      # [Q, H]
    fresh = jnp.asarray(rep.version) == jnp.asarray(dt.version)
    eligible = jnp.any(hit, axis=1) & (q != EMPTY_KEY) & fresh
    return eligible, jnp.argmax(hit, axis=1)


def lookup_hybrid_report(dt: DistributedTable, keys, *, max_matches: int,
                         capacity: int | None = None, names=None,
                         rt: mesh.Runtime | None = None):
    """Skew-resilient point lookup: hot keys answer locally from the
    mirror, the cold tail routes — same flat report contract as
    ``lookup_routed_report`` (``cols [Q, M], valid [Q, M], answered [Q],
    dropped [s]``), bit-identical answers to pure routing.

    The split is in-graph: hot lanes are masked to ``EMPTY_KEY`` before
    the exchange, so (by the routed path's pad contract) they never
    consume a (src, dest) capacity lane and never count as drops — the
    owner of a celebrity key sees only the cold tail.  Hot answers
    gather from the mirror and recombine in input order.  Statically
    falls back to pure routing when no mirror is attached or the query
    wants deeper chains than the mirror stores; dynamically degrades to
    pure routing per-batch while the mirror is stale (version gate).
    """
    rt = mesh.resolve(rt).check(dt.num_shards)
    joins.check_max_matches(max_matches)
    q = joins.as_int64_keys(keys)
    rep = dt.replica
    if rep is None or max_matches > rep.max_matches:
        return lookup_routed_report(dt, q, max_matches=max_matches,
                                    capacity=capacity, names=names, rt=rt)
    eligible, slot = _replica_split(dt, q)
    cold_q = jnp.where(eligible, EMPTY_KEY, q)
    cols_r, valid_r, answered_r, dropped = lookup_routed_report(
        dt, cold_q, max_matches=max_matches, capacity=capacity,
        names=names, rt=rt)
    nm = tuple(names) if names is not None else tuple(dt.schema.names)
    cols = {k: jnp.where(eligible[:, None],
                         rep.cols[k][slot, :max_matches], cols_r[k])
            for k in nm}
    valid = jnp.where(eligible[:, None],
                      rep.valid[slot, :max_matches], valid_r)
    return cols, valid, eligible | answered_r, dropped


def lookup_hybrid_flat(dt: DistributedTable, keys, *, max_matches: int,
                       names=None, rt: mesh.Runtime | None = None):
    """Hybrid point lookup with the FLAT contract (``[Q]`` keys →
    ``(cols [Q, M], valid [Q, M])``) — what the facade and planner
    execute "HybridLookup" through.  Cold capacity is the lane count
    (never drops), hot lanes never reach the exchange at all."""
    cols, valid, _, _ = lookup_hybrid_report(
        dt, keys, max_matches=max_matches, capacity=None, names=names,
        rt=rt)
    return cols, valid


def indexed_join_hybrid(dt: DistributedTable, probe_cols: dict,
                        probe_key: str, *, max_matches: int, names=None,
                        rt: mesh.Runtime | None = None):
    """Skew-resilient equi-join, flat local contract (same as
    ``indexed_join_routed``): hot probe keys join against the mirror
    locally, the cold tail rides the routed exchange — a power-law probe
    side no longer concentrates its exchange lanes on one owner."""
    q = joins.as_int64_keys(probe_cols[probe_key])
    build_cols, valid = lookup_hybrid_flat(dt, q, max_matches=max_matches,
                                           names=names, rt=rt)
    m = valid.shape[1]
    probe_b = {k: jnp.broadcast_to(jnp.asarray(v)[:, None],
                                   (q.shape[0], m))
               for k, v in probe_cols.items()}
    return build_cols, probe_b, valid


def reseed_tracker(hot, num_shards: int):
    """Host-side tracker re-seed for ``reshard``: route the surviving
    tracker entries to their NEW owning shards (``partition_hash_host`` —
    same bits as the device routing) and keep each new shard's top-T.

    Top-k counts carry through as exact lower bounds (entries were
    disjoint across the old shards, so the merge has no duplicate keys);
    sketch planes restart at zero — per-plane cell sums cannot be
    re-partitioned by key, so after a reshard the sketch re-estimates
    from subsequent ingest while the carried top-k entries keep the hot
    set warm."""
    top_k = hot.keys.shape[-1]
    k = np.asarray(jax.device_get(hot.keys)).reshape(-1)
    c = np.asarray(jax.device_get(hot.counts)).reshape(-1)
    live = k != np.int64(EMPTY_KEY)
    k, c = k[live], c[live]
    owner = hashing.partition_hash_host(k, num_shards)
    keys = np.full((num_shards, top_k), np.int64(EMPTY_KEY))
    counts = np.zeros((num_shards, top_k), np.int64)
    for s in range(num_shards):
        m = owner == s
        ks, cs = k[m], c[m]
        o = np.lexsort((ks, -cs))[:top_k]          # count desc, key asc
        keys[s, :o.size] = ks[o]
        counts[s, :o.size] = cs[o]
    sketch = (None if hot.sketch is None
              else jnp.zeros((num_shards,) + hot.sketch.shape[-2:],
                             jnp.int64))
    return dataclasses.replace(hot, keys=jnp.asarray(keys),
                               counts=jnp.asarray(counts), sketch=sketch)


def hot_fraction(dt: DistributedTable, keys) -> float:
    """Host-side diagnostic: fraction of CONCRETE query keys the mirror
    would answer locally (``explain()`` reports it; never called under a
    trace).  Uses a host mirror of the replica keys cached on the
    instance — one device_get per replica object, not per call."""
    rep = dt.replica
    if rep is None or isinstance(rep.keys, jax.core.Tracer):
        return 0.0
    hk = getattr(rep, "_host_keys", None)
    if hk is None:
        hk = np.asarray(jax.device_get(rep.keys))
        object.__setattr__(rep, "_host_keys", hk)
    q = np.asarray(keys).astype(np.int64).reshape(-1)
    if q.size == 0:
        return 0.0
    return float(np.isin(q, hk[hk != np.int64(EMPTY_KEY)]).mean())


def choose_lookup(dt, total_queries: int, *,
                  routed_threshold: int = 4096) -> str:
    """Back-compat shim: the bcast/routed cost rule now lives in the
    Planner (rules L2/L3, ``Planner.lookup_flavor``); this keeps the
    original string-returning helper for existing call sites."""
    planner = planner_mod.Planner(routed_threshold=routed_threshold)
    op, _ = planner.lookup_flavor(int(getattr(dt, "num_shards", 1)),
                                  total_queries)
    return op


def indexed_join_bcast(dt: DistributedTable, probe_cols: dict,
                       probe_key: str, max_matches: int, *, names=None,
                       rt: mesh.Runtime | None = None):
    """Broadcast equi-join: ship the (small) probe side to every shard.

    Returns (build_cols [Q, M], probe_cols broadcast [Q, M], valid [Q, M])
    — the same contract as ``core.joins.indexed_join``.
    """
    q = joins.as_int64_keys(probe_cols[probe_key])
    build_cols, valid, _ = lookup(dt, q, max_matches=max_matches,
                                  names=names, rt=rt)
    m = valid.shape[1]
    probe_b = {k: jnp.broadcast_to(jnp.asarray(v)[:, None],
                                   (q.shape[0], m))
               for k, v in probe_cols.items()}
    return build_cols, probe_b, valid


def indexed_join_shuffle(dt: DistributedTable, probe_cols: dict,
                         probe_key: str, probe_valid, max_matches: int, *,
                         capacity: int | None = None, names=None,
                         rt: mesh.Runtime | None = None):
    """Shuffle equi-join: the (large) probe side arrives sharded [s, n];
    probe rows ride the all-to-all to the shard owning their key
    (``shuffle.shuffle_global_axis``), then join locally — results stay
    sharded on their owner.

    Returns (build_cols [s, s*cap, M], probe_cols [s, s*cap, M],
    valid [s, s*cap, M], dropped [s]).  ``capacity`` bounds each
    (src, dest) exchange lane; the default ``n`` can never drop.
    """
    rt = mesh.resolve(rt).check(dt.num_shards)
    joins.check_max_matches(max_matches)
    s = dt.num_shards
    keys = joins.as_int64_keys(probe_cols[probe_key])
    assert keys.shape[0] == s, (keys.shape, s)
    cap = capacity if capacity is not None else keys.shape[1]
    payload = {k: jnp.asarray(v) for k, v in probe_cols.items()}

    def local(t, k, p, v):
        rk, rp, rv, dropped = shuffle.shuffle_global_axis(
            k, p, v, s, cap, rt.axis)
        rids, _ = t.lookup(jnp.where(rv, rk, EMPTY_KEY), max_matches)
        valid = (rids != NULL_PTR) & rv[:, None]
        cols = t.gather_rows(jnp.where(valid, rids, NULL_PTR), names=names)
        probe_b = {kk: jnp.broadcast_to(vv[..., None],
                                        vv.shape + (max_matches,))
                   for kk, vv in rp.items()}
        return cols, probe_b, valid, dropped

    return mesh.axis_map(local, rt)(dt.table, keys, payload,
                                    jnp.asarray(probe_valid, bool))


def indexed_join_routed(dt: DistributedTable, probe_cols: dict,
                        probe_key: str, *, max_matches: int, names=None,
                        rt: mesh.Runtime | None = None):
    """Shuffle-flavored equi-join with the FLAT local contract: probe keys
    ride the routed exchange to their owning shard (two all-to-alls, each
    key probed exactly once — the same data movement as
    ``indexed_join_shuffle``'s probe side), while the probe *payload*
    never leaves the caller: answers come home in input order and the
    probe columns broadcast locally.

    Returns (build_cols [Q, M], probe_cols broadcast [Q, M],
    valid [Q, M]) — the same contract as ``core.joins.indexed_join`` and
    ``indexed_join_bcast``, which is what lets the facade/planner swap
    flavors per call without changing callers.  ``indexed_join_shuffle``
    remains the owner-sharded-output form for pipelines that continue
    shard-local.
    """
    q = joins.as_int64_keys(probe_cols[probe_key])
    build_cols, valid = lookup_routed_flat(dt, q, max_matches=max_matches,
                                           names=names, rt=rt)
    m = valid.shape[1]
    probe_b = {k: jnp.broadcast_to(jnp.asarray(v)[:, None],
                                   (q.shape[0], m))
               for k, v in probe_cols.items()}
    return build_cols, probe_b, valid


def choose_join(dt, probe_rows: int, *,
                bcast_threshold: int = 1_000_000) -> str:
    """Back-compat shim: the bcast/shuffle cost rule now lives in the
    Planner (rules J2/J3, ``Planner.join_flavor``)."""
    planner = planner_mod.Planner(bcast_threshold=bcast_threshold)
    op, _ = planner.join_flavor(probe_rows)
    return op
