"""DistributedTable — the hash-partitioned Indexed DataFrame (paper §III-C/D).

A dtable stacks per-shard ``IndexedTable``s leaf-wise into ONE pytree whose
every array leaf carries a leading ``[num_shards]`` axis — segments AND the
stored Snapshot.  That buys two things the paper's distributed design needs:

* **The single-partition code IS the distributed code.**  Every query
  vmaps the unchanged ``IndexedTable`` methods over the shard axis; the
  fused lookup consumes each shard's Snapshot leaves directly (zero
  in-graph view rebuilds).  On a real mesh the same functions run under
  ``shard_map`` with the leading axis sharded over devices; CPU CI vmaps.
* **Jitted distributed queries take the dtable as a pytree argument** —
  e.g. ``jax.jit(lambda dt, q: indexed_join_bcast(dt, {"k": q}, "k", 16))``
  compiles once and stays cached across failure/rebuild cycles (leaf
  shapes are deterministic) and across structurally equal appends.

Construction routes rows to their owning shard (``partition_hash``) on the
host, pads every shard to a common capacity with ``valid=False`` lanes, and
builds all shards in one vmapped ``make_segment_arrays`` call (the
overflow-doubling retry stays a host loop, doubling until *every* shard
fits — bucket counts must agree across shards for the stacked pytree).

MVCC (paper §III-D/E): ``append_distributed`` is the functional append —
per-shard delta segments, snapshot extension, and a global version bump;
parent and child dtables coexist and share every parent buffer.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hashindex as hix
from repro.core import hashing
from repro.core import snapshot as snap_mod
from repro.core.hashindex import EMPTY_KEY
from repro.core.pointers import NULL_PTR, PTR_DTYPE
from repro.core.schema import Schema
from repro.core.table import IndexedTable, make_segment_arrays, pad_to_batches
from repro.dist import shuffle


@partial(jax.tree_util.register_dataclass, data_fields=["table"],
         meta_fields=["num_shards", "version"])
@dataclasses.dataclass(frozen=True)
class DistributedTable:
    """Shard-stacked Indexed DataFrame: one pytree, leading shard axis."""

    table: IndexedTable   # every array leaf is [num_shards, ...]
    num_shards: int
    version: int          # global MVCC version (paper §III-D)

    @property
    def schema(self) -> Schema:
        return self.table.schema

    @property
    def rows_per_batch(self) -> int:
        return self.table.rows_per_batch

    @property
    def layout(self) -> str:
        return self.table.layout

    @property
    def slots(self) -> int:
        return self.table.slots

    def num_rows(self):
        """Total valid rows across all shards."""
        return self.table.num_rows()

    def index_nbytes(self) -> int:
        return self.table.index_nbytes()

    def data_nbytes(self) -> int:
        return self.table.data_nbytes()


# ---------------------------------------------------------------------------
# Host-side routing (ingest path: exact, no capacity bound)
# ---------------------------------------------------------------------------

def _route_host(cols, schema: Schema, num_shards: int, rows_per_batch: int,
                valid=None):
    """Partition columns by key hash into [num_shards, cap] padded arrays.

    The ingest path routes on the host (numpy) so it is exact — capacity is
    *derived* from the worst shard's row count, not guessed; query-time
    probe routing is the vectorized ``dist.shuffle`` instead.
    """
    keys = np.asarray(cols[schema.key]).astype(np.int64)
    n = keys.shape[0]
    v = (np.ones(n, bool) if valid is None
         else np.asarray(valid, bool).copy())
    dest = np.asarray(hashing.partition_hash(jnp.asarray(keys), num_shards))
    counts = np.bincount(dest[v], minlength=num_shards)
    cap = pad_to_batches(max(int(counts.max()), 1), rows_per_batch)
    out = {c.name: np.zeros((num_shards, cap), np.dtype(c.dtype))
           for c in schema.columns}
    vout = np.zeros((num_shards, cap), bool)
    for d in range(num_shards):
        m = v & (dest == d)
        k = int(m.sum())
        for c in schema.columns:
            out[c.name][d, :k] = np.asarray(cols[c.name])[m]
        vout[d, :k] = True
    return ({name: jnp.asarray(a) for name, a in out.items()},
            jnp.asarray(vout), cap)


def _build_stacked_segment(shard_cols, shard_valid, heads, schema: Schema, *,
                           row_base: int, rows_per_batch: int, layout: str,
                           slots: int, max_retries: int = 6):
    """One vmapped segment build across shards, retrying until no shard's
    bucket array overflows (all shards share one bucket count — the
    stacked pytree needs uniform shapes)."""
    cap = int(shard_valid.shape[1])
    nb = hix.suggest_num_buckets(cap, slots)
    for _ in range(max_retries):
        seg, overflow = jax.vmap(
            lambda c, v, h, _nb=nb: make_segment_arrays(
                c, v, h, schema, row_base=row_base,
                rows_per_batch=rows_per_batch, layout=layout,
                num_buckets=_nb, slots=slots))(shard_cols, shard_valid,
                                               heads)
        if int(jnp.max(overflow)) == 0:
            return seg
        nb *= 2
    raise RuntimeError("distributed segment build kept overflowing")


def create_distributed(cols: dict, schema: Schema, num_shards: int, *,
                       rows_per_batch: int = 4096, layout: str = "row",
                       slots: int = hix.DEFAULT_SLOTS,
                       valid=None) -> DistributedTable:
    """Paper Listing 1 ``createIndex`` at cluster scope: hash-partition the
    dataframe, then build every shard's index in one vmapped pass.

    Shard snapshots are built **with flat data**: distributed queries take
    the dtable as a jit argument, so everything the fused pipeline needs
    (probe planes, prev, row data) must live in the stored pytree.
    """
    sc, sv, cap = _route_host(cols, schema, num_shards, rows_per_batch,
                              valid)
    heads = jnp.full((num_shards, cap), NULL_PTR, PTR_DTYPE)
    seg = _build_stacked_segment(sc, sv, heads, schema, row_base=0,
                                 rows_per_batch=rows_per_batch,
                                 layout=layout, slots=slots)
    snap = jax.vmap(lambda s: snap_mod.snapshot_from_segments(
        (s,), layout, schema=schema, with_data=True))(seg)
    table = IndexedTable(segments=(seg,), snapshot=snap, schema=schema,
                         rows_per_batch=rows_per_batch, layout=layout,
                         version=0, slots=slots)
    return DistributedTable(table=table, num_shards=num_shards, version=0)


def append_distributed(dt: DistributedTable, cols: dict,
                       valid=None) -> DistributedTable:
    """Functional distributed append -> new version (paper §III-D MVCC).

    Routes the delta to owning shards, probes each shard's parent for head
    links, builds one delta segment per shard (vmapped), and extends each
    shard's snapshot incrementally.  The parent dtable is untouched —
    divergent appends coexist, sharing every parent buffer by reference.
    """
    schema, rpb = dt.schema, dt.rows_per_batch
    sc, sv, cap = _route_host(cols, schema, dt.num_shards, rpb, valid)
    keys = jnp.where(sv, jnp.asarray(sc[schema.key], jnp.int64), EMPTY_KEY)
    heads = jax.vmap(lambda t, k: t.probe_latest_ref(k))(dt.table, keys)
    seg = _build_stacked_segment(sc, sv, heads, schema,
                                 row_base=dt.table.capacity,
                                 rows_per_batch=rpb, layout=dt.layout,
                                 slots=dt.slots)
    snap = jax.vmap(lambda sn, sg: snap_mod.extend_snapshot(
        sn, sg, schema=schema))(dt.table.snapshot, seg)
    child = dataclasses.replace(dt.table,
                                segments=dt.table.segments + (seg,),
                                snapshot=snap,
                                version=dt.table.version + 1)
    return DistributedTable(table=child, num_shards=dt.num_shards,
                            version=dt.version + 1)


# ---------------------------------------------------------------------------
# Distributed queries (vmapped single-partition ops + owner select)
# ---------------------------------------------------------------------------

def lookup(dt: DistributedTable, keys, *, max_matches: int, names=None):
    """Distributed point lookup -> (cols [Q, M], valid [Q, M], owner [Q]).

    Keys are routed by ``partition_hash``; every shard answers the full
    query batch through its own Snapshot (the broadcast probe of
    ``indexed_join_bcast``) and the owner shard's answer is selected per
    query.  Rows for a key live only on its owner, so the select is exact.
    """
    q = jnp.asarray(keys, jnp.int64)
    owner = hashing.partition_hash(q, dt.num_shards)

    def shard(t):
        rids, _ = t.lookup(q, max_matches)
        valid = rids != NULL_PTR
        cols = t.gather_rows(jnp.maximum(rids, 0), names=names)
        return cols, valid

    cols_s, valid_s = jax.vmap(shard)(dt.table)       # [s, Q, M] leaves
    iq = jnp.arange(q.shape[0])
    cols = {k: v[owner, iq] for k, v in cols_s.items()}
    return cols, valid_s[owner, iq], owner


def indexed_join_bcast(dt: DistributedTable, probe_cols: dict,
                       probe_key: str, max_matches: int, *, names=None):
    """Broadcast equi-join: ship the (small) probe side to every shard.

    Returns (build_cols [Q, M], probe_cols broadcast [Q, M], valid [Q, M])
    — the same contract as ``core.joins.indexed_join``.
    """
    q = jnp.asarray(probe_cols[probe_key], jnp.int64)
    build_cols, valid, _ = lookup(dt, q, max_matches=max_matches,
                                  names=names)
    m = valid.shape[1]
    probe_b = {k: jnp.broadcast_to(jnp.asarray(v)[:, None],
                                   (q.shape[0], m))
               for k, v in probe_cols.items()}
    return build_cols, probe_b, valid


def indexed_join_shuffle(dt: DistributedTable, probe_cols: dict,
                         probe_key: str, probe_valid, max_matches: int, *,
                         capacity: int | None = None, names=None):
    """Shuffle equi-join: the (large) probe side arrives sharded [s, n];
    probe rows are shuffled to the shard owning their key
    (``dist.shuffle``), then joined locally — results stay sharded.

    Returns (build_cols [s, s*cap, M], probe_cols [s, s*cap, M],
    valid [s, s*cap, M], dropped [s]).  ``capacity`` bounds each
    (src, dest) exchange lane; the default ``n`` can never drop.
    """
    s = dt.num_shards
    keys = jnp.asarray(probe_cols[probe_key], jnp.int64)
    assert keys.shape[0] == s, (keys.shape, s)
    cap = capacity if capacity is not None else keys.shape[1]
    payload = {k: jnp.asarray(v) for k, v in probe_cols.items()}
    rk, rp, rv, dropped = shuffle.shuffle_global(
        keys, payload, jnp.asarray(probe_valid, bool), s, cap)

    def local(t, k, v):
        rids, _ = t.lookup(k, max_matches)
        valid = (rids != NULL_PTR) & v[:, None]
        cols = t.gather_rows(jnp.maximum(rids, 0), names=names)
        return cols, valid

    build_cols, valid = jax.vmap(local)(dt.table, rk, rv)
    probe_b = {k: jnp.broadcast_to(v[..., None], v.shape + (max_matches,))
               for k, v in rp.items()}
    return build_cols, probe_b, valid, dropped


def choose_join(dt, probe_rows: int, *,
                bcast_threshold: int = 1_000_000) -> str:
    """Paper §III-D planner rule: broadcast the probe side while it is
    cheaper to replicate than to shuffle; shuffle at scale."""
    return "bcast" if probe_rows <= bcast_threshold else "shuffle"
