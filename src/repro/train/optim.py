"""AdamW optimizer — pure-JAX, ZeRO-shardable, bf16-moment option.

State layout mirrors the params pytree (one ``m``/``v`` leaf per param), so
ZeRO sharding is a *sharding decision*, not a data-structure change: the
launcher pins optimizer-state leaves to ``("pod","data")`` on their largest
divisible axis (see launch/shardings.py) while params stay on the model
axes.  That is ZeRO-1/2 semantics under GSPMD: each data-parallel rank
holds 1/N of the moments, and the update math is identical because the
arithmetic is elementwise.

For the 671B-class configs the fp32 m+v would be 9.4 TB; ``moment_dtype=
bfloat16`` halves that, and ``master_weights=False`` (stochastic-rounding
style update applied directly to the bf16 params) removes the fp32 master
copy — both are config switches recorded in DESIGN.md §6.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr_peak: float = 3e-4
    warmup_steps: int = 200
    decay_steps: int = 10_000
    lr_min_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip_norm: float = 1.0
    moment_dtype: str = "float32"      # "float32" | "bfloat16" (ZeRO mem)
    master_weights: bool = False       # fp32 master copy of bf16 params

    @property
    def moment_jnp(self):
        return {"float32": jnp.float32,
                "bfloat16": jnp.bfloat16}[self.moment_dtype]


def lr_at(cfg: AdamWConfig, step):
    """Linear warmup -> cosine decay to lr_min_ratio."""
    step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    warm = cfg.lr_peak * (step + 1) / max(cfg.warmup_steps, 1)
    t = jnp.clip((step - cfg.warmup_steps)
                 / max(cfg.decay_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.lr_min_ratio + (1 - cfg.lr_min_ratio) * 0.5 \
        * (1 + jnp.cos(jnp.pi * t))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.lr_peak * cos)


def init_state(cfg: AdamWConfig, params):
    state = {
        "m": jax.tree.map(lambda p: jnp.zeros(p.shape, cfg.moment_jnp),
                          params),
        "v": jax.tree.map(lambda p: jnp.zeros(p.shape, cfg.moment_jnp),
                          params),
        "step": jnp.zeros((), jnp.int32),
    }
    if cfg.master_weights:
        state["master"] = jax.tree.map(
            lambda p: p.astype(jnp.float32), params)
    return state


def global_norm(tree):
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), \
        norm


def apply_updates(cfg: AdamWConfig, params, grads, state):
    """One AdamW step.  Returns (new_params, new_state, metrics)."""
    grads_f, gnorm = clip_by_global_norm(grads, cfg.grad_clip_norm)
    step = state["step"] + 1
    lr = lr_at(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v, master=None):
        m32 = m.astype(jnp.float32) * b1 + g * (1 - b1)
        v32 = v.astype(jnp.float32) * b2 + jnp.square(g) * (1 - b2)
        mhat = m32 / bc1
        vhat = v32 / bc2
        base = (master if master is not None else p).astype(jnp.float32)
        new = base - lr * (mhat / (jnp.sqrt(vhat) + cfg.eps)
                           + cfg.weight_decay * base)
        return new, m32.astype(cfg.moment_jnp), v32.astype(cfg.moment_jnp)

    if cfg.master_weights:
        out = jax.tree.map(upd, params, grads_f, state["m"], state["v"],
                           state["master"])
    else:
        out = jax.tree.map(upd, params, grads_f, state["m"], state["v"])
    new32 = jax.tree.map(lambda t: t[0], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda t: isinstance(t, tuple))

    new_params = jax.tree.map(lambda n, p: n.astype(p.dtype), new32, params)
    new_state = {"m": new_m, "v": new_v, "step": step}
    if cfg.master_weights:
        new_state["master"] = new32
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, new_state, metrics
