"""train_step factory: loss dispatch, microbatch accumulation, remat,
optional cross-pod gradient compression.

The returned ``train_step`` is a pure function
``(params, opt_state, batch [, residual]) -> (params, opt_state, metrics
[, residual])`` — the launcher jits it with mesh shardings; tests call it
eagerly on CPU.  Microbatching reshapes the global batch ``[B, ...]`` into
``[k, B/k, ...]`` and accumulates gradients with a ``lax.scan`` so peak
activation memory is one microbatch (the standard memory/throughput knob;
combined with remat policies from models/transformer.py).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.models import transformer as tf
from repro.models import whisper as wh
from repro.train import compress as cmp
from repro.train import optim


def make_loss_fn(cfg, remat: str = "dots"):
    """Family dispatch: batch dict -> scalar loss (+ metrics)."""
    if cfg.encoder_decoder:
        def loss_fn(params, batch):
            return wh.forward_train(params, cfg, batch["frames"],
                                    batch["tokens"], remat=remat)
    elif cfg.family == "vlm":
        def loss_fn(params, batch):
            return tf.forward_train(params, cfg, batch["tokens"],
                                    patch_emb=batch["patch_emb"],
                                    mrope_positions=batch.get(
                                        "mrope_positions"),
                                    remat=remat)
    else:
        def loss_fn(params, batch):
            return tf.forward_train(params, cfg, batch["tokens"],
                                    remat=remat)
    return loss_fn


def init_params(cfg, key):
    if cfg.encoder_decoder:
        return wh.init_params(cfg, key)
    return tf.init_params(cfg, key)


def _split_micro(batch, k: int):
    def sp(name, x):
        if name == "mrope_positions":      # [3, B, S]: batch is dim 1
            b = x.shape[1]
            assert b % k == 0, (b, k)
            parts = x.reshape(x.shape[0], k, b // k, *x.shape[2:])
            return jnp.moveaxis(parts, 1, 0)
        b = x.shape[0]
        assert b % k == 0, (b, k)
        return x.reshape(k, b // k, *x.shape[1:])
    return {name: sp(name, x) for name, x in batch.items()}


def make_train_step(cfg, opt_cfg: optim.AdamWConfig, *,
                    microbatches: int = 1, remat: str = "dots",
                    compress_grads: bool = False):
    loss_fn = make_loss_fn(cfg, remat)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def compute_grads(params, batch):
        if microbatches == 1:
            (loss, metrics), grads = grad_fn(params, batch)
            return grads, metrics
        micro = _split_micro(batch, microbatches)

        def body(carry, mb):
            acc = carry
            (loss, metrics), g = grad_fn(params, mb)
            acc = jax.tree.map(
                lambda a, x: a + x.astype(jnp.float32) / microbatches,
                acc, g)
            return acc, metrics

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                             params)
        grads, metrics = jax.lax.scan(body, zeros, micro)
        metrics = jax.tree.map(lambda m: jnp.mean(m), metrics)
        return grads, metrics

    if compress_grads:
        def train_step(params, opt_state, batch, residual):
            grads, metrics = compute_grads(params, batch)
            grads, residual = cmp.ef_compress_grads(grads, residual)
            params, opt_state, om = optim.apply_updates(
                opt_cfg, params, grads, opt_state)
            return params, opt_state, {**metrics, **om}, residual
    else:
        def train_step(params, opt_state, batch):
            grads, metrics = compute_grads(params, batch)
            params, opt_state, om = optim.apply_updates(
                opt_cfg, params, grads, opt_state)
            return params, opt_state, {**metrics, **om}
    return train_step
