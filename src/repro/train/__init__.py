"""train — optimizer, gradient compression, train-step factory.

  optim.py     AdamW (ZeRO-shardable state, bf16 moments, master-free)
  compress.py  int8 block-quantized gradient compression + error feedback
  step.py      train_step factory (microbatching, remat, loss dispatch)
"""

from repro.train.optim import AdamWConfig, init_state, apply_updates, lr_at
from repro.train.step import make_train_step, make_loss_fn, init_params
from repro.train import compress

__all__ = ["AdamWConfig", "init_state", "apply_updates", "lr_at",
           "make_train_step", "make_loss_fn", "init_params", "compress"]
