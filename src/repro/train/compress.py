"""Gradient compression with error feedback — the distributed-optimization
trick for the cross-pod reduction.

At 512+ chips the data-parallel gradient all-reduce crosses the (slow)
pod-to-pod links.  We compress the *cross-pod* hop: int8 block-quantized
gradients with an error-feedback residual (Seide et al. / 1-bit Adam
lineage).  Within a pod the reduction stays full-precision (ICI is fast);
between pods the bytes drop 4x (bf16->int8 with per-block scales).

Implementation notes:
  * ``quantize``/``dequantize`` are pure and jit-friendly; block size is
    static.  Scales are f32 per block of 256 values.
  * ``ef_compress_grads`` applies error feedback: residual carries the
    quantization error into the next step — unbiased in the long run,
    which is what keeps convergence intact.
  * The *wire* win shows up in the dry-run HLO as the cross-pod
    all-reduce operating on int8 (4x fewer collective bytes on the "pod"
    axis); EXPERIMENTS.md §Perf quantifies it on the collective term.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

BLOCK = 256


def _pad_to_block(x):
    n = x.size
    pad = (-n) % BLOCK
    return jnp.pad(x.reshape(-1), (0, pad)), n


def quantize(x):
    """f32/bf16 array -> (int8 codes, f32 scales, orig_shape, orig_size)."""
    flat, n = _pad_to_block(x.astype(jnp.float32))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    safe = jnp.maximum(scale, 1e-12)
    codes = jnp.clip(jnp.round(blocks / safe), -127, 127).astype(jnp.int8)
    return codes, scale, x.shape, n


def dequantize(codes, scale, shape, n):
    flat = (codes.astype(jnp.float32) * scale).reshape(-1)[:n]
    return flat.reshape(shape)


def compress_roundtrip(x):
    """quantize -> dequantize (what the far side reconstructs)."""
    return dequantize(*quantize(x))


def ef_compress_grads(grads, residual):
    """Error-feedback compression over a gradient pytree.

    Returns (compressed_grads, new_residual).  ``compressed_grads`` is what
    goes over the wire (reconstructed form); ``new_residual`` carries the
    per-leaf quantization error to the next step.
    """
    def one(g, r):
        g32 = g.astype(jnp.float32) + r
        q = compress_roundtrip(g32)
        return q, g32 - q

    out = jax.tree.map(one, grads, residual)
    comp = jax.tree.map(lambda t: t[0], out,
                        is_leaf=lambda t: isinstance(t, tuple))
    res = jax.tree.map(lambda t: t[1], out,
                       is_leaf=lambda t: isinstance(t, tuple))
    return comp, res


def init_residual(grads_like):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32),
                        grads_like)
