"""repro — the Indexed DataFrame (Uta et al., 2021) rebuilt as a JAX/TPU
framework: an in-memory, hash-partitioned indexed cache with MVCC appends,
plus the training/serving substrates that consume it.

int64 keys are first-class in the index (the paper's key columns are 32/64-bit
integers and hashed strings), so x64 is enabled at import.  All model code
uses explicit dtypes (bf16/f32) and is unaffected.
"""

import jax

jax.config.update("jax_enable_x64", True)

__version__ = "1.0.0"
