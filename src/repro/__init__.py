"""repro — the Indexed DataFrame (Uta et al., 2021) rebuilt as a JAX/TPU
framework: an in-memory, hash-partitioned indexed cache with MVCC appends,
plus the training/serving substrates that consume it.

int64 keys are first-class in the index (the paper's key columns are 32/64-bit
integers and hashed strings), so x64 is enabled at import.  All model code
uses explicit dtypes (bf16/f32) and is unaffected.
"""

import jax

jax.config.update("jax_enable_x64", True)

__version__ = "1.0.0"
__all__ = ["FramePlan", "IndexedFrame", "PartitionSpec"]


def __getattr__(name):
    # The public facade (DESIGN.md §11), re-exported LAZILY: importing
    # repro.frame builds core module constants (jnp arrays), which would
    # initialize the XLA backend and lock the device count before entry
    # points like launch/dryrun.py get to set XLA_FLAGS.
    if name in __all__:
        from repro import frame
        return getattr(frame, name)
    raise AttributeError(f"module 'repro' has no attribute {name!r}")
