"""Mamba2 / SSD (state-space duality) layers — chunked, TPU-friendly.

Implements the SSD algorithm of Dao & Gu (arXiv:2405.21060): sequence split
into chunks; intra-chunk terms are batched matmuls (MXU work), inter-chunk
state is a short ``lax.scan`` over chunk summaries.  The same layer serves
mamba2-370m and jamba's mamba blocks (DESIGN.md notes jamba ships mamba-1;
we use the SSD formulation as the TPU-idiomatic equivalent — same
selective-state semantics, hardware-appropriate compute shape).

Decode keeps a fixed-size recurrent state [B, H, P, N] — O(1) per token,
which is what makes the ssm/hybrid archs eligible for long_500k.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig, dense_init, ones, rms_norm
from repro.models.sharding import hint


def _cfg_dims(cfg: ModelConfig):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    n_heads = d_inner // s.head_dim
    return s, d_inner, n_heads


def ssm_init(key, cfg: ModelConfig, dtype):
    s, d_inner, n_heads = _cfg_dims(cfg)
    ks = jax.random.split(key, 8)
    conv_dim = d_inner + 2 * s.n_groups * s.d_state
    p = {
        # fused input projection: [z (gate), x, B, C, dt]
        "w_in": dense_init(ks[0], cfg.d_model,
                           2 * d_inner + 2 * s.n_groups * s.d_state
                           + n_heads, dtype),
        "conv_w": (jax.random.normal(ks[1], (s.d_conv, conv_dim),
                                     jnp.float32) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, n_heads)
                         ).astype(jnp.float32),
        "D": jnp.ones((n_heads,), jnp.float32),
        "dt_bias": jnp.zeros((n_heads,), jnp.float32),
        "norm": ones((d_inner,), dtype),
        "w_out": dense_init(ks[2], d_inner, cfg.d_model, dtype),
    }
    return p


def _split_proj(cfg, zxbcdt):
    s, d_inner, n_heads = _cfg_dims(cfg)
    gn = s.n_groups * s.d_state
    z, x, B, C, dt = jnp.split(
        zxbcdt, [d_inner, 2 * d_inner, 2 * d_inner + gn,
                 2 * d_inner + 2 * gn], axis=-1)
    return z, x, B, C, dt


def _causal_conv(x, w, b):
    """Depthwise causal conv1d: x [B,S,C], w [K,C]."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(k))
    return out + b


def _segsum(logd):
    """log-decay cumulative segment sums: [..., Q] -> [..., Q, Q] lower-tri."""
    q = logd.shape[-1]
    cs = jnp.cumsum(logd, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    ii = jnp.arange(q)
    mask = ii[:, None] >= ii[None, :]
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x, dt, A, B, C, D, chunk: int):
    """SSD forward.

    x  : [b, s, h, p]   (heads x head_dim)
    dt : [b, s, h]      (softplus'd step sizes, >0)
    A  : [h]            (negative decay rates)
    B  : [b, s, g, n]   C: [b, s, g, n]
    returns y [b, s, h, p], final_state [b, h, p, n]

    Sequences not divisible by ``chunk`` are zero-padded internally
    (dt = 0 on padding => exp(0) decay, zero state contribution — exact).
    """
    b, s_in, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    pad = (-s_in) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
    s = s_in + pad
    nc = s // chunk
    rep = h // g

    xc = x.reshape(b, nc, chunk, h, p)
    dtc = dt.reshape(b, nc, chunk, h)
    Bc = B.reshape(b, nc, chunk, g, n)
    Cc = C.reshape(b, nc, chunk, g, n)
    Bh = jnp.repeat(Bc, rep, axis=3)          # [b,nc,q,h,n]
    Ch = jnp.repeat(Cc, rep, axis=3)

    logd = dtc * A[None, None, None, :]       # [b,nc,q,h] (negative)
    # --- intra-chunk (quadratic within chunk; MXU batched matmuls) ---------
    L = jnp.exp(_segsum(jnp.moveaxis(logd, -1, 2)))       # [b,nc,h,q,q]
    scores = jnp.einsum("bcqhn,bckhn->bchqk", Ch, Bh)     # [b,nc,h,q,q]
    y_diag = jnp.einsum("bchqk,bchqk,bckh,bckhp->bcqhp",
                        scores, L, dtc, xc)

    # --- chunk summaries -> inter-chunk scan -------------------------------
    total = jnp.sum(logd, axis=2)                          # [b,nc,h]
    decay_out = jnp.exp(jnp.cumsum(logd, axis=2))          # [b,nc,q,h]
    # state contribution of each chunk: sum_k exp(total - cum_k) dt_k B_k x_k
    decay_in = jnp.exp(total[:, :, None, :]
                       - jnp.cumsum(logd, axis=2))         # [b,nc,q,h]
    states = jnp.einsum("bcqh,bcqh,bcqhn,bcqhp->bchpn",
                        dtc, decay_in, Bh, xc)             # [b,nc,h,p,n]

    def scan_fn(carry, inp):
        st, tot = inp
        new = carry * jnp.exp(tot)[..., None, None] + st
        return new, carry                                  # emit PREV state

    init = jnp.zeros((b, h, p, n), jnp.float32)
    final, prev_states = jax.lax.scan(
        scan_fn, init,
        (jnp.moveaxis(states, 1, 0).astype(jnp.float32),
         jnp.moveaxis(total, 1, 0)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)          # [b,nc,h,p,n]

    y_off = jnp.einsum("bcqhn,bcqh,bchpn->bcqhp",
                       Ch, decay_out, prev_states)
    y = (y_diag + y_off).reshape(b, s, h, p)
    y = y + x * D[None, None, :, None]
    return y[:, :s_in].astype(x.dtype), final


def ssm_prefill(p, xin, cfg: ModelConfig):
    """[B, S, D] -> ([B, S, D], state [B,H,P,N] + conv tail)."""
    s_cfg, d_inner, n_heads = _cfg_dims(cfg)
    zxbcdt = jnp.einsum("bsd,de->bse", xin, p["w_in"])
    z, x, B, C, dt = _split_proj(cfg, zxbcdt)
    conv_in = jnp.concatenate([x, B, C], axis=-1)
    conv_out = jax.nn.silu(_causal_conv(conv_in, p["conv_w"], p["conv_b"]))
    x, B, C = jnp.split(conv_out,
                        [d_inner, d_inner + s_cfg.n_groups * s_cfg.d_state],
                        axis=-1)
    bsz, s, _ = x.shape
    xh = x.reshape(bsz, s, n_heads, s_cfg.head_dim)
    xh = hint(xh, "batch", "seq", "state", None)
    Bh = B.reshape(bsz, s, s_cfg.n_groups, s_cfg.d_state)
    Ch = C.reshape(bsz, s, s_cfg.n_groups, s_cfg.d_state)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    y, state = ssd_chunked(xh.astype(jnp.float32), dt, A,
                           Bh.astype(jnp.float32), Ch.astype(jnp.float32),
                           p["D"], s_cfg.chunk)
    y = y.reshape(bsz, s, d_inner).astype(xin.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["w_out"])
    conv_tail = conv_in[:, -(s_cfg.d_conv - 1):, :]
    return hint(out, "batch", "res_seq", "model_d"), \
        {"state": state, "conv": conv_tail}


def ssm_decode(p, xin, cfg: ModelConfig, cache):
    """Single-token step.  cache: {state [B,H,P,N], conv [B,K-1,Cc]}."""
    s_cfg, d_inner, n_heads = _cfg_dims(cfg)
    zxbcdt = jnp.einsum("bsd,de->bse", xin, p["w_in"])     # [B,1,E]
    z, x, B, C, dt = _split_proj(cfg, zxbcdt)
    conv_in = jnp.concatenate([x, B, C], axis=-1)          # [B,1,Cc]
    hist = jnp.concatenate([cache["conv"], conv_in], axis=1)  # [B,K,Cc]
    w = p["conv_w"]
    conv_out = jax.nn.silu(
        jnp.einsum("bkc,kc->bc", hist[:, -w.shape[0]:, :], w)
        + p["conv_b"])[:, None, :]
    x, B, C = jnp.split(conv_out,
                        [d_inner, d_inner + s_cfg.n_groups * s_cfg.d_state],
                        axis=-1)
    bsz = x.shape[0]
    xh = x.reshape(bsz, n_heads, s_cfg.head_dim).astype(jnp.float32)
    Bh = jnp.repeat(B.reshape(bsz, s_cfg.n_groups, s_cfg.d_state),
                    n_heads // s_cfg.n_groups, axis=1).astype(jnp.float32)
    Ch = jnp.repeat(C.reshape(bsz, s_cfg.n_groups, s_cfg.d_state),
                    n_heads // s_cfg.n_groups, axis=1).astype(jnp.float32)
    dt1 = jax.nn.softplus(dt.astype(jnp.float32)[:, 0, :]
                          + p["dt_bias"])                  # [B,H]
    A = -jnp.exp(p["A_log"])
    decay = jnp.exp(dt1 * A[None, :])                      # [B,H]
    st = cache["state"] * decay[..., None, None] \
        + jnp.einsum("bh,bhn,bhp->bhpn", dt1, Bh, xh)
    y = jnp.einsum("bhn,bhpn->bhp", Ch, st) \
        + xh * p["D"][None, :, None]
    y = y.reshape(bsz, 1, d_inner).astype(xin.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["w_out"])
    return out, {"state": st, "conv": hist[:, 1:, :]}
