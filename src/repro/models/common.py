"""Shared model-building blocks: config dataclasses, init helpers, norms.

Pure-JAX (no flax): params are nested dicts of arrays; every module is an
(init, apply) function pair.  Layers are grouped into homogeneous *scan
groups* (transformer.py) so deep models lower as ``lax.scan`` — compile
time and HLO size stay bounded at 61+ layers on a 512-device mesh.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Config
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int
    kv_lora_rank: int
    qk_nope_head_dim: int
    qk_rope_head_dim: int
    v_head_dim: int


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared: int = 0
    first_dense_layers: int = 0
    every_k: int = 1            # MoE layer every k-th layer (jamba: 2)
    capacity_factor: float = 1.25
    router: str = "softmax"     # softmax | sigmoid (deepseek-v3)
    router_aux_free_bias: bool = False   # ds-v3 aux-loss-free balancing


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk: int = 128


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    # attention flavor
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 1e4
    local_rope_theta: float | None = None   # gemma3 local layers
    sliding_window: Optional[int] = None
    local_global_pattern: int = 0  # gemma3: 5 local then 1 global
    mrope_sections: tuple[int, ...] = ()    # qwen2-vl (t, h, w)
    # substructure
    mla: Optional[MLAConfig] = None
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    attn_layer_period: int = 0   # jamba: attention every 8th layer...
    attn_layer_offset: int = 0   # ...at offset 4
    # enc-dec (whisper)
    encoder_decoder: bool = False
    encoder_layers: int = 0
    encoder_seq: int = 0         # 1500 frames for whisper
    max_pos: int = 4096          # learned-position table size (whisper)
    # extras
    mtp_depth: int = 0           # deepseek-v3 multi-token prediction
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    # which attention layers are quadratic-free (filled by layer_kinds())

    @property
    def jnp_dtype(self):
        return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[self.dtype]

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    def sub_quadratic(self) -> bool:
        """Eligible for long_500k (DESIGN.md §5)."""
        if self.family in ("ssm", "hybrid"):
            return True
        return self.local_global_pattern > 0


# ---------------------------------------------------------------------------
# Init helpers
# ---------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, dtype, scale: float | None = None):
    scale = scale if scale is not None else (1.0 / jnp.sqrt(d_in))
    return (jax.random.normal(key, (d_in, d_out), jnp.float32)
            * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype):
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02
            ).astype(dtype)


def zeros(shape, dtype):
    return jnp.zeros(shape, dtype)


def ones(shape, dtype):
    return jnp.ones(shape, dtype)


# ---------------------------------------------------------------------------
# Norms / activations
# ---------------------------------------------------------------------------

def rms_norm(x, weight, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps)
    return (out * weight.astype(jnp.float32)).astype(dt)


def layer_norm(x, weight, bias, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    out = (x - mu) * jax.lax.rsqrt(var + eps)
    return (out * weight.astype(jnp.float32)
            + bias.astype(jnp.float32)).astype(dt)


def swiglu(x, w_gate, w_up, w_down):
    """LLaMA-style gated MLP: down( silu(x@gate) * (x@up) )."""
    g = jax.nn.silu(jnp.einsum("...d,df->...f", x, w_gate))
    u = jnp.einsum("...d,df->...f", x, w_up)
    return jnp.einsum("...f,fd->...d", g * u, w_down)


def gelu_mlp(x, w_in, b_in, w_out, b_out):
    """GELU MLP with biases (whisper)."""
    h = jax.nn.gelu(jnp.einsum("...d,df->...f", x, w_in) + b_in)
    return jnp.einsum("...f,fd->...d", h, w_out) + b_out


def cross_entropy(logits, labels, *, z_loss: float = 0.0, mask=None):
    """Token-mean cross entropy in f32 with optional z-loss.

    The label log-prob uses a one-hot select+reduce rather than
    ``take_along_axis``: a gather along the vocab axis would force GSPMD
    to all-gather the (model-sharded) logits, while the masked reduction
    fuses and reduces per-shard (tens of GB per device at 150k vocab).
    """
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                          logits.ndim - 1)
    ll = jnp.sum(jnp.where(vocab_iota == labels[..., None], logits, 0.0),
                 axis=-1)
    loss = lse - ll
    if z_loss:
        loss = loss + z_loss * jnp.square(lse)
    if mask is None:
        return jnp.mean(loss)
    mask = mask.astype(jnp.float32)
    return jnp.sum(loss * mask) / jnp.maximum(jnp.sum(mask), 1.0)
