"""Decoder-only LM assembly covering dense / MoE / MLA / SSM / hybrid / VLM.

Layers are grouped into *scan groups* — maximal runs of structurally
identical layers — and each group lowers as one ``lax.scan`` over stacked
parameters.  deepseek-v3 (3 dense + 58 MoE layers) lowers as two scans;
gemma3's 5-local:1-global pattern as alternating groups; jamba's
1:7 attn:mamba interleave with MoE-every-2 as its repeating blocks.  This
keeps HLO size and compile time bounded on the 512-device dry-run mesh.

API (used by train/, serving/, launch/):
  init_params(cfg, key)                  -> params pytree
  forward_train(params, cfg, tokens, …)  -> (loss, metrics)
  prefill(params, cfg, tokens, …)        -> (logits, caches)
  init_cache(cfg, batch, max_len)        -> caches (dense KV / latent / ssm)
  decode_step(params, cfg, last_tok, caches, …) -> (logits, caches)
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.ad_checkpoint  # noqa: F401 (checkpoint_name in block bodies)
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.common import (ModelConfig, cross_entropy, dense_init,
                                 embed_init, ones, rms_norm, swiglu)
from repro.models.sharding import hint


# ---------------------------------------------------------------------------
# Layer kinds & scan grouping
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LayerKind:
    attn: str            # gqa | mla | ssm
    ffn: str             # dense | moe
    window: int | None   # sliding window (None = global)
    theta: float


def layer_kinds(cfg: ModelConfig) -> list[LayerKind]:
    kinds = []
    for i in range(cfg.num_layers):
        # attention flavor
        if cfg.family == "ssm":
            a = "ssm"
        elif cfg.attn_layer_period:
            a = ("gqa" if i % cfg.attn_layer_period == cfg.attn_layer_offset
                 else "ssm")
        elif cfg.mla is not None:
            a = "mla"
        else:
            a = "gqa"
        # window / theta (gemma3 local:global)
        window, theta = None, cfg.rope_theta
        if cfg.local_global_pattern and a == "gqa":
            period = cfg.local_global_pattern + 1
            if i % period != cfg.local_global_pattern:
                window = cfg.sliding_window
                theta = cfg.local_rope_theta or cfg.rope_theta
        # ffn flavor ("none" = pure mixer blocks, e.g. mamba2 with d_ff=0)
        f = "none" if cfg.d_ff == 0 else "dense"
        if cfg.moe is not None:
            m = cfg.moe
            if i >= m.first_dense_layers and \
                    (i % m.every_k) == (m.every_k - 1 if m.every_k > 1
                                        else 0):
                f = "moe"
        if a == "ssm":
            f = "dense" if f == "dense" else f   # jamba: moe applies too
        kinds.append(LayerKind(a, f, window, theta))
    return kinds


def scan_groups(cfg: ModelConfig) -> list[tuple[LayerKind, int]]:
    """[(kind, run_length), ...] over consecutive identical kinds."""
    groups, kinds = [], layer_kinds(cfg)
    for k in kinds:
        if groups and groups[-1][0] == k:
            groups[-1] = (k, groups[-1][1] + 1)
        else:
            groups.append((k, 1))
    return groups


# ---------------------------------------------------------------------------
# Per-layer init/apply
# ---------------------------------------------------------------------------

def _layer_init(key, cfg: ModelConfig, kind: LayerKind):
    ks = jax.random.split(key, 4)
    dtype = cfg.jnp_dtype
    p = {"ln1": ones((cfg.d_model,), dtype),
         "ln2": ones((cfg.d_model,), dtype)}
    if kind.attn == "gqa":
        p["attn"] = attn.gqa_init(ks[0], cfg, dtype)
    elif kind.attn == "mla":
        p["attn"] = attn.mla_init(ks[0], cfg, dtype)
    else:
        p["attn"] = ssm_mod.ssm_init(ks[0], cfg, dtype)
    if kind.ffn == "dense":
        p["ffn"] = {
            "w_gate": dense_init(ks[1], cfg.d_model, cfg.d_ff, dtype),
            "w_up": dense_init(ks[2], cfg.d_model, cfg.d_ff, dtype),
            "w_down": dense_init(ks[3], cfg.d_ff, cfg.d_model, dtype),
        }
    elif kind.ffn == "moe":
        p["ffn"] = moe_mod.moe_init(ks[1], cfg, dtype)
    else:  # "none": pure mixer block (mamba2) — drop the second norm too
        del p["ln2"]
    return p


def _block_prefill(pl, x, cfg: ModelConfig, kind: LayerKind,
                   mrope_positions=None, want_cache: bool = True):
    h = rms_norm(x, pl["ln1"], cfg.norm_eps)
    if kind.attn == "gqa":
        a, kv = attn.gqa_prefill(pl["attn"], h, cfg, theta=kind.theta,
                                 window=kind.window,
                                 mrope_positions=mrope_positions)
        cache = {"k": kv[0], "v": kv[1]} if want_cache else None
    elif kind.attn == "mla":
        a, kv = attn.mla_prefill(pl["attn"], h, cfg)
        cache = {"c_kv": kv[0], "k_rope": kv[1]} if want_cache else None
    else:
        a, cache = ssm_mod.ssm_prefill(pl["attn"], h, cfg)
        cache = cache if want_cache else None
    # name block outputs so the 'outs' remat policy can pin exactly the
    # post-collective tensors (backward then skips re-running the TP
    # all-reduces that dominate the collective term)
    a = jax.ad_checkpoint.checkpoint_name(a, "block_attn_out")
    x = x + a
    if kind.ffn == "none":
        return x, cache, jnp.zeros((), jnp.float32)
    h2 = rms_norm(x, pl["ln2"], cfg.norm_eps)
    if kind.ffn == "dense":
        f = swiglu(h2, pl["ffn"]["w_gate"], pl["ffn"]["w_up"],
                   pl["ffn"]["w_down"])
        aux = jnp.zeros((), jnp.float32)
    else:
        f, aux = moe_mod.moe_ffn(pl["ffn"], h2, cfg)
    f = jax.ad_checkpoint.checkpoint_name(f, "block_ffn_out")
    return x + f, cache, aux


def _block_decode(pl, x, cache, cfg: ModelConfig, kind: LayerKind):
    h = rms_norm(x, pl["ln1"], cfg.norm_eps)
    if kind.attn == "gqa":
        a, cache = attn.gqa_decode(pl["attn"], h, cfg, cache,
                                   theta=kind.theta, window=kind.window)
    elif kind.attn == "mla":
        a, cache = attn.mla_decode(pl["attn"], h, cfg, cache)
    else:
        a, cache = ssm_mod.ssm_decode(pl["attn"], h, cfg, cache)
    x = x + a
    if kind.ffn == "none":
        return x, cache
    h2 = rms_norm(x, pl["ln2"], cfg.norm_eps)
    if kind.ffn == "dense":
        f = swiglu(h2, pl["ffn"]["w_gate"], pl["ffn"]["w_up"],
                   pl["ffn"]["w_down"])
    else:
        f, _ = moe_mod.moe_ffn(pl["ffn"], h2, cfg)
    return x + f, cache


# ---------------------------------------------------------------------------
# Model init / apply
# ---------------------------------------------------------------------------

def init_params(cfg: ModelConfig, key):
    dtype = cfg.jnp_dtype
    keys = jax.random.split(key, cfg.num_layers + 4)
    params = {"embed": embed_init(keys[0], cfg.vocab_size, cfg.d_model,
                                  dtype),
              "final_norm": ones((cfg.d_model,), dtype),
              "groups": []}
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(keys[1], cfg.d_model,
                                       cfg.vocab_size, dtype)
    li = 0
    for kind, n in scan_groups(cfg):
        gkeys = jnp.stack([keys[2 + li + j] for j in range(n)])
        params["groups"].append(
            jax.vmap(lambda k: _layer_init(k, cfg, kind))(gkeys))
        li += n
    if cfg.mtp_depth:
        mk = jax.random.split(keys[-1], cfg.mtp_depth + 1)
        kind = layer_kinds(cfg)[-1]
        params["mtp"] = {
            "proj": dense_init(mk[0], 2 * cfg.d_model, cfg.d_model, dtype),
            "norm_h": ones((cfg.d_model,), dtype),
            "norm_e": ones((cfg.d_model,), dtype),
            "block": _layer_init(mk[1], cfg, kind),
        }
    return params


def _embed(params, cfg, tokens, patch_emb=None):
    x = params["embed"][tokens]                       # [B,S,D]
    x = x.astype(cfg.jnp_dtype)
    if patch_emb is not None:
        # VLM stub: patch embeddings overwrite the first P positions
        p = patch_emb.shape[1]
        x = jnp.concatenate([patch_emb.astype(cfg.jnp_dtype),
                             x[:, p:]], axis=1)
    return hint(x, "batch", "res_seq", "model_d")


def _logits(params, cfg, x):
    if cfg.tie_embeddings:
        out = jnp.einsum("bsd,vd->bsv", x, params["embed"])
    else:
        out = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])
    return hint(out, "batch", "seq", "vocab")


def backbone_prefill(params, cfg: ModelConfig, x, mrope_positions=None,
                     remat: str = "none", want_cache: bool = True):
    """``remat``: 'none' | 'dots' (save matmul outputs — cheap recompute,
    high memory) | 'full' (save only layer-boundary activations — the
    production default at scale).  Training passes want_cache=False so KV
    tensors are never built/stacked (they'd ride the backward scan carry
    otherwise)."""
    caches, aux_total = [], jnp.zeros((), jnp.float32)
    for gi, (kind, n) in enumerate(scan_groups(cfg)):
        body = partial(_block_prefill, cfg=cfg, kind=kind,
                       mrope_positions=mrope_positions,
                       want_cache=want_cache)

        def scan_body(carry, pl, body=body):
            y, cache, aux = body(pl, carry)
            return y, (cache, aux)

        if remat == "full":
            scan_body = jax.checkpoint(scan_body)
        elif remat == "dots":
            scan_body = jax.checkpoint(
                scan_body,
                policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
        elif remat == "outs":
            # save only the named post-collective block outputs: memory
            # ~2 residual-sized tensors per layer, and backward recompute
            # never re-runs the wo/w_down all-reduces
            scan_body = jax.checkpoint(
                scan_body,
                policy=jax.checkpoint_policies.save_only_these_names(
                    "block_attn_out", "block_ffn_out"))
        x, (cache_g, aux_g) = jax.lax.scan(scan_body, x,
                                           params["groups"][gi])
        caches.append(cache_g)
        aux_total = aux_total + jnp.sum(aux_g)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x, caches, aux_total


def forward_train(params, cfg: ModelConfig, tokens, *, patch_emb=None,
                  mrope_positions=None, loss_mask=None, remat: str = "dots",
                  aux_weight: float = 0.01, mtp_weight: float = 0.3):
    """tokens [B, S] -> scalar loss (+ metrics dict)."""
    x = _embed(params, cfg, tokens, patch_emb)
    h, _, aux = backbone_prefill(params, cfg, x,
                                 mrope_positions=mrope_positions,
                                 remat=remat, want_cache=False)
    logits = _logits(params, cfg, h)
    labels = tokens[:, 1:]
    mask = loss_mask[:, 1:] if loss_mask is not None else None
    loss = cross_entropy(logits[:, :-1], labels, mask=mask, z_loss=1e-4)
    metrics = {"lm_loss": loss, "aux_loss": aux}
    total = loss + aux_weight * aux

    if cfg.mtp_depth:
        # deepseek-v3 multi-token prediction: predict t+2 from (h_t, e_{t+1})
        mp = params["mtp"]
        h_in = rms_norm(h[:, :-1], mp["norm_h"], cfg.norm_eps)
        e_in = rms_norm(_embed(params, cfg, tokens[:, 1:]),
                        mp["norm_e"], cfg.norm_eps)
        z = jnp.einsum("bsd,dk->bsk",
                       jnp.concatenate([h_in, e_in], axis=-1), mp["proj"])
        kind = layer_kinds(cfg)[-1]
        z, _, _ = _block_prefill(mp["block"], z, cfg, kind)
        mtp_logits = _logits(params, cfg, z)
        mtp_loss = cross_entropy(mtp_logits[:, :-1], tokens[:, 2:])
        metrics["mtp_loss"] = mtp_loss
        total = total + mtp_weight * mtp_loss

    metrics["loss"] = total
    return total, metrics


def prefill(params, cfg: ModelConfig, tokens, *, patch_emb=None,
            mrope_positions=None):
    x = _embed(params, cfg, tokens, patch_emb)
    h, caches, _ = backbone_prefill(params, cfg, x,
                                    mrope_positions=mrope_positions)
    return _logits(params, cfg, h[:, -1:]), caches


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=None) -> list:
    """Dense decode caches, one stacked pytree per scan group."""
    dtype = dtype or cfg.jnp_dtype
    caches = []
    for kind, n in scan_groups(cfg):
        if kind.attn == "gqa":
            cap = min(max_len, kind.window) if kind.window else max_len
            c = {"k": jnp.zeros((n, batch, cap, cfg.num_kv_heads,
                                 cfg.head_dim), dtype),
                 "v": jnp.zeros((n, batch, cap, cfg.num_kv_heads,
                                 cfg.head_dim), dtype),
                 "length": jnp.zeros((n, batch), jnp.int32)}
        elif kind.attn == "mla":
            m = cfg.mla
            c = {"c_kv": jnp.zeros((n, batch, max_len, m.kv_lora_rank),
                                   dtype),
                 "k_rope": jnp.zeros((n, batch, max_len,
                                      m.qk_rope_head_dim), dtype),
                 "length": jnp.zeros((n, batch), jnp.int32)}
        else:
            s = cfg.ssm
            d_inner = s.expand * cfg.d_model
            heads = d_inner // s.head_dim
            conv_dim = d_inner + 2 * s.n_groups * s.d_state
            c = {"state": jnp.zeros((n, batch, heads, s.head_dim,
                                     s.d_state), jnp.float32),
                 "conv": jnp.zeros((n, batch, s.d_conv - 1, conv_dim),
                                   dtype)}
        caches.append(c)
    return caches


def decode_step(params, cfg: ModelConfig, last_tok, caches):
    """last_tok [B, 1] -> (logits [B, 1, V], updated caches)."""
    x = _embed(params, cfg, last_tok)
    new_caches = []
    for gi, (kind, n) in enumerate(scan_groups(cfg)):

        def scan_body(carry, inp, kind=kind):
            pl, cache = inp
            y, cache = _block_decode(pl, carry, cache, cfg, kind)
            return y, cache

        x, cache_g = jax.lax.scan(scan_body, x,
                                  (params["groups"][gi], caches[gi]))
        new_caches.append(cache_g)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return _logits(params, cfg, x), new_caches
