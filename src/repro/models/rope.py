"""Rotary position embeddings: standard, per-layer theta, and M-RoPE.

All functions take explicit ``positions`` so prefill (arange) and decode
(cache length) share one code path.  M-RoPE (Qwen2-VL) carries three
position streams (t, h, w); text tokens use t = h = w = index, vision
patches use their grid coordinates — supplied by the caller.
"""

from __future__ import annotations

import jax.numpy as jnp


def rope_freqs(head_dim: int, theta: float):
    half = head_dim // 2
    inv = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    return inv                                            # [half]


def rotate(x, positions, theta: float):
    """x: [..., S, H, D]; positions: broadcastable to [..., S]."""
    d = x.shape[-1]
    inv = rope_freqs(d, theta)
    ang = positions[..., None].astype(jnp.float32) * inv   # [..., S, half]
    sin = jnp.sin(ang)[..., None, :]                       # [..., S, 1, half]
    cos = jnp.cos(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos],
                          axis=-1)
    return out.astype(x.dtype)


def rotate_mrope(x, positions_thw, theta: float, sections: tuple[int, ...]):
    """M-RoPE: head_dim/2 frequency slots split across (t, h, w) streams.

    x             : [..., S, H, D]
    positions_thw : [3, ..., S]  (t, h, w positions)
    sections      : slot counts per stream, summing to D//2 (e.g. 16,24,24).
    """
    d = x.shape[-1]
    half = d // 2
    assert sum(sections) == half, (sections, half)
    inv = rope_freqs(d, theta)                             # [half]
    # pick the position stream for each frequency slot
    sec_id = jnp.repeat(jnp.arange(len(sections)),
                        jnp.asarray(sections), total_repeat_length=half)
    pos = jnp.take_along_axis(
        jnp.moveaxis(positions_thw, 0, -1),                # [..., S, 3]
        sec_id[(None,) * (positions_thw.ndim - 1)].astype(jnp.int32),
        axis=-1)                                           # [..., S, half]
    ang = pos.astype(jnp.float32) * inv
    sin = jnp.sin(ang)[..., None, :]
    cos = jnp.cos(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos],
                          axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq: int, d: int):
    """Whisper-encoder style fixed sinusoids [seq, d]."""
    half = d // 2
    inv = 1.0 / (10000 ** (jnp.arange(half, dtype=jnp.float32) / (half - 1)))
    ang = jnp.arange(seq, dtype=jnp.float32)[:, None] * inv[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)
