"""models — the architecture zoo (10 assigned archs + paper workloads).

  common.py      configs, init helpers, norms, losses
  rope.py        RoPE / M-RoPE / sinusoids
  attention.py   GQA, qk-norm, bias, sliding-window, MLA (absorbed decode)
  moe.py         GShard-style MoE with shared experts, ds-v3 routing
  ssm.py         Mamba2 / SSD chunked scan + O(1) decode
  transformer.py decoder-only assembly via scan groups
  whisper.py     encoder-decoder (audio)
  sharding.py    logical-axis sharding hints
"""

from repro.models.common import (ModelConfig, MLAConfig, MoEConfig,
                                 SSMConfig)
from repro.models import transformer, whisper, sharding

__all__ = ["ModelConfig", "MLAConfig", "MoEConfig", "SSMConfig",
           "transformer", "whisper", "sharding"]
