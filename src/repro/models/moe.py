"""Mixture-of-Experts FFN — two dispatch mechanisms, one routing contract.

1. ``moe_ffn_einsum`` — GShard-style dense one-hot dispatch
   (``[B,S,E,C]`` einsums).  Simple and exact, but its dispatch FLOPs are
   O(T * E * C * D): at deepseek-v3 scale that is ~8x the model's useful
   compute.  Kept as the small-scale reference path (CPU tests, smoke
   configs) and as the oracle the sorted path is tested against.

2. ``moe_ffn_sorted`` / ``moe_ffn_ep`` — **sort-based dispatch**: tokens
   argsort by expert id, segment-rank gives each token its capacity slot,
   one scatter builds the per-expert batch, experts run as a vmapped
   matmul, one gather+scatter-add combines.  This is the Indexed
   DataFrame's shuffle (hash -> stable sort -> segment rank -> scatter,
   dist/shuffle.py) applied to expert routing — the paper's routing
   substrate and the MoE dispatch are literally the same algorithm
   (DESIGN.md §3).  Dispatch cost falls to sort + O(T*k*D) memory moves.

   ``moe_ffn_ep`` wraps the sorted dispatch in ``shard_map`` for expert
   parallelism: experts shard over the ``model`` axis; each shard packs
   only tokens routed to *its* experts (routing math is replicated over
   the model axis, so no metadata exchange is needed), and the combine is
   one ``psum`` over the model axis — the same collective class as a
   Megatron-TP FFN all-reduce.

Flavors covered:
  * shared experts (deepseek v2/v3: always-on experts added to routed out)
  * softmax top-k routing (classic) and sigmoid scoring (deepseek-v3)
  * aux-loss-free balancing bias (ds-v3) + standard load-balance aux loss
  * first-k-dense layers (ds v2/v3), MoE-every-k layers (jamba)
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.common import ModelConfig, MoEConfig, dense_init, swiglu
from repro.models import sharding as shd
from repro.models.sharding import hint

# dtype of the EP combine psum (§Perf lever: bf16 halves the collective
# bytes of the per-layer [B,S,D] all-reduce; None = f32 exact)
COMBINE_DTYPE = None


def moe_init(key, cfg: ModelConfig, dtype):
    m = cfg.moe
    ks = jax.random.split(key, 8)
    d, f, e = cfg.d_model, m.d_ff_expert, m.num_experts
    p = {
        "router": dense_init(ks[0], d, e, jnp.float32),
        "w_gate": (jax.random.normal(ks[1], (e, d, f), jnp.float32)
                   / jnp.sqrt(d)).astype(dtype),
        "w_up": (jax.random.normal(ks[2], (e, d, f), jnp.float32)
                 / jnp.sqrt(d)).astype(dtype),
        "w_down": (jax.random.normal(ks[3], (e, f, d), jnp.float32)
                   / jnp.sqrt(f)).astype(dtype),
    }
    if m.router_aux_free_bias:
        p["router_bias"] = jnp.zeros((e,), jnp.float32)
    if m.num_shared:
        fs = f * m.num_shared
        p["shared_gate"] = dense_init(ks[4], d, fs, dtype)
        p["shared_up"] = dense_init(ks[5], d, fs, dtype)
        p["shared_down"] = dense_init(ks[6], fs, d, dtype)
    return p


def _capacity(tokens_per_group: int, m: MoEConfig) -> int:
    c = int(tokens_per_group * m.top_k / m.num_experts * m.capacity_factor)
    return max(4, -(-c // 4) * 4)


def route(p, x, m: MoEConfig):
    """Top-k routing.  Returns (weights [B,S,K], experts [B,S,K], aux)."""
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), p["router"])
    if m.router == "sigmoid":                     # deepseek-v3
        scores = jax.nn.sigmoid(logits)
        sel = scores + (p["router_bias"] if m.router_aux_free_bias else 0.0)
    else:
        scores = jax.nn.softmax(logits, axis=-1)
        sel = scores
    _, topk_idx = jax.lax.top_k(sel, m.top_k)                 # [B,S,K]
    topk_w = jnp.take_along_axis(scores, topk_idx, axis=-1)
    if m.router == "sigmoid":
        topk_w = topk_w / jnp.maximum(
            topk_w.sum(-1, keepdims=True), 1e-9)
    # load-balance aux loss (Switch-style)
    probs = scores if m.router == "softmax" else \
        scores / jnp.maximum(scores.sum(-1, keepdims=True), 1e-9)
    density = jnp.mean(jax.nn.one_hot(topk_idx, m.num_experts,
                                      dtype=jnp.float32), axis=(0, 1, 2))
    mean_prob = jnp.mean(probs, axis=(0, 1))
    aux = m.num_experts * jnp.sum(density * mean_prob)
    return topk_w, topk_idx, aux


def moe_ffn(p, x, cfg: ModelConfig):
    """[B, S, D] -> ([B, S, D], aux_loss).  Capacity-dropped tokens pass
    through (residual semantics).

    Mechanism selection: expert-parallel sorted dispatch when a mesh with
    a 'model' axis that divides num_experts is active (production path);
    dense einsum otherwise (reference path).
    """
    mesh = shd._mesh()
    rules = shd._rules()
    if mesh is not None and rules is not None:
        model_axis = rules.get("experts")
        if model_axis is not None and isinstance(model_axis, str):
            esz = mesh.shape[model_axis]
            if cfg.moe.num_experts % esz == 0:
                return moe_ffn_ep(p, x, cfg, mesh=mesh,
                                  dp=rules.get("batch"),
                                  model_axis=model_axis)
    return moe_ffn_einsum(p, x, cfg)


def moe_ffn_einsum(p, x, cfg: ModelConfig):
    """Dense one-hot dispatch (reference / small-scale path)."""
    m = cfg.moe
    b, s, d = x.shape
    cap = _capacity(s, m)
    w, idx, aux = route(p, x, m)

    onehot = jax.nn.one_hot(idx, m.num_experts, dtype=jnp.float32)  # [B,S,K,E]
    # position of each (token, k) within its expert queue
    pos = jnp.cumsum(onehot.reshape(b, s * m.top_k, m.num_experts),
                     axis=1) - 1.0
    pos = pos.reshape(b, s, m.top_k, m.num_experts)
    keep = (pos < cap) & (onehot > 0)
    pos_oh = jax.nn.one_hot(jnp.where(keep, pos, cap).astype(jnp.int32),
                            cap, dtype=jnp.float32)          # [B,S,K,E,C]
    dispatch = (onehot[..., None] * pos_oh).sum(2)            # [B,S,E,C]
    combine = (w[..., None, None] * onehot[..., None]
               * pos_oh).sum(2)                               # [B,S,E,C]
    dispatch = hint(dispatch, "batch", "seq", "experts", "expert_cap")

    xin = jnp.einsum("bsec,bsd->ebcd", dispatch,
                     x.astype(jnp.float32)).astype(cfg.jnp_dtype)
    xin = hint(xin, "experts", "batch", "expert_cap", "model_d")
    h = jax.vmap(lambda xi, g, u, dn: swiglu(xi, g, u, dn))(
        xin, p["w_gate"], p["w_up"], p["w_down"])             # [E,B,C,D]
    h = hint(h, "experts", "batch", "expert_cap", "model_d")
    out = jnp.einsum("bsec,ebcd->bsd", combine,
                     h.astype(jnp.float32)).astype(cfg.jnp_dtype)

    if m.num_shared:
        out = out + swiglu(x, p["shared_gate"], p["shared_up"],
                           p["shared_down"])
    return hint(out, "batch", "res_seq", "model_d"), aux


# ---------------------------------------------------------------------------
# Sort-based dispatch (the shuffle algorithm applied to expert routing)
# ---------------------------------------------------------------------------

def _segment_rank(sorted_ids):
    n = sorted_ids.shape[0]
    pos = jnp.arange(n, dtype=jnp.int32)
    is_start = jnp.concatenate(
        [jnp.ones((1,), bool), sorted_ids[1:] != sorted_ids[:-1]])
    start = jax.lax.associative_scan(jnp.maximum,
                                     jnp.where(is_start, pos, -1))
    return pos - start


def _dispatch_sorted(x_flat, idx, wts, wg, wu, wd, cap: int, e_lo,
                     n_local: int, out_dtype):
    """Route [T,D] tokens to ``n_local`` experts starting at ``e_lo``.

    x_flat [T,D]; idx [T,K] expert ids; wts [T,K] combine weights;
    wg/wu/wd [n_local, ...] expert weights.  Returns [T,D] contribution of
    these experts (zeros for tokens routed elsewhere/dropped).
    """
    t, k = idx.shape
    d = x_flat.shape[1]
    tk = t * k
    eid = idx.reshape(tk)
    w_flat = wts.reshape(tk).astype(jnp.float32)
    tok = jnp.arange(tk, dtype=jnp.int32) // k

    order = jnp.argsort(eid, stable=True)          # shuffle's stable sort
    eid_s, tok_s, w_s = eid[order], tok[order], w_flat[order]
    rank = _segment_rank(eid_s)                    # capacity slot per token

    local = (eid_s >= e_lo) & (eid_s < e_lo + n_local)
    keep = local & (rank < cap)
    slot = jnp.where(keep, (eid_s - e_lo) * cap + rank,
                     jnp.int32(n_local * cap))     # OOB = drop

    buf = jnp.zeros((n_local * cap, d), out_dtype)
    buf = buf.at[slot].set(x_flat[tok_s].astype(out_dtype), mode="drop")
    h = jax.vmap(swiglu)(buf.reshape(n_local, cap, d), wg, wu, wd)
    h_flat = h.reshape(n_local * cap, d)

    vals = h_flat[jnp.minimum(slot, n_local * cap - 1)].astype(jnp.float32)
    vals = vals * (keep[:, None] * w_s[:, None])
    out = jnp.zeros((t, d), jnp.float32)
    out = out.at[tok_s].add(vals)
    return out


def moe_ffn_sorted(p, x, cfg: ModelConfig):
    """Single-device sorted dispatch (tested against moe_ffn_einsum)."""
    m = cfg.moe
    b, s, d = x.shape
    cap = _capacity(b * s, m)
    w, idx, aux = route(p, x, m)
    out = _dispatch_sorted(x.reshape(b * s, d), idx.reshape(b * s, m.top_k),
                           w.reshape(b * s, m.top_k), p["w_gate"],
                           p["w_up"], p["w_down"], cap, jnp.int32(0),
                           m.num_experts, cfg.jnp_dtype)
    out = out.reshape(b, s, d).astype(cfg.jnp_dtype)
    if m.num_shared:
        out = out + swiglu(x, p["shared_gate"], p["shared_up"],
                           p["shared_down"])
    return out, aux


def _shard_map_compat(f, mesh, in_specs, out_specs):
    """``jax.shard_map`` across jax versions (older jax: experimental API
    with ``check_rep`` instead of ``check_vma``)."""
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_vma=False)
    from jax.experimental.shard_map import shard_map as sm_exp
    return sm_exp(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_rep=False)


def moe_ffn_ep(p, x, cfg: ModelConfig, *, mesh, dp, model_axis: str):
    """Expert-parallel sorted dispatch under shard_map.

    Experts shard over ``model_axis``; activations shard over ``dp``
    (batch).  Routing is computed inside the shard_map block — x does not
    vary over the model axis, so every model shard derives identical
    routing without any metadata exchange.  Each shard packs + computes
    its local experts; the combine is one psum over the model axis.
    """
    m = cfg.moe
    b, s, d = x.shape
    esz = mesh.shape[model_axis]
    n_local = m.num_experts // esz

    def local_fn(xl, router, bias, wg, wu, wd):
        bl = xl.shape[0]
        t = bl * s
        cap = _capacity(t, m)
        # routing (replicated over model axis)
        pl = {"router": router}
        if bias is not None:
            pl["router_bias"] = bias
        w, idx, aux = route(pl, xl, m)
        j = jax.lax.axis_index(model_axis)
        e_lo = (j * n_local).astype(jnp.int32)
        out = _dispatch_sorted(xl.reshape(t, d), idx.reshape(t, m.top_k),
                               w.reshape(t, m.top_k), wg, wu, wd, cap,
                               e_lo, n_local, cfg.jnp_dtype)
        if COMBINE_DTYPE is not None:
            out = out.astype(COMBINE_DTYPE)
        out = jax.lax.psum(out.reshape(bl, s, d), model_axis)
        aux = jax.lax.pmean(aux, model_axis)
        return out.astype(cfg.jnp_dtype), aux

    xspec = P(dp, None, None)
    espec = P(model_axis, None, None)
    bias = p.get("router_bias")
    out, aux = _shard_map_compat(
        local_fn, mesh,
        (xspec, P(None, None), None if bias is None else P(None),
         espec, espec, espec),
        (xspec, P()),
    )(x, p["router"], bias, p["w_gate"], p["w_up"], p["w_down"])

    if m.num_shared:
        out = out + swiglu(x, p["shared_gate"], p["shared_up"],
                           p["shared_down"])
    return hint(out, "batch", "res_seq", "model_d"), aux
