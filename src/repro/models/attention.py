"""Attention flavors for the assigned architectures.

One module covers: GQA (llama/qwen/gemma), qk-norm (qwen3), QKV bias
(qwen1.5), sliding-window with per-layer theta (gemma3), M-RoPE (qwen2-vl),
MLA with low-rank q/kv and decoupled RoPE (deepseek), and cross-attention
(whisper decoder).

Prefill computes full causal attention; decode consumes a dense KV cache
(serving's *paged* cache lives in serving/kvcache.py and feeds the Pallas
decode kernel; the dense path here is the XLA-lowerable one the dry-run
compiles).

MLA decode uses the **absorbed** formulation: W_uk folds into the query and
W_uv into the output projection, so per-step attention works directly on
the cached latent (kv_lora + rope dims) — the cache stays low-rank, which
is the entire point of MLA, and the per-token FLOPs drop from
O(S·H·(d_nope+d_v)) expansions to O(S·(kv_lora+rope)).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import flash
from repro.models import rope as rp
from repro.models.common import ModelConfig, dense_init, rms_norm, zeros, ones
from repro.models.sharding import hint

NEG_INF = -2.3819763e38


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def gqa_init(key, cfg: ModelConfig, dtype):
    ks = jax.random.split(key, 8)
    d, qd, kvd = cfg.d_model, cfg.q_dim, cfg.kv_dim
    p = {
        "wq": dense_init(ks[0], d, qd, dtype),
        "wk": dense_init(ks[1], d, kvd, dtype),
        "wv": dense_init(ks[2], d, kvd, dtype),
        "wo": dense_init(ks[3], qd, d, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = zeros((qd,), dtype)
        p["bk"] = zeros((kvd,), dtype)
        p["bv"] = zeros((kvd,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = ones((cfg.head_dim,), dtype)
        p["k_norm"] = ones((cfg.head_dim,), dtype)
    return p


def mla_init(key, cfg: ModelConfig, dtype):
    m = cfg.mla
    ks = jax.random.split(key, 8)
    d, h = cfg.d_model, cfg.num_heads
    qk_head = m.qk_nope_head_dim + m.qk_rope_head_dim
    p = {}
    if m.q_lora_rank:
        p["wq_a"] = dense_init(ks[0], d, m.q_lora_rank, dtype)
        p["q_a_norm"] = ones((m.q_lora_rank,), dtype)
        p["wq_b"] = dense_init(ks[1], m.q_lora_rank, h * qk_head, dtype)
    else:
        p["wq"] = dense_init(ks[0], d, h * qk_head, dtype)
    p["wkv_a"] = dense_init(ks[2], d, m.kv_lora_rank + m.qk_rope_head_dim,
                            dtype)
    p["kv_a_norm"] = ones((m.kv_lora_rank,), dtype)
    p["wkv_b"] = dense_init(ks[3], m.kv_lora_rank,
                            h * (m.qk_nope_head_dim + m.v_head_dim), dtype)
    p["wo"] = dense_init(ks[4], h * m.v_head_dim, d, dtype)
    return p


def cross_init(key, cfg: ModelConfig, dtype):
    return gqa_init(key, cfg, dtype)


# ---------------------------------------------------------------------------
# masks
# ---------------------------------------------------------------------------

def causal_mask(q_pos, k_pos, window):
    """[..., Sq, Sk] bool; window (dynamic scalar or None) limits lookback."""
    m = q_pos[..., :, None] >= k_pos[..., None, :]
    if window is not None:
        m = m & (q_pos[..., :, None] - k_pos[..., None, :] < window)
    return m


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------

def _qk_headnorm(x, w, eps):
    return rms_norm(x, w, eps)


def gqa_project_qkv(p, x, cfg: ModelConfig, positions, theta,
                    mrope_positions=None, use_rope: bool = True):
    b, s, _ = x.shape
    h, hk, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = jnp.einsum("bsd,dq->bsq", x, p["wq"])
    k = jnp.einsum("bsd,dq->bsq", x, p["wk"])
    v = jnp.einsum("bsd,dq->bsq", x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, s, h, dh)
    k = k.reshape(b, s, hk, dh)
    v = v.reshape(b, s, hk, dh)
    if cfg.qk_norm:
        q = _qk_headnorm(q, p["q_norm"], cfg.norm_eps)
        k = _qk_headnorm(k, p["k_norm"], cfg.norm_eps)
    if not use_rope:
        return q, k, v
    if cfg.mrope_sections and mrope_positions is not None:
        q = rp.rotate_mrope(q, mrope_positions, theta, cfg.mrope_sections)
        k = rp.rotate_mrope(k, mrope_positions, theta, cfg.mrope_sections)
    else:
        q = rp.rotate(q, positions, theta)
        k = rp.rotate(k, positions, theta)
    return q, k, v


def gqa_core(q, k, v, mask, scale):
    """[B,Sq,H,D] x [B,Sk,Hkv,D] -> [B,Sq,H,D]; grouped heads.

    Operands stay in their storage dtype; the contractions accumulate in
    f32 via ``preferred_element_type`` (the MXU-native form).  Casting
    K/V to f32 first would materialize a full-cache f32 copy per decode
    layer — the dominant temp buffer at 32k decode before this change.
    """
    b, sq, h, dh = q.shape
    hk = k.shape[2]
    g = h // hk
    qg = q.reshape(b, sq, hk, g, dh)
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qg, k,
                        preferred_element_type=jnp.float32) * scale
    logits = jnp.where(mask[:, None, None], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", w.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, sq, h, dh).astype(q.dtype)


def gqa_prefill(p, x, cfg: ModelConfig, *, theta, window=None,
                mrope_positions=None, cross_kv=None, causal=True,
                use_rope: bool = True):
    b, s, _ = x.shape
    positions = jnp.arange(s, dtype=jnp.int32)[None, :]
    q, k, v = gqa_project_qkv(p, x, cfg, positions, theta,
                              mrope_positions, use_rope=use_rope)
    q = hint(q, "batch", "seq", "heads", None)
    scale = cfg.head_dim ** -0.5
    if cross_kv is not None:
        k, v = cross_kv                      # pre-projected encoder KV
        mask = jnp.ones((b, s, k.shape[1]), bool)
        out = gqa_core(q, k, v, mask, scale)
    elif causal and s >= flash.FLASH_THRESHOLD:
        # long prefill: chunked online-softmax (O(S*block) live memory)
        out = flash.flash_gqa(q, k, v, scale=scale, causal=True,
                              window=window)
    elif causal:
        mask = causal_mask(positions, positions, window)
        mask = jnp.broadcast_to(mask, (b, s, s))
        out = gqa_core(q, k, v, mask, scale)
    else:
        mask = jnp.ones((b, s, s), bool)
        out = gqa_core(q, k, v, mask, scale)
    out = jnp.einsum("bsq,qd->bsd", out.reshape(b, s, -1), p["wo"])
    return hint(out, "batch", "res_seq", "model_d"), (k, v)


def project_cross_kv(p, enc_out, cfg: ModelConfig):
    """Project encoder output to (k, v) once per utterance (whisper)."""
    b, s, _ = enc_out.shape
    hk, dh = cfg.num_kv_heads, cfg.head_dim
    k = jnp.einsum("bsd,dq->bsq", enc_out, p["wk"]).reshape(b, s, hk, dh)
    v = jnp.einsum("bsd,dq->bsq", enc_out, p["wv"]).reshape(b, s, hk, dh)
    if cfg.qkv_bias:
        k = k + p["bk"].reshape(hk, dh)
        v = v + p["bv"].reshape(hk, dh)
    return k, v


def gqa_decode(p, x, cfg: ModelConfig, cache, *, theta, window=None,
               use_rope: bool = True, cross_kv=None):
    """x: [B, 1, D]; cache: dict(k=[B,S,Hkv,Dh], v=..., length=[B]).

    With ``cross_kv`` the cache is ignored for K/V (whisper cross-attn:
    encoder KV is static) but ``length`` still drives positions.
    """
    b = x.shape[0]
    if cross_kv is not None:
        k, v = cross_kv
        q = jnp.einsum("bsd,dq->bsq", x, p["wq"])
        if cfg.qkv_bias:
            q = q + p["bq"]
        q = q.reshape(b, 1, cfg.num_heads, cfg.head_dim)
        mask = jnp.ones((b, 1, k.shape[1]), bool)
        out = gqa_core(q, k, v, mask, cfg.head_dim ** -0.5)
        out = jnp.einsum("bsq,qd->bsd", out.reshape(b, 1, -1), p["wo"])
        return out, cache
    positions = cache["length"][:, None]                  # [B, 1]
    q, k_new, v_new = gqa_project_qkv(p, x, cfg, positions, theta,
                                      use_rope=use_rope)
    at = cache["length"]                                   # [B]
    if window is not None and cache["k"].shape[1] <= window:
        # Ring-free sliding cache: shift-evict the oldest entry.  K was
        # roped at its absolute position when inserted, so eviction is a
        # pure memory move; absolute positions reconstruct from `length`.
        w = cache["k"].shape[1]
        k = jnp.concatenate([cache["k"][:, 1:], k_new], axis=1)
        v = jnp.concatenate([cache["v"][:, 1:], v_new], axis=1)
        kpos = at[:, None] - (w - 1) + jnp.arange(w, dtype=jnp.int32)[None]
        mask = (kpos >= 0) & (at[:, None] - kpos < window)
        mask = mask[:, None, :]
        out = gqa_core(q, k, v, mask, cfg.head_dim ** -0.5)
        out = jnp.einsum("bsq,qd->bsd", out.reshape(b, 1, -1), p["wo"])
        return out, {"k": k, "v": v, "length": cache["length"] + 1}
    smax = cache["k"].shape[1]
    z = jnp.int32(0)
    k = jax.vmap(lambda c, n, i: jax.lax.dynamic_update_slice(
        c, n, (i, z, z)))(cache["k"], k_new, at)
    v = jax.vmap(lambda c, n, i: jax.lax.dynamic_update_slice(
        c, n, (i, z, z)))(cache["v"], v_new, at)
    kpos = jnp.arange(smax, dtype=jnp.int32)[None, :]
    mask = kpos <= at[:, None]
    if window is not None:
        mask = mask & (at[:, None] - kpos < window)
    mask = mask[:, None, :]                                # [B, 1, S]
    out = gqa_core(q, k, v, mask, cfg.head_dim ** -0.5)
    out = jnp.einsum("bsq,qd->bsd", out.reshape(b, 1, -1), p["wo"])
    new_cache = {"k": k, "v": v, "length": cache["length"] + 1}
    return out, new_cache


# ---------------------------------------------------------------------------
# MLA (deepseek)
# ---------------------------------------------------------------------------

def _mla_q(p, x, cfg, positions):
    m = cfg.mla
    b, s, _ = x.shape
    h = cfg.num_heads
    qk_head = m.qk_nope_head_dim + m.qk_rope_head_dim
    if m.q_lora_rank:
        cq = rms_norm(jnp.einsum("bsd,dr->bsr", x, p["wq_a"]),
                      p["q_a_norm"], cfg.norm_eps)
        q = jnp.einsum("bsr,rq->bsq", cq, p["wq_b"])
    else:
        q = jnp.einsum("bsd,dq->bsq", x, p["wq"])
    q = q.reshape(b, s, h, qk_head)
    q_nope = q[..., :m.qk_nope_head_dim]
    q_rope = rp.rotate(q[..., m.qk_nope_head_dim:], positions,
                       cfg.rope_theta)
    return q_nope, q_rope


def mla_latent(p, x, cfg: ModelConfig, positions):
    """Compute the cached latent: c_kv [B,S,R], k_rope [B,S,Dr]."""
    m = cfg.mla
    kv = jnp.einsum("bsd,dr->bsr", x, p["wkv_a"])
    c_kv = rms_norm(kv[..., :m.kv_lora_rank], p["kv_a_norm"], cfg.norm_eps)
    k_rope = rp.rotate(kv[..., None, m.kv_lora_rank:], positions,
                       cfg.rope_theta)[..., 0, :]
    return c_kv, k_rope


def mla_prefill(p, x, cfg: ModelConfig):
    """Non-absorbed prefill (DeepSeek's own choice for the compute-bound
    phase): expand K/V from the latent, run (flash) attention at head_dim
    (e + r) = 192 — cheaper in the quadratic term than the absorbed form
    (rl + r = 576).  Decode uses the absorbed latent form (mla_decode)."""
    m = cfg.mla
    b, s, _ = x.shape
    h = cfg.num_heads
    positions = jnp.arange(s, dtype=jnp.int32)[None, :]
    q_nope, q_rope = _mla_q(p, x, cfg, positions)
    c_kv, k_rope = mla_latent(p, x, cfg, positions)
    kvb = p["wkv_b"].reshape(m.kv_lora_rank, h,
                             m.qk_nope_head_dim + m.v_head_dim)
    k_nope = jnp.einsum("bsr,rhe->bshe", c_kv, kvb[..., :m.qk_nope_head_dim])
    v = jnp.einsum("bsr,rhe->bshe", c_kv, kvb[..., m.qk_nope_head_dim:])
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    if s >= flash.FLASH_THRESHOLD:
        q_full = jnp.concatenate([q_nope, q_rope], axis=-1)   # [B,S,H,E+R]
        k_full = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                      (b, s, h, m.qk_rope_head_dim))],
            axis=-1)
        out = flash.flash_gqa(q_full, k_full, v, scale=scale, causal=True)
        out = out.astype(jnp.float32)
    else:
        logits = (jnp.einsum("bqhe,bshe->bhqs", q_nope.astype(jnp.float32),
                             k_nope.astype(jnp.float32))
                  + jnp.einsum("bqhe,bse->bhqs", q_rope.astype(jnp.float32),
                               k_rope.astype(jnp.float32))) * scale
        mask = causal_mask(positions, positions, None)
        logits = jnp.where(mask[:, None], logits, NEG_INF)
        w = jax.nn.softmax(logits, axis=-1)
        out = jnp.einsum("bhqs,bshe->bqhe", w, v.astype(jnp.float32))
    out = out.reshape(b, s, h * m.v_head_dim).astype(x.dtype)
    out = jnp.einsum("bsv,vd->bsd", out, p["wo"])
    return hint(out, "batch", "res_seq", "model_d"), (c_kv, k_rope)


def mla_decode(p, x, cfg: ModelConfig, cache):
    """Absorbed decode over the latent cache.

    cache: dict(c_kv=[B,S,R], k_rope=[B,S,Dr], length=[B]).
    """
    m = cfg.mla
    b = x.shape[0]
    h = cfg.num_heads
    positions = cache["length"][:, None]
    q_nope, q_rope = _mla_q(p, x, cfg, positions)          # [B,1,H,*]
    c_new, kr_new = mla_latent(p, x, cfg, positions)
    at = cache["length"]
    z = jnp.int32(0)
    c_kv = jax.vmap(lambda c, n, i: jax.lax.dynamic_update_slice(
        c, n, (i, z)))(cache["c_kv"], c_new, at)
    k_rope = jax.vmap(lambda c, n, i: jax.lax.dynamic_update_slice(
        c, n, (i, z)))(cache["k_rope"], kr_new, at)

    kvb = p["wkv_b"].reshape(m.kv_lora_rank, h,
                             m.qk_nope_head_dim + m.v_head_dim)
    w_uk = kvb[..., :m.qk_nope_head_dim]                   # [R, H, E]
    w_uv = kvb[..., m.qk_nope_head_dim:]                   # [R, H, V]
    # absorb W_uk into q: q_lat [B,1,H,R].  The latent cache stays in its
    # storage dtype — contractions accumulate f32 via
    # preferred_element_type (no full-cache f32 copies at 32k decode).
    q_lat = jnp.einsum("bqhe,rhe->bqhr", q_nope, w_uk,
                       preferred_element_type=jnp.float32)
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    logits = (jnp.einsum("bqhr,bsr->bhqs", q_lat.astype(c_kv.dtype),
                         c_kv, preferred_element_type=jnp.float32)
              + jnp.einsum("bqhe,bse->bhqs", q_rope.astype(k_rope.dtype),
                           k_rope,
                           preferred_element_type=jnp.float32)) * scale
    smax = c_kv.shape[1]
    kpos = jnp.arange(smax, dtype=jnp.int32)[None, :]
    mask = (kpos <= at[:, None])[:, None, None, :]
    logits = jnp.where(mask, logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1)
    o_lat = jnp.einsum("bhqs,bsr->bqhr", w.astype(c_kv.dtype), c_kv,
                       preferred_element_type=jnp.float32)
    out = jnp.einsum("bqhr,rhv->bqhv", o_lat.astype(w_uv.dtype), w_uv,
                     preferred_element_type=jnp.float32)
    out = out.reshape(b, 1, h * m.v_head_dim).astype(x.dtype)
    out = jnp.einsum("bsv,vd->bsd", out, p["wo"])
    new_cache = {"c_kv": c_kv, "k_rope": k_rope,
                 "length": cache["length"] + 1}
    return out, new_cache
