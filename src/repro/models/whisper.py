"""Whisper-style encoder-decoder (audio family).

The conv mel frontend is a STUB per the assignment: ``input_specs()``
supplies precomputed post-conv frame embeddings [B, frames, D] (whisper
large-v3: 1500 frames).  Encoder = non-causal self-attention + GELU MLP
with LayerNorm(+bias); decoder = causal self-attn + cross-attn over the
encoder output + GELU MLP; learned decoder positions; tied lm head — all
faithful to the original architecture.

Serving note (DESIGN.md §5): the cross-attention KV is computed once per
utterance and cached; the serving layer stores it in the indexed cache
keyed by utterance id — a literal point-lookup workload.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import rope as rp
from repro.models.common import (ModelConfig, cross_entropy, dense_init,
                                 embed_init, gelu_mlp, layer_norm, ones,
                                 zeros)
from repro.models.sharding import hint


def _ln_init(d, dtype):
    return {"w": ones((d,), dtype), "b": zeros((d,), dtype)}


def _enc_layer_init(key, cfg, dtype):
    ks = jax.random.split(key, 4)
    d, f = cfg.d_model, cfg.d_ff
    return {
        "ln1": _ln_init(d, dtype), "ln2": _ln_init(d, dtype),
        "attn": attn.gqa_init(ks[0], cfg, dtype),
        "mlp": {"w_in": dense_init(ks[1], d, f, dtype),
                "b_in": zeros((f,), dtype),
                "w_out": dense_init(ks[2], f, d, dtype),
                "b_out": zeros((d,), dtype)},
    }


def _dec_layer_init(key, cfg, dtype):
    ks = jax.random.split(key, 5)
    p = _enc_layer_init(ks[0], cfg, dtype)
    p["ln_x"] = _ln_init(cfg.d_model, dtype)
    p["cross"] = attn.cross_init(ks[1], cfg, dtype)
    return p


def init_params(cfg: ModelConfig, key):
    dtype = cfg.jnp_dtype
    ks = jax.random.split(key, 6)
    enc_keys = jax.random.split(ks[0], cfg.encoder_layers)
    dec_keys = jax.random.split(ks[1], cfg.num_layers)
    return {
        "embed": embed_init(ks[2], cfg.vocab_size, cfg.d_model, dtype),
        "pos_dec": embed_init(ks[3], cfg.max_pos, cfg.d_model, dtype),
        "enc_ln_post": _ln_init(cfg.d_model, dtype),
        "dec_ln_post": _ln_init(cfg.d_model, dtype),
        "enc": jax.vmap(lambda k: _enc_layer_init(k, cfg, dtype))(enc_keys),
        "dec": jax.vmap(lambda k: _dec_layer_init(k, cfg, dtype))(dec_keys),
    }


def _enc_block(pl, x, cfg):
    h = layer_norm(x, pl["ln1"]["w"], pl["ln1"]["b"])
    a, _ = attn.gqa_prefill(pl["attn"], h, cfg, theta=cfg.rope_theta,
                            causal=False, use_rope=False)
    x = x + a
    h = layer_norm(x, pl["ln2"]["w"], pl["ln2"]["b"])
    return x + gelu_mlp(h, pl["mlp"]["w_in"], pl["mlp"]["b_in"],
                        pl["mlp"]["w_out"], pl["mlp"]["b_out"])


def encode(params, cfg: ModelConfig, frames):
    """frames: [B, T, D] post-conv features (stub frontend output)."""
    x = frames.astype(cfg.jnp_dtype) \
        + rp.sinusoidal_positions(frames.shape[1],
                                  cfg.d_model).astype(cfg.jnp_dtype)
    x = hint(x, "batch", "seq", "model_d")

    def body(carry, pl):
        return _enc_block(pl, carry, cfg), None

    x, _ = jax.lax.scan(body, x, params["enc"])
    return layer_norm(x, params["enc_ln_post"]["w"],
                      params["enc_ln_post"]["b"])


def _dec_block(pl, x, cfg, cross_kv):
    h = layer_norm(x, pl["ln1"]["w"], pl["ln1"]["b"])
    a, kv = attn.gqa_prefill(pl["attn"], h, cfg, theta=cfg.rope_theta,
                             causal=True, use_rope=False)
    x = x + a
    h = layer_norm(x, pl["ln_x"]["w"], pl["ln_x"]["b"])
    c, _ = attn.gqa_prefill(pl["cross"], h, cfg, theta=cfg.rope_theta,
                            cross_kv=cross_kv, use_rope=False)
    x = x + c
    h = layer_norm(x, pl["ln2"]["w"], pl["ln2"]["b"])
    x = x + gelu_mlp(h, pl["mlp"]["w_in"], pl["mlp"]["b_in"],
                     pl["mlp"]["w_out"], pl["mlp"]["b_out"])
    return x, kv


def forward_train(params, cfg: ModelConfig, frames, tokens, *,
                  loss_mask=None, remat: str = "dots"):
    enc_out = encode(params, cfg, frames)

    x = params["embed"][tokens].astype(cfg.jnp_dtype)
    x = x + params["pos_dec"][:tokens.shape[1]].astype(cfg.jnp_dtype)

    def body(carry, pl):
        cross_kv = attn.project_cross_kv(pl["cross"], enc_out, cfg)
        y, _ = _dec_block(pl, carry, cfg, cross_kv)
        return y, None

    if remat != "none":
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["dec"])
    x = layer_norm(x, params["dec_ln_post"]["w"], params["dec_ln_post"]["b"])
    logits = jnp.einsum("bsd,vd->bsv", x, params["embed"])
    logits = hint(logits, "batch", "seq", "vocab")
    mask = loss_mask[:, 1:] if loss_mask is not None else None
    loss = cross_entropy(logits[:, :-1], tokens[:, 1:], mask=mask)
    return loss, {"loss": loss, "lm_loss": loss}


def prefill(params, cfg: ModelConfig, frames, tokens):
    """Inference prefill: encode once, teacher-forced decoder pass.
    Returns logits at the last position (the decode caches mirror the
    self-attn KV computed here; dry-run lowers this compute shape)."""
    enc_out = encode(params, cfg, frames)
    x = params["embed"][tokens].astype(cfg.jnp_dtype)
    x = x + params["pos_dec"][:tokens.shape[1]].astype(cfg.jnp_dtype)

    def body(carry, pl):
        cross_kv = attn.project_cross_kv(pl["cross"], enc_out, cfg)
        y, kv = _dec_block(pl, carry, cfg, cross_kv)
        return y, kv

    x, kvs = jax.lax.scan(body, x, params["dec"])
    x = layer_norm(x, params["dec_ln_post"]["w"], params["dec_ln_post"]["b"])
    logits = jnp.einsum("bsd,vd->bsv", x[:, -1:], params["embed"])
    return logits, kvs


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None):
    dtype = dtype or cfg.jnp_dtype
    n = cfg.num_layers
    return {
        "k": jnp.zeros((n, batch, max_len, cfg.num_kv_heads, cfg.head_dim),
                       dtype),
        "v": jnp.zeros((n, batch, max_len, cfg.num_kv_heads, cfg.head_dim),
                       dtype),
        "length": jnp.zeros((n, batch), jnp.int32),
        # cross-attn KV: computed once per utterance, then point-looked-up
        "cross_k": jnp.zeros((n, batch, cfg.encoder_seq, cfg.num_kv_heads,
                              cfg.head_dim), dtype),
        "cross_v": jnp.zeros((n, batch, cfg.encoder_seq, cfg.num_kv_heads,
                              cfg.head_dim), dtype),
    }


def build_cross_cache(params, cfg: ModelConfig, enc_out):
    ks, vs = [], []

    def body(_, pl):
        k, v = attn.project_cross_kv(pl["cross"], enc_out, cfg)
        return None, (k, v)

    _, (k, v) = jax.lax.scan(body, None, params["dec"])
    return k, v


def decode_step(params, cfg: ModelConfig, last_tok, cache):
    """last_tok [B,1]; cache from init_cache with cross_k/v filled."""
    x = params["embed"][last_tok].astype(cfg.jnp_dtype)
    pos = cache["length"][0]                               # [B]
    x = x + params["pos_dec"][pos][:, None, :].astype(cfg.jnp_dtype)

    def body(carry, inp):
        pl, k, v, ck, cv, ln = inp
        self_cache = {"k": k, "v": v, "length": ln}
        h = layer_norm(carry, pl["ln1"]["w"], pl["ln1"]["b"])
        a, self_cache = attn.gqa_decode(pl["attn"], h, cfg, self_cache,
                                        theta=cfg.rope_theta,
                                        use_rope=False)
        y = carry + a
        h = layer_norm(y, pl["ln_x"]["w"], pl["ln_x"]["b"])
        c, _ = attn.gqa_decode(pl["cross"], h, cfg, self_cache,
                               theta=cfg.rope_theta, cross_kv=(ck, cv))
        y = y + c
        h = layer_norm(y, pl["ln2"]["w"], pl["ln2"]["b"])
        y = y + gelu_mlp(h, pl["mlp"]["w_in"], pl["mlp"]["b_in"],
                         pl["mlp"]["w_out"], pl["mlp"]["b_out"])
        return y, (self_cache["k"], self_cache["v"], self_cache["length"])

    x, (k, v, ln) = jax.lax.scan(
        body, x, (params["dec"], cache["k"], cache["v"],
                  cache["cross_k"], cache["cross_v"], cache["length"]))
    x = layer_norm(x, params["dec_ln_post"]["w"], params["dec_ln_post"]["b"])
    logits = jnp.einsum("bsd,vd->bsv", x, params["embed"])
    new_cache = dict(cache, k=k, v=v, length=ln)
    return logits, new_cache
