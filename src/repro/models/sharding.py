"""Logical-axis sharding hints for model code.

Model code annotates activations with *logical* axes ("batch", "seq",
"model_d", "heads", "experts", ...).  The launcher installs a mapping from
logical axes to mesh axes; outside a mesh context the hints are no-ops, so
the same model code runs in CPU tests, smoke configs, and the 512-chip
dry-run.

The hillclimbing loop (EXPERIMENTS.md §Perf) works by swapping rule sets —
e.g. moving "seq" from unsharded to the data axis turns on sequence
parallelism without touching model code.
"""

from __future__ import annotations

import contextlib
import threading

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

_state = threading.local()


def _rules() -> dict | None:
    return getattr(_state, "rules", None)


def _mesh():
    return getattr(_state, "mesh", None)


@contextlib.contextmanager
def logical_sharding(mesh, rules: dict):
    """rules: logical axis name -> mesh axis (str | tuple | None)."""
    old = (_mesh(), _rules())
    _state.mesh, _state.rules = mesh, rules
    try:
        yield
    finally:
        _state.mesh, _state.rules = old


def hint(x, *logical_axes):
    """Constrain ``x`` (rank must equal len(logical_axes); None = any)."""
    mesh, rules = _mesh(), _rules()
    if mesh is None or rules is None:
        return x
    spec = P(*[rules.get(a) if a is not None else None
               for a in logical_axes])
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def spec_for(*logical_axes) -> P:
    rules = _rules() or {}
    return P(*[rules.get(a) if a is not None else None
               for a in logical_axes])


# Default rule sets ----------------------------------------------------------
#
# "res_seq" is the *residual-stream* sequence axis (block inputs/outputs).
# It is distinct from "seq" (attention-internal / logits) so Megatron-style
# sequence parallelism can be switched on by mapping res_seq -> "model"
# without touching attention math: GSPMD then lowers the TP all-reduce after
# wo / w_down into reduce-scatter + all-gather pairs (half the wire bytes,
# and norms/elementwise run on S/model_size tokens).

def rules_single_pod() -> dict:
    return {
        "batch": "data", "seq": None, "res_seq": None, "model_d": None,
        "heads": "model", "kv_heads": "model", "ff": "model",
        "vocab": "model", "experts": "model", "expert_cap": None,
        "state": "model",
    }


def rules_multi_pod() -> dict:
        return {
            "batch": ("pod", "data"), "seq": None, "res_seq": None,
            "model_d": None, "heads": "model", "kv_heads": "model",
            "ff": "model", "vocab": "model", "experts": "model",
            "expert_cap": None, "state": "model",
        }


def rules_seq_parallel(base: dict) -> dict:
    """Sequence parallelism over data: shard the sequence axis when batch
    cannot be sharded (long-context, batch=1)."""
    out = dict(base)
    out["seq"] = "data"
    out["res_seq"] = "data"
    out["batch"] = None
    return out


def rules_megatron_sp(base: dict) -> dict:
    """Megatron SP: residual stream sharded over the model axis between
    blocks (reduce-scatter/all-gather instead of all-reduce)."""
    out = dict(base)
    out["res_seq"] = "model"
    return out


def rules_pure_dp(multi_pod: bool = False) -> dict:
    """Small-model policy: no tensor parallelism — every axis of the mesh
    is data parallel (params replicated, batch over all axes)."""
    batch = ("pod", "data", "model") if multi_pod else ("data", "model")
    return {
        "batch": batch, "seq": None, "res_seq": None, "model_d": None,
        "heads": None, "kv_heads": None, "ff": None, "vocab": None,
        "experts": None, "expert_cap": None, "state": None,
    }
