"""Chunked online-softmax attention (pure-JAX flash) for long prefill.

Materializing causal logits at 32k tokens is [B,H,S,S] f32 — petabytes at
the assigned shapes — so prefill attention streams KV in blocks with the
standard flash recurrence (running max / running sum / rescaled
accumulator), carried by a ``lax.scan``.  Peak live memory drops from
O(S^2) to O(S * block_k) per head group.

The math is exact (tests assert allclose vs the dense core).  GQA grouping
is handled inside; the sliding-window mask composes with causal.

This is the XLA-lowerable path the dry-run compiles.  On a real TPU the
same contract would dispatch to a fused Pallas flash kernel; the Pallas
decode kernel (kernels/decode_attention.py) already implements the decode
side of that contract over the indexed cache's pages.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

NEG_INF = -2.3819763e38

# prefill sequences at or above this length take the flash path
FLASH_THRESHOLD = 2048
DEFAULT_BLOCK_K = 1024


def flash_gqa(q, k, v, *, scale, causal=True, window=None,
              block_k: int = DEFAULT_BLOCK_K):
    """q [B,Sq,H,Dq]; k [B,Sk,Hkv,Dq]; v [B,Sk,Hkv,Dv] -> [B,Sq,H,Dv].

    Assumes q position i attends to k positions <= i (prefill: Sq == Sk and
    aligned).  ``window`` limits lookback (exclusive of positions further
    than window-1 back).
    """
    b, sq, h, dq = q.shape
    sk, hk = k.shape[1], k.shape[2]
    dv = v.shape[3]
    g = h // hk
    nb = -(-sk // block_k)
    pad = nb * block_k - sk

    # K/V stay in storage dtype (no full-sequence f32 copies); the block
    # contractions accumulate in f32 via preferred_element_type, and P is
    # cast to the KV dtype for the PV matmul — the standard TPU-flash
    # bf16-MXU/f32-accumulator recipe.
    qf = q.reshape(b, sq, hk, g, dq)
    kf = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
    vf = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kb = jnp.moveaxis(kf.reshape(b, nb, block_k, hk, dq), 1, 0)
    vb = jnp.moveaxis(vf.reshape(b, nb, block_k, hk, dv), 1, 0)

    q_pos = jnp.arange(sq, dtype=jnp.int32)

    def body(carry, inp):
        m, l, acc = carry                     # [b,hk,g,sq], same, [...,dv]
        kblk, vblk, jb = inp                  # [b,bk,hk,d], [b,bk,hk,dv], []
        k_pos = jb * block_k + jnp.arange(block_k, dtype=jnp.int32)
        logits = jnp.einsum("bqkgd,bskd->bkgqs", qf, kblk,
                            preferred_element_type=jnp.float32) * scale
        mask = k_pos[None, :] <= q_pos[:, None] if causal else \
            jnp.ones((sq, block_k), bool)
        mask = mask & (k_pos[None, :] < sk)
        if window is not None:
            mask = mask & (q_pos[:, None] - k_pos[None, :] < window)
        logits = jnp.where(mask[None, None, None], logits, NEG_INF)
        m_new = jnp.maximum(m, logits.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(logits - m_new[..., None])
        l_new = l * alpha + p.sum(axis=-1)
        pv = jnp.einsum("bkgqs,bskd->bkgqd", p.astype(vblk.dtype), vblk,
                        preferred_element_type=jnp.float32)
        acc_new = acc * alpha[..., None] + pv
        return (m_new, l_new, acc_new), None

    init = (jnp.full((b, hk, g, sq), NEG_INF, jnp.float32),
            jnp.zeros((b, hk, g, sq), jnp.float32),
            jnp.zeros((b, hk, g, sq, dv), jnp.float32))
    (m, l, acc), _ = jax.lax.scan(
        body, init, (kb, vb, jnp.arange(nb, dtype=jnp.int32)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]          # [b,hk,g,sq,dv]
    out = jnp.moveaxis(out, 3, 1).reshape(b, sq, h, dv)
    return out.astype(q.dtype)


def flash_mla(q_nope, q_rope, c_kv, k_rope, w_uk, w_uv, *, scale,
              block_k: int = DEFAULT_BLOCK_K):
    """Latent-space flash for MLA prefill (absorbed formulation).

    q_nope [B,S,H,E]; q_rope [B,S,H,R]; c_kv [B,S,Rl]; k_rope [B,S,R];
    w_uk [Rl,H,E]; w_uv [Rl,H,V].  Attention runs against the *latent*
    cache (q_nope absorbed through W_uk), so the streamed KV block is the
    low-rank latent — the whole point of MLA, kept intact under flash.
    Returns [B,S,H,V] (pre-W_o).
    """
    b, s, h, e = q_nope.shape
    rl = c_kv.shape[-1]
    v_dim = w_uv.shape[-1]
    nb = -(-s // block_k)
    pad = nb * block_k - s

    q_lat = jnp.einsum("bqhe,rhe->bqhr", q_nope.astype(jnp.float32),
                       w_uk.astype(jnp.float32))            # [B,S,H,Rl]
    qr = q_rope.astype(jnp.float32)
    ckv = jnp.pad(c_kv.astype(jnp.float32), ((0, 0), (0, pad), (0, 0)))
    kr = jnp.pad(k_rope.astype(jnp.float32), ((0, 0), (0, pad), (0, 0)))
    ckv_b = jnp.moveaxis(ckv.reshape(b, nb, block_k, rl), 1, 0)
    kr_b = jnp.moveaxis(kr.reshape(b, nb, block_k, -1), 1, 0)

    q_pos = jnp.arange(s, dtype=jnp.int32)

    def body(carry, inp):
        m, l, acc = carry                      # [b,h,s], [b,h,s], [b,h,s,Rl]
        cblk, rblk, jb = inp
        k_pos = jb * block_k + jnp.arange(block_k, dtype=jnp.int32)
        logits = (jnp.einsum("bqhr,bsr->bhqs", q_lat, cblk)
                  + jnp.einsum("bqhr,bsr->bhqs", qr, rblk)) * scale
        mask = (k_pos[None, :] <= q_pos[:, None]) & (k_pos[None, :] < s)
        logits = jnp.where(mask[None, None], logits, NEG_INF)
        m_new = jnp.maximum(m, logits.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(logits - m_new[..., None])
        l_new = l * alpha + p.sum(axis=-1)
        pc = jnp.einsum("bhqs,bsr->bhqr", p, cblk)          # latent accum
        acc_new = acc * alpha[..., None] + pc
        return (m_new, l_new, acc_new), None

    init = (jnp.full((b, h, s), NEG_INF, jnp.float32),
            jnp.zeros((b, h, s), jnp.float32),
            jnp.zeros((b, h, s, rl), jnp.float32))
    (m, l, acc), _ = jax.lax.scan(
        body, init, (ckv_b, kr_b, jnp.arange(nb, dtype=jnp.int32)))
    o_lat = acc / jnp.maximum(l, 1e-30)[..., None]          # [b,h,s,Rl]
    out = jnp.einsum("bhqr,rhv->bqhv", o_lat, w_uv.astype(jnp.float32))
    return out
