"""jit'd public wrappers around the Pallas kernels.

``probe`` is a drop-in accelerated replacement for
``repro.core.hashindex.probe`` — same signature, same results (tests sweep
both).  The wrapper owns everything that does not belong in the vector
kernel: bucket-id hashing (64-bit scalar math), int64 -> (hi, lo) plane
splitting, tile padding, and EMPTY-key masking.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import hashing
from repro.core.hashindex import EMPTY_KEY, HashIndex
from repro.core.pointers import NULL_PTR
from repro.kernels import hash_probe
from repro.kernels import decode_attention as _da


def _split64(x):
    bits = jax.lax.bitcast_convert_type(jnp.asarray(x, jnp.int64), jnp.uint64)
    lo = jax.lax.bitcast_convert_type(
        (bits & jnp.uint64(0xFFFFFFFF)).astype(jnp.uint32), jnp.int32)
    hi = jax.lax.bitcast_convert_type(
        (bits >> jnp.uint64(32)).astype(jnp.uint32), jnp.int32)
    return hi, lo


def probe(index: HashIndex, query_keys, *, interpret: bool = True):
    """Latest row id per query key — Pallas-accelerated probe."""
    q = jnp.asarray(query_keys, jnp.int64)
    nq = q.shape[0]
    tile = hash_probe.QUERY_TILE
    pad = (-nq) % tile
    qp = jnp.pad(q, (0, pad), constant_values=int(EMPTY_KEY))

    bids = hashing.bucket_hash(qp, index.num_buckets)
    qhi, qlo = _split64(qp)
    khi, klo = _split64(index.bucket_keys)

    out = hash_probe.probe_tiles(bids, qhi, qlo, khi, klo,
                                 index.bucket_ptrs, interpret=interpret)
    out = out[:nq]
    # EMPTY query keys can never match (EMPTY slots hold NULL ptrs), but be
    # explicit for defense in depth:
    return jnp.where(q == EMPTY_KEY, NULL_PTR, out)


def decode_attention(q, k_pages, v_pages, page_table, lengths, scale, *,
                     interpret: bool = True):
    """Paged GQA flash decode attention (serving hot path)."""
    return _da.decode_paged(q, k_pages, v_pages, page_table, lengths, scale,
                            interpret=interpret)
