"""jit'd public wrappers around the Pallas kernels.

``probe`` is a drop-in accelerated replacement for
``repro.core.hashindex.probe`` — same signature, same results (tests sweep
both).  ``fused_lookup`` is the multi-segment hot path: probe + in-kernel
chain walk over a table's stored Snapshot (DESIGN.md §3).  The wrappers own
everything that does not belong in the vector kernel: bucket-id hashing
(64-bit scalar math), int64 -> (hi, lo) plane splitting, tile padding, and
EMPTY-key masking.

Backend dispatch: ``interpret=None`` resolves per jax backend (kernel
compiled on TPU, interpret elsewhere).  For the fused path on non-TPU
backends the Pallas interpreter's per-query scalar loops are pure overhead,
so the dispatcher runs the *vectorized* flat oracle (ref.fused_lookup_ref —
bit-identical contract, swept against the kernel in tests) unless
``use_kernel=True`` forces the kernel.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import hashing, snapshot
from repro.core.hashindex import EMPTY_KEY, HashIndex
from repro.core.pointers import NULL_PTR
from repro.kernels import hash_probe, ref, runtime
from repro.kernels import decode_attention as _da

_split64 = hashing.split64  # kept under the old name for external callers


def probe(index: HashIndex, query_keys, *, interpret: bool | None = None):
    """Latest row id per query key — Pallas-accelerated probe."""
    q = jnp.asarray(query_keys, jnp.int64)
    nq = q.shape[0]
    tile = hash_probe.QUERY_TILE
    pad = (-nq) % tile
    qp = jnp.pad(q, (0, pad), constant_values=int(EMPTY_KEY))

    bids = hashing.bucket_hash(qp, index.num_buckets)
    qhi, qlo = hashing.split64(qp)
    khi, klo = hashing.split64(index.bucket_keys)

    out = hash_probe.probe_tiles(bids, qhi, qlo, khi, klo,
                                 index.bucket_ptrs, interpret=interpret)
    out = out[:nq]
    # EMPTY query keys can never match (EMPTY slots hold NULL ptrs), but be
    # explicit for defense in depth:
    return jnp.where(q == EMPTY_KEY, NULL_PTR, out)


# ---------------------------------------------------------------------------
# Fused multi-segment lookup (probe -> chain walk) over a stored Snapshot
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("max_matches",))
def _fused_ref_jit(qp, snap, *, max_matches):
    bids = jnp.stack([hashing.bucket_hash(qp, nb)
                      for nb in snap.bucket_counts])
    qhi, qlo = hashing.split64(qp)
    return ref.fused_lookup_ref(bids, qhi, qlo, snap, max_matches)


@functools.partial(jax.jit, static_argnames=("max_matches", "interpret"))
def _fused_kernel_jit(q, snap, *, max_matches, interpret):
    """Kernel-branch prep (pad, hash, split) fused into one jitted program
    so a direct fused_lookup call dispatches once, not per prep op."""
    pad = (-q.shape[0]) % hash_probe.QUERY_TILE
    qp = jnp.pad(q, (0, pad), constant_values=int(EMPTY_KEY))
    bids = jnp.stack([hashing.bucket_hash(qp, nb)
                      for nb in snap.bucket_counts])
    qhi, qlo = hashing.split64(qp)
    rows, last = hash_probe.fused_lookup_tiles(
        bids, qhi, qlo, snap, max_matches=max_matches, interpret=interpret)
    return rows[:q.shape[0]], last[:q.shape[0]]


def fused_lookup(query_keys, snap, *, max_matches: int,
                 interpret: bool | None = None,
                 use_kernel: bool | None = None):
    """[Q] keys against a table's Snapshot -> ([Q, M] rows, truncated).

    query_keys : [Q] int64
    snap       : core.snapshot.Snapshot — ragged per-segment (hi, lo, ptrs)
                 bucket planes, per-segment bucket counts (treedef meta;
                 each segment's bucket ids are computed modulo its own
                 count), and the flat [capacity] int32 backward-pointer
                 array.  A registered pytree: under jit/vmap the arrays
                 trace as leaves (zero in-graph view rebuilds) and the
                 same code runs per-shard in the distributed layer.
    Returns rows [Q, max_matches] global row ids newest-first (NULL-padded)
    and truncated [Q] bool — identical contract to IndexedTable.lookup_ref.

    The probe path never reads row data, so the snapshot's optional
    ``data`` is stripped before entering the jitted cores: lookup compile
    caches are independent of when a table materializes its flat data.

    ``use_kernel=True`` with ``interpret=True`` is a parity-test/debug
    combination: emulating the unrolled per-segment loop is slow to trace
    beyond ~8 segments.  Production paths never hit it — the dispatcher
    picks the compiled kernel on TPU and the vectorized oracle elsewhere.
    """
    q = jnp.asarray(query_keys, jnp.int64)
    snap = snapshot.strip_data(snap)
    if use_kernel is None:
        use_kernel = not runtime.resolve_interpret(interpret)

    if use_kernel:
        rows, last = _fused_kernel_jit(
            q, snap, max_matches=max_matches,
            interpret=runtime.resolve_interpret(interpret))
    else:
        rows, last = _fused_ref_jit(q, snap, max_matches=max_matches)

    # EMPTY query keys never match (EMPTY slots hold NULL ptrs) — explicit
    # mask for defense in depth, mirroring probe():
    empty = (q == EMPTY_KEY)[:, None]
    rows = jnp.where(empty, NULL_PTR, rows)
    truncated = jnp.where(empty[:, 0], False, last >= 0)
    return rows, truncated


def fused_probe(query_keys, snap, *, interpret: bool | None = None,
                use_kernel: bool | None = None):
    """Head (latest) row id per key over a Snapshot's planes. [Q] int32."""
    # A one-step fused lookup: rows[:, 0] is the head pointer.
    rows, _ = fused_lookup(query_keys, snap, max_matches=1,
                           interpret=interpret, use_kernel=use_kernel)
    return rows[:, 0]


def decode_attention(q, k_pages, v_pages, page_table, lengths, scale, *,
                     interpret: bool | None = None):
    """Paged GQA flash decode attention (serving hot path)."""
    return _da.decode_paged(q, k_pages, v_pages, page_table, lengths, scale,
                            interpret=interpret)
