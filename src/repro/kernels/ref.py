"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the *semantic contract*; kernel tests sweep shapes/dtypes
and assert_allclose against these.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

NULL = jnp.int32(-1)


def probe_ref(bucket_ids, q_hi, q_lo, keys_hi, keys_lo, ptrs):
    """Oracle for hash_probe.probe_tiles: one [Q, S] gather + compare."""
    row_hi = keys_hi[bucket_ids]           # [Q, S]
    row_lo = keys_lo[bucket_ids]
    row_ptr = ptrs[bucket_ids]
    match = (row_hi == q_hi[:, None]) & (row_lo == q_lo[:, None])
    return jnp.max(jnp.where(match, row_ptr, NULL), axis=1)


def fused_probe_ref(bucket_ids, q_hi, q_lo, snapshot):
    """Oracle for the probe stage of hash_probe.fused_lookup_tiles.

    bucket_ids [S, Q]; ``snapshot`` is a core.snapshot.Snapshot whose
    per-segment (hi, lo, ptrs) planes are each [nb_s, slots] (ragged).
    One [Q, slots] gather + compare per segment, then a first-non-NULL
    select newest -> oldest.  This IS the vectorized flat lookup — on
    non-TPU backends ops.fused_lookup runs it directly instead of
    emulating the Pallas kernel (DESIGN.md §3).
    """
    cands = []
    for s, (hi, lo, ptr) in enumerate(snapshot.key_planes):
        row_hi = hi[bucket_ids[s]]                    # [Q, slots]
        row_lo = lo[bucket_ids[s]]
        row_ptr = ptr[bucket_ids[s]]
        match = (row_hi == q_hi[:, None]) & (row_lo == q_lo[:, None])
        cands.append(jnp.max(jnp.where(match, row_ptr, NULL), axis=-1))
    # First non-NULL newest -> oldest via one stacked argmax select.  (An
    # unrolled where(head==NULL, ...) fold compiles pathologically on the
    # CPU backend beyond ~10 segments — XLA fusion goes combinatorial.)
    cands = jnp.stack(cands)[::-1]                    # [S, Q] newest first
    hit = cands != NULL
    first = jnp.argmax(hit, axis=0)                   # [Q]
    head = jnp.take_along_axis(cands, first[None], axis=0)[0]
    head = jnp.where(hit.any(axis=0), head, NULL)
    # fill-masked: an arena tail's reserved-but-unwritten lanes (row ids
    # >= fill) can never be answered — by construction no bucket entry
    # points there, but with buffer donation a reserved lane may alias
    # retired memory, so the mask is the hard guarantee (DESIGN.md §4).
    return jnp.where(head < snapshot.fill, head, NULL)


def fused_lookup_ref(bucket_ids, q_hi, q_lo, snapshot, max_matches: int):
    """Oracle for hash_probe.fused_lookup_tiles: fused probe + chain walk
    over a Snapshot (probe planes + flat ``prev``).

    Returns (rows [Q, max_matches] newest-first NULL-padded, last [Q] — the
    would-be next row id; >= 0 means truncated)."""
    head = fused_probe_ref(bucket_ids, q_hi, q_lo, snapshot)
    prev = snapshot.prev
    fill = snapshot.fill

    def step(cur, _):
        nxt = jnp.where(cur >= 0, prev[jnp.maximum(cur, 0)], NULL)
        nxt = jnp.where(nxt < fill, nxt, NULL)    # fill-masked chain walk
        return nxt, cur

    last, rows = jax.lax.scan(step, head, None, length=max_matches)
    return jnp.moveaxis(rows, 0, 1), last


def decode_attention_ref(q, k_pages, v_pages, page_table, lengths, scale):
    """Oracle for decode_attention: GQA flash decode over paged KV.

    q          : [B, Hq, D]
    k_pages    : [P, page, Hkv, D]   (pages = the indexed cache's row batches)
    v_pages    : [P, page, Hkv, D]
    page_table : [B, max_pages] int32  (NULL = -1 padding)
    lengths    : [B] int32  (total valid KV per sequence)
    returns    : [B, Hq, D] float32
    """
    b, hq, d = q.shape
    p, page, hkv, _ = k_pages.shape
    groups = hq // hkv
    max_pages = page_table.shape[1]

    # materialize per-sequence KV [B, max_pages*page, Hkv, D]
    safe = jnp.maximum(page_table, 0)
    k = k_pages[safe].reshape(b, max_pages * page, hkv, d)
    v = v_pages[safe].reshape(b, max_pages * page, hkv, d)
    pos = jnp.arange(max_pages * page)[None, :]
    mask = pos < lengths[:, None]

    qg = q.reshape(b, hkv, groups, d).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    logits = jnp.einsum("bkgd,bskd->bkgs", qg, kf) * jnp.float32(scale)
    logits = jnp.where(mask[:, None, None, :], logits, -jnp.inf)
    w = jnp.exp(logits - logits.max(axis=-1, keepdims=True))
    w = w / w.sum(axis=-1, keepdims=True)
    out = jnp.einsum("bkgs,bskd->bkgd", w, vf)
    return out.reshape(b, hq, d)
