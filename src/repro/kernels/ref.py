"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the *semantic contract*; kernel tests sweep shapes/dtypes
and assert_allclose against these.
"""

from __future__ import annotations

import jax.numpy as jnp

NULL = jnp.int32(-1)


def probe_ref(bucket_ids, q_hi, q_lo, keys_hi, keys_lo, ptrs):
    """Oracle for hash_probe.probe_tiles: one [Q, S] gather + compare."""
    row_hi = keys_hi[bucket_ids]           # [Q, S]
    row_lo = keys_lo[bucket_ids]
    row_ptr = ptrs[bucket_ids]
    match = (row_hi == q_hi[:, None]) & (row_lo == q_lo[:, None])
    return jnp.max(jnp.where(match, row_ptr, NULL), axis=1)


def decode_attention_ref(q, k_pages, v_pages, page_table, lengths, scale):
    """Oracle for decode_attention: GQA flash decode over paged KV.

    q          : [B, Hq, D]
    k_pages    : [P, page, Hkv, D]   (pages = the indexed cache's row batches)
    v_pages    : [P, page, Hkv, D]
    page_table : [B, max_pages] int32  (NULL = -1 padding)
    lengths    : [B] int32  (total valid KV per sequence)
    returns    : [B, Hq, D] float32
    """
    b, hq, d = q.shape
    p, page, hkv, _ = k_pages.shape
    groups = hq // hkv
    max_pages = page_table.shape[1]

    # materialize per-sequence KV [B, max_pages*page, Hkv, D]
    safe = jnp.maximum(page_table, 0)
    k = k_pages[safe].reshape(b, max_pages * page, hkv, d)
    v = v_pages[safe].reshape(b, max_pages * page, hkv, d)
    pos = jnp.arange(max_pages * page)[None, :]
    mask = pos < lengths[:, None]

    qg = q.reshape(b, hkv, groups, d).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    logits = jnp.einsum("bkgd,bskd->bkgs", qg, kf) * jnp.float32(scale)
    logits = jnp.where(mask[:, None, None, :], logits, -jnp.inf)
    w = jnp.exp(logits - logits.max(axis=-1, keepdims=True))
    w = w / w.sum(axis=-1, keepdims=True)
    out = jnp.einsum("bkgs,bskd->bkgd", w, vf)
    return out.reshape(b, hq, d)
