"""Pallas TPU kernel for the hash-index probe — the paper's hot path.

The paper's Fig 1 observation is that the *probe* amortizes: the index is
built once and probed millions of times (point lookups, join probes).  On a
TPU the probe is a bucket gather + vector compare; this kernel keeps the
bucket arrays resident in VMEM and streams query tiles through them.

TPU adaptation notes (DESIGN.md §7):
  * int64 keys are pre-split into (hi, lo) int32 planes — the TPU VPU has no
    64-bit lanes; two int32 compares AND'd give the exact equality test.
  * bucket ids are precomputed in the XLA wrapper (ops.py) — the splitmix
    mix uses 64-bit multiplies which belong on the scalar/XLA side, not in
    the vector kernel.
  * the per-query bucket row load is a *scalar dynamic slice*
    (``ref[pl.ds(b, 1)]``) — the same pattern production paged-attention
    kernels use for page-table indirection; Mosaic pipelines these loads.
  * slot resolution is branch-free: ``max(where(match, ptr, NULL))`` — valid
    pointers are >= 0 and NULL is -1, so a vector max replaces the argmax/
    select pair.

VMEM budget: the table block is ``num_buckets * slots * 12`` bytes (hi, lo,
ptr).  With the default per-shard sizing (DESIGN.md: ≥256-way sharding keeps
shard-local distinct keys ≲ 2M) this is ≤ 96 MB; for bigger shards callers
chunk the bucket axis at the ops.py level (grid over table chunks, combined
with a second pass, since each query touches exactly one bucket).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import runtime

QUERY_TILE = 256


def _probe_kernel(bids_ref, qhi_ref, qlo_ref, khi_ref, klo_ref, ptr_ref,
                  out_ref):
    """One grid step: QUERY_TILE queries against the whole bucket table."""
    null = jnp.array(-1, jnp.int32)

    def body(j, _):
        b = bids_ref[j]
        row_hi = khi_ref[pl.ds(b, 1), :]        # [1, S]
        row_lo = klo_ref[pl.ds(b, 1), :]
        row_ptr = ptr_ref[pl.ds(b, 1), :]
        match = (row_hi == qhi_ref[j]) & (row_lo == qlo_ref[j])
        hit = jnp.max(jnp.where(match, row_ptr, null))
        out_ref[j] = hit
        return 0

    jax.lax.fori_loop(0, QUERY_TILE, body, 0)


@functools.partial(jax.jit, static_argnames=("interpret",))
def probe_tiles(bucket_ids, q_hi, q_lo, keys_hi, keys_lo, ptrs, *,
                interpret: bool | None = None):
    """[Q] bucket ids + key planes against [NB, S] table planes -> [Q] ptrs.

    Q must be a multiple of QUERY_TILE (ops.py pads).
    """
    interpret = runtime.resolve_interpret(interpret)
    q = bucket_ids.shape[0]
    assert q % QUERY_TILE == 0, q
    nb, s = keys_hi.shape
    grid = (q // QUERY_TILE,)

    qspec = pl.BlockSpec((QUERY_TILE,), lambda i: (i,))
    tspec = pl.BlockSpec((nb, s), lambda i: (0, 0))   # table resident in VMEM

    return pl.pallas_call(
        _probe_kernel,
        grid=grid,
        in_specs=[qspec, qspec, qspec, tspec, tspec, tspec],
        out_specs=qspec,
        out_shape=jax.ShapeDtypeStruct((q,), jnp.int32),
        interpret=interpret,
    )(bucket_ids, q_hi, q_lo, keys_hi, keys_lo, ptrs)


# ---------------------------------------------------------------------------
# Fused multi-segment lookup: probe -> first hit -> in-kernel chain walk
# ---------------------------------------------------------------------------

def _fused_lookup_kernel(*refs, num_segments: int, max_matches: int):
    """One grid step: QUERY_TILE queries against ALL segment index planes.

    refs layout: bids, qhi, qlo, then (hi, lo, ptr) per segment (ragged —
    each segment keeps its own bucket count), then prev, then the fill
    scalar, then the two outputs (rows, last).

    Per query j (DESIGN.md §3):
      1. probe the per-segment bucket planes newest -> oldest; the first
         non-NULL match is the head pointer (the cTrie-snapshot read of
         paper §III-E);
      2. walk the backward-pointer chain against the FLAT prev array —
         global row ids index ``prev_ref`` directly, no per-segment rebase —
         emitting ``max_matches`` row ids newest-first;
      3. record the would-be next pointer so the wrapper can flag truncation.

    The head and EVERY chain hop are fill-masked in-kernel (DESIGN.md §4):
    a pointer into the arena's reserved-but-unwritten lanes truncates the
    chain right there, exactly like the oracle's per-step mask — masking
    only the kernel's outputs would let garbage that bounces back below
    ``fill`` survive.  Both loops stay branch-free scalar code: the
    segment loop is unrolled (num_segments is static and small), the
    chain walk is a fori over ``max_matches`` of one dynamic scalar load
    from VMEM-resident ``prev``.
    """
    bids_ref, qhi_ref, qlo_ref = refs[:3]
    plane_refs = refs[3:3 + 3 * num_segments]
    prev_ref = refs[3 + 3 * num_segments]
    fill_ref = refs[3 + 3 * num_segments + 1]
    rows_ref, last_ref = refs[-2:]
    null = jnp.array(-1, jnp.int32)

    def body(j, _):
        qhi = qhi_ref[j]
        qlo = qlo_ref[j]
        fill = fill_ref[0]
        head = null
        for s in range(num_segments - 1, -1, -1):     # newest -> oldest
            khi_ref, klo_ref, ptr_ref = plane_refs[3 * s:3 * s + 3]
            b = bids_ref[s, j]
            row_hi = khi_ref[pl.ds(b, 1), :]          # [1, S] scalar-steered
            row_lo = klo_ref[pl.ds(b, 1), :]
            row_ptr = ptr_ref[pl.ds(b, 1), :]
            match = (row_hi == qhi) & (row_lo == qlo)
            cand = jnp.max(jnp.where(match, row_ptr, null))
            head = jnp.where(head == null, cand, head)
        head = jnp.where(head < fill, head, null)     # fill-masked head

        def walk(m, cur):
            rows_ref[j, m] = cur
            nxt = prev_ref[jnp.maximum(cur, 0)]
            nxt = jnp.where(nxt < fill, nxt, null)    # fill-masked hop
            return jnp.where(cur >= 0, nxt, null)

        last = jax.lax.fori_loop(0, max_matches, walk, head)
        last_ref[j] = last
        return 0

    jax.lax.fori_loop(0, QUERY_TILE, body, 0)


@functools.partial(jax.jit, static_argnames=("max_matches", "interpret"))
def fused_lookup_tiles(bucket_ids, q_hi, q_lo, snapshot,
                       *, max_matches: int, interpret: bool | None = None):
    """Fused probe + chain walk over a table's stored Snapshot.

    bucket_ids : [S, Q] int32  per-segment bucket ids (Q padded to tile)
    q_hi/q_lo  : [Q] int32     query key planes
    snapshot   : core.snapshot.Snapshot — ragged per-segment (hi, lo, ptrs)
                 planes (each [nb_s, slots] int32) plus the flat [capacity]
                 int32 backward-pointer array; a registered pytree, so this
                 jit caches on its structure (bucket_counts ride in the
                 treedef) and traces its arrays as leaves
    returns    : (rows [Q, max_matches] int32 newest-first NULL-padded,
                  last [Q] int32 — next row id after the walk; >= 0 means
                  the chain was truncated at max_matches)

    VMEM budget: sum_s(nb_s) * slots * 12 bytes of planes + capacity * 4
    bytes for ``prev``; callers keep per-shard capacity small enough
    (DESIGN.md §3) or compact() to bound S.
    """
    interpret = runtime.resolve_interpret(interpret)
    key_planes = snapshot.key_planes
    prev = snapshot.prev
    s, q = bucket_ids.shape
    assert q % QUERY_TILE == 0, q
    assert len(key_planes) == s
    cap = prev.shape[0]
    grid = (q // QUERY_TILE,)

    qspec = pl.BlockSpec((QUERY_TILE,), lambda i: (i,))
    bspec = pl.BlockSpec((s, QUERY_TILE), lambda i: (0, i))
    plane_specs, plane_args = [], []
    for hi, lo, ptr in key_planes:                 # planes resident in VMEM
        nb, slots = hi.shape
        plane_specs += [pl.BlockSpec((nb, slots), lambda i: (0, 0))] * 3
        plane_args += [hi, lo, ptr]
    pspec = pl.BlockSpec((cap,), lambda i: (0,))
    fspec = pl.BlockSpec((1,), lambda i: (0,))
    fill = snapshot.fill.astype(jnp.int32).reshape(1)

    kernel = functools.partial(_fused_lookup_kernel, num_segments=s,
                               max_matches=max_matches)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[bspec, qspec, qspec, *plane_specs, pspec, fspec],
        out_specs=(pl.BlockSpec((QUERY_TILE, max_matches), lambda i: (i, 0)),
                   qspec),
        out_shape=(jax.ShapeDtypeStruct((q, max_matches), jnp.int32),
                   jax.ShapeDtypeStruct((q,), jnp.int32)),
        interpret=interpret,
    )(bucket_ids, q_hi, q_lo, *plane_args, prev, fill)
