"""Pallas TPU kernel for the hash-index probe — the paper's hot path.

The paper's Fig 1 observation is that the *probe* amortizes: the index is
built once and probed millions of times (point lookups, join probes).  On a
TPU the probe is a bucket gather + vector compare; this kernel keeps the
bucket arrays resident in VMEM and streams query tiles through them.

TPU adaptation notes (DESIGN.md §7):
  * int64 keys are pre-split into (hi, lo) int32 planes — the TPU VPU has no
    64-bit lanes; two int32 compares AND'd give the exact equality test.
  * bucket ids are precomputed in the XLA wrapper (ops.py) — the splitmix
    mix uses 64-bit multiplies which belong on the scalar/XLA side, not in
    the vector kernel.
  * the per-query bucket row load is a *scalar dynamic slice*
    (``ref[pl.ds(b, 1)]``) — the same pattern production paged-attention
    kernels use for page-table indirection; Mosaic pipelines these loads.
  * slot resolution is branch-free: ``max(where(match, ptr, NULL))`` — valid
    pointers are >= 0 and NULL is -1, so a vector max replaces the argmax/
    select pair.

VMEM budget: the table block is ``num_buckets * slots * 12`` bytes (hi, lo,
ptr).  With the default per-shard sizing (DESIGN.md: ≥256-way sharding keeps
shard-local distinct keys ≲ 2M) this is ≤ 96 MB; for bigger shards callers
chunk the bucket axis at the ops.py level (grid over table chunks, combined
with a second pass, since each query touches exactly one bucket).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

QUERY_TILE = 256


def _probe_kernel(bids_ref, qhi_ref, qlo_ref, khi_ref, klo_ref, ptr_ref,
                  out_ref):
    """One grid step: QUERY_TILE queries against the whole bucket table."""
    null = jnp.array(-1, jnp.int32)

    def body(j, _):
        b = bids_ref[j]
        row_hi = khi_ref[pl.ds(b, 1), :]        # [1, S]
        row_lo = klo_ref[pl.ds(b, 1), :]
        row_ptr = ptr_ref[pl.ds(b, 1), :]
        match = (row_hi == qhi_ref[j]) & (row_lo == qlo_ref[j])
        hit = jnp.max(jnp.where(match, row_ptr, null))
        out_ref[j] = hit
        return 0

    jax.lax.fori_loop(0, QUERY_TILE, body, 0)


@functools.partial(jax.jit, static_argnames=("interpret",))
def probe_tiles(bucket_ids, q_hi, q_lo, keys_hi, keys_lo, ptrs, *,
                interpret: bool = True):
    """[Q] bucket ids + key planes against [NB, S] table planes -> [Q] ptrs.

    Q must be a multiple of QUERY_TILE (ops.py pads).
    """
    q = bucket_ids.shape[0]
    assert q % QUERY_TILE == 0, q
    nb, s = keys_hi.shape
    grid = (q // QUERY_TILE,)

    qspec = pl.BlockSpec((QUERY_TILE,), lambda i: (i,))
    tspec = pl.BlockSpec((nb, s), lambda i: (0, 0))   # table resident in VMEM

    return pl.pallas_call(
        _probe_kernel,
        grid=grid,
        in_specs=[qspec, qspec, qspec, tspec, tspec, tspec],
        out_specs=qspec,
        out_shape=jax.ShapeDtypeStruct((q,), jnp.int32),
        interpret=interpret,
    )(bucket_ids, q_hi, q_lo, keys_hi, keys_lo, ptrs)
