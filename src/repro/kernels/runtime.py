"""Kernel runtime helpers shared by the Pallas wrappers."""

from __future__ import annotations

import jax


def resolve_interpret(interpret) -> bool:
    """Resolve the ``interpret=None`` default: interpret everywhere except on
    a real TPU backend, so the same call sites compile on hardware and still
    run (emulated) in CPU containers/CI."""
    if interpret is None:
        return jax.default_backend() != "tpu"
    return bool(interpret)
