"""Paged GQA flash decode attention — Pallas TPU kernel.

Serving consumes the Indexed DataFrame's row batches as **KV pages**: the
prefix cache (serving/kvcache.py) stores pages and resolves a request's
page list via the hash-index probe; this kernel then computes one decode
step of attention directly over those pages.

Structure (the production paged-attention pattern):
  * ``PrefetchScalarGridSpec`` with the page table + lengths as scalar
    prefetch — the k/v BlockSpec ``index_map`` reads ``page_table[b, j]`` to
    steer the HBM->VMEM DMA for grid step (b, j).  Pages land in VMEM just
    in time; compute overlaps the next page's copy.
  * online-softmax (flash) accumulation across the page axis in VMEM
    scratch — one pass over KV, no [S] logits materialization.
  * GQA layout [Hkv, G, D] so the per-page contraction is an MXU matmul
    with D=128-aligned operands.

Validated in interpret mode against ref.decode_attention_ref across
shape/dtype sweeps (tests/test_kernels.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import runtime

NEG_INF = -1e30


def _kernel(pt_ref, len_ref, q_ref, k_ref, v_ref, out_ref,
            m_ref, l_ref, acc_ref, *, page: int, groups: int, scale: float):
    b = pl.program_id(0)
    j = pl.program_id(1)
    np_ = pl.num_programs(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    hkv = k_ref.shape[2]
    d = k_ref.shape[3]

    q = q_ref[0].astype(jnp.float32)                    # [Hq, D]
    qg = q.reshape(hkv, groups, d)
    k = k_ref[0].astype(jnp.float32)                    # [page, Hkv, D]
    v = v_ref[0].astype(jnp.float32)

    logits = jax.lax.dot_general(                        # [Hkv, G, page]
        qg, jnp.transpose(k, (1, 2, 0)),
        (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32) * jnp.float32(scale)

    pos = j * page + jax.lax.broadcasted_iota(jnp.int32, (1, 1, page), 2)
    valid = (pos < len_ref[b]) & (pt_ref[b, j] >= 0)
    logits = jnp.where(valid, logits, NEG_INF)

    m_prev = m_ref[...]                                  # [Hkv, G]
    m_new = jnp.maximum(m_prev, logits.max(axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(logits - m_new[..., None])               # [Hkv, G, page]
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1)
    pv = jax.lax.dot_general(                            # [Hkv, G, D]
        p, jnp.transpose(v, (1, 0, 2)),
        (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)
    acc_ref[...] = acc_ref[...] * alpha[..., None] + pv
    m_ref[...] = m_new

    @pl.when(j == np_ - 1)
    def _finish():
        l = l_ref[...]
        out = acc_ref[...] / jnp.maximum(l, 1e-30)[..., None]
        out_ref[0] = out.reshape(hkv * groups, d)


@functools.partial(jax.jit,
                   static_argnames=("scale", "interpret"))
def decode_paged(q, k_pages, v_pages, page_table, lengths, scale: float, *,
                 interpret: bool | None = None):
    """One decode step of paged attention.

    q          : [B, Hq, D] (bf16/f32)
    k_pages    : [P, page, Hkv, D]
    v_pages    : [P, page, Hkv, D]
    page_table : [B, NP] int32 (-1 padded)
    lengths    : [B] int32
    returns    : [B, Hq, D] float32
    """
    interpret = runtime.resolve_interpret(interpret)
    bsz, hq, d = q.shape
    _, page, hkv, _ = k_pages.shape
    npages = page_table.shape[1]
    groups = hq // hkv
    assert hq == groups * hkv

    grid = (bsz, npages)

    def q_map(b, j, pt, ln):
        return (b, 0, 0)

    def kv_map(b, j, pt, ln):
        return (jnp.maximum(pt[b, j], 0), 0, 0, 0)

    def out_map(b, j, pt, ln):
        return (b, 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, hq, d), q_map),
            pl.BlockSpec((1, page, hkv, d), kv_map),
            pl.BlockSpec((1, page, hkv, d), kv_map),
        ],
        out_specs=pl.BlockSpec((1, hq, d), out_map),
        scratch_shapes=[
            pltpu.VMEM((hkv, groups), jnp.float32),
            pltpu.VMEM((hkv, groups), jnp.float32),
            pltpu.VMEM((hkv, groups, d), jnp.float32),
        ],
    )

    kernel = functools.partial(_kernel, page=page, groups=groups,
                               scale=scale)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((bsz, hq, d), jnp.float32),
        interpret=interpret,
    )(page_table, lengths, q, k_pages, v_pages)
