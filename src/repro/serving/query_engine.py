"""Continuous-batching query serving engine with p50/p99 SLOs.

The MVCC design's whole point is readers staying on a consistent
snapshot while writers append (PAPER.md §3), and the ROADMAP's north
star is "heavy traffic from millions of users" — this module is the
serving loop that makes that story measurable.  The shape follows
rtp-llm's FIFO scheduler + KV-cache manager (PAPERS.md) applied to the
paper's dataframe operators instead of token decode:

* **Admission** — many client streams ``submit_lookup`` / ``submit_join``
  / ``submit_append`` into one FIFO queue.  No reordering: a micro-batch
  is a contiguous head run of compatible requests (same kind,
  ``max_matches``, probe columns), exactly the FIFOScheduler contract.
* **Pad-to-bucket micro-batching** — each batch's key vector is padded
  to the smallest bucket in a power-of-two ladder with the reserved
  ``PAD_KEY`` sentinel (``core.hashindex.EMPTY_KEY`` — a guaranteed miss
  on every physical operator), so every batch size hits an existing jit
  cache entry: the arena/ring static-shape trick (DESIGN.md §4, §13)
  applied to query batching.  One trace per (site, bucket), zero
  retraces thereafter — ``scripts/trace_gate.py`` gates it.
* **Write interleaving** — writer deltas are staged into the PR-7
  device-resident ``AppendQueue`` between ticks (zero host syncs) and
  flushed on ring-full or a tick deadline: ONE fused ingest, ONE version
  bump for the whole ring.  Reads admitted in the same tick ride the
  pre-flush snapshot — the one-version-bump MVCC contract, observable
  per request via ``QueryRequest.version``.
* **Supervision** — hand the engine a ``RecoveryManager``
  (``frame.supervised(...)``) instead of a bare frame and every batch
  runs through the PR-6 self-healing read path: the engine serves
  traffic mid-heal (tests/test_query_engine.py).
* **SLO accounting** — per-request latency (submit -> answer ready) and
  write visibility lag feed ``latency_summary()`` (p50/p99/mean);
  ``benchmarks/serve.py`` sweeps a QPS × write-rate grid into
  ``BENCH_serve.json``.

The engine OWNS the frame from construction on (like ``supervised``):
it attaches the append ring up front (the one treedef change, before
any read site compiles) and replaces the frame on every write.
``write_log`` records each landed version with its coalesced delta
group, so an unbatched twin can replay the exact interleaving and
verify bit-identity (scripts/serve_smoke.py).
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import joins
from repro.core import partition as partition_mod
from repro.core import table as table_mod
from repro.core.hashindex import EMPTY_KEY

# The reserved pad sentinel: the probe side treats EMPTY_KEY as
# can-never-match on every physical operator (local fused probe, bcast,
# routed exchange — dist masks it out of the all-to-all entirely), so a
# padded lane costs one probe of an empty slot and can never fabricate a
# hit, consume routed capacity, or perturb neighbouring answers.
PAD_KEY = int(np.asarray(EMPTY_KEY))

DEFAULT_MIN_BUCKET = 8
DEFAULT_MAX_BUCKET = 256


def bucket_ladder(max_bucket: int = DEFAULT_MAX_BUCKET, *,
                  min_bucket: int = DEFAULT_MIN_BUCKET) -> tuple[int, ...]:
    """The power-of-two bucket ladder ``(min, 2*min, ..., max)``.

    Every micro-batch is padded up to a rung, so the number of distinct
    shapes the jitted read sites ever see — and therefore the number of
    compiles — is ``len(ladder)``, not the number of request sizes.
    """
    if min_bucket < 1 or max_bucket < min_bucket:
        raise ValueError(f"need 1 <= min_bucket <= max_bucket, got "
                         f"{min_bucket} / {max_bucket}")
    lo = 1 << (min_bucket - 1).bit_length()
    hi = 1 << (max_bucket - 1).bit_length()
    return tuple(lo << i for i in range((hi // lo).bit_length()))


def pick_bucket(n: int, ladder: tuple[int, ...]) -> int:
    """Smallest rung >= n (callers bound n by ``ladder[-1]`` at admission)."""
    for b in ladder:
        if n <= b:
            return b
    raise ValueError(f"batch of {n} rows exceeds the ladder max "
                     f"{ladder[-1]}")


def pad_keys(keys: np.ndarray, bucket: int) -> np.ndarray:
    """Pad a key vector to ``bucket`` lanes with the ``PAD_KEY`` sentinel."""
    out = np.full(bucket, PAD_KEY, np.int64)
    out[:keys.shape[0]] = keys
    return out


@dataclasses.dataclass
class QueryRequest:
    """One admitted read: a lookup key batch or a join probe block."""

    req_id: int
    stream_id: int
    kind: str                      # "lookup" | "join"
    keys: np.ndarray | None        # lookup: [n] int64
    probe_cols: dict | None        # join: columnar probe block
    on: str | None
    max_matches: int
    t_submit: float
    t_done: float | None = None
    version: int | None = None     # MVCC version the answer was read at
    bucket: int | None = None
    result: tuple | None = None    # lookup: (cols, valid); join: 3-tuple

    @property
    def size(self) -> int:
        return (self.keys.shape[0] if self.kind == "lookup"
                else next(iter(self.probe_cols.values())).shape[0])

    @property
    def done(self) -> bool:
        return self.t_done is not None

    @property
    def latency_s(self) -> float | None:
        return None if self.t_done is None else self.t_done - self.t_submit


@dataclasses.dataclass
class WriteRequest:
    """One admitted writer delta: staged into the ring, visible at flush."""

    req_id: int
    stream_id: int
    cols: dict
    valid: np.ndarray | None
    t_submit: float
    t_staged: float | None = None
    t_visible: float | None = None
    version: int | None = None     # version that made the delta visible

    @property
    def latency_s(self) -> float | None:
        return (None if self.t_visible is None
                else self.t_visible - self.t_submit)


class EngineStats:
    """Counters + latency samples the SLO summary and benchmarks read."""

    def __init__(self):
        self.ticks = 0
        self.reads = 0
        self.writes = 0
        self.batches = 0
        self.batched_keys = 0
        self.padded_lanes = 0
        self.flushes = 0
        self.direct_appends = 0
        self.read_latencies_s: list[float] = []
        self.write_latencies_s: list[float] = []

    def to_dict(self) -> dict:
        d = {k: v for k, v in vars(self).items()
             if not isinstance(v, list)}
        d["read_latency"] = percentiles(self.read_latencies_s)
        d["write_latency"] = percentiles(self.write_latencies_s)
        return d


def percentiles(latencies_s) -> dict:
    """p50/p99/mean/max in milliseconds over a latency sample."""
    if not len(latencies_s):
        return {"n": 0}
    ms = np.asarray(latencies_s, np.float64) * 1e3
    return {"n": int(ms.size), "p50_ms": float(np.percentile(ms, 50)),
            "p99_ms": float(np.percentile(ms, 99)),
            "mean_ms": float(ms.mean()), "max_ms": float(ms.max())}


class QueryEngine:
    """Continuous-batching serving loop over an ``IndexedFrame`` (or a
    ``RecoveryManager`` wrapping one — duck-typed: a manager has no
    ``plan_lookup``).

    ``tick()`` is one scheduler step: (1) drain the read FIFO into
    pad-to-bucket micro-batches against the CURRENT snapshot, (2) stage
    admitted writer deltas into the append ring (auto-flushing a full
    ring), (3) flush on the tick deadline.  ``drain()`` runs ticks until
    idle and lands the final flush.  The engine owns the frame; callers
    keep handles to their ``QueryRequest``s and read results off them.
    """

    def __init__(self, frame, *, ladder: tuple[int, ...] | None = None,
                 max_bucket: int = DEFAULT_MAX_BUCKET,
                 min_bucket: int = DEFAULT_MIN_BUCKET,
                 max_matches: int = 8, names=None, op: str = "auto",
                 flush_deadline_ticks: int = 4,
                 queue_lanes: int = table_mod.DEFAULT_QUEUE_LANES,
                 queue_lane_rows: int | None = None,
                 flush_donate: bool = False,
                 enqueue_donate: bool = True):
        self.ladder = (tuple(ladder) if ladder is not None
                       else bucket_ladder(max_bucket, min_bucket=min_bucket))
        if list(self.ladder) != sorted(set(self.ladder)):
            raise ValueError(f"ladder must be strictly increasing, got "
                             f"{self.ladder}")
        self.max_matches = int(max_matches)
        joins.check_max_matches(self.max_matches)
        self.names = None if names is None else tuple(names)
        self.op = op
        self.flush_deadline_ticks = max(1, int(flush_deadline_ticks))
        self.flush_donate = flush_donate
        self.enqueue_donate = enqueue_donate

        # a RecoveryManager (supervised mode) vs a bare frame: the
        # manager owns healing + its own jitted sites; the engine only
        # adds admission/batching/interleaving on top.
        self._mgr = None
        if not hasattr(frame, "plan_lookup"):
            self._mgr = frame
            frame = frame.frame
        # partitioned frames have no frame-level ring (appends route per
        # partition) and no engine-owned jit sites (the partition layer's
        # per-partition sites carry the compile cache + pruning); writes
        # go through the direct-append path, one version bump each
        self._partitioned = bool(getattr(frame, "is_partitioned", False))
        self._part0 = (partition_mod.site_traces(),
                       partition_mod.expected_site_traces())
        # attach the ring NOW — the frame's one treedef change happens
        # before any read site compiles, so streaming stays retrace-free
        if frame.queue is None and not self._partitioned:
            frame = frame.with_queue(lanes=queue_lanes,
                                     lane_rows=queue_lane_rows)
        if self._mgr is not None:
            self._mgr.frame = frame
        else:
            self._frame = frame

        self._readq: deque[QueryRequest] = deque()
        self._writeq: deque[WriteRequest] = deque()
        self._staged: list[WriteRequest] = []
        self._next_id = 0
        self._ticks_since_flush = 0
        self._sites: dict = {}         # site key -> (jit fn, trace ctr)
        self._bucket_use: set = set()  # (site key, bucket) pairs driven
        self.stats = EngineStats()
        # host-side MVCC version mirror: ONE sync at construction, then
        # +1 per flush / direct append — serving never reads the device
        # scalar back (verify_version() checks the mirror in tests)
        self._version_host = int(np.asarray(self.frame.version))
        self.write_log: list[dict] = []

    # -- frame ownership -------------------------------------------------------

    @property
    def frame(self):
        """The live frame (the manager's, in supervised mode)."""
        return self._mgr.frame if self._mgr is not None else self._frame

    def _set_frame(self, fr):
        if self._mgr is not None:
            self._mgr.frame = fr
        else:
            self._frame = fr

    @property
    def supervised(self) -> bool:
        return self._mgr is not None

    @property
    def version_host(self) -> int:
        """Host mirror of the frame's MVCC version (no device sync)."""
        return self._version_host

    def verify_version(self) -> bool:
        """One device sync: does the host mirror match the device scalar?
        (One bump per flush — the MVCC contract check for tests/smoke.)"""
        return int(np.asarray(self.frame.version)) == self._version_host

    # -- admission (the FIFO queue) --------------------------------------------

    def _admit_keys(self, keys) -> np.ndarray:
        arr = np.asarray(keys)
        if arr.ndim != 1 or arr.size == 0:
            raise ValueError(f"a request is a non-empty [n] key vector, "
                             f"got shape {arr.shape}")
        if arr.dtype.kind not in "iu":
            raise ValueError(f"keys must be integers, got {arr.dtype}")
        if arr.size > self.ladder[-1]:
            raise ValueError(
                f"request of {arr.size} keys exceeds the ladder max "
                f"{self.ladder[-1]}; split it across requests")
        return arr.astype(np.int64)

    def submit_lookup(self, keys, *, stream_id: int = 0,
                      max_matches: int | None = None,
                      t_submit: float | None = None) -> QueryRequest:
        """Admit one lookup request (``getRows`` over a key batch).
        ``t_submit`` lets an open-loop driver charge queueing delay from
        the scheduled arrival time, not the submit call."""
        mm = self.max_matches if max_matches is None else int(max_matches)
        joins.check_max_matches(mm)
        r = QueryRequest(
            req_id=self._next_id, stream_id=stream_id, kind="lookup",
            keys=self._admit_keys(keys), probe_cols=None, on=None,
            max_matches=mm,
            t_submit=time.perf_counter() if t_submit is None else t_submit)
        self._next_id += 1
        self._readq.append(r)
        self.stats.reads += 1
        return r

    def submit_join(self, probe_cols: dict, on: str, *, stream_id: int = 0,
                    max_matches: int | None = None,
                    t_submit: float | None = None) -> QueryRequest:
        """Admit one join request (this frame as the build side)."""
        mm = self.max_matches if max_matches is None else int(max_matches)
        joins.check_max_matches(mm)
        pc = {k: np.asarray(v) for k, v in probe_cols.items()}
        self._admit_keys(pc[on])          # validates size/dtype via on-col
        r = QueryRequest(
            req_id=self._next_id, stream_id=stream_id, kind="join",
            keys=None, probe_cols=pc, on=on, max_matches=mm,
            t_submit=time.perf_counter() if t_submit is None else t_submit)
        self._next_id += 1
        self._readq.append(r)
        self.stats.reads += 1
        return r

    def submit_append(self, cols: dict, valid=None, *, stream_id: int = 0,
                      t_submit: float | None = None) -> WriteRequest:
        """Admit one writer delta: staged into the device-resident ring
        at the next tick, visible at the next flush."""
        w = WriteRequest(
            req_id=self._next_id, stream_id=stream_id, cols=cols,
            valid=valid,
            t_submit=time.perf_counter() if t_submit is None else t_submit)
        self._next_id += 1
        self._writeq.append(w)
        self.stats.writes += 1
        return w

    @property
    def pending_reads(self) -> int:
        return len(self._readq)

    @property
    def pending_writes(self) -> int:
        """Admitted but not yet staged (ring-staged deltas are counted
        by ``staged_writes`` until the flush makes them visible)."""
        return len(self._writeq)

    @property
    def staged_writes(self) -> int:
        return len(self._staged)

    @property
    def has_work(self) -> bool:
        return bool(self._readq or self._writeq or self._staged)

    # -- jitted read sites (one compile per (site, bucket)) --------------------

    def _site(self, skey):
        if skey not in self._sites:
            ctr = {"n": 0}
            if skey[0] == "lookup":
                _, mm, names, op = skey

                def f(fr, q):
                    ctr["n"] += 1
                    return fr.lookup(q, max_matches=mm, names=names, op=op)
            else:
                _, on, mm, names, op, _colnames = skey

                def f(fr, pc):
                    ctr["n"] += 1
                    return fr.join(pc, on, max_matches=mm, names=names,
                                   op=op)
            self._sites[skey] = (jax.jit(f), ctr)
        return self._sites[skey]

    def _batch_key(self, r: QueryRequest):
        if r.kind == "lookup":
            return ("lookup", r.max_matches, self.names, self.op)
        return ("join", r.on, r.max_matches, self.names, self.op,
                tuple(sorted(r.probe_cols)))

    @property
    def trace_counts(self) -> dict:
        """Traces per engine-owned read site (supervised mode: the
        manager's sites count instead — see ``retraces``)."""
        return {k: ctr["n"] for k, (_, ctr) in self._sites.items()}

    @property
    def retraces(self) -> int:
        """Total traces across the serving read sites.  Equals
        ``expected_traces`` exactly when nothing retraced: each
        (site, bucket) pair compiles once and every later batch of that
        shape reuses the cache entry.

        Partitioned frames: the partition layer's counters are
        PROCESS-GLOBAL, so this is a baseline-subtracted window — exact
        only while no OTHER partitioned frame or engine in the process
        runs lookups concurrently (their traces would be misattributed
        to this engine).  Gates and benchmarks drive one engine at a
        time, which is the supported measurement setup."""
        if self._mgr is not None:
            return self._mgr.retraces
        if self._partitioned:
            return partition_mod.site_traces() - self._part0[0]
        return sum(ctr["n"] for _, ctr in self._sites.values())

    @property
    def expected_traces(self) -> int:
        """Distinct (read site, bucket) pairs this engine has driven.
        Partitioned frames count the partition layer's per-partition
        sites instead (its fingerprints subsume the bucket ladder) —
        process-global with a construction-time baseline, same caveat
        as ``retraces``."""
        if self._mgr is None and self._partitioned:
            return partition_mod.expected_site_traces() - self._part0[1]
        return len(self._bucket_use)

    @property
    def zero_retraces_after_warmup(self) -> bool:
        return self.retraces == self.expected_traces

    # -- micro-batching --------------------------------------------------------

    def _take_batch(self) -> list[QueryRequest]:
        """A contiguous FIFO head run of compatible requests bounded by
        the ladder max — strict arrival order, never reordered past an
        incompatible request (the FIFOScheduler admission contract)."""
        head = self._readq.popleft()
        batch, key, total = [head], self._batch_key(head), head.size
        while self._readq:
            nxt = self._readq[0]
            if (self._batch_key(nxt) != key
                    or total + nxt.size > self.ladder[-1]):
                break
            batch.append(self._readq.popleft())
            total += nxt.size
        return batch

    def _run_batch(self, batch: list[QueryRequest]) -> list[QueryRequest]:
        key = self._batch_key(batch[0])
        n = sum(r.size for r in batch)
        bucket = pick_bucket(n, self.ladder)
        self.stats.batches += 1
        self.stats.batched_keys += n
        self.stats.padded_lanes += bucket - n
        self._bucket_use.add((key, bucket))
        if batch[0].kind == "lookup":
            out = self._exec_lookup(key, batch, n, bucket)
        else:
            out = self._exec_join(key, batch, n, bucket)
        t_done = time.perf_counter()
        off = 0
        for r in batch:
            sl = slice(off, off + r.size)
            r.result = tuple(
                {k: np.asarray(v[sl]) for k, v in part.items()}
                if isinstance(part, dict) else np.asarray(part[sl])
                for part in out)
            r.bucket = bucket
            r.version = self._version_host
            r.t_done = t_done
            self.stats.read_latencies_s.append(r.latency_s)
            off += r.size
        return batch

    def _exec_lookup(self, skey, batch, n, bucket):
        padded = pad_keys(np.concatenate([r.keys for r in batch]), bucket)
        mm = skey[1]
        if self._mgr is not None:
            cols, valid = self._mgr.lookup(
                jnp.asarray(padded), max_matches=mm, names=self.names,
                op=self.op)
        elif self._partitioned:
            # eager call: routing needs HOST keys (pruning), and the
            # partition layer's own jitted per-partition sites are the
            # compile cache — an engine-level jit would turn keys into
            # tracers and forfeit both
            cols, valid = self._frame.lookup(
                padded, max_matches=mm, names=self.names, op=self.op)
        else:
            fn, _ = self._site(skey)
            cols, valid = fn(self._frame, jnp.asarray(padded))
        jax.block_until_ready(valid)
        return cols, valid

    def _exec_join(self, skey, batch, n, bucket):
        on = skey[1]
        cat = {c: np.concatenate([r.probe_cols[c] for r in batch])
               for c in batch[0].probe_cols}
        padded = {}
        for c, v in cat.items():
            fill = np.zeros(bucket, v.dtype)
            if c == on:
                fill = pad_keys(np.zeros(0, np.int64), bucket)
            fill[:n] = v
            padded[c] = fill
        mm = skey[2]
        if self._mgr is not None:
            bcols, pcols, valid = self._mgr.join(
                {k: jnp.asarray(v) for k, v in padded.items()}, on,
                max_matches=mm, names=self.names, op=self.op)
        elif self._partitioned:
            bcols, pcols, valid = self._frame.join(
                padded, on, max_matches=mm, names=self.names, op=self.op)
        else:
            fn, _ = self._site(skey)
            bcols, pcols, valid = fn(
                self._frame, {k: jnp.asarray(v) for k, v in padded.items()})
        jax.block_until_ready(valid)
        return bcols, pcols, valid

    # -- write interleaving ----------------------------------------------------

    def _enqueue(self, cols, valid):
        if self._mgr is not None:
            self._mgr.enqueue(cols, valid)
        else:
            self._frame = self._frame.enqueue(cols, valid,
                                              donate=self.enqueue_donate)

    def _append_direct(self, w: WriteRequest):
        """The documented oversize bypass: a delta too big for a ring
        lane lands through the ordinary coalesced append — its own
        version bump, immediately visible."""
        if self._mgr is not None:
            self._mgr.append(w.cols, w.valid)
        else:
            self._frame = self._frame.append(w.cols, w.valid)
        self._version_host += 1
        t = time.perf_counter()
        w.t_staged = w.t_visible = t
        w.version = self._version_host
        self.write_log.append({"version": self._version_host,
                               "writes": [(w.cols, w.valid)]})
        self.stats.direct_appends += 1
        self.stats.write_latencies_s.append(w.latency_s)

    def _stage_write(self, w: WriteRequest):
        if self._partitioned:
            # no frame-level ring on partitioned frames (supervised or
            # not): every write is a routed direct append, its own
            # version bump — the twin replay stays bit-identical because
            # write_log records each as its own group
            self._append_direct(w)
            return
        try:
            self._enqueue(w.cols, w.valid)
        except table_mod.QueueOverflow:
            self.flush()                       # ring-full: flush, retry
            try:
                self._enqueue(w.cols, w.valid)
            except table_mod.QueueOverflow:
                self._append_direct(w)
                return
        w.t_staged = time.perf_counter()
        self._staged.append(w)

    def flush(self):
        """Land the staged ring: ONE fused ingest, ONE version bump for
        however many deltas are staged; the flushed group is recorded in
        ``write_log`` so a twin can replay the interleaving."""
        if not self._staged:
            return
        if self._mgr is not None:
            self._mgr.flush()
        else:
            self._frame = self._frame.flush(donate=self.flush_donate)
        self._version_host += 1
        t = time.perf_counter()
        self.write_log.append({
            "version": self._version_host,
            "writes": [(w.cols, w.valid) for w in self._staged]})
        for w in self._staged:
            w.t_visible = t
            w.version = self._version_host
            self.stats.write_latencies_s.append(w.latency_s)
        self._staged.clear()
        self.stats.flushes += 1
        self._ticks_since_flush = 0

    # -- the scheduler tick ----------------------------------------------------

    def tick(self) -> list[QueryRequest]:
        """One continuous-batching step: drain reads against the current
        (pre-flush) snapshot, stage writes into the ring, flush on the
        deadline.  Returns the requests completed this tick."""
        self.stats.ticks += 1
        done = []
        while self._readq:
            done.extend(self._run_batch(self._take_batch()))
        while self._writeq:
            self._stage_write(self._writeq.popleft())
        self._ticks_since_flush += 1
        if self._staged and \
                self._ticks_since_flush >= self.flush_deadline_ticks:
            self.flush()
        return done

    def drain(self) -> list[QueryRequest]:
        """Tick until idle, then land the final flush."""
        done = []
        while self._readq or self._writeq:
            done.extend(self.tick())
        self.flush()
        return done

    # -- SLO summary -----------------------------------------------------------

    def latency_summary(self) -> dict:
        """p50/p99 read latency + write visibility lag + batching shape
        (the per-cell record ``benchmarks/serve.py`` commits)."""
        s = self.stats
        return {
            "read": percentiles(s.read_latencies_s),
            "write_visibility": percentiles(s.write_latencies_s),
            "reads": s.reads, "writes": s.writes, "ticks": s.ticks,
            "batches": s.batches,
            "mean_batch_keys": (s.batched_keys / s.batches
                                if s.batches else 0.0),
            "pad_fraction": (s.padded_lanes
                             / (s.padded_lanes + s.batched_keys)
                             if s.batched_keys else 0.0),
            "flushes": s.flushes, "direct_appends": s.direct_appends,
            "retraces": self.retraces,
            "expected_traces": self.expected_traces,
            "zero_retraces_after_warmup": self.zero_retraces_after_warmup,
        }


def replay_unbatched(frame0, requests, write_log, *,
                     names=None, op: str = "auto", site_cache=None):
    """Verify the serving run against an unbatched MVCC twin.

    Replays ``write_log`` version by version on ``frame0`` (the frame the
    engine was BUILT from, pre-serving) and answers every request
    individually — no admission queue, no padding, no ring — at exactly
    the version the engine answered it.  Returns the number of requests
    whose engine answers are NOT bit-identical to the twin's (0 is the
    acceptance claim in scripts/serve_smoke.py and BENCH_serve.json).

    ``site_cache``: an optional dict the caller owns.  When given, the
    twin's per-request reads run through jitted sites cached there (one
    compile per request shape, reused across calls AND across replays
    sharing the dict — successive MVCC twins are structurally equal, so
    nothing retraces).  The answers are bit-identical to the eager path;
    benchmarks pass a shared dict so grid cells on the slow-compiling
    shard_map backend don't pay the oracle's compile cost per cell.
    """
    twin = frame0
    log = sorted(write_log, key=lambda g: g["version"])
    li = 0
    mismatches = 0

    def read(r):
        if site_cache is None:
            if r.kind == "lookup":
                return twin.lookup(jnp.asarray(r.keys),
                                   max_matches=r.max_matches, names=names,
                                   op=op)
            return twin.join({k: jnp.asarray(v)
                              for k, v in r.probe_cols.items()}, r.on,
                             max_matches=r.max_matches, names=names, op=op)
        if r.kind == "lookup":
            skey = ("lookup", r.keys.shape[0], r.max_matches, names, op)
            if skey not in site_cache:
                mm = r.max_matches
                site_cache[skey] = jax.jit(lambda fr, q, _mm=mm: fr.lookup(
                    q, max_matches=_mm, names=names, op=op))
            return site_cache[skey](twin, jnp.asarray(r.keys))
        skey = ("join", r.on, next(iter(r.probe_cols.values())).shape[0],
                r.max_matches, names, op, tuple(sorted(r.probe_cols)))
        if skey not in site_cache:
            mm, on = r.max_matches, r.on
            site_cache[skey] = jax.jit(lambda fr, pc, _mm=mm, _on=on:
                                       fr.join(pc, _on, max_matches=_mm,
                                               names=names, op=op))
        return site_cache[skey](twin, {k: jnp.asarray(v)
                                       for k, v in r.probe_cols.items()})

    for r in sorted([r for r in requests if r.done],
                    key=lambda r: r.version):
        while li < len(log) and log[li]["version"] <= r.version:
            group = log[li]["writes"]
            cols = [c for c, _ in group]
            valid = [v for _, v in group]
            if any(v is not None for v in valid):
                twin = twin.append(cols if len(cols) > 1 else cols[0],
                                   valid if len(cols) > 1 else valid[0])
            else:
                twin = twin.append(cols if len(cols) > 1 else cols[0])
            li += 1
        if not _results_equal(r.result, read(r)):
            mismatches += 1
    return mismatches


def _results_equal(got, want) -> bool:
    for g, w in zip(got, want):
        if isinstance(g, dict):
            for k in w:
                if not np.array_equal(np.asarray(g[k]), np.asarray(w[k])):
                    return False
        elif not np.array_equal(np.asarray(g), np.asarray(w)):
            return False
    return True
