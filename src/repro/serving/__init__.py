"""serving — indexed prefix/KV cache + decode engine.

  kvcache.py  PagePool (row batches) + PrefixCache (hash-index lookup,
              MVCC commits) — the paper's cache applied to inference
  engine.py   dense serve_step (dry-run path), paged GQA fast path,
              host-side batched Engine
"""

from repro.serving.kvcache import PagePool, PrefixCache, prefix_hashes
from repro.serving.engine import Engine, Request, make_serve_step, \
    paged_decode_step

__all__ = ["PagePool", "PrefixCache", "prefix_hashes", "Engine", "Request",
           "make_serve_step", "paged_decode_step"]
