"""serving — indexed prefix/KV cache + decode engine + query serving.

  kvcache.py       PagePool (row batches) + PrefixCache (hash-index
                   lookup, MVCC commits) — the paper's cache applied to
                   inference
  engine.py        dense serve_step (dry-run path), paged GQA fast path,
                   host-side batched Engine
  query_engine.py  continuous-batching QueryEngine over the IndexedFrame
                   facade: FIFO admission, pad-to-bucket micro-batching,
                   AppendQueue write interleaving, p50/p99 SLO accounting
                   (DESIGN.md §14)
"""

from repro.serving.kvcache import PagePool, PrefixCache, prefix_hashes
from repro.serving.engine import Engine, Request, make_serve_step, \
    paged_decode_step
from repro.serving.query_engine import (PAD_KEY, EngineStats, QueryEngine,
                                        QueryRequest, WriteRequest,
                                        bucket_ladder, pad_keys,
                                        percentiles, pick_bucket,
                                        replay_unbatched)

__all__ = ["PagePool", "PrefixCache", "prefix_hashes", "Engine", "Request",
           "make_serve_step", "paged_decode_step",
           "PAD_KEY", "EngineStats", "QueryEngine", "QueryRequest",
           "WriteRequest", "bucket_ladder", "pad_keys", "percentiles",
           "pick_bucket", "replay_unbatched"]
