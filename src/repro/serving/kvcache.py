"""Indexed prefix/KV cache — the paper's cache applied to inference.

Decode-time prefix reuse is a *point lookup* problem: hash(token prefix) ->
cached KV page pointer.  The structures map 1:1 onto the Indexed DataFrame
(DESIGN.md §3):

  row batches        -> KV page pool  [num_pages, page, Hkv, D] per layer
  cTrie index        -> dense hash index: prefix_hash -> latest page entry
  backward pointers  -> per-prefix chain (a sequence's pages chain back to
                        its predecessor page, newest-first) — walking the
                        chain reconstructs the page list
  MVCC append        -> committing a new sequence's pages = one functional
                        append of (prefix_hash, page_id) rows; concurrent
                        sessions = divergent children, exactly Listing 2

Keys are *cumulative* prefix hashes at page boundaries (splitmix over the
previous hash and the page's tokens), so two sequences share cache entries
exactly when they share a page-aligned prefix.

The pool itself is device-resident; the index is the paper's structure from
``core/``.  ``lookup_prefix`` probes **all** page-aligned prefixes of a
request in one vectorized probe (one kernel launch) and takes the longest
hit — O(pages) hashing + one probe, no host loop over lengths.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Schema
from repro.frame import IndexedFrame

PAGE_SCHEMA = Schema.of("prefix_hash", prefix_hash="int64", page_id="int32",
                        page_index="int32", seq_id="int32")

_MIX = np.uint64(0x9E3779B97F4A7C15)


def _mix64(a, b):
    """One splitmix-style combine step (vectorized, uint64)."""
    x = (a ^ b) * _MIX
    x = (x ^ (x >> np.uint64(29))) * np.uint64(0xBF58476D1CE4E5B9)
    return x ^ (x >> np.uint64(32))


def prefix_hashes(tokens: np.ndarray, page: int) -> np.ndarray:
    """Cumulative hash at each page boundary.  tokens [S] -> [S//page]."""
    s = (len(tokens) // page) * page
    if s == 0:
        return np.zeros((0,), np.int64)
    with np.errstate(over="ignore"):        # uint64 wraparound is the hash
        t = np.asarray(tokens[:s], np.uint64).reshape(-1, page)
        # hash each page's content, then chain cumulatively
        h = np.full((t.shape[0],), np.uint64(0xCBF29CE484222325))
        for j in range(page):
            h = _mix64(h, t[:, j])
        out = np.empty_like(h)
        acc = np.uint64(0x2545F4914F6CDD1D)
        for i in range(len(h)):
            acc = _mix64(acc, h[i])
            out[i] = acc
    return out.astype(np.int64)


@dataclasses.dataclass
class PagePool:
    """Device-resident KV pages for all layers: the cache's row batches."""

    k: jax.Array          # [L, num_pages, page, Hkv, D]
    v: jax.Array
    page: int
    free: list            # host-side free list of page ids

    @staticmethod
    def create(layers: int, num_pages: int, page: int, hkv: int, d: int,
               dtype=jnp.bfloat16) -> "PagePool":
        return PagePool(
            k=jnp.zeros((layers, num_pages, page, hkv, d), dtype),
            v=jnp.zeros((layers, num_pages, page, hkv, d), dtype),
            page=page, free=list(range(num_pages)))

    def alloc(self, n: int) -> list[int]:
        if len(self.free) < n:
            raise RuntimeError("KV page pool exhausted")
        ids, self.free = self.free[:n], self.free[n:]
        return ids

    def release(self, ids):
        self.free.extend(int(i) for i in ids)

    def write_pages(self, layer_k, layer_v, page_ids):
        """Insert prefill KV into pages.  layer_k: [L, B=1 folded, S, Hkv, D]
        with S a multiple of `page`; page_ids: [S/page] ints."""
        l, s, hkv, d = layer_k.shape
        np_ = s // self.page
        kp = layer_k.reshape(l, np_, self.page, hkv, d)
        vp = layer_v.reshape(l, np_, self.page, hkv, d)
        ids = jnp.asarray(page_ids, jnp.int32)
        self.k = self.k.at[:, ids].set(kp.astype(self.k.dtype))
        self.v = self.v.at[:, ids].set(vp.astype(self.v.dtype))
        return self


class PrefixCache:
    """The indexed cache: prefix_hash -> page entries, MVCC appends.

    Built on the public ``IndexedFrame`` facade (DESIGN.md §11) — the
    serving layer is a consumer of the paper's dataframe API, not of the
    internal free functions.
    """

    def __init__(self, rows_per_batch: int = 256):
        self.rows_per_batch = rows_per_batch
        self.frame = None            # lazily created on first commit
        self._released: set[int] = set()   # page ids handed back to the pool

    @property
    def table(self):
        """The wrapped IndexedTable (back-compat for stats/introspection)."""
        return None if self.frame is None else self.frame.data

    # -- writes ----------------------------------------------------------
    def commit(self, hashes: np.ndarray, page_ids: list[int], seq_id: int):
        """Register a sequence's pages (one MVCC append — paper §III-E)."""
        n = len(hashes)
        cols = {"prefix_hash": np.asarray(hashes, np.int64),
                "page_id": np.asarray(page_ids, np.int32),
                "page_index": np.arange(n, dtype=np.int32),
                "seq_id": np.full(n, seq_id, np.int32)}
        if self.frame is None:
            self.frame = IndexedFrame.from_columns(
                cols, PAGE_SCHEMA, rows_per_batch=self.rows_per_batch)
        else:
            self.frame = self.frame.append(cols)
        return int(self.frame.version)

    # -- reads -----------------------------------------------------------
    def lookup_prefix(self, tokens: np.ndarray, page: int):
        """Longest cached page-aligned prefix of ``tokens``.

        Returns (num_cached_pages, page_ids [num_cached_pages]).  One
        vectorized probe over every boundary hash (the paper's batched
        point lookup), then take the longest contiguous run of hits.
        """
        if self.frame is None:
            return 0, np.zeros((0,), np.int32)
        hs = prefix_hashes(tokens, page)
        if len(hs) == 0:
            return 0, np.zeros((0,), np.int32)
        cols, valid = self.frame.lookup(jnp.asarray(hs), max_matches=1)
        hit = np.asarray(valid[:, 0])
        pid = np.asarray(cols["page_id"][:, 0])
        n = 0
        # a committed-then-released page must never resurface as a hit:
        # the index row still exists (MVCC appends are immutable) but the
        # page's KV contents are gone, so the usable prefix stops there
        while n < len(hs) and hit[n] and int(pid[n]) not in self._released:
            n += 1
        return n, pid[:n].astype(np.int32)

    def release(self, page_ids):
        """Hand pages back (eviction / sequence teardown): their index
        entries stay — the MVCC log is immutable — but ``lookup_prefix``
        stops treating them as cached."""
        self._released.update(int(i) for i in page_ids)

    def memory_overhead_bytes(self) -> int:
        return 0 if self.frame is None else self.frame.index_nbytes()
