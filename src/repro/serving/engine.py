"""Serving engine: batched decode with the indexed prefix/KV cache.

Two decode paths, one contract:

  * ``make_serve_step`` — dense-cache decode step for *any* family
    (gqa/mla/ssm/hybrid/whisper).  This is what the dry-run lowers for the
    decode_32k / long_500k shapes: one new token against a seq_len KV
    cache, global-view shardable.
  * ``paged_decode_step`` — the paged fast path for uniform GQA models:
    attention reads KV pages straight from the PagePool via the Pallas
    kernel (kernels/decode_attention.py), i.e. serving *consumes the
    indexed cache's row batches on-TPU*.  Pages are resolved once per
    request by PrefixCache.lookup_prefix (the paper's point lookup), not
    per token.

The host-side ``Engine`` glues them: request admission, prefix-cache
lookup (skip cached pages), prefill, page commit (MVCC append), batched
decode.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops
from repro.models import transformer as tf
from repro.models import rope as rp
from repro.models.common import ModelConfig, rms_norm, swiglu
from repro.serving.kvcache import PagePool, PrefixCache, prefix_hashes


# ---------------------------------------------------------------------------
# Dense serve step (the dry-run path)
# ---------------------------------------------------------------------------

def make_serve_step(cfg: ModelConfig):
    """(params, caches, last_tok [B,1]) -> (logits, caches)."""
    if cfg.encoder_decoder:
        from repro.models import whisper as wh

        def serve_step(params, caches, last_tok):
            return wh.decode_step(params, cfg, last_tok, caches)
    else:
        def serve_step(params, caches, last_tok):
            return tf.decode_step(params, cfg, last_tok, caches)
    return serve_step


# ---------------------------------------------------------------------------
# Paged decode (GQA fast path over the indexed cache's pages)
# ---------------------------------------------------------------------------

def paged_decode_step(params, cfg: ModelConfig, last_tok, pool: PagePool,
                      page_tables, lengths, *, interpret: bool | None = None):
    """One decode step reading/writing KV pages in place.

    last_tok    : [B, 1] int32
    pool        : PagePool (k/v: [L, P, page, Hkv, D])
    page_tables : [B, MAXP] int32 (-1 padded) — resolved by PrefixCache
    lengths     : [B] int32 current sequence lengths
    returns (logits [B, 1, V], new pool)

    Restriction: uniform dense GQA models (one scan group, no window) —
    the fast-path regime; other families use the dense path.
    """
    groups = tf.scan_groups(cfg)
    assert len(groups) == 1 and groups[0][0].attn == "gqa" \
        and groups[0][0].ffn == "dense" and groups[0][0].window is None, \
        "paged fast path supports uniform GQA stacks"
    kind = groups[0][0]
    page = pool.page
    b = last_tok.shape[0]

    x = tf._embed(params, cfg, last_tok)                   # [B, 1, D]
    pids = page_tables[jnp.arange(b), lengths // page]     # [B]
    offs = lengths % page                                  # [B]

    def body(carry, inp):
        x = carry
        pl, kp, vp = inp                                   # kp: [P,page,Hkv,D]
        h = rms_norm(x, pl["ln1"], cfg.norm_eps)
        from repro.models.attention import gqa_project_qkv
        q, k_new, v_new = gqa_project_qkv(
            pl["attn"], h, cfg, lengths[:, None], kind.theta)
        kp = kp.at[pids, offs].set(k_new[:, 0].astype(kp.dtype))
        vp = vp.at[pids, offs].set(v_new[:, 0].astype(vp.dtype))
        out = ops.decode_attention(
            q[:, 0], kp, vp, page_tables, lengths + 1,
            cfg.head_dim ** -0.5, interpret=interpret)     # [B, Hq, D]
        out = out.reshape(b, 1, cfg.q_dim).astype(x.dtype)
        x = x + jnp.einsum("bsq,qd->bsd", out, pl["attn"]["wo"])
        h2 = rms_norm(x, pl["ln2"], cfg.norm_eps)
        x = x + swiglu(h2, pl["ffn"]["w_gate"], pl["ffn"]["w_up"],
                       pl["ffn"]["w_down"])
        return x, (kp, vp)

    x, (new_k, new_v) = jax.lax.scan(
        body, x, (params["groups"][0], pool.k, pool.v))
    pool = dataclasses.replace(pool, k=new_k, v=new_v)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return tf._logits(params, cfg, x), pool


# ---------------------------------------------------------------------------
# Host-side engine
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Request:
    seq_id: int
    prompt: np.ndarray            # [S] int32
    max_new: int = 16
    out: list = dataclasses.field(default_factory=list)


class Engine:
    """Batched serving with indexed prefix reuse (paper-cache-as-KV-cache)."""

    def __init__(self, params, cfg: ModelConfig, *, num_pages: int = 256,
                 page: int = 16, max_pages_per_seq: int = 32,
                 interpret: bool | None = None):
        self.params, self.cfg = params, cfg
        self.page, self.maxp = page, max_pages_per_seq
        self.pool = PagePool.create(cfg.num_layers, num_pages, page,
                                    cfg.num_kv_heads, cfg.head_dim,
                                    dtype=jnp.float32)
        self.cache = PrefixCache()
        self.interpret = interpret
        self.stats = {"pages_reused": 0, "pages_computed": 0,
                      "prefill_tokens_skipped": 0}

    # -- admission --------------------------------------------------------
    def admit(self, req: Request):
        """Prefill with prefix reuse; returns (page_table [MAXP], length)."""
        cfg, page = self.cfg, self.page
        n_cached, cached_ids = self.cache.lookup_prefix(req.prompt, page)
        self.stats["pages_reused"] += n_cached
        self.stats["prefill_tokens_skipped"] += n_cached * page

        # full prefill for simplicity of KV extraction; cached pages are
        # *not recomputed* in the page pool (they're shared), only new ones
        # are written.  (A production engine would prefill the suffix only;
        # the page-sharing bookkeeping is identical.)
        toks = jnp.asarray(req.prompt[None, :], jnp.int32)
        _, caches = tf.prefill(self.params, cfg, toks)
        # caches: list per scan group, dict k: [n, B, S, Hkv, D]
        k = jnp.concatenate([c["k"][:, 0] for c in caches], axis=0)
        v = jnp.concatenate([c["v"][:, 0] for c in caches], axis=0)

        s_full = (len(req.prompt) // page) * page
        n_new = s_full // page - n_cached
        new_ids = self.pool.alloc(max(n_new, 0) + 1)  # +1 decode page
        if n_new > 0:
            lo = n_cached * page
            self.pool = self.pool.write_pages(
                k[:, lo:s_full], v[:, lo:s_full], new_ids[:n_new])
            self.stats["pages_computed"] += n_new
            hs = prefix_hashes(req.prompt, page)
            self.cache.commit(hs[n_cached:], new_ids[:n_new], req.seq_id)

        # tail tokens (not page aligned) go into the decode page
        tail = len(req.prompt) - s_full
        decode_page = new_ids[-1]
        if tail:
            l, _, hkv, d = k.shape
            pad = page - tail
            kt = jnp.pad(k[:, s_full:], ((0, 0), (0, pad), (0, 0), (0, 0)))
            vt = jnp.pad(v[:, s_full:], ((0, 0), (0, pad), (0, 0), (0, 0)))
            self.pool = self.pool.write_pages(kt, vt, [decode_page])

        pt = np.full((self.maxp,), -1, np.int32)
        ids = list(cached_ids) + new_ids[:n_new] + [decode_page]
        pt[:len(ids)] = ids
        return pt, len(req.prompt)

    # -- batched decode ---------------------------------------------------
    def run(self, requests: list[Request], steps: int):
        cfg = self.cfg
        pts, lens = [], []
        for r in requests:
            pt, ln = self.admit(r)
            pts.append(pt)
            lens.append(ln)
        page_tables = jnp.asarray(np.stack(pts))
        lengths = jnp.asarray(np.asarray(lens, np.int32))
        # greedy last token of each prompt
        last = jnp.asarray(np.stack([r.prompt[-1:] for r in requests]))

        for _ in range(steps):
            # grow page tables when a sequence crosses a page boundary
            need = np.asarray((lengths % self.page) == 0)
            if need.any():
                pts = np.asarray(page_tables)
                for i in np.nonzero(need)[0]:
                    slot = int(lengths[i]) // self.page
                    if pts[i, slot] < 0:
                        pts[i, slot] = self.pool.alloc(1)[0]
                page_tables = jnp.asarray(pts)
            logits, self.pool = paged_decode_step(
                self.params, cfg, last, self.pool, page_tables, lengths,
                interpret=self.interpret)
            nxt = jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32)
            for i, r in enumerate(requests):
                r.out.append(int(nxt[i]))
            last = nxt[:, None]
            lengths = lengths + 1
        return requests
