"""qwen1.5-4b [dense] — QKV bias.

Assignment: 40L d_model=2560 20H (GQA kv=20) d_ff=6912 vocab=151936
[hf:Qwen/Qwen1.5-4B].  head_dim=128; rope theta 5e6 (hf).
"""

from repro.models.common import ModelConfig

ID = "qwen1.5-4b"


def full() -> ModelConfig:
    return ModelConfig(
        name=ID, family="dense", num_layers=40, d_model=2560,
        num_heads=20, num_kv_heads=20, head_dim=128,
        d_ff=6912, vocab_size=151936, qkv_bias=True, rope_theta=5e6,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ID + "-smoke", family="dense", num_layers=3, d_model=64,
        num_heads=4, num_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=128, qkv_bias=True, rope_theta=5e6,
        dtype="float32",
    )
