"""qwen2-vl-2b [vlm] — M-RoPE, dynamic resolution (frontend stubbed).

Assignment: 28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936
[arXiv:2409.12191; hf:Qwen/Qwen2-VL-2B].  head_dim=128; M-RoPE sections
(t,h,w) = (16, 24, 24).  The vision tower is a STUB: input_specs()
supplies precomputed patch embeddings [B, P, d_model] + the 3-stream
position ids.
"""

from repro.models.common import ModelConfig

ID = "qwen2-vl-2b"


def full() -> ModelConfig:
    return ModelConfig(
        name=ID, family="vlm", num_layers=28, d_model=1536,
        num_heads=12, num_kv_heads=2, head_dim=128,
        d_ff=8960, vocab_size=151936, qkv_bias=True, rope_theta=1e6,
        mrope_sections=(16, 24, 24), tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ID + "-smoke", family="vlm", num_layers=3, d_model=64,
        num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=128, qkv_bias=True, rope_theta=1e6,
        mrope_sections=(2, 3, 3), tie_embeddings=True, dtype="float32",
    )
