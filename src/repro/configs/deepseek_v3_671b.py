"""deepseek-v3-671b [moe] — MLA, 1 shared + 256 routed top-8, MTP.

Assignment: 61L d_model=7168 128H (GQA kv=128) d_ff=2048 vocab=129280,
MoE 256e top-8  [arXiv:2412.19437; hf].  The assignment's d_ff=2048 is the
routed-expert intermediate size; the 3 leading dense layers use 18432
(hf: deepseek-ai/DeepSeek-V3 first_k_dense_replace=3,
intermediate_size=18432, moe_intermediate_size=2048).
"""

from repro.models.common import MLAConfig, ModelConfig, MoEConfig

ID = "deepseek-v3-671b"


def full() -> ModelConfig:
    return ModelConfig(
        name=ID, family="moe", num_layers=61, d_model=7168,
        num_heads=128, num_kv_heads=128, head_dim=128,
        d_ff=18432, vocab_size=129280, rope_theta=1e4,
        mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512,
                      qk_nope_head_dim=128, qk_rope_head_dim=64,
                      v_head_dim=128),
        moe=MoEConfig(num_experts=256, top_k=8, d_ff_expert=2048,
                      num_shared=1, first_dense_layers=3,
                      router="sigmoid", router_aux_free_bias=True),
        mtp_depth=1,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ID + "-smoke", family="moe", num_layers=4, d_model=64,
        num_heads=4, num_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=128, rope_theta=1e4,
        mla=MLAConfig(q_lora_rank=32, kv_lora_rank=32,
                      qk_nope_head_dim=16, qk_rope_head_dim=8,
                      v_head_dim=16),
        moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=32,
                      num_shared=1, first_dense_layers=1,
                      router="sigmoid", router_aux_free_bias=True),
        mtp_depth=1, dtype="float32",
    )
