"""configs — the 10 assigned architectures (+ smoke variants) and shapes.

``get_config(arch_id)`` / ``get_smoke(arch_id)`` resolve ``--arch`` names;
``shapes.input_specs(cfg, shape)`` builds the dry-run stand-ins.
"""

from repro.configs import (deepseek_v3_671b, deepseek_v2_lite_16b,
                           qwen3_0_6b, gemma3_4b, qwen1_5_4b,
                           tinyllama_1_1b, qwen2_vl_2b, mamba2_370m,
                           jamba_v0_1_52b, whisper_large_v3)
from repro.configs import shapes
from repro.configs.shapes import SHAPES, applicable, input_specs

_MODULES = [deepseek_v3_671b, deepseek_v2_lite_16b, qwen3_0_6b, gemma3_4b,
            qwen1_5_4b, tinyllama_1_1b, qwen2_vl_2b, mamba2_370m,
            jamba_v0_1_52b, whisper_large_v3]

REGISTRY = {m.ID: m for m in _MODULES}
ARCH_IDS = list(REGISTRY)


def get_config(arch_id: str):
    if arch_id not in REGISTRY:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    return REGISTRY[arch_id].full()


def get_smoke(arch_id: str):
    return REGISTRY[arch_id].smoke()


__all__ = ["REGISTRY", "ARCH_IDS", "get_config", "get_smoke", "SHAPES",
           "applicable", "input_specs", "shapes"]
