"""mamba2-370m [ssm] — SSD (state-space duality), attention-free.

Assignment: 48L d_model=1024 (attn-free) d_ff=0 vocab=50280,
ssm_state=128 [arXiv:2405.21060; unverified].  Mamba2 block: expand=2,
head_dim=64, conv=4, n_groups=1.  The d_ff=0 assignment means no separate
MLP — the mamba mixer is the whole block; we honor that by setting the
ffn to a minimal identity-free gate... faithful mamba2 has NO MLP, so the
config drives layer_kinds to 'ssm' blocks only and d_ff is unused.
Sub-quadratic -> long_500k runs.
"""

from repro.models.common import ModelConfig, SSMConfig

ID = "mamba2-370m"


def full() -> ModelConfig:
    return ModelConfig(
        name=ID, family="ssm", num_layers=48, d_model=1024,
        num_heads=0, num_kv_heads=0, head_dim=0,
        d_ff=0, vocab_size=50280, tie_embeddings=True,
        ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64,
                      n_groups=1, chunk=256),
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ID + "-smoke", family="ssm", num_layers=4, d_model=64,
        num_heads=0, num_kv_heads=0, head_dim=0,
        d_ff=0, vocab_size=128, tie_embeddings=True,
        ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=16,
                      n_groups=1, chunk=8),
        dtype="float32",
    )
