"""Assigned input shapes and per-(arch × shape) input specs.

Four shapes per LM arch (assignment):
  train_4k     seq 4,096   global_batch 256   -> lowers train_step
  prefill_32k  seq 32,768  global_batch 32    -> lowers prefill
  decode_32k   seq 32,768  global_batch 128   -> lowers serve_step
                                                 (1 new token, KV = seq)
  long_500k    seq 524,288 global_batch 1     -> serve_step; only for
                                                 sub-quadratic archs

``input_specs`` returns ShapeDtypeStruct stand-ins (weak-type-correct, no
allocation) for every model input of that cell, plus which step function
the cell lowers.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # train | prefill | decode
    seq: int
    batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}

I32 = jnp.int32


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def applicable(cfg: ModelConfig, shape_name: str) -> bool:
    """Assignment skip rules (recorded in DESIGN.md §Arch-applicability)."""
    if shape_name == "long_500k":
        return cfg.sub_quadratic()
    return True


def batch_inputs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    """Training/prefill batch pytree as ShapeDtypeStructs."""
    b, s = shape.batch, shape.seq
    batch = {"tokens": _sds((b, s), I32)}
    if cfg.encoder_decoder:
        batch["frames"] = _sds((b, cfg.encoder_seq, cfg.d_model),
                               cfg.jnp_dtype)
    if cfg.family == "vlm":
        # patch embeddings fill the leading positions (frontend stub);
        # 1024 patches ~ one 1024x1024 image at 32x32 merge.
        p = min(1024, s // 2)
        batch["patch_emb"] = _sds((b, p, cfg.d_model), cfg.jnp_dtype)
        batch["mrope_positions"] = _sds((3, b, s), I32)
    return batch


def cache_specs(cfg: ModelConfig, batch: int, max_len: int) -> list:
    """Decode-cache pytree as ShapeDtypeStructs (mirrors tf.init_cache)."""
    from repro.models import transformer as tf
    if cfg.encoder_decoder:
        from repro.models import whisper as wh
        return jax.eval_shape(
            lambda: wh.init_cache(cfg, batch, max_len))
    return jax.eval_shape(lambda: tf.init_cache(cfg, batch, max_len))


def decode_inputs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    return {"last_tok": _sds((shape.batch, 1), I32),
            "caches": cache_specs(cfg, shape.batch, shape.seq)}


def params_specs(cfg: ModelConfig):
    from repro.train.step import init_params
    return jax.eval_shape(
        lambda: init_params(cfg, jax.random.PRNGKey(0)))


def input_specs(cfg: ModelConfig, shape_name: str) -> dict:
    """Everything dryrun needs for one cell: step kind + input pytrees."""
    shape = SHAPES[shape_name]
    if not applicable(cfg, shape_name):
        raise ValueError(f"{cfg.name} x {shape_name}: skipped "
                         "(full-attention arch at 500k; see DESIGN.md)")
    if shape.kind == "train":
        return {"kind": "train", "batch": batch_inputs(cfg, shape)}
    if shape.kind == "prefill":
        return {"kind": "prefill", "batch": batch_inputs(cfg, shape)}
    return {"kind": "decode", **decode_inputs(cfg, shape)}
