"""whisper-large-v3 [audio] — encoder-decoder, conv frontend stubbed.

Assignment: 32L d_model=1280 20H (GQA kv=20) d_ff=5120 vocab=51866
[arXiv:2212.04356; unverified].  32 encoder + 32 decoder layers,
head_dim=64, 1500 encoder frames.  input_specs() supplies post-conv frame
embeddings (the conv mel frontend is a STUB per the assignment).  decode
shapes lower the decoder step mechanically at the assigned seq_len even
though the real model caps at 448 positions (DESIGN.md §5).
"""

from repro.models.common import ModelConfig

ID = "whisper-large-v3"


def full() -> ModelConfig:
    return ModelConfig(
        name=ID, family="audio", num_layers=32, d_model=1280,
        num_heads=20, num_kv_heads=20, head_dim=64,
        d_ff=5120, vocab_size=51866, encoder_decoder=True,
        encoder_layers=32, encoder_seq=1500, tie_embeddings=True,
        # real model caps at 448 positions; the assigned decode shapes
        # lower mechanically at 32k, so the learned table is sized up
        max_pos=40960,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ID + "-smoke", family="audio", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=128, encoder_decoder=True,
        encoder_layers=2, encoder_seq=30, tie_embeddings=True,
        dtype="float32",
    )
