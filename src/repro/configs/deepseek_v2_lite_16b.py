"""deepseek-v2-lite-16b [moe] — MLA kv_lora=512, shared+routed top-6.

Assignment: 27L d_model=2048 16H (GQA kv=16) d_ff=1408 vocab=102400,
MoE 64e top-6 [arXiv:2405.04434; hf].  (The assignment note "160 routed"
matches full V2; Lite has 64 routed + 2 shared — we follow the 64e field
and hf: deepseek-ai/DeepSeek-V2-Lite.)  Lite has no q LoRA; first layer
dense with d_ff 10944.
"""

from repro.models.common import MLAConfig, ModelConfig, MoEConfig

ID = "deepseek-v2-lite-16b"


def full() -> ModelConfig:
    return ModelConfig(
        name=ID, family="moe", num_layers=27, d_model=2048,
        num_heads=16, num_kv_heads=16, head_dim=128,
        d_ff=10944, vocab_size=102400, rope_theta=1e4,
        mla=MLAConfig(q_lora_rank=0, kv_lora_rank=512,
                      qk_nope_head_dim=128, qk_rope_head_dim=64,
                      v_head_dim=128),
        moe=MoEConfig(num_experts=64, top_k=6, d_ff_expert=1408,
                      num_shared=2, first_dense_layers=1),
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ID + "-smoke", family="moe", num_layers=3, d_model=64,
        num_heads=4, num_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=128, rope_theta=1e4,
        mla=MLAConfig(q_lora_rank=0, kv_lora_rank=32,
                      qk_nope_head_dim=16, qk_rope_head_dim=8,
                      v_head_dim=16),
        moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=32,
                      num_shared=2, first_dense_layers=1),
        dtype="float32",
    )
