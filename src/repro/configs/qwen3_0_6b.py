"""qwen3-0.6b [dense] — qk_norm, GQA.

Assignment: 28L d_model=1024 16H (GQA kv=8) d_ff=3072 vocab=151936
[hf:Qwen/Qwen3-0.6B].  head_dim=128 (q_dim 2048 > d_model, per hf).
"""

from repro.models.common import ModelConfig

ID = "qwen3-0.6b"


def full() -> ModelConfig:
    return ModelConfig(
        name=ID, family="dense", num_layers=28, d_model=1024,
        num_heads=16, num_kv_heads=8, head_dim=128,
        d_ff=3072, vocab_size=151936, qk_norm=True, rope_theta=1e6,
        tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ID + "-smoke", family="dense", num_layers=3, d_model=64,
        num_heads=4, num_kv_heads=2, head_dim=32,
        d_ff=128, vocab_size=128, qk_norm=True, rope_theta=1e6,
        tie_embeddings=True, dtype="float32",
    )
