"""tinyllama-1.1b [dense] — llama2-arch small.

Assignment: 22L d_model=2048 32H (GQA kv=4) d_ff=5632 vocab=32000
[arXiv:2401.02385; hf:TinyLlama/TinyLlama-1.1B].  head_dim=64.
"""

from repro.models.common import ModelConfig

ID = "tinyllama-1.1b"


def full() -> ModelConfig:
    return ModelConfig(
        name=ID, family="dense", num_layers=22, d_model=2048,
        num_heads=32, num_kv_heads=4, head_dim=64,
        d_ff=5632, vocab_size=32000, rope_theta=1e4,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ID + "-smoke", family="dense", num_layers=3, d_model=64,
        num_heads=8, num_kv_heads=2, head_dim=8,
        d_ff=128, vocab_size=128, rope_theta=1e4, dtype="float32",
    )
