"""gemma3-4b [dense] — 5:1 local:global sliding window, 128k context.

Assignment: 34L d_model=2560 8H (GQA kv=4) d_ff=10240 vocab=262144
[hf:google/gemma-3-4b-pt; unverified].  head_dim=256; local window 1024
with theta 10k; global layers theta 1M (hf gemma-3 family defaults).
Sub-quadratic in the local layers -> long_500k runs for this arch.
"""

from repro.models.common import ModelConfig

ID = "gemma3-4b"


def full() -> ModelConfig:
    return ModelConfig(
        name=ID, family="dense", num_layers=34, d_model=2560,
        num_heads=8, num_kv_heads=4, head_dim=256,
        d_ff=10240, vocab_size=262144,
        local_global_pattern=5, sliding_window=1024,
        rope_theta=1e6, local_rope_theta=1e4, tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ID + "-smoke", family="dense", num_layers=6, d_model=64,
        num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=128,
        local_global_pattern=5, sliding_window=8,
        rope_theta=1e6, local_rope_theta=1e4, tie_embeddings=True,
        dtype="float32",
    )
