"""jamba-v0.1-52b [hybrid] — Mamba + attention 1:7 interleave, MoE.

Assignment: 32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536,
MoE 16e top-2 [arXiv:2403.19887; hf:ai21labs/Jamba-v0.1].  Attention every
8th layer at offset 4 (1:7 attn:mamba); MoE every 2nd layer; mamba blocks
d_state=16, conv=4, expand=2 (paper ships mamba-1; we use the SSD
formulation — DESIGN.md §2 hardware-adaptation note).  head_dim=128.
Sub-quadratic (7/8 of layers) -> long_500k runs.
"""

from repro.models.common import ModelConfig, MoEConfig, SSMConfig

ID = "jamba-v0.1-52b"


def full() -> ModelConfig:
    return ModelConfig(
        name=ID, family="hybrid", num_layers=32, d_model=4096,
        num_heads=32, num_kv_heads=8, head_dim=128,
        d_ff=14336, vocab_size=65536, rope_theta=1e4,
        attn_layer_period=8, attn_layer_offset=4,
        ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=64,
                      n_groups=1, chunk=256),
        moe=MoEConfig(num_experts=16, top_k=2, d_ff_expert=14336,
                      every_k=2),
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ID + "-smoke", family="hybrid", num_layers=8, d_model=64,
        num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=128, rope_theta=1e4,
        attn_layer_period=8, attn_layer_offset=4,
        ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=16,
                      n_groups=1, chunk=8),
        moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=64, every_k=2),
        dtype="float32",
    )
