"""IndexedFrame — ONE dataframe facade over both execution backends.

The paper's public object (Listing 1) is a single *Indexed DataFrame*
with ``createIndex / getRows / appendRows / join`` semantics; PRs 1-4
grew that into ~20 free functions split across ``repro.core`` (one
partition) and ``repro.dist`` (hash-partitioned shards), with every
caller hand-picking the backend AND the physical operator.  This module
is the seam that puts the paper's abstraction back on top (the same
place Modin's dataframe algebra and Cylon's unified local/distributed
API draw it):

* ``IndexedFrame.from_columns(cols, schema, num_shards=...)`` builds a
  local ``IndexedTable`` (``num_shards=1``) or a ``DistributedTable``
  behind the same handle.
* ``.lookup`` / ``.join`` route through the **Planner's physical-operator
  selection** (core/planner.py rules L1-L3 / J1-J3): the facade auto-picks
  local vs broadcast vs routed/shuffle per call from the query volume and
  shard count, and ``.plan_lookup(...).explain()`` names the rule that
  fired.  The free functions remain the stable internal layer — each
  facade method IS a thin dispatch onto one of them, bit-identical by
  test (tests/test_frame.py).
* ``.append`` is the MVCC write path (parent stays queryable); a *list*
  of deltas is coalesced host-side into ONE fused ingest launch, paying
  the per-append host round-trip once (``core.table.coalesce_deltas``).
* ``.filter/.select/.agg`` build ``core.planner`` logical trees over the
  frame's relation, with ``.explain()`` / ``.execute()``.
* ``.save/.load/.reshard`` delegate to ``dist.checkpoint``.

The frame is a registered pytree whose ONLY data field is the wrapped
table, so jitted call sites can take the frame itself as an argument:
facade dispatch adds zero retraces (the trace gate drives the fused read
sites through the Frame API — scripts/trace_gate.py).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hashing
from repro.core import joins
from repro.core import partition as partition_mod
from repro.core import planner as planner_mod
from repro.core import table as table_mod
from repro.core.pointers import PTR_DTYPE
from repro.core.schema import Schema

if False:  # annotations only (PEP 563 strings; dist itself loads lazily)
    from repro.dist import mesh

_LOOKUP_OPS = ("auto", "local", "bcast", "routed", "hybrid")
_JOIN_OPS = ("auto", "local", "bcast", "shuffle", "hybrid")

# re-exported for the facade surface: repro.PartitionSpec is the
# partition_by= argument type (core/partition.py, DESIGN.md §16)
PartitionSpec = partition_mod.PartitionSpec


def _dtable():
    """The distributed layer, imported on first distributed use — local
    frames (and repro.core-only consumers like serving/kvcache.py) never
    pull in repro.dist."""
    from repro.dist import dtable
    return dtable


def _checkpoint():
    from repro.dist import checkpoint
    return checkpoint


def _hash_string_cols(cols: dict, schema: Schema,
                      dictionary: "hashing.StringDictionary | None" = None
                      ) -> dict:
    """String-valued columns -> int64 FNV-1a keys, vectorized.

    The facade accepts raw string columns anywhere a delta enters
    (``from_columns`` / ``append`` / ``enqueue``) and hashes them in one
    numpy batch (``hashing.hash_strings_host``, bit-identical to the
    scalar ``hash_string_host`` loop) — the paper's Fig-15 string-ingest
    tax paid vectorized instead of per row.  Device arrays and numeric
    columns pass through untouched.  An optional
    ``hashing.StringDictionary`` caches vocabulary -> code across
    batches so repeated strings skip the byte-matrix hash entirely
    (codes stay bit-identical either way).
    """
    encode = (hashing.hash_strings_host if dictionary is None
              else dictionary.encode)
    out, changed = dict(cols), False
    for name, v in cols.items():
        if isinstance(v, jax.Array):
            continue
        a = np.asarray(v)
        if a.dtype.kind in "US" or (a.dtype.kind == "O" and a.size
                                    and isinstance(a.reshape(-1)[0], str)):
            out[name] = encode(a)
            changed = True
    return out if changed else cols


@dataclasses.dataclass(frozen=True)
class FramePlan:
    """A logical-plan builder over a frame's relation: chain ``filter`` /
    ``select`` / ``agg``, then ``explain()`` (which physical operators and
    why — the paper's ``df.explain`` verification) or ``execute()``."""

    node: Any
    planner: planner_mod.Planner

    def filter(self, pred) -> "FramePlan":
        return FramePlan(planner_mod.Filter(self.node, pred), self.planner)

    def select(self, *names) -> "FramePlan":
        return FramePlan(planner_mod.Project(self.node, tuple(names)),
                         self.planner)

    def agg(self, op: str, col: str) -> "FramePlan":
        return FramePlan(planner_mod.Aggregate(self.node, op, col),
                         self.planner)

    def plan(self) -> planner_mod.Physical:
        return self.planner.plan(self.node)

    def explain(self) -> str:
        return self.plan().explain()

    def execute(self):
        return self.planner.execute(self.node)


@partial(jax.tree_util.register_dataclass, data_fields=["data", "queue"],
         meta_fields=["rt"])
@dataclasses.dataclass(frozen=True)
class IndexedFrame:
    """The paper's Indexed DataFrame: one facade, either backend.

    ``data`` is the wrapped ``IndexedTable`` or ``DistributedTable`` (a
    pytree data field — successive MVCC versions of a frame stay
    structurally equal exactly when the wrapped table does, so jitted
    read sites taking the frame as an argument never retrace across
    in-class appends).  ``queue`` is the optional device-resident append
    ring (``core.table.AppendQueue``, DESIGN.md §13) behind
    ``enqueue``/``flush``/``append(queued=True)`` — also a data field
    with fixed lane shapes, so a frame streams deltas and flushes with
    ZERO treedef change (attaching a queue to a queue-less frame is the
    one-time treedef change, hence one retrace — do it before the jitted
    read loop, or at construction).  ``rt`` is the ``dist.mesh.Runtime``
    every distributed op executes under (treedef metadata; None = the
    vmap emulation backend).
    """

    data: Any
    rt: mesh.Runtime | None = None
    queue: Any = None

    # -- construction ---------------------------------------------------------

    @classmethod
    def from_columns(cls, cols: dict, schema: Schema, *, num_shards: int = 1,
                     rt: mesh.Runtime | None = None,
                     rows_per_batch: int = 4096, layout: str = "row",
                     slots: int | None = None, valid=None,
                     reserve: int | None = None,
                     track_hot: int | None = None,
                     hot_mode: str = "topk",
                     partition_by: partition_mod.PartitionSpec | None = None,
                     dictionary: "hashing.StringDictionary | None" = None
                     ) -> "IndexedFrame":
        """Paper Listing 1 ``createIndex``: build the index over a keyed
        columnar dict — one partition (``num_shards=1``) or hash-
        partitioned across shards, same handle either way.  ``track_hot``
        attaches a top-k hot-key tracker (DESIGN.md §15) counting
        subsequent ingest; ``hot_mode="sketch"`` uses the count-min
        fallback for unbounded key universes.

        ``partition_by`` (a ``core.partition.PartitionSpec``) builds a
        PARTITIONED frame instead: per-partition arenas grouped under a
        range/list partition map (DESIGN.md §16), pruned reads via
        planner rules P1-P3, and O(1) retention through
        ``drop_partition`` / ``retain``.  With ``num_shards > 1`` each
        partition is shard-stacked (partition-major, shard-minor).

        ``dictionary`` (a ``hashing.StringDictionary``) caches the
        string-column vocabulary -> int64 code table so repeated strings
        skip the FNV byte walk; pass the same dictionary to later
        ``append`` / ``enqueue`` calls to amortize across a stream
        (codes are bit-identical with or without it)."""
        cols = _hash_string_cols(cols, schema, dictionary)
        kw = {} if slots is None else {"slots": slots}
        if partition_by is not None:
            t = partition_mod.create_partitioned(
                cols, schema, partition_by, num_shards=num_shards, rt=rt,
                rows_per_batch=rows_per_batch, layout=layout, valid=valid,
                reserve=reserve, track_hot=track_hot, hot_mode=hot_mode,
                **kw)
        elif num_shards == 1:
            t = table_mod.create_index(
                cols, schema, rows_per_batch=rows_per_batch, layout=layout,
                valid=valid, reserve=reserve, track_hot=track_hot,
                hot_mode=hot_mode, **kw)
        else:
            t = _dtable().create_distributed(
                cols, schema, num_shards, rows_per_batch=rows_per_batch,
                layout=layout, valid=valid, reserve=reserve, rt=rt,
                track_hot=track_hot, hot_mode=hot_mode, **kw)
        return cls(data=t, rt=rt)

    # -- shape facts / passthroughs -------------------------------------------

    @property
    def is_distributed(self) -> bool:
        # duck-typed like the planner (_is_dist): DistributedTable is the
        # only backend with a shard count, and this keeps repro.dist out
        # of local frames' import graph.  A PartitionedTable has no
        # ``num_shards`` itself (its partitions may) — check
        # ``is_partitioned`` first when dispatching.
        return hasattr(self.data, "num_shards")

    @property
    def is_partitioned(self) -> bool:
        return isinstance(self.data, partition_mod.PartitionedTable)

    @property
    def num_shards(self) -> int:
        if self.is_partitioned:
            return self.data.shards_per_partition
        return self.data.num_shards if self.is_distributed else 1

    @property
    def num_partitions(self) -> int:
        return self.data.num_partitions if self.is_partitioned else 1

    @property
    def partition_ids(self) -> tuple:
        return self.data.partition_ids if self.is_partitioned else ()

    @property
    def schema(self) -> Schema:
        return self.data.schema

    @property
    def version(self):
        return self.data.version

    def num_rows(self):
        return self.data.num_rows()

    def index_nbytes(self, **kw) -> int:
        return self.data.index_nbytes(**kw)

    def data_nbytes(self, **kw) -> int:
        return self.data.data_nbytes(**kw)

    def with_flat_data(self) -> "IndexedFrame":
        """Materialize the snapshot's flat data (local frames) so jitted
        call sites taking the frame as an argument trace the whole fused
        pipeline as stored leaves; dist frames always carry it."""
        if self.is_distributed:
            return self
        return dataclasses.replace(self, data=self.data.with_flat_data())

    def _planner(self, planner: planner_mod.Planner | None,
                 max_matches: int = 64) -> planner_mod.Planner:
        if planner is not None:
            return planner
        return planner_mod.Planner(max_matches=max_matches, rt=self.rt)

    # -- reads: planner-routed physical operators -----------------------------

    def _forced_plan(self, op: str, ops: tuple, kinds: dict
                     ) -> planner_mod.Physical:
        """A Physical node for an explicitly forced flavor, rejecting ops
        the frame's backend cannot run (``kinds["local"]`` is the one
        single-partition operator; the rest need shards)."""
        if op not in ops:
            raise ValueError(f"op must be one of {ops}, got {op!r}")
        kind = kinds[op]
        wants_local = kind == kinds["local"]
        if wants_local == self.is_distributed:
            raise ValueError(
                f"op={op!r} needs a "
                f"{'local' if wants_local else 'distributed'} frame; "
                f"this frame has {self.num_shards} shard(s)")
        return planner_mod.Physical(kind, f"forced: op={op!r}", self.data)

    def _annotate(self, phys: planner_mod.Physical,
                  keys) -> planner_mod.Physical:
        """The uniform reason suffix every planned read carries:
        ``pending_ring_rows=N`` (rows staged in the ring, invisible until
        flush) and, for hybrid flavors with concrete keys, the measured
        ``hot_fraction`` — so ``explain()`` reads the same for every
        flavor.  Both are host facts; under a trace (the gated read sites
        drive planning with tracer keys) the hot fraction is skipped."""
        notes = [f"pending_ring_rows={self.pending_rows}"]
        if (phys.kind in ("HybridLookup", "HybridJoin")
                and not isinstance(keys, jax.core.Tracer)):
            frac = _dtable().hot_fraction(self.data, keys)
            notes.append(f"hot_fraction={frac:.2f}")
        return dataclasses.replace(
            phys, reason=phys.reason + "; " + " ".join(notes))

    def plan_lookup(self, keys, *, max_matches: int = 64, op: str = "auto",
                    planner: planner_mod.Planner | None = None
                    ) -> planner_mod.Physical:
        """The physical operator ``lookup`` would run for this query batch
        (rules L1-L4) — ``.explain()`` on the result names the rule."""
        if op == "auto":
            p = self._planner(planner, max_matches)
            phys = p.physical_lookup(self.data, int(jnp.shape(keys)[0]),
                                     keys=keys)
        elif self.is_partitioned:
            raise ValueError(
                f"a partitioned frame picks the per-partition flavor "
                f"itself (rule P1); op must be 'auto', got {op!r}")
        else:
            phys = self._forced_plan(op, _LOOKUP_OPS,
                                     {"local": "IndexedLookup",
                                      "bcast": "BroadcastLookup",
                                      "routed": "RoutedLookup",
                                      "hybrid": "HybridLookup"})
        return self._annotate(phys, keys)

    def lookup(self, keys, *, max_matches: int = 64, names=None,
               op: str = "auto",
               planner: planner_mod.Planner | None = None):
        """Paper Listing 1 ``getRows``: rows for each key, newest-first.

        Returns ``(cols [Q, max_matches], valid [Q, max_matches])`` on
        every backend and flavor; the Planner picks local vs broadcast vs
        routed (``op`` forces a flavor; ``plan_lookup`` explains).
        """
        joins.check_max_matches(max_matches)
        keys = joins.as_int64_keys(keys)
        kind = self.plan_lookup(keys, max_matches=max_matches, op=op,
                                planner=planner).kind
        if kind == "PartitionedLookup":
            return partition_mod.lookup_partitioned(
                self.data, keys, max_matches=max_matches, names=names,
                rt=self.rt, routed_threshold=self._planner(
                    planner, max_matches).routed_threshold)
        if kind == "IndexedLookup":
            return joins.indexed_lookup(self.data, keys,
                                        max_matches=max_matches, names=names)
        if kind == "BroadcastLookup":
            cols, valid, _ = _dtable().lookup(
                self.data, keys, max_matches=max_matches, names=names,
                rt=self.rt)
            return cols, valid
        if kind == "HybridLookup":
            return _dtable().lookup_hybrid_flat(
                self.data, keys, max_matches=max_matches, names=names,
                rt=self.rt)
        return _dtable().lookup_routed_flat(
            self.data, keys, max_matches=max_matches, names=names,
            rt=self.rt)

    def plan_join(self, probe_cols: dict, on: str, *, max_matches: int = 64,
                  op: str = "auto",
                  planner: planner_mod.Planner | None = None
                  ) -> planner_mod.Physical:
        """The physical operator ``join`` would run for this probe side
        (rules J1-J4)."""
        if op == "auto":
            p = self._planner(planner, max_matches)
            phys = p.physical_join(self.data,
                                   int(jnp.shape(probe_cols[on])[0]),
                                   keys=probe_cols[on])
        elif self.is_partitioned:
            raise ValueError(
                f"a partitioned frame picks the per-partition flavor "
                f"itself (rule P3); op must be 'auto', got {op!r}")
        else:
            phys = self._forced_plan(op, _JOIN_OPS,
                                     {"local": "IndexedJoin",
                                      "bcast": "BroadcastJoin",
                                      "shuffle": "ShuffleJoin",
                                      "hybrid": "HybridJoin"})
        return self._annotate(phys, probe_cols[on])

    def join(self, probe_cols: dict, on: str, *, max_matches: int = 64,
             names=None, op: str = "auto",
             planner: planner_mod.Planner | None = None):
        """Equi-join with this frame as the build side.

        Returns ``(build_cols [Q, M], probe_cols broadcast [Q, M],
        valid [Q, M])`` on every backend and flavor — the shuffle flavor
        routes probe keys to their owners and brings answers home
        (``dist.indexed_join_routed``), so results land in probe order
        like every other flavor.
        """
        joins.check_max_matches(max_matches)
        keys = joins.as_int64_keys(probe_cols[on])
        kind = self.plan_join(probe_cols, on, max_matches=max_matches,
                              op=op, planner=planner).kind
        if kind == "PartitionedJoin":
            return partition_mod.join_partitioned(
                self.data, probe_cols, on, max_matches=max_matches,
                names=names, rt=self.rt, routed_threshold=self._planner(
                    planner, max_matches).routed_threshold)
        if kind == "IndexedJoin":
            return joins.indexed_join(self.data, probe_cols, on,
                                      max_matches=max_matches, names=names)
        if kind == "BroadcastJoin":
            return _dtable().indexed_join_bcast(
                self.data, probe_cols, on, max_matches, names=names,
                rt=self.rt)
        if kind == "HybridJoin":
            return _dtable().indexed_join_hybrid(
                self.data, probe_cols, on, max_matches=max_matches,
                names=names, rt=self.rt)
        return _dtable().indexed_join_routed(
            self.data, probe_cols, on, max_matches=max_matches, names=names,
            rt=self.rt)

    # -- writes: MVCC appends, compaction -------------------------------------

    def append(self, cols, valid=None, *, donate: bool = False,
               mode: str = "arena", queued: bool = False,
               compact_threshold: int | None = None,
               dictionary: "hashing.StringDictionary | None" = None
               ) -> "IndexedFrame":
        """Paper Listing 1 ``appendRows``: functional append -> a new
        frame; the parent stays queryable (divergent MVCC children,
        Listing 2 — unless ``donate=True`` trades the parent for in-place
        buffer aliasing).

        ``cols`` may be a list/tuple of deltas: they are coalesced
        host-side (``core.table.coalesce_deltas``) and land through ONE
        fused ingest launch — one ``_arena_fits`` pre-flight and one
        ``int(fill)`` check for the whole batch, one version bump —
        instead of one host round-trip per delta (the ROADMAP's write-hot
        streams item).  ``valid`` is then a matching list of masks (or
        None).

        ``queued=True`` stages the delta in the device-resident ring
        instead (``enqueue`` — zero host syncs, invisible until
        ``flush``), auto-attaching a default ring and auto-flushing when
        the ring fills; an oversize delta flushes then lands directly
        (the documented lane-size bypass).  String-valued columns are
        hashed to int64 keys in one vectorized batch either way.

        Partitioned frames route the delta host-side on the partition
        column and land it in the receiving partitions only (one global
        version bump); they have no frame-level ring, so ``queued=True``
        degrades to the direct append.
        """
        queued = queued and not self.is_partitioned
        if queued:
            if isinstance(cols, (list, tuple)):
                fr = self
                for i, d in enumerate(cols):
                    fr = fr.append(d, None if valid is None else valid[i],
                                   queued=True, donate=donate,
                                   compact_threshold=compact_threshold,
                                   dictionary=dictionary)
                return fr
            try:
                return self.enqueue(cols, valid, donate=donate,
                                    dictionary=dictionary)
            except table_mod.QueueOverflow:
                fr = self.flush(compact_threshold=compact_threshold)
                try:
                    return fr.enqueue(cols, valid, donate=donate,
                                      dictionary=dictionary)
                except table_mod.QueueOverflow:
                    # oversize for a lane even when empty -> land directly
                    return fr.append(cols, valid, donate=donate,
                                     compact_threshold=compact_threshold,
                                     dictionary=dictionary)
        if isinstance(cols, (list, tuple)):
            cols, valid = table_mod.coalesce_deltas(
                [_hash_string_cols(d, self.schema, dictionary)
                 for d in cols],
                self.schema, valid)
        else:
            cols = _hash_string_cols(cols, self.schema, dictionary)
        if self.is_partitioned:
            if mode != "arena":
                raise ValueError(
                    f"partitioned append supports only mode='arena' "
                    f"(got {mode!r})")
            new = partition_mod.append_partitioned(
                self.data, cols, valid, rt=self.rt, donate=donate,
                compact_threshold=compact_threshold)
        elif self.is_distributed:
            if mode != "arena":
                raise ValueError(
                    f"distributed append supports only mode='arena' "
                    f"(got {mode!r}); the segment-chain reference path is "
                    f"single-partition")
            new = self._refreshed(_dtable().append_distributed(
                self.data, cols, valid, rt=self.rt, donate=donate,
                compact_threshold=compact_threshold))
        else:
            new = table_mod.append(self.data, cols, valid, mode=mode,
                                   donate=donate,
                                   compact_threshold=compact_threshold)
        return dataclasses.replace(self, data=new)

    # -- streaming ingest: the device-resident ring (DESIGN.md §13) ------------

    @property
    def pending_deltas(self) -> int:
        """Occupied ring lanes (0 for a queue-less frame) — host mirror,
        no device sync on the facade path."""
        return 0 if self.queue is None else table_mod.queue_pending(
            self.queue)[0]

    @property
    def pending_rows(self) -> int:
        """Valid rows staged in the ring, invisible to readers until
        ``flush`` (``plan_lookup`` reasons mention them)."""
        return 0 if self.queue is None else table_mod.queue_pending(
            self.queue)[1]

    def with_queue(self, *, lanes: int = table_mod.DEFAULT_QUEUE_LANES,
                   lane_rows: int | None = None) -> "IndexedFrame":
        """Attach a fresh device-resident append ring (idempotent on
        shape: an already-attached same-shape ring is kept).  This is the
        frame's ONE treedef change — do it before entering a jitted read
        loop and streaming stays retrace-free."""
        if self.is_partitioned:
            raise ValueError(
                "partitioned frames have no frame-level append ring (each "
                "partition keeps its own arena); use append — it routes "
                "and lands the delta per partition")
        lr = self.data.rows_per_batch if lane_rows is None else int(lane_rows)
        q = self.queue
        if q is not None and (q.lanes, q.lane_rows) == (lanes, lr):
            return self
        q = table_mod.empty_queue(
            self.schema, lanes=lanes, lane_rows=lr,
            num_shards=self.num_shards if self.is_distributed else None)
        return dataclasses.replace(self, queue=q)

    def enqueue(self, cols, valid=None, *, donate: bool = True,
                dictionary: "hashing.StringDictionary | None" = None
                ) -> "IndexedFrame":
        """Stage one delta in the ring — NO host sync, NO table change;
        rows become visible (one version bump for the whole ring) at
        ``flush``.  Auto-attaches a default ring on first use.  The ring
        is linearly owned, so the parent frame's ring is donated by
        default (``donate=False`` keeps it alive; the *table* is MVCC
        either way).  Raises ``core.table.QueueOverflow`` when full —
        ``append(queued=True)`` auto-flushes instead."""
        fr = self.with_queue() if self.queue is None else self
        cols = _hash_string_cols(cols, self.schema, dictionary)
        if fr.is_distributed:
            q = _dtable().enqueue_distributed(fr.data, fr.queue, cols, valid,
                                              rt=fr.rt, donate=donate)
        else:
            q = table_mod.enqueue(fr.queue, cols, valid, donate=donate)
        return dataclasses.replace(fr, queue=q)

    def flush(self, *, donate: bool = False,
              compact_threshold: int | None = None) -> "IndexedFrame":
        """Land the ring in the arena: ONE fused jit + ONE host sync (the
        overflow flag) for however many deltas are staged — vs one
        pre-flight + one fill check per ``append`` call.  Exactly one
        version bump; on capacity pressure the flush holds and the
        drained ring lands through the ordinary promote path
        (bit-identical either way).  ``donate=True`` hands the parent
        table state AND the ring to XLA (true in-place landing — only
        when no other frame aliases them).  Empty ring: no-op, returns
        self."""
        if self.queue is None or self.pending_deltas == 0:
            return self
        if self.is_distributed:
            data, q, _ = _dtable().flush_queue_distributed(
                self.data, self.queue, rt=self.rt, donate=donate,
                compact_threshold=compact_threshold)
            data = self._refreshed(data)
        else:
            data, q, _ = table_mod.flush_queue(
                self.data, self.queue, donate=donate,
                compact_threshold=compact_threshold)
        return dataclasses.replace(self, data=data, queue=q)

    def compact(self, *, reserve: int | None = None) -> "IndexedFrame":
        """Merge all segments into one fresh arena (bounds MVCC probe
        fan-out; DESIGN.md §4) — lookups bit-identical before and after.
        Partitioned frames compact per partition (one global version
        bump)."""
        if self.is_partitioned:
            return dataclasses.replace(self, data=partition_mod.
                                       compact_partitioned(
                                           self.data, rt=self.rt,
                                           reserve=reserve))
        if self.is_distributed:
            new = self._refreshed(_dtable().compact_distributed(
                self.data, rt=self.rt, reserve=reserve))
        else:
            new = table_mod.compact(self.data, reserve=reserve)
        return dataclasses.replace(self, data=new)

    # -- partitions: pruned reads, O(1) retention (DESIGN.md §16) --------------

    def _need_partitioned(self, what: str):
        if not self.is_partitioned:
            raise ValueError(f"{what} needs a partitioned frame; build "
                             f"with from_columns(partition_by=...)")

    def drop_partition(self, pid) -> "IndexedFrame":
        """O(1) retention: structurally remove one partition (by id or
        index) — one version bump, no compact, no data movement; the
        surviving partitions' read sites never recompile
        (gate_partition)."""
        self._need_partitioned("drop_partition")
        return dataclasses.replace(
            self, data=partition_mod.drop_partition(self.data, pid))

    def retain(self, *, min_value=None, keep=None) -> "IndexedFrame":
        """Rolling retention sweep: ``min_value`` drops every range
        partition wholly below it (the hot-recent-window expiry);
        ``keep`` names the surviving partition ids.  One version bump."""
        self._need_partitioned("retain")
        return dataclasses.replace(
            self, data=partition_mod.retain(self.data, min_value=min_value,
                                            keep=keep))

    def per_partition_bytes(self) -> list:
        """Logical vs reserved bytes per partition (memory accounting —
        arena slack in cold partitions stays attributed to them)."""
        self._need_partitioned("per_partition_bytes")
        return self.data.per_partition_bytes()

    # -- skew resilience: hot-key tracking + replication (DESIGN.md §15) -------

    def with_hot_tracker(self, top_k: int | None = None, *,
                         mode: str = "topk") -> "IndexedFrame":
        """Attach an exact top-k hot-key tracker (``mode="sketch"`` for
        the count-min fallback) counting subsequent ingest — ONE treedef
        change, like attaching a queue; do it at (or right after)
        construction so lineage replay reproduces the hot set."""
        if self.is_partitioned:
            raise ValueError("hot-key tracking is per-table; attach "
                             "track_hot at construction "
                             "(from_columns(track_hot=..., "
                             "partition_by=...)) to track every partition")
        k = table_mod.DEFAULT_HOT_TOP_K if top_k is None else int(top_k)
        if self.is_distributed:
            hot = table_mod.empty_tracker(k, mode=mode,
                                          num_shards=self.num_shards)
            data = dataclasses.replace(
                self.data, table=dataclasses.replace(self.data.table,
                                                     hot=hot))
        else:
            data = table_mod.with_hot(self.data, k, mode=mode)
        return dataclasses.replace(self, data=data)

    def with_replica(self, *, capacity: int | None = None,
                     max_matches: int | None = None) -> "IndexedFrame":
        """Attach the fixed-capacity hot-key mirror the hybrid flavors
        (rules L4/J4) answer hot queries from.  Starts stale (never
        consulted) until the first refresh; the facade auto-refreshes
        after every version bump from here on.  Needs a hot-key tracker
        and a distributed frame."""
        if not self.is_distributed:
            raise ValueError("with_replica needs a distributed frame "
                             "(a single partition has no exchange to skip)")
        dd = _dtable()
        kw = {}
        if capacity is not None:
            kw["capacity"] = int(capacity)
        if max_matches is not None:
            kw["max_matches"] = int(max_matches)
        return dataclasses.replace(self, data=dd.attach_replica(self.data,
                                                                **kw))

    def refresh_replica(self) -> "IndexedFrame":
        """Re-mirror the current global top-H hot keys at the live
        version (one cached jit call, zero host syncs) — normally
        implicit: ``append``/``flush``/``compact`` refresh automatically
        when a mirror is attached."""
        return dataclasses.replace(
            self, data=_dtable().refresh_replica(self.data, rt=self.rt))

    def _refreshed(self, data):
        """Auto re-mirror after a version bump: a stale mirror is always
        SAFE (the hybrid degrades to pure routing) but cold — keeping it
        fresh on the write path is what keeps the Zipf sweep flat."""
        if getattr(data, "replica", None) is not None:
            data = _dtable().refresh_replica(data, rt=self.rt)
        return data

    # -- supervision (self-healing reads) --------------------------------------

    def supervised(self, *, lineage=None, policy=None, injector=None,
                   checkpoint_dir: str | None = None):
        """Wrap this distributed frame in a ``dist.resilience``
        ``RecoveryManager``: reads are version-fenced, integrity-probed,
        auto-healed (restore latest checkpoint + replay the lineage
        suffix + splice), and routed drops auto-retry with doubled
        capacity — failure handling as part of the operator contract
        instead of the caller's job (DESIGN.md §12).  The manager owns
        the live frame from here on (``manager.frame``).

        A PARTITIONED distributed frame heals per partition: one
        ``RecoveryManager`` per partition behind a
        ``PartitionedSupervisor`` whose reads route pruned sub-batches
        to the owning partition's manager — a fault in one partition
        never touches another partition's read path.  Inject faults per
        partition via ``supervisor.managers[i].injector``; pass
        ``lineage=True`` to auto-build one replay recipe per partition
        (a single frame-level ``Lineage`` cannot be split)."""
        from repro.dist import resilience
        if self.is_partitioned:
            if injector is not None or (lineage is not None
                                        and lineage is not True):
                raise ValueError(
                    "partitioned supervision is per partition: pass "
                    "lineage=True for auto per-partition lineages and "
                    "set supervisor.managers[i].injector for faults")
            return resilience.PartitionedSupervisor(
                self, policy=policy, checkpoint_dir=checkpoint_dir,
                with_lineage=lineage is True)
        return resilience.RecoveryManager(
            self, lineage=lineage, policy=policy, injector=injector,
            checkpoint_dir=checkpoint_dir)

    def serve(self, **kw):
        """Wrap this frame in a ``serving.query_engine.QueryEngine``:
        FIFO admission from many client streams, pad-to-bucket
        micro-batching into the fused read sites (one trace per bucket),
        writer deltas interleaved through the append ring (reads ride
        the pre-flush snapshot), p50/p99 SLO accounting (DESIGN.md §14).
        The engine owns the frame from here on (``engine.frame``);
        a supervised frame serves via ``frame.supervised(...).serve()``
        — i.e. ``QueryEngine(manager, **kw)``."""
        from repro.serving.query_engine import QueryEngine
        return QueryEngine(self, **kw)

    # -- relational plans ------------------------------------------------------

    def relation(self, name: str = "frame") -> planner_mod.Relation:
        """This frame as a ``core.planner`` Relation leaf (either
        backend; the planner dispatches on it)."""
        return planner_mod.Relation(name, table=self.data)

    def filter(self, pred, *,
               planner: planner_mod.Planner | None = None) -> FramePlan:
        return FramePlan(planner_mod.Filter(self.relation(), pred),
                         self._planner(planner))

    def select(self, *names,
               planner: planner_mod.Planner | None = None) -> FramePlan:
        return FramePlan(planner_mod.Project(self.relation(), tuple(names)),
                         self._planner(planner))

    def agg(self, op: str, col: str, *,
            planner: planner_mod.Planner | None = None) -> FramePlan:
        return FramePlan(planner_mod.Aggregate(self.relation(), op, col),
                         self._planner(planner))

    # -- persistence / elasticity ---------------------------------------------

    def save(self, path: str):
        """Checkpoint the frame's table (dist.checkpoint leaf format;
        partitioned frames save one CRC-verified subdir per partition
        plus the spec)."""
        if self.is_partitioned:
            partition_mod.save_partitioned(path, self.data)
        elif self.is_distributed:
            _checkpoint().save_dtable(path, self.data)
        else:
            _checkpoint().save_table(path, self.data)

    @classmethod
    def load(cls, path: str, like: "IndexedFrame") -> "IndexedFrame":
        """Restore a checkpoint into ``like``'s structure (``like``
        supplies the treedef AND the runtime, exactly as
        ``dist.checkpoint.restore_dtable``)."""
        if like.is_partitioned:
            data = partition_mod.restore_partitioned(path, like.data)
        elif like.is_distributed:
            data = _checkpoint().restore_dtable(path, like.data)
        else:
            data = _checkpoint().restore_table(path, like.data)
        return dataclasses.replace(like, data=data)

    def reshard(self, num_shards: int, *,
                rt_out: mesh.Runtime | None = None) -> "IndexedFrame":
        """Elastic scale: re-route every valid row into a ``num_shards``
        topology (``dist.checkpoint.reshard_dtable``; a local frame is
        promoted by the same collect -> re-route -> re-index pass).  The
        global MVCC version is preserved.  A pending append ring is
        flushed first (its lane shapes are per-topology), and the
        resharded frame comes back queue-less — ``with_queue()`` again
        on the new topology."""
        self = self.flush()
        if self.is_partitioned:
            # per-partition reshard: each partition re-routes its own rows
            # into the new topology (partition-major, shard-minor); the
            # global MVCC version is preserved
            parts = tuple(
                IndexedFrame(data=p, rt=self.rt)
                .reshard(num_shards, rt_out=rt_out).data
                for p in self.data.parts)
            pt = dataclasses.replace(self.data, parts=parts)
            return IndexedFrame(data=pt, rt=rt_out)
        dd = _dtable() if self.is_distributed else None
        if self.is_distributed:
            old = self.data
            new = _checkpoint().reshard_dtable(self.data, num_shards, rt=self.rt,
                                      rt_out=rt_out)
            if old.table.hot is not None:
                # carry the hot set into the new topology: re-route the
                # tracker entries to their new owners (counts survive as
                # exact lower bounds; DESIGN.md §15)
                new = dataclasses.replace(new, table=dataclasses.replace(
                    new.table,
                    hot=dd.reseed_tracker(old.table.hot, num_shards)))
            if old.replica is not None:
                new = dd.attach_replica(
                    new, capacity=old.replica.keys.shape[0],
                    max_matches=old.replica.max_matches)
                new = dd.refresh_replica(new, rt=rt_out)
            return IndexedFrame(data=new, rt=rt_out)
        t = self.data
        valid_all = np.concatenate([np.asarray(s.valid)
                                    for s in t.segments])
        bases = np.concatenate([np.asarray(s.row_base
                                           + np.arange(s.capacity))
                                for s in t.segments])
        cols = t.gather_rows(jnp.asarray(bases[valid_all], PTR_DTYPE))
        dt = _dtable().create_distributed(
            {k: np.asarray(v) for k, v in cols.items()}, t.schema,
            num_shards, rows_per_batch=t.rows_per_batch, layout=t.layout,
            slots=t.slots, rt=rt_out)
        dt = dataclasses.replace(
            dt, version=jnp.asarray(int(np.asarray(t.version)), jnp.int32))
        if t.hot is not None:
            dt = dataclasses.replace(dt, table=dataclasses.replace(
                dt.table, hot=_dtable().reseed_tracker(t.hot, num_shards)))
        return IndexedFrame(data=dt, rt=rt_out)
