"""IndexedTable — one partition of the Indexed DataFrame.

Paper §III-C: a partition is (1) a cTrie index pointing at the *latest* row
per key, (2) row batches holding the tabular data, (3) backward pointers
chaining equal-key rows.  Paper §III-E: appends snapshot the index so
divergent children share the parent's state and store only deltas.

TPU adaptation (DESIGN.md §2/§4): a partition is an ordered tuple of
**capacity-reserved arena segments**.  ``create_index`` builds segment 0
over-allocated to a power-of-two capacity class; ``append`` within the
reserved capacity is a jit-compiled fused on-device ingest — hash the
delta, write its bucket/chain planes, link parent heads, bump the
``fill`` scalar — with ZERO pytree shape change, so jitted read sites
compile once per class instead of once per version.  Capacity exhaustion
seals the tail and opens the next class (one recompile, geometric
amortization); past a segment-count threshold the table compacts.  The
pre-arena path — one exactly-sized delta segment per append, parent
segments shared by reference (the paper's persistent-data-structure
scheme; Listing 2's divergent appends with no copy-on-write) — survives
as ``append(..., mode="segment")`` and anchors the equivalence property
tests.  Non-donated arena appends are equally functional (the parent is
never touched); ``donate=True`` trades the parent for true in-place
buffer aliasing.

Row storage is batch-granular: a segment's data is ``[num_batches,
rows_per_batch, width_words] int32`` (row layout) or per-column typed arrays
(columnar layout).  ``rows_per_batch`` is the paper's Fig-5 knob.

The read hot path (probe -> chain walk -> gather) runs **fused** over the
table's stored ``Snapshot`` (core/snapshot.py, DESIGN.md §3): ragged
per-segment bucket planes (split int64 keys), one flat backward-pointer
array, and optional contiguous data for single-gather decode.  The snapshot
is part of the table's *pytree form* — ``create_index`` builds it eagerly,
``append`` extends it incrementally — so jitted call sites that take the
table as an argument trace it as leaves instead of rebuilding it in-graph.
The original segment-looped methods survive as ``*_ref`` and anchor the
parity tests.

Everything here is written to be **vmap-friendly over a leading shard
axis**: the inner segment constructor is pure (no host branching), padding
rows carry ``valid=False`` and an EMPTY key, and the overflow-doubling retry
lives in thin host wrappers.  dist/dtable.py stacks whole tables (segments
AND snapshot) across shards and vmaps these same functions.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hashindex as hix
from repro.core import hashing
from repro.core import snapshot as snap_mod
from repro.core.hashindex import EMPTY_KEY, HashIndex
from repro.core.pointers import NULL_PTR, PTR_DTYPE
from repro.core.schema import Schema
from repro.core.snapshot import (FlatBlock, Snapshot, extend_snapshot,
                                 snapshot_from_segments)
# kernels only imports leaf core modules (hashing/hashindex/pointers/
# snapshot), so this does not cycle; importing here (not inside methods)
# keeps module constants from being created inside an active jit trace.
from repro.kernels import ops as kops
from repro.kernels import ref as kref

# Back-compat alias: PR-1 exported the probe-side view as ``FlatView``.
FlatView = Snapshot

# Logical (occupied-entry) index accounting, shared with the Fig-11
# benchmark so the formula lives in exactly one place:
INDEX_ENTRY_BYTES = 12   # int64 key + int32 ptr per occupied bucket slot
ROW_PTR_BYTES = 5        # int32 prev + bool valid per live row

# ---------------------------------------------------------------------------
# Segment
# ---------------------------------------------------------------------------

@partial(jax.tree_util.register_dataclass,
         data_fields=["data", "index", "prev", "valid"],
         meta_fields=["row_base", "layout"])
@dataclasses.dataclass(frozen=True)
class Segment:
    """One immutable append unit (segment 0 = the created index)."""

    data: object          # [nb, rpb, W] int32  |  dict[name -> [nb, rpb] typed]
    index: HashIndex      # delta index: key -> GLOBAL row id (latest in segment)
    prev: jax.Array       # [nb*rpb] int32 — backward ptrs, GLOBAL row ids
    valid: jax.Array      # [nb*rpb] bool — False for padding rows
    row_base: int         # global row id of this segment's row 0
    layout: str

    @property
    def capacity(self) -> int:
        return self.prev.shape[-1]

    def _row_bytes(self) -> int:
        """Bytes per row — shard-stack-agnostic (shape-tail based, so a
        dist layer's [num_shards, ...] leading axis doesn't inflate it)."""
        if self.layout == "row":
            return self.data.shape[-1] * 4
        return sum(a.dtype.itemsize for a in self.data.values())

    def data_nbytes(self, *, logical: bool = False):
        """Row-storage bytes.  ``logical=False`` (default) counts the full
        reserved planes; ``logical=True`` counts only valid rows — arenas
        over-allocate (DESIGN.md §4), and the paper's Fig-11 overhead claim
        is about logical bytes, not arena slack."""
        if logical:
            return jnp.sum(self.valid) * self._row_bytes()
        if self.layout == "row":
            return self.data.size * 4
        return sum(int(np.prod(a.shape)) * a.dtype.itemsize
                   for a in self.data.values())

    def index_nbytes(self, *, logical: bool = False):
        if logical:
            occupied = jnp.sum(self.index.bucket_keys != EMPTY_KEY)
            return (occupied * INDEX_ENTRY_BYTES
                    + jnp.sum(self.valid) * ROW_PTR_BYTES)
        return self.index.nbytes + self.prev.size * 4 + self.valid.size


@partial(jax.tree_util.register_dataclass,
         data_fields=["segments", "snapshot", "version", "hot"],
         meta_fields=["schema", "rows_per_batch", "layout", "slots"])
@dataclasses.dataclass(frozen=True)
class IndexedTable:
    """A fully functional (immutable) indexed partition with MVCC versions.

    ``snapshot`` is the stored read-optimized form (DESIGN.md §3): both the
    segments and the snapshot are pytree data, so the table round-trips
    through jit/vmap with the fused-path arrays as leaves.

    ``version`` is a *data leaf* (scalar int32), not treedef metadata
    (DESIGN.md §4): the arena append path bumps it on-device with zero
    pytree shape change, so successive versions stay structurally equal
    and every jitted read site taking the table as an argument keeps its
    compile-cache entry across appends.
    """

    segments: tuple[Segment, ...]
    snapshot: Snapshot
    version: jax.Array    # scalar int32 — paper §III-D MVCC version
    schema: Schema
    rows_per_batch: int
    layout: str           # "row" | "columnar"
    slots: int
    hot: object = None    # HotTracker | None — skew detection (§15)

    # -- shape facts ----------------------------------------------------------
    @property
    def capacity(self) -> int:
        return self.segments[-1].row_base + self.segments[-1].capacity

    @property
    def num_segments(self) -> int:
        return len(self.segments)

    @property
    def fill(self) -> jax.Array:
        """First unwritten global row id (scalar int32 leaf)."""
        return self.snapshot.fill

    def spare_capacity(self) -> int:
        """Reserved-but-unwritten rows left in the arena tail (host int —
        reads the ``fill`` scalar; appends are host-coordinated anyway)."""
        tail = self.segments[-1]
        return (tail.row_base + tail.capacity
                - int(jax.device_get(self.snapshot.fill)))

    def num_rows(self):
        """Valid (non-padding) rows; array under trace, int when concrete."""
        return sum(jnp.sum(s.valid) for s in self.segments)

    def data_nbytes(self, *, logical: bool = False):
        """Reserved row-storage bytes; ``logical=True`` counts valid rows
        only (Fig 11 must not be distorted by arena slack, DESIGN.md §4)."""
        return sum(s.data_nbytes(logical=logical) for s in self.segments)

    def index_nbytes(self, *, logical: bool = False):
        """Index memory overhead — the paper's Fig-11 measurement."""
        return sum(s.index_nbytes(logical=logical) for s in self.segments)

    # -- snapshot access (fused-path representation, DESIGN.md §3) -------------

    def flat_view(self) -> Snapshot:
        """The stored Snapshot for this version (a field access — the view
        is built eagerly by ``create_index`` and extended by ``append``)."""
        return self.snapshot

    def with_flat_data(self) -> "IndexedTable":
        """This table with the snapshot's flat data materialized.

        Use before passing the table as a jit *argument* to call sites that
        decode rows (``gather_rows`` / ``joins.indexed_lookup``): with the
        data on board, the whole fused pipeline traces as stored leaves —
        zero in-graph rebuilds.  Appends carry materialized data forward.
        This is the ONLY way the stored pytree gains the data leaf — host
        reads never mutate the table's structure (jit caches and captured
        treedefs stay valid).
        """
        if self.snapshot.data is not None:
            return self
        return dataclasses.replace(
            self, snapshot=dataclasses.replace(self.snapshot,
                                               data=self._flat_data()))

    def _flat_data(self):
        """Flat data for single-gather decode.  Prefers the snapshot's
        stored copy; otherwise builds it once and caches it on the host
        instance (``_flatdata``, deliberately OUTSIDE the pytree: the
        table's structure must not change as a side effect of a read)."""
        d = self.snapshot.data
        if d is not None:
            return d
        d = getattr(self, "_flatdata", None)
        if d is None:
            d = snap_mod.flat_data_from_segments(self.segments, self.schema,
                                                 self.layout)
            leaves = jax.tree_util.tree_leaves(d)
            if not any(isinstance(a, jax.core.Tracer) for a in leaves):
                object.__setattr__(self, "_flatdata", d)
        return d

    # -- point operations ------------------------------------------------------
    #
    # The default path is the FUSED one: probe -> chain walk -> gather runs
    # against the Snapshot in one pass (Pallas kernel on TPU, vectorized flat
    # gathers elsewhere).  The *_ref methods keep the original segment-looped
    # code as the semantic reference the parity tests sweep against.

    def probe_latest(self, keys, *, fused: bool = True) -> jax.Array:
        """Global row id of the *latest* row per key (NULL_PTR if absent).

        Probes delta indexes newest -> oldest and takes the first hit —
        the cTrie-snapshot read path of paper §III-E.
        """
        if not fused:
            return self.probe_latest_ref(keys)
        return kops.fused_probe(keys, self.snapshot)

    def probe_latest_ref(self, keys) -> jax.Array:
        """Segment-looped reference: one full probe per delta index."""
        keys = jnp.asarray(keys, jnp.int64)
        out = jnp.full(keys.shape, NULL_PTR, PTR_DTYPE)
        for seg in reversed(self.segments):
            hit = hix.probe(seg.index, keys)
            out = jnp.where(out == NULL_PTR, hit, out)
        return out

    def gather_prev(self, rids, *, fused: bool = True) -> jax.Array:
        """prev[rid] across segments (NULL for NULL/out-of-range input)."""
        if not fused:
            return self.gather_prev_ref(rids)
        prev = self.snapshot.prev
        cap = self.snapshot.capacity
        rids = jnp.asarray(rids, PTR_DTYPE)
        in_range = (rids >= 0) & (rids < self.snapshot.fill)
        got = prev[jnp.clip(rids, 0, cap - 1)]
        return jnp.where(in_range, got, NULL_PTR)

    def gather_prev_ref(self, rids) -> jax.Array:
        """Segment-looped reference: re-scans every segment per call."""
        rids = jnp.asarray(rids, PTR_DTYPE)
        out = jnp.full(rids.shape, NULL_PTR, PTR_DTYPE)
        for seg in self.segments:
            local = rids - seg.row_base
            in_seg = (local >= 0) & (local < seg.capacity)
            got = seg.prev[jnp.clip(local, 0, seg.capacity - 1)]
            out = jnp.where(in_seg, got, out)
        return out

    def lookup(self, keys, max_matches: int, *, fused: bool = True):
        """[Q] keys -> ([Q, max_matches] global row ids newest-first,
        truncated flags).  Paper's point-lookup: cTrie probe + backward-
        pointer traversal — fused into one pass over the Snapshot."""
        if not fused:
            return self.lookup_ref(keys, max_matches)
        return kops.fused_lookup(keys, self.snapshot,
                                 max_matches=max_matches)

    def lookup_ref(self, keys, max_matches: int):
        """Segment-looped reference lookup (the pre-fusion hot path)."""
        head = self.probe_latest_ref(keys)

        def step(cur, _):
            nxt = jnp.where(cur >= 0, self.gather_prev_ref(cur), NULL_PTR)
            return nxt, cur

        last, rows = jax.lax.scan(step, head, None, length=max_matches)
        return jnp.moveaxis(rows, 0, 1), last >= 0

    def gather_rows(self, rids, names=None, *, fused: bool = True) -> dict:
        """Decode rows for global row ids (zeros where rid out of range)."""
        if not fused:
            return self.gather_rows_ref(rids, names=names)
        data = self._flat_data()
        rids = jnp.asarray(rids, PTR_DTYPE)
        # fill-masked: reserved-but-unwritten arena lanes never decode
        # (with donation they may alias retired buffers — DESIGN.md §4)
        in_range = (rids >= 0) & (rids < self.snapshot.fill)
        safe = jnp.clip(rids, 0, self.capacity - 1)
        if self.layout == "row":
            flat = jnp.where(in_range[..., None], data[safe], 0)
            return self.schema.decode_rows(flat, names=names)
        out = {}
        for name in (names or self.schema.names):
            col = self.schema.column(name)
            out[name] = jnp.where(in_range, data[name][safe],
                                  jnp.zeros((), col.jnp_dtype))
        return out

    def gather_rows_ref(self, rids, names=None) -> dict:
        """Segment-looped reference: one masked pass per segment."""
        rids = jnp.asarray(rids, PTR_DTYPE)
        if self.layout == "row":
            w = self.schema.width_words
            flat = jnp.zeros(rids.shape + (w,), jnp.int32)
            for seg in self.segments:
                local = rids - seg.row_base
                in_seg = (local >= 0) & (local < seg.capacity)
                lc = jnp.clip(local, 0, seg.capacity - 1)
                got = seg.data.reshape(seg.capacity, w)[lc]
                flat = jnp.where(in_seg[..., None], got, flat)
            return self.schema.decode_rows(flat, names=names)
        out = {}
        for name in (names or self.schema.names):
            col = self.schema.column(name)
            acc = jnp.zeros(rids.shape, col.jnp_dtype)
            for seg in self.segments:
                local = rids - seg.row_base
                in_seg = (local >= 0) & (local < seg.capacity)
                lc = jnp.clip(local, 0, seg.capacity - 1)
                arr = seg.data[name].reshape(-1)
                acc = jnp.where(in_seg, arr[lc], acc)
            out[name] = acc
        return out

    def scan_column(self, name: str):
        """Full column scan (baseline path) -> (values, valid)."""
        parts, valid = [], []
        for seg in self.segments:
            if self.layout == "row":
                w = self.schema.width_words
                flat = seg.data.reshape(seg.capacity, w)
                vals = self.schema.decode_rows(flat, names=(name,))[name]
            else:
                vals = seg.data[name].reshape(-1)
            parts.append(vals)
            valid.append(seg.valid)
        return jnp.concatenate(parts), jnp.concatenate(valid)


# ---------------------------------------------------------------------------
# Segment construction (vmap-friendly core + host wrappers)
# ---------------------------------------------------------------------------

ARENA_GROWTH = 2
DEFAULT_COMPACT_THRESHOLD = 8


def pad_to_batches(n: int, rows_per_batch: int) -> int:
    nb = max(1, -(-n // rows_per_batch))
    return nb * rows_per_batch


def capacity_class(n_rows: int, rows_per_batch: int,
                   growth: int = ARENA_GROWTH) -> int:
    """Reserved arena capacity for ``n_rows``: the smallest power-of-two
    number of row batches covering ``growth * n_rows`` (DESIGN.md §4).
    Power-of-two classes mean a growing table visits O(log n) distinct
    plane shapes — one read-site recompile per class, geometrically
    amortized — and ``growth`` leaves headroom so appends land in the
    zero-shape-change in-place ingest instead of promoting immediately."""
    need = max(1, int(n_rows)) * growth
    nb = max(1, -(-need // rows_per_batch))
    return (1 << (nb - 1).bit_length()) * rows_per_batch


def prepare_cols(cols: dict, schema: Schema, rows_per_batch: int,
                 valid=None, *, min_capacity: int = 0):
    """Left-pack valid rows, pad columns to a batch multiple (at least
    ``min_capacity`` rows); returns (padded cols, valid, cap).

    Packing keeps the arena invariant — written lanes are exactly
    ``[0, valid_count)`` — and is a stable permutation, so per-key MVCC
    chain order (append order) is preserved.
    """
    n = int(next(iter(cols.values())).shape[0])
    cap = max(pad_to_batches(n, rows_per_batch),
              pad_to_batches(min_capacity, rows_per_batch)
              if min_capacity else 0)
    pad = cap - n
    if valid is not None:
        valid = jnp.asarray(valid, bool)
        order = jnp.argsort(~valid, stable=True)   # valid first, order kept
        cols = {c.name: jnp.asarray(cols[c.name], c.jnp_dtype)[order]
                for c in schema.columns}
        valid = valid[order]
    out = {}
    for c in schema.columns:
        a = jnp.asarray(cols[c.name], c.jnp_dtype)
        out[c.name] = jnp.pad(a, (0, pad))
    if valid is None:
        valid = jnp.ones((n,), bool)
    valid = jnp.pad(jnp.asarray(valid, bool), (0, pad))
    return out, valid, cap


def make_segment_arrays(cols: dict, valid, parent_heads, schema: Schema, *,
                        row_base: int, rows_per_batch: int, layout: str,
                        num_buckets: int, slots: int):
    """Pure segment constructor (jit/vmap-friendly).

    cols         : dict of [cap]-padded typed columns
    valid        : [cap] bool
    parent_heads : [cap] int32 — parent's latest row per key (NULL if none /
                   no parent); the MVCC chain link (paper §III-E)
    Returns (Segment, overflow scalar).
    """
    cap = int(valid.shape[0])
    nb = cap // rows_per_batch
    keys = jnp.where(valid, jnp.asarray(cols[schema.key], jnp.int64),
                     EMPTY_KEY)

    if layout == "row":
        words = schema.encode_rows(cols)
        data = words.reshape(nb, rows_per_batch, schema.width_words)
    else:
        data = {c.name: jnp.asarray(cols[c.name], c.jnp_dtype)
                        .reshape(nb, rows_per_batch)
                for c in schema.columns}

    gids = jnp.arange(cap, dtype=PTR_DTYPE) + PTR_DTYPE(row_base)
    bk, bp, prev_rows, prev_vals, overflow = hix._build_arrays(
        keys, gids, valid, num_buckets, slots)
    index = HashIndex(bk, bp, num_buckets, slots)

    prev = jnp.full((cap,), NULL_PTR, PTR_DTYPE)
    prev = prev.at[prev_rows - PTR_DTYPE(row_base)].set(prev_vals,
                                                        mode="drop")
    # chain the OLDEST row per appended key into the parent's latest row
    need_link = valid & (prev == NULL_PTR) & (parent_heads != NULL_PTR)
    prev = jnp.where(need_link, parent_heads, prev)

    seg = Segment(data=data, index=index, prev=prev, valid=valid,
                  row_base=row_base, layout=layout)
    return seg, overflow


def _build_segment_retrying(cols, valid, parent_heads, schema, *, row_base,
                            rows_per_batch, layout, slots,
                            num_buckets=None, max_retries: int = 5):
    cap = int(valid.shape[0])
    nb = num_buckets or hix.suggest_num_buckets(cap, slots)
    for _ in range(max_retries):
        seg, overflow = make_segment_arrays(
            cols, valid, parent_heads, schema, row_base=row_base,
            rows_per_batch=rows_per_batch, layout=layout, num_buckets=nb,
            slots=slots)
        if int(overflow) == 0:
            return seg
        nb *= 2
    raise RuntimeError("segment index build kept overflowing")


def create_index(cols: dict, schema: Schema, *, rows_per_batch: int = 4096,
                 layout: str = "row", slots: int = hix.DEFAULT_SLOTS,
                 valid=None, reserve: int | None = None,
                 track_hot: int | None = None,
                 hot_mode: str = "topk") -> IndexedTable:
    """Paper Listing 1 ``createIndex``: build the index over a dataframe.

    In the distributed layer this is preceded by the hash-partition shuffle;
    here we build one partition.  The probe-side Snapshot is built eagerly
    as part of the table's stored form (DESIGN.md §3); flat data stays lazy.

    Segment 0 is a **capacity-reserved arena** (DESIGN.md §4): its data /
    index / pointer planes are over-allocated to the power-of-two capacity
    class of the input, fill tracked by the snapshot's ``valid_count``
    scalar (``fill``), so appends within the reserved capacity run as an
    in-place on-device ingest with zero pytree shape change.  ``reserve``
    overrides the class policy: an explicit minimum row capacity, or ``0``
    for no over-allocation (the pre-arena PR-3 write path, kept for the
    segment-chain reference and benchmarks' before/after comparison).
    """
    n = int(next(iter(cols.values())).shape[0])
    reserved = (capacity_class(n, rows_per_batch) if reserve is None
                else pad_to_batches(max(n, int(reserve), 1), rows_per_batch))
    cols_p, valid_p, cap = prepare_cols(cols, schema, rows_per_batch, valid,
                                        min_capacity=reserved)
    heads = jnp.full((cap,), NULL_PTR, PTR_DTYPE)
    seg = _build_segment_retrying(cols_p, valid_p, heads, schema, row_base=0,
                                  rows_per_batch=rows_per_batch,
                                  layout=layout, slots=slots)
    snap = snapshot_from_segments((seg,), layout, schema=schema)
    # track_hot attaches an EMPTY tracker (see with_hot: the created rows
    # are not back-counted — replay-deterministic by construction)
    hot = (None if track_hot is None
           else empty_tracker(track_hot, mode=hot_mode))
    return IndexedTable(segments=(seg,), snapshot=snap, schema=schema,
                        rows_per_batch=rows_per_batch, layout=layout,
                        version=jnp.asarray(0, jnp.int32), slots=slots,
                        hot=hot)


# ---------------------------------------------------------------------------
# Arena append: fused on-device in-place ingest (DESIGN.md §4)
# ---------------------------------------------------------------------------

def _delta_order(keys, valid):
    """Lexsort delta lanes by (key, arrival): the chain/head scaffold.

    Returns ``(order, same, is_head)`` — ``same[i]`` marks a sorted lane
    whose predecessor holds the same key (its backward pointer stays
    intra-delta), ``is_head`` the newest valid lane per key (the lane that
    lands in the bucket planes).
    """
    d = keys.shape[0]
    order = jnp.lexsort((jnp.arange(d, dtype=PTR_DTYPE), keys))
    k_s, v_s = keys[order], valid[order]
    same = jnp.concatenate(
        [jnp.zeros((1,), bool), (k_s[1:] == k_s[:-1]) & v_s[1:] & v_s[:-1]])
    is_head = jnp.concatenate(
        [k_s[1:] != k_s[:-1], jnp.ones((1,), bool)]) & v_s
    return order, same, is_head


# ---------------------------------------------------------------------------
# Hot-key tracker (skew detection, DESIGN.md §15)
# ---------------------------------------------------------------------------

DEFAULT_HOT_TOP_K = 128
SKETCH_DEPTH = 4
SKETCH_WIDTH = 1024


@partial(jax.tree_util.register_dataclass,
         data_fields=["keys", "counts", "sketch"], meta_fields=["mode"])
@dataclasses.dataclass(frozen=True)
class HotTracker:
    """Exact top-k hot-key counts maintained at ingest (DESIGN.md §15).

    Every mutable field is a DATA leaf (the §4 arena trick): the hot set
    changes across appends with ZERO pytree shape change, so the hybrid
    dispatch consuming it never retraces.  Entries live in canonical
    (count desc, key asc) order with ``EMPTY_KEY`` marking vacant slots,
    which makes the ingest-time fold idempotent: merging an all-invalid
    delta (a held ring flush) reproduces the tracker bit-for-bit.

    ``mode="topk"`` keeps Misra-Gries-style counts — exact while the
    distinct-key population fits ``top_k``, a lower bound after
    evictions (an evicted key re-enters at its fresh delta count).
    ``mode="sketch"`` adds count-min planes for unbounded streams:
    counts become CMS upper-bound estimates over the whole history, the
    candidate set is still (tracker ∪ delta heads).
    """

    keys: jax.Array    # [T] int64 — EMPTY_KEY = vacant slot
    counts: jax.Array  # [T] int64 — lower bounds (topk) / CMS estimates
    sketch: object     # [D, W] int64 count-min planes | None (topk mode)
    mode: str          # "topk" | "sketch"


def empty_tracker(top_k: int = DEFAULT_HOT_TOP_K, *, mode: str = "topk",
                  sketch_depth: int = SKETCH_DEPTH,
                  sketch_width: int = SKETCH_WIDTH,
                  num_shards: int | None = None) -> HotTracker:
    """A fresh all-vacant tracker (``num_shards`` stacks the dist leading
    axis — each shard counts its OWN ingest; routing partitions by key,
    so per-shard hot sets are disjoint and a global top-H is a flat merge
    of the per-shard arrays)."""
    if mode not in ("topk", "sketch"):
        raise ValueError(f"tracker mode must be 'topk' or 'sketch', "
                         f"got {mode!r}")
    lead = () if num_shards is None else (num_shards,)
    sketch = (jnp.zeros(lead + (sketch_depth, sketch_width), jnp.int64)
              if mode == "sketch" else None)
    return HotTracker(keys=jnp.full(lead + (top_k,), EMPTY_KEY, jnp.int64),
                      counts=jnp.zeros(lead + (top_k,), jnp.int64),
                      sketch=sketch, mode=mode)


def with_hot(table: IndexedTable, top_k: int = DEFAULT_HOT_TOP_K, *,
             mode: str = "topk", sketch_depth: int = SKETCH_DEPTH,
             sketch_width: int = SKETCH_WIDTH) -> IndexedTable:
    """Attach an empty tracker — ONE treedef change (like adding a queue),
    done before entering jitted loops.  Rows already in the table are NOT
    back-counted: the hot set accumulates from subsequent ingest only, so
    lineage replay (which re-attaches an empty tracker before replaying
    the append log) reproduces the live tracker bit-identically."""
    return dataclasses.replace(table, hot=empty_tracker(
        top_k, mode=mode, sketch_depth=sketch_depth,
        sketch_width=sketch_width))


def _seg_scan(op, vals, newrun):
    """Segmented inclusive scan: ``op`` restarts at every ``newrun`` lane,
    so a run's LAST lane holds the run's full reduction."""
    def comb(a, b):
        af, av = a
        bf, bv = b
        return af | bf, jnp.where(bf, bv, op(av, bv))
    _, out = jax.lax.associative_scan(comb, (newrun, vals))
    return out


def _tracker_top(cand_k, cand_c, top_k: int, *, combine: str):
    """Combine equal candidate keys (``sum`` of exact per-delta counts;
    ``max`` when candidates are whole-history re-estimates), then keep the
    ``top_k`` entries in canonical (count desc, key asc) order.  Vacant
    (EMPTY_KEY / zero-count) lanes sort last, so the result is unique,
    permutation-invariant in the candidates, and idempotent on an
    all-vacant candidate set — a held flush cannot perturb the tracker."""
    n = cand_k.shape[0]
    idx = jnp.arange(n, dtype=jnp.int32)
    o = jnp.lexsort((idx, cand_k))
    k_s = cand_k[o]
    c_s = cand_c[o].astype(jnp.int64)
    newrun = jnp.concatenate([jnp.ones((1,), bool), k_s[1:] != k_s[:-1]])
    is_end = jnp.concatenate([k_s[1:] != k_s[:-1], jnp.ones((1,), bool)])
    op = jnp.add if combine == "sum" else jnp.maximum
    total = _seg_scan(op, c_s, newrun)
    live = is_end & (k_s != EMPTY_KEY) & (total > 0)
    rk = jnp.where(live, k_s, EMPTY_KEY)
    rc = jnp.where(live, total, jnp.int64(0))
    o2 = jnp.lexsort((rk, -rc))
    return rk[o2][:top_k], rc[o2][:top_k]


def _tracker_fold(hkeys, hcounts, hsketch, hot_k, hot_c):
    """Fold per-key delta head counts into the tracker arrays.

    ``hot_k``/``hot_c`` carry one lane per distinct delta key (EMPTY / 0
    elsewhere).  Returns ``(keys, counts, sketch)``; pure sorts and
    scatter-adds — safe inside the fused ingest and under vmap/shard_map.
    """
    top_k = hkeys.shape[0]
    if hsketch is not None:
        depth, width = hsketch.shape
        sk = hsketch
        for r in range(depth):
            slot = jnp.where(hot_k != EMPTY_KEY,
                             hashing.sketch_hash(hot_k, r, width),
                             jnp.int32(width))
            sk = sk.at[r, slot].add(hot_c, mode="drop")
        cand = jnp.concatenate([hkeys, hot_k])
        est = jnp.full(cand.shape, jnp.iinfo(jnp.int64).max, jnp.int64)
        for r in range(depth):
            est = jnp.minimum(est, sk[r, hashing.sketch_hash(cand, r,
                                                             width)])
        est = jnp.where(cand == EMPTY_KEY, jnp.int64(0), est)
        nk, nc = _tracker_top(cand, est, top_k, combine="max")
        return nk, nc, sk
    nk, nc = _tracker_top(jnp.concatenate([hkeys, hot_k]),
                          jnp.concatenate([hcounts, hot_c]),
                          top_k, combine="sum")
    return nk, nc, None


@jax.jit
def _tracker_ingest(hot: HotTracker, keys, valid) -> HotTracker:
    """Standalone delta fold (the promote path): same lexsort scaffold and
    merge as the in-ingest update, so both paths land bit-identical
    trackers for the same delta."""
    order, same, is_head = _delta_order(keys, valid)
    k_s, v_s = keys[order], valid[order]
    cnt = _seg_scan(jnp.add, v_s.astype(jnp.int64), ~same)
    hot_k = jnp.where(is_head, k_s, EMPTY_KEY)
    hot_c = jnp.where(is_head, cnt, jnp.int64(0))
    nk, nc, sk = _tracker_fold(hot.keys, hot.counts, hot.sketch,
                               hot_k, hot_c)
    return dataclasses.replace(hot, keys=nk, counts=nc, sketch=sk)


def _ingest_arrays(state, parent_blocks, cols_p, valid_p, *, schema, layout,
                   rb, bucket_counts, slots):
    """One fused on-device pass over the tail's DEDUPLICATED mutable state.

    ``state`` holds each overwritten buffer exactly once (the donation/
    aliasing rules of DESIGN.md §4): the tail's bucket-pointer plane is the
    snapshot block's ``ptrs`` (always one buffer), and a single-segment
    tail's ``prev`` IS the snapshot's flat ``prev`` (``tprev=None`` then).
    Keeping the signature deduplicated is what makes the donated variant
    legal — XLA rejects the same buffer donated twice, which is exactly
    what jit-of-the-whole-table would do.

    state = dict(bk      [nb, slots] int64  tail bucket keys,
                 bhi/blo [nb, slots] int32  tail block key planes,
                 bptr    [nb, slots] int32  tail head ptrs (index AND block),
                 sprev   [total]     int32  snapshot flat prev,
                 tprev   [cap_t] | None     tail-local prev (None iff rb==0),
                 tvalid  [cap_t] bool,
                 tdata   tail row storage,
                 sdata   flat data | None (None also when single-segment:
                         derived from tdata by reshape at reassembly),
                 hkeys/hcounts/hsketch  hot-key tracker leaves | None
                         (DESIGN.md §15 — folded in this same pass),
                 fill / version scalars)
    Returns (new state, overflow).
    """
    sch = schema
    nb_t, _ = state["bk"].shape
    cap_t = state["tvalid"].shape[0]
    fill_g = state["fill"]
    drop = jnp.int32(2**31 - 1)                     # scatter target: dropped

    keys = jnp.where(valid_p, jnp.asarray(cols_p[sch.key], jnp.int64),
                     EMPTY_KEY)
    # packed row ids: valid delta lanes land at [fill, fill + nv)
    pos = jnp.cumsum(valid_p.astype(PTR_DTYPE)) - 1
    rid_g = jnp.where(valid_p, fill_g.astype(PTR_DTYPE) + pos, drop)
    rid_l = jnp.where(valid_p, rid_g - PTR_DTYPE(rb), drop)
    nv = jnp.sum(valid_p).astype(jnp.int32)

    # -- backward chains (sorted order) -------------------------------------
    order, same, is_head = _delta_order(keys, valid_p)
    k_s, v_s = keys[order], valid_p[order]
    gid_s = jnp.where(v_s, rid_g[order], drop)
    pred = jnp.concatenate([jnp.full((1,), NULL_PTR, PTR_DTYPE),
                            gid_s[:-1]])
    # parent head per key: fused probe of the WHOLE pre-insert snapshot
    # (newest -> oldest across all segments), inside this same jit
    probe_snap = snap_mod.probe_view(
        parent_blocks + (FlatBlock(state["bhi"], state["blo"],
                                   state["bptr"], nb_t),),
        state["sprev"], fill_g, bucket_counts=bucket_counts, layout=layout)
    bids = jnp.stack([hashing.bucket_hash(k_s, nb) for nb in bucket_counts])
    qhi, qlo = hashing.split64(k_s)
    parent_head = kref.fused_probe_ref(bids, qhi, qlo, probe_snap)
    prev_vals = jnp.where(v_s, jnp.where(same, pred, parent_head), NULL_PTR)

    out = dict(state)
    out["sprev"] = state["sprev"].at[jnp.where(v_s, gid_s, drop)
                                     ].set(prev_vals, mode="drop")
    if state["tprev"] is not None:
        out["tprev"] = state["tprev"].at[
            jnp.where(v_s, gid_s - PTR_DTYPE(rb), drop)
        ].set(prev_vals, mode="drop")

    # -- row data (original delta order; invalid lanes scatter-drop) --------
    out["tvalid"] = state["tvalid"].at[rid_l].set(True, mode="drop")
    if layout == "row":
        words = sch.encode_rows({c.name: jnp.asarray(cols_p[c.name],
                                                     c.jnp_dtype)
                                 for c in sch.columns})
        out["tdata"] = (state["tdata"].reshape(cap_t, sch.width_words)
                        .at[rid_l].set(words, mode="drop")
                        .reshape(state["tdata"].shape))
        if state["sdata"] is not None:
            out["sdata"] = state["sdata"].at[rid_g].set(words, mode="drop")
    else:
        out["tdata"] = {
            c.name: (state["tdata"][c.name].reshape(cap_t)
                     .at[rid_l].set(jnp.asarray(cols_p[c.name], c.jnp_dtype),
                                    mode="drop")
                     .reshape(state["tdata"][c.name].shape))
            for c in sch.columns}
        if state["sdata"] is not None:
            out["sdata"] = {
                c.name: (state["sdata"][c.name]
                         .at[rid_g].set(jnp.asarray(cols_p[c.name],
                                                    c.jnp_dtype),
                                        mode="drop"))
                for c in sch.columns}

    # -- bucket/head insert on the tail planes (index + snapshot block) -----
    hk = jnp.where(is_head, k_s, EMPTY_KEY)
    flat_slot, overflow = hix.arena_insert_plan(state["bk"], hk, is_head)
    head_ptr = jnp.where(v_s, gid_s, NULL_PTR)
    hhi, hlo = hashing.split64(hk)
    out["bk"] = (state["bk"].reshape(-1)
                 .at[flat_slot].set(hk, mode="drop").reshape(nb_t, slots))
    out["bhi"] = (state["bhi"].reshape(-1)
                  .at[flat_slot].set(hhi, mode="drop").reshape(nb_t, slots))
    out["blo"] = (state["blo"].reshape(-1)
                  .at[flat_slot].set(hlo, mode="drop").reshape(nb_t, slots))
    out["bptr"] = (state["bptr"].reshape(-1)
                   .at[flat_slot].set(head_ptr, mode="drop")
                   .reshape(nb_t, slots))

    # -- hot-key tracker (skew detection, DESIGN.md §15) --------------------
    # Rides the same lexsort scaffold the chain writer just built — zero
    # extra sorts over the delta, zero host syncs.  ``hk`` already holds
    # each distinct key at its head lane (EMPTY elsewhere); the per-key
    # count is the valid-run total at that lane.  A fully-gated delta (a
    # held flush) folds all-vacant candidates: bit-identical no-op.
    if state["hkeys"] is not None:
        cnt = _seg_scan(jnp.add, v_s.astype(jnp.int64), ~same)
        hot_c = jnp.where(is_head, cnt, jnp.int64(0))
        out["hkeys"], out["hcounts"], out["hsketch"] = _tracker_fold(
            state["hkeys"], state["hcounts"], state["hsketch"], hk, hot_c)

    out["fill"] = fill_g + nv
    out["version"] = state["version"] + 1
    return out, overflow


def _dedup_state(table: IndexedTable) -> dict:
    """The tail's mutable buffers, each exactly once (DESIGN.md §4)."""
    tail = table.segments[-1]
    snap = table.snapshot
    single = len(table.segments) == 1
    hot = table.hot
    return dict(bk=tail.index.bucket_keys,
                bhi=snap.blocks[-1].key_hi,
                blo=snap.blocks[-1].key_lo,
                bptr=snap.blocks[-1].ptrs,
                sprev=snap.prev,
                tprev=None if single else tail.prev,
                tvalid=tail.valid,
                tdata=tail.data,
                sdata=None if single else snap.data,
                hkeys=None if hot is None else hot.keys,
                hcounts=None if hot is None else hot.counts,
                hsketch=None if hot is None else hot.sketch,
                fill=snap.fill,
                version=table.version)


def _reassemble(table: IndexedTable, out: dict) -> IndexedTable:
    """Rebuild the child table from an ingest's output state, restoring
    the aliasing-by-construction invariants: the tail index and snapshot
    block share ONE ptrs plane; a single-segment tail shares its prev (and
    derives flat data by reshape) with the snapshot."""
    tail = table.segments[-1]
    snap = table.snapshot
    sch = table.schema
    single = len(table.segments) == 1
    nb_t = tail.index.num_buckets
    slots = tail.index.slots
    tail_new = dataclasses.replace(
        tail, data=out["tdata"], valid=out["tvalid"],
        prev=out["sprev"] if single else out["tprev"],
        index=HashIndex(out["bk"], out["bptr"], nb_t, slots))
    if snap.data is None:
        sdata = None
    elif single:
        # leading-axis-agnostic reshape: works on [nb, rpb, ...] segment
        # data AND its shard-stacked [s, nb, rpb, ...] form (the dist
        # layer reassembles the stacked table outside the mapped region)
        if table.layout == "row":
            td = out["tdata"]
            sdata = td.reshape(td.shape[:-3] + (-1, sch.width_words))
        else:
            sdata = {c.name: out["tdata"][c.name].reshape(
                         out["tdata"][c.name].shape[:-2] + (-1,))
                     for c in sch.columns}
    else:
        sdata = out["sdata"]
    blk_new = FlatBlock(key_hi=out["bhi"], key_lo=out["blo"],
                        ptrs=out["bptr"], num_buckets=nb_t)
    snap_new = dataclasses.replace(
        snap, blocks=snap.blocks[:-1] + (blk_new,), prev=out["sprev"],
        data=sdata, fill=out["fill"])
    hot = table.hot
    if hot is not None:
        hot = dataclasses.replace(hot, keys=out["hkeys"],
                                  counts=out["hcounts"],
                                  sketch=out["hsketch"])
    return dataclasses.replace(
        table, segments=table.segments[:-1] + (tail_new,),
        snapshot=snap_new, version=out["version"], hot=hot)


def _arena_ingest_core(table: IndexedTable, cols_p: dict, valid_p):
    """Delta -> the parent's arena tail, zero pytree shape change.

    Hashes the delta, writes its bucket/chain planes, links parent heads,
    writes row data, and bumps ``fill``/``version`` — the child is
    structurally equal to the parent, so every jitted read site stays
    compile-cached (DESIGN.md §4).  Pure and collective-free: the
    distributed layer maps it per shard through ``mesh.axis_map``
    unchanged.  Returns ``(child, overflow)``; non-zero overflow means a
    *new* key found its bucket full — the host wrapper discards the child
    and promotes instead (counted, never silent).
    """
    out, overflow = _ingest_arrays(
        _dedup_state(table), table.snapshot.blocks[:-1], cols_p, valid_p,
        schema=table.schema, layout=table.layout,
        rb=table.segments[-1].row_base,
        bucket_counts=table.snapshot.bucket_counts,
        slots=table.slots)
    return _reassemble(table, out), overflow


_arena_ingest = jax.jit(_arena_ingest_core)


@partial(jax.jit, static_argnames=("schema", "layout", "rb",
                                   "bucket_counts", "slots"),
         donate_argnums=(0,))
def _ingest_arrays_donated(state, parent_blocks, cols_p, valid_p, *,
                           schema, layout, rb, bucket_counts, slots):
    """Donated ingest: every buffer in ``state`` is handed to XLA for
    in-place aliasing — true zero-copy appends.  The parent table is
    CONSUMED (its arrays become invalid); MVCC divergence (paper
    Listing 2) needs the non-donated path.  Legal only because ``state``
    is deduplicated — see ``_ingest_arrays``."""
    return _ingest_arrays(state, parent_blocks, cols_p, valid_p,
                          schema=schema, layout=layout, rb=rb,
                          bucket_counts=bucket_counts, slots=slots)


def _arena_fits_core(bucket_keys, keys, valid):
    """Would this delta's new keys overflow the tail's buckets?  Pure —
    ``_flush_core`` folds it into the fused flush; the jitted ``_arena_fits``
    wrapper runs it standalone BEFORE a donated ingest (donation consumes
    the parent, so the overflow -> promote fallback must be decided on the
    intact table)."""
    order, _, is_head = _delta_order(keys, valid)
    hk = jnp.where(is_head, keys[order], EMPTY_KEY)
    _, overflow = hix.arena_insert_plan(bucket_keys, hk, is_head)
    return overflow


_arena_fits = jax.jit(_arena_fits_core)


def _append_promote(table: IndexedTable, cols_p: dict, valid_p, nv: int
                    ) -> IndexedTable:
    """Capacity exhaustion (or bucket overflow): seal the tail and open a
    fresh arena segment at the next capacity class — at least double the
    sealed tail, and large enough for the delta's own class.  One read-site
    recompile per class (new pytree structure), geometrically amortized."""
    rpb = table.rows_per_batch
    tail_cap = table.segments[-1].capacity
    # prepare_cols left-packed the valid rows, so a sparse valid-mask
    # delta can be trimmed to its valid-row class before padding (the
    # class covers nv, not the raw lane count — without the trim a
    # mostly-invalid delta would need a capacity beyond its class)
    keep = pad_to_batches(max(nv, 1), rpb)
    if keep < valid_p.shape[0]:
        cols_p = {k: v[:keep] for k, v in cols_p.items()}
        valid_p = valid_p[:keep]
    new_cap = max(2 * tail_cap, capacity_class(max(nv, 1), rpb),
                  valid_p.shape[0])
    pad = new_cap - valid_p.shape[0]
    cols_r = {k: jnp.pad(v, [(0, pad)] + [(0, 0)] * (v.ndim - 1))
              for k, v in cols_p.items()}
    valid_r = jnp.pad(valid_p, (0, pad))
    keys = jnp.where(valid_r, jnp.asarray(cols_r[table.schema.key],
                                          jnp.int64), EMPTY_KEY)
    heads = table.probe_latest_ref(keys)
    seg = _build_segment_retrying(cols_r, valid_r, heads, table.schema,
                                  row_base=table.capacity,
                                  rows_per_batch=rpb, layout=table.layout,
                                  slots=table.slots)
    snap = extend_snapshot(table.snapshot, seg, schema=table.schema)
    hot = table.hot
    if hot is not None:
        # the promote path bypasses _ingest_arrays; fold the delta here
        # with the same merge so both write paths count identically
        hot = _tracker_ingest(hot, keys, valid_r)
    return dataclasses.replace(table, segments=table.segments + (seg,),
                               snapshot=snap, version=table.version + 1,
                               hot=hot)


def append(table: IndexedTable, cols: dict, valid=None, *,
           mode: str = "arena", donate: bool = False,
           compact_threshold: int | None = None) -> IndexedTable:
    """Paper Listing 1 ``appendRows``: functional append -> new version.

    ``mode="arena"`` (default, DESIGN.md §4): within the tail's reserved
    capacity the delta lands via the jit-compiled in-place ingest — zero
    pytree shape change, so structurally-equal appends hit the compile
    cache at every read site.  On capacity exhaustion (or bucket
    overflow) the tail is sealed and a next-class arena opens (one
    recompile per class); when the segment count then exceeds
    ``compact_threshold`` (default ``DEFAULT_COMPACT_THRESHOLD``) the
    table is compacted so MVCC probe fan-out stays bounded.
    ``donate=True`` additionally donates the parent's buffers to XLA for
    in-place aliasing — the parent table becomes unusable (skip it when
    divergent appends on one parent are needed, paper Listing 2).

    ``mode="segment"`` is the pre-arena path — one exactly-sized delta
    segment per append, parent buffers shared by reference — kept as the
    semantic reference for the equivalence property tests and the
    before/after benchmarks.
    """
    if mode not in ("arena", "segment"):
        raise ValueError(f"append mode must be 'arena' or 'segment', "
                         f"got {mode!r}")
    cols_p, valid_p, cap = prepare_cols(cols, table.schema,
                                        table.rows_per_batch, valid)
    if mode == "segment":
        keys = jnp.where(valid_p,
                         jnp.asarray(cols_p[table.schema.key], jnp.int64),
                         EMPTY_KEY)
        # Head-link probe: the eager segment-looped reference — the fused
        # core's jit would retrace per append on this growing-shape path.
        heads = table.probe_latest_ref(keys)
        seg = _build_segment_retrying(cols_p, valid_p, heads, table.schema,
                                      row_base=table.capacity,
                                      rows_per_batch=table.rows_per_batch,
                                      layout=table.layout,
                                      slots=table.slots)
        snap = extend_snapshot(table.snapshot, seg, schema=table.schema)
        child = dataclasses.replace(table,
                                    segments=table.segments + (seg,),
                                    snapshot=snap,
                                    version=table.version + 1)
        if compact_threshold is not None \
                and child.num_segments > compact_threshold:
            child = compact(child, _bump_version=False)
        return child

    # host syncs below go through jax.device_get — the funnel the
    # benchmarks' SyncCounter instruments, so syncs-per-append is a
    # measured number (the queue's flush path pays ONE of these total)
    nv = int(jax.device_get(jnp.sum(valid_p)))
    if nv <= table.spare_capacity():
        if donate:
            keys = jnp.where(valid_p,
                             jnp.asarray(cols_p[table.schema.key],
                                         jnp.int64), EMPTY_KEY)
            ovf = int(jax.device_get(
                _arena_fits(table.segments[-1].index.bucket_keys,
                            keys, valid_p)))
            if ovf == 0:
                out, _ = _ingest_arrays_donated(
                    _dedup_state(table), table.snapshot.blocks[:-1],
                    cols_p, valid_p, schema=table.schema,
                    layout=table.layout,
                    rb=table.segments[-1].row_base,
                    bucket_counts=table.snapshot.bucket_counts,
                    slots=table.slots)
                return _reassemble(table, out)
        else:
            child, ovf = _arena_ingest(table, cols_p, valid_p)
            if int(jax.device_get(ovf)) == 0:
                return child
    child = _append_promote(table, cols_p, valid_p, nv)
    threshold = (DEFAULT_COMPACT_THRESHOLD if compact_threshold is None
                 else compact_threshold)
    if child.num_segments > threshold:
        child = compact(child, _bump_version=False)
    return child


def coalesce_deltas(deltas, schema: Schema, valids=None):
    """Concatenate N append deltas into ONE delta (host-side numpy).

    Delta ``i``'s rows precede delta ``i+1``'s, and the arena ingest's
    lexsort keys on (key, arrival lane), so landing the coalesced delta
    through one ``append`` yields per-key MVCC chains bit-identical to N
    sequential appends — while paying the per-append host round-trip
    (``_arena_fits`` pre-flight + ``int(fill)`` capacity check) and ingest
    launch ONCE instead of N times.  The coalesced append bumps the
    version once; use sequential appends when each delta must be its own
    queryable version.

    Returns ``(cols, valid)`` — ``valid`` is None when ``valids`` is None
    (every row valid), else the concatenation with per-delta ``None``
    meaning all-valid.
    """
    deltas = list(deltas)
    if not deltas:
        raise ValueError("coalesce_deltas needs at least one delta")
    cols = {c.name: np.concatenate([np.asarray(d[c.name]) for d in deltas])
            for c in schema.columns}
    if valids is None:
        return cols, None
    valids = list(valids)
    if len(valids) != len(deltas):
        raise ValueError(f"{len(valids)} validity masks for "
                         f"{len(deltas)} deltas")
    valid = np.concatenate([
        np.ones(np.shape(np.asarray(d[schema.key]))[0], bool)
        if v is None else np.asarray(v, bool)
        for d, v in zip(deltas, valids)])
    return cols, valid


# ---------------------------------------------------------------------------
# Device-resident append queue (DESIGN.md §13)
# ---------------------------------------------------------------------------

DEFAULT_QUEUE_LANES = 8

# Trace counters for the CI gate (scripts/trace_gate.py): bumped once per
# TRACE of the enqueue/flush cores — a full ring wrap must not retrace.
QUEUE_TRACES = {"enqueue": 0, "flush": 0}


class QueueOverflow(ValueError):
    """A delta does not fit the ring: the lane rows are too small for it,
    or every lane is occupied (flush first — ``frame.append(queued=True)``
    does both automatically)."""


@partial(jax.tree_util.register_dataclass,
         data_fields=["cols", "valid", "fills", "count"],
         meta_fields=["lanes", "lane_rows"])
@dataclasses.dataclass(frozen=True)
class AppendQueue:
    """A fixed-lane ring of pending deltas living beside the arena.

    Every field the ring mutates is a *data leaf* — per-lane fill
    counters and the occupied-lane ``count`` scalar included, the same
    trick as ``Snapshot.fill`` (DESIGN.md §4) — so enqueue and flush are
    pure on-device ops with ZERO pytree shape change: jitted read sites
    and the enqueue/flush sites themselves stay compile-cached across a
    full ring wrap (fill lanes -> flush -> fill again).

    ``cols`` holds one ``[lanes, lane_rows]`` typed plane per schema
    column (layout-agnostic: rows encode at flush, inside the fused
    ingest); ``valid`` masks real rows inside each lane; ``fills[l]`` is
    lane ``l``'s valid-row count; ``count`` is the number of occupied
    lanes (lanes ``[0, count)`` are pending, in enqueue order).  The
    distributed layer stacks a leading ``[num_shards]`` axis on every
    leaf and axis-maps the same enqueue/flush cores per shard.

    Queued rows are NOT part of any table version: they sit outside the
    arena and outside ``fill``, so every reader hard-masks them out
    (``snapshot.probe_view``) until a flush moves them into the arena —
    MVCC snapshot isolation with no reader changes.  Unlike the table,
    the ring is a *staging buffer*, not an MVCC object: the frame owns it
    linearly, and a flush resets it in place.
    """

    cols: dict            # {name: [lanes, lane_rows] typed}
    valid: jax.Array      # [lanes, lane_rows] bool
    fills: jax.Array      # [lanes] int32 — valid rows per lane
    count: jax.Array      # scalar int32 — occupied lanes
    lanes: int
    lane_rows: int

    @property
    def capacity_rows(self) -> int:
        return self.lanes * self.lane_rows

    def nbytes(self) -> int:
        return (sum(int(np.prod(a.shape)) * a.dtype.itemsize
                    for a in self.cols.values())
                + self.valid.size + self.fills.size * 4 + 4)


def _set_queue_mirror(queue: AppendQueue, lanes_used, rows):
    """Host mirror of the pending counts, OUTSIDE the pytree (like
    ``IndexedTable._flatdata``): the facade issues every enqueue, so it
    knows the counts for free — no device sync to answer 'is the ring
    full?' or 'how many rows are pending?'."""
    object.__setattr__(queue, "_host_lanes", int(lanes_used))
    object.__setattr__(queue, "_host_rows", int(rows))
    return queue


def queue_pending(queue: AppendQueue):
    """``(lanes_used, pending_rows)`` as host ints.  Reads the host
    mirror the enqueue/flush wrappers maintain; falls back to ONE device
    sync when the queue came back through a jit boundary (the mirror does
    not survive tracing).  UNDER a trace (the frame itself is a jit
    argument) the counts are unknowable host-side — report (0, 0): the
    ring is reader-invisible anyway, so traced read plans never depend
    on it."""
    lanes_used = getattr(queue, "_host_lanes", None)
    rows = getattr(queue, "_host_rows", None)
    if lanes_used is None or rows is None:
        if isinstance(queue.count, jax.core.Tracer):
            return 0, 0
        count, fills = jax.device_get((queue.count, queue.fills))
        lanes_used = int(np.asarray(count).reshape(-1)[0])
        rows = int(np.asarray(fills)[..., :lanes_used].sum())
        _set_queue_mirror(queue, lanes_used, rows)
    return lanes_used, rows


def empty_queue(schema: Schema, *, lanes: int = DEFAULT_QUEUE_LANES,
                lane_rows: int = 4096,
                num_shards: int | None = None) -> AppendQueue:
    """A fresh all-empty ring (``num_shards`` stacks the dist leading
    axis; per-shard ``count`` scalars stay in lockstep — every enqueue
    touches every shard's ring, possibly with zero valid rows)."""
    lead = () if num_shards is None else (num_shards,)
    cols = {c.name: jnp.zeros(lead + (lanes, lane_rows), c.jnp_dtype)
            for c in schema.columns}
    q = AppendQueue(cols=cols,
                    valid=jnp.zeros(lead + (lanes, lane_rows), bool),
                    fills=jnp.zeros(lead + (lanes,), jnp.int32),
                    count=jnp.zeros(lead, jnp.int32),
                    lanes=lanes, lane_rows=lane_rows)
    return _set_queue_mirror(q, 0, 0)


def reset_queue(queue: AppendQueue) -> AppendQueue:
    """Empty the ring without touching the (stale, masked) column planes."""
    q = dataclasses.replace(queue,
                            valid=jnp.zeros_like(queue.valid),
                            fills=jnp.zeros_like(queue.fills),
                            count=jnp.zeros_like(queue.count))
    return _set_queue_mirror(q, 0, 0)


def _enqueue_core(queue: AppendQueue, lane_cols: dict, lane_valid):
    """Pure on-device scatter of one delta into the next free lane.

    One dynamic-index write per plane at lane ``count`` (scatter-dropped
    if a misuse ever aims past the ring) plus the fill/count bumps —
    zero host syncs, zero pytree shape change.  The distributed layer
    axis-maps this unchanged per shard.
    """
    QUEUE_TRACES["enqueue"] += 1
    c = queue.count
    nv = jnp.sum(lane_valid).astype(jnp.int32)
    cols = {k: queue.cols[k].at[c].set(
                jnp.asarray(lane_cols[k], queue.cols[k].dtype), mode="drop")
            for k in queue.cols}
    valid = queue.valid.at[c].set(jnp.asarray(lane_valid, bool), mode="drop")
    fills = queue.fills.at[c].set(nv, mode="drop")
    count = jnp.minimum(c + 1, jnp.int32(queue.lanes))
    return dataclasses.replace(queue, cols=cols, valid=valid, fills=fills,
                               count=count)


_enqueue = jax.jit(_enqueue_core)
# The ring is linearly owned (see AppendQueue docstring), so donating it
# makes enqueue a true in-place lane write — the hot streaming loop's
# default cost.  The PARENT queue object becomes unusable, exactly like
# a donated table append.
_enqueue_donated = jax.jit(_enqueue_core, donate_argnums=(0,))


def _lane_arrays(queue: AppendQueue, cols: dict, valid):
    """Pad a host delta to one ``[lane_rows]`` lane (+ mask).  Host-side
    shape work only — no device round-trip."""
    n = int(np.shape(cols[next(iter(queue.cols))])[0])
    if n > queue.lane_rows:
        raise QueueOverflow(
            f"delta has {n} rows but queue lanes hold {queue.lane_rows}; "
            f"append() it directly or size the ring with "
            f"with_queue(lane_rows=...)")
    pad = queue.lane_rows - n
    lane_cols = {k: jnp.pad(jnp.asarray(cols[k], q.dtype), (0, pad))
                 for k, q in queue.cols.items()}
    v = (jnp.ones((n,), bool) if valid is None
         else jnp.asarray(valid, bool))
    nv = n if valid is None else int(np.asarray(valid, bool).sum())
    return lane_cols, jnp.pad(v, (0, pad)), nv


def enqueue(queue: AppendQueue, cols: dict, valid=None, *,
            donate: bool = True) -> AppendQueue:
    """Stage one delta in the ring — NO host sync, NO table change.

    The delta becomes visible (and the version bumps, once for the whole
    ring) only at ``flush_queue``.  Raises ``QueueOverflow`` when the
    ring is full or the delta exceeds a lane — the facade's
    ``append(queued=True)`` auto-flushes / falls back.  ``donate=True``
    (default) writes the lane in place; pass ``False`` to keep the parent
    queue object alive (divergent staging is NOT an MVCC feature — the
    ring is linearly owned).
    """
    lanes_used, rows = queue_pending(queue)
    if lanes_used >= queue.lanes:
        raise QueueOverflow(
            f"append queue is full ({queue.lanes} lanes pending); flush() "
            f"first (frame.append(queued=True) does this automatically)")
    lane_cols, lane_valid, nv = _lane_arrays(queue, cols, valid)
    out = (_enqueue_donated if donate else _enqueue)(queue, lane_cols,
                                                     lane_valid)
    return _set_queue_mirror(out, lanes_used + 1, rows + nv)


def _flush_core(state, parent_blocks, queue: AppendQueue, *, schema, layout,
                rb, bucket_counts, slots, cap, axis=None):
    """ONE fused flush: ring -> arena with the pre-flight folded in.

    Flattens the occupied lanes, lexsorts + chains them, probes parent
    heads, and ingests into the arena exactly like ``_ingest_arrays`` —
    but the capacity check AND the bucket-overflow pre-flight
    (``_arena_fits_core``) run inside the same jit, and the ENTIRE write
    is gated on their conjunction ``ok``: when the ring does not fit,
    every scatter drops (all-False valid), ``fill``/version stay put, and
    the ring keeps its contents — the host reads the single ``ok`` flag
    and takes the overflow -> promote path on the intact state.  Under a
    shard axis (``axis``), ``ok`` is psum-reduced so every shard flushes
    or holds *together* (uniform versions across the stacked pytree).

    Works over the tail's DEDUPLICATED state (``_dedup_state``) exactly
    like ``_ingest_arrays``, so the donated variant is legal: a donated
    flush is a true in-place ring -> arena move, the streaming hot
    path's cost.  ``cap`` is the tail's ``row_base + capacity`` (static).

    Returns ``(out_state, ring_after, ok)``.  The only host sync in a
    successful flush is the caller's read of ``ok``.
    """
    QUEUE_TRACES["flush"] += 1
    lanes, lane_rows = queue.lanes, queue.lane_rows
    d = lanes * lane_rows
    occ = jnp.arange(lanes, dtype=jnp.int32) < queue.count       # [lanes]
    valid_flat = (queue.valid & occ[:, None]).reshape(d)
    cols_flat = {k: v.reshape((d,) + v.shape[2:])
                 for k, v in queue.cols.items()}
    keys = jnp.where(valid_flat, jnp.asarray(cols_flat[schema.key],
                                             jnp.int64), EMPTY_KEY)
    nv = jnp.sum(queue.fills * occ.astype(jnp.int32))
    room = jnp.int32(cap) - state["fill"]
    fits = nv <= room
    ovf = _arena_fits_core(state["bk"], keys, valid_flat)
    ok = fits & (ovf == 0)
    if axis is None:
        ok = ok & (nv > 0)
    else:
        bad = jax.lax.psum((~ok).astype(jnp.int32), axis)
        total = jax.lax.psum(nv, axis)
        ok = (bad == 0) & (total > 0)
    gated_valid = valid_flat & ok
    version = state["version"]
    out, _ = _ingest_arrays(
        state, parent_blocks, cols_flat, gated_valid, schema=schema,
        layout=layout, rb=rb, bucket_counts=bucket_counts, slots=slots)
    # _ingest_arrays bumps unconditionally; a held flush must not.
    out["version"] = version + ok.astype(jnp.int32)
    ring = dataclasses.replace(
        queue,
        valid=queue.valid & ~ok,
        fills=jnp.where(ok, 0, queue.fills),
        count=jnp.where(ok, 0, queue.count))
    return out, ring, ok


_FLUSH_STATICS = ("schema", "layout", "rb", "bucket_counts", "slots", "cap",
                  "axis")
_flush = jax.jit(_flush_core, static_argnames=_FLUSH_STATICS)
# Donating state + ring makes flush a true in-place lane -> arena move
# (the table's tail planes are rewritten in place, the ring is cleared in
# place); parent blocks stay shared.  A HELD flush (ok=False) writes the
# state back unchanged and keeps the ring contents, so the promote slow
# path still works off the returned (content-identical) buffers.
_flush_donated = jax.jit(_flush_core, donate_argnums=(0, 2),
                         static_argnames=_FLUSH_STATICS)


def drain_queue(queue: AppendQueue):
    """Ring contents -> host ``(cols, valid=None)`` in enqueue order
    (lane-major; within a lane, arrival order).  The overflow -> promote
    slow path and the resilience layer's ring rebuild use this — the fast
    path never does."""
    cols, valid, count, fills = jax.device_get(
        (queue.cols, queue.valid, queue.count, queue.fills))
    c = int(np.asarray(count).reshape(-1)[0])
    v = np.asarray(valid) & (np.arange(queue.lanes)[:, None] < c)
    flat_v = v.reshape(-1)
    return ({k: np.asarray(a).reshape(-1)[flat_v]
             for k, a in cols.items()}, None)


def flush_queue(table: IndexedTable, queue: AppendQueue, *,
                donate: bool = False,
                compact_threshold: int | None = None):
    """Land the ring in the arena: ONE fused jit + ONE host sync (the
    ``ok`` flag) on the fast path.  Returns ``(table', ring', promoted)``.

    ``donate=True`` trades the parent table AND ring for a true in-place
    move (the streaming loop's cost); the returned pair is the only
    usable version afterwards — same contract as ``append(donate=True)``.

    The overflow -> promote contract: when the ring would blow the tail's
    capacity or buckets, the fused flush holds (bit-identical state, no
    version bump), the ring is drained host-side, and the coalesced delta
    lands through the ordinary ``append`` — which seals the tail and
    opens the next capacity class.  Either way the flush is exactly ONE
    version bump, same as a coalesced list append, and the decoded table
    is bit-identical to having appended the deltas directly (the lane-
    major drain order equals enqueue order — tests/test_queue.py).
    An empty ring is a no-op (no bump, no sync).
    """
    lanes_used, _ = queue_pending(queue)
    if lanes_used == 0:
        return table, queue, False
    tail = table.segments[-1]
    fn = _flush_donated if donate else _flush
    out, ring, ok = fn(_dedup_state(table), table.snapshot.blocks[:-1],
                       queue, schema=table.schema, layout=table.layout,
                       rb=tail.row_base,
                       bucket_counts=table.snapshot.bucket_counts,
                       slots=table.slots,
                       cap=tail.row_base + tail.capacity)
    child = _reassemble(table, out)
    if bool(jax.device_get(ok)):              # THE one host sync per flush
        return child, _set_queue_mirror(ring, 0, 0), False
    # held: child is content-identical to the parent (all scatters
    # dropped, version post-corrected); under donation the PARENT buffers
    # are consumed, so the promote lands on the reassembled child.
    cols, valid = drain_queue(ring)
    child = append(child, cols, valid, donate=donate,
                   compact_threshold=compact_threshold)
    return child, reset_queue(ring), True


def compact(table: IndexedTable, *, reserve: int | None = None,
            _bump_version: bool = True) -> IndexedTable:
    """Merge all segments into one fresh arena (bounds probe fan-out after
    promotions; the paper's cTrie amortizes the same way via trie-node
    sharing).  The result is reserved at the capacity class of the live
    row count, so post-compaction appends re-enter the in-place path."""
    if table.num_segments == 1 and reserve is None:
        return table
    # Host-level: gather valid rows in global (append) order.
    valid_all = np.concatenate([np.asarray(s.valid) for s in table.segments])
    bases = np.concatenate([np.asarray(s.row_base + np.arange(s.capacity))
                            for s in table.segments])
    rids = jnp.asarray(bases[valid_all], PTR_DTYPE)
    cols = table.gather_rows(rids)
    fresh = create_index(cols, table.schema,
                         rows_per_batch=table.rows_per_batch,
                         layout=table.layout, slots=table.slots,
                         reserve=reserve)
    version = table.version + 1 if _bump_version else table.version
    # compaction rewrites storage, not history: the tracker's ingest
    # counts carry through unchanged (DESIGN.md §15)
    return dataclasses.replace(fresh, version=jnp.asarray(version,
                                                          jnp.int32),
                               hot=table.hot)
