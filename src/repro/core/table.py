"""IndexedTable — one partition of the Indexed DataFrame.

Paper §III-C: a partition is (1) a cTrie index pointing at the *latest* row
per key, (2) row batches holding the tabular data, (3) backward pointers
chaining equal-key rows.  Paper §III-E: appends snapshot the index so
divergent children share the parent's state and store only deltas.

TPU adaptation (DESIGN.md §2): a partition is an ordered tuple of immutable
**segments**.  ``create_index`` builds segment 0; every ``append`` creates a
new segment holding only the delta — data batches, a delta hash index over
the appended keys, and backward pointers whose *oldest* appended row chains
into the parent's latest row for that key.  Parent segments are shared by
reference (JAX arrays are immutable buffers), which is exactly the paper's
persistent-data-structure scheme with zero-copy snapshots — Listing 2's
divergent appends work with no copy-on-write.

Row storage is batch-granular: a segment's data is ``[num_batches,
rows_per_batch, width_words] int32`` (row layout) or per-column typed arrays
(columnar layout).  ``rows_per_batch`` is the paper's Fig-5 knob.

The read hot path (probe -> chain walk -> gather) runs **fused** over the
table's stored ``Snapshot`` (core/snapshot.py, DESIGN.md §3): ragged
per-segment bucket planes (split int64 keys), one flat backward-pointer
array, and optional contiguous data for single-gather decode.  The snapshot
is part of the table's *pytree form* — ``create_index`` builds it eagerly,
``append`` extends it incrementally — so jitted call sites that take the
table as an argument trace it as leaves instead of rebuilding it in-graph.
The original segment-looped methods survive as ``*_ref`` and anchor the
parity tests.

Everything here is written to be **vmap-friendly over a leading shard
axis**: the inner segment constructor is pure (no host branching), padding
rows carry ``valid=False`` and an EMPTY key, and the overflow-doubling retry
lives in thin host wrappers.  dist/dtable.py stacks whole tables (segments
AND snapshot) across shards and vmaps these same functions.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hashindex as hix
from repro.core import snapshot as snap_mod
from repro.core.hashindex import EMPTY_KEY, HashIndex
from repro.core.pointers import NULL_PTR, PTR_DTYPE
from repro.core.schema import Schema
from repro.core.snapshot import (FlatBlock, Snapshot, extend_snapshot,
                                 snapshot_from_segments)
# kernels only imports leaf core modules (hashing/hashindex/pointers/
# snapshot), so this does not cycle; importing here (not inside methods)
# keeps module constants from being created inside an active jit trace.
from repro.kernels import ops as kops

# Back-compat alias: PR-1 exported the probe-side view as ``FlatView``.
FlatView = Snapshot

# ---------------------------------------------------------------------------
# Segment
# ---------------------------------------------------------------------------

@partial(jax.tree_util.register_dataclass,
         data_fields=["data", "index", "prev", "valid"],
         meta_fields=["row_base", "layout"])
@dataclasses.dataclass(frozen=True)
class Segment:
    """One immutable append unit (segment 0 = the created index)."""

    data: object          # [nb, rpb, W] int32  |  dict[name -> [nb, rpb] typed]
    index: HashIndex      # delta index: key -> GLOBAL row id (latest in segment)
    prev: jax.Array       # [nb*rpb] int32 — backward ptrs, GLOBAL row ids
    valid: jax.Array      # [nb*rpb] bool — False for padding rows
    row_base: int         # global row id of this segment's row 0
    layout: str

    @property
    def capacity(self) -> int:
        return self.prev.shape[-1]

    def data_nbytes(self) -> int:
        if self.layout == "row":
            return self.data.size * 4
        return sum(int(np.prod(a.shape)) * a.dtype.itemsize
                   for a in self.data.values())

    def index_nbytes(self) -> int:
        return self.index.nbytes + self.prev.size * 4 + self.valid.size


@partial(jax.tree_util.register_dataclass,
         data_fields=["segments", "snapshot"],
         meta_fields=["schema", "rows_per_batch", "layout", "version",
                      "slots"])
@dataclasses.dataclass(frozen=True)
class IndexedTable:
    """A fully functional (immutable) indexed partition with MVCC versions.

    ``snapshot`` is the stored read-optimized form (DESIGN.md §3): both the
    segments and the snapshot are pytree data, so the table round-trips
    through jit/vmap with the fused-path arrays as leaves.
    """

    segments: tuple[Segment, ...]
    snapshot: Snapshot
    schema: Schema
    rows_per_batch: int
    layout: str           # "row" | "columnar"
    version: int          # paper §III-D: bumped per append; stale detection
    slots: int

    # -- shape facts ----------------------------------------------------------
    @property
    def capacity(self) -> int:
        return self.segments[-1].row_base + self.segments[-1].capacity

    @property
    def num_segments(self) -> int:
        return len(self.segments)

    def num_rows(self):
        """Valid (non-padding) rows; array under trace, int when concrete."""
        return sum(jnp.sum(s.valid) for s in self.segments)

    def data_nbytes(self) -> int:
        return sum(s.data_nbytes() for s in self.segments)

    def index_nbytes(self) -> int:
        """Index memory overhead — the paper's Fig-11 measurement."""
        return sum(s.index_nbytes() for s in self.segments)

    # -- snapshot access (fused-path representation, DESIGN.md §3) -------------

    def flat_view(self) -> Snapshot:
        """The stored Snapshot for this version (a field access — the view
        is built eagerly by ``create_index`` and extended by ``append``)."""
        return self.snapshot

    def with_flat_data(self) -> "IndexedTable":
        """This table with the snapshot's flat data materialized.

        Use before passing the table as a jit *argument* to call sites that
        decode rows (``gather_rows`` / ``joins.indexed_lookup``): with the
        data on board, the whole fused pipeline traces as stored leaves —
        zero in-graph rebuilds.  Appends carry materialized data forward.
        This is the ONLY way the stored pytree gains the data leaf — host
        reads never mutate the table's structure (jit caches and captured
        treedefs stay valid).
        """
        if self.snapshot.data is not None:
            return self
        return dataclasses.replace(
            self, snapshot=dataclasses.replace(self.snapshot,
                                               data=self._flat_data()))

    def _flat_data(self):
        """Flat data for single-gather decode.  Prefers the snapshot's
        stored copy; otherwise builds it once and caches it on the host
        instance (``_flatdata``, deliberately OUTSIDE the pytree: the
        table's structure must not change as a side effect of a read)."""
        d = self.snapshot.data
        if d is not None:
            return d
        d = getattr(self, "_flatdata", None)
        if d is None:
            d = snap_mod.flat_data_from_segments(self.segments, self.schema,
                                                 self.layout)
            leaves = jax.tree_util.tree_leaves(d)
            if not any(isinstance(a, jax.core.Tracer) for a in leaves):
                object.__setattr__(self, "_flatdata", d)
        return d

    # -- point operations ------------------------------------------------------
    #
    # The default path is the FUSED one: probe -> chain walk -> gather runs
    # against the Snapshot in one pass (Pallas kernel on TPU, vectorized flat
    # gathers elsewhere).  The *_ref methods keep the original segment-looped
    # code as the semantic reference the parity tests sweep against.

    def probe_latest(self, keys, *, fused: bool = True) -> jax.Array:
        """Global row id of the *latest* row per key (NULL_PTR if absent).

        Probes delta indexes newest -> oldest and takes the first hit —
        the cTrie-snapshot read path of paper §III-E.
        """
        if not fused:
            return self.probe_latest_ref(keys)
        return kops.fused_probe(keys, self.snapshot)

    def probe_latest_ref(self, keys) -> jax.Array:
        """Segment-looped reference: one full probe per delta index."""
        keys = jnp.asarray(keys, jnp.int64)
        out = jnp.full(keys.shape, NULL_PTR, PTR_DTYPE)
        for seg in reversed(self.segments):
            hit = hix.probe(seg.index, keys)
            out = jnp.where(out == NULL_PTR, hit, out)
        return out

    def gather_prev(self, rids, *, fused: bool = True) -> jax.Array:
        """prev[rid] across segments (NULL for NULL/out-of-range input)."""
        if not fused:
            return self.gather_prev_ref(rids)
        prev = self.snapshot.prev
        cap = self.snapshot.capacity
        rids = jnp.asarray(rids, PTR_DTYPE)
        in_range = (rids >= 0) & (rids < cap)
        got = prev[jnp.clip(rids, 0, cap - 1)]
        return jnp.where(in_range, got, NULL_PTR)

    def gather_prev_ref(self, rids) -> jax.Array:
        """Segment-looped reference: re-scans every segment per call."""
        rids = jnp.asarray(rids, PTR_DTYPE)
        out = jnp.full(rids.shape, NULL_PTR, PTR_DTYPE)
        for seg in self.segments:
            local = rids - seg.row_base
            in_seg = (local >= 0) & (local < seg.capacity)
            got = seg.prev[jnp.clip(local, 0, seg.capacity - 1)]
            out = jnp.where(in_seg, got, out)
        return out

    def lookup(self, keys, max_matches: int, *, fused: bool = True):
        """[Q] keys -> ([Q, max_matches] global row ids newest-first,
        truncated flags).  Paper's point-lookup: cTrie probe + backward-
        pointer traversal — fused into one pass over the Snapshot."""
        if not fused:
            return self.lookup_ref(keys, max_matches)
        return kops.fused_lookup(keys, self.snapshot,
                                 max_matches=max_matches)

    def lookup_ref(self, keys, max_matches: int):
        """Segment-looped reference lookup (the pre-fusion hot path)."""
        head = self.probe_latest_ref(keys)

        def step(cur, _):
            nxt = jnp.where(cur >= 0, self.gather_prev_ref(cur), NULL_PTR)
            return nxt, cur

        last, rows = jax.lax.scan(step, head, None, length=max_matches)
        return jnp.moveaxis(rows, 0, 1), last >= 0

    def gather_rows(self, rids, names=None, *, fused: bool = True) -> dict:
        """Decode rows for global row ids (zeros where rid out of range)."""
        if not fused:
            return self.gather_rows_ref(rids, names=names)
        data = self._flat_data()
        rids = jnp.asarray(rids, PTR_DTYPE)
        in_range = (rids >= 0) & (rids < self.capacity)
        safe = jnp.clip(rids, 0, self.capacity - 1)
        if self.layout == "row":
            flat = jnp.where(in_range[..., None], data[safe], 0)
            return self.schema.decode_rows(flat, names=names)
        out = {}
        for name in (names or self.schema.names):
            col = self.schema.column(name)
            out[name] = jnp.where(in_range, data[name][safe],
                                  jnp.zeros((), col.jnp_dtype))
        return out

    def gather_rows_ref(self, rids, names=None) -> dict:
        """Segment-looped reference: one masked pass per segment."""
        rids = jnp.asarray(rids, PTR_DTYPE)
        if self.layout == "row":
            w = self.schema.width_words
            flat = jnp.zeros(rids.shape + (w,), jnp.int32)
            for seg in self.segments:
                local = rids - seg.row_base
                in_seg = (local >= 0) & (local < seg.capacity)
                lc = jnp.clip(local, 0, seg.capacity - 1)
                got = seg.data.reshape(seg.capacity, w)[lc]
                flat = jnp.where(in_seg[..., None], got, flat)
            return self.schema.decode_rows(flat, names=names)
        out = {}
        for name in (names or self.schema.names):
            col = self.schema.column(name)
            acc = jnp.zeros(rids.shape, col.jnp_dtype)
            for seg in self.segments:
                local = rids - seg.row_base
                in_seg = (local >= 0) & (local < seg.capacity)
                lc = jnp.clip(local, 0, seg.capacity - 1)
                arr = seg.data[name].reshape(-1)
                acc = jnp.where(in_seg, arr[lc], acc)
            out[name] = acc
        return out

    def scan_column(self, name: str):
        """Full column scan (baseline path) -> (values, valid)."""
        parts, valid = [], []
        for seg in self.segments:
            if self.layout == "row":
                w = self.schema.width_words
                flat = seg.data.reshape(seg.capacity, w)
                vals = self.schema.decode_rows(flat, names=(name,))[name]
            else:
                vals = seg.data[name].reshape(-1)
            parts.append(vals)
            valid.append(seg.valid)
        return jnp.concatenate(parts), jnp.concatenate(valid)


# ---------------------------------------------------------------------------
# Segment construction (vmap-friendly core + host wrappers)
# ---------------------------------------------------------------------------

def pad_to_batches(n: int, rows_per_batch: int) -> int:
    nb = max(1, -(-n // rows_per_batch))
    return nb * rows_per_batch


def prepare_cols(cols: dict, schema: Schema, rows_per_batch: int,
                 valid=None):
    """Pad columns to a batch multiple; returns (padded cols, valid, cap)."""
    n = int(next(iter(cols.values())).shape[0])
    cap = pad_to_batches(n, rows_per_batch)
    pad = cap - n
    out = {}
    for c in schema.columns:
        a = jnp.asarray(cols[c.name], c.jnp_dtype)
        out[c.name] = jnp.pad(a, (0, pad))
    if valid is None:
        valid = jnp.ones((n,), bool)
    valid = jnp.pad(jnp.asarray(valid, bool), (0, pad))
    return out, valid, cap


def make_segment_arrays(cols: dict, valid, parent_heads, schema: Schema, *,
                        row_base: int, rows_per_batch: int, layout: str,
                        num_buckets: int, slots: int):
    """Pure segment constructor (jit/vmap-friendly).

    cols         : dict of [cap]-padded typed columns
    valid        : [cap] bool
    parent_heads : [cap] int32 — parent's latest row per key (NULL if none /
                   no parent); the MVCC chain link (paper §III-E)
    Returns (Segment, overflow scalar).
    """
    cap = int(valid.shape[0])
    nb = cap // rows_per_batch
    keys = jnp.where(valid, jnp.asarray(cols[schema.key], jnp.int64),
                     EMPTY_KEY)

    if layout == "row":
        words = schema.encode_rows(cols)
        data = words.reshape(nb, rows_per_batch, schema.width_words)
    else:
        data = {c.name: jnp.asarray(cols[c.name], c.jnp_dtype)
                        .reshape(nb, rows_per_batch)
                for c in schema.columns}

    gids = jnp.arange(cap, dtype=PTR_DTYPE) + PTR_DTYPE(row_base)
    bk, bp, prev_rows, prev_vals, overflow = hix._build_arrays(
        keys, gids, valid, num_buckets, slots)
    index = HashIndex(bk, bp, num_buckets, slots)

    prev = jnp.full((cap,), NULL_PTR, PTR_DTYPE)
    prev = prev.at[prev_rows - PTR_DTYPE(row_base)].set(prev_vals,
                                                        mode="drop")
    # chain the OLDEST row per appended key into the parent's latest row
    need_link = valid & (prev == NULL_PTR) & (parent_heads != NULL_PTR)
    prev = jnp.where(need_link, parent_heads, prev)

    seg = Segment(data=data, index=index, prev=prev, valid=valid,
                  row_base=row_base, layout=layout)
    return seg, overflow


def _build_segment_retrying(cols, valid, parent_heads, schema, *, row_base,
                            rows_per_batch, layout, slots,
                            num_buckets=None, max_retries: int = 5):
    cap = int(valid.shape[0])
    nb = num_buckets or hix.suggest_num_buckets(cap, slots)
    for _ in range(max_retries):
        seg, overflow = make_segment_arrays(
            cols, valid, parent_heads, schema, row_base=row_base,
            rows_per_batch=rows_per_batch, layout=layout, num_buckets=nb,
            slots=slots)
        if int(overflow) == 0:
            return seg
        nb *= 2
    raise RuntimeError("segment index build kept overflowing")


def create_index(cols: dict, schema: Schema, *, rows_per_batch: int = 4096,
                 layout: str = "row", slots: int = hix.DEFAULT_SLOTS,
                 valid=None) -> IndexedTable:
    """Paper Listing 1 ``createIndex``: build the index over a dataframe.

    In the distributed layer this is preceded by the hash-partition shuffle;
    here we build one partition.  The probe-side Snapshot is built eagerly
    as part of the table's stored form (DESIGN.md §3); flat data stays lazy.
    """
    cols_p, valid_p, cap = prepare_cols(cols, schema, rows_per_batch, valid)
    heads = jnp.full((cap,), NULL_PTR, PTR_DTYPE)
    seg = _build_segment_retrying(cols_p, valid_p, heads, schema, row_base=0,
                                  rows_per_batch=rows_per_batch,
                                  layout=layout, slots=slots)
    snap = snapshot_from_segments((seg,), layout, schema=schema)
    return IndexedTable(segments=(seg,), snapshot=snap, schema=schema,
                        rows_per_batch=rows_per_batch, layout=layout,
                        version=0, slots=slots)


def append(table: IndexedTable, cols: dict, valid=None) -> IndexedTable:
    """Paper Listing 1 ``appendRows``: functional append -> new version.

    O(|delta|) work; the parent's segments are shared by reference (the
    cTrie-snapshot analog).  Divergent appends on one parent (paper
    Listing 2) both succeed and coexist.  The child's snapshot extends the
    parent's incrementally: only the delta's block is computed, parent
    blocks are shared, and flat data is carried only if materialized.
    """
    cols_p, valid_p, cap = prepare_cols(cols, table.schema,
                                        table.rows_per_batch, valid)
    keys = jnp.where(valid_p,
                     jnp.asarray(cols_p[table.schema.key], jnp.int64),
                     EMPTY_KEY)
    # Head-link probe: always the eager segment-looped reference.  The
    # fused path's jitted core would retrace per append (shapes grow every
    # version); a one-shot probe over |delta| keys amortizes nothing.
    heads = table.probe_latest_ref(keys)
    seg = _build_segment_retrying(cols_p, valid_p, heads, table.schema,
                                  row_base=table.capacity,
                                  rows_per_batch=table.rows_per_batch,
                                  layout=table.layout, slots=table.slots)
    snap = extend_snapshot(table.snapshot, seg, schema=table.schema)
    return dataclasses.replace(table, segments=table.segments + (seg,),
                               snapshot=snap, version=table.version + 1)


def compact(table: IndexedTable) -> IndexedTable:
    """Merge all segments into one (bounds probe fan-out after many appends;
    the paper's cTrie amortizes the same way via trie-node sharing)."""
    if table.num_segments == 1:
        return table
    # Host-level: gather valid rows in global (append) order.
    valid_all = np.concatenate([np.asarray(s.valid) for s in table.segments])
    bases = np.concatenate([np.asarray(s.row_base + np.arange(s.capacity))
                            for s in table.segments])
    rids = jnp.asarray(bases[valid_all], PTR_DTYPE)
    cols = table.gather_rows(rids)
    fresh = create_index(cols, table.schema,
                         rows_per_batch=table.rows_per_batch,
                         layout=table.layout, slots=table.slots)
    return dataclasses.replace(fresh, version=table.version + 1)
